//! Async sweep driver: DmSGD vs DecentLaM vs PmSGD on a 16-node ring as
//! node-clock heterogeneity grows — the clock layer's time-to-target
//! demonstration (DESIGN.md §8). Every source of randomness (data,
//! topology, clock draws) is seeded, so two identical invocations print
//! byte-identical output.
//!
//! ```bash
//! cargo run --release --example async_sweep
//! cargo run --release --example async_sweep -- --nodes 8 --steps 80
//! cargo run --release --example async_sweep -- --tau 3 --jitter 0.3
//! cargo run --release --example async_sweep -- --spread 4   # one column
//! ```

use decentlam::experiments::fig_async;
use decentlam::util::cli::Args;
use decentlam::util::table::{sig, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut opts = fig_async::Opts::default();
    opts.steps = 120;
    opts.apply_args(&args)?;

    let (rows, table) = fig_async::run(&opts)?;
    println!("{}", table.render());

    // The bias-gap view: absolute eval-loss degradation relative to each
    // method's own uniform (spread=1) cell, side by side. `degradation`
    // returns empty when the sweep lacks a spread=1 baseline — no
    // verdict then.
    let dm = fig_async::degradation(&rows, "dmsgd");
    let dl = fig_async::degradation(&rows, "decentlam");
    if dm.is_empty() || dl.is_empty() {
        println!("verdict: n/a (sweep has no spread=1 baseline to compare against)");
        return Ok(());
    }
    let mut gap = Table::new(
        "eval-loss degradation vs spread=1 at matched simulated budget (lower = more robust)",
        &["spread", "dmsgd", "decentlam", "decentlam - dmsgd"],
    );
    let mut decentlam_no_worse = true;
    for ((spread, dmd), (_, dld)) in dm.iter().zip(&dl) {
        gap.row(vec![
            format!("{spread}"),
            format!("{dmd:+.4}"),
            format!("{dld:+.4}"),
            format!("{:+.4}", dld - dmd),
        ]);
        if *spread > 1.0 && *dld > dmd + 1e-9 {
            decentlam_no_worse = false;
        }
    }
    println!("{}", gap.render());
    println!(
        "{}",
        if decentlam_no_worse {
            "verdict: DecentLaM's eval loss degrades no faster than DmSGD's under stragglers"
        } else {
            "verdict: DecentLaM degraded FASTER than DmSGD on this sweep"
        }
    );

    // Wall-clock view: rounds each pattern fit into the shared budget.
    let mut wall = Table::new(
        "rounds inside the budget (gossip pipelines; all-reduce barriers wait)",
        &["spread", "gossip rounds", "pmsgd rounds", "gossip sim s", "pmsgd sim s"],
    );
    for (spread, _) in &dl {
        let g = rows.iter().find(|r| r.method == "decentlam" && r.spread == *spread);
        let p = rows.iter().find(|r| r.method == "pmsgd" && r.spread == *spread);
        if let (Some(g), Some(p)) = (g, p) {
            wall.row(vec![
                format!("{spread}"),
                g.steps.to_string(),
                p.steps.to_string(),
                sig(g.sim_s, 4),
                sig(p.sim_s, 4),
            ]);
        }
    }
    println!("{}", wall.render());
    Ok(())
}
