//! Compression sweep driver: loss vs wire bytes for every gossip
//! payload codec (fp32 / fp16 / stochastic int8 / top-k) on rings at
//! n ∈ {16, 64} — the codec layer's demonstration (DESIGN.md §7).
//! Every source of randomness (data, topology, stochastic rounding) is
//! seeded, so two identical invocations print byte-identical output.
//!
//! ```bash
//! cargo run --release --example compression_sweep
//! cargo run --release --example compression_sweep -- --nodes 16 --steps 100
//! cargo run --release --example compression_sweep -- --codec topk,k=0.01
//! cargo run --release --example compression_sweep -- --smoke   # CI gate:
//!     # fp32 bitwise == pre-codec engine; int8 reruns byte-identical,
//!     # parallel == serial, ≥3.9x byte cut, eval loss within 5%
//! ```

use decentlam::experiments::fig_compression;
use decentlam::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.get_bool("smoke") {
        return fig_compression::smoke(&args);
    }

    let mut opts = fig_compression::Opts::default();
    opts.apply_args(&args)?;
    let (rows, table) = fig_compression::run(&opts)?;
    println!("{}", table.render());

    // Headline view: per (n, method), the byte cut each lossy codec
    // buys and the eval-loss premium it costs relative to fp32.
    for &n in &opts.nodes_list {
        for method in &opts.methods {
            let Some(fp32) = rows
                .iter()
                .find(|r| r.nodes == n && &r.method == method && r.codec.starts_with("fp32"))
            else {
                continue;
            };
            for row in rows.iter().filter(|r| {
                r.nodes == n && &r.method == method && !r.codec.starts_with("fp32")
            }) {
                let premium = 100.0 * (row.eval_loss - fp32.eval_loss) / fp32.eval_loss.abs();
                println!(
                    "n={n} {method} {}: {:.2}x fewer bytes, eval loss {premium:+.2}% vs fp32",
                    row.codec, row.ratio_vs_fp32
                );
            }
        }
    }
    Ok(())
}
