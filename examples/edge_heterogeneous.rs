//! EdgeAI scenario (paper §2 "decentralized methods on heterogeneous
//! data"): strongly non-iid nodes (Dirichlet α = 0.05 — each node sees
//! essentially 1–2 classes), small batch, sparse time-varying topology.
//! DecentLaM is pitched for data centers but must also survive this
//! regime; compare it against DSGD, DmSGD and QG-DmSGD (the concurrent
//! work designed exactly for EdgeAI).
//!
//! ```bash
//! cargo run --release --example edge_heterogeneous -- --steps 400
//! ```

use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::mlp;
use decentlam::util::cli::Args;
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::table::{pct, sig, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 400)?;
    let nodes = args.get_usize("nodes", 8)?;
    let alpha = args.get_f64("alpha", 0.05)?;

    let probe = ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 768,
        eval_samples: 1024,
        dirichlet_alpha: alpha,
        seed: 3,
        ..Default::default()
    });
    println!(
        "heterogeneity: mean TV distance of node label dists = {:.3} (0 = iid)",
        probe.heterogeneity()
    );
    for (rank, shard) in probe.shards.iter().enumerate().take(4) {
        println!("  node {rank} label histogram: {:?}", shard.label_histogram(10));
    }

    let mut table = Table::new(
        &format!("EdgeAI — α={alpha}, bipartite random-match topology, batch 256"),
        &["optimizer", "val acc", "final train loss", "consensus"],
    );
    for optimizer in ["dsgd", "dmsgd", "qg-dmsgd", "decentlam"] {
        let data = ClassificationData::generate(&SynthSpec {
            nodes,
            samples_per_node: 768,
            eval_samples: 1024,
            dirichlet_alpha: alpha,
            seed: 3,
            ..Default::default()
        });
        let wl = mlp::workload(mlp::MlpArch::family("mlp-s")?, data, 32, 3);
        let mut cfg = Config::default();
        cfg.optimizer = optimizer.into();
        cfg.topology = "bipartite".into();
        cfg.nodes = nodes;
        cfg.steps = steps;
        cfg.total_batch = 256;
        cfg.micro_batch = 32;
        cfg.lr = 0.04;
        cfg.linear_scaling = false;
        cfg.momentum = 0.9;
        cfg.schedule = LrSchedule::WarmupStep {
            warmup_steps: steps / 20,
            milestones: vec![steps / 2, 3 * steps / 4],
        };
        cfg.seed = 3;
        let mut t = Trainer::new(cfg, wl)?;
        let r = t.run();
        table.row(vec![
            optimizer.into(),
            pct(r.final_accuracy),
            sig(*r.losses.last().unwrap(), 4),
            sig(r.final_consensus, 3),
        ]);
    }
    println!("\n{}", table.render());
    Ok(())
}
