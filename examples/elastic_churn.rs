//! Elastic-churn sweep driver: DmSGD vs DecentLaM vs PmSGD on a ring
//! whose roster grows and shrinks mid-run — the elastic layer's
//! bias-under-churn demonstration (DESIGN.md §9). Every source of
//! randomness (data, topology, churn schedule) is seeded, so two
//! identical invocations print byte-identical output.
//!
//! ```bash
//! cargo run --release --example elastic_churn
//! cargo run --release --example elastic_churn -- --nodes 8 --capacity 12 --steps 80
//! cargo run --release --example elastic_churn -- --rate 0.05   # one column
//! ```

use decentlam::experiments::fig_elastic;
use decentlam::util::cli::Args;
use decentlam::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut opts = fig_elastic::Opts::default();
    opts.steps = 120;
    opts.apply_args(&args)?;

    let (rows, table) = fig_elastic::run(&opts)?;
    println!("{}", table.render());

    // The bias-gap view: eval-loss degradation relative to each
    // method's own churn-free cell, side by side. `degradation`
    // returns empty when the sweep lacks a rate=0 baseline — no
    // verdict then.
    let dm = fig_elastic::degradation(&rows, "dmsgd");
    let dl = fig_elastic::degradation(&rows, "decentlam");
    if dm.is_empty() || dl.is_empty() {
        println!("verdict: n/a (sweep has no rate=0 baseline to compare against)");
        return Ok(());
    }
    let mut gap = Table::new(
        "eval-loss degradation vs churn-free (lower = more robust to membership churn)",
        &["rate", "dmsgd", "decentlam", "decentlam - dmsgd"],
    );
    let mut decentlam_no_worse = true;
    for ((rate, dmd), (_, dld)) in dm.iter().zip(&dl) {
        gap.row(vec![
            format!("{rate}"),
            format!("{dmd:+.4}"),
            format!("{dld:+.4}"),
            format!("{:+.4}", dld - dmd),
        ]);
        if *rate > 0.0 && *dld > dmd + 1e-9 {
            decentlam_no_worse = false;
        }
    }
    println!("{}", gap.render());
    println!(
        "{}",
        if decentlam_no_worse {
            "verdict: DecentLaM's eval loss degrades no faster than DmSGD's under churn"
        } else {
            "verdict: DecentLaM degraded FASTER than DmSGD on this sweep"
        }
    );

    // Roster view: how much the fleet actually moved per rate.
    let mut fleet = Table::new(
        "realized membership churn (decentlam cells)",
        &["rate", "joins", "leaves", "final n"],
    );
    for row in rows.iter().filter(|r| r.method == "decentlam") {
        fleet.row(vec![
            format!("{}", row.rate),
            row.joins.to_string(),
            row.leaves.to_string(),
            row.final_nodes.to_string(),
        ]);
    }
    println!("{}", fleet.render());
    Ok(())
}
