//! Fault sweep driver: DecentLaM vs DmSGD on a 32-node ring as node
//! dropout grows — the sim layer's bias-gap demonstration (DESIGN.md
//! §6). Every source of randomness (data, topology, fault schedule) is
//! seeded, so two identical invocations print byte-identical output.
//!
//! ```bash
//! cargo run --release --example fault_sweep
//! cargo run --release --example fault_sweep -- --nodes 16 --steps 100
//! cargo run --release --example fault_sweep -- --straggle 0.1 --stale 0.05
//! cargo run --release --example fault_sweep -- --smoke   # CI: all ten
//!                                                        # optimizers under
//!                                                        # faults, assert
//!                                                        # finite losses
//! ```

use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::experiments::fig_faults;
use decentlam::grad::mlp;
use decentlam::optim;
use decentlam::util::cli::Args;
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::table::{sig, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.get_bool("smoke") {
        return smoke(&args);
    }

    let mut opts = fig_faults::Opts::default();
    opts.nodes = 32;
    opts.steps = 160;
    opts.drop_rates = vec![0.0, 0.1, 0.3];
    opts.apply_args(&args)?;

    let (rows, table) = fig_faults::run(&opts)?;
    println!("{}", table.render());

    // The bias-gap view: per-method consensus degradation relative to
    // its own fault-free run, side by side. `degradation` returns
    // empty when the sweep lacks a drop=0 baseline — no verdict then.
    let dm = fig_faults::degradation(&rows, "dmsgd");
    let dl = fig_faults::degradation(&rows, "decentlam");
    if dm.is_empty() || dl.is_empty() {
        println!("verdict: n/a (sweep has no drop=0 baseline to compare against)");
        return Ok(());
    }
    let mut gap = Table::new(
        "consensus degradation vs fault-free (lower = more robust)",
        &["drop", "dmsgd", "decentlam", "decentlam/dmsgd"],
    );
    let mut decentlam_no_faster = true;
    for ((rate, dmf), (_, dlf)) in dm.iter().zip(&dl) {
        gap.row(vec![
            format!("{rate}"),
            sig(*dmf, 3),
            sig(*dlf, 3),
            sig(dlf / dmf, 3),
        ]);
        // Slack: "no faster" up to 5% measurement noise.
        if *rate > 0.0 && *dlf > dmf * 1.05 {
            decentlam_no_faster = false;
        }
    }
    println!("{}", gap.render());
    println!(
        "{}",
        if decentlam_no_faster {
            "verdict: DecentLaM's consensus degrades no faster than DmSGD's"
        } else {
            "verdict: DecentLaM degraded FASTER than DmSGD on this sweep"
        }
    );
    Ok(())
}

/// CI smoke: every optimizer trains 50 steps on a tiny faulty ring with
/// a fixed seed and must keep finite losses. Exits nonzero on failure.
/// (The pmsgd rows are fault-free controls: pure all-reduce traffic
/// bypasses the decentralized fault model — DESIGN.md §6.)
fn smoke(args: &Args) -> anyhow::Result<()> {
    let nodes = 6;
    let steps = args.get_usize("steps", 50)?;
    let faults = "drop=0.15,link=0.05,straggle=0.1,seed=7";
    let mut table = Table::new(
        &format!("fault smoke — n={nodes} ring, {steps} steps, faults [{faults}]"),
        &["optimizer", "first loss", "last loss", "consensus"],
    );
    for name in optim::ALL.iter().chain([&"dsgd"]) {
        let data = ClassificationData::generate(&SynthSpec {
            nodes,
            samples_per_node: 128,
            eval_samples: 128,
            dirichlet_alpha: 0.5,
            seed: 3,
            ..Default::default()
        });
        let workload = mlp::workload(mlp::MlpArch::family("mlp-xs")?, data, 16, 3);
        let mut cfg = Config::default();
        cfg.optimizer = (*name).into();
        cfg.topology = "ring".into();
        cfg.nodes = nodes;
        cfg.steps = steps;
        cfg.total_batch = 96;
        cfg.micro_batch = 16;
        cfg.lr = 0.02;
        cfg.linear_scaling = false;
        cfg.momentum = 0.9;
        cfg.schedule = LrSchedule::Constant;
        cfg.seed = 3;
        cfg.apply_kv("faults", faults)?;
        let mut t = Trainer::new(cfg, workload)?;
        let report = t.run();
        let bad = report.losses.iter().any(|l| !l.is_finite());
        anyhow::ensure!(!bad, "{name}: non-finite loss under faults");
        anyhow::ensure!(
            report.final_consensus.is_finite(),
            "{name}: non-finite consensus under faults"
        );
        table.row(vec![
            (*name).into(),
            sig(report.losses[0], 4),
            sig(*report.losses.last().unwrap(), 4),
            sig(report.final_consensus, 3),
        ]);
    }
    println!("{}", table.render());
    println!("fault smoke OK: all {} optimizers finite", optim::ALL.len() + 1);
    Ok(())
}
