//! Large-batch sweep (the paper's intro motivation), now measured
//! rather than modeled: hold the number of optimization steps fixed,
//! grow the total batch (and with it the linearly-scaled learning
//! rate), and read the momentum-bias proxy straight off the telemetry
//! stream. DmSGD's bias grows ~γ² with the scaled rate; DecentLaM's
//! local correction keeps the γ²-normalized bias flat; momentum-free
//! dsgd sits at f32-rounding level throughout.
//!
//! Every run tees its stream to disk and replays it — the replayed
//! `metrics` lines must match the trainer's in-memory log bit for bit
//! (the same check `decentlam profile` relies on).
//!
//! ```bash
//! cargo run --release --example large_batch_sweep -- --steps 150
//! cargo run --release --example large_batch_sweep -- --smoke   # CI gates
//! ```

use std::path::PathBuf;

use decentlam::coordinator::Trainer;
use decentlam::experiments::{mlp_workload_named, synth_imagenet};
use decentlam::telemetry::replay_path;
use decentlam::util::cli::Args;
use decentlam::util::config::Config;
use decentlam::util::math;
use decentlam::util::table::{sig, Table};

fn sweep_cfg(method: &str, batch: usize, steps: usize, nodes: usize) -> anyhow::Result<Config> {
    let mut cfg = Config::default();
    for (k, v) in [
        ("nodes", nodes.to_string()),
        ("topology", "ring".into()),
        ("optimizer", method.into()),
        ("model", "mlp-xs".into()),
        ("steps", steps.to_string()),
        ("total-batch", batch.to_string()),
        ("micro-batch", "32".into()),
        // γ_ref chosen so the scaled rate stays convergent at 16x:
        // γ ∈ {0.005, 0.02, 0.08} across the batch grid — a clean
        // 1:16:256 spread in γ², which is what the bias tracks.
        ("lr", "0.005".into()),
        ("linear-scaling", "true".into()),
        ("lr-ref-batch", "256".into()),
        ("max-lr-scale", "16".into()),
        ("momentum", "0.9".into()),
        ("schedule", "constant".into()),
        ("eval-every", steps.to_string()),
        ("seed", "1".into()),
        ("metrics", "every=1".into()),
    ] {
        cfg.apply_kv(k, &v)?;
    }
    Ok(cfg)
}

struct Cell {
    method: &'static str,
    batch: usize,
    scaled_lr: f64,
    bias: f64,
    bias_norm: f64,
    final_loss: f64,
}

fn run_cell(
    method: &'static str,
    batch: usize,
    steps: usize,
    nodes: usize,
) -> anyhow::Result<Cell> {
    let stream: PathBuf = std::env::temp_dir().join(format!(
        "decentlam_sweep_{}_{method}_{batch}.jsonl",
        std::process::id()
    ));
    let mut cfg = sweep_cfg(method, batch, steps, nodes)?;
    cfg.apply_kv("telemetry", &stream.to_string_lossy())?;
    let scaled_lr = cfg.scaled_lr();

    let data = synth_imagenet(nodes, 1);
    let wl = mlp_workload_named("mlp-xs", data, cfg.micro_batch, cfg.seed)?;
    let mut t = Trainer::new(cfg, wl)?;
    let report = t.run();
    anyhow::ensure!(t.telemetry_error().is_none(), "telemetry stream went inert");

    // Gate 1 (always on): the offline replay of the stream must carry
    // exactly the metrics the trainer computed — bit for bit.
    let r = replay_path(&stream)?;
    anyhow::ensure!(
        r.metrics == t.metrics_log(),
        "{method}@{batch}: replayed metrics diverge from the live log"
    );
    std::fs::remove_file(&stream).ok();

    // Steady-state bias: mean proxy over the last ≤10 metric steps
    // (the early transient, before momentum saturates, is not the
    // paper's quantity).
    let log = t.metrics_log();
    let tail = &log[log.len().saturating_sub(10)..];
    anyhow::ensure!(!tail.is_empty(), "{method}@{batch}: no metrics collected");
    let bias = math::sum_f64(tail.iter().map(|m| m.bias_proxy)) / tail.len() as f64;
    anyhow::ensure!(bias.is_finite(), "{method}@{batch}: diverged (bias {bias})");

    Ok(Cell {
        method,
        batch,
        scaled_lr,
        bias,
        bias_norm: bias / (scaled_lr * scaled_lr),
        final_loss: report.losses.last().copied().unwrap_or(f64::NAN),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.get_bool("smoke");
    let steps = if smoke { 40 } else { args.get_usize("steps", 150)? };
    let nodes = args.get_usize("nodes", 16)?;
    let batches = [256usize, 1024, 4096];
    let methods = ["dsgd", "dmsgd", "decentlam"];

    let mut cells: Vec<Cell> = Vec::new();
    let mut table = Table::new(
        "large-batch sweep — steady-state momentum-bias proxy (ring, linear LR scaling)",
        &["method", "batch", "scaled lr", "bias proxy", "bias / γ²", "final loss"],
    );
    for &batch in &batches {
        for method in methods {
            let c = run_cell(method, batch, steps, nodes)?;
            table.row(vec![
                c.method.into(),
                c.batch.to_string(),
                sig(c.scaled_lr, 3),
                format!("{:.3e}", c.bias),
                format!("{:.3e}", c.bias_norm),
                sig(c.final_loss, 4),
            ]);
            cells.push(c);
        }
    }
    println!("{}", table.render());
    println!(
        "shape check: dsgd ~0 (momentum-free); dmsgd bias grows with batch \
         (γ²-amplified momentum inconsistency); decentlam's bias/γ² stays flat."
    );

    if smoke {
        let get = |method: &str, batch: usize| {
            cells.iter().find(|c| c.method == method && c.batch == batch)
        };
        let top = *batches.last().unwrap_or(&0);

        // Gate 2: momentum-free dsgd is bias-free up to rounding —
        // negligible against dmsgd at the largest batch.
        let (dsgd, dmsgd_top) = match (get("dsgd", top), get("dmsgd", top)) {
            (Some(a), Some(b)) => (a.bias, b.bias),
            _ => anyhow::bail!("smoke: missing sweep cells"),
        };
        anyhow::ensure!(
            dsgd <= 1e-6 * dmsgd_top,
            "smoke: dsgd bias {dsgd:.3e} not negligible vs dmsgd {dmsgd_top:.3e}"
        );

        // Gate 3: dmsgd's bias strictly grows with batch size — the
        // paper's Fig. 1 phenomenon.
        for w in batches.windows(2) {
            let (lo, hi) = match (get("dmsgd", w[0]), get("dmsgd", w[1])) {
                (Some(a), Some(b)) => (a.bias, b.bias),
                _ => anyhow::bail!("smoke: missing dmsgd cells"),
            };
            anyhow::ensure!(
                hi > lo,
                "smoke: dmsgd bias did not grow {} -> {} ({lo:.3e} -> {hi:.3e})",
                w[0],
                w[1]
            );
        }

        // Gate 4: decentlam's γ²-normalized bias is batch-independent
        // (no momentum amplification left once the γ² scaling is
        // divided out).
        let norms: Vec<f64> = batches
            .iter()
            .filter_map(|&b| get("decentlam", b).map(|c| c.bias_norm))
            .collect();
        anyhow::ensure!(norms.len() == batches.len(), "smoke: missing decentlam cells");
        let (min, max) = norms
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| (lo.min(v), hi.max(v)));
        anyhow::ensure!(
            max / min < 10.0,
            "smoke: decentlam normalized bias not flat ({min:.3e}..{max:.3e})"
        );

        println!(
            "smoke gates passed: dsgd ≈ 0, dmsgd grows with batch, \
             decentlam bias/γ² flat within 10x; all streams replayed bit-exact"
        );
    }
    Ok(())
}
