//! Large-batch sweep (the paper's intro motivation): hold the number of
//! optimization steps fixed, grow the total batch, and watch the
//! momentum-amplified inconsistency bias separate DmSGD from DecentLaM
//! while PmSGD pays the all-reduce in (modeled) wall-clock.
//!
//! ```bash
//! cargo run --release --example large_batch_sweep -- --steps 250
//! ```

use decentlam::comm::{CommCost, CommStats, LinkSpec, PayloadBytes};
use decentlam::coordinator::Trainer;
use decentlam::experiments::{mlp_workload_named, protocol_config, synth_imagenet};
use decentlam::topology::{Kind, Topology};
use decentlam::util::cli::Args;
use decentlam::util::table::{pct, sig, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 250)?;
    let nodes = args.get_usize("nodes", 8)?;
    let batches = [256usize, 1024, 4096];
    let methods = ["pmsgd", "dmsgd", "decentlam"];

    let cost = CommCost::new(LinkSpec::tcp_10gbps());
    let stats = CommStats::of_topology(&Topology::build(Kind::SymExp, nodes));
    let bytes = PayloadBytes::uniform(25.5e6 * 4.0); // ResNet-50-sized fp32 payload

    let mut table = Table::new(
        "large-batch sweep — accuracy and modeled per-iter wall time (10 Gbps)",
        &["method", "batch", "val acc", "train loss", "comm ms/iter", "wall ms/iter"],
    );
    for &batch in &batches {
        for method in methods {
            let data = synth_imagenet(nodes, 1);
            let mut cfg = protocol_config(method, batch, steps, nodes);
            cfg.seed = 1;
            let wl = mlp_workload_named("mlp-s", data, cfg.micro_batch, 1)?;
            let mut t = Trainer::new(cfg, wl)?;
            let report = t.run();
            let comm_s = cost.per_iter_comm_s(t.comm_pattern(), &stats, bytes);
            let per_gpu = batch as f64 / (nodes * 8) as f64;
            let compute_s = per_gpu / 250.0;
            let wall_s = cost.per_iter_wall_s(compute_s, comm_s);
            table.row(vec![
                method.into(),
                batch.to_string(),
                pct(report.final_accuracy),
                sig(*report.losses.last().unwrap(), 4),
                sig(comm_s * 1e3, 3),
                sig(wall_s * 1e3, 3),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "shape check: DmSGD acc drops fastest with batch; DecentLaM holds; \
         PmSGD pays ~{}x the comm of partial averaging.",
        sig(
            cost.allreduce_s(nodes, bytes.allreduce)
                / cost.neighbor_exchange_s(&stats, bytes.neighbor),
            2
        )
    );
    Ok(())
}
