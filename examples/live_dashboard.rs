//! Live terminal dashboard over a telemetry stream (DESIGN.md §14):
//! point it at the JSONL file a training run is teeing with
//! `--telemetry run.jsonl` and it re-replays the file on a fixed poll
//! cadence, rendering run progress, the momentum-bias trajectory, and
//! the phase profile as they stream in. The replay layer's torn-tail
//! tolerance is what makes this safe against a mid-line writer: a
//! partial final line is dropped, never a parse error.
//!
//! ```bash
//! # terminal 1: any run with a telemetry tee
//! cargo run --release -- train --nodes 8 --steps 400 \
//!     --telemetry /tmp/run.jsonl,flush=1 --metrics every=5 --profile every=20
//! # terminal 2: watch it
//! cargo run --release --example live_dashboard -- /tmp/run.jsonl
//! # one-shot render (CI smoke): no follow loop, no screen clearing
//! cargo run --release --example live_dashboard -- /tmp/run.jsonl --snapshot
//! ```
//!
//! The dashboard is a pure *reader*: it never touches the stream file
//! beyond `read_to_string`, and exits when the `run-end` envelope
//! arrives (or immediately with `--snapshot`).

use decentlam::telemetry::{Event, Replay};
use decentlam::util::cli::Args;

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Log-scaled sparkline over the positive values; zeros render as the
/// lowest bar, non-finite values (a diverged run) as `!`.
fn sparkline(values: &[f64]) -> String {
    let pos: Vec<f64> = values.iter().copied().filter(|v| v.is_finite() && *v > 0.0).collect();
    let (lo, hi) = pos
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    values
        .iter()
        .map(|&v| {
            if !v.is_finite() {
                '!'
            } else if v <= 0.0 || pos.is_empty() {
                SPARK[0]
            } else if hi <= lo {
                SPARK[SPARK.len() / 2]
            } else {
                let t = (v.ln() - lo.ln()) / (hi.ln() - lo.ln());
                SPARK[((t * (SPARK.len() - 1) as f64).round() as usize).min(SPARK.len() - 1)]
            }
        })
        .collect()
}

fn render(path: &str, r: &Replay) {
    let status = if r.complete {
        "complete"
    } else if r.truncated {
        "running (torn tail dropped)"
    } else {
        "running"
    };
    println!("== {path} — {} stream, {status}, {} events", r.version, r.events);

    let steps = r.report.losses.len();
    let last_loss = r.report.losses.last().copied().unwrap_or(f64::NAN);
    println!(
        "run:     {steps} steps | loss {last_loss:.6} | {:.0} wire B/iter{}",
        r.report.wire_bytes_per_iter,
        if r.complete {
            format!(" | final acc {:.4}", r.report.final_accuracy)
        } else {
            String::new()
        }
    );

    match r.metrics.last() {
        Some(m) => {
            println!(
                "metrics: step {} | bias proxy {:.3e} | momentum disagreement {:.3e}",
                m.step, m.bias_proxy, m.momentum_disagreement
            );
            println!(
                "         consensus p50 {:.3e}  p95 {:.3e}  max {:.3e}",
                m.consensus_p50, m.consensus_p95, m.consensus_max
            );
            let tail: Vec<f64> = r
                .metrics
                .iter()
                .rev()
                .take(48)
                .rev()
                .map(|m| m.bias_proxy)
                .collect();
            println!("bias:    {} (last {} observations, log scale)", sparkline(&tail), tail.len());
        }
        None => println!("metrics: none yet (run with --metrics every=K)"),
    }

    match &r.last_timing {
        Some(Event::Timing {
            step, grad_ns, encode_ns, exchange_ns, update_ns, lane_busy_ns, ..
        }) => {
            let total = (grad_ns + encode_ns + exchange_ns + update_ns).max(1);
            let pct = |ns: u64| 100.0 * ns as f64 / total as f64;
            println!(
                "timing:  step {step} | grad {:.1}% | encode {:.1}% | exchange {:.1}% | \
                 update {:.1}% (cumulative)",
                pct(*grad_ns),
                pct(*encode_ns),
                pct(*exchange_ns),
                pct(*update_ns)
            );
            let busiest = lane_busy_ns.iter().copied().max().unwrap_or(0).max(1);
            let lanes: Vec<String> = lane_busy_ns
                .iter()
                .map(|&ns| format!("{:.0}%", 100.0 * ns as f64 / busiest as f64))
                .collect();
            println!("lanes:   [{}] busy vs busiest", lanes.join(" "));
        }
        _ => println!("timing:  none (run with --profile)"),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let path = match args.positional.first() {
        Some(p) => p.clone(),
        None => anyhow::bail!(
            "usage: live_dashboard RUN.jsonl [--snapshot] [--poll-ms N] (a --telemetry stream)"
        ),
    };
    let snapshot = args.get_bool("snapshot");
    let poll_ms = args.get_usize("poll-ms", 250)?;

    loop {
        // Mid-write reads are fine: only the torn tail line can be
        // incomplete, and the replay layer drops it. A missing or
        // not-yet-started file is a "waiting" state, not an error —
        // the run may simply not have opened its sink yet.
        let parsed = std::fs::read_to_string(&path)
            .map_err(anyhow::Error::from)
            .and_then(|text| decentlam::telemetry::replay_str(&text));
        if !snapshot {
            // Clear + home, repaint in place.
            print!("\x1b[2J\x1b[H");
        }
        match parsed {
            Ok(r) => {
                render(&path, &r);
                if r.complete || snapshot {
                    return Ok(());
                }
            }
            Err(e) if snapshot => return Err(e.context(format!("snapshot of {path}"))),
            Err(e) => println!("== {path} — waiting for a stream ({e:#})"),
        }
        std::thread::sleep(std::time::Duration::from_millis(poll_ms as u64));
    }
}
