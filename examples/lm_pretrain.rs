//! End-to-end driver (deliverable (e) of DESIGN.md): decentralized
//! pretraining of the transformer LM through the FULL three-layer stack —
//! Rust coordinator → PJRT CPU runtime → AOT HLO lowered from the JAX
//! model that calls the Pallas `fused_linear` kernel.
//!
//! Trains the ~3.2M-parameter char-level transformer (`lm-base`) with
//! DecentLaM over 4 nodes on a ring for a few hundred steps on the
//! built-in corpus, logging the loss curve. Recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example lm_pretrain -- --steps 300
//! ```

use std::path::Path;

use decentlam::coordinator::Trainer;
use decentlam::data::corpus::Corpus;
use decentlam::grad::pjrt;
use decentlam::runtime::{Manifest, Runtime};
use decentlam::util::bench::WallTimer;
use decentlam::util::cli::Args;
use decentlam::util::config::{Config, LrSchedule};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300)?;
    let nodes = args.get_usize("nodes", 4)?;
    let optimizer = args.get_str("optimizer", "decentlam").to_string();
    let artifacts = args.get_str("artifacts", "artifacts").to_string();

    let manifest = Manifest::load(Path::new(&artifacts))?;
    let runtime = Runtime::start()?;
    let rt = runtime.handle();
    let corpus = Corpus::builtin();
    println!(
        "corpus: {} tokens, {} node shards + held-out eval shard",
        corpus.tokens.len(),
        nodes
    );
    let workload = pjrt::lm_workload(&rt, &manifest, "lm-base", &corpus, nodes)?;
    println!("model lm-base: {} parameters (flat)", workload.dim);

    let mut cfg = Config::default();
    cfg.optimizer = optimizer.clone();
    cfg.model = "lm-base".into();
    cfg.nodes = nodes;
    cfg.steps = steps;
    cfg.micro_batch = manifest.model("lm-base")?.micro_batch;
    cfg.total_batch = cfg.micro_batch * nodes; // accum 1: LM steps are pricey on CPU
    cfg.lr = args.get_f64("lr", 0.05)?;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.topology = args.get_str("topology", "ring").into();
    cfg.schedule = LrSchedule::WarmupCosine {
        warmup_steps: (steps / 10).max(1),
        total_steps: steps,
    };
    cfg.eval_every = (steps / 6).max(1);
    cfg.seed = 1;

    let mut trainer = Trainer::new(cfg, workload)?;
    let t0 = WallTimer::start();
    let mut last_print = WallTimer::start();
    let mut losses = Vec::new();
    for k in 0..steps {
        let loss = trainer.step(k);
        losses.push(loss);
        if last_print.elapsed_s() > 5.0 || k == 0 || k + 1 == steps {
            println!(
                "step {k:>5}/{steps}  train loss {loss:.4}  ({:.2} steps/s)",
                (k + 1) as f64 / t0.elapsed_s()
            );
            last_print.restart();
        }
    }
    let xbar = trainer.average_model();
    let eval_loss = trainer.workload.eval.loss(&xbar).unwrap_or(f64::NAN);
    let l0: f64 = losses[..5.min(losses.len())].iter().sum::<f64>() / 5f64.min(losses.len() as f64);
    let l1: f64 = losses[losses.len().saturating_sub(10)..].iter().sum::<f64>()
        / 10f64.min(losses.len() as f64);
    println!("---");
    println!("optimizer            : {optimizer}");
    println!("initial train loss   : {l0:.4}  (log vocab = {:.4})", (96f64).ln());
    println!("final train loss     : {l1:.4}");
    println!("held-out eval loss   : {eval_loss:.4}");
    println!("consensus distance   : {:.3e}", trainer.consensus_distance());
    println!("wall time            : {:.1}s", t0.elapsed_s());
    anyhow::ensure!(l1 < l0, "training failed to descend");
    Ok(())
}
