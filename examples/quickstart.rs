//! Quickstart: train a small classifier with DecentLaM over 8 nodes on
//! a ring, compare against DmSGD at the same hyper-parameters, and
//! print the accuracy + consensus summary.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::mlp;
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::table::{pct, sig, Table};

fn main() -> anyhow::Result<()> {
    let nodes = 8;
    let mut table = Table::new(
        "quickstart — 8-node ring, heterogeneous data, total batch 1024",
        &["optimizer", "val acc", "final loss", "consensus"],
    );
    for optimizer in ["dmsgd", "decentlam"] {
        // Heterogeneous shards: each node sees a skewed label slice.
        let data = ClassificationData::generate(&SynthSpec {
            nodes,
            samples_per_node: 1024,
            eval_samples: 1024,
            dirichlet_alpha: 0.3,
            seed: 1,
            ..Default::default()
        });
        let workload =
            mlp::workload(mlp::MlpArch::family("mlp-s")?, data, 64, 1);

        let mut cfg = Config::default();
        cfg.optimizer = optimizer.into();
        cfg.topology = "ring".into();
        cfg.nodes = nodes;
        cfg.steps = 300;
        cfg.total_batch = 1024;
        cfg.micro_batch = 64;
        cfg.lr = 0.05;
        cfg.momentum = 0.9;
        cfg.schedule = LrSchedule::WarmupStep { warmup_steps: 15, milestones: vec![150, 250] };
        cfg.eval_every = 100;

        let mut trainer = Trainer::new(cfg, workload)?;
        let report = trainer.run();
        println!(
            "{optimizer}: step evals {:?}",
            report
                .evals
                .iter()
                .map(|(k, a)| format!("{k}:{:.3}", a))
                .collect::<Vec<_>>()
        );
        table.row(vec![
            optimizer.into(),
            pct(report.final_accuracy),
            sig(*report.losses.last().unwrap(), 4),
            sig(report.final_consensus, 3),
        ]);
    }
    println!("\n{}", table.render());
    Ok(())
}
