//! Telemetry bus + offline replay demo (DESIGN.md §11): a faults×churn
//! DecentLaM run streams its typed JSONL events to disk, then the
//! stream alone — no trainer state — reconstructs the run's summary
//! exactly, tolerates a crash-truncated tail, and proves byte-identical
//! determinism across two invocations.
//!
//! ```bash
//! cargo run --release --example telemetry_replay
//! cargo run --release --example telemetry_replay -- --nodes 8 --steps 60
//! cargo run --release --example telemetry_replay -- --out run.jsonl
//! # then inspect offline:  cargo run --release -- replay run.jsonl
//! ```

use std::path::{Path, PathBuf};

use decentlam::coordinator::{TrainReport, Trainer};
use decentlam::telemetry::{replay_path, replay_str};
use decentlam::util::cli::Args;
use decentlam::util::config::Config;

fn build_cfg(nodes: usize, steps: usize, out: &Path) -> anyhow::Result<Config> {
    let mut cfg = Config::default();
    for (k, v) in [
        ("nodes", nodes.to_string()),
        ("topology", "ring".into()),
        ("optimizer", "decentlam".into()),
        ("model", "mlp-xs".into()),
        ("steps", steps.to_string()),
        ("total-batch", (8 * nodes).to_string()),
        ("micro-batch", "8".into()),
        ("lr", "0.05".into()),
        ("linear-scaling", "false".into()),
        ("schedule", "constant".into()),
        ("eval-every", (steps / 4).max(1).to_string()),
        ("threads", "1".into()),
        ("seed", "7".into()),
        // Both realization layers at once: seeded node drops AND an
        // elastic roster — the stream carries fault and churn events.
        ("faults", "drop=0.1,seed=3".into()),
        (
            "churn",
            format!("join=0.05,leave=0.05,nmin={},nmax={},seed=5", nodes / 2, nodes + 4),
        ),
        ("telemetry", out.to_string_lossy().into_owned()),
    ] {
        cfg.apply_kv(k, &v)?;
    }
    Ok(cfg)
}

fn run_once(nodes: usize, steps: usize, out: &Path) -> anyhow::Result<TrainReport> {
    let cfg = build_cfg(nodes, steps, out)?;
    // Elastic runs shard data over the whole stable-id capacity (nmax).
    let capacity = match cfg.churn {
        None => cfg.nodes,
        Some(spec) => spec.with_run_seed(cfg.seed).resolve(cfg.nodes)?.nmax,
    };
    let data = decentlam::experiments::synth_imagenet(capacity, cfg.seed);
    let wl =
        decentlam::experiments::mlp_workload_named("mlp-xs", data, cfg.micro_batch, cfg.seed)?;
    let mut t = Trainer::new(cfg, wl)?;
    let report = t.run();
    anyhow::ensure!(t.telemetry_error().is_none(), "telemetry stream went inert");
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nodes = args.get_usize("nodes", 8)?;
    let steps = args.get_usize("steps", 40)?;
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join("decentlam_telemetry_replay.jsonl"));

    println!("== live run (ring{nodes}, decentlam, drop=0.1 + elastic churn, {steps} steps)");
    let live = run_once(nodes, steps, &out)?;
    println!(
        "live:   final loss {:.6}, acc {:.4}, {:.0} realized wire B/iter",
        live.losses.last().copied().unwrap_or(f64::NAN),
        live.final_accuracy,
        live.wire_bytes_per_iter
    );

    println!("\n== offline replay of {}", out.display());
    let r = replay_path(&out)?;
    println!(
        "replay: {} events — {} step, {} eval, {} churn lines; \
         final loss {:.6}, acc {:.4}, {:.0} wire B/iter",
        r.events,
        r.report.losses.len(),
        r.report.evals.len(),
        r.churn_events,
        r.report.losses.last().copied().unwrap_or(f64::NAN),
        r.report.final_accuracy,
        r.report.wire_bytes_per_iter
    );
    if let Some(f) = &r.fault_totals {
        println!(
            "replay: fault totals — {} masked edges, {} dropped node-steps",
            f.masked_edges, f.dropped_node_steps
        );
    }
    r.matches_report(&live)?;
    println!("replayed summary matches the live report bit for bit");

    // Crash tolerance: chop the stream mid-final-line, as a dying
    // writer would. The replay drops the torn tail and still yields a
    // usable partial summary — while anything malformed EARLIER in the
    // stream stays a hard error.
    println!("\n== crash-truncated tail");
    let text = std::fs::read_to_string(&out)?;
    let cut = &text[..text.len() - 17];
    let partial = replay_str(cut)?;
    anyhow::ensure!(partial.truncated && !partial.complete, "expected a truncated stream");
    println!(
        "truncated replay: {} events salvaged, {} losses, incomplete as expected",
        partial.events,
        partial.report.losses.len()
    );

    // Determinism: a second identical run must produce the same bytes.
    println!("\n== determinism");
    let out2 = out.with_extension("second.jsonl");
    let live2 = run_once(nodes, steps, &out2)?;
    anyhow::ensure!(
        std::fs::read(&out)? == std::fs::read(&out2)?,
        "two identical runs produced different telemetry bytes"
    );
    anyhow::ensure!(
        live.losses == live2.losses,
        "two identical runs produced different losses"
    );
    std::fs::remove_file(&out2).ok();
    println!("two identical runs → byte-identical telemetry streams");
    println!("\nstream kept at {} (inspect with `decentlam replay`)", out.display());
    Ok(())
}
