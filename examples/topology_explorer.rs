//! Topology explorer: prints every shipped topology at a given node
//! count — edge counts, spectral constant ρ, gossip mixing time, and
//! the modeled per-step communication cost **charged from the realized
//! edge count** (never an n×n walk). The sparse neighbor-list engine
//! plus power-iteration ρ keep it fast at the node counts where
//! decentralized methods shine:
//!
//! ```bash
//! cargo run --release --example topology_explorer -- --nodes 6
//! cargo run --release --example topology_explorer -- --nodes 512 --topology ring
//! ```
//!
//! At n ≤ 8 the per-node weight rows and the Fig. 1 dense-matrix
//! analogue are printed too (the App. G.3 material).

use decentlam::comm::{
    wire_bytes_per_iter, CommCost, CommEngine, CommStats, LinkSpec, PayloadBytes,
};
use decentlam::optim::CommPattern;
use decentlam::topology::{
    metropolis_hastings, rho_power, spectral, Kind, SparseWeights, Topology,
};
use decentlam::util::cli::Args;
use decentlam::util::table::{sig, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("nodes", 6)?;
    // ResNet-50-sized fp32 payload per exchanged model, as in Fig. 6.
    let bytes = PayloadBytes::uniform(25.5e6 * 4.0);
    let cost = CommCost::new(LinkSpec::tcp_10gbps());
    // Resolve the filter through Kind::parse so aliases work ("grid",
    // "er", ...) and typos error out instead of printing an empty table.
    let only: Option<Kind> = args.get("topology").map(Kind::parse).transpose()?;

    let mut table = Table::new(
        &format!("topology explorer (n={n}, Metropolis–Hastings weights, 10 Gbps model)"),
        &[
            "topology",
            "edges",
            "max deg",
            "rho",
            "mixing T(1e-3)",
            "MB on wire/step",
            "comm ms/step",
        ],
    );
    for kind in Kind::ALL {
        let name = kind.name();
        if let Some(o) = only {
            if o != kind {
                continue;
            }
        }
        // `full` at large n is the one deliberately-dense graph: its
        // edge count is O(n²) by definition, so skip it past 64 nodes
        // unless the user explicitly asked for it.
        if kind == Kind::Full && n > 64 && only.is_none() {
            continue;
        }
        let topo = Topology::at_step(kind, n, 42, 0);
        let sw = SparseWeights::metropolis_hastings(&topo);
        let stats = CommStats::of_engine(&sw);
        let r = rho_power(&sw, 200_000);
        let pattern = CommPattern::Neighbor { payloads: 1 };
        let wire_mb = wire_bytes_per_iter(pattern, &stats, bytes) / 1e6;
        let comm_ms = cost.per_iter_comm_s(pattern, &stats, bytes) * 1e3;
        table.row(vec![
            name.into(),
            stats.edges.to_string(),
            stats.max_degree.to_string(),
            sig(r, 4),
            sig(spectral::mixing_time_of(r, 1e-3), 3),
            sig(wire_mb, 4),
            sig(comm_ms, 4),
        ]);

        if n <= 8 {
            println!("== {name} (n={n}) ==");
            for i in 0..n {
                let row: Vec<String> =
                    sw.row(i).iter().map(|&(j, w)| format!("{j}:{w:.3}")).collect();
                println!(
                    "  node {i}: neighbors {:?}  W row [{}]",
                    topo.neighbors(i),
                    row.join(" ")
                );
            }
            if kind.time_varying() {
                println!("  (time-varying: step 1 realization)");
                let t1 = Topology::at_step(kind, n, 42, 1);
                for i in 0..n {
                    println!("  node {i}: neighbors {:?}", t1.neighbors(i));
                }
            }
            println!();
        }
    }
    println!("{}", table.render());

    if n <= 8 && only.is_none() {
        // The Fig. 1 weight matrix, reproduced for the mesh-of-6 of the
        // paper (small n: the dense engine is fine here).
        let mut fig1 = Table::new(
            "paper Fig. 1 analogue — dense W for mesh n=6 (Metropolis–Hastings)",
            &["", "0", "1", "2", "3", "4", "5"],
        );
        let topo = Topology::build(Kind::Mesh, 6);
        let wm = metropolis_hastings(&topo);
        for i in 0..6 {
            let mut row = vec![format!("node {i}")];
            for j in 0..6 {
                row.push(sig(wm.dense.get(i, j), 3));
            }
            fig1.row(row);
        }
        println!("{}", fig1.render());
    }
    Ok(())
}
