//! Topology explorer: prints every shipped topology at a given node
//! count with its adjacency, Metropolis–Hastings weight row, spectral
//! constant ρ and gossip mixing time — the Fig. 1 / App. G.3 material.
//!
//! ```bash
//! cargo run --release --example topology_explorer -- --nodes 6
//! ```

use decentlam::topology::{metropolis_hastings, rho, spectral, Kind, Topology};
use decentlam::util::cli::Args;
use decentlam::util::table::{sig, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("nodes", 6)?;

    for name in ["ring", "mesh", "star", "sym-exp", "full", "erdos", "bipartite", "one-peer-exp"] {
        let kind = Kind::parse(name)?;
        let topo = Topology::at_step(kind, n, 42, 0);
        let wm = metropolis_hastings(&topo);
        println!("== {name} (n={n}) ==");
        for i in 0..n {
            let row: Vec<String> = wm
                .row(i)
                .iter()
                .map(|&(j, w)| format!("{j}:{w:.3}"))
                .collect();
            println!("  node {i}: neighbors {:?}  W row [{}]", topo.neighbors(i), row.join(" "));
        }
        println!(
            "  rho = {:.4}   spectral gap = {:.4}   mixing T(1e-3) = {:.1} rounds",
            rho(&wm),
            1.0 - rho(&wm),
            spectral::mixing_time(&wm, 1e-3)
        );
        if kind.time_varying() {
            println!("  (time-varying: step 1 realization)");
            let t1 = Topology::at_step(kind, n, 42, 1);
            for i in 0..n {
                println!("  node {i}: neighbors {:?}", t1.neighbors(i));
            }
        }
        println!();
    }

    // The Fig. 1 weight matrix, reproduced for the mesh-of-6 of the paper.
    let mut table = Table::new(
        "paper Fig. 1 analogue — dense W for mesh n=6 (Metropolis–Hastings)",
        &["", "0", "1", "2", "3", "4", "5"],
    );
    let topo = Topology::build(Kind::Mesh, 6);
    let wm = metropolis_hastings(&topo);
    for i in 0..6 {
        let mut row = vec![format!("node {i}")];
        for j in 0..6 {
            row.push(sig(wm.dense.get(i, j), 3));
        }
        table.row(row);
    }
    println!("{}", table.render());
    Ok(())
}
