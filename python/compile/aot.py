"""AOT pipeline: lower every Layer-2 entry point to HLO *text* artifacts.

Build-time only (`make artifacts`). Emits into `artifacts/`:

  * `<name>.hlo.txt`      — HLO text per jitted entry point. Text, never
    `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit ids that
    xla_extension 0.5.1 rejects; the text parser reassigns ids.
  * `<model>_init.bin`    — initial flat parameters, little-endian f32.
  * `manifest.json`       — input/output specs, model metadata (dim,
    layer ranges for LARS, batch shapes) for the Rust runtime.
  * `golden.json`         — oracle evaluations of the kernels and a
    single-node training step; the Rust test-suite replays these against
    its native implementations (one source of truth across layers).

Usage: cd python && python -m compile.aot [--outdir ../artifacts] [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import decentlam_update, partial_average
from .kernels import ref

# Padded neighborhood size baked into the update-kernel artifacts. Every
# topology we ship at n=8 has degree+self <= 8; rows are padded with zero
# weights (the kernel is exactly linear in w, so padding is a no-op).
KPAD = 8

MICRO_BATCH = 64       # per-node MLP micro-batch (large batch = accumulation)
EVAL_BATCH = 256
LM_BATCH = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


def _spec(args):
    return [
        {"shape": list(a.shape), "dtype": _dtype_name(a.dtype)} for a in args
    ]


class Emitter:
    def __init__(self, outdir: str):
        self.outdir = outdir
        self.manifest = {"artifacts": {}, "models": {}, "kernels": {}}
        os.makedirs(outdir, exist_ok=True)

    def lower(self, name: str, fn, example_args, n_outputs: int):
        """jit + lower fn at the example shapes, write HLO text."""
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(self.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _spec(example_args),
            "n_outputs": n_outputs,
        }
        print(f"  lowered {name}: {len(text) / 1e6:.2f} MB")

    def write_init(self, name: str, theta: np.ndarray):
        path = os.path.join(self.outdir, f"{name}_init.bin")
        theta.astype("<f4").tofile(path)

    def finish(self):
        with open(os.path.join(self.outdir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote {self.outdir}/manifest.json")


def shaped(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def emit_update_kernels(em: Emitter, dim: int):
    """The Layer-1 kernels as standalone artifacts at this model size."""
    name = f"decentlam_update_{dim}"
    em.lower(
        name,
        lambda z, w, x, m, hp: decentlam_update(z, w, x, m, hp),
        (
            shaped((KPAD, dim)),
            shaped((KPAD,)),
            shaped((dim,)),
            shaped((dim,)),
            shaped((2,)),
        ),
        n_outputs=2,
    )
    em.manifest["kernels"][name] = {"dim": dim, "kpad": KPAD, "kind": "decentlam"}
    name = f"partial_average_{dim}"
    em.lower(
        name,
        lambda z, w: partial_average(z, w),
        (shaped((KPAD, dim)), shaped((KPAD,))),
        n_outputs=1,
    )
    em.manifest["kernels"][name] = {"dim": dim, "kpad": KPAD, "kind": "mix"}


def emit_mlp(em: Emitter, cfg: M.MlpConfig, seed: int):
    spec = cfg.spec()
    dim = spec.dim
    theta0 = cfg.init(seed)
    em.write_init(cfg.name, theta0)
    em.lower(
        f"{cfg.name}_grad",
        lambda t, x, y: M.mlp_loss_and_grad(cfg, t, x, y),
        (
            shaped((dim,)),
            shaped((MICRO_BATCH, cfg.input_dim)),
            shaped((MICRO_BATCH,), jnp.int32),
        ),
        n_outputs=2,
    )
    em.lower(
        f"{cfg.name}_logits",
        lambda t, x: M.mlp_logits(cfg, t, x),
        (shaped((dim,)), shaped((EVAL_BATCH, cfg.input_dim))),
        n_outputs=1,
    )
    em.manifest["models"][cfg.name] = {
        "kind": "mlp",
        "dim": dim,
        "input_dim": cfg.input_dim,
        "hidden": list(cfg.hidden),
        "num_classes": cfg.num_classes,
        "micro_batch": MICRO_BATCH,
        "eval_batch": EVAL_BATCH,
        "init": f"{cfg.name}_init.bin",
        "layer_ranges": spec.layer_ranges(),
    }


def emit_transformer(em: Emitter, cfg: M.TransformerConfig, seed: int):
    spec = cfg.spec()
    dim = spec.dim
    em.write_init(cfg.name, cfg.init(seed))
    toks = shaped((LM_BATCH, cfg.seq_len), jnp.int32)
    em.lower(
        f"{cfg.name}_grad",
        lambda t, x, y: M.transformer_loss_and_grad(cfg, t, x, y),
        (shaped((dim,)), toks, toks),
        n_outputs=2,
    )
    em.lower(
        f"{cfg.name}_loss",
        lambda t, x, y: (M.transformer_loss(cfg, t, x, y),),
        (shaped((dim,)), toks, toks),
        n_outputs=1,
    )
    em.manifest["models"][cfg.name] = {
        "kind": "lm",
        "dim": dim,
        "vocab": cfg.vocab,
        "seq_len": cfg.seq_len,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "micro_batch": LM_BATCH,
        "init": f"{cfg.name}_init.bin",
        "layer_ranges": spec.layer_ranges(),
    }


def emit_det(em: Emitter, cfg: M.DetConfig, seed: int):
    spec = cfg.spec()
    dim = spec.dim
    em.write_init(cfg.name, cfg.init(seed))
    em.lower(
        f"{cfg.name}_grad",
        lambda t, x, y, b: M.det_loss_and_grad(cfg, t, x, y, b),
        (
            shaped((dim,)),
            shaped((MICRO_BATCH, cfg.input_dim)),
            shaped((MICRO_BATCH,), jnp.int32),
            shaped((MICRO_BATCH, cfg.box_dim)),
        ),
        n_outputs=2,
    )
    em.manifest["models"][cfg.name] = {
        "kind": "det",
        "dim": dim,
        "input_dim": cfg.input_dim,
        "num_classes": cfg.num_classes,
        "box_dim": cfg.box_dim,
        "micro_batch": MICRO_BATCH,
        "init": f"{cfg.name}_init.bin",
        "layer_ranges": spec.layer_ranges(),
    }


def emit_golden(em: Emitter):
    """Oracle evaluations replayed by the Rust test-suite (see
    rust/tests/golden.rs). Small shapes, deterministic inputs."""
    rng = np.random.default_rng(7)
    k, d = 3, 8
    z = rng.normal(size=(k, d)).astype(np.float32)
    w = np.array([0.5, 0.25, 0.25], np.float32)
    x = rng.normal(size=d).astype(np.float32)
    m = rng.normal(size=d).astype(np.float32)
    gamma, beta = 0.05, 0.9
    xn, mn = ref.decentlam_update_ref(
        jnp.asarray(z), jnp.asarray(w), jnp.asarray(x), jnp.asarray(m), gamma, beta
    )
    mix = ref.partial_average_ref(jnp.asarray(z), jnp.asarray(w))
    golden = {
        "decentlam_update": {
            "z": z.ravel().tolist(),
            "k": k,
            "d": d,
            "w": w.tolist(),
            "x": x.tolist(),
            "m": m.tolist(),
            "gamma": gamma,
            "beta": beta,
            "x_new": np.asarray(xn).tolist(),
            "m_new": np.asarray(mn).tolist(),
        },
        "partial_average": {"mix": np.asarray(mix).tolist()},
    }
    with open(os.path.join(em.outdir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    print("wrote golden.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) ignored, use --outdir")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="only the default MLP + kernels (fast CI path)",
    )
    args = ap.parse_args()
    outdir = args.outdir
    if args.out is not None:
        outdir = os.path.dirname(args.out) or outdir

    em = Emitter(outdir)
    default_mlp = M.MLP_FAMILY["mlp-s"]
    emit_mlp(em, default_mlp, seed=1)
    emit_update_kernels(em, default_mlp.spec().dim)
    emit_golden(em)
    if not args.quick:
        for name, cfg in M.MLP_FAMILY.items():
            if name != default_mlp.name:
                emit_mlp(em, cfg, seed=1)
        lm = M.TransformerConfig()
        emit_transformer(em, lm, seed=2)
        emit_update_kernels(em, lm.spec().dim)
        emit_det(em, M.DetConfig(), seed=3)
    em.finish()


if __name__ == "__main__":
    sys.exit(main())
