"""Layer-1 Pallas kernels + the pure-jnp oracle (ref).

All kernels run interpret=True so they lower to plain HLO that the CPU
PJRT client can execute; see DESIGN.md §Hardware-Adaptation for the TPU
schedule each block structure encodes.
"""

from .decentlam_update import decentlam_update
from .fused_linear import fused_linear
from .partial_average import partial_average
from . import ref

__all__ = ["decentlam_update", "fused_linear", "partial_average", "ref"]
