"""Layer-1 Pallas kernel: the DecentLaM fused update (paper eq. (17)).

This is the per-iteration hot spot of the decentralized runtime: every
node, every step, consumes the K half-step vectors received from its
neighborhood and produces its next model + momentum. The unfused sequence
(average -> corrected gradient -> momentum -> apply) makes three full
passes over the D-sized parameter state; this kernel makes exactly one.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the flat
parameter dimension D into VMEM-resident blocks; each grid step loads a
(K, BLOCK_D) neighbor tile plus (BLOCK_D,) x/m tiles, reduces over K on
the VPU, and writes both outputs — one HBM round trip per parameter.
`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for AOT artifacts and
its *structure* (block shapes, footprint) is what carries to real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default parameter-dimension tile. With K <= 8 neighbors this keeps the
# working set (K+3) * BLOCK_D * 4B  ~=  11 * 8192 * 4B ~= 352 KiB, far under
# the ~16 MiB VMEM budget, leaving room for double buffering.
BLOCK_D = 8192


def _kernel(z_ref, w_ref, x_ref, m_ref, hp_ref, x_out_ref, m_out_ref):
    """One (K, BLOCK_D) tile of the fused update.

    hp_ref holds (gamma, beta) so the artifact is hyper-parameter generic
    (no re-lowering when the LR schedule moves).
    """
    gamma = hp_ref[0]
    beta = hp_ref[1]
    z = z_ref[...]  # (K, BLOCK_D)
    w = w_ref[...]  # (K,)
    x = x_ref[...]  # (BLOCK_D,)
    m = m_ref[...]
    # Weighted neighborhood reduction over K (VPU, K is tiny).
    mix = jnp.einsum("k,kd->d", w.astype(z.dtype), z)
    gt = (x - mix) / gamma
    m_new = beta * m + gt
    # x - gamma*m_new == mix - gamma*beta*m, written in the numerically
    # fused form to reuse mix already in registers.
    x_new = mix - gamma * beta * m
    x_out_ref[...] = x_new
    m_out_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=("block_d",))
def decentlam_update(z, w, x, m, hp, *, block_d: int = BLOCK_D):
    """Fused DecentLaM update over flat parameters.

    Args:
      z:  (K, D) stacked half-steps from the neighborhood (self included).
      w:  (K,) mixing weights (the node's row of W restricted to N_i).
      x:  (D,) current model.
      m:  (D,) current momentum.
      hp: (2,) array [gamma, beta].
      block_d: tile size along D (D must be divisible, pad upstream).

    Returns:
      (x_new, m_new), both (D,).
    """
    k, d = z.shape
    bd = min(block_d, d)
    pad = (-d) % bd
    if pad:
        # Model dims are rarely tile multiples; pad the flat dimension with
        # zeros (the update maps 0 -> 0 for x=m=z=0) and slice the result.
        z = jnp.pad(z, ((0, 0), (0, pad)))
        x = jnp.pad(x, (0, pad))
        m = jnp.pad(m, (0, pad))
        d += pad
    grid = (d // bd,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, bd), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((d,), m.dtype),
        ],
        interpret=True,
    )(z, w, x, m, hp)
    if pad:
        return out[0][: d - pad], out[1][: d - pad]
    return out[0], out[1]
