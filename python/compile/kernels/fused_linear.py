"""Layer-1 Pallas kernel: fused dense layer (matmul + bias) with a
hand-written custom_vjp whose backward passes are also Pallas kernels.

This is the MXU-bound kernel of the stack: the Layer-2 models
(python/compile/model.py) route every dense layer through it, so the
kernel lowers into the very HLO artifact the Rust runtime executes.

TPU mapping: (B, I) x (I, O) tiles sized for the 128x128 MXU; bias add is
fused into the same VMEM-resident output tile. On the CPU AOT path
(interpret=True) this becomes plain HLO dot/add, so the artifact runs at
native XLA speed while the block structure documents the TPU schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        x.dtype
    ) + b


def _dx_kernel(dy_ref, w_ref, o_ref):
    dy = dy_ref[...]
    w = w_ref[...]
    o_ref[...] = jnp.dot(dy, w.T, preferred_element_type=jnp.float32).astype(dy.dtype)


def _dw_kernel(x_ref, dy_ref, o_ref):
    x = x_ref[...]
    dy = dy_ref[...]
    o_ref[...] = jnp.dot(x.T, dy, preferred_element_type=jnp.float32).astype(x.dtype)


def _call(kernel, out_shape, *args):
    """Single-tile pallas_call: model layers here are small enough that one
    VMEM tile holds each operand; larger layers would add a grid over
    (B, O) with an inner K loop — the schedule is identical in kind."""
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, args[0].dtype),
        interpret=True,
    )(*args)


@jax.custom_vjp
def fused_linear(x, w, b):
    """y = x @ w + b via the Pallas forward kernel.

    x: (B, I), w: (I, O), b: (O,) -> (B, O).
    """
    return _call(_fwd_kernel, (x.shape[0], w.shape[1]), x, w, b)


def _fwd(x, w, b):
    return fused_linear(x, w, b), (x, w)


def _bwd(res, dy):
    x, w = res
    dx = _call(_dx_kernel, x.shape, dy, w)
    dw = _call(_dw_kernel, w.shape, x, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fwd, _bwd)
