"""Layer-1 Pallas kernel: plain partial averaging (paper eq. (3)).

The gossip primitive shared by DSGD / DmSGD / DA-DmSGD: a weighted
reduction of the K neighborhood payloads. DecentLaM's fused kernel
(decentlam_update.py) subsumes this; it is kept separate because the
baseline optimizers apply it to different payloads (models, half-steps,
momenta) and because ablating "fused vs unfused" (EXPERIMENTS.md §Perf)
needs the unfused pass as its own artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 8192


def _kernel(z_ref, w_ref, out_ref):
    z = z_ref[...]
    w = w_ref[...]
    out_ref[...] = jnp.einsum("k,kd->d", w.astype(z.dtype), z)


@functools.partial(jax.jit, static_argnames=("block_d",))
def partial_average(z, w, *, block_d: int = BLOCK_D):
    """mix = sum_k w[k] * z[k, :] over (K, D) payloads, tiled along D."""
    k, d = z.shape
    bd = min(block_d, d)
    pad = (-d) % bd
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)))
        d += pad
    out = pl.pallas_call(
        _kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((k, bd), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), z.dtype),
        interpret=True,
    )(z, w)
    return out[: d - pad] if pad else out
