"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest (python/tests/) asserts
allclose between kernel and oracle across hypothesis-generated shapes,
dtypes and hyper-parameters; aot.py additionally serializes a few oracle
evaluations as golden vectors that the Rust test-suite replays against its
native implementations, tying all three layers to one source of truth.

Math (paper eq. (17) and Algorithm 2), per node i with neighbor set N_i:

    z_j    = x_j - gamma * grad_j          (the "half step", exchanged)
    mix_i  = sum_{j in N_i} w_ij * z_j     (partial averaging)
    gt_i   = (x_i - mix_i) / gamma         (bias-corrected gradient)
    m_i'   = beta * m_i + gt_i             (momentum update)
    x_i'   = x_i - gamma * m_i'            (model update)
           = mix_i - gamma * beta * m_i    (fused form used by the kernel)
"""

from __future__ import annotations

import jax.numpy as jnp


def partial_average_ref(z: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Weighted neighborhood average: mix = sum_k w[k] * z[k, :].

    z: (K, D) stacked neighbor payloads (self included), w: (K,) weights.
    """
    return jnp.einsum("k,kd->d", w.astype(z.dtype), z)


def decentlam_update_ref(z, w, x, m, gamma, beta):
    """DecentLaM fused update (eq. 17). Returns (x_new, m_new).

    z: (K, D) neighbor half-steps; w: (K,); x, m: (D,); gamma, beta scalars.
    """
    mix = partial_average_ref(z, w)
    gt = (x - mix) / gamma
    m_new = beta * m + gt
    x_new = x - gamma * m_new
    return x_new, m_new


def dmsgd_update_ref(z, w):
    """DmSGD application step is a plain partial average of half-steps."""
    return partial_average_ref(z, w)


def linear_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Dense layer oracle: y = x @ w + b. x: (B, I), w: (I, O), b: (O,)."""
    return jnp.dot(x, w) + b


def linear_grads_ref(x, w, dy):
    """VJP oracle for the dense layer: (dx, dw, db)."""
    dx = jnp.dot(dy, w.T)
    dw = jnp.dot(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db
