"""Layer-2 JAX models, written over a single flat f32 parameter vector.

Every model here exposes the same two jit-able entry points:

    loss_and_grad(theta, batch...) -> (loss, grad)   # training artifact
    logits(theta, x)               -> logits         # evaluation artifact

`theta` is one flat f32[D] vector; layers are sliced + reshaped out of it
inside the traced function. This keeps the Rust side trivial — one buffer
per node — and makes the decentralized update kernels (which operate on
flat vectors) compose with any model.

Dense layers route through the Pallas `fused_linear` kernel (Layer 1), so
the kernel lowers into the same HLO artifact the Rust runtime executes.

Models:
  * MLP classifier family (five capacities — the Table 4 "architectures").
  * Character-level transformer LM (the end-to-end example workload).
  * Multi-head "detection" model: shared trunk + classification head (CE)
    + box head (smooth-L1), the Table 6 substitute task.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fused_linear


# --------------------------------------------------------------------------
# Flat-parameter packing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shapes (in order) packed into the flat theta vector."""

    shapes: Tuple[Tuple[int, ...], ...]

    @property
    def sizes(self) -> List[int]:
        return [int(np.prod(s)) for s in self.shapes]

    @property
    def dim(self) -> int:
        return int(sum(self.sizes))

    def unpack(self, theta: jnp.ndarray) -> List[jnp.ndarray]:
        out, off = [], 0
        for shape, size in zip(self.shapes, self.sizes):
            out.append(theta[off : off + size].reshape(shape))
            off += size
        return out

    def layer_ranges(self) -> List[Tuple[int, int]]:
        """(start, end) offsets per tensor — consumed by Rust LARS, which
        needs per-layer norms over the flat vector."""
        ranges, off = [], 0
        for size in self.sizes:
            ranges.append((off, off + size))
            off += size
        return ranges


def _he_init(rng: np.random.Generator, shape, fan_in) -> np.ndarray:
    return rng.normal(0.0, np.sqrt(2.0 / fan_in), size=shape).astype(np.float32)


# --------------------------------------------------------------------------
# MLP classifier family
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    name: str
    input_dim: int
    hidden: Tuple[int, ...]
    num_classes: int

    def spec(self) -> ParamSpec:
        dims = [self.input_dim, *self.hidden, self.num_classes]
        shapes: List[Tuple[int, ...]] = []
        for i, o in zip(dims[:-1], dims[1:]):
            shapes.append((i, o))
            shapes.append((o,))
        return ParamSpec(tuple(shapes))

    def init(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        dims = [self.input_dim, *self.hidden, self.num_classes]
        parts = []
        for i, o in zip(dims[:-1], dims[1:]):
            parts.append(_he_init(rng, (i, o), i).ravel())
            parts.append(np.zeros(o, np.float32))
        return np.concatenate(parts)


# The Table 4 "architecture" family (ResNet-18/34/50, MobileNet-v2,
# EfficientNet stand-ins of increasing capacity — see DESIGN.md §2).
MLP_FAMILY = {
    "mlp-xs": MlpConfig("mlp-xs", 64, (64,), 10),
    "mlp-s": MlpConfig("mlp-s", 64, (128, 64), 10),
    "mlp-m": MlpConfig("mlp-m", 64, (256, 128), 10),
    "mlp-l": MlpConfig("mlp-l", 64, (512, 256, 128), 10),
    "mlp-xl": MlpConfig("mlp-xl", 64, (1024, 512, 256), 10),
}


def mlp_logits(cfg: MlpConfig, theta: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    params = cfg.spec().unpack(theta)
    h = x
    n_layers = len(params) // 2
    for li in range(n_layers):
        w, b = params[2 * li], params[2 * li + 1]
        h = fused_linear(h, w, b)
        if li + 1 < n_layers:
            h = jax.nn.relu(h)
    return h


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def mlp_loss(cfg: MlpConfig, theta, x, y) -> jnp.ndarray:
    return softmax_xent(mlp_logits(cfg, theta, x), y)


def mlp_loss_and_grad(cfg: MlpConfig, theta, x, y):
    loss, grad = jax.value_and_grad(lambda t: mlp_loss(cfg, t, x, y))(theta)
    return loss, grad


# --------------------------------------------------------------------------
# Character-level transformer LM (end-to-end example workload)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm-base"
    vocab: int = 96
    seq_len: int = 128
    d_model: int = 256
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 1024

    def spec(self) -> ParamSpec:
        d, f, v, t = self.d_model, self.d_ff, self.vocab, self.seq_len
        shapes: List[Tuple[int, ...]] = [(v, d), (t, d)]  # tok emb, pos emb
        for _ in range(self.n_layers):
            shapes += [
                (d,), (d,),          # ln1 scale, bias
                (d, 3 * d), (3 * d,),  # qkv
                (d, d), (d,),        # attn out
                (d,), (d,),          # ln2 scale, bias
                (d, f), (f,),        # ff in
                (f, d), (d,),        # ff out
            ]
        shapes += [(d,), (d,), (d, v), (v,)]  # final ln, lm head
        return ParamSpec(tuple(shapes))

    def init(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        parts: List[np.ndarray] = []
        for shape in self.spec().shapes:
            if len(shape) == 1:
                # LayerNorm scales start at 1, everything else at 0. The
                # packer cannot tell them apart, so initialize scales via
                # position: handled below by post-pass.
                parts.append(np.zeros(shape, np.float32))
            else:
                fan_in = shape[0]
                parts.append(
                    rng.normal(0.0, 0.02 * np.sqrt(768 / fan_in), shape)
                    .astype(np.float32)
                    .ravel()
                )
        theta = np.concatenate([p.ravel() for p in parts])
        # Second pass: set LN scale vectors to 1.0.
        spec = self.spec()
        ranges = spec.layer_ranges()
        ln_scale_tensor_idx = []
        # Per layer block of 12 tensors starting at index 2: ln1 scale at +0,
        # ln2 scale at +6; final ln scale at -4.
        for layer in range(self.n_layers):
            base = 2 + 12 * layer
            ln_scale_tensor_idx += [base, base + 6]
        ln_scale_tensor_idx.append(len(spec.shapes) - 4)
        for ti in ln_scale_tensor_idx:
            s, e = ranges[ti]
            theta[s:e] = 1.0
        return theta


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(x, wqkv, bqkv, wo, bo, n_heads):
    b, t, d = x.shape
    qkv = fused_linear(x.reshape(b * t, d), wqkv, bqkv).reshape(b, t, 3 * d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = d // n_heads

    def heads(u):
        return u.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b * t, d)
    return fused_linear(out, wo, bo).reshape(b, t, d)


def transformer_logits(cfg: TransformerConfig, theta, tokens):
    """tokens: (B, T) int32 -> logits (B, T, V)."""
    p = cfg.spec().unpack(theta)
    idx = 0
    tok_emb, pos_emb = p[0], p[1]
    idx = 2
    b, t = tokens.shape
    h = tok_emb[tokens] + pos_emb[None, :t, :]
    d = cfg.d_model
    for _ in range(cfg.n_layers):
        (ln1s, ln1b, wqkv, bqkv, wo, bo, ln2s, ln2b, w1, b1, w2, b2) = p[
            idx : idx + 12
        ]
        idx += 12
        h = h + _attention(_layer_norm(h, ln1s, ln1b), wqkv, bqkv, wo, bo, cfg.n_heads)
        hn = _layer_norm(h, ln2s, ln2b)
        ff = fused_linear(hn.reshape(b * t, d), w1, b1)
        ff = jax.nn.gelu(ff)
        ff = fused_linear(ff, w2, b2).reshape(b, t, d)
        h = h + ff
    lnfs, lnfb, whead, bhead = p[idx : idx + 4]
    h = _layer_norm(h, lnfs, lnfb)
    return fused_linear(h.reshape(b * t, d), whead, bhead).reshape(b, t, cfg.vocab)


def transformer_loss(cfg: TransformerConfig, theta, tokens, targets):
    logits = transformer_logits(cfg, theta, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def transformer_loss_and_grad(cfg: TransformerConfig, theta, tokens, targets):
    loss, grad = jax.value_and_grad(
        lambda t: transformer_loss(cfg, t, tokens, targets)
    )(theta)
    return loss, grad


# --------------------------------------------------------------------------
# Multi-head "detection" model (Table 6 substitute)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DetConfig:
    name: str = "det-head"
    input_dim: int = 64
    trunk: Tuple[int, ...] = (128, 128)
    num_classes: int = 10
    box_dim: int = 4

    def spec(self) -> ParamSpec:
        shapes: List[Tuple[int, ...]] = []
        dims = [self.input_dim, *self.trunk]
        for i, o in zip(dims[:-1], dims[1:]):
            shapes += [(i, o), (o,)]
        last = dims[-1]
        shapes += [(last, self.num_classes), (self.num_classes,)]  # cls head
        shapes += [(last, self.box_dim), (self.box_dim,)]  # box head
        return ParamSpec(tuple(shapes))

    def init(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        parts = []
        for shape in self.spec().shapes:
            if len(shape) == 1:
                parts.append(np.zeros(shape, np.float32))
            else:
                parts.append(_he_init(rng, shape, shape[0]).ravel())
        return np.concatenate(parts)


def det_forward(cfg: DetConfig, theta, x):
    p = cfg.spec().unpack(theta)
    h = x
    n_trunk = len(cfg.trunk)
    for li in range(n_trunk):
        h = jax.nn.relu(fused_linear(h, p[2 * li], p[2 * li + 1]))
    base = 2 * n_trunk
    cls = fused_linear(h, p[base], p[base + 1])
    box = fused_linear(h, p[base + 2], p[base + 3])
    return cls, box


def smooth_l1(pred, target):
    d = jnp.abs(pred - target)
    return jnp.mean(jnp.where(d < 1.0, 0.5 * d * d, d - 0.5))


def det_loss(cfg: DetConfig, theta, x, y, boxes):
    cls, box = det_forward(cfg, theta, x)
    return softmax_xent(cls, y) + smooth_l1(box, boxes)


def det_loss_and_grad(cfg: DetConfig, theta, x, y, boxes):
    loss, grad = jax.value_and_grad(lambda t: det_loss(cfg, t, x, y, boxes))(theta)
    return loss, grad
