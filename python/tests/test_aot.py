"""AOT pipeline tests: manifest integrity, artifact invariants, golden
vectors. Runs the emitter into a temp dir (quick mode) so the test does
not depend on `make artifacts` having run."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    em = aot.Emitter(str(d))
    cfg = M.MLP_FAMILY["mlp-xs"]
    aot.emit_mlp(em, cfg, seed=1)
    aot.emit_update_kernels(em, cfg.spec().dim)
    aot.emit_golden(em)
    em.finish()
    return str(d)


def _manifest(outdir):
    with open(os.path.join(outdir, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_artifacts_listed_and_present(self, outdir):
        man = _manifest(outdir)
        assert man["artifacts"], "no artifacts emitted"
        for name, art in man["artifacts"].items():
            path = os.path.join(outdir, art["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 100, name

    def test_hlo_text_is_text(self, outdir):
        man = _manifest(outdir)
        for art in man["artifacts"].values():
            with open(os.path.join(outdir, art["file"])) as f:
                head = f.read(200)
            assert "HloModule" in head

    def test_grad_artifact_signature(self, outdir):
        man = _manifest(outdir)
        model = man["models"]["mlp-xs"]
        art = man["artifacts"]["mlp-xs_grad"]
        dim = model["dim"]
        assert art["inputs"][0]["shape"] == [dim]
        assert art["inputs"][1]["shape"] == [model["micro_batch"], model["input_dim"]]
        assert art["inputs"][1]["dtype"] == "f32"
        assert art["inputs"][2]["dtype"] == "i32"
        assert art["n_outputs"] == 2

    def test_init_bin_matches_dim(self, outdir):
        man = _manifest(outdir)
        model = man["models"]["mlp-xs"]
        raw = np.fromfile(os.path.join(outdir, model["init"]), dtype="<f4")
        assert raw.shape == (model["dim"],)
        assert np.isfinite(raw).all()

    def test_layer_ranges_partition(self, outdir):
        man = _manifest(outdir)
        model = man["models"]["mlp-xs"]
        ranges = model["layer_ranges"]
        assert ranges[0][0] == 0 and ranges[-1][1] == model["dim"]

    def test_kernel_artifacts_padded_k(self, outdir):
        man = _manifest(outdir)
        for name, k in man["kernels"].items():
            art = man["artifacts"][name]
            assert art["inputs"][0]["shape"][0] == aot.KPAD
            assert k["kpad"] == aot.KPAD


class TestGolden:
    def test_golden_consistent_with_oracle(self, outdir):
        from compile.kernels import ref
        import jax.numpy as jnp

        with open(os.path.join(outdir, "golden.json")) as f:
            g = json.load(f)["decentlam_update"]
        z = jnp.asarray(np.array(g["z"], np.float32).reshape(g["k"], g["d"]))
        xn, mn = ref.decentlam_update_ref(
            z,
            jnp.asarray(np.array(g["w"], np.float32)),
            jnp.asarray(np.array(g["x"], np.float32)),
            jnp.asarray(np.array(g["m"], np.float32)),
            np.float32(g["gamma"]),
            np.float32(g["beta"]),
        )
        np.testing.assert_allclose(np.asarray(xn), g["x_new"], rtol=1e-6)
        np.testing.assert_allclose(np.asarray(mn), g["m_new"], rtol=1e-6)

    def test_golden_weights_stochastic(self, outdir):
        with open(os.path.join(outdir, "golden.json")) as f:
            g = json.load(f)["decentlam_update"]
        assert abs(sum(g["w"]) - 1.0) < 1e-6


class TestCli:
    def test_quick_cli_runs(self, tmp_path):
        env = dict(os.environ)
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        out = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--outdir", str(tmp_path), "--quick"],
            cwd=root,
            env=env,
            capture_output=True,
            text=True,
            timeout=300,
        )
        assert out.returncode == 0, out.stderr
        assert os.path.exists(tmp_path / "manifest.json")
