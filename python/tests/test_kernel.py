"""Kernel-vs-oracle correctness: the CORE Layer-1 signal.

hypothesis sweeps shapes, dtypes and hyper-parameters; every property
asserts allclose between the Pallas kernel (interpret=True) and the
pure-jnp oracle in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decentlam_update, fused_linear, partial_average, ref

F32 = np.float32


def _arr(rng, shape, dtype=F32, scale=1.0):
    return jnp.asarray((rng.normal(size=shape) * scale).astype(dtype))


def _weights(rng, k, dtype=F32):
    """A valid mixing row: non-negative, sums to one (Assumption A.3)."""
    w = rng.random(k).astype(np.float64) + 0.05
    return jnp.asarray((w / w.sum()).astype(dtype))


dims = st.sampled_from([1, 2, 4, 8, 16, 64, 256, 1024])
degrees = st.integers(min_value=1, max_value=8)
gammas = st.floats(min_value=1e-4, max_value=1.0)
betas = st.floats(min_value=0.0, max_value=0.99)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestDecentLamUpdate:
    @settings(max_examples=40, deadline=None)
    @given(d=dims, k=degrees, gamma=gammas, beta=betas, seed=seeds)
    def test_matches_oracle(self, d, k, gamma, beta, seed):
        rng = np.random.default_rng(seed)
        z, w = _arr(rng, (k, d)), _weights(rng, k)
        x, m = _arr(rng, d), _arr(rng, d)
        hp = jnp.asarray(np.array([gamma, beta], F32))
        xn, mn = decentlam_update(z, w, x, m, hp, block_d=min(d, 256))
        xr, mr = ref.decentlam_update_ref(z, w, x, m, F32(gamma), F32(beta))
        np.testing.assert_allclose(xn, xr, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(mn, mr, rtol=2e-4, atol=2e-4)

    @settings(max_examples=15, deadline=None)
    @given(d=dims, k=degrees, seed=seeds)
    def test_fused_identity_beta0_selfweight1(self, d, k, seed):
        """With w = e_self and beta=0, the update must reduce to plain SGD:
        x' = z_self, m' = grad (invariant used by the Rust fast path)."""
        rng = np.random.default_rng(seed)
        gamma = F32(0.1)
        x, m, g = _arr(rng, d), _arr(rng, d), _arr(rng, d)
        z = jnp.zeros((k, d), F32).at[0].set(x - gamma * g)
        w = jnp.zeros((k,), F32).at[0].set(1.0)
        hp = jnp.asarray(np.array([gamma, 0.0], F32))
        xn, mn = decentlam_update(z, w, x, m, hp, block_d=min(d, 256))
        np.testing.assert_allclose(xn, x - gamma * g, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(mn, g, rtol=2e-3, atol=2e-3)

    @settings(max_examples=15, deadline=None)
    @given(d=dims, k=degrees, gamma=gammas, beta=betas, seed=seeds)
    def test_zero_weight_padding_is_noop(self, d, k, gamma, beta, seed):
        """Padding the neighborhood with zero-weight rows must not change
        the result — the property the KPAD artifact relies on."""
        rng = np.random.default_rng(seed)
        z, w = _arr(rng, (k, d)), _weights(rng, k)
        x, m = _arr(rng, d), _arr(rng, d)
        hp = jnp.asarray(np.array([gamma, beta], F32))
        zp = jnp.concatenate([z, _arr(rng, (2, d), scale=100.0)])
        wp = jnp.concatenate([w, jnp.zeros(2, F32)])
        a = decentlam_update(z, w, x, m, hp, block_d=min(d, 256))
        b = decentlam_update(zp, wp, x, m, hp, block_d=min(d, 256))
        np.testing.assert_allclose(a[0], b[0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(a[1], b[1], rtol=1e-5, atol=1e-5)

    def test_fixed_point(self):
        """At consensus with zero gradient, the update is a no-op
        (x' = x, m' = beta*m): the bias-freeness DecentLaM is built for."""
        d, k = 32, 4
        rng = np.random.default_rng(0)
        x = _arr(rng, d)
        z = jnp.tile(x[None, :], (k, 1))  # all neighbors at x, zero grad
        w = _weights(rng, k)
        m = jnp.zeros(d, F32)
        hp = jnp.asarray(np.array([0.05, 0.9], F32))
        xn, mn = decentlam_update(z, w, x, m, hp, block_d=32)
        np.testing.assert_allclose(xn, x, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(mn, jnp.zeros(d), atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(3)
        d, k = 64, 4
        z = _arr(rng, (k, d)).astype(dtype)
        w = _weights(rng, k).astype(dtype)
        x, m = _arr(rng, d).astype(dtype), _arr(rng, d).astype(dtype)
        hp = jnp.asarray(np.array([0.1, 0.9], F32)).astype(dtype)
        xn, mn = decentlam_update(z, w, x, m, hp, block_d=64)
        xr, mr = ref.decentlam_update_ref(z, w, x, m, dtype(0.1), dtype(0.9))
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(xn, F32), np.asarray(xr, F32), rtol=tol, atol=tol
        )
        np.testing.assert_allclose(
            np.asarray(mn, F32), np.asarray(mr, F32), rtol=tol, atol=tol
        )


class TestPartialAverage:
    @settings(max_examples=40, deadline=None)
    @given(d=dims, k=degrees, seed=seeds)
    def test_matches_oracle(self, d, k, seed):
        rng = np.random.default_rng(seed)
        z, w = _arr(rng, (k, d)), _weights(rng, k)
        mix = partial_average(z, w, block_d=min(d, 256))
        np.testing.assert_allclose(
            mix, ref.partial_average_ref(z, w), rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=15, deadline=None)
    @given(d=dims, k=degrees, seed=seeds)
    def test_consensus_preserved(self, d, k, seed):
        """Averaging identical payloads with a stochastic row returns the
        payload (W 1 = 1, Assumption A.3)."""
        rng = np.random.default_rng(seed)
        x = _arr(rng, d)
        z = jnp.tile(x[None, :], (k, 1))
        mix = partial_average(z, _weights(rng, k), block_d=min(d, 256))
        np.testing.assert_allclose(mix, x, rtol=1e-5, atol=1e-5)


class TestFusedLinear:
    @settings(max_examples=30, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 8, 32]),
        i=st.sampled_from([1, 4, 16, 64]),
        o=st.sampled_from([1, 4, 16, 64]),
        seed=seeds,
    )
    def test_forward_matches_oracle(self, b, i, o, seed):
        rng = np.random.default_rng(seed)
        x, w, bias = _arr(rng, (b, i)), _arr(rng, (i, o)), _arr(rng, o)
        np.testing.assert_allclose(
            fused_linear(x, w, bias), ref.linear_ref(x, w, bias), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.sampled_from([1, 8, 32]),
        i=st.sampled_from([4, 16]),
        o=st.sampled_from([4, 16]),
        seed=seeds,
    )
    def test_custom_vjp_matches_autodiff(self, b, i, o, seed):
        rng = np.random.default_rng(seed)
        x, w, bias = _arr(rng, (b, i)), _arr(rng, (i, o)), _arr(rng, o)

        def loss_k(a, ww, bb):
            return jnp.sum(jnp.tanh(fused_linear(a, ww, bb)))

        def loss_r(a, ww, bb):
            return jnp.sum(jnp.tanh(ref.linear_ref(a, ww, bb)))

        gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, w, bias)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, w, bias)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-3)

    def test_vjp_kernels_match_manual_oracle(self):
        rng = np.random.default_rng(11)
        x, w, bias = _arr(rng, (8, 16)), _arr(rng, (16, 4)), _arr(rng, 4)
        dy = _arr(rng, (8, 4))
        _, vjp = jax.vjp(fused_linear, x, w, bias)
        dx, dw, db = vjp(dy)
        rdx, rdw, rdb = ref.linear_grads_ref(x, w, dy)
        np.testing.assert_allclose(dx, rdx, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(dw, rdw, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(db, rdb, rtol=1e-4, atol=1e-4)
