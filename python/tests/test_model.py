"""Layer-2 model tests: shapes, packing, gradients, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

F32 = np.float32


class TestParamSpec:
    @settings(max_examples=20, deadline=None)
    @given(
        shapes=st.lists(
            st.tuples(st.integers(1, 7), st.integers(1, 7)), min_size=1, max_size=5
        )
    )
    def test_pack_unpack_roundtrip(self, shapes):
        spec = M.ParamSpec(tuple(tuple(s) for s in shapes))
        theta = jnp.arange(spec.dim, dtype=jnp.float32)
        parts = spec.unpack(theta)
        flat_again = jnp.concatenate([p.ravel() for p in parts])
        np.testing.assert_array_equal(flat_again, theta)

    def test_layer_ranges_partition_dim(self):
        for cfg in M.MLP_FAMILY.values():
            spec = cfg.spec()
            ranges = spec.layer_ranges()
            assert ranges[0][0] == 0
            assert ranges[-1][1] == spec.dim
            for (_, e0), (s1, _) in zip(ranges, ranges[1:]):
                assert e0 == s1


class TestMlp:
    @pytest.mark.parametrize("name", sorted(M.MLP_FAMILY))
    def test_init_dim_matches_spec(self, name):
        cfg = M.MLP_FAMILY[name]
        assert cfg.init(0).shape == (cfg.spec().dim,)

    def test_logits_shape(self):
        cfg = M.MLP_FAMILY["mlp-s"]
        theta = jnp.asarray(cfg.init(1))
        x = jnp.ones((5, cfg.input_dim), jnp.float32)
        assert M.mlp_logits(cfg, theta, x).shape == (5, cfg.num_classes)

    def test_initial_loss_near_log_c(self):
        cfg = M.MLP_FAMILY["mlp-s"]
        theta = jnp.asarray(cfg.init(1))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(128, cfg.input_dim)).astype(F32))
        y = jnp.asarray(rng.integers(0, cfg.num_classes, 128).astype(np.int32))
        loss = M.mlp_loss(cfg, theta, x, y)
        assert abs(float(loss) - np.log(cfg.num_classes)) < 0.6

    def test_grad_matches_finite_difference(self):
        cfg = M.MlpConfig("tiny", 4, (5,), 3)
        theta = jnp.asarray(cfg.init(0))
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(8, 4)).astype(F32))
        y = jnp.asarray(rng.integers(0, 3, 8).astype(np.int32))
        _, grad = M.mlp_loss_and_grad(cfg, theta, x, y)
        eps = 1e-3
        idx = rng.integers(0, cfg.spec().dim, 10)
        for i in idx:
            e = jnp.zeros_like(theta).at[int(i)].set(eps)
            fd = (M.mlp_loss(cfg, theta + e, x, y) - M.mlp_loss(cfg, theta - e, x, y)) / (
                2 * eps
            )
            assert abs(float(fd) - float(grad[int(i)])) < 5e-3

    def test_sgd_reduces_loss(self):
        cfg = M.MLP_FAMILY["mlp-xs"]
        theta = jnp.asarray(cfg.init(2))
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(64, cfg.input_dim)).astype(F32))
        y = jnp.asarray((np.argmax(np.asarray(x)[:, :10], axis=1)).astype(np.int32))
        l0, _ = M.mlp_loss_and_grad(cfg, theta, x, y)
        step = jax.jit(
            lambda t: t - 0.2 * M.mlp_loss_and_grad(cfg, t, x, y)[1]
        )
        for _ in range(30):
            theta = step(theta)
        l1, _ = M.mlp_loss_and_grad(cfg, theta, x, y)
        assert float(l1) < 0.6 * float(l0)


class TestTransformer:
    CFG = M.TransformerConfig(
        name="lm-tiny", vocab=32, seq_len=16, d_model=32, n_heads=2, n_layers=2, d_ff=64
    )

    def test_init_dim_matches_spec(self):
        assert self.CFG.init(0).shape == (self.CFG.spec().dim,)

    def test_ln_scales_initialized_to_one(self):
        theta = self.CFG.init(0)
        spec = self.CFG.spec()
        ranges = spec.layer_ranges()
        # ln1 scale of layer 0 is tensor index 2.
        s, e = ranges[2]
        np.testing.assert_array_equal(theta[s:e], np.ones(e - s, F32))

    def test_logits_shape_and_initial_loss(self):
        theta = jnp.asarray(self.CFG.init(1))
        toks = jnp.asarray(
            np.random.default_rng(0)
            .integers(0, self.CFG.vocab, (3, self.CFG.seq_len))
            .astype(np.int32)
        )
        logits = M.transformer_logits(self.CFG, theta, toks)
        assert logits.shape == (3, self.CFG.seq_len, self.CFG.vocab)
        loss = M.transformer_loss(self.CFG, theta, toks, toks)
        assert abs(float(loss) - np.log(self.CFG.vocab)) < 0.5

    def test_causality(self):
        """Changing a future token must not change past logits."""
        theta = jnp.asarray(self.CFG.init(1))
        rng = np.random.default_rng(4)
        toks = rng.integers(0, self.CFG.vocab, (1, self.CFG.seq_len)).astype(np.int32)
        l0 = M.transformer_logits(self.CFG, theta, jnp.asarray(toks))
        toks2 = toks.copy()
        toks2[0, -1] = (toks2[0, -1] + 1) % self.CFG.vocab
        l1 = M.transformer_logits(self.CFG, theta, jnp.asarray(toks2))
        np.testing.assert_allclose(l0[0, :-1], l1[0, :-1], rtol=1e-4, atol=1e-4)

    def test_overfits_tiny_sequence(self):
        theta = jnp.asarray(self.CFG.init(5))
        toks = jnp.asarray(
            (np.arange(16) % 4).reshape(1, 16).astype(np.int32)
        )  # trivially predictable
        tgt = jnp.asarray(np.roll(np.asarray(toks), -1, axis=1))
        step = jax.jit(
            lambda t: t - 0.5 * M.transformer_loss_and_grad(self.CFG, t, toks, tgt)[1]
        )
        l0 = float(M.transformer_loss(self.CFG, theta, toks, tgt))
        for _ in range(60):
            theta = step(theta)
        l1 = float(M.transformer_loss(self.CFG, theta, toks, tgt))
        assert l1 < 0.5 * l0


class TestDet:
    CFG = M.DetConfig()

    def test_forward_shapes(self):
        theta = jnp.asarray(self.CFG.init(0))
        x = jnp.ones((7, self.CFG.input_dim), jnp.float32)
        cls, box = M.det_forward(self.CFG, theta, x)
        assert cls.shape == (7, self.CFG.num_classes)
        assert box.shape == (7, self.CFG.box_dim)

    def test_smooth_l1_regimes(self):
        # quadratic inside |d|<1, linear outside
        p = jnp.asarray(np.array([[0.5], [3.0]], F32))
        t = jnp.zeros((2, 1), jnp.float32)
        assert abs(float(M.smooth_l1(p[:1], t[:1])) - 0.125) < 1e-6
        assert abs(float(M.smooth_l1(p[1:], t[1:])) - 2.5) < 1e-6

    def test_grad_nonzero_both_heads(self):
        theta = jnp.asarray(self.CFG.init(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, self.CFG.input_dim)).astype(F32))
        y = jnp.asarray(rng.integers(0, self.CFG.num_classes, 16).astype(np.int32))
        b = jnp.asarray(rng.normal(size=(16, self.CFG.box_dim)).astype(F32))
        _, grad = M.det_loss_and_grad(self.CFG, theta, x, y, b)
        ranges = self.CFG.spec().layer_ranges()
        cls_w = grad[ranges[-4][0] : ranges[-4][1]]
        box_w = grad[ranges[-2][0] : ranges[-2][1]]
        assert float(jnp.linalg.norm(cls_w)) > 0
        assert float(jnp.linalg.norm(box_w)) > 0
