//! Bench: end-to-end training-step time through the coordinator — the
//! Tables 1/3/4 workload path (native engine, threaded gradient phase)
//! and, when built with `--features pjrt` and artifacts are present,
//! the PJRT path (JAX MLP grad + the Pallas update-kernel artifact).
//! EXPERIMENTS.md §Perf's headline rows.
//!
//! Run: `cargo bench --bench end_to_end_step`
//! (PJRT rows: `make artifacts && cargo bench --features pjrt --bench end_to_end_step`).

use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::experiments::mlp_workload_named;
use decentlam::util::bench::Bench;
use decentlam::util::cli::Args;
use decentlam::util::config::{Config, LrSchedule};

fn data(nodes: usize) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 512,
        eval_samples: 64,
        dirichlet_alpha: 0.3,
        seed: 1,
        ..Default::default()
    })
}

fn cfg_for(optimizer: &str, nodes: usize, total_batch: usize, threads: usize) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.nodes = nodes;
    cfg.total_batch = total_batch;
    cfg.micro_batch = 64;
    cfg.lr = 0.01;
    cfg.linear_scaling = false;
    cfg.schedule = LrSchedule::Constant;
    cfg.steps = 1;
    cfg.threads = threads;
    cfg
}

fn main() {
    let args = Args::from_env();
    let mut bench = Bench::new();
    let nodes = 8;

    // Native engine: threaded vs sequential gradient phase, small/large batch.
    for &(batch, threads, label) in &[
        (512usize, 1usize, "seq"),
        (512, 0, "par"),
        (4096, 0, "par"),
    ] {
        let wl = mlp_workload_named("mlp-s", data(nodes), 64, 1).unwrap();
        let mut t = Trainer::new(cfg_for("decentlam", nodes, batch, threads), wl).unwrap();
        let mut k = 0usize;
        bench.case(
            &format!("native mlp-s step n={nodes} batch={batch} grad={label}"),
            || {
                t.step(k);
                k += 1;
            },
        );
    }

    #[cfg(feature = "pjrt")]
    pjrt_benches::run(&mut bench);
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt feature disabled: native rows only — rebuild with --features pjrt)");
    bench.write_json_arg(&args).expect("--json write failed");
}

#[cfg(feature = "pjrt")]
mod pjrt_benches {
    use std::path::Path;

    use decentlam::coordinator::Trainer;
    use decentlam::grad::pjrt;
    use decentlam::runtime::{Manifest, Runtime, Tensor};
    use decentlam::util::bench::Bench;
    use decentlam::util::rng::Pcg64;

    use super::{cfg_for, data};

    pub fn run(bench: &mut Bench) {
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            println!("(artifacts missing: skipping PJRT benches — run `make artifacts`)");
            return;
        }
        let manifest = Manifest::load(dir).unwrap();
        let runtime = Runtime::start().unwrap();
        let rt = runtime.handle();

        // Single mlp-s grad artifact call.
        rt.load_artifact(&manifest, "mlp-s_grad").unwrap();
        let info = manifest.model("mlp-s").unwrap();
        let theta = manifest.load_init(&info).unwrap();
        let mut rng = Pcg64::seeded(2);
        let mut xb = vec![0.0f32; info.micro_batch * info.input_dim];
        rng.normal_fill(&mut xb, 1.0);
        let yb: Vec<i32> = (0..info.micro_batch).map(|i| (i % 10) as i32).collect();
        bench.case("pjrt mlp-s_grad exec (B=64)", || {
            let out = rt
                .exec(
                    "mlp-s_grad",
                    vec![
                        Tensor::f32(theta.clone(), &[info.dim as i64]),
                        Tensor::f32(xb.clone(), &[info.micro_batch as i64, info.input_dim as i64]),
                        Tensor::i32(yb.clone(), &[info.micro_batch as i64]),
                    ],
                )
                .unwrap();
            assert_eq!(out.len(), 2);
        });

        // The Pallas decentlam_update kernel artifact at mlp-s size.
        let kernel = manifest.update_kernel_for_dim(info.dim).unwrap();
        rt.load_artifact(&manifest, &kernel).unwrap();
        let d = info.dim;
        let mut z = vec![0.0f32; 8 * d];
        rng.normal_fill(&mut z, 1.0);
        let w = vec![0.2f32, 0.2, 0.2, 0.2, 0.2, 0.0, 0.0, 0.0];
        let x = vec![0.1f32; d];
        let m = vec![0.0f32; d];
        bench.case_bytes(
            &format!("pjrt pallas decentlam_update d={d}"),
            ((8 + 4) * d * 4) as f64,
            || {
                let out = rt
                    .exec(
                        &kernel,
                        vec![
                            Tensor::f32(z.clone(), &[8, d as i64]),
                            Tensor::f32(w.clone(), &[8]),
                            Tensor::f32(x.clone(), &[d as i64]),
                            Tensor::f32(m.clone(), &[d as i64]),
                            Tensor::f32(vec![0.05, 0.9], &[2]),
                        ],
                    )
                    .unwrap();
                assert_eq!(out.len(), 2);
            },
        );

        // Full PJRT end-to-end decentralized step (4 nodes).
        let wl = pjrt::mlp_workload(&rt, &manifest, "mlp-s", data(4)).unwrap();
        let mut t = Trainer::new(cfg_for("decentlam", 4, 256, 0), wl).unwrap();
        let mut k = 0usize;
        bench.case("pjrt end-to-end decentlam step (n=4, batch=256)", || {
            t.step(k);
            k += 1;
        });
    }
}
