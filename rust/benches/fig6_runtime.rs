//! Bench: Fig. 6 — regenerates the paper's runtime-comparison figure
//! (per-iteration compute/comm breakdown, 10 and 25 Gbps) and measures
//! the REAL in-process partial-averaging throughput that the analytic
//! model's compute side rests on.
//!
//! Run: `cargo bench --bench fig6_runtime`.

use decentlam::experiments::fig6;
use decentlam::optim::partial_average_all;
use decentlam::topology::{metropolis_hastings, Kind, Topology};
use decentlam::util::bench::{opaque, Bench};

fn main() {
    // 1. The paper figure itself (analytic model, DESIGN.md §2 substitution).
    let (rows, table) = fig6::run(&fig6::Opts::default()).unwrap();
    println!("{}", table.render());
    let band: Vec<f64> = rows
        .iter()
        .filter(|r| r.method == "decentlam")
        .map(|r| r.speedup_vs_pmsgd)
        .collect();
    println!(
        "decentralized speedup band: {:.2}x .. {:.2}x (paper: 1.2-1.9x)\n",
        band.iter().cloned().fold(f64::INFINITY, f64::min),
        band.iter().cloned().fold(0.0, f64::max)
    );

    // 2. Measured gossip throughput (the in-process exchange itself).
    let mut bench = Bench::new();
    let n = 8;
    for kind in [Kind::Ring, Kind::SymExp, Kind::Full] {
        let wm = metropolis_hastings(&Topology::build(kind, n));
        for &d in &[17_226usize, 1_000_000] {
            let src: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32; d]).collect();
            let mut dst = vec![vec![0.0f32; d]; n];
            // bytes touched ~= (edges incl self) * d * 4 reads + n*d*4 writes
            let touched: usize = (0..n).map(|i| wm.row(i).len() * d * 4).sum::<usize>() + n * d * 4;
            bench.case_bytes(
                &format!("partial_average_all {kind:?} n={n} d={d}"),
                touched as f64,
                || {
                    partial_average_all(&wm, &src, &mut dst);
                    opaque(&dst);
                },
            );
        }
    }
}
