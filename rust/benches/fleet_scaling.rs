//! Bench: gossip-step scaling to 10⁵-node fleets — the headline curve
//! of the persistent-pool executor (DESIGN.md §13, ROADMAP
//! "Million-node fleets").
//!
//! For each topology kind (ring, sym-exp) and fleet size n this target
//! measures ns/iter of one full partial-averaging round under three
//! executors over the SAME chunk geometry:
//!
//!   * `serial` — the plain sequential loop (the floor),
//!   * `spawn`  — the PR-1 spawn-per-phase reference path (scoped
//!     threads created and joined every phase),
//!   * `pool`   — the persistent worker pool (epoch handoff, no thread
//!     churn).
//!
//! Before timing, every size cross-checks all three paths bitwise
//! (parallel == serial is the repo's determinism contract, and the
//! bench doubles as a pin on it at fleet scale). The run *asserts* the
//! pool does not lose to spawn-per-phase at n ≥ 4096 — thread-creation
//! overhead is exactly what capped the old executor near n ≈ 1024 — so
//! `cargo bench --bench fleet_scaling` is a perf regression check, not
//! just a report. A per-size arena-warmed `rebuild` case rides along
//! (the elastic-churn path must stay O(edges) with no reallocation).
//!
//! Run: `cargo bench --bench fleet_scaling -- --json out.json`
//! (`DECENTLAM_BENCH_FAST=1` shrinks to n ∈ {256, 4096} — the per-PR
//! scale-smoke tier; the full curve up to n = 65536 runs nightly).

use decentlam::coordinator::NodeExecutor;
use decentlam::optim::{partial_average_all, partial_average_all_par};
use decentlam::topology::{Kind, SparseWeights, Topology};
use decentlam::util::bench::{opaque, Bench};
use decentlam::util::cli::Args;

/// Per-node parameter dimension: big enough that a row's gather spans
/// several MIX_BLOCK tiles, small enough that n = 65536 fits in RAM
/// (two f32 buffers ≈ 67 MB).
const D: usize = 128;

/// Deterministic publish buffers (no RNG needed — the bench pins
/// timing and bitwise identity, not statistics).
fn fill_src(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..D).map(|k| ((i * 31 + k * 7) % 97) as f32 * 0.03125 - 1.5).collect())
        .collect()
}

fn main() {
    let args = Args::from_env();
    let mut bench = Bench::new();
    let fast = std::env::var("DECENTLAM_BENCH_FAST").is_ok();
    let sizes: &[usize] = if fast { &[256, 4096] } else { &[256, 1024, 4096, 16384, 65536] };

    // One pool for the whole run (it persists across phases — that is
    // the point); the spawn reference gets the same thread budget.
    let pool = NodeExecutor::new(0);
    let spawn = NodeExecutor::spawn_per_phase(pool.threads());
    let serial = NodeExecutor::serial();
    println!("fleet_scaling: {} threads, d={D}, sizes {sizes:?}", pool.threads());

    for kind in [Kind::Ring, Kind::SymExp] {
        for &n in sizes {
            let topo = Topology::build(kind, n);
            let sw = SparseWeights::metropolis_hastings(&topo);
            let src = fill_src(n);
            let mut dst = vec![vec![0.0f32; D]; n];

            // Bitwise identity gate before any timing: pool == spawn ==
            // serial, element for element.
            let mut reference = vec![vec![0.0f32; D]; n];
            partial_average_all(&sw, &src, &mut reference);
            for (name, exec) in [("pool", &pool), ("spawn", &spawn), ("serial", &serial)] {
                partial_average_all_par(&sw, &src, &mut dst, exec);
                let same = dst
                    .iter()
                    .zip(&reference)
                    .all(|(a, b)| a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
                assert!(same, "{name} diverged from serial on {} n={n}", kind.name());
            }

            let label = |mode: &str| format!("fleet_scaling {} n={n} {mode}", kind.name());
            let t_serial = bench
                .case_items(&label("serial"), n as f64, || {
                    partial_average_all(&sw, &src, &mut dst);
                    opaque(&dst);
                })
                .median_ns;
            let t_spawn = bench
                .case_items(&label("spawn"), n as f64, || {
                    partial_average_all_par(&sw, &src, &mut dst, &spawn);
                    opaque(&dst);
                })
                .median_ns;
            let t_pool = bench
                .case_items(&label("pool"), n as f64, || {
                    partial_average_all_par(&sw, &src, &mut dst, &pool);
                    opaque(&dst);
                })
                .median_ns;

            // The elastic-churn rebuild path, arenas warmed: stays in
            // the trajectory so a reallocation regression shows up as
            // ns/iter, not just an allocator stat.
            let mut scratch = SparseWeights::default();
            scratch.rebuild_metropolis(&topo);
            bench.case(&label("rebuild"), || {
                scratch.rebuild_metropolis(&topo);
                opaque(scratch.nnz());
            });

            println!(
                "  {} n={n}: serial/pool {:.2}x, spawn/pool {:.2}x\n",
                kind.name(),
                t_serial / t_pool,
                t_spawn / t_pool,
            );
            // The headline assertion: at fleet scale the persistent
            // pool must not lose to per-phase spawning. 10% band
            // absorbs timer noise on runners where both paths
            // degenerate to the same inline-serial code (threads=1).
            if n >= 4096 {
                assert!(
                    t_pool <= t_spawn * 1.10,
                    "persistent pool lost to spawn-per-phase on {} at n={n}: \
                     pool {t_pool:.0} ns !<= spawn {t_spawn:.0} ns (+10% band)",
                    kind.name()
                );
            }
        }
    }
    bench.write_json_arg(&args).expect("--json write failed");
}
