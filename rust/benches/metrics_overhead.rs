//! Bench: observability cost on the training step. The `metrics-off`
//! rows ARE today's hot path — `--metrics`/`--profile` default to 0 and
//! every collector is behind a cadence gate, so metrics-off step time
//! must track `end_to_end_step` (the CI perf gate holds the off row to
//! the same trajectory bounds). The `on` rows price the collector
//! itself: an x-snapshot + two nominal mixes + canonical reductions per
//! metric step, and the profiler's WallTimer/atomics per phase.
//!
//! Run: `cargo bench --bench metrics_overhead`

use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::experiments::mlp_workload_named;
use decentlam::util::bench::Bench;
use decentlam::util::cli::Args;
use decentlam::util::config::{Config, LrSchedule};

fn data(nodes: usize) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 512,
        eval_samples: 64,
        dirichlet_alpha: 0.3,
        seed: 1,
        ..Default::default()
    })
}

fn cfg_for(metrics_every: usize, profile_every: usize) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = "dmsgd".into();
    cfg.nodes = 8;
    cfg.total_batch = 512;
    cfg.micro_batch = 64;
    cfg.lr = 0.01;
    cfg.linear_scaling = false;
    cfg.schedule = LrSchedule::Constant;
    cfg.steps = 1;
    cfg.threads = 0;
    cfg.metrics_every = metrics_every;
    cfg.profile_every = profile_every;
    cfg
}

fn main() {
    let args = Args::from_env();
    let mut bench = Bench::new();

    for &(metrics, profile, label) in &[
        (0usize, 0usize, "metrics_overhead off"),
        (1, 0, "metrics_overhead metrics every=1"),
        (0, 1, "metrics_overhead profile every=1"),
        (1, 1, "metrics_overhead both every=1"),
    ] {
        let wl = mlp_workload_named("mlp-s", data(8), 64, 1).unwrap();
        let mut t = Trainer::new(cfg_for(metrics, profile), wl).unwrap();
        let mut k = 0usize;
        bench.case(&format!("{label} (dmsgd n=8 batch=512)"), || {
            t.step(k);
            k += 1;
        });
    }
    bench.write_json_arg(&args).expect("--json write failed");
}
