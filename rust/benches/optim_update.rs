//! Bench: optimizer update rules — ns/param and effective bandwidth of
//! the Layer-3 hot path (the per-iteration exchange + update phase) for
//! every algorithm, at the mlp-s size and at a 3.2M-param (lm-base-like)
//! size. This is the bench behind EXPERIMENTS.md §Perf L3.
//!
//! Run: `cargo bench --bench optim_update` (DECENTLAM_BENCH_FAST=1 to shrink;
//! `-- --json out.json` dumps the measurements for the CI perf trajectory).

use decentlam::optim::{self, decentlam::fused_apply, NodeState, RoundCtx, Scratch};
use decentlam::topology::{metropolis_hastings, Kind, Topology};
use decentlam::util::bench::{opaque, Bench};
use decentlam::util::cli::Args;
use decentlam::util::rng::Pcg64;

fn main() {
    let args = Args::from_env();
    let mut bench = Bench::new();
    let n = 8;
    let wm = metropolis_hastings(&Topology::build(Kind::SymExp, n));

    for &d in &[17_226usize, 3_241_568] {
        println!("--- n={n} sym-exp, D={d} ---");
        let mut rng = Pcg64::seeded(1);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.normal_fill(&mut g, 1.0);
                g
            })
            .collect();
        // Fused single-node apply: the kernel-equivalent inner loop.
        {
            let mut x = vec![0.1f32; d];
            let mut m = vec![0.0f32; d];
            let mix = vec![0.05f32; d];
            // read x, m, mix + write x, m = 5 f32 streams
            bench.case_bytes(&format!("fused_apply d={d}"), (d * 4 * 5) as f64, || {
                fused_apply(&mut x, &mut m, &mix, 0.05, 0.9);
                opaque(&x);
            });
        }
        for name in optim::ALL.iter().chain(["dsgd"].iter()) {
            let mut o = optim::build(name, 12, 0.7).unwrap();
            let mut states: Vec<NodeState> =
                (0..n).map(|_| NodeState::new(vec![0.1f32; d], o.aux_count())).collect();
            let mut scratch = Scratch::new(n, d);
            let mut step = 0usize;
            bench.case_items(&format!("{name} round (n={n}) d={d}"), (n * d) as f64, || {
                let ctx = RoundCtx::new(&wm, 0.01, 0.9, step, false);
                o.round(&mut states, &grads, &ctx, &mut scratch);
                step += 1;
            });
        }
    }
    println!(
        "\nnote: `ns/item` is ns per (node x parameter); the exchange+update \
         phase should stay an order of magnitude below gradient compute."
    );
    bench.write_json_arg(&args).expect("--json write failed");
}
