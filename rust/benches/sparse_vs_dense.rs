//! Bench: the sparse neighbor-list comm engine vs the dense-matrix
//! path, at the node counts where decentralized methods are supposed to
//! shine (ring n = 64 … 1024).
//!
//! Three comparisons per size:
//!   1. **exchange** — one full partial-averaging round: CSR neighbor
//!      rows vs a dense n×n matrix–vector walk (the O(n²·d) path the
//!      engine replaces).
//!   2. **rebuild** — per-step weight reconstruction for time-varying
//!      topologies: O(edges) neighbor-list rebuild vs the O(n²)
//!      dense-matrix build.
//!   3. **parallel exchange** — the sparse round fanned out over the
//!      node executor.
//!
//! The run asserts (not just prints) that sparse beats dense on the
//! ring at n ≥ 256, so `cargo bench --bench sparse_vs_dense` doubles as
//! a perf regression check.
//!
//! Run: `cargo bench --bench sparse_vs_dense` (DECENTLAM_BENCH_FAST=1 shrinks).

use decentlam::comm::CommEngine;
use decentlam::coordinator::NodeExecutor;
use decentlam::optim::{partial_average_all, partial_average_all_par};
use decentlam::topology::{metropolis_hastings, Kind, SparseWeights, Topology};
use decentlam::util::bench::{opaque, Bench};
use decentlam::util::cli::Args;

/// The dense path: mixed[i] = Σ_j W[i][j] · src[j] walking every column
/// of the dense matrix — what an engine without neighbor lists must do.
fn dense_mix_all(dense: &decentlam::util::math::SymMatrix, src: &[Vec<f32>], dst: &mut [Vec<f32>]) {
    let n = dense.n;
    for i in 0..n {
        let row = &mut dst[i];
        row.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..n {
            let w = dense.get(i, j) as f32;
            if w != 0.0 {
                for (o, &s) in row.iter_mut().zip(&src[j]) {
                    *o += w * s;
                }
            }
        }
    }
}

fn main() {
    let args = Args::from_env();
    let mut bench = Bench::new();
    let d = 1024; // parameter dimension per node
    let fast = std::env::var("DECENTLAM_BENCH_FAST").is_ok();
    let sizes: &[usize] = if fast { &[64, 256] } else { &[64, 256, 512, 1024] };

    for &n in sizes {
        let topo = Topology::build(Kind::Ring, n);
        let wm = metropolis_hastings(&topo);
        let sw = SparseWeights::metropolis_hastings(&topo);
        let (edges, nnz) = (sw.num_edges(), sw.nnz());
        println!("--- ring n={n}, d={d}: {edges} edges, {nnz} stored weights ---");
        let src: Vec<Vec<f32>> = (0..n).map(|i| vec![(i % 17) as f32 * 0.1; d]).collect();
        let mut dst = vec![vec![0.0f32; d]; n];

        let dense = bench
            .case_items(&format!("dense exchange n={n}"), (n * d) as f64, || {
                dense_mix_all(&wm.dense, &src, &mut dst);
                opaque(&dst);
            })
            .mean_ns;
        let sparse = bench
            .case_items(&format!("sparse exchange n={n}"), (n * d) as f64, || {
                partial_average_all(&sw, &src, &mut dst);
                opaque(&dst);
            })
            .mean_ns;
        let exec = NodeExecutor::new(0);
        let sparse_par = bench
            .case_items(
                &format!("sparse exchange n={n} ({}T)", exec.threads()),
                (n * d) as f64,
                || {
                    partial_average_all_par(&sw, &src, &mut dst, &exec);
                    opaque(&dst);
                },
            )
            .mean_ns;

        // Per-step rebuild (the time-varying-topology path).
        let rebuild_dense = bench
            .case(&format!("dense W rebuild n={n}"), || {
                opaque(metropolis_hastings(&topo));
            })
            .mean_ns;
        let mut scratch_sw = SparseWeights::default();
        let rebuild_sparse = bench
            .case(&format!("sparse W rebuild n={n}"), || {
                scratch_sw.rebuild_metropolis(&topo);
                opaque(scratch_sw.nnz());
            })
            .mean_ns;

        println!(
            "  speedup: exchange {:.1}x (parallel {:.1}x), rebuild {:.1}x\n",
            dense / sparse,
            dense / sparse_par,
            rebuild_dense / rebuild_sparse,
        );
        if n >= 256 {
            assert!(
                sparse < dense,
                "sparse exchange must beat the dense path at n={n}: {sparse} !< {dense}"
            );
            assert!(
                rebuild_sparse < rebuild_dense,
                "sparse rebuild must beat the dense build at n={n}"
            );
        }
    }

    // Correctness spot-check at the largest size: both paths agree.
    let n = *sizes.last().unwrap();
    let topo = Topology::build(Kind::Ring, n);
    let wm = metropolis_hastings(&topo);
    let sw = SparseWeights::metropolis_hastings(&topo);
    let src: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32; 4]).collect();
    let mut a = vec![vec![0.0f32; 4]; n];
    let mut b = vec![vec![0.0f32; 4]; n];
    dense_mix_all(&wm.dense, &src, &mut a);
    partial_average_all(&sw, &src, &mut b);
    for i in 0..n {
        for k in 0..4 {
            assert!((a[i][k] - b[i][k]).abs() < 1e-3, "mismatch at [{i}][{k}]");
        }
    }
    println!("sparse/dense agreement verified at n={n}");
    bench.write_json_arg(&args).expect("--json write failed");
}
