//! Bench: regenerates the bias results — Fig. 2, Fig. 3 (linreg
//! convergence curves) and Table 2 (measured bias-scaling exponents) —
//! at the paper's full App. G.2 settings, and times a full linreg
//! optimizer round as the micro-benchmark.
//!
//! Run: `cargo bench --bench table_bias` (DECENTLAM_BENCH_FAST=1 shrinks
//! the step counts).

use decentlam::experiments::{fig2_3, table2};
use decentlam::util::bench::Bench;

fn main() {
    let fast = std::env::var("DECENTLAM_BENCH_FAST").is_ok();

    // Fig. 2 (DSGD vs DmSGD) and Fig. 3 (+ DecentLaM).
    let mut opts = fig2_3::Opts::default();
    if fast {
        opts.steps = 6000;
    }
    let (series, table) = fig2_3::run(&opts, true).unwrap();
    println!("{}", table.render());
    for s in &series {
        let mid = s.rel_error[s.rel_error.len() / 2];
        println!(
            "  {}: error at T/2 = {:.3e}, final = {:.3e}",
            s.method,
            mid,
            s.final_error()
        );
    }
    println!();

    // Table 2: measured exponents.
    let mut t2 = table2::Opts::default();
    if fast {
        t2.steps = 8000;
        t2.methods = vec!["dsgd".into(), "dmsgd".into(), "decentlam".into()];
    }
    let (_, table) = table2::run(&t2).unwrap();
    println!("{}", table.render());

    // Micro: one full-batch linreg DecentLaM step at App. G.2 scale.
    use decentlam::coordinator::Trainer;
    use decentlam::data::LinRegProblem;
    use decentlam::grad::linreg;
    use decentlam::util::config::{Config, LrSchedule};
    let problem = LinRegProblem::generate(8, 50, 30, 1);
    let mut cfg = Config::default();
    cfg.optimizer = "decentlam".into();
    cfg.topology = "mesh".into();
    cfg.lr = 0.001;
    cfg.linear_scaling = false;
    cfg.schedule = LrSchedule::Constant;
    cfg.steps = 1;
    cfg.threads = 1;
    let mut trainer = Trainer::new(cfg, linreg::workload(problem)).unwrap();
    let mut bench = Bench::new();
    let mut k = 0usize;
    bench.case("linreg decentlam full step (n=8, d=30)", || {
        trainer.step(k);
        k += 1;
    });
}
