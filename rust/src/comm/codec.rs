//! Payload codecs for the gossip wire path (DESIGN.md §7).
//!
//! Every decentralized optimizer here ships one (or two) parameter-sized
//! payloads per neighbor per round; at large n the wire volume — not the
//! topology — is what gates real speedups ("From promise to practice",
//! PAPERS.md). A [`PayloadCodec`] compresses what goes on the wire:
//!
//! * [`Fp32`] — identity (the pre-codec engine, bit for bit);
//! * [`Fp16`] — IEEE binary16 round-to-nearest-even, 2 bytes/element;
//! * [`Int8Stochastic`] — max-abs-scaled int8 with *seeded stochastic
//!   rounding* (unbiased, counter-keyed per (seed, step, node, slot) so
//!   the quantization replays bit-identically and is iteration-order
//!   free) plus an optional per-node **error-feedback residual**: the
//!   quantization error of round k is added back into round k+1's
//!   payload, so compression error averages out instead of accumulating;
//! * [`TopK`] — magnitude sparsification: the k largest-|v| entries ship
//!   as (index, value) pairs, the rest stay in the EF residual.
//!
//! The simulation never materializes byte buffers: `encode` writes the
//! *receiver-side reconstruction* (decode ∘ encode) directly, which is
//! value-identical to encoding once and decoding per edge because decode
//! is deterministic and senders broadcast one payload to all neighbors.
//! The wire format (int8 lanes + one f32 scale, f16 lanes, (u32, f32)
//! pairs) defines the byte accounting via [`PayloadCodec::wire_bytes`],
//! which [`crate::comm::cost::PayloadBytes`] charges instead of 4·d.
//!
//! [`CodecState`] owns the cross-round mutable state: per-(node, slot)
//! EF residuals (multi-payload rounds like da-dmsgd get one residual per
//! exchange slot, so payload kinds never share a residual) and the wire
//! buffers the mix path reads. Encoding fans out per node over the
//! [`NodeExecutor`]; each node draws from its own stream, so parallel
//! encoding is bitwise identical to serial.

use anyhow::{bail, Result};

use crate::coordinator::executor::NodeExecutor;
use crate::util::kvspec::KvSpec;
use crate::util::rng::Pcg64;

/// Which codec, parsed from the CLI form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    Fp32,
    Fp16,
    Int8,
    TopK,
}

/// Parsed codec configuration: `--codec int8,ef=true,seed=7` or
/// `topk,k=0.05`. The seed defaults to the run seed (like `--faults`).
#[derive(Debug, Clone, PartialEq)]
pub struct CodecSpec {
    pub kind: CodecKind,
    /// Error feedback: carry each round's compression error into the
    /// next round's payload. Defaults on for int8/topk (the lossy
    /// codecs it provably helps), off for fp16.
    pub ef: bool,
    /// Kept fraction for top-k sparsification, in (0, 1].
    pub k: f64,
    /// Seed of the stochastic-rounding streams.
    pub seed: u64,
    /// True when `seed=` was NOT explicit — the seed should follow the
    /// run seed (resolved later via [`CodecSpec::with_run_seed`]).
    pub seed_from_run: bool,
}

impl KvSpec for CodecSpec {
    const NAME: &'static str = "codec";
    const HAS_HEAD: bool = true;

    fn begin(head: Option<&str>, default_seed: u64) -> Result<CodecSpec> {
        let kind = match head {
            Some("fp32") | Some("none") => CodecKind::Fp32,
            Some("fp16") => CodecKind::Fp16,
            Some("int8") => CodecKind::Int8,
            Some("topk") => CodecKind::TopK,
            Some(other) => bail!("unknown codec `{other}` (fp32|fp16|int8|topk)"),
            None => bail!("empty codec spec"),
        };
        Ok(CodecSpec {
            kind,
            ef: matches!(kind, CodecKind::Int8 | CodecKind::TopK),
            k: 0.05,
            seed: default_seed,
            seed_from_run: true,
        })
    }

    // Keys that the chosen codec would silently ignore are rejected —
    // eager validation means a misconfiguration (e.g. `int8,k=0.01`
    // expecting sparsification) fails at the CLI instead of running
    // with a different meaning.
    fn set_kv(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "ef" => {
                if self.kind == CodecKind::Fp32 {
                    bail!("`ef` does not apply to fp32 (lossless identity codec)");
                }
                self.ef = v.trim().parse()?;
            }
            "k" => {
                if self.kind != CodecKind::TopK {
                    bail!("`k` only applies to the topk codec");
                }
                self.k = v.trim().parse()?;
                if !(self.k > 0.0 && self.k <= 1.0) {
                    bail!("topk fraction `k={}` outside (0, 1]", self.k);
                }
            }
            "seed" => {
                if self.kind != CodecKind::Int8 {
                    bail!("`seed` only applies to int8 (the one stochastic codec)");
                }
                self.seed = v.trim().parse()?;
                self.seed_from_run = false;
            }
            other => bail!("unknown codec key `{other}` (ef|k|seed)"),
        }
        Ok(())
    }

    fn to_spec_string(&self) -> String {
        // Emit only keys legal for the kind, so the string reparses.
        match self.kind {
            CodecKind::Fp32 => "fp32".to_string(),
            CodecKind::Fp16 => format!("fp16,ef={}", self.ef),
            CodecKind::Int8 => {
                let mut s = format!("int8,ef={}", self.ef);
                if !self.seed_from_run {
                    s.push_str(&format!(",seed={}", self.seed));
                }
                s
            }
            CodecKind::TopK => format!("topk,ef={},k={}", self.ef, self.k),
        }
    }
}

impl CodecSpec {
    /// Parse `kind[,key=value,...]` with keys `ef`, `k`, `seed`.
    pub fn parse(s: &str, default_seed: u64) -> Result<CodecSpec> {
        <CodecSpec as KvSpec>::parse(s, default_seed)
    }

    /// Canonical spec string; reparses (default_seed 0) to an equal spec.
    pub fn to_spec_string(&self) -> String {
        <CodecSpec as KvSpec>::to_spec_string(self)
    }

    /// Resolve seed inheritance: adopt `run_seed` unless `seed=` was
    /// explicit in the spec string.
    pub fn with_run_seed(mut self, run_seed: u64) -> CodecSpec {
        if self.seed_from_run {
            self.seed = run_seed;
        }
        self
    }

    /// Instantiate the codec this spec names.
    pub fn build(&self) -> Box<dyn PayloadCodec> {
        match self.kind {
            CodecKind::Fp32 => Box::new(Fp32),
            CodecKind::Fp16 => Box::new(Fp16 { ef: self.ef }),
            CodecKind::Int8 => Box::new(Int8Stochastic { ef: self.ef }),
            CodecKind::TopK => Box::new(TopK { frac: self.k, ef: self.ef }),
        }
    }
}

/// Stream identity of one encode call: every (seed, step, node, slot)
/// gets its own counter-keyed PCG64, the same discipline as
/// `sim::FaultPlan` — replayable and iteration-order free.
#[derive(Debug, Clone, Copy)]
pub struct StreamKey {
    pub seed: u64,
    pub step: usize,
    pub node: usize,
    pub slot: usize,
}

/// Domain-separation tag for the stochastic-rounding streams.
const TAG_STOCHASTIC: u64 = 0xc0de_c517;

impl StreamKey {
    fn rng(&self) -> Pcg64 {
        let entity = ((self.node as u64) << 8) | (self.slot as u64 & 0xff);
        Pcg64::counter_keyed(self.seed, TAG_STOCHASTIC, self.step as u64, entity)
    }
}

/// Reusable per-node encode scratch, owned by [`CodecState`] so the
/// per-round encode path stays allocation-free like the rest of the
/// step loop (only top-k selection needs it today).
#[derive(Debug, Clone, Default)]
pub struct EncodeScratch {
    /// (|v|, index) selection buffer for top-k.
    order: Vec<(f32, u32)>,
}

/// A gossip payload compressor. `encode` reads one node's publish
/// buffer and writes the receiver-side reconstruction into `wire`,
/// folding the error-feedback residual in and out when the codec uses
/// one; `wire_bytes` is what one encoded payload occupies on the wire.
pub trait PayloadCodec: Send + Sync {
    fn name(&self) -> &'static str;

    /// Bytes of one encoded d-element payload.
    fn wire_bytes(&self, d: usize) -> f64;

    /// Identity codecs let the engine mix the publish buffers directly
    /// (bitwise identical to the pre-codec path, zero copies).
    fn is_identity(&self) -> bool {
        false
    }

    fn uses_error_feedback(&self) -> bool {
        false
    }

    /// wire = decode(encode(src [+ residual])); residual updated in
    /// place when error feedback is on, untouched otherwise.
    fn encode(
        &self,
        key: StreamKey,
        src: &[f32],
        residual: &mut [f32],
        wire: &mut [f32],
        scratch: &mut EncodeScratch,
    );
}

/// Identity codec: raw fp32 lanes, 4 bytes/element.
pub struct Fp32;

impl PayloadCodec for Fp32 {
    fn name(&self) -> &'static str {
        "fp32"
    }

    fn wire_bytes(&self, d: usize) -> f64 {
        4.0 * d as f64
    }

    fn is_identity(&self) -> bool {
        true
    }

    fn encode(
        &self,
        _key: StreamKey,
        src: &[f32],
        _residual: &mut [f32],
        wire: &mut [f32],
        _scratch: &mut EncodeScratch,
    ) {
        wire.copy_from_slice(src);
    }
}

/// IEEE 754 binary16 round-trip, 2 bytes/element.
pub struct Fp16 {
    pub ef: bool,
}

impl PayloadCodec for Fp16 {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn wire_bytes(&self, d: usize) -> f64 {
        2.0 * d as f64
    }

    fn uses_error_feedback(&self) -> bool {
        self.ef
    }

    fn encode(
        &self,
        _key: StreamKey,
        src: &[f32],
        residual: &mut [f32],
        wire: &mut [f32],
        _scratch: &mut EncodeScratch,
    ) {
        for k in 0..src.len() {
            let v = if self.ef { src[k] + residual[k] } else { src[k] };
            let w = f16_bits_to_f32(f32_to_f16_bits(v));
            wire[k] = w;
            if self.ef {
                residual[k] = v - w;
            }
        }
    }
}

/// Max-abs-scaled int8 with seeded stochastic rounding and optional
/// error feedback: 1 byte/element + one f32 scale per payload.
pub struct Int8Stochastic {
    pub ef: bool,
}

impl PayloadCodec for Int8Stochastic {
    fn name(&self) -> &'static str {
        "int8"
    }

    fn wire_bytes(&self, d: usize) -> f64 {
        d as f64 + 4.0
    }

    fn uses_error_feedback(&self) -> bool {
        self.ef
    }

    fn encode(
        &self,
        key: StreamKey,
        src: &[f32],
        residual: &mut [f32],
        wire: &mut [f32],
        _scratch: &mut EncodeScratch,
    ) {
        let d = src.len();
        let mut maxabs = 0.0f32;
        for k in 0..d {
            let v = if self.ef { src[k] + residual[k] } else { src[k] };
            maxabs = maxabs.max(v.abs());
        }
        if maxabs == 0.0 || !maxabs.is_finite() {
            // All-zero payload quantizes exactly; non-finite payloads
            // pass through so divergence stays visible, not masked.
            for k in 0..d {
                let v = if self.ef { src[k] + residual[k] } else { src[k] };
                wire[k] = v;
                if self.ef {
                    residual[k] = 0.0;
                }
            }
            return;
        }
        let scale = maxabs / 127.0;
        let inv = 127.0 / maxabs;
        let mut rng = key.rng();
        for k in 0..d {
            let v = if self.ef { src[k] + residual[k] } else { src[k] };
            // Unbiased stochastic floor: E[q] = v/scale. The clamp only
            // guards the q = ±128 corner f32 rounding can reach.
            let q = (v * inv + rng.f32()).floor().clamp(-127.0, 127.0);
            let w = q * scale;
            wire[k] = w;
            if self.ef {
                residual[k] = v - w;
            }
        }
    }
}

/// Magnitude sparsification: keep the ⌈frac·d⌉ largest-|v| entries as
/// (u32 index, f32 value) pairs, leave the rest to the EF residual.
pub struct TopK {
    pub frac: f64,
    pub ef: bool,
}

impl TopK {
    fn kept(&self, d: usize) -> usize {
        ((self.frac * d as f64).ceil() as usize).clamp(1, d.max(1))
    }
}

impl PayloadCodec for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn wire_bytes(&self, d: usize) -> f64 {
        8.0 * self.kept(d) as f64
    }

    fn uses_error_feedback(&self) -> bool {
        self.ef
    }

    fn encode(
        &self,
        _key: StreamKey,
        src: &[f32],
        residual: &mut [f32],
        wire: &mut [f32],
        scratch: &mut EncodeScratch,
    ) {
        let d = src.len();
        if d == 0 {
            return;
        }
        let kept = self.kept(d);
        // Selection is deterministic: |v| descending, index ascending on
        // ties is a strict total order (total_cmp — no partial-order
        // surprises), so the kept SET is unique however the selection
        // algorithm permutes. A full O(d log d) sort is not needed —
        // select_nth partitions the top `kept` in O(d), and the scatter
        // below writes distinct indices, so iteration order inside the
        // kept prefix never affects the output.
        let order = &mut scratch.order;
        order.clear();
        order.extend((0..d).map(|k| {
            let v = if self.ef { src[k] + residual[k] } else { src[k] };
            (v.abs(), k as u32)
        }));
        if kept < d {
            order.select_nth_unstable_by(kept - 1, |a, b| {
                b.0.total_cmp(&a.0).then(a.1.cmp(&b.1))
            });
        }
        for k in 0..d {
            let v = if self.ef { src[k] + residual[k] } else { src[k] };
            wire[k] = 0.0;
            if self.ef {
                residual[k] = v;
            }
        }
        for &(_, idx) in &order[..kept] {
            let k = idx as usize;
            let v = if self.ef { residual[k] } else { src[k] };
            wire[k] = v;
            if self.ef {
                residual[k] = 0.0;
            }
        }
    }
}

/// Cross-round codec state owned by the trainer: the codec itself,
/// per-(slot, node) error-feedback residuals, and the wire buffers the
/// mix path (and the fault engine's stale cache) read. One instance per
/// run; `begin_step` resets the slot counter so multi-payload rounds
/// deterministically map exchange #0, #1, … to their own residuals.
pub struct CodecState {
    codec: Box<dyn PayloadCodec>,
    seed: u64,
    step: usize,
    slot: usize,
    /// residuals[slot][node] — error feedback, one buffer per exchange
    /// slot per node so payload kinds never mix residuals.
    residuals: Vec<Vec<Vec<f32>>>,
    /// wire[node] — receiver-side reconstruction of the latest exchange.
    wire: Vec<Vec<f32>>,
    /// Per-node encode scratch (reused every round, zipped with `wire`).
    scratch: Vec<EncodeScratch>,
    /// Stable id of each dense row — the stochastic-rounding stream
    /// identity. Identity `0..n` on fixed rosters (bit-compatible with
    /// the pre-elastic engine); under churn [`CodecState::set_roster`]
    /// keeps each physical node on its own stream across resizes.
    ids: Vec<u32>,
    n: usize,
    d: usize,
}

/// Reserved exchange-slot id for joiner warm-start reconstruction —
/// `StreamKey` packs the slot into the low 8 bits of the entity, so the
/// regular slots (0, 1, …) never collide with it.
const WARM_START_SLOT: usize = 0xff;

impl CodecState {
    pub fn new(spec: &CodecSpec, n: usize, d: usize) -> CodecState {
        CodecState {
            codec: spec.build(),
            seed: spec.seed,
            step: 0,
            slot: 0,
            residuals: Vec::new(),
            wire: (0..n).map(|_| vec![0.0; d]).collect(),
            scratch: vec![EncodeScratch::default(); n],
            ids: (0..n as u32).collect(),
            n,
            d,
        }
    }

    pub fn name(&self) -> &'static str {
        self.codec.name()
    }

    pub fn is_identity(&self) -> bool {
        self.codec.is_identity()
    }

    /// Bytes one encoded payload of this run's dimension occupies.
    pub fn payload_bytes(&self) -> f64 {
        self.codec.wire_bytes(self.d)
    }

    /// Start step `step`: exchange slots restart at 0.
    pub fn begin_step(&mut self, step: usize) {
        self.step = step;
        self.slot = 0;
    }

    /// Encode one round's publish buffers into the wire view and return
    /// it. Fans out per node over `exec`; every node draws from its own
    /// (seed, step, node, slot) stream, so parallel == serial bitwise.
    pub fn encode_round(&mut self, src: &[Vec<f32>], exec: &NodeExecutor) -> &[Vec<f32>] {
        assert_eq!(src.len(), self.n, "publish rows != node count");
        let slot = self.slot;
        self.slot += 1;
        while self.residuals.len() <= slot {
            let (n, d) = (self.n, self.d);
            self.residuals.push((0..n).map(|_| vec![0.0; d]).collect());
        }
        let (codec, seed, step) = (&self.codec, self.seed, self.step);
        let ids = &self.ids;
        let residuals = &mut self.residuals[slot];
        exec.for_each_triple_mut(
            &mut self.wire,
            residuals,
            &mut self.scratch,
            |node, wire, residual, scratch| {
                assert_eq!(src[node].len(), wire.len(), "payload dim mismatch");
                let key = StreamKey { seed, step, node: ids[node] as usize, slot };
                codec.encode(key, &src[node], residual, wire, scratch);
            },
        );
        &self.wire
    }

    /// Wire view of the latest exchange (what the fault engine's stale
    /// cache must hold: the compressed payload, not the raw publish).
    pub fn wire(&self) -> &[Vec<f32>] {
        &self.wire
    }

    /// ‖residual‖₂ of one node's EF buffer (diagnostics/tests); 0 when
    /// the slot never ran or the codec keeps no residual.
    pub fn residual_norm(&self, slot: usize, node: usize) -> f64 {
        self.residuals
            .get(slot)
            .map(|r| crate::util::math::norm2(&r[node]))
            .unwrap_or(0.0)
    }

    /// Remap the per-node state to a new roster of stable ids (elastic
    /// membership, DESIGN.md §9): surviving nodes carry their EF
    /// residuals over, joiners start from zero residuals, and the
    /// stochastic-rounding streams stay keyed to the stable id so the
    /// quantization schedule follows physical nodes across resizes.
    pub fn set_roster(&mut self, ids: &[u32]) {
        let old_ids = std::mem::take(&mut self.ids);
        let n = ids.len();
        let d = self.d;
        for slot in self.residuals.iter_mut() {
            let mut old: Vec<Option<Vec<f32>>> =
                std::mem::take(slot).into_iter().map(Some).collect();
            *slot = ids
                .iter()
                .map(|id| match old_ids.iter().position(|o| o == id) {
                    Some(p) => old[p].take().unwrap_or_else(|| vec![0.0; d]),
                    None => vec![0.0; d],
                })
                .collect();
        }
        self.wire = (0..n).map(|_| vec![0.0; d]).collect();
        self.scratch = vec![EncodeScratch::default(); n];
        self.n = n;
        self.ids = ids.to_vec();
    }

    /// Point the per-node state at a new roster WITHOUT carrying
    /// residuals over — the resume path, where the snapshot supplies
    /// them wholesale right after ([`CodecState::restore_residuals`]);
    /// a [`CodecState::set_roster`] remap here would be thrown away.
    pub fn reset_roster(&mut self, ids: &[u32]) {
        let n = ids.len();
        let d = self.d;
        self.residuals.clear();
        self.wire = (0..n).map(|_| vec![0.0; d]).collect();
        self.scratch = vec![EncodeScratch::default(); n];
        self.n = n;
        self.ids = ids.to_vec();
    }

    /// EF residuals per (slot, dense node) — the codec's only
    /// cross-round state; what a checkpoint captures (DESIGN.md §9).
    pub fn export_residuals(&self) -> Vec<Vec<Vec<f32>>> {
        self.residuals.clone()
    }

    /// Restore residuals captured by [`CodecState::export_residuals`].
    pub fn restore_residuals(&mut self, residuals: Vec<Vec<Vec<f32>>>) -> Result<()> {
        for (s, slot) in residuals.iter().enumerate() {
            anyhow::ensure!(
                slot.len() == self.n,
                "snapshot residual slot {s} has {} rows, run has {} nodes",
                slot.len(),
                self.n
            );
            for (node, row) in slot.iter().enumerate() {
                anyhow::ensure!(
                    row.len() == self.d,
                    "snapshot residual [{s}][{node}] has dim {}, run has {}",
                    row.len(),
                    self.d
                );
            }
        }
        self.residuals = residuals;
        Ok(())
    }

    /// Receiver-side reconstruction of one payload OUTSIDE the round
    /// flow: joiner warm-start reads each neighbor's params through the
    /// wire codec (what would actually cross the wire) using a
    /// throwaway residual on the reserved warm-start slot, so live EF
    /// state is untouched while the draw stays seeded per
    /// (step, stable id).
    pub fn reconstruct(&self, step: usize, node_id: u32, src: &[f32], out: &mut [f32]) {
        if self.codec.is_identity() {
            out.copy_from_slice(src);
            return;
        }
        let mut residual = vec![0.0f32; src.len()];
        let mut scratch = EncodeScratch::default();
        let key = StreamKey {
            seed: self.seed,
            step,
            node: node_id as usize,
            slot: WARM_START_SLOT,
        };
        self.codec.encode(key, src, &mut residual, out, &mut scratch);
    }
}

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even (overflow → ±inf,
/// underflow → subnormals → ±0).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (NaN payloads collapse to one quiet NaN).
        let nan = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan;
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7c00;
    }
    if e <= 0 {
        if e < -10 {
            return sign;
        }
        // Subnormal: shift the implicit-1 mantissa into 10 bits.
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = m >> shift;
        let round = 1u32 << (shift - 1);
        let rem = m & ((1u32 << shift) - 1);
        let up = rem > round || (rem == round && (half & 1) == 1);
        return sign | (half as u16 + up as u16);
    }
    let half = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1fff;
    let up = rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1);
    // A mantissa carry ripples into the exponent correctly (1.11… → 10.0,
    // and max-normal + carry → inf).
    sign | (half + up as u32) as u16
}

/// IEEE 754 binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, _) => {
            // Subnormal: renormalize into an f32 normal.
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x03ff) << 13)
        }
        (31, 0) => sign | 0x7f80_0000,
        (31, _) => sign | 0x7fc0_0000,
        _ => sign | ((exp + 112) << 23) | (mant << 13),
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(step: usize, node: usize) -> StreamKey {
        StreamKey { seed: 7, step, node, slot: 0 }
    }

    #[test]
    fn spec_parses_kinds_keys_and_defaults() {
        let s = CodecSpec::parse("int8,ef=true,seed=5", 1).unwrap();
        assert_eq!(s.kind, CodecKind::Int8);
        assert!(s.ef);
        assert_eq!(s.seed, 5);
        let s = CodecSpec::parse("int8", 9).unwrap();
        assert!(s.ef, "int8 defaults to error feedback on");
        assert_eq!(s.seed, 9, "seed defaults to the run seed");
        let s = CodecSpec::parse("fp16", 0).unwrap();
        assert!(!s.ef, "fp16 defaults to error feedback off");
        let s = CodecSpec::parse("topk,k=0.1,ef=false", 0).unwrap();
        assert_eq!(s.k, 0.1);
        assert!(!s.ef);
        assert!(CodecSpec::parse("", 0).is_err());
        assert!(CodecSpec::parse("zfp", 0).is_err());
        assert!(CodecSpec::parse("topk,k=0", 0).is_err());
        assert!(CodecSpec::parse("topk,k=1.5", 0).is_err());
        assert!(CodecSpec::parse("int8,warp=1", 0).is_err());
        assert!(CodecSpec::parse("int8,ef", 0).is_err());
        // Keys the chosen codec would ignore are rejected, not dropped.
        assert!(CodecSpec::parse("int8,k=0.01", 0).is_err());
        assert!(CodecSpec::parse("fp32,ef=true", 0).is_err());
        assert!(CodecSpec::parse("fp16,seed=7", 0).is_err());
        assert!(CodecSpec::parse("topk,seed=7", 0).is_err());
    }

    #[test]
    fn exact_error_strings_are_pinned() {
        let e = CodecSpec::parse("zfp", 0).unwrap_err().to_string();
        assert_eq!(e, "unknown codec `zfp` (fp32|fp16|int8|topk)");
        let e = CodecSpec::parse("", 0).unwrap_err().to_string();
        assert_eq!(e, "empty codec spec");
        let e = CodecSpec::parse("int8,k=0.01", 0).unwrap_err().to_string();
        assert_eq!(e, "`k` only applies to the topk codec");
        let e = CodecSpec::parse("int8,ef", 0).unwrap_err().to_string();
        assert_eq!(e, "codec spec entry `ef` is not key=value");
        let e = CodecSpec::parse("topk,k=1.5", 0).unwrap_err().to_string();
        assert_eq!(e, "topk fraction `k=1.5` outside (0, 1]");
    }

    #[test]
    fn spec_string_round_trips() {
        for s in ["fp32", "none", "fp16", "fp16,ef=true", "int8", "int8,ef=false,seed=5", "topk,k=0.1,ef=false"] {
            let a = CodecSpec::parse(s, 0).unwrap();
            let b = CodecSpec::parse(&a.to_spec_string(), 0).unwrap();
            assert_eq!(a, b, "round trip of `{s}` via `{}`", a.to_spec_string());
        }
    }

    #[test]
    fn run_seed_resolution_respects_explicit_seed() {
        let inherit = CodecSpec::parse("int8", 0).unwrap().with_run_seed(42);
        assert_eq!(inherit.seed, 42);
        let explicit = CodecSpec::parse("int8,seed=7", 0).unwrap().with_run_seed(42);
        assert_eq!(explicit.seed, 7);
    }

    #[test]
    fn wire_bytes_per_codec() {
        let d = 1000;
        assert_eq!(Fp32.wire_bytes(d), 4000.0);
        assert_eq!(Fp16 { ef: false }.wire_bytes(d), 2000.0);
        assert_eq!(Int8Stochastic { ef: true }.wire_bytes(d), 1004.0);
        assert_eq!(TopK { frac: 0.05, ef: true }.wire_bytes(d), 8.0 * 50.0);
        // int8 cuts >= 3.9x as soon as d >= 160 (the acceptance bound).
        let ratio = Fp32.wire_bytes(4810) / Int8Stochastic { ef: true }.wire_bytes(4810);
        assert!(ratio >= 3.9, "int8 ratio {ratio}");
    }

    #[test]
    fn f16_roundtrip_exact_on_representable_values() {
        for &v in &[0.0f32, -0.0, 0.5, 1.0, -1.5, 2.0, 65504.0, -65504.0, 6.103_515_6e-5] {
            let rt = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(rt.to_bits(), v.to_bits(), "{v} -> {rt}");
        }
        // Smallest f16 subnormal survives the round trip.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tiny)), tiny);
    }

    #[test]
    fn f16_rounding_and_overflow() {
        // Relative error of a normal-range value is <= 2^-11.
        let mut rng = Pcg64::seeded(3);
        for _ in 0..2000 {
            let v = (rng.f32() - 0.5) * 100.0;
            let rt = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!(
                (rt - v).abs() <= v.abs() * (1.0 / 2048.0) + 1e-7,
                "{v} -> {rt}"
            );
        }
        // Ties round to even: 65520 sits exactly between 65504 and the
        // (overflowing) next step, whose mantissa is even -> inf.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(65520.0)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e30)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e30)), f32::NEG_INFINITY);
        // Below half the smallest subnormal -> zero.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-9)), 0.0);
    }

    #[test]
    fn int8_is_deterministic_and_element_bounded() {
        let c = Int8Stochastic { ef: true };
        let mut sc = EncodeScratch::default();
        let mut rng = Pcg64::seeded(11);
        let d = 257;
        let mut src = vec![0.0f32; d];
        rng.normal_fill(&mut src, 1.0);
        let maxabs = src.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = maxabs / 127.0;
        let (mut r1, mut w1) = (vec![0.0; d], vec![0.0; d]);
        let (mut r2, mut w2) = (vec![0.0; d], vec![0.0; d]);
        c.encode(key(3, 1), &src, &mut r1, &mut w1, &mut sc);
        c.encode(key(3, 1), &src, &mut r2, &mut w2, &mut sc);
        assert_eq!(w1, w2, "same stream key must replay bit-identically");
        assert_eq!(r1, r2);
        for k in 0..d {
            assert!((w1[k] - src[k]).abs() <= scale + 1e-7, "element {k}");
            assert!((r1[k] - (src[k] - w1[k])).abs() < 1e-7, "EF residual {k}");
        }
        // Different nodes / steps use different streams.
        let (mut r3, mut w3) = (vec![0.0; d], vec![0.0; d]);
        c.encode(key(3, 2), &src, &mut r3, &mut w3, &mut sc);
        assert_ne!(w1, w3, "node streams must differ");
        let (mut r4, mut w4) = (vec![0.0; d], vec![0.0; d]);
        c.encode(key(4, 1), &src, &mut r4, &mut w4, &mut sc);
        assert_ne!(w1, w4, "step streams must differ");
    }

    #[test]
    fn int8_zero_payload_stays_zero() {
        let c = Int8Stochastic { ef: true };
        let mut sc = EncodeScratch::default();
        let (mut r, mut w) = (vec![0.0f32; 8], vec![1.0f32; 8]);
        c.encode(key(0, 0), &[0.0; 8], &mut r, &mut w, &mut sc);
        assert!(w.iter().all(|&v| v == 0.0));
        assert!(r.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn int8_stochastic_rounding_is_unbiased_on_average() {
        // A constant 0.3-quantum value must round up ~30% of the time
        // across independent node streams.
        let c = Int8Stochastic { ef: false };
        let mut sc = EncodeScratch::default();
        let d = 4;
        let src = vec![0.3f32, 127.0, -0.3, -127.0]; // maxabs 127 -> scale 1
        let mut sum = 0.0f64;
        let trials = 4000;
        for node in 0..trials {
            let (mut r, mut w) = (vec![0.0; d], vec![0.0; d]);
            c.encode(key(0, node), &src, &mut r, &mut w, &mut sc);
            assert!(w[0] == 0.0 || w[0] == 1.0, "q of 0.3 must be 0 or 1, got {}", w[0]);
            sum += w[0] as f64;
        }
        let mean = sum / trials as f64;
        assert!((mean - 0.3).abs() < 0.03, "E[q] = {mean}, want 0.3");
    }

    #[test]
    fn topk_keeps_largest_and_residual_carries_rest() {
        let c = TopK { frac: 0.4, ef: true };
        let mut sc = EncodeScratch::default();
        let src = vec![0.1f32, -3.0, 0.2, 2.0, -0.05];
        let (mut r, mut w) = (vec![0.0; 5], vec![0.0; 5]);
        c.encode(key(0, 0), &src, &mut r, &mut w, &mut sc);
        // ceil(0.4 * 5) = 2 kept: indices 1 and 3.
        assert_eq!(w, vec![0.0, -3.0, 0.0, 2.0, 0.0]);
        assert_eq!(r, vec![0.1, 0.0, 0.2, 0.0, -0.05]);
        // Next round the residual joins the payload: 0.2 + 0.2 = 0.4
        // outranks... still below |2.0| refill; just pin determinism.
        let (mut r2, mut w2) = (r.clone(), vec![0.0; 5]);
        c.encode(key(1, 0), &src, &mut r2, &mut w2, &mut sc);
        let (mut r3, mut w3) = (r, vec![0.0; 5]);
        c.encode(key(1, 0), &src, &mut r3, &mut w3, &mut sc);
        assert_eq!(w2, w3);
        assert_eq!(r2, r3);
    }

    #[test]
    fn topk_tie_breaks_by_lower_index() {
        let c = TopK { frac: 0.25, ef: false };
        let mut sc = EncodeScratch::default();
        let src = vec![1.0f32, -1.0, 1.0, 1.0];
        let (mut r, mut w) = (vec![0.0; 4], vec![0.0; 4]);
        c.encode(key(0, 0), &src, &mut r, &mut w, &mut sc);
        assert_eq!(w, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn ef_residual_stays_bounded_over_rounds() {
        // Error feedback must not accumulate: with inputs bounded by 1,
        // the int8 steady-state residual is ~maxabs/127 per element.
        let spec = CodecSpec::parse("int8,ef=true,seed=3", 0).unwrap();
        let mut state = CodecState::new(&spec, 2, 64);
        let mut rng = Pcg64::seeded(21);
        let mut src = vec![vec![0.0f32; 64]; 2];
        for step in 0..100 {
            for row in src.iter_mut() {
                for v in row.iter_mut() {
                    *v = rng.f32() * 2.0 - 1.0;
                }
            }
            state.begin_step(step);
            state.encode_round(&src, &NodeExecutor::serial());
            for node in 0..2 {
                let norm = state.residual_norm(0, node);
                assert!(norm <= 64f64.sqrt() * 0.02, "step {step}: residual norm {norm}");
            }
        }
    }

    #[test]
    fn codec_state_parallel_encode_matches_serial() {
        let spec = CodecSpec::parse("int8,ef=true,seed=9", 0).unwrap();
        let n = 13;
        let d = 97;
        let mut rng = Pcg64::seeded(5);
        let mut src = vec![vec![0.0f32; d]; n];
        for row in src.iter_mut() {
            rng.normal_fill(row, 1.0);
        }
        let mut a = CodecState::new(&spec, n, d);
        let mut b = CodecState::new(&spec, n, d);
        for step in 0..3 {
            a.begin_step(step);
            b.begin_step(step);
            let wa = a.encode_round(&src, &NodeExecutor::serial()).to_vec();
            let wb = b.encode_round(&src, &NodeExecutor::new(4)).to_vec();
            assert_eq!(wa, wb, "step {step}: parallel encode diverged");
        }
    }

    #[test]
    fn codec_state_slots_keep_independent_residuals() {
        let spec = CodecSpec::parse("topk,k=0.25", 1).unwrap();
        let mut state = CodecState::new(&spec, 1, 4);
        state.begin_step(0);
        state.encode_round(&[vec![1.0, 0.1, 0.0, 0.0]], &NodeExecutor::serial());
        let slot0 = state.residual_norm(0, 0);
        state.encode_round(&[vec![0.0, 0.0, 1.0, 0.3]], &NodeExecutor::serial());
        let slot1 = state.residual_norm(1, 0);
        assert!((slot0 - 0.1).abs() < 1e-7, "slot 0 residual {slot0}");
        assert!((slot1 - 0.3).abs() < 1e-7, "slot 1 residual {slot1}");
        // Slot 0's residual untouched by slot 1's exchange.
        assert!((state.residual_norm(0, 0) - 0.1).abs() < 1e-7);
    }

    #[test]
    fn set_roster_carries_residuals_by_stable_id() {
        let spec = CodecSpec::parse("topk,k=0.25", 1).unwrap();
        let mut state = CodecState::new(&spec, 3, 4);
        state.begin_step(0);
        // Nodes 0..3 encode; node 1's residual ends up nonzero.
        state.encode_round(
            &[vec![1.0, 0.0, 0.0, 0.0], vec![1.0, 0.5, 0.0, 0.0], vec![1.0, 0.25, 0.0, 0.0]],
            &NodeExecutor::serial(),
        );
        let r1 = state.residual_norm(0, 1);
        assert!((r1 - 0.5).abs() < 1e-7);
        // New roster drops node 0, keeps 1 and 2, adds 5: node 1 is now
        // dense row 0 and keeps its residual; the joiner starts clean.
        state.set_roster(&[1, 2, 5]);
        assert!((state.residual_norm(0, 0) - 0.5).abs() < 1e-7, "node 1 residual moved");
        assert!((state.residual_norm(0, 1) - 0.25).abs() < 1e-7, "node 2 residual moved");
        assert_eq!(state.residual_norm(0, 2), 0.0, "joiner starts with zero residual");
    }

    #[test]
    fn reconstruct_is_deterministic_and_leaves_residuals_alone() {
        let spec = CodecSpec::parse("int8,ef=true,seed=9", 1).unwrap();
        let mut state = CodecState::new(&spec, 2, 16);
        let mut rng = Pcg64::seeded(4);
        let mut src = vec![0.0f32; 16];
        rng.normal_fill(&mut src, 1.0);
        state.begin_step(2);
        state.encode_round(&[src.clone(), src.clone()], &NodeExecutor::serial());
        let before = state.residual_norm(0, 0);
        let (mut a, mut b) = (vec![0.0f32; 16], vec![0.0f32; 16]);
        state.reconstruct(3, 7, &src, &mut a);
        state.reconstruct(3, 7, &src, &mut b);
        assert_eq!(a, b, "same (step, id) must reconstruct identically");
        let mut c = vec![0.0f32; 16];
        state.reconstruct(3, 8, &src, &mut c);
        assert_ne!(a, c, "different stable ids draw different streams");
        assert_eq!(state.residual_norm(0, 0), before, "live EF residual touched");
        // Identity codec: exact passthrough.
        let fp32 = CodecState::new(&CodecSpec::parse("fp32", 0).unwrap(), 2, 16);
        let mut d = vec![0.0f32; 16];
        fp32.reconstruct(0, 0, &src, &mut d);
        assert_eq!(d, src);
    }

    #[test]
    fn fp32_is_identity() {
        let spec = CodecSpec::parse("fp32", 0).unwrap();
        let state = CodecState::new(&spec, 2, 3);
        assert!(state.is_identity());
        assert_eq!(state.payload_bytes(), 12.0);
        let mut sc = EncodeScratch::default();
        let (mut r, mut w) = (vec![0.0f32; 3], vec![0.0f32; 3]);
        Fp32.encode(key(0, 0), &[1.0, -2.0, 3.5], &mut r, &mut w, &mut sc);
        assert_eq!(w, vec![1.0, -2.0, 3.5]);
    }
}
