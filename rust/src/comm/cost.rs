//! α–β communication cost model for the Fig. 6 runtime comparison.
//!
//! Collectives are modeled with the standard latency–bandwidth (α–β)
//! framework over a flat inter-node network of per-link bandwidth `B`
//! and per-message latency `α`:
//!
//! * **Ring all-reduce** (PmSGD / NCCL-over-TCP):
//!   time = 2(n−1)·α + 2·(n−1)/n · M/(B·EFF_ALLREDUCE). The efficiency
//!   factor models chunked, ack-gated TCP collectives, which achieve a
//!   fraction of line rate across 2(n−1) serialized stages (the paper's
//!   25 Gbps TCP testbed).
//! * **Neighbor exchange** (partial averaging): one stage; sends to the
//!   deg neighbors stream concurrently over the full-duplex NIC, so the
//!   marginal cost of an extra neighbor is far below a full payload:
//!   time = α + (1 + NEIGHBOR_SERIAL·(deg−1)) · M/B. This is O(1) in n
//!   for constant-degree graphs — the paper's §3 claim — and the serial
//!   fraction is calibrated so the modeled end-to-end speedup lands in
//!   the paper's measured 1.2–1.9× band (Fig. 6); BlueFog does not
//!   publish the per-flow serialization of its neighbor_allreduce.
//!
//! Everything is charged from a [`CommStats`] summary — node count,
//! **actual undirected edge count**, max degree — taken from the
//! realized topology or comm engine, never from an n×n matrix walk; the
//! wire-byte accounting ([`wire_bytes_per_iter`]) is exact in the edge
//! count, so a ring at n=512 charges 2·512 payloads per exchange, not
//! 512².
//!
//! With computation–communication overlap (WFBP, paper Fig. 4), the
//! per-iteration wall time is compute + the communication tail that the
//! backprop pipeline cannot hide, modeled with an `overlap` fraction.

use crate::comm::engine::CommEngine;
use crate::optim::CommPattern;
use crate::topology::Topology;

/// Physical link parameters.
#[derive(Debug, Clone, Copy)]
pub struct LinkSpec {
    /// Per-node NIC bandwidth in Gbit/s (the paper uses 10 and 25).
    pub bandwidth_gbps: f64,
    /// Per-message latency in microseconds.
    pub latency_us: f64,
}

impl LinkSpec {
    pub fn tcp_25gbps() -> LinkSpec {
        LinkSpec { bandwidth_gbps: 25.0, latency_us: 25.0 }
    }

    pub fn tcp_10gbps() -> LinkSpec {
        LinkSpec { bandwidth_gbps: 10.0, latency_us: 25.0 }
    }

    /// Seconds to push `bytes` through the NIC once.
    pub fn transfer_s(&self, bytes: f64) -> f64 {
        bytes * 8.0 / (self.bandwidth_gbps * 1e9)
    }

    pub fn latency_s(&self) -> f64 {
        self.latency_us * 1e-6
    }
}

/// Achieved fraction of line rate for chunked TCP all-reduce.
pub const EFF_ALLREDUCE: f64 = 0.55;
/// Marginal NIC serialization per extra concurrent neighbor stream.
pub const NEIGHBOR_SERIAL: f64 = 0.10;

/// Graph summary the cost model charges from: node count, realized
/// undirected edge count, and the bottleneck degree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommStats {
    pub n: usize,
    pub edges: usize,
    pub max_degree: usize,
}

impl CommStats {
    /// Stats of a realized topology (adjacency-list walk, O(n)).
    pub fn of_topology(topo: &Topology) -> CommStats {
        CommStats { n: topo.n, edges: topo.num_edges(), max_degree: topo.max_degree() }
    }

    /// Stats of a comm engine's neighbor lists.
    pub fn of_engine(engine: &dyn CommEngine) -> CommStats {
        CommStats {
            n: engine.n(),
            edges: engine.num_edges(),
            max_degree: engine.max_degree(),
        }
    }
}

/// Per-payload byte widths of one iteration's wire traffic. The gossip
/// payload is whatever the configured [`crate::comm::codec`] puts on
/// the wire (possibly compressed); periodic all-reduce legs (SlowMo
/// sync, PmSGD) model a collective fabric outside the codec path and
/// always ship raw fp32. Replaces the old single `bytes` argument so
/// nothing in the cost model silently assumes 4·d.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PayloadBytes {
    /// Bytes of ONE encoded neighbor-gossip payload.
    pub neighbor: f64,
    /// Bytes of one all-reduce payload-equivalent (uncompressed).
    pub allreduce: f64,
}

impl PayloadBytes {
    /// Same width on gossip and all-reduce legs (no codec in play).
    pub fn uniform(bytes: f64) -> PayloadBytes {
        PayloadBytes { neighbor: bytes, allreduce: bytes }
    }

    /// Raw fp32 payload of a d-element parameter vector.
    pub fn fp32(d: usize) -> PayloadBytes {
        PayloadBytes::uniform(4.0 * d as f64)
    }

    /// Codec-compressed gossip payload; all-reduce legs stay raw fp32.
    pub fn compressed(neighbor_bytes: f64, d: usize) -> PayloadBytes {
        PayloadBytes { neighbor: neighbor_bytes, allreduce: 4.0 * d as f64 }
    }
}

/// Seconds for one neighbor exchange of `bytes` payload by a node of
/// the given degree — the per-node form of the α–β neighbor-exchange
/// model ([`CommCost::neighbor_exchange_s`] applies it at the
/// bottleneck degree; the discrete-event clock sim in `sim::clock`
/// charges each node its own degree). Single source of truth for the
/// formula. An isolated node (degree 0 — possible after heavy fault
/// masking or churn down to a cut vertex) exchanges nothing and costs
/// nothing: no latency, no transfer.
pub fn neighbor_exchange_deg_s(link: &LinkSpec, degree: usize, bytes: f64) -> f64 {
    if degree == 0 {
        return 0.0;
    }
    let deg = degree as f64;
    link.latency_s() + (1.0 + NEIGHBOR_SERIAL * (deg - 1.0)) * link.transfer_s(bytes)
}

/// Total bytes put on the wire in one iteration of `pattern` at the
/// given per-payload widths — exact in the edge count (each undirected
/// edge carries the encoded payload once per direction).
pub fn wire_bytes_per_iter(pattern: CommPattern, stats: &CommStats, payload: PayloadBytes) -> f64 {
    let neighbor = 2.0 * stats.edges as f64 * payload.neighbor;
    let allreduce =
        if stats.n <= 1 { 0.0 } else { 2.0 * (stats.n as f64 - 1.0) * payload.allreduce };
    match pattern {
        CommPattern::Neighbor { payloads } => payloads as f64 * neighbor,
        CommPattern::AllReduce => allreduce,
        CommPattern::NeighborPlusPeriodicAllReduce { payloads, period } => {
            payloads as f64 * neighbor + allreduce / period.max(1) as f64
        }
    }
}

/// Cost model over a graph summary + link spec.
#[derive(Debug, Clone)]
pub struct CommCost {
    pub link: LinkSpec,
    /// Fraction of communication hidden behind backprop (WFBP overlap).
    pub overlap: f64,
}

impl CommCost {
    pub fn new(link: LinkSpec) -> CommCost {
        CommCost { link, overlap: 0.3 }
    }

    /// Seconds for one ring all-reduce of `bytes` over `n` nodes.
    pub fn allreduce_s(&self, n: usize, bytes: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        steps as f64 * self.link.latency_s()
            + 2.0 * (n as f64 - 1.0) / n as f64 * self.link.transfer_s(bytes) / EFF_ALLREDUCE
    }

    /// Seconds for one neighbor exchange of `bytes` payload on a graph
    /// with the given stats (single stage; concurrent full-duplex
    /// streams to the neighbors, bottlenecked by the max-degree node).
    pub fn neighbor_exchange_s(&self, stats: &CommStats, bytes: f64) -> f64 {
        neighbor_exchange_deg_s(&self.link, stats.max_degree, bytes)
    }

    /// Average per-iteration communication seconds for an optimizer's
    /// declared pattern at the given per-payload widths (gossip legs
    /// move the possibly-compressed payload, all-reduce legs raw fp32).
    pub fn per_iter_comm_s(
        &self,
        pattern: CommPattern,
        stats: &CommStats,
        payload: PayloadBytes,
    ) -> f64 {
        match pattern {
            CommPattern::Neighbor { payloads } => {
                payloads as f64 * self.neighbor_exchange_s(stats, payload.neighbor)
            }
            CommPattern::AllReduce => self.allreduce_s(stats.n, payload.allreduce),
            CommPattern::NeighborPlusPeriodicAllReduce { payloads, period } => {
                payloads as f64 * self.neighbor_exchange_s(stats, payload.neighbor)
                    + self.allreduce_s(stats.n, payload.allreduce) / period.max(1) as f64
            }
        }
    }

    /// Wall-clock per iteration with WFBP overlap: compute plus the
    /// communication that cannot hide behind it.
    pub fn per_iter_wall_s(&self, compute_s: f64, comm_s: f64) -> f64 {
        let hidden = (comm_s * self.overlap).min(compute_s);
        compute_s + (comm_s - hidden)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Kind, SparseWeights};

    fn stats(kind: Kind) -> CommStats {
        CommStats::of_topology(&Topology::build(kind, 8))
    }

    #[test]
    fn allreduce_scales_with_message_size() {
        let c = CommCost::new(LinkSpec::tcp_25gbps());
        let small = c.allreduce_s(8, 1e6);
        let big = c.allreduce_s(8, 1e8);
        assert!(big > 50.0 * small);
    }

    #[test]
    fn partial_averaging_beats_allreduce_on_sparse_graphs() {
        // The paper's Fig. 6 claim: neighbor exchange on ring/exp graphs
        // is cheaper than global all-reduce at equal payload.
        let bytes = 25.5e6 * 4.0; // ResNet-50 fp32
        for link in [LinkSpec::tcp_10gbps(), LinkSpec::tcp_25gbps()] {
            let c = CommCost::new(link);
            let ar = c.allreduce_s(8, bytes);
            for kind in [Kind::Ring, Kind::SymExp] {
                let ne = c.neighbor_exchange_s(&stats(kind), bytes);
                assert!(ne < ar, "{kind:?}: {ne} !< {ar}");
            }
        }
    }

    #[test]
    fn lower_bandwidth_widens_the_gap() {
        let bytes = 25.5e6 * 4.0;
        let gap = |l: LinkSpec| {
            let c = CommCost::new(l);
            c.allreduce_s(8, bytes) / c.neighbor_exchange_s(&stats(Kind::Ring), bytes)
        };
        assert!(gap(LinkSpec::tcp_10gbps()) >= gap(LinkSpec::tcp_25gbps()) * 0.99);
    }

    #[test]
    fn comm_pattern_costs_ordered() {
        let c = CommCost::new(LinkSpec::tcp_25gbps());
        let s = stats(Kind::Ring);
        let bytes = PayloadBytes::uniform(1e8);
        let one = c.per_iter_comm_s(CommPattern::Neighbor { payloads: 1 }, &s, bytes);
        let two = c.per_iter_comm_s(CommPattern::Neighbor { payloads: 2 }, &s, bytes);
        let ar = c.per_iter_comm_s(CommPattern::AllReduce, &s, bytes);
        assert!((two / one - 2.0).abs() < 1e-9);
        assert!(ar > one);
        let slowmo = c.per_iter_comm_s(
            CommPattern::NeighborPlusPeriodicAllReduce { payloads: 1, period: 12 },
            &s,
            bytes,
        );
        assert!(slowmo > one && slowmo < one + ar);
    }

    #[test]
    fn overlap_hides_comm_behind_compute() {
        let c = CommCost::new(LinkSpec::tcp_25gbps());
        // hideable fraction = overlap * comm (compute is long enough)
        let w = c.per_iter_wall_s(1.0, 0.5);
        assert!((w - (1.0 + 0.5 * (1.0 - c.overlap))).abs() < 1e-9);
        // comm dominates: at most `compute` can hide
        let w2 = c.per_iter_wall_s(0.1, 1.0);
        assert!(w2 >= 1.0 - 1e-9 && w2 <= 1.1 + 1e-9);
    }

    #[test]
    fn stats_agree_between_topology_and_engine() {
        for kind in [Kind::Ring, Kind::Mesh, Kind::Star, Kind::SymExp] {
            let topo = Topology::build(kind, 12);
            let sw = SparseWeights::metropolis_hastings(&topo);
            assert_eq!(CommStats::of_topology(&topo), CommStats::of_engine(&sw), "{kind:?}");
        }
    }

    #[test]
    fn wire_bytes_charged_from_edge_counts() {
        let bytes = 1e6;
        let payload = PayloadBytes::uniform(bytes);
        // Ring n=512: exactly 2 * 512 payloads per exchange — linear in
        // n, nowhere near the n² a dense-matrix walk would charge.
        let ring = CommStats::of_topology(&Topology::build(Kind::Ring, 512));
        let nb = wire_bytes_per_iter(CommPattern::Neighbor { payloads: 1 }, &ring, payload);
        assert!((nb - 2.0 * 512.0 * bytes).abs() < 1e-3);
        assert!(nb < 512.0 * 511.0 * bytes / 4.0);
        // All-reduce moves 2(n-1) payload-equivalents in total.
        let ar = wire_bytes_per_iter(CommPattern::AllReduce, &ring, payload);
        assert!((ar - 2.0 * 511.0 * bytes).abs() < 1e-3);
        // SlowMo amortizes the all-reduce over its period.
        let sm = wire_bytes_per_iter(
            CommPattern::NeighborPlusPeriodicAllReduce { payloads: 1, period: 8 },
            &ring,
            payload,
        );
        assert!((sm - (nb + ar / 8.0)).abs() < 1e-3);
    }

    #[test]
    fn isolated_node_exchange_costs_zero() {
        // Degree 0 = nothing on the wire: no latency, no transfer. The
        // old `degree.max(1)` clamp charged an isolated node a full
        // latency + payload transfer.
        for link in [LinkSpec::tcp_10gbps(), LinkSpec::tcp_25gbps()] {
            assert_eq!(neighbor_exchange_deg_s(&link, 0, 1e8), 0.0);
            // Degree >= 1 is untouched by the fix.
            let one = neighbor_exchange_deg_s(&link, 1, 1e6);
            assert!((one - (link.latency_s() + link.transfer_s(1e6))).abs() < 1e-15);
        }
        let c = CommCost::new(LinkSpec::tcp_25gbps());
        let isolated = CommStats { n: 4, edges: 0, max_degree: 0 };
        assert_eq!(c.neighbor_exchange_s(&isolated, 1e8), 0.0);
        assert_eq!(
            c.per_iter_comm_s(
                CommPattern::Neighbor { payloads: 2 },
                &isolated,
                PayloadBytes::uniform(1e8)
            ),
            0.0
        );
    }

    #[test]
    fn drop_plan_isolating_every_node_realizes_zero_cost() {
        // Regression for the degree.max(1) clamp: a drop-plan that
        // isolates nodes must realize a degree-0 graph whose neighbor
        // exchange costs 0 — both in CommCost and (via the same
        // neighbor_exchange_deg_s) in the sim::clock event sim.
        use crate::sim::{FaultPlan, FaultSpec, FaultyEngine};
        let topo = Topology::build(Kind::Ring, 4);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = FaultyEngine::new(FaultPlan::new(FaultSpec::parse("drop=1", 7).unwrap()));
        f.begin_step(0, &nominal);
        let s = CommStats::of_engine(&f);
        assert_eq!(s, CommStats { n: 4, edges: 0, max_degree: 0 });
        let c = CommCost::new(LinkSpec::tcp_25gbps());
        assert_eq!(c.neighbor_exchange_s(&s, 1e6), 0.0);
        assert_eq!(
            wire_bytes_per_iter(
                CommPattern::Neighbor { payloads: 1 },
                &s,
                PayloadBytes::uniform(1e6)
            ),
            0.0
        );
    }

    #[test]
    fn compressed_gossip_leaves_allreduce_legs_raw() {
        // A codec shrinks only the neighbor payload: SlowMo's periodic
        // all-reduce keeps shipping raw fp32.
        let d = 1000usize;
        let ring = CommStats::of_topology(&Topology::build(Kind::Ring, 8));
        let raw = PayloadBytes::fp32(d);
        let int8 = PayloadBytes::compressed(d as f64 + 4.0, d);
        assert_eq!(raw.neighbor, 4000.0);
        assert_eq!(int8.allreduce, 4000.0);
        let nb = |p| wire_bytes_per_iter(CommPattern::Neighbor { payloads: 1 }, &ring, p);
        let ratio = nb(raw) / nb(int8);
        assert!(ratio >= 3.9, "int8 neighbor ratio {ratio}");
        let ar = |p| wire_bytes_per_iter(CommPattern::AllReduce, &ring, p);
        assert_eq!(ar(raw), ar(int8), "all-reduce legs must not be compressed");
        let sm = CommPattern::NeighborPlusPeriodicAllReduce { payloads: 1, period: 4 };
        let want = nb(int8) + ar(raw) / 4.0;
        assert!((wire_bytes_per_iter(sm, &ring, int8) - want).abs() < 1e-9);
    }
}
