//! The communication engine abstraction (DESIGN.md §3).
//!
//! Every decentralized optimizer expresses its wire traffic through one
//! primitive — "mix my published vector with my neighbors' under the
//! row weights of W" — so the *storage* of W is an implementation
//! detail behind this trait. Two engines ship:
//!
//! * [`crate::topology::sparse::SparseWeights`] — CSR-style per-node
//!   neighbor lists, O(edges) memory and per-step rebuild cost. The
//!   trainer's default.
//! * [`crate::topology::WeightMatrix`] — the dense n×n matrix, kept for
//!   spectral analysis (eigenvalues need the full matrix) and as the
//!   reference implementation the sparse engine is property-tested
//!   against.
//! * [`crate::sim::FaultyEngine`] — wraps the sparse engine and
//!   realizes a fault schedule on its rows (masking + renormalization
//!   + stale-message substitution); what the trainer mixes through
//!   when `--faults` is set.
//!
//! Rows always include the self entry `(i, w_ii)`, sorted by neighbor
//! index, so one weighted sum over the row is the whole exchange.

use crate::util::math;

/// Neighbor-list view of a mixing matrix row: `(j, w_ij)`, self entry
/// included. Metropolis–Hastings rows always carry a strictly positive
/// self weight (w_ii = 1 − Σ 1/(1+max deg) ≥ 1/(1+deg_i) > 0 — the
/// property suite asserts it); a `self_weight` of exactly 0.0 from the
/// default impl therefore means the entry is *missing*, not a valid
/// weight.
pub type RowEntry = (u32, f32);

/// A mixing-weight provider the optimizers communicate through.
pub trait CommEngine: Sync {
    /// Number of nodes.
    fn n(&self) -> usize;

    /// Sparse row of node `i`: `(neighbor incl. self, weight)`, sorted
    /// by neighbor index.
    fn row(&self, i: usize) -> &[RowEntry];

    /// Self-mixing weight w_ii.
    fn self_weight(&self, i: usize) -> f32 {
        self.row(i)
            .iter()
            .find(|&&(j, _)| j as usize == i)
            .map(|&(_, w)| w)
            .unwrap_or(0.0)
    }

    /// Undirected edge count (self loops excluded) — what the cost
    /// model charges payloads from.
    fn num_edges(&self) -> usize {
        let total: usize = (0..self.n()).map(|i| self.row(i).len()).sum();
        (total - self.n()) / 2
    }

    /// Max neighbor count of any node (self excluded).
    fn max_degree(&self) -> usize {
        (0..self.n()).map(|i| self.row(i).len() - 1).max().unwrap_or(0)
    }

    /// out = Σ_{j ∈ N(i) ∪ {i}} w_ij · src[j] — one node's exchange.
    /// Delegates to [`mix_row`]; engines that resolve entries against
    /// other sources (the fault engine's stale cache) override this but
    /// fall back to `mix_row` on unaffected rows, which keeps them
    /// bitwise identical to the default path there.
    fn mix_node(&self, i: usize, src: &[Vec<f32>], out: &mut [f32]) {
        mix_row(self.row(i), src, out);
    }

    /// Hook invoked by [`crate::optim::gossip_exchange`] once per
    /// exchange, immediately before the mix fan-out, with the exact
    /// source view the mix will read (the codec's wire view when a
    /// lossy codec is active, the raw publish otherwise). Engines that
    /// replay past payloads — the async bounded-staleness mode of
    /// [`crate::sim::FaultyEngine`] — snapshot it here into their
    /// per-exchange-slot ring caches; the default is a no-op, so plain
    /// engines pay nothing.
    fn begin_exchange(&self, _src: &[Vec<f32>]) {}

    /// Max |row sum − 1| over all nodes (stochasticity diagnostic).
    fn row_sum_error(&self) -> f64 {
        (0..self.n())
            .map(|i| {
                let s: f64 = self.row(i).iter().map(|&(_, w)| w as f64).sum();
                (s - 1.0).abs()
            })
            .fold(0.0, f64::max)
    }
}

/// Fixed inner-tile width of the blocked mix kernel, in f32 lanes:
/// 128 floats = 512 B — one tile of `out` spans a handful of cache
/// lines and an integer number of AVX2/AVX-512/NEON vectors, so the
/// autovectorizer gets clean fixed-trip inner loops while the `out`
/// tile stays resident across every term of the row instead of being
/// streamed through memory once per neighbor.
pub const MIX_BLOCK: usize = 128;

/// `x[t..e]` clamped to `x`'s length — reproduces `zip` truncation on
/// a tile, so the blocked kernel keeps the reference kernel's exact
/// behavior when a source vector is shorter than `out`.
#[inline]
fn tile(x: &[f32], t: usize, e: usize) -> &[f32] {
    let len = x.len();
    &x[t.min(len)..e.min(len)]
}

/// out = Σ_t w_t · src[j_t] over one sparse row — the shared kernel of
/// every engine's exchange. Allocation-free (the step loop's hot path).
///
/// The kernel is *blocked* (DESIGN.md §13): the outer loop walks `out`
/// in fixed [`MIX_BLOCK`]-float tiles, and the inner loops apply every
/// row term — the leading scale, then the remaining neighbors fused
/// pairwise as in `math::weighted_sum_into` — to that one tile before
/// moving on. Blocking changes only *which element is touched when*,
/// never the per-element arithmetic: every `out[k]` still sees exactly
/// `w0·x0[k]`, then `+= wa·a[k] + wb·b[k]` per pair left to right,
/// then `+= w·x[k]` for an odd trailing neighbor — the identical
/// left-to-right accumulation order as the pre-blocking kernel, so
/// results are bitwise stable (pinned by `blocked_mix_row_is_bitwise_
/// identical_to_reference` below).
pub fn mix_row(row: &[RowEntry], src: &[Vec<f32>], out: &mut [f32]) {
    let ((j0, w0), rest) = match row {
        [] => {
            out.iter_mut().for_each(|v| *v = 0.0);
            return;
        }
        [first, rest @ ..] => (*first, rest),
    };
    let x0 = &src[j0 as usize];
    let d = out.len();
    let mut t = 0;
    while t < d {
        let e = (t + MIX_BLOCK).min(d);
        for (o, &x) in out[t..e].iter_mut().zip(tile(x0, t, e)) {
            *o = w0 * x;
        }
        let mut pairs = rest.chunks_exact(2);
        for pair in &mut pairs {
            let (ja, wa) = pair[0];
            let (jb, wb) = pair[1];
            let xa = tile(&src[ja as usize], t, e);
            let xb = tile(&src[jb as usize], t, e);
            for ((o, &a), &b) in out[t..e].iter_mut().zip(xa).zip(xb) {
                *o += wa * a + wb * b;
            }
        }
        if let [(j, w)] = pairs.remainder() {
            math::axpy(&mut out[t..e], *w, tile(&src[*j as usize], t, e));
        }
        t = e;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{metropolis_hastings, Kind, Topology};

    #[test]
    fn engine_views_of_dense_matrix() {
        let topo = Topology::build(Kind::Ring, 6);
        let wm = metropolis_hastings(&topo);
        let e: &dyn CommEngine = &wm;
        assert_eq!(e.n(), 6);
        assert_eq!(e.num_edges(), 6);
        assert_eq!(e.max_degree(), 2);
        assert!(e.row_sum_error() < 1e-6);
        assert!((e.self_weight(0) - 1.0 / 3.0).abs() < 1e-6);
    }

    /// The pre-blocking kernel, verbatim: full-width sweeps per term,
    /// pairwise fusion, axpy remainder. The blocked kernel must match
    /// it bit for bit — blocking may only re-tile the traversal, never
    /// change any element's accumulation sequence.
    fn reference_mix_row(row: &[RowEntry], src: &[Vec<f32>], out: &mut [f32]) {
        match row {
            [] => out.iter_mut().for_each(|v| *v = 0.0),
            [(j0, w0), rest @ ..] => {
                for (o, &x) in out.iter_mut().zip(&src[*j0 as usize]) {
                    *o = w0 * x;
                }
                let mut pairs = rest.chunks_exact(2);
                for pair in &mut pairs {
                    let (ja, wa) = pair[0];
                    let (jb, wb) = pair[1];
                    let xa = &src[ja as usize];
                    let xb = &src[jb as usize];
                    for ((o, &a), &b) in out.iter_mut().zip(xa).zip(xb) {
                        *o += wa * a + wb * b;
                    }
                }
                if let [(j, w)] = pairs.remainder() {
                    math::axpy(out, *w, &src[*j as usize]);
                }
            }
        }
    }

    #[test]
    fn blocked_mix_row_is_bitwise_identical_to_reference() {
        use crate::util::rng::Pcg64;
        // Row lengths 0..=7 cover: empty, scale-only, exact pairs and
        // odd remainders; d values straddle the MIX_BLOCK boundary.
        for d in [0usize, 1, 5, 127, 128, 129, 300, 1024] {
            let mut rng = Pcg64::seeded(0x9e37 ^ d as u64);
            let mut src: Vec<Vec<f32>> = vec![vec![0.0; d]; 8];
            for v in &mut src {
                rng.normal_fill(v, 1.0);
            }
            for terms in 0..=7usize {
                let mut wbuf = vec![0.0f32; terms];
                rng.normal_fill(&mut wbuf, 0.5);
                let row: Vec<RowEntry> =
                    (0..terms).map(|t| (t as u32, wbuf[t])).collect();
                let mut blocked = vec![f32::NAN; d];
                let mut reference = vec![f32::NAN; d];
                mix_row(&row, &src, &mut blocked);
                reference_mix_row(&row, &src, &mut reference);
                let same = blocked
                    .iter()
                    .zip(&reference)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "d={d} terms={terms}: blocked kernel diverged");
            }
        }
    }

    #[test]
    fn mix_node_matches_manual_weighted_sum() {
        let topo = Topology::build(Kind::Star, 5);
        let wm = metropolis_hastings(&topo);
        let src: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32, -(i as f32)]).collect();
        let mut out = vec![0.0f32; 2];
        wm.mix_node(0, &src, &mut out);
        let mut want = [0.0f32; 2];
        for &(j, w) in wm.row(0) {
            for k in 0..2 {
                want[k] += w * src[j as usize][k];
            }
        }
        assert!((out[0] - want[0]).abs() < 1e-6 && (out[1] - want[1]).abs() < 1e-6);
    }
}
