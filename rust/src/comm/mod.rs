//! Communication layer: the [`engine::CommEngine`] trait every
//! optimizer exchanges through (sparse neighbor lists in production,
//! dense matrix as the property-tested reference), plus the *analytic
//! cost model* ([`cost`]) that maps each optimizer's wire pattern onto
//! cluster time (Fig. 6) — the substitute for the paper's 8×V100 NCCL
//! testbed (DESIGN.md §2). Payloads are charged from realized edge
//! counts ([`cost::CommStats`]), never from an n×n matrix walk.

pub mod cost;
pub mod engine;

pub use cost::{wire_bytes_per_iter, CommCost, CommStats, LinkSpec};
pub use engine::CommEngine;
