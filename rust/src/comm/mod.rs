//! Communication layer: the [`engine::CommEngine`] trait every
//! optimizer exchanges through (sparse neighbor lists in production,
//! dense matrix as the property-tested reference), the payload
//! [`codec`]s that compress what goes on the gossip wire (fp32 / fp16 /
//! stochastic int8 / top-k, with error feedback — DESIGN.md §7), plus
//! the *analytic cost model* ([`cost`]) that maps each optimizer's wire
//! pattern onto cluster time (Fig. 6) — the substitute for the paper's
//! 8×V100 NCCL testbed (DESIGN.md §2). Payloads are charged from
//! realized edge counts ([`cost::CommStats`]) at their *encoded* widths
//! ([`cost::PayloadBytes`]), never from an n×n matrix walk or a blanket
//! 4·d assumption.

pub mod codec;
pub mod cost;
pub mod engine;

pub use codec::{CodecSpec, CodecState, EncodeScratch, PayloadCodec};
pub use cost::{wire_bytes_per_iter, CommCost, CommStats, LinkSpec, PayloadBytes};
pub use engine::CommEngine;
