//! Communication layer: the in-process exchange used by the trainer is
//! plain shared-memory buffer passing (`optim::partial_average_all`);
//! this module provides the *analytic cost model* that maps each
//! optimizer's wire pattern onto cluster time (Fig. 6) — the substitute
//! for the paper's 8×V100 NCCL testbed (DESIGN.md §2).

pub mod cost;

pub use cost::{CommCost, LinkSpec};
