//! Parallel node executor: contiguous-block fan-out over nodes for the
//! gradient, exchange and update phases (DESIGN.md §4, §13).
//!
//! Each helper partitions one (or several, zipped) `&mut` slices into
//! contiguous blocks — at most one block per lane — and runs the
//! closure on every element. Per-node work is independent and the
//! arithmetic is identical to the sequential order (no cross-lane
//! reductions), so results are bitwise equal to a serial run; the
//! trainer's `threads == 1` path and the tests rely on that.
//!
//! Two execution strategies share one chunk geometry ([`PhasePlan`],
//! computed once per phase — never re-derived per block):
//!
//! * **Persistent pool** (the default, [`NodeExecutor::new`]) —
//!   `threads - 1` long-lived workers created lazily on the first
//!   parallel phase and shared by every clone of the handle. A phase
//!   is an epoch handoff: the caller publishes a type-erased closure
//!   under a mutex, bumps the epoch, runs block 0 itself, and blocks
//!   on a condvar barrier until every worker checked in. No threads
//!   are created or destroyed per phase, which is what lets fleets of
//!   10⁴–10⁵ nodes amortize the fan-out (the PR-1 spawn-per-phase
//!   path stopped scaling near n ≈ 1024).
//! * **Spawn-per-phase** ([`NodeExecutor::spawn_per_phase`]) — the
//!   PR-1 reference path: scoped threads spawned per phase, one per
//!   block. Kept for `benches/fleet_scaling.rs` (the pool must beat
//!   it at n ≥ 4096) and the bitwise-identity pins in
//!   `tests/executor_pool.rs`.
//!
//! A panic inside any lane is caught at the lane boundary, the barrier
//! still completes (every worker checks in), and the panic resurfaces
//! on the calling thread — a panicking chunk can never deadlock the
//! pool or leave a worker reading a dead closure.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

/// Mutex guard that survives a poisoned lock: pool state is a set of
/// plain counters, valid at every instant, and panics propagate via
/// the explicit `panicked` flag rather than lock poisoning.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Condvar wait with the same poison-recovery rule as [`lock`].
fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Type-erased pointer to a phase closure (`Fn(lane)`), valid from the
/// epoch publish until every lane of that epoch has checked in.
type Task = *const (dyn Fn(usize) + Sync);

/// The job slot content, nameable so it can cross the `Mutex`.
struct Job(Task);

// SAFETY: the raw pointer is only dereferenced between an epoch's
// publish and its final check-in, a window during which `run_phase`
// keeps the pointee alive on the calling thread's stack; the pointee
// is `Sync`, so shared calls from several workers are sound.
unsafe impl Send for Job {}

/// Shared worker-pool state behind one mutex.
struct PoolState {
    /// Phase counter: workers run exactly one job per epoch bump.
    epoch: u64,
    /// The current phase closure (present iff a phase is in flight).
    job: Option<Job>,
    /// Workers that have not yet checked in for the current epoch.
    active: usize,
    /// Some lane's chunk panicked this epoch.
    panicked: bool,
    /// Pool is shutting down (handle dropped); workers exit.
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between phases.
    work_cv: Condvar,
    /// The caller parks here until every worker checked in.
    done_cv: Condvar,
}

/// The persistent worker pool: `workers` long-lived threads plus the
/// calling thread make `workers + 1` lanes per phase.
struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: usize,
    /// Serializes whole phases: concurrent `run_phase` calls on clones
    /// of one handle queue up instead of corrupting the job slot.
    phase_lock: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

fn worker_loop(shared: Arc<PoolShared>, lane: usize) {
    let mut seen: u64 = 0;
    loop {
        let task = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = wait(&shared.work_cv, st);
            }
            seen = st.epoch;
            // The job is installed before the epoch bump and cleared
            // only after `active` hits zero, so it is present here; the
            // `None` arm keeps the barrier sound regardless.
            st.job.as_ref().map(|j| j.0)
        };
        let ok = match task {
            Some(t) => {
                // SAFETY: `t` points at the phase closure, which
                // `run_phase` keeps alive until this lane's check-in
                // below; lanes touch disjoint index blocks.
                let f = unsafe { &*t };
                catch_unwind(AssertUnwindSafe(|| f(lane))).is_ok()
            }
            None => true,
        };
        let mut st = lock(&shared.state);
        if !ok {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl WorkerPool {
    fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..=workers)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared, lane))
            })
            .collect();
        WorkerPool { shared, workers, phase_lock: Mutex::new(()), handles }
    }

    /// One epoch handoff: run `task(lane)` on lanes `0..=workers` —
    /// lane 0 inline on the caller, the rest on the pool threads — and
    /// return only after every lane checked in. A panic on any lane
    /// resurfaces here after the barrier, never before (workers hold
    /// raw views into the caller's data until they check in).
    fn run_phase(&self, task: &(dyn Fn(usize) + Sync)) {
        let _phase = lock(&self.phase_lock);
        {
            let mut st = lock(&self.shared.state);
            debug_assert!(st.job.is_none() && st.active == 0, "phases never nest");
            // SAFETY: lifetime erasure only — the pointee lives on this
            // stack frame, and this function does not return (or
            // unwind) past the barrier below, so no worker can observe
            // it after the borrow ends.
            let erased: Task =
                unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), Task>(task) };
            st.job = Some(Job(erased));
            st.epoch += 1;
            st.active = self.workers;
            st.panicked = false;
        }
        self.shared.work_cv.notify_all();
        let caller = catch_unwind(AssertUnwindSafe(|| task(0)));
        let worker_panicked = {
            let mut st = lock(&self.shared.state);
            while st.active > 0 {
                st = wait(&self.shared.done_cv, st);
            }
            st.job = None;
            st.panicked
        };
        match caller {
            Err(payload) => resume_unwind(payload),
            Ok(()) if worker_panicked => {
                panic!("NodeExecutor worker panicked during a parallel phase")
            }
            Ok(()) => {}
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        lock(&self.shared.state).shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            // Worker bodies catch panics around the task; a join error
            // here would mean the runtime killed the thread — nothing
            // useful left to do with it during teardown.
            let _ = h.join();
        }
    }
}

/// Raw base pointer smuggled into a phase closure; lanes only ever
/// index disjoint blocks of the underlying slice.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);

// SAFETY: a lane dereferences only indices inside its own block and
// blocks partition the slice (see `dispatch`), so `&mut` aliasing
// across lanes is impossible; `T: Send` makes moving element access to
// another thread sound.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Execution strategy behind a [`NodeExecutor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// Persistent worker pool (the default for `threads > 1`).
    Pool,
    /// PR-1 reference path: scoped threads spawned every phase.
    SpawnPerPhase,
}

/// One phase's chunk geometry: block `b` covers
/// `[b·chunk, min((b+1)·chunk, n))`. Computed once per phase (the PR-9
/// fix — previously re-derived from `n` on every internal call) and
/// shared by the serial, spawn-per-phase and pool paths, so chunk
/// boundaries — and therefore results — cannot diverge between them.
/// `tests/executor_pool.rs` pins the boundaries for every n ≤ 4096.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhasePlan {
    /// Total items in the phase.
    pub n: usize,
    /// Items per contiguous block.
    pub chunk: usize,
    /// Number of blocks (= lanes that actually run work).
    pub blocks: usize,
}

/// Thread-count policy + execution strategy for fan-out over nodes.
/// Cheap to clone: clones share the same lazily-created pool.
#[derive(Clone)]
pub struct NodeExecutor {
    threads: usize,
    mode: Mode,
    /// Lazily created persistent pool, shared by every clone; `None`
    /// when `threads == 1` or in spawn-per-phase mode.
    pool: Option<Arc<OnceLock<WorkerPool>>>,
    /// Per-lane busy-time meter the profiler attaches (`--profile`);
    /// `None` (the default) keeps dispatch free of clock reads. Shared
    /// by clones, so the trainer's grad/exchange/update executors all
    /// accumulate into one view.
    meter: Option<Arc<crate::util::bench::LaneMeter>>,
}

impl std::fmt::Debug for NodeExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NodeExecutor")
            .field("threads", &self.threads)
            .field("mode", &self.mode)
            .field("pool_started", &self.pool_workers().is_some())
            .finish()
    }
}

impl NodeExecutor {
    /// Sequential executor (the default in unit tests).
    pub fn serial() -> NodeExecutor {
        NodeExecutor { threads: 1, mode: Mode::Pool, pool: None, meter: None }
    }

    /// `threads == 0` means one lane per available hardware thread.
    /// The persistent pool (if any) starts on the first parallel phase.
    pub fn new(threads: usize) -> NodeExecutor {
        NodeExecutor::with_mode(threads, Mode::Pool)
    }

    /// The PR-1 spawn-per-phase strategy: scoped threads created and
    /// joined every phase. Identical results to [`NodeExecutor::new`]
    /// (same [`PhasePlan`], same per-item bodies) at strictly worse
    /// fan-out cost — kept as the reference the pool is benchmarked
    /// and property-tested against.
    pub fn spawn_per_phase(threads: usize) -> NodeExecutor {
        NodeExecutor::with_mode(threads, Mode::SpawnPerPhase)
    }

    fn with_mode(threads: usize, mode: Mode) -> NodeExecutor {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            threads
        };
        let threads = threads.max(1);
        let pool =
            (threads > 1 && mode == Mode::Pool).then(|| Arc::new(OnceLock::new()));
        NodeExecutor { threads, mode, pool, meter: None }
    }

    /// Attach a per-lane busy-time meter: every dispatched block is
    /// timed and charged to its lane. Timing never changes which
    /// indices a lane visits, so results stay bitwise identical to the
    /// unmetered executor.
    pub fn with_meter(mut self, meter: Arc<crate::util::bench::LaneMeter>) -> NodeExecutor {
        self.meter = Some(meter);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Persistent worker threads actually spawned: `Some(threads - 1)`
    /// once the pool started, `None` before the first parallel phase
    /// and always in serial / spawn-per-phase modes. The count never
    /// depends on the fleet size n — `tests/executor_pool.rs` pins it
    /// across elastic resizes.
    pub fn pool_workers(&self) -> Option<usize> {
        self.pool.as_ref().and_then(|cell| cell.get()).map(|p| p.workers)
    }

    /// Chunk geometry so that `n` items spread over at most `threads`
    /// contiguous blocks — computed ONCE per phase.
    pub fn phase_plan(&self, n: usize) -> PhasePlan {
        let workers = self.threads.min(n).max(1);
        let chunk = (n + workers - 1) / workers;
        let blocks = if n == 0 { 0 } else { (n + chunk - 1) / chunk };
        PhasePlan { n, chunk, blocks }
    }

    /// Fan `body(start, end)` out over the plan's contiguous blocks.
    /// All `for_each` variants and both execution strategies route
    /// through this single geometry, which is what makes parallel
    /// results bitwise identical to serial: blocks partition `0..n` in
    /// order and bodies visit indices ascending within a block.
    fn dispatch(&self, plan: PhasePlan, body: &(dyn Fn(usize, usize) + Sync)) {
        let PhasePlan { n, chunk, blocks } = plan;
        if n == 0 {
            return;
        }
        // Metered wrapper around the block body: times the block and
        // charges it to the executing lane. With no meter attached this
        // is a plain call — zero clock reads on the unprofiled path.
        let run = |lane: usize, start: usize, end: usize| match &self.meter {
            Some(m) => {
                let t = crate::util::bench::WallTimer::start();
                body(start, end);
                m.add(lane, t.elapsed_ns());
            }
            None => body(start, end),
        };
        if blocks <= 1 {
            run(0, 0, n);
            return;
        }
        match self.mode {
            Mode::SpawnPerPhase => {
                std::thread::scope(|scope| {
                    for b in 0..blocks {
                        let start = b * chunk;
                        let end = (start + chunk).min(n);
                        let run = &run;
                        scope.spawn(move || run(b, start, end));
                    }
                });
            }
            Mode::Pool => match &self.pool {
                Some(cell) => {
                    let pool = cell.get_or_init(|| WorkerPool::new(self.threads - 1));
                    pool.run_phase(&|lane| {
                        if lane < blocks {
                            let start = lane * chunk;
                            let end = (start + chunk).min(n);
                            run(lane, start, end);
                        }
                    });
                }
                // threads == 1 never reaches here (blocks <= 1 above);
                // degrade to serial rather than trust that invariant.
                None => run(0, 0, n),
            },
        }
    }

    /// Run `f(i, &mut items[i])` for every index, fanned out over
    /// contiguous blocks.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let plan = self.phase_plan(items.len());
        let base = SendPtr(items.as_mut_ptr());
        let body = |start: usize, end: usize| {
            for i in start..end {
                // SAFETY: blocks partition `0..n` disjointly (dispatch
                // geometry) and `i < items.len()`, so no two lanes
                // alias an element; the slice outlives the phase.
                let item = unsafe { &mut *base.0.add(i) };
                f(i, item);
            }
        };
        self.dispatch(plan, &body);
    }

    /// Run `f(i, &mut a[i], &mut b[i])` for every index (equal-length
    /// slices, e.g. node states zipped with their publish buffers).
    pub fn for_each_pair_mut<A, B, F>(&self, a: &mut [A], b: &mut [B], f: F)
    where
        A: Send,
        B: Send,
        F: Fn(usize, &mut A, &mut B) + Sync,
    {
        let n = a.len();
        assert_eq!(n, b.len(), "zipped slices must have equal length");
        let plan = self.phase_plan(n);
        let (pa, pb) = (SendPtr(a.as_mut_ptr()), SendPtr(b.as_mut_ptr()));
        let body = |start: usize, end: usize| {
            for i in start..end {
                // SAFETY: as in `for_each_mut` — disjoint blocks over
                // equal-length slices, `i < n` for both.
                let (ai, bi) = unsafe { (&mut *pa.0.add(i), &mut *pb.0.add(i)) };
                f(i, ai, bi);
            }
        };
        self.dispatch(plan, &body);
    }

    /// Three-way zipped variant (gradient phase: engines, gradient
    /// buffers, per-node losses).
    pub fn for_each_triple_mut<A, B, C, F>(&self, a: &mut [A], b: &mut [B], c: &mut [C], f: F)
    where
        A: Send,
        B: Send,
        C: Send,
        F: Fn(usize, &mut A, &mut B, &mut C) + Sync,
    {
        let n = a.len();
        assert_eq!(n, b.len(), "zipped slices must have equal length");
        assert_eq!(n, c.len(), "zipped slices must have equal length");
        let plan = self.phase_plan(n);
        let (pa, pb, pc) =
            (SendPtr(a.as_mut_ptr()), SendPtr(b.as_mut_ptr()), SendPtr(c.as_mut_ptr()));
        let body = |start: usize, end: usize| {
            for i in start..end {
                // SAFETY: as in `for_each_mut` — disjoint blocks over
                // equal-length slices, `i < n` for all three.
                let (ai, bi, ci) =
                    unsafe { (&mut *pa.0.add(i), &mut *pb.0.add(i), &mut *pc.0.add(i)) };
                f(i, ai, bi, ci);
            }
        };
        self.dispatch(plan, &body);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn executors(threads: usize) -> [NodeExecutor; 2] {
        [NodeExecutor::new(threads), NodeExecutor::spawn_per_phase(threads)]
    }

    #[test]
    fn indices_cover_every_item_exactly_once() {
        for threads in [1usize, 2, 3, 8, 64] {
            for exec in executors(threads) {
                for n in [0usize, 1, 2, 7, 64, 101] {
                    let mut hits = vec![0u32; n];
                    exec.for_each_mut(&mut hits, |i, h| {
                        *h += 1 + i as u32;
                    });
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(*h, 1 + i as u32, "threads={threads} n={n} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn pair_and_triple_stay_aligned() {
        for exec in executors(4) {
            let n = 37;
            let mut a: Vec<usize> = (0..n).collect();
            let mut b = vec![0usize; n];
            exec.for_each_pair_mut(&mut a, &mut b, |i, ai, bi| {
                *bi = *ai * 2 + i;
            });
            assert!(b.iter().enumerate().all(|(i, &v)| v == i * 3));

            let mut c = vec![0usize; n];
            exec.for_each_triple_mut(&mut a, &mut b, &mut c, |i, ai, bi, ci| {
                *ci = *ai + *bi + i;
            });
            assert!(c.iter().enumerate().all(|(i, &v)| v == i * 5));
        }
    }

    #[test]
    fn parallel_matches_serial_output() {
        let mut serial: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let work = |_i: usize, v: &mut f32| {
            *v = (*v).sqrt() * 3.0 + 1.0;
        };
        NodeExecutor::serial().for_each_mut(&mut serial, work);
        for exec in executors(7) {
            let mut par: Vec<f32> = (0..1000).map(|i| i as f32).collect();
            // Two consecutive phases through the same executor: the
            // pool must hand off cleanly across epochs.
            exec.for_each_mut(&mut par, work);
            exec.for_each_mut(&mut par, |_i, v| *v += 0.0);
            assert_eq!(serial, par, "parallel execution must be bitwise identical");
        }
    }

    #[test]
    fn pool_starts_lazily_and_is_shared_by_clones() {
        let exec = NodeExecutor::new(3);
        assert_eq!(exec.pool_workers(), None, "no threads before the first phase");
        let clone = exec.clone();
        let mut v = vec![0u8; 64];
        clone.for_each_mut(&mut v, |_i, x| *x = 1);
        assert_eq!(exec.pool_workers(), Some(2), "clones share one pool");
        assert_eq!(clone.pool_workers(), Some(2));
        assert_eq!(NodeExecutor::serial().pool_workers(), None);
        assert_eq!(NodeExecutor::spawn_per_phase(3).pool_workers(), None);
    }

    #[test]
    fn meter_charges_lanes_without_changing_results() {
        let meter = Arc::new(crate::util::bench::LaneMeter::new(3));
        let exec = NodeExecutor::new(3).with_meter(Arc::clone(&meter));
        let mut a: Vec<f32> = (0..50_000).map(|i| i as f32).collect();
        exec.for_each_mut(&mut a, |_i, v| *v = v.sqrt() * 3.0 + 1.0);
        let mut b: Vec<f32> = (0..50_000).map(|i| i as f32).collect();
        NodeExecutor::serial().for_each_mut(&mut b, |_i, v| *v = v.sqrt() * 3.0 + 1.0);
        assert_eq!(a, b, "metering must not perturb results");
        let busy = meter.snapshot();
        assert_eq!(busy.len(), 3);
        assert!(busy.iter().sum::<u64>() > 0, "blocks were timed: {busy:?}");
    }

    #[test]
    fn zero_threads_resolves_to_hardware() {
        assert!(NodeExecutor::new(0).threads() >= 1);
        assert_eq!(NodeExecutor::serial().threads(), 1);
    }
}
