//! Parallel node executor: chunked scoped-thread fan-out over nodes for
//! the gradient, exchange and update phases (DESIGN.md §4).
//!
//! Each helper partitions one (or several, zipped) `&mut` slices into
//! contiguous blocks — at most one block per worker — and runs the
//! closure on every element inside `std::thread::scope`. Per-node work
//! is independent and the arithmetic is identical to the sequential
//! order (no cross-thread reductions), so results are bitwise equal to
//! a serial run; the trainer's `threads == 1` path and the tests rely
//! on that.
//!
//! The executor is a trivially-copyable handle (just a thread count):
//! threads are spawned per phase, which measures well up to n ≈ 1024
//! nodes given each phase does O(d) work per node — a persistent pool
//! is an upgrade documented in DESIGN.md §Open.

/// Thread-count policy for fan-out over nodes.
#[derive(Debug, Clone, Copy)]
pub struct NodeExecutor {
    threads: usize,
}

impl NodeExecutor {
    /// Sequential executor (the default in unit tests).
    pub fn serial() -> NodeExecutor {
        NodeExecutor { threads: 1 }
    }

    /// `threads == 0` means one worker per available hardware thread.
    pub fn new(threads: usize) -> NodeExecutor {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            threads
        };
        NodeExecutor { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Block size so that `n` items spread over at most `threads` blocks.
    fn chunk_for(&self, n: usize) -> usize {
        let workers = self.threads.min(n).max(1);
        (n + workers - 1) / workers
    }

    /// Run `f(i, &mut items[i])` for every index, fanned out over
    /// contiguous blocks.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let chunk = self.chunk_for(n);
        if chunk >= n {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let f = &f;
        std::thread::scope(|scope| {
            for (b, block) in items.chunks_mut(chunk).enumerate() {
                scope.spawn(move || {
                    for (k, item) in block.iter_mut().enumerate() {
                        f(b * chunk + k, item);
                    }
                });
            }
        });
    }

    /// Run `f(i, &mut a[i], &mut b[i])` for every index (equal-length
    /// slices, e.g. node states zipped with their publish buffers).
    pub fn for_each_pair_mut<A, B, F>(&self, a: &mut [A], b: &mut [B], f: F)
    where
        A: Send,
        B: Send,
        F: Fn(usize, &mut A, &mut B) + Sync,
    {
        let n = a.len();
        assert_eq!(n, b.len(), "zipped slices must have equal length");
        if n == 0 {
            return;
        }
        let chunk = self.chunk_for(n);
        if chunk >= n {
            for (i, (ai, bi)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
                f(i, ai, bi);
            }
            return;
        }
        let f = &f;
        std::thread::scope(|scope| {
            for (blk, (ba, bb)) in a.chunks_mut(chunk).zip(b.chunks_mut(chunk)).enumerate() {
                scope.spawn(move || {
                    for (k, (ai, bi)) in ba.iter_mut().zip(bb.iter_mut()).enumerate() {
                        f(blk * chunk + k, ai, bi);
                    }
                });
            }
        });
    }

    /// Three-way zipped variant (gradient phase: engines, gradient
    /// buffers, per-node losses).
    pub fn for_each_triple_mut<A, B, C, F>(&self, a: &mut [A], b: &mut [B], c: &mut [C], f: F)
    where
        A: Send,
        B: Send,
        C: Send,
        F: Fn(usize, &mut A, &mut B, &mut C) + Sync,
    {
        let n = a.len();
        assert_eq!(n, b.len(), "zipped slices must have equal length");
        assert_eq!(n, c.len(), "zipped slices must have equal length");
        if n == 0 {
            return;
        }
        let chunk = self.chunk_for(n);
        if chunk >= n {
            for i in 0..n {
                f(i, &mut a[i], &mut b[i], &mut c[i]);
            }
            return;
        }
        let f = &f;
        std::thread::scope(|scope| {
            for (blk, ((ba, bb), bc)) in a
                .chunks_mut(chunk)
                .zip(b.chunks_mut(chunk))
                .zip(c.chunks_mut(chunk))
                .enumerate()
            {
                scope.spawn(move || {
                    for (k, ((ai, bi), ci)) in
                        ba.iter_mut().zip(bb.iter_mut()).zip(bc.iter_mut()).enumerate()
                    {
                        f(blk * chunk + k, ai, bi, ci);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_cover_every_item_exactly_once() {
        for threads in [1usize, 2, 3, 8, 64] {
            for n in [0usize, 1, 2, 7, 64, 101] {
                let exec = NodeExecutor::new(threads);
                let mut hits = vec![0u32; n];
                exec.for_each_mut(&mut hits, |i, h| {
                    *h += 1 + i as u32;
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(*h, 1 + i as u32, "threads={threads} n={n} i={i}");
                }
            }
        }
    }

    #[test]
    fn pair_and_triple_stay_aligned() {
        let exec = NodeExecutor::new(4);
        let n = 37;
        let mut a: Vec<usize> = (0..n).collect();
        let mut b = vec![0usize; n];
        exec.for_each_pair_mut(&mut a, &mut b, |i, ai, bi| {
            *bi = *ai * 2 + i;
        });
        assert!(b.iter().enumerate().all(|(i, &v)| v == i * 3));

        let mut c = vec![0usize; n];
        exec.for_each_triple_mut(&mut a, &mut b, &mut c, |i, ai, bi, ci| {
            *ci = *ai + *bi + i;
        });
        assert!(c.iter().enumerate().all(|(i, &v)| v == i * 5));
    }

    #[test]
    fn parallel_matches_serial_output() {
        let mut serial: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let mut par = serial.clone();
        let work = |_i: usize, v: &mut f32| {
            *v = (*v).sqrt() * 3.0 + 1.0;
        };
        NodeExecutor::serial().for_each_mut(&mut serial, work);
        NodeExecutor::new(7).for_each_mut(&mut par, work);
        assert_eq!(serial, par, "parallel execution must be bitwise identical");
    }

    #[test]
    fn zero_threads_resolves_to_hardware() {
        assert!(NodeExecutor::new(0).threads() >= 1);
        assert_eq!(NodeExecutor::serial().threads(), 1);
    }
}
