//! The Layer-3 coordinator: drives `n` nodes through synchronous
//! decentralized training rounds (gradient phase → exchange → update),
//! with gradient accumulation for large total batches, scheduled
//! learning rates, periodic evaluation and consensus tracking.

pub mod trainer;

pub use trainer::{TrainReport, Trainer};
