//! The Layer-3 coordinator: drives `n` nodes through synchronous
//! decentralized training rounds (gradient phase → exchange → update),
//! with gradient accumulation for large total batches, scheduled
//! learning rates, periodic evaluation and consensus tracking. All
//! three phases fan out over nodes through the [`executor`]'s chunked
//! scoped threads.

pub mod executor;
pub mod trainer;

pub use executor::NodeExecutor;
pub use trainer::{TrainReport, Trainer};
