//! The training loop.
//!
//! One `step`:
//!   1. **Gradient phase** — every node computes its mean gradient over
//!      `accum` micro-batches at its own model, fanned out over the
//!      [`NodeExecutor`] (PJRT engines funnel into the runtime thread,
//!      native engines run truly in parallel).
//!   2. **Exchange + update phase** — the configured [`Optimizer`]
//!      performs its communication (partial averaging / all-reduce) and
//!      applies its update rule, also chunked over nodes by the
//!      executor. The wire pattern is whatever the optimizer declared;
//!      the Fig. 6 cost model charges it from realized edge counts.
//!   3. **Bookkeeping** — losses, learning-rate schedule, periodic eval
//!      of the network-average model, consensus distance.
//!
//! Mixing weights live in a [`SparseWeights`] neighbor-list engine —
//! O(edges) memory and rebuild cost, so ring/grid/exp-graph runs scale
//! to n=512–1024. Time-varying topologies (one-peer exp, bipartite
//! random match) rebuild only the neighbor lists each step from the
//! shared seed, never an n×n matrix.
//!
//! When `Config::faults` is set, a [`FaultyEngine`] sits between the
//! nominal weights and the optimizers: each step it masks dropped
//! nodes / failed links, renormalizes the Metropolis–Hastings weights
//! in place, and serves stale cached messages for stragglers — the
//! whole run stays deterministic under the fault seed (DESIGN.md §6).
//!
//! When `Config::codec` is set, every gossip payload is compressed
//! through the named [`CodecState`] (fp16 / stochastic int8 / top-k
//! with error feedback, DESIGN.md §7): the optimizers' exchanges all
//! route through `optim::gossip_exchange`, which encodes each publish
//! buffer once and mixes the decoded wire view; the fault engine's
//! stale cache then holds encoded payloads, so faults and compression
//! compose. Runs stay byte-identical under the codec seed.
//!
//! When `Config::async_mode` is set (`--async tau=2,spread=4`), rounds
//! execute against the discrete-event clock sim's bounded-staleness
//! schedule (DESIGN.md §8): nodes run on heterogeneous seeded virtual
//! clocks and each edge delivery may be up to `tau` rounds old, served
//! from the fault engine's per-exchange-slot ring caches. With uniform
//! speeds, zero jitter and `tau=0` the schedule realizes all-fresh and
//! the run is bitwise identical to the synchronous path; `pmsgd` runs
//! as the barrier baseline (simulated time only, no staleness).
//!
//! When `Config::churn` is set (`--churn join=0.02,leave=0.02,nmin=8,
//! nmax=64`), the roster itself becomes elastic (DESIGN.md §9): a
//! seeded [`ChurnPlan`] realizes join/leave events at the top of each
//! step, the CSR mixing weights are rebuilt in place at the new node
//! count (symmetric doubly stochastic at every size), joiners
//! warm-start from their neighbors' decoded wire average with momentum
//! zeroed, and every seeded schedule (faults, codec streams, churn
//! itself) keys on STABLE node ids so resizes never perturb another
//! node's randomness. A zero-rate plan leaves the run bitwise
//! identical to the fixed-roster trainer.
//!
//! [`Trainer::checkpoint`] / [`Trainer::resume`] capture and restore
//! the complete cross-step mutable state — node states, shard cursors
//! + RNG counters, codec EF residuals, fault cache and async ring
//! history, the roster — through the checksummed
//! [`crate::elastic::Snapshot`] format: save → restore → continue is
//! bitwise identical to an uninterrupted run.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::comm::codec::CodecState;
use crate::comm::cost::{wire_bytes_per_iter, CommCost, CommStats, PayloadBytes};
use crate::comm::CommEngine;
use crate::data::synth::ShardCursor;
use crate::elastic::snapshot::{FaultState, Snapshot, SnapshotMeta};
use crate::elastic::{ChurnPlan, ChurnStats, Roster, StepChurn};
use crate::grad::{NodeGrad, Workload};
use crate::optim::{self, NodeState, Optimizer, RoundCtx, Scratch};
use crate::sim::clock::{simulate_barrier, simulate_gossip, AsyncReport};
use crate::sim::{FaultPlan, FaultSpec, FaultStats, FaultyEngine};
use crate::telemetry::{Event, StepMetrics, TelemetrySink};
use crate::topology::{metropolis_hastings, Kind, SparseWeights, Topology, WeightMatrix};
use crate::util::bench;
use crate::util::config::Config;
use crate::util::json::Value;
use crate::util::math;

use super::executor::NodeExecutor;

/// Everything a finished run reports.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// Run manifest (compact JSON): every reproducibility-relevant
    /// config knob of the run that produced this report — seed,
    /// topology, node counts, optimizer, batch shape, codec/fault/
    /// async/churn specs — so an experiment artifact alone suffices to
    /// replay the run.
    pub manifest: String,
    /// Mean training loss per step (averaged over nodes).
    pub losses: Vec<f64>,
    /// (step, accuracy) evaluation points of the average model.
    pub evals: Vec<(usize, f64)>,
    /// (step, eval loss) if the evaluator provides one.
    pub eval_losses: Vec<(usize, f64)>,
    /// Final top-1 accuracy of the average model.
    pub final_accuracy: f64,
    /// Final consensus distance (1/n)Σ‖x_i − x̄‖².
    pub final_consensus: f64,
    /// Wall seconds in the gradient phase / update phase.
    pub grad_seconds: f64,
    pub update_seconds: f64,
    pub steps: usize,
    /// REALIZED wire bytes summed over the executed steps: per-step
    /// edge counts (after fault masks and membership resizes) × the
    /// configured payload widths — not one nominal snapshot × steps.
    pub wire_bytes_total: f64,
    /// `wire_bytes_total / executed steps` (0 when no step ran). Equals
    /// the nominal analytic value exactly on static fault-free runs:
    /// every step realizes the same graph and (total·w)/total == w in
    /// IEEE f64.
    pub wire_bytes_per_iter: f64,
}

/// Multi-node trainer.
pub struct Trainer {
    pub cfg: Config,
    pub workload: Workload,
    pub kind: Kind,
    /// Sparse neighbor-list comm engine (the nominal mixing weights).
    pub comm: SparseWeights,
    /// Fault-injection wrapper (None = ideal network). When present,
    /// every round mixes through the masked + renormalized realized
    /// rows instead of the nominal ones.
    faults: Option<FaultyEngine>,
    /// Payload codec for the gossip wire path (None = raw fp32). Owned
    /// here because the EF residuals and wire buffers are cross-round
    /// state; rounds reach it through `RoundCtx::codec`.
    codec: Option<Mutex<CodecState>>,
    /// Timing + staleness summary of the `--async` discrete-event run
    /// (None = synchronous). The schedule itself lives inside the fault
    /// engine, which replays it round by round.
    async_report: Option<AsyncReport>,
    topo: Topology,
    pub states: Vec<NodeState>,
    optimizer: Box<dyn Optimizer>,
    scratch: Scratch,
    grads: Vec<Vec<f32>>,
    losses: Vec<f64>,
    /// Executor for the gradient phase (compute-heavy per node).
    exec: NodeExecutor,
    /// Executor for the exchange/update phases: serial when n·d is too
    /// small to amortize thread spawns (results are identical either
    /// way — the executor never reorders arithmetic).
    update_exec: NodeExecutor,
    /// Elastic membership (None = fixed roster; DESIGN.md §9).
    elastic: Option<Elastic>,
    /// Stable id owning each `workload.nodes` slot. Invariant: slots
    /// [0..m) are the active ids in dense order, [m..capacity) the
    /// parked ids — the gradient phase fans over the first m slots.
    engine_ids: Vec<u32>,
    /// First step `run` executes next: 0 on a fresh trainer, the
    /// checkpoint's cursor after [`Trainer::restore`].
    next_step: usize,
    /// Step the current topology realization was built at (last resize).
    topo_step: usize,
    /// Has any membership change happened? Engages the optimizers'
    /// time-varying guard from the first resize on (a resize makes the
    /// realized W time-varying exactly like a fault mask does).
    churned: bool,
    /// Realized wire-byte accounting: per-step sums over the engine's
    /// REALIZED edge counts (fault masks and resizes change the graph
    /// step to step, so one nominal snapshot × steps misstates traffic).
    wire_bytes_total: f64,
    wire_steps: usize,
    /// Telemetry stream (None = off; `--telemetry out.jsonl`). With it
    /// unset the step loop is bitwise identical to the pre-telemetry
    /// trainer (DESIGN.md §11).
    telemetry: Option<TelemetrySink>,
    /// Run-profile metrics collected at the `--metrics every=K` cadence
    /// (DESIGN.md §14), in step order — what the stream's `metrics`
    /// lines carry, kept in memory for in-process consumers (the
    /// large-batch sweep gates). Empty when metrics are off.
    metrics_log: Vec<StepMetrics>,
    /// Wall-clock phase profiler (None = off; `--profile every=K`).
    /// Strictly observability: timings flow into `timing` events only,
    /// which replay parses but excludes from equality (DESIGN.md §14).
    profiler: Option<Profiler>,
}

/// Elastic-membership state: the seeded event schedule, the live
/// roster, and cumulative accounting.
struct Elastic {
    plan: ChurnPlan,
    roster: Roster,
    stats: ChurnStats,
}

/// Wall-clock phase profiler state behind `--profile every=K`
/// (DESIGN.md §14). The trainer times the gradient phase and the whole
/// optimizer round itself; [`optim::gossip_exchange`] splits the round
/// into encode/exchange spans via the shared [`bench::PhaseClock`], and
/// the metered executors charge per-lane busy time to the shared
/// [`bench::LaneMeter`]. The update phase is the round's remainder.
/// Phase index order everywhere: grad, encode, exchange, update.
struct Profiler {
    every: usize,
    clock: bench::PhaseClock,
    meter: Arc<bench::LaneMeter>,
    /// Cumulative per-phase wall nanoseconds.
    totals: [u64; 4],
    /// Per-phase log2(ns) duration histograms over the observed steps
    /// (deterministic bucket edges; the counts are wall-clock noise,
    /// which is why `timing` events never enter replay equality).
    hists: [BTreeMap<i32, usize>; 4],
    /// Clock totals at the previous observation, for per-step deltas.
    seen: (u64, u64),
}

impl Profiler {
    fn new(every: usize, lanes: usize) -> Profiler {
        Profiler {
            every,
            clock: bench::PhaseClock::new(),
            meter: Arc::new(bench::LaneMeter::new(lanes)),
            totals: [0; 4],
            hists: Default::default(),
            seen: (0, 0),
        }
    }

    /// Fold one step in: grad and whole-round wall time measured by the
    /// trainer, encode/exchange as this step's phase-clock deltas,
    /// update as the round's remainder.
    fn observe(&mut self, grad_ns: u64, round_ns: u64) {
        let (enc, exch) = self.clock.totals();
        let enc_d = enc.saturating_sub(self.seen.0);
        let exch_d = exch.saturating_sub(self.seen.1);
        self.seen = (enc, exch);
        let upd_d = round_ns.saturating_sub(enc_d + exch_d);
        for (slot, ns) in [(0, grad_ns), (1, enc_d), (2, exch_d), (3, upd_d)] {
            self.totals[slot] += ns;
            *self.hists[slot].entry(bench::log2_ns_bucket(ns)).or_insert(0) += 1;
        }
    }

    fn due(&self, k: usize) -> bool {
        self.every > 0 && k % self.every == 0
    }

    fn to_event(&self, step: usize) -> Event {
        let hist = |m: &BTreeMap<i32, usize>| m.iter().map(|(&b, &c)| (b, c)).collect();
        Event::Timing {
            step,
            grad_ns: self.totals[0],
            encode_ns: self.totals[1],
            exchange_ns: self.totals[2],
            update_ns: self.totals[3],
            grad_hist: hist(&self.hists[0]),
            encode_hist: hist(&self.hists[1]),
            exchange_hist: hist(&self.hists[2]),
            update_hist: hist(&self.hists[3]),
            lane_busy_ns: self.meter.snapshot(),
        }
    }
}

/// Below this many touched f32s per phase (n·d), the exchange/update
/// loops run serially — a scoped-thread spawn costs more than copying
/// a few thousand floats.
const PARALLEL_UPDATE_MIN_ITEMS: usize = 1 << 17;

impl Trainer {
    pub fn new(cfg: Config, workload: Workload) -> Result<Trainer> {
        // Cross-field invariants live in ONE place (churn ⇒ static
        // topology + synchronous rounds, slowmo ⇏ async, known
        // topology/optimizer names) — the scenario runner validates the
        // same way without building a trainer.
        cfg.validate()?;
        let kind = Kind::parse(&cfg.topology)?;
        let n = cfg.nodes;
        // Elastic membership: resolve the churn bounds against the
        // run's initial node count. The stable-id space is 0..nmax and
        // the workload must supply one shard per stable id; `nodes`
        // stays the INITIAL active count.
        let elastic = match cfg.churn {
            None => None,
            Some(spec) => {
                let spec = spec.with_run_seed(cfg.seed).resolve(n)?;
                Some(Elastic {
                    plan: ChurnPlan::new(spec),
                    roster: Roster::new(n, spec.nmax),
                    stats: ChurnStats::default(),
                })
            }
        };
        let capacity = elastic.as_ref().map(|el| el.roster.capacity()).unwrap_or(n);
        anyhow::ensure!(
            workload.nodes.len() == capacity,
            "workload has {} node shards, run wants {capacity} (the churn capacity \
             nmax; initial active nodes = {n})",
            workload.nodes.len()
        );
        let topo = Topology::at_step(kind, n, cfg.seed, 0);
        // B-connectivity sanity: the union graph over the kind's
        // declared window must be connected (Assumption A.3 over a
        // window); kinds with only probabilistic guarantees (bipartite
        // random match) declare no window and are exempt.
        if let Some(w) = kind.connectivity_window(n) {
            let union = Topology::union_over_window(kind, n, cfg.seed, 0, w);
            anyhow::ensure!(
                union.is_connected(),
                "{kind:?} union over its {w}-step window is disconnected at n={n}"
            );
        }
        let mut comm = SparseWeights::metropolis_hastings(&topo);
        if cfg.positive_definite {
            comm.make_lazy();
        }
        // Elastic runs rebuild the weights on every membership resize:
        // warm the CSR arenas at the roster's nmax once, so the
        // `apply_churn` rebuilds never reallocate. Churn requires a
        // static kind (cfg.validate), whose nnz = n + 2·edges is
        // monotone in n — the nmax realization is the high-water mark.
        if elastic.is_some() {
            let edges = Topology::at_step(kind, capacity, cfg.seed, 0).num_edges();
            comm.reserve_for(capacity, capacity + 2 * edges);
        }
        let optimizer = optim::build(&cfg.optimizer, cfg.slowmo_period, cfg.slowmo_beta)?;
        let mut faults = match cfg.faults {
            None => None,
            // Attach an engine only when the optimizer actually mixes
            // through the comm engine — pure all-reduce baselines
            // (PmSGD) model a centralized fabric outside the
            // decentralized fault model, and attaching one would report
            // faults that never touched training (`fault_stats()` stays
            // None for them).
            Some(spec) => {
            let spec = spec.with_run_seed(cfg.seed);
            match optimizer.comm_pattern() {
                optim::CommPattern::AllReduce => None,
                pattern => {
                    let mut engine = FaultyEngine::new(FaultPlan::new(spec));
                    // Stale replay is only faithful when the round
                    // publishes a single quantity — the cache then holds
                    // last round's same payload. Multi-payload optimizers
                    // (da-dmsgd) fall back to masking for straggle/stale
                    // faults (see FaultyEngine docs).
                    let single_payload = match pattern {
                        optim::CommPattern::Neighbor { payloads } => payloads == 1,
                        optim::CommPattern::NeighborPlusPeriodicAllReduce {
                            payloads, ..
                        } => payloads == 1,
                        optim::CommPattern::AllReduce => unreachable!(),
                    };
                    engine.set_stale_capable(single_payload);
                    Some(engine)
                }
            }
            }
        };
        let d = workload.dim;
        let codec = match &cfg.codec {
            None => None,
            // Codec seed defaults to the run seed (like --faults). Pure
            // all-reduce optimizers (PmSGD) never touch the gossip wire
            // the codec compresses — attach no state for them, so
            // `codec_name()`/`payload_bytes()` never report a
            // compression that cannot happen (same honesty rule as the
            // fault engine above).
            Some(spec) => {
                let spec = spec.clone().with_run_seed(cfg.seed);
                match optimizer.comm_pattern() {
                    optim::CommPattern::AllReduce => None,
                    _ => Some(Mutex::new(CodecState::new(&spec, n, d))),
                }
            }
        };
        // Asynchronous execution: run the discrete-event clock sim over
        // the static topology (DESIGN.md §8). Event times are
        // value-free, so the whole schedule — per-(step, edge)
        // staleness ages plus completion times — is known up front; the
        // fault engine replays the ages from per-slot ring caches while
        // the trainer's global-step loop executes the rounds in order
        // (a topological execution of the event DAG, value-identical to
        // firing nodes in event order). Gossip legs charge the codec's
        // ENCODED payload width, so compression shortens simulated
        // exchanges too.
        let async_report = match &cfg.async_mode {
            None => None,
            Some(spec) => {
            let spec = spec.clone().with_run_seed(cfg.seed);
            match optimizer.comm_pattern() {
                optim::CommPattern::AllReduce => {
                    // Barrier-synchronous baseline: each simulated round
                    // costs the slowest node's compute plus the
                    // collective; no staleness ever reaches training.
                    let ar = CommCost::new(spec.link()).allreduce_s(n, 4.0 * d as f64);
                    let (cum, wait) = simulate_barrier(&spec, n, ar, cfg.steps);
                    Some(AsyncReport::barrier(cum, wait))
                }
                optim::CommPattern::NeighborPlusPeriodicAllReduce { .. } => {
                    anyhow::bail!(
                        "--async models pure gossip rounds; `{}`'s periodic all-reduce \
                         is a global barrier (run pmsgd for the barrier baseline)",
                        cfg.optimizer
                    );
                }
                optim::CommPattern::Neighbor { payloads } => {
                    anyhow::ensure!(
                        !kind.time_varying(),
                        "--async requires a static topology; `{}` changes neighbors per step",
                        cfg.topology
                    );
                    let neighbor_bytes = match &codec {
                        Some(c) => c.lock().unwrap().payload_bytes(),
                        None => 4.0 * d as f64,
                    };
                    let sched = simulate_gossip(&spec, &comm, neighbor_bytes, payloads, cfg.steps);
                    let report = sched.report();
                    let engine = faults.get_or_insert_with(|| {
                        let mut e = FaultyEngine::new(FaultPlan::new(FaultSpec {
                            seed: cfg.seed,
                            ..Default::default()
                        }));
                        e.set_stale_capable(payloads == 1);
                        e
                    });
                    engine.set_async(sched);
                    Some(report)
                }
            }
            }
        };
        // Elastic runs key every fault stream on stable ids from the
        // start (identity initially, so draws are unchanged); resizes
        // then only swap the id list.
        if elastic.is_some() {
            if let Some(f) = &mut faults {
                f.set_ids(Some((0..n as u32).collect()));
            }
        }
        let states = (0..n)
            .map(|_| NodeState::new(workload.init.clone(), optimizer.aux_count()))
            .collect();
        // One persistent pool per trainer (started lazily on the first
        // parallel phase); `update_exec` clones the handle — clones
        // share the pool — or stays serial when phases are too small to
        // amortize even a pool handoff. With `--profile` on, both
        // executors share the profiler's lane meter (a serial update
        // path charges lane 0).
        let mut exec = NodeExecutor::new(cfg.threads);
        let profiler =
            (cfg.profile_every > 0).then(|| Profiler::new(cfg.profile_every, exec.threads()));
        if let Some(p) = &profiler {
            exec = exec.with_meter(Arc::clone(&p.meter));
        }
        let update_exec = if n * d >= PARALLEL_UPDATE_MIN_ITEMS {
            exec.clone()
        } else {
            match &profiler {
                Some(p) => NodeExecutor::serial().with_meter(Arc::clone(&p.meter)),
                None => NodeExecutor::serial(),
            }
        };
        let mut t = Trainer {
            cfg,
            workload,
            kind,
            comm,
            faults,
            codec,
            async_report,
            topo,
            states,
            optimizer,
            scratch: Scratch::new(n, d),
            grads: (0..n).map(|_| vec![0.0; d]).collect(),
            losses: vec![0.0; n],
            exec,
            update_exec,
            elastic,
            engine_ids: (0..capacity as u32).collect(),
            next_step: 0,
            topo_step: 0,
            churned: false,
            wire_bytes_total: 0.0,
            wire_steps: 0,
            telemetry: None,
            metrics_log: Vec::new(),
            profiler,
        };
        // Telemetry stream (DESIGN.md §11): open the sink and write the
        // run envelope up front, so even a crashed run leaves a stream
        // whose manifest identifies it. Creation failures are loud —
        // the user asked for a stream and no work is lost yet; runtime
        // IO errors later never abort training (sink goes inert).
        if let Some(path) = t.cfg.telemetry.clone() {
            let sink = TelemetrySink::create_with_flush(Path::new(&path), t.cfg.telemetry_flush)?;
            sink.emit(&Event::run_start(t.manifest_json()));
            if let Some(ar) = &t.async_report {
                sink.emit(&Event::Async {
                    steps: ar.step_done_s.len(),
                    makespan_s: ar.makespan_s,
                    total_wait_s: ar.total_wait_s,
                    mean_staleness: ar.mean_staleness,
                    max_staleness: ar.max_staleness as usize,
                    stale_fraction: ar.stale_fraction,
                });
            }
            t.telemetry = Some(sink);
        }
        Ok(t)
    }

    /// The network-average model x̄.
    pub fn average_model(&self) -> Vec<f32> {
        let refs: Vec<&[f32]> = self.states.iter().map(|s| s.x.as_slice()).collect();
        math::mean_of(&refs)
    }

    /// Consensus distance (1/n) Σ ‖x_i − x̄‖².
    pub fn consensus_distance(&self) -> f64 {
        let xbar = self.average_model();
        math::sum_f64(self.states.iter().map(|s| math::dist2(&s.x, &xbar)))
            / self.states.len() as f64
    }

    /// Dense mixing matrix of the current topology realization — for
    /// spectral analysis only (O(n²) memory); the training path never
    /// materializes it.
    pub fn mixing_matrix(&self) -> WeightMatrix {
        let wm = metropolis_hastings(&self.topo);
        if self.cfg.positive_definite {
            wm.lazy()
        } else {
            wm
        }
    }

    /// One training step; returns the mean training loss (over the
    /// active roster).
    pub fn step(&mut self, k: usize) -> f64 {
        // --- elastic membership (DESIGN.md §9) ---
        // Realize this step's churn events before any phase: leavers
        // are gone for the whole step, joiners warm-start from their
        // neighbors and contribute a gradient immediately. A quiet
        // step (or a zero-rate plan) touches nothing, so zero-churn
        // runs stay bitwise identical to the fixed-roster trainer.
        let ev = self.elastic.as_ref().map(|el| el.plan.step_churn(k, &el.roster));
        if let Some(ev) = ev {
            if !ev.is_empty() {
                // `apply_churn` consumes the event; keep the id lists
                // only when a stream wants them.
                let emitted =
                    self.telemetry.is_some().then(|| (ev.joins.clone(), ev.leaves.clone()));
                self.apply_churn(k, ev);
                if let (Some(sink), Some((joins, leaves))) = (&self.telemetry, emitted) {
                    sink.emit(&Event::Churn {
                        step: k,
                        joins,
                        leaves,
                        nodes: self.states.len(),
                    });
                }
            }
        }
        let accum = self.cfg.accum_steps();
        let lr = self.cfg.lr_at(k);
        let m = self.states.len();
        // --- gradient phase (executor-chunked over nodes) ---
        // Active engines occupy the first m slots in dense order (the
        // `engine_ids` invariant); parked shards never compute.
        let t_grad = self.profiler.as_ref().map(|_| bench::WallTimer::start());
        let loss = {
            let states = &self.states;
            self.exec.for_each_triple_mut(
                &mut self.workload.nodes[..m],
                &mut self.grads,
                &mut self.losses,
                |i, node, g, loss| {
                    *loss = node.grad_accum(&states[i].x, accum, g);
                },
            );
            math::mean_f64(&self.losses)
        };
        let grad_ns = t_grad.map(|t| t.elapsed_ns()).unwrap_or(0);
        // Snapshot the parameters entering the round only on metric
        // steps — the bias proxy compares the realized round against
        // the bias-free W-mixed update of this view (DESIGN.md §14).
        let x_before: Option<Vec<Vec<f32>>> = (self.cfg.metrics_every > 0
            && k % self.cfg.metrics_every == 0)
            .then(|| self.states.iter().map(|s| s.x.clone()).collect());
        // --- exchange + update phase ---
        if self.kind.time_varying() {
            self.rebuild_topology(self.cfg.nodes, k);
        }
        // Realize this step's faults (and async staleness ages) over
        // the nominal weights. An active fault plan makes the
        // *realized* mixing matrix time-varying even on static
        // topologies, and bounded staleness re-injects stale-direction
        // disagreement the same way — either engages the optimizers'
        // time-varying guards (DecentLaM's disagreement clip). An
        // all-fresh async schedule (uniform clocks / tau=0) engages
        // nothing, preserving bitwise equality with synchronous runs.
        // Cumulative fault counters BEFORE this step realizes, so the
        // stream can carry per-step deltas (only read when both a
        // stream and an engine exist).
        let fault_before = match (&self.telemetry, &self.faults) {
            (Some(_), Some(f)) => Some(*f.stats()),
            _ => None,
        };
        let faults_active = match &mut self.faults {
            Some(f) => {
                f.begin_step(k, &self.comm);
                f.active() || f.async_engaged()
            }
            None => false,
        };
        let comm: &dyn CommEngine = match &self.faults {
            Some(f) => f,
            None => &self.comm,
        };
        if let Some(c) = &self.codec {
            c.lock().unwrap().begin_step(k);
        }
        // This step's REALIZED wire traffic: the engine's post-mask
        // edge counts (satellite fix — a nominal snapshot × steps
        // overstates faulty/churned runs) at the configured payload
        // widths.
        let step_wire =
            wire_bytes_per_iter(self.optimizer.comm_pattern(), &CommStats::of_engine(comm), self.payload_bytes());
        let ctx = RoundCtx {
            comm,
            exec: self.update_exec.clone(),
            lr,
            beta: self.cfg.momentum as f32,
            step: k,
            // A membership resize makes the realized W time-varying
            // exactly like a fault mask does — once any resize has
            // happened the guard stays engaged (momentum still carries
            // pre-resize directions for a few rounds).
            time_varying: self.kind.time_varying() || faults_active || self.churned,
            layer_ranges: &self.workload.layer_ranges,
            codec: self.codec.as_ref(),
            clock: self.profiler.as_ref().map(|p| &p.clock),
        };
        let t_round = self.profiler.as_ref().map(|_| bench::WallTimer::start());
        self.optimizer.round(&mut self.states, &self.grads, &ctx, &mut self.scratch);
        let round_ns = t_round.map(|t| t.elapsed_ns()).unwrap_or(0);
        if let Some(f) = &mut self.faults {
            if f.needs_publish_cache() {
                // What went on the wire this round is next round's
                // stale payload for stragglers / stale links. With a
                // lossy codec that is the ENCODED payload (the codec's
                // wire view), not the raw publish buffer — a stale
                // replay re-delivers last round's compressed bytes.
                match &self.codec {
                    Some(c) => {
                        let state = c.lock().unwrap();
                        if state.is_identity() {
                            f.record_publish(&self.scratch.publish);
                        } else {
                            f.record_publish(state.wire());
                        }
                    }
                    None => f.record_publish(&self.scratch.publish),
                }
            }
        }
        self.wire_bytes_total += step_wire;
        self.wire_steps += 1;
        // Run-profile metrics (DESIGN.md §14): canonical reductions over
        // the post-round states, mixed through the NOMINAL weights (see
        // telemetry::metrics docs) — bitwise rerun-identical and
        // independent of `--threads`.
        let step_metrics = x_before.map(|xb| {
            crate::telemetry::metrics::collect(k, &xb, &self.states, &self.grads, &self.comm, lr)
        });
        if let Some(sink) = &self.telemetry {
            if let (Some(before), Some(f)) = (fault_before, &self.faults) {
                let now = *f.stats();
                let masked = now.masked_edges - before.masked_edges;
                let stale = now.stale_messages - before.stale_messages;
                let async_stale = now.async_stale_messages - before.async_stale_messages;
                let dropped = now.dropped_node_steps - before.dropped_node_steps;
                let straggled = now.straggler_node_steps - before.straggler_node_steps;
                // Only steps where something was actually realized make
                // a line; an all-quiet engine stays silent.
                if masked + stale + async_stale + dropped + straggled > 0 {
                    sink.emit(&Event::Fault {
                        step: k,
                        nominal_edges: now.nominal_edges - before.nominal_edges,
                        realized_edges: now.realized_edges - before.realized_edges,
                        masked_edges: masked,
                        stale_messages: stale,
                        async_stale_messages: async_stale,
                        dropped_node_steps: dropped,
                        straggler_node_steps: straggled,
                    });
                }
            }
            sink.emit(&Event::Step {
                step: k,
                loss,
                lr: lr as f64,
                consensus: self.consensus_distance(),
                wire_bytes: step_wire,
            });
            if let Some(m) = &step_metrics {
                sink.emit(&m.to_event());
            }
        }
        if let Some(m) = step_metrics {
            self.metrics_log.push(m);
        }
        if let Some(p) = &mut self.profiler {
            p.observe(grad_ns, round_ns);
            if p.due(k) {
                if let Some(sink) = &self.telemetry {
                    sink.emit(&p.to_event(k));
                }
            }
        }
        self.next_step = k + 1;
        loss
    }

    /// THE topology rebuild rule: realize the kind at `n` nodes for
    /// `step` and rebuild the CSR mixing weights in place (+ the lazy
    /// transform when configured). Every path that changes the
    /// realized graph — time-varying steps, churn resizes, snapshot
    /// restore — goes through this one helper so the rule can never
    /// fork between them.
    fn rebuild_topology(&mut self, n: usize, step: usize) {
        self.topo = Topology::at_step(self.kind, n, self.cfg.seed, step);
        self.comm.rebuild_metropolis(&self.topo);
        if self.cfg.positive_definite {
            self.comm.make_lazy();
        }
    }

    /// Realize one step's membership events (DESIGN.md §9): leavers'
    /// rows fold out of the mixing graph and the Metropolis–Hastings
    /// CSR is rebuilt in place at the new node count (symmetric doubly
    /// stochastic at every size, by construction); joiners warm-start
    /// from their neighbors' decoded wire average with momentum zeroed;
    /// every per-node resource (states, shard engines, codec residuals,
    /// fault streams) follows its stable id into the new dense order.
    fn apply_churn(&mut self, step: usize, ev: StepChurn) {
        let d = self.workload.dim;
        let el = self.elastic.as_mut().expect("churn event without elastic state");
        let old_active = el.roster.active().to_vec();
        el.roster.apply(&ev);
        el.stats.record(&ev);
        let new_active = el.roster.active().to_vec();
        let slot_order = el.roster.slot_order();
        let m = new_active.len();

        // Survivors keep their full state, keyed by stable id.
        let mut survivors: BTreeMap<u32, NodeState> = old_active
            .iter()
            .copied()
            .zip(std::mem::take(&mut self.states))
            .filter(|(id, _)| !ev.leaves.contains(id))
            .collect();

        // Live topology resize: the PR-1 in-place CSR rebuild extended
        // to a changing n. Static kinds are connected at every size;
        // the assert is defense in depth (the churn plan must never
        // realize a disconnected roster).
        self.rebuild_topology(m, step);
        assert!(self.topo.is_connected(), "realized churn topology disconnected at n={m}");
        self.topo_step = step;
        self.churned = true;

        // Joiner warm-start params: the average of the joiner's
        // non-joiner neighbors in the NEW topology, each payload read
        // through the wire codec when one is configured (exactly what
        // the joiner would receive over the wire). A neighborhood made
        // entirely of fellow joiners falls back to the survivor-wide
        // average — deterministic and order-free either way, because
        // only pre-existing nodes are ever read.
        let joiner_dense: Vec<bool> =
            new_active.iter().map(|id| ev.joins.contains(id)).collect();
        let mut warm: Vec<(usize, Vec<f32>)> = Vec::with_capacity(ev.joins.len());
        {
            let codec_guard = self.codec.as_ref().map(|c| c.lock().unwrap());
            let mut tmp = vec![0.0f32; d];
            let add = |acc: &mut Vec<f32>, src_id: u32, src: &[f32], tmp: &mut Vec<f32>| {
                match &codec_guard {
                    Some(state) => {
                        state.reconstruct(step, src_id, src, tmp);
                        math::axpy(acc, 1.0, tmp);
                    }
                    None => math::axpy(acc, 1.0, src),
                }
            };
            for (dj, &joins) in joiner_dense.iter().enumerate() {
                if !joins {
                    continue;
                }
                let mut acc = vec![0.0f32; d];
                let mut count = 0usize;
                for &p in self.topo.neighbors(dj) {
                    if joiner_dense[p] {
                        continue;
                    }
                    let nid = new_active[p];
                    add(&mut acc, nid, &survivors[&nid].x, &mut tmp);
                    count += 1;
                }
                if count == 0 {
                    for (&nid, st) in survivors.iter() {
                        add(&mut acc, nid, &st.x, &mut tmp);
                        count += 1;
                    }
                }
                math::scale(&mut acc, 1.0 / count as f32);
                warm.push((dj, acc));
            }
        }

        // Rebuild the dense state vector: survivors in order, joiners
        // from their warm-started params with optimizer buffers
        // initialized by the optimizer's own rule.
        let mut warm = warm.into_iter();
        let mut new_states = Vec::with_capacity(m);
        for (dj, &id) in new_active.iter().enumerate() {
            if joiner_dense[dj] {
                let (wdj, x) = warm.next().expect("warm-start entry missing");
                debug_assert_eq!(wdj, dj);
                let mut st = NodeState::new(x, self.optimizer.aux_count());
                self.optimizer.warm_start(&mut st);
                new_states.push(st);
            } else {
                new_states.push(survivors.remove(&id).expect("survivor state missing"));
            }
        }
        self.states = new_states;

        // Per-node buffers follow the roster size; contents are
        // per-round transient.
        self.grads.resize_with(m, || vec![0.0; d]);
        self.losses.resize(m, 0.0);
        self.scratch.resize(m, d);

        // Per-stable-id resources repack into the new dense order.
        self.reorder_engines(&slot_order);
        if let Some(c) = &self.codec {
            c.lock().unwrap().set_roster(&new_active);
        }
        if let Some(f) = &mut self.faults {
            f.set_ids(Some(new_active));
            // Per-dense-row history is invalid across a resize: the
            // first post-resize round serves fresh messages while the
            // publish cache re-warms (same rule as the cold start).
            f.clear_cache();
        }
    }

    /// Permute `workload.nodes` so slots hold `target` stable ids in
    /// order (active dense order first, parked tail after) — O(capacity)
    /// pointer moves, no shard data is copied.
    fn reorder_engines(&mut self, target: &[u32]) {
        debug_assert_eq!(target.len(), self.engine_ids.len());
        if self.engine_ids == target {
            return;
        }
        let capacity = self.engine_ids.len();
        let mut by_id = vec![usize::MAX; capacity];
        for (slot, &id) in self.engine_ids.iter().enumerate() {
            by_id[id as usize] = slot;
        }
        let mut slots: Vec<Option<Box<dyn NodeGrad>>> =
            std::mem::take(&mut self.workload.nodes).into_iter().map(Some).collect();
        self.workload.nodes = target
            .iter()
            .map(|&id| slots[by_id[id as usize]].take().expect("engine slot reused"))
            .collect();
        self.engine_ids = target.to_vec();
    }

    /// Current active node count (elastic rosters move mid-run).
    pub fn active_nodes(&self) -> usize {
        self.states.len()
    }

    /// Active stable ids in dense order (identity 0..n on a fixed
    /// roster).
    pub fn active_ids(&self) -> Vec<u32> {
        match &self.elastic {
            Some(el) => el.roster.active().to_vec(),
            None => (0..self.cfg.nodes as u32).collect(),
        }
    }

    /// Cumulative membership accounting (None = fixed roster).
    pub fn churn_stats(&self) -> Option<&ChurnStats> {
        self.elastic.as_ref().map(|el| &el.stats)
    }

    /// Run manifest (compact JSON): the canonical
    /// [`Config::to_manifest`] form plus run-derived identity, so an
    /// experiment artifact alone suffices to replay the run — feed the
    /// `config` object back through `--config` / `Config::load`. Also
    /// embedded in every [`TrainReport`] and pinned (by sha256) in
    /// scenario manifests.
    pub fn manifest_json(&self) -> String {
        Value::obj(vec![
            ("version", Value::Str(crate::scenario::MANIFEST_VERSION.to_string())),
            ("config", self.cfg.to_manifest()),
            (
                "run",
                Value::obj(vec![
                    ("active_nodes", Value::Num(self.states.len() as f64)),
                    ("dim", Value::Num(self.workload.dim as f64)),
                    ("model", Value::Str(self.workload.name.clone())),
                ]),
            ),
        ])
        .to_string()
    }

    /// Canonical fingerprint of every trajectory-determining hyper
    /// parameter. Part of [`SnapshotMeta`]: resuming under a different
    /// lr / momentum / schedule / batch shape / lazy-W / SlowMo config
    /// would silently diverge from the uninterrupted run, so restore
    /// refuses on any mismatch here.
    fn hyper_fingerprint(&self) -> String {
        let c = &self.cfg;
        format!(
            "lr={};momentum={};schedule={:?};linear_scaling={};lr_ref_batch={};\
             max_lr_scale={};total_batch={};micro_batch={};steps={};\
             positive_definite={};slowmo={}x{};alpha={}",
            c.lr,
            c.momentum,
            c.schedule,
            c.linear_scaling,
            c.lr_ref_batch,
            c.max_lr_scale,
            c.total_batch,
            c.micro_batch,
            c.steps,
            c.positive_definite,
            c.slowmo_period,
            c.slowmo_beta,
            c.dirichlet_alpha
        )
    }

    fn snapshot_meta(&self) -> SnapshotMeta {
        let capacity = match &self.elastic {
            Some(el) => el.roster.capacity(),
            None => self.cfg.nodes,
        };
        SnapshotMeta {
            optimizer: self.cfg.optimizer.clone(),
            topology: self.cfg.topology.clone(),
            // Snapshot meta stores the canonical spec STRINGS: the
            // binary format predates the typed specs, and both the
            // saving and restoring trainer derive them from the same
            // parsed Config, so canonicalization cannot desync them.
            codec: self.cfg.codec.as_ref().map(|s| s.to_spec_string()).unwrap_or_default(),
            faults: self.cfg.faults.as_ref().map(|s| s.to_spec_string()).unwrap_or_default(),
            async_mode: self.cfg.async_mode.as_ref().map(|s| s.to_spec_string()).unwrap_or_default(),
            churn: self.cfg.churn.as_ref().map(|s| s.to_spec_string()).unwrap_or_default(),
            seed: self.cfg.seed,
            nodes: self.cfg.nodes as u32,
            capacity: capacity as u32,
            dim: self.workload.dim as u32,
            model: self.workload.name.clone(),
            aux_labels: self.optimizer.aux_labels().join(","),
            hyper: self.hyper_fingerprint(),
        }
    }

    /// Capture the complete cross-step mutable state (DESIGN.md §9):
    /// restoring the snapshot into a freshly constructed trainer of the
    /// same configuration and continuing is bitwise identical to never
    /// having stopped.
    pub fn checkpoint(&self) -> Snapshot {
        let meta = self.snapshot_meta();
        let capacity = meta.capacity as usize;
        let (active, churn_stats) = match &self.elastic {
            Some(el) => (el.roster.active().to_vec(), el.stats),
            None => ((0..self.cfg.nodes as u32).collect(), ChurnStats::default()),
        };
        let mut cursors: Vec<Option<ShardCursor>> = vec![None; capacity];
        for (slot, &id) in self.engine_ids.iter().enumerate() {
            cursors[id as usize] = self.workload.nodes[slot].export_cursor();
        }
        let codec_residuals =
            self.codec.as_ref().map(|c| c.lock().unwrap().export_residuals());
        let faults = self.faults.as_ref().map(|f| FaultState {
            cache: f.export_cache(),
            stats: *f.stats(),
            rings: f.export_rings(),
        });
        Snapshot {
            meta,
            step: self.next_step as u64,
            churned: self.churned,
            topo_step: self.topo_step as u64,
            churn_stats,
            active,
            states: self.states.clone(),
            cursors,
            codec_residuals,
            faults,
        }
    }

    /// [`Trainer::checkpoint`] straight to a checksummed file.
    pub fn checkpoint_to(&self, path: &Path) -> Result<()> {
        self.checkpoint().write_file(path)?;
        if let Some(sink) = &self.telemetry {
            sink.emit(&Event::Checkpoint { step: self.next_step });
            // A checkpoint marks a resumable cut; leave the stream
            // durable up to the same cut.
            sink.flush();
        }
        Ok(())
    }

    /// Restore a snapshot into this (freshly constructed) trainer.
    /// Refuses on any configuration mismatch — a checkpoint is only
    /// bitwise-resumable into the exact run that wrote it.
    pub fn restore(&mut self, snap: &Snapshot) -> Result<()> {
        let meta = self.snapshot_meta();
        anyhow::ensure!(
            snap.meta == meta,
            "snapshot belongs to a different run\n  snapshot: {:?}\n  this run: {:?}",
            snap.meta,
            meta
        );
        anyhow::ensure!(
            snap.step as usize <= self.cfg.steps,
            "snapshot is at step {} but the schedule has only {} steps",
            snap.step,
            self.cfg.steps
        );
        let capacity = meta.capacity as usize;
        let d = self.workload.dim;
        let m = snap.active.len();
        anyhow::ensure!(
            snap.states.len() == m,
            "snapshot holds {} states for {m} active nodes",
            snap.states.len()
        );
        for st in &snap.states {
            anyhow::ensure!(
                st.x.len() == d && st.m.len() == d,
                "snapshot state dim {} != run dim {d}",
                st.x.len()
            );
            anyhow::ensure!(
                st.aux.len() == self.optimizer.aux_count()
                    && st.aux.iter().all(|a| a.len() == d),
                "snapshot aux layout does not match `{}`",
                self.cfg.optimizer
            );
        }
        anyhow::ensure!(
            snap.cursors.len() == capacity,
            "snapshot has {} shard cursors for capacity {capacity}",
            snap.cursors.len()
        );
        // Roster + topology at the restored size.
        match &mut self.elastic {
            Some(el) => {
                el.roster = Roster::from_active(snap.active.clone(), capacity)?;
                el.stats = snap.churn_stats;
            }
            None => anyhow::ensure!(
                snap.active.len() == self.cfg.nodes
                    && snap.active.iter().enumerate().all(|(i, &id)| id as usize == i),
                "fixed-roster run cannot restore a churned roster"
            ),
        }
        if self.elastic.is_some() {
            self.topo_step = snap.topo_step as usize;
            self.rebuild_topology(m, self.topo_step);
        }
        self.states = snap.states.clone();
        self.next_step = snap.step as usize;
        self.churned = snap.churned;
        self.grads.resize_with(m, || vec![0.0; d]);
        self.losses.resize(m, 0.0);
        self.scratch.resize(m, d);
        // Engines into dense order, then cursors by stable id. Presence
        // must agree: a stateful engine with no snapshot cursor (or
        // vice versa) would silently drift off the batch sequence.
        let slot_order: Vec<u32> = match &self.elastic {
            Some(el) => el.roster.slot_order(),
            None => (0..capacity as u32).collect(),
        };
        self.reorder_engines(&slot_order);
        for (slot, &id) in self.engine_ids.iter().enumerate() {
            let engine_stateful = self.workload.nodes[slot].export_cursor().is_some();
            match &snap.cursors[id as usize] {
                Some(c) => {
                    anyhow::ensure!(
                        engine_stateful,
                        "snapshot has a cursor for stateless engine {id}"
                    );
                    self.workload.nodes[slot].restore_cursor(c)?;
                }
                None => anyhow::ensure!(
                    !engine_stateful,
                    "snapshot lacks the cursor for stateful engine {id}"
                ),
            }
        }
        // Codec + fault engine state.
        match (&self.codec, &snap.codec_residuals) {
            (Some(c), Some(res)) => {
                let mut state = c.lock().unwrap();
                if self.elastic.is_some() {
                    // Resize-only repoint: the snapshot supplies the
                    // residuals wholesale, so no carry-over remap.
                    state.reset_roster(&snap.active);
                }
                state.restore_residuals(res.clone())?;
            }
            (None, None) => {}
            _ => anyhow::bail!("snapshot codec state does not match the run's codec config"),
        }
        match (&mut self.faults, &snap.faults) {
            (Some(f), Some(fs)) => {
                if self.elastic.is_some() {
                    f.set_ids(Some(snap.active.clone()));
                }
                f.restore_cache(fs.cache.clone());
                f.restore_stats(fs.stats);
                f.restore_rings(fs.rings.clone());
            }
            (None, None) => {}
            _ => anyhow::bail!("snapshot fault state does not match the run's fault config"),
        }
        Ok(())
    }

    /// Construct a trainer and restore a snapshot into it in one call —
    /// the resume entry point. `cfg` and `workload` must be built
    /// exactly as for the run that wrote the snapshot.
    pub fn resume(cfg: Config, workload: Workload, snap: &Snapshot) -> Result<Trainer> {
        let mut t = Trainer::new(cfg, workload)?;
        t.restore(snap)?;
        Ok(t)
    }

    /// Per-payload wire widths of this run: codec-encoded gossip
    /// payloads, raw fp32 all-reduce legs (for the cost model).
    pub fn payload_bytes(&self) -> PayloadBytes {
        let d = self.workload.dim;
        match &self.codec {
            Some(c) => PayloadBytes::compressed(c.lock().unwrap().payload_bytes(), d),
            None => PayloadBytes::fp32(d),
        }
    }

    /// Name of the configured payload codec (None = raw fp32 path).
    pub fn codec_name(&self) -> Option<&'static str> {
        self.codec.as_ref().map(|c| c.lock().unwrap().name())
    }

    /// Communication pattern of the configured optimizer (for the cost
    /// model).
    pub fn comm_pattern(&self) -> optim::CommPattern {
        self.optimizer.comm_pattern()
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Cumulative fault accounting (None when running fault-free, or
    /// when the optimizer's all-reduce traffic bypasses the fault
    /// model entirely).
    pub fn fault_stats(&self) -> Option<&FaultStats> {
        self.faults.as_ref().map(|f| f.stats())
    }

    /// Timing + staleness summary of the `--async` discrete-event run
    /// (None in synchronous mode). `step_done_s[k]` is the simulated
    /// wall second at which every node has completed step k — the
    /// x-axis of time-to-target-loss plots.
    pub fn async_report(&self) -> Option<&AsyncReport> {
        self.async_report.as_ref()
    }

    /// Cumulative REALIZED wire bytes over the steps this trainer has
    /// executed (per-step post-mask edge counts × payload widths).
    pub fn wire_bytes_total(&self) -> f64 {
        self.wire_bytes_total
    }

    /// Mean realized wire bytes per executed step (0 before any step).
    pub fn wire_bytes_per_iter(&self) -> f64 {
        if self.wire_steps == 0 {
            0.0
        } else {
            self.wire_bytes_total / self.wire_steps as f64
        }
    }

    /// First telemetry IO error, if the stream went inert mid-run
    /// (None = no stream, or a healthy one).
    pub fn telemetry_error(&self) -> Option<String> {
        self.telemetry.as_ref().and_then(|s| s.error())
    }

    /// Run-profile metrics collected at the `--metrics every=K` cadence,
    /// in step order (empty when metrics are off). Exactly what the
    /// stream's `metrics` lines carry — the large-batch sweep gates
    /// pin live-vs-replayed equality on this.
    pub fn metrics_log(&self) -> &[StepMetrics] {
        &self.metrics_log
    }

    /// Run the full schedule (or, after [`Trainer::restore`], the
    /// remaining steps), reporting losses/evals.
    pub fn run(&mut self) -> TrainReport {
        let mut report = TrainReport {
            steps: self.cfg.steps,
            manifest: self.manifest_json(),
            ..Default::default()
        };
        // Wall time is observability-only (rule D02): it flows into the
        // report's grad/update_seconds and nowhere else — never the
        // manifest, digests, or the telemetry stream, which all replay
        // bitwise (pinned by rust/tests/determinism.rs).
        let mut grad_s = 0.0;
        let mut upd_s = 0.0;
        for k in self.next_step..self.cfg.steps {
            let t0 = bench::WallTimer::start();
            let loss = self.step(k);
            let dt = t0.elapsed_s();
            // step() mixes both phases; attribute by re-measuring would
            // double work. Track total and split via a dedicated probe in
            // the benches; here we record total into grad_seconds.
            grad_s += dt;
            report.losses.push(loss);
            if self.cfg.eval_every > 0 && (k + 1) % self.cfg.eval_every == 0 {
                let t1 = bench::WallTimer::start();
                let xbar = self.average_model();
                let acc = self.workload.eval.accuracy(&xbar);
                let accuracy = acc.is_finite().then_some(acc);
                let eval_loss = self.workload.eval.loss(&xbar);
                if let Some(a) = accuracy {
                    report.evals.push((k + 1, a));
                }
                if let Some(el) = eval_loss {
                    report.eval_losses.push((k + 1, el));
                }
                // Stream exactly what the report records — an eval
                // producing neither signal makes no line.
                if accuracy.is_some() || eval_loss.is_some() {
                    if let Some(sink) = &self.telemetry {
                        sink.emit(&Event::Eval { step: k + 1, accuracy, eval_loss });
                    }
                }
                upd_s += t1.elapsed_s();
            }
        }
        let xbar = self.average_model();
        report.final_accuracy = self.workload.eval.accuracy(&xbar);
        report.final_consensus = self.consensus_distance();
        report.grad_seconds = grad_s;
        report.update_seconds = upd_s;
        report.wire_bytes_total = self.wire_bytes_total;
        report.wire_bytes_per_iter = self.wire_bytes_per_iter();
        if let Some(sink) = &self.telemetry {
            sink.emit(&Event::RunEnd {
                steps: report.steps,
                final_accuracy: report.final_accuracy,
                final_consensus: report.final_consensus,
                wire_bytes_total: self.wire_bytes_total,
            });
            sink.flush();
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::engine::CommEngine;
    use crate::data::synth::{ClassificationData, SynthSpec};
    use crate::data::LinRegProblem;
    use crate::grad::{linreg, mlp};
    use crate::util::config::LrSchedule;

    fn small_cfg(optimizer: &str, steps: usize) -> Config {
        let mut cfg = Config::default();
        cfg.optimizer = optimizer.into();
        cfg.nodes = 4;
        cfg.steps = steps;
        cfg.total_batch = 128;
        cfg.micro_batch = 32;
        cfg.lr = 0.05;
        cfg.linear_scaling = false;
        cfg.schedule = LrSchedule::Constant;
        cfg.topology = "ring".into();
        cfg
    }

    fn mlp_workload(nodes: usize) -> Workload {
        let spec = SynthSpec {
            nodes,
            samples_per_node: 256,
            eval_samples: 256,
            dirichlet_alpha: 1.0,
            ..Default::default()
        };
        let data = ClassificationData::generate(&spec);
        mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1)
    }

    #[test]
    fn decentlam_trains_mlp_above_chance() {
        let cfg = small_cfg("decentlam", 120);
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let report = t.run();
        assert!(report.losses[0] > report.losses.last().unwrap() * 1.5);
        assert!(report.final_accuracy > 0.4, "acc={}", report.final_accuracy);
    }

    #[test]
    fn all_optimizers_run_and_descend() {
        for name in crate::optim::ALL {
            let mut cfg = small_cfg(name, 40);
            cfg.lr = 0.02;
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let report = t.run();
            let first = report.losses[..5].iter().sum::<f64>() / 5.0;
            let last = report.losses[report.losses.len() - 5..].iter().sum::<f64>() / 5.0;
            assert!(
                last < first,
                "{name}: loss did not descend ({first} -> {last})"
            );
            assert!(report.losses.iter().all(|l| l.is_finite()), "{name} diverged");
        }
    }

    #[test]
    fn linreg_consensus_shrinks_under_training() {
        let p = LinRegProblem::generate(4, 30, 10, 3);
        let mut cfg = small_cfg("decentlam", 400);
        cfg.lr = 0.005;
        cfg.momentum = 0.8;
        let mut t = Trainer::new(cfg, linreg::workload(p)).unwrap();
        let report = t.run();
        assert!(report.final_consensus < 1e-2, "consensus={}", report.final_consensus);
        assert!(report.final_accuracy > -0.05, "rel err={}", -report.final_accuracy);
    }

    #[test]
    fn time_varying_topology_trains() {
        let mut cfg = small_cfg("decentlam", 60);
        cfg.topology = "bipartite".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let report = t.run();
        assert!(report.losses.iter().all(|l| l.is_finite()));
        assert!(report.losses[0] > *report.losses.last().unwrap());
    }

    #[test]
    fn time_varying_topology_rebuilds_neighbor_lists() {
        let mut cfg = small_cfg("dsgd", 3);
        cfg.topology = "bipartite".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let mut partners = Vec::new();
        for k in 0..3 {
            t.step(k);
            // Sparse engine must mirror the step-k realization exactly.
            let topo = t.topology();
            for i in 0..4 {
                assert_eq!(
                    t.comm.row(i).len(),
                    topo.neighbors(i).len() + 1,
                    "step {k} node {i}"
                );
            }
            partners.push(topo.neighbors(0).to_vec());
        }
        assert!(
            partners.iter().any(|p| p != &partners[0]),
            "bipartite match never changed partner"
        );
    }

    #[test]
    fn threaded_and_sequential_phases_agree() {
        let mk = |threads: usize| {
            let mut cfg = small_cfg("dmsgd", 10);
            cfg.threads = threads;
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            t.run().losses
        };
        let seq = mk(1);
        let par = mk(0);
        for (a, b) in seq.iter().zip(&par) {
            assert!((a - b).abs() < 1e-9, "threading changed results: {a} vs {b}");
        }
    }

    #[test]
    fn faulty_run_descends_and_replays_identically() {
        let mk = || {
            let mut cfg = small_cfg("decentlam", 40);
            cfg.lr = 0.02;
            cfg.apply_kv("faults", "drop=0.15,straggle=0.1,seed=5").unwrap();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let stats = *t.fault_stats().unwrap();
            (losses, stats)
        };
        let (a, stats) = mk();
        let (b, stats_b) = mk();
        assert_eq!(a, b, "fault schedule must replay bit-identically");
        assert_eq!(stats, stats_b);
        assert!(a.iter().all(|l| l.is_finite()));
        let first = a[..5].iter().sum::<f64>() / 5.0;
        let last = a[a.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(last < first, "loss did not descend under faults ({first} -> {last})");
        assert_eq!(stats.steps, 40);
        assert!(stats.masked_edges > 0, "drop=0.15 never masked an edge");
        assert!(stats.stale_messages > 0, "straggle=0.1 never went stale");
        assert!(stats.realized_edges < stats.nominal_edges);
    }

    #[test]
    fn zero_rate_faults_bitwise_match_fault_free_run() {
        let run = |faults: &str| {
            let mut cfg = small_cfg("dmsgd", 25);
            cfg.apply_kv("faults", faults).unwrap();
            Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
        };
        assert_eq!(run(""), run("drop=0,link=0,seed=99"));
    }

    #[test]
    fn faults_compose_with_time_varying_topologies() {
        let mut cfg = small_cfg("decentlam", 30);
        cfg.topology = "one-peer-exp".into();
        cfg.apply_kv("faults", "drop=0.2,link=0.1,seed=2").unwrap();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let r = t.run();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        let stats = t.fault_stats().unwrap();
        assert_eq!(stats.steps, 30);
        assert!(stats.realized_edges < stats.nominal_edges);
    }

    #[test]
    fn allreduce_optimizer_ignores_fault_spec_honestly() {
        // pmsgd never touches the comm engine; a fault spec must not
        // attach an engine that would report phantom fault traffic.
        let mut cfg = small_cfg("pmsgd", 10);
        cfg.apply_kv("faults", "drop=0.5,seed=4").unwrap();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let r = t.run();
        assert!(t.fault_stats().is_none());
        assert!(r.losses.iter().all(|l| l.is_finite()));
        // Still validated: a malformed spec fails even for pmsgd — at
        // the config boundary now, before a trainer is ever built.
        let mut bad = small_cfg("pmsgd", 5);
        assert!(bad.apply_kv("faults", "drop=2").is_err());
    }

    #[test]
    fn multi_payload_optimizer_masks_stragglers_instead_of_staling() {
        // da-dmsgd publishes two quantities per round; a single stale
        // cache cannot replay both, so its straggle faults must fall
        // back to edge masking (no stale deliveries, edges lost).
        let mut cfg = small_cfg("da-dmsgd", 20);
        cfg.apply_kv("faults", "straggle=0.4,seed=8").unwrap();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let r = t.run();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        let stats = t.fault_stats().unwrap();
        assert_eq!(stats.stale_messages, 0, "multi-payload round must not stale");
        assert!(stats.masked_edges > 0, "stragglers should mask exchanges");
    }

    #[test]
    fn fp32_codec_is_bitwise_identical_to_no_codec() {
        let run = |codec: &str| {
            let mut cfg = small_cfg("dmsgd", 25);
            cfg.apply_kv("codec", codec).unwrap();
            Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
        };
        assert_eq!(run(""), run("fp32"), "identity codec must not change a single bit");
    }

    #[test]
    fn lossy_codecs_train_and_replay_identically() {
        for codec in ["fp16", "int8,ef=true,seed=5", "topk,k=0.25"] {
            let run = || {
                let mut cfg = small_cfg("decentlam", 40);
                cfg.lr = 0.02;
                cfg.apply_kv("codec", codec).unwrap();
                Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "{codec}: rerun must be byte-identical");
            assert!(a.iter().all(|l| l.is_finite()), "{codec} diverged");
            let first = a[..5].iter().sum::<f64>() / 5.0;
            let last = a[a.len() - 5..].iter().sum::<f64>() / 5.0;
            assert!(last < first, "{codec}: loss did not descend ({first} -> {last})");
        }
    }

    #[test]
    fn codec_threaded_and_serial_runs_agree() {
        let mk = |threads: usize| {
            let mut cfg = small_cfg("dmsgd", 10);
            cfg.threads = threads;
            cfg.apply_kv("codec", "int8,seed=3").unwrap();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            t.run().losses
        };
        let seq = mk(1);
        let par = mk(0);
        assert_eq!(seq, par, "codec must keep parallel == serial bitwise");
    }

    #[test]
    fn codec_composes_with_faults_and_stales_encoded_payloads() {
        // Straggle + int8: the stale cache holds the codec's wire view,
        // and the run stays deterministic and finite.
        let run = || {
            let mut cfg = small_cfg("decentlam", 30);
            cfg.lr = 0.02;
            cfg.apply_kv("codec", "int8,ef=true,seed=4").unwrap();
            cfg.apply_kv("faults", "straggle=0.3,seed=6").unwrap();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let stats = *t.fault_stats().unwrap();
            (losses, stats)
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(sa.stale_messages > 0, "straggle=0.3 never went stale");
    }

    #[test]
    fn multi_payload_optimizer_gets_per_slot_codec_residuals() {
        // da-dmsgd runs two compressed exchanges per round (momentum
        // then parameters); the per-slot EF residuals keep them apart
        // and the run must stay finite + deterministic.
        let run = || {
            let mut cfg = small_cfg("da-dmsgd", 25);
            cfg.lr = 0.02;
            cfg.apply_kv("codec", "int8,ef=true,seed=2").unwrap();
            Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
        };
        let a = run();
        assert_eq!(a, run());
        assert!(a.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn payload_bytes_reflects_codec() {
        let d_of = |t: &Trainer| t.workload.dim;
        let mk = |codec: &str| {
            let mut cfg = small_cfg("decentlam", 1);
            cfg.apply_kv("codec", codec).unwrap();
            Trainer::new(cfg, mlp_workload(4)).unwrap()
        };
        let raw = mk("");
        let d = d_of(&raw);
        assert_eq!(raw.payload_bytes().neighbor, 4.0 * d as f64);
        assert_eq!(raw.codec_name(), None);
        let int8 = mk("int8");
        assert_eq!(int8.payload_bytes().neighbor, d as f64 + 4.0);
        assert_eq!(int8.payload_bytes().allreduce, 4.0 * d as f64);
        assert_eq!(int8.codec_name(), Some("int8"));
        let ratio = raw.payload_bytes().neighbor / int8.payload_bytes().neighbor;
        assert!(ratio >= 3.9, "int8 byte cut {ratio} < 3.9x at d={d}");
    }

    #[test]
    fn allreduce_optimizer_ignores_codec_honestly() {
        // pmsgd never touches the gossip wire; a codec spec must not
        // attach state that would report a compression that never
        // happens — mirrors the fault-engine rule.
        let mut cfg = small_cfg("pmsgd", 5);
        cfg.apply_kv("codec", "int8").unwrap();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let d = t.workload.dim;
        assert_eq!(t.codec_name(), None);
        assert_eq!(t.payload_bytes().neighbor, 4.0 * d as f64);
        let r = t.run();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        // Still validated: a malformed spec fails even for pmsgd — at
        // the config boundary now, before a trainer is ever built.
        let mut bad = small_cfg("pmsgd", 5);
        assert!(bad.apply_kv("codec", "int8,k=0.5").is_err());
    }

    #[test]
    fn async_uniform_tau0_is_bitwise_synchronous() {
        // The tentpole invariant: uniform speeds + zero jitter + tau=0
        // must reproduce the synchronous trainer losses bit for bit
        // (star included — irregular degrees desynchronize gather
        // times, but version capping keeps every delivery exact).
        for topology in ["ring", "star"] {
            for opt in ["dmsgd", "decentlam"] {
                let run = |asynch: &str| {
                    let mut cfg = small_cfg(opt, 25);
                    cfg.topology = topology.into();
                    cfg.apply_kv("async", asynch).unwrap();
                    Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
                };
                assert_eq!(
                    run(""),
                    run("tau=0,spread=1,jitter=0"),
                    "{opt} on {topology}: async(uniform, tau=0) must be bitwise synchronous"
                );
            }
        }
    }

    #[test]
    fn async_heterogeneous_run_is_deterministic_and_stale() {
        let run = |threads: usize| {
            let mut cfg = small_cfg("decentlam", 40);
            cfg.lr = 0.02;
            cfg.threads = threads;
            cfg.apply_kv("async", "tau=2,spread=6,jitter=0.3,seed=9").unwrap();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let report = t.async_report().unwrap().clone();
            (losses, report)
        };
        let (a, ra) = run(0);
        let (b, rb) = run(0);
        assert_eq!(a, b, "async rerun must be byte-identical");
        assert_eq!(ra, rb);
        let (c, _) = run(1);
        assert_eq!(a, c, "async parallel != serial");
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(ra.max_staleness >= 1, "spread=6 never delivered stale");
        assert!(ra.mean_staleness > 0.0 && ra.max_staleness <= 2);
        assert_eq!(ra.step_done_s.len(), 40);
        assert!(ra.makespan_s > 0.0);
        let first = a[..5].iter().sum::<f64>() / 5.0;
        let last = a[a.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(last < first, "loss did not descend under staleness ({first} -> {last})");
    }

    #[test]
    fn async_composes_with_faults_and_codec() {
        let run = || {
            let mut cfg = small_cfg("decentlam", 30);
            cfg.lr = 0.02;
            cfg.apply_kv("async", "tau=2,spread=4,jitter=0.2,seed=3").unwrap();
            cfg.apply_kv("faults", "drop=0.1,straggle=0.2,seed=5").unwrap();
            cfg.apply_kv("codec", "int8,ef=true,seed=4").unwrap();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let stats = *t.fault_stats().unwrap();
            (losses, stats)
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(sa.masked_edges > 0, "drop=0.1 never masked");
    }

    #[test]
    fn async_multi_payload_optimizer_staleness_is_faithful() {
        // da-dmsgd exchanges two payload kinds per round; the per-slot
        // ring caches replay each kind's own history, so async staleness
        // needs no masking downgrade.
        let run = |threads: usize| {
            let mut cfg = small_cfg("da-dmsgd", 30);
            cfg.lr = 0.02;
            cfg.threads = threads;
            cfg.apply_kv("async", "tau=2,spread=6,jitter=0.3,seed=11").unwrap();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let stats = *t.fault_stats().unwrap();
            (losses, stats)
        };
        let (a, sa) = run(0);
        assert_eq!(a, run(0).0, "rerun must be byte-identical");
        assert_eq!(a, run(1).0, "parallel != serial");
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(sa.async_stale_messages > 0, "spread=6 never delivered stale");
        assert_eq!(sa.masked_edges, 0, "async staleness must not mask edges");
    }

    #[test]
    fn async_allreduce_baseline_reports_barrier_time_only() {
        let mut cfg = small_cfg("pmsgd", 10);
        cfg.apply_kv("async", "tau=2,spread=4,jitter=0.2").unwrap();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let r = t.run();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert!(t.fault_stats().is_none(), "pmsgd must not grow a fault engine");
        let rep = t.async_report().unwrap();
        assert_eq!(rep.step_done_s.len(), 10);
        assert_eq!(rep.max_staleness, 0, "all-reduce is a barrier: nothing stales");
        assert!(rep.total_wait_s > 0.0, "a 4x spread barrier must wait");
        assert!(rep.makespan_s > 0.0);
    }

    #[test]
    fn async_rejects_time_varying_topologies_and_slowmo() {
        let mut cfg = small_cfg("decentlam", 5);
        cfg.topology = "bipartite".into();
        cfg.apply_kv("async", "tau=1").unwrap();
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
        let mut cfg = small_cfg("slowmo", 5);
        cfg.apply_kv("async", "tau=1").unwrap();
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
        let mut bad = small_cfg("decentlam", 5);
        assert!(bad.apply_kv("async", "tau=999").is_err());
    }

    #[test]
    fn bad_codec_spec_rejected_at_config_boundary() {
        let mut cfg = small_cfg("dsgd", 5);
        assert!(cfg.apply_kv("codec", "zfp").is_err());
    }

    #[test]
    fn bad_fault_spec_rejected_at_config_boundary() {
        let mut cfg = small_cfg("dsgd", 5);
        assert!(cfg.apply_kv("faults", "drop=7").is_err());
    }

    #[test]
    fn node_count_mismatch_rejected() {
        let mut cfg = small_cfg("dmsgd", 5);
        cfg.nodes = 6;
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
    }

    #[test]
    fn zero_churn_is_bitwise_identical_to_fixed_roster() {
        let run = |churn: &str| {
            let mut cfg = small_cfg("decentlam", 25);
            cfg.apply_kv("churn", churn).unwrap();
            Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
        };
        assert_eq!(
            run(""),
            run("join=0,leave=0,nmin=4,nmax=4,seed=9"),
            "a zero-rate churn plan must not change a single bit"
        );
    }

    #[test]
    fn churn_resizes_roster_and_stays_deterministic() {
        let run = |threads: usize| {
            let mut cfg = small_cfg("decentlam", 50);
            cfg.lr = 0.02;
            cfg.threads = threads;
            cfg.apply_kv("churn", "join=0.15,leave=0.15,nmin=2,nmax=6,seed=3").unwrap();
            let mut t = Trainer::new(cfg, mlp_workload(6)).unwrap();
            let losses = t.run().losses;
            let stats = *t.churn_stats().unwrap();
            let ids = t.active_ids();
            (losses, stats, ids)
        };
        let (a, sa, ids_a) = run(0);
        let (b, sb, ids_b) = run(0);
        assert_eq!(a, b, "churn rerun must be byte-identical");
        assert_eq!(sa, sb);
        assert_eq!(ids_a, ids_b);
        let (c, _, _) = run(1);
        assert_eq!(a, c, "churn parallel != serial");
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(sa.joins > 0 && sa.leaves > 0, "rates 0.15 never realized events: {sa:?}");
        assert!((2..=6).contains(&ids_a.len()), "roster size {} out of bounds", ids_a.len());
    }

    #[test]
    fn churn_composes_with_faults_and_codec() {
        let run = || {
            let mut cfg = small_cfg("decentlam", 40);
            cfg.lr = 0.02;
            cfg.apply_kv("churn", "join=0.1,leave=0.1,nmin=2,nmax=6,seed=5").unwrap();
            cfg.apply_kv("faults", "drop=0.1,straggle=0.1,seed=7").unwrap();
            cfg.apply_kv("codec", "int8,ef=true,seed=4").unwrap();
            let mut t = Trainer::new(cfg, mlp_workload(6)).unwrap();
            let losses = t.run().losses;
            (losses, *t.fault_stats().unwrap(), *t.churn_stats().unwrap())
        };
        let (a, fa, ca) = run();
        let (b, fb, cb) = run();
        assert_eq!(a, b);
        assert_eq!(fa, fb);
        assert_eq!(ca, cb);
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(ca.resizes > 0, "no resize ever happened");
    }

    #[test]
    fn churn_rejects_time_varying_async_and_bad_capacity() {
        let mut cfg = small_cfg("decentlam", 5);
        cfg.topology = "bipartite".into();
        cfg.apply_kv("churn", "join=0.1,nmax=6").unwrap();
        assert!(Trainer::new(cfg, mlp_workload(6)).is_err(), "time-varying must be rejected");
        let mut cfg = small_cfg("decentlam", 5);
        cfg.apply_kv("churn", "join=0.1,nmax=6").unwrap();
        cfg.apply_kv("async", "tau=1").unwrap();
        assert!(Trainer::new(cfg, mlp_workload(6)).is_err(), "async must be rejected");
        let mut cfg = small_cfg("decentlam", 5);
        cfg.apply_kv("churn", "join=0.1,nmax=6").unwrap();
        assert!(
            Trainer::new(cfg, mlp_workload(4)).is_err(),
            "workload must supply nmax shards"
        );
    }

    #[test]
    fn checkpoint_resume_is_bitwise_mid_run() {
        let mut cfg = small_cfg("decentlam", 12);
        cfg.apply_kv("churn", "join=0.2,leave=0.2,nmin=2,nmax=6,seed=8").unwrap();
        // Uninterrupted reference.
        let mut full = Trainer::new(cfg.clone(), mlp_workload(6)).unwrap();
        let mut ref_losses = Vec::new();
        for k in 0..12 {
            ref_losses.push(full.step(k));
        }
        // Interrupted run: checkpoint at step 6, resume from the BYTES
        // (exercising the checksummed wire format), continue.
        let mut first = Trainer::new(cfg.clone(), mlp_workload(6)).unwrap();
        for k in 0..6 {
            assert_eq!(first.step(k), ref_losses[k], "prefix diverged at {k}");
        }
        let bytes = first.checkpoint().to_bytes();
        let snap = crate::elastic::Snapshot::from_bytes(&bytes).unwrap();
        let mut resumed = Trainer::resume(cfg, mlp_workload(6), &snap).unwrap();
        for (k, want) in ref_losses.iter().enumerate().skip(6) {
            assert_eq!(resumed.step(k), *want, "resumed run diverged at step {k}");
        }
        let a: Vec<u32> = full.average_model().iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = resumed.average_model().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "final average model differs after resume");
        assert_eq!(full.active_ids(), resumed.active_ids());
        assert_eq!(full.churn_stats().unwrap(), resumed.churn_stats().unwrap());
    }

    #[test]
    fn restore_refuses_mismatched_runs() {
        let cfg = small_cfg("decentlam", 10);
        let mut t = Trainer::new(cfg.clone(), mlp_workload(4)).unwrap();
        t.step(0);
        let snap = t.checkpoint();
        // Different optimizer.
        let mut other = small_cfg("dmsgd", 10);
        other.threads = cfg.threads;
        assert!(Trainer::resume(other, mlp_workload(4), &snap).is_err());
        // Different seed.
        let mut other = cfg.clone();
        other.seed = cfg.seed + 1;
        assert!(Trainer::resume(other, mlp_workload(4), &snap).is_err());
        // Different hyper parameters (lr, schedule) — a resumed run
        // would silently diverge, so the fingerprint must refuse.
        let mut other = cfg.clone();
        other.lr = cfg.lr * 0.5;
        assert!(Trainer::resume(other, mlp_workload(4), &snap).is_err());
        let mut other = cfg.clone();
        other.schedule = LrSchedule::WarmupCosine { warmup_steps: 2, total_steps: 10 };
        assert!(Trainer::resume(other, mlp_workload(4), &snap).is_err());
        // Same config resumes fine.
        assert!(Trainer::resume(cfg, mlp_workload(4), &snap).is_ok());
    }

    #[test]
    fn run_after_restore_covers_remaining_steps_only() {
        let cfg = small_cfg("dmsgd", 10);
        let mut full = Trainer::new(cfg.clone(), mlp_workload(4)).unwrap();
        let all = full.run().losses;
        assert_eq!(all.len(), 10);
        let mut first = Trainer::new(cfg.clone(), mlp_workload(4)).unwrap();
        for k in 0..4 {
            first.step(k);
        }
        let snap = first.checkpoint();
        let mut resumed = Trainer::resume(cfg, mlp_workload(4), &snap).unwrap();
        let tail = resumed.run().losses;
        assert_eq!(tail.len(), 6, "resumed run must cover the remaining steps only");
        assert_eq!(tail, all[4..].to_vec(), "resumed tail diverged");
    }

    #[test]
    fn manifest_is_valid_json_with_run_identity() {
        let mut cfg = small_cfg("decentlam", 3);
        cfg.apply_kv("codec", "int8,seed=3").unwrap();
        cfg.apply_kv("churn", "join=0.1,leave=0.1,nmin=2,nmax=5,seed=2").unwrap();
        let mut t = Trainer::new(cfg, mlp_workload(5)).unwrap();
        let report = t.run();
        let v = crate::util::json::Value::parse(&report.manifest).unwrap();
        assert_eq!(
            v.get("version").unwrap().as_str().unwrap(),
            crate::scenario::MANIFEST_VERSION
        );
        let c = v.get("config").unwrap();
        assert_eq!(c.get("optimizer").unwrap().as_str().unwrap(), "decentlam");
        assert_eq!(c.get("topology").unwrap().as_str().unwrap(), "ring");
        assert_eq!(c.get("nodes").unwrap().as_usize().unwrap(), 4);
        // Seeds serialize as strings: u64 must survive above 2^53.
        assert_eq!(c.get("seed").unwrap().as_str().unwrap(), "1");
        assert_eq!(c.get("codec").unwrap().as_str().unwrap(), "int8,seed=3");
        assert!(c.get("churn").unwrap().as_str().unwrap().contains("join=0.1"));
        let run = v.get("run").unwrap();
        assert!(run.get("active_nodes").unwrap().as_usize().unwrap() >= 2);
        // The embedded config round-trips through the manifest reader.
        let cur = crate::util::json::Cursor::root(c, "manifest.config");
        Config::from_manifest(&cur).unwrap();
        // Deterministic: same run, same manifest bytes.
        let mut cfg2 = small_cfg("decentlam", 3);
        cfg2.apply_kv("codec", "int8,seed=3").unwrap();
        cfg2.apply_kv("churn", "join=0.1,leave=0.1,nmin=2,nmax=5,seed=2").unwrap();
        let manifest2 = Trainer::new(cfg2, mlp_workload(5)).unwrap().manifest_json();
        assert_eq!(report.manifest, manifest2, "manifest must be deterministic");
    }

    #[test]
    fn large_ring_trains_without_dense_matrix() {
        // n=128 on a ring: the dense engine would rebuild/walk 16K-entry
        // matrices; the sparse engine holds 3n entries. A couple of
        // linreg steps must run quickly and keep the mean dynamics.
        let p = LinRegProblem::generate(128, 4, 6, 9);
        let mut cfg = small_cfg("dsgd", 3);
        cfg.nodes = 128;
        cfg.lr = 0.01;
        let mut t = Trainer::new(cfg, linreg::workload(p)).unwrap();
        assert_eq!(t.comm.nnz(), 3 * 128);
        for k in 0..3 {
            let loss = t.step(k);
            assert!(loss.is_finite());
        }
    }
}
