//! The training loop.
//!
//! One `step`:
//!   1. **Gradient phase** — every node computes its mean gradient over
//!      `accum` micro-batches at its own model (threaded; PJRT engines
//!      funnel into the runtime thread, native engines run truly in
//!      parallel).
//!   2. **Exchange + update phase** — the configured [`Optimizer`]
//!      performs its communication (partial averaging / all-reduce) and
//!      applies its update rule. The wire pattern is whatever the
//!      optimizer declared; the Fig. 6 cost model charges it.
//!   3. **Bookkeeping** — losses, learning-rate schedule, periodic eval
//!      of the network-average model, consensus distance.
//!
//! Time-varying topologies (one-peer exp, bipartite random match)
//! rebuild `W` each step from the shared seed.

use std::time::Instant;

use anyhow::Result;

use crate::grad::Workload;
use crate::optim::{self, NodeState, Optimizer, RoundCtx, Scratch};
use crate::topology::{metropolis_hastings, Kind, Topology, WeightMatrix};
use crate::util::config::Config;
use crate::util::math;

/// Everything a finished run reports.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// Mean training loss per step (averaged over nodes).
    pub losses: Vec<f64>,
    /// (step, accuracy) evaluation points of the average model.
    pub evals: Vec<(usize, f64)>,
    /// (step, eval loss) if the evaluator provides one.
    pub eval_losses: Vec<(usize, f64)>,
    /// Final top-1 accuracy of the average model.
    pub final_accuracy: f64,
    /// Final consensus distance (1/n)Σ‖x_i − x̄‖².
    pub final_consensus: f64,
    /// Wall seconds in the gradient phase / update phase.
    pub grad_seconds: f64,
    pub update_seconds: f64,
    pub steps: usize,
}

/// Multi-node trainer.
pub struct Trainer {
    pub cfg: Config,
    pub workload: Workload,
    pub kind: Kind,
    pub wm: WeightMatrix,
    topo: Topology,
    pub states: Vec<NodeState>,
    optimizer: Box<dyn Optimizer>,
    scratch: Scratch,
    grads: Vec<Vec<f32>>,
}

impl Trainer {
    pub fn new(cfg: Config, workload: Workload) -> Result<Trainer> {
        let kind = Kind::parse(&cfg.topology)?;
        let n = cfg.nodes;
        anyhow::ensure!(
            workload.nodes.len() == n,
            "workload has {} node shards, config wants {n}",
            workload.nodes.len()
        );
        let topo = Topology::at_step(kind, n, cfg.seed, 0);
        let mut wm = metropolis_hastings(&topo);
        if cfg.positive_definite {
            wm = wm.lazy();
        }
        let optimizer = optim::build(&cfg.optimizer, cfg.slowmo_period, cfg.slowmo_beta)?;
        let d = workload.dim;
        let states = (0..n)
            .map(|_| NodeState::new(workload.init.clone(), optimizer.aux_count()))
            .collect();
        Ok(Trainer {
            cfg,
            workload,
            kind,
            wm,
            topo,
            states,
            optimizer,
            scratch: Scratch::new(n, d),
            grads: (0..n).map(|_| vec![0.0; d]).collect(),
        })
    }

    /// The network-average model x̄.
    pub fn average_model(&self) -> Vec<f32> {
        let refs: Vec<&[f32]> = self.states.iter().map(|s| s.x.as_slice()).collect();
        math::mean_of(&refs)
    }

    /// Consensus distance (1/n) Σ ‖x_i − x̄‖².
    pub fn consensus_distance(&self) -> f64 {
        let xbar = self.average_model();
        self.states.iter().map(|s| math::dist2(&s.x, &xbar)).sum::<f64>()
            / self.states.len() as f64
    }

    /// One training step; returns the mean training loss.
    pub fn step(&mut self, k: usize) -> f64 {
        let accum = self.cfg.accum_steps();
        let lr = self.cfg.lr_at(k);
        // --- gradient phase (threaded over nodes) ---
        let loss = {
            let threads = if self.cfg.threads == 0 {
                self.cfg.nodes
            } else {
                self.cfg.threads.max(1)
            };
            let losses: Vec<f64> = if threads <= 1 {
                self.states
                    .iter()
                    .zip(self.workload.nodes.iter_mut())
                    .zip(self.grads.iter_mut())
                    .map(|((st, node), g)| node.grad_accum(&st.x, accum, g))
                    .collect()
            } else {
                let states = &self.states;
                let mut out = vec![0.0f64; states.len()];
                std::thread::scope(|scope| {
                    let mut handles = Vec::new();
                    for (((st, node), g), o) in states
                        .iter()
                        .zip(self.workload.nodes.iter_mut())
                        .zip(self.grads.iter_mut())
                        .zip(out.iter_mut())
                    {
                        handles.push(scope.spawn(move || {
                            *o = node.grad_accum(&st.x, accum, g);
                        }));
                    }
                    for h in handles {
                        h.join().expect("gradient worker panicked");
                    }
                });
                out
            };
            losses.iter().sum::<f64>() / losses.len() as f64
        };
        // --- exchange + update phase ---
        if self.kind.time_varying() {
            self.topo = Topology::at_step(self.kind, self.cfg.nodes, self.cfg.seed, k);
            self.wm = metropolis_hastings(&self.topo);
            if self.cfg.positive_definite {
                self.wm = self.wm.lazy();
            }
        }
        let ctx = RoundCtx {
            wm: &self.wm,
            lr,
            beta: self.cfg.momentum as f32,
            step: k,
            time_varying: self.kind.time_varying(),
            layer_ranges: &self.workload.layer_ranges,
        };
        self.optimizer.round(&mut self.states, &self.grads, &ctx, &mut self.scratch);
        loss
    }

    /// Communication pattern of the configured optimizer (for the cost
    /// model).
    pub fn comm_pattern(&self) -> optim::CommPattern {
        self.optimizer.comm_pattern()
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Run the full schedule, reporting losses/evals.
    pub fn run(&mut self) -> TrainReport {
        let mut report = TrainReport { steps: self.cfg.steps, ..Default::default() };
        let mut grad_s = 0.0;
        let mut upd_s = 0.0;
        for k in 0..self.cfg.steps {
            let t0 = Instant::now();
            let loss = self.step(k);
            let dt = t0.elapsed().as_secs_f64();
            // step() mixes both phases; attribute by re-measuring would
            // double work. Track total and split via a dedicated probe in
            // the benches; here we record total into grad_seconds.
            grad_s += dt;
            report.losses.push(loss);
            if self.cfg.eval_every > 0 && (k + 1) % self.cfg.eval_every == 0 {
                let t1 = Instant::now();
                let xbar = self.average_model();
                let acc = self.workload.eval.accuracy(&xbar);
                if acc.is_finite() {
                    report.evals.push((k + 1, acc));
                }
                if let Some(el) = self.workload.eval.loss(&xbar) {
                    report.eval_losses.push((k + 1, el));
                }
                upd_s += t1.elapsed().as_secs_f64();
            }
        }
        let xbar = self.average_model();
        report.final_accuracy = self.workload.eval.accuracy(&xbar);
        report.final_consensus = self.consensus_distance();
        report.grad_seconds = grad_s;
        report.update_seconds = upd_s;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{ClassificationData, SynthSpec};
    use crate::data::LinRegProblem;
    use crate::grad::{linreg, mlp};
    use crate::util::config::LrSchedule;

    fn small_cfg(optimizer: &str, steps: usize) -> Config {
        let mut cfg = Config::default();
        cfg.optimizer = optimizer.into();
        cfg.nodes = 4;
        cfg.steps = steps;
        cfg.total_batch = 128;
        cfg.micro_batch = 32;
        cfg.lr = 0.05;
        cfg.linear_scaling = false;
        cfg.schedule = LrSchedule::Constant;
        cfg.topology = "ring".into();
        cfg
    }

    fn mlp_workload(nodes: usize) -> Workload {
        let spec = SynthSpec {
            nodes,
            samples_per_node: 256,
            eval_samples: 256,
            dirichlet_alpha: 1.0,
            ..Default::default()
        };
        let data = ClassificationData::generate(&spec);
        mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1)
    }

    #[test]
    fn decentlam_trains_mlp_above_chance() {
        let cfg = small_cfg("decentlam", 120);
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let report = t.run();
        assert!(report.losses[0] > report.losses.last().unwrap() * 1.5);
        assert!(report.final_accuracy > 0.4, "acc={}", report.final_accuracy);
    }

    #[test]
    fn all_optimizers_run_and_descend() {
        for name in crate::optim::ALL {
            let mut cfg = small_cfg(name, 40);
            cfg.lr = 0.02;
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let report = t.run();
            let first = report.losses[..5].iter().sum::<f64>() / 5.0;
            let last = report.losses[report.losses.len() - 5..].iter().sum::<f64>() / 5.0;
            assert!(
                last < first,
                "{name}: loss did not descend ({first} -> {last})"
            );
            assert!(report.losses.iter().all(|l| l.is_finite()), "{name} diverged");
        }
    }

    #[test]
    fn linreg_consensus_shrinks_under_training() {
        let p = LinRegProblem::generate(4, 30, 10, 3);
        let mut cfg = small_cfg("decentlam", 400);
        cfg.lr = 0.005;
        cfg.momentum = 0.8;
        let mut t = Trainer::new(cfg, linreg::workload(p)).unwrap();
        let report = t.run();
        assert!(report.final_consensus < 1e-2, "consensus={}", report.final_consensus);
        assert!(report.final_accuracy > -0.05, "rel err={}", -report.final_accuracy);
    }

    #[test]
    fn time_varying_topology_trains() {
        let mut cfg = small_cfg("decentlam", 60);
        cfg.topology = "bipartite".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let report = t.run();
        assert!(report.losses.iter().all(|l| l.is_finite()));
        assert!(report.losses[0] > *report.losses.last().unwrap());
    }

    #[test]
    fn threaded_and_sequential_grad_phase_agree() {
        let mk = |threads: usize| {
            let mut cfg = small_cfg("dmsgd", 10);
            cfg.threads = threads;
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            t.run().losses
        };
        let seq = mk(1);
        let par = mk(0);
        for (a, b) in seq.iter().zip(&par) {
            assert!((a - b).abs() < 1e-9, "threading changed results: {a} vs {b}");
        }
    }

    #[test]
    fn node_count_mismatch_rejected() {
        let mut cfg = small_cfg("dmsgd", 5);
        cfg.nodes = 6;
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
    }
}
