//! The training loop.
//!
//! One `step`:
//!   1. **Gradient phase** — every node computes its mean gradient over
//!      `accum` micro-batches at its own model, fanned out over the
//!      [`NodeExecutor`] (PJRT engines funnel into the runtime thread,
//!      native engines run truly in parallel).
//!   2. **Exchange + update phase** — the configured [`Optimizer`]
//!      performs its communication (partial averaging / all-reduce) and
//!      applies its update rule, also chunked over nodes by the
//!      executor. The wire pattern is whatever the optimizer declared;
//!      the Fig. 6 cost model charges it from realized edge counts.
//!   3. **Bookkeeping** — losses, learning-rate schedule, periodic eval
//!      of the network-average model, consensus distance.
//!
//! Mixing weights live in a [`SparseWeights`] neighbor-list engine —
//! O(edges) memory and rebuild cost, so ring/grid/exp-graph runs scale
//! to n=512–1024. Time-varying topologies (one-peer exp, bipartite
//! random match) rebuild only the neighbor lists each step from the
//! shared seed, never an n×n matrix.
//!
//! When `Config::faults` is set, a [`FaultyEngine`] sits between the
//! nominal weights and the optimizers: each step it masks dropped
//! nodes / failed links, renormalizes the Metropolis–Hastings weights
//! in place, and serves stale cached messages for stragglers — the
//! whole run stays deterministic under the fault seed (DESIGN.md §6).
//!
//! When `Config::codec` is set, every gossip payload is compressed
//! through the named [`CodecState`] (fp16 / stochastic int8 / top-k
//! with error feedback, DESIGN.md §7): the optimizers' exchanges all
//! route through `optim::gossip_exchange`, which encodes each publish
//! buffer once and mixes the decoded wire view; the fault engine's
//! stale cache then holds encoded payloads, so faults and compression
//! compose. Runs stay byte-identical under the codec seed.
//!
//! When `Config::async_mode` is set (`--async tau=2,spread=4`), rounds
//! execute against the discrete-event clock sim's bounded-staleness
//! schedule (DESIGN.md §8): nodes run on heterogeneous seeded virtual
//! clocks and each edge delivery may be up to `tau` rounds old, served
//! from the fault engine's per-exchange-slot ring caches. With uniform
//! speeds, zero jitter and `tau=0` the schedule realizes all-fresh and
//! the run is bitwise identical to the synchronous path; `pmsgd` runs
//! as the barrier baseline (simulated time only, no staleness).

use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::comm::codec::{CodecSpec, CodecState};
use crate::comm::cost::{CommCost, PayloadBytes};
use crate::comm::CommEngine;
use crate::grad::Workload;
use crate::optim::{self, NodeState, Optimizer, RoundCtx, Scratch};
use crate::sim::clock::{simulate_barrier, simulate_gossip, AsyncReport, AsyncSpec};
use crate::sim::{FaultPlan, FaultSpec, FaultStats, FaultyEngine};
use crate::topology::{metropolis_hastings, Kind, SparseWeights, Topology, WeightMatrix};
use crate::util::config::Config;
use crate::util::math;

use super::executor::NodeExecutor;

/// Everything a finished run reports.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// Mean training loss per step (averaged over nodes).
    pub losses: Vec<f64>,
    /// (step, accuracy) evaluation points of the average model.
    pub evals: Vec<(usize, f64)>,
    /// (step, eval loss) if the evaluator provides one.
    pub eval_losses: Vec<(usize, f64)>,
    /// Final top-1 accuracy of the average model.
    pub final_accuracy: f64,
    /// Final consensus distance (1/n)Σ‖x_i − x̄‖².
    pub final_consensus: f64,
    /// Wall seconds in the gradient phase / update phase.
    pub grad_seconds: f64,
    pub update_seconds: f64,
    pub steps: usize,
}

/// Multi-node trainer.
pub struct Trainer {
    pub cfg: Config,
    pub workload: Workload,
    pub kind: Kind,
    /// Sparse neighbor-list comm engine (the nominal mixing weights).
    pub comm: SparseWeights,
    /// Fault-injection wrapper (None = ideal network). When present,
    /// every round mixes through the masked + renormalized realized
    /// rows instead of the nominal ones.
    faults: Option<FaultyEngine>,
    /// Payload codec for the gossip wire path (None = raw fp32). Owned
    /// here because the EF residuals and wire buffers are cross-round
    /// state; rounds reach it through `RoundCtx::codec`.
    codec: Option<Mutex<CodecState>>,
    /// Timing + staleness summary of the `--async` discrete-event run
    /// (None = synchronous). The schedule itself lives inside the fault
    /// engine, which replays it round by round.
    async_report: Option<AsyncReport>,
    topo: Topology,
    pub states: Vec<NodeState>,
    optimizer: Box<dyn Optimizer>,
    scratch: Scratch,
    grads: Vec<Vec<f32>>,
    losses: Vec<f64>,
    /// Executor for the gradient phase (compute-heavy per node).
    exec: NodeExecutor,
    /// Executor for the exchange/update phases: serial when n·d is too
    /// small to amortize thread spawns (results are identical either
    /// way — the executor never reorders arithmetic).
    update_exec: NodeExecutor,
}

/// Below this many touched f32s per phase (n·d), the exchange/update
/// loops run serially — a scoped-thread spawn costs more than copying
/// a few thousand floats.
const PARALLEL_UPDATE_MIN_ITEMS: usize = 1 << 17;

impl Trainer {
    pub fn new(cfg: Config, workload: Workload) -> Result<Trainer> {
        let kind = Kind::parse(&cfg.topology)?;
        let n = cfg.nodes;
        anyhow::ensure!(
            workload.nodes.len() == n,
            "workload has {} node shards, config wants {n}",
            workload.nodes.len()
        );
        let topo = Topology::at_step(kind, n, cfg.seed, 0);
        // B-connectivity sanity: the union graph over the kind's
        // declared window must be connected (Assumption A.3 over a
        // window); kinds with only probabilistic guarantees (bipartite
        // random match) declare no window and are exempt.
        if let Some(w) = kind.connectivity_window(n) {
            let union = Topology::union_over_window(kind, n, cfg.seed, 0, w);
            anyhow::ensure!(
                union.is_connected(),
                "{kind:?} union over its {w}-step window is disconnected at n={n}"
            );
        }
        let mut comm = SparseWeights::metropolis_hastings(&topo);
        if cfg.positive_definite {
            comm.make_lazy();
        }
        let optimizer = optim::build(&cfg.optimizer, cfg.slowmo_period, cfg.slowmo_beta)?;
        let mut faults = if cfg.faults.trim().is_empty() {
            None
        } else {
            // Validate the spec for every optimizer, but only attach an
            // engine when the optimizer actually mixes through the comm
            // engine — pure all-reduce baselines (PmSGD) model a
            // centralized fabric outside the decentralized fault model,
            // and attaching one would report faults that never touched
            // training (`fault_stats()` stays None for them).
            let spec = FaultSpec::parse(&cfg.faults, cfg.seed)?;
            match optimizer.comm_pattern() {
                optim::CommPattern::AllReduce => None,
                pattern => {
                    let mut engine = FaultyEngine::new(FaultPlan::new(spec));
                    // Stale replay is only faithful when the round
                    // publishes a single quantity — the cache then holds
                    // last round's same payload. Multi-payload optimizers
                    // (da-dmsgd) fall back to masking for straggle/stale
                    // faults (see FaultyEngine docs).
                    let single_payload = match pattern {
                        optim::CommPattern::Neighbor { payloads } => payloads == 1,
                        optim::CommPattern::NeighborPlusPeriodicAllReduce {
                            payloads, ..
                        } => payloads == 1,
                        optim::CommPattern::AllReduce => unreachable!(),
                    };
                    engine.set_stale_capable(single_payload);
                    Some(engine)
                }
            }
        };
        let d = workload.dim;
        let codec = if cfg.codec.trim().is_empty() {
            None
        } else {
            // Codec seed defaults to the run seed (like --faults). Pure
            // all-reduce optimizers (PmSGD) never touch the gossip wire
            // the codec compresses — validate the spec but attach no
            // state, so `codec_name()`/`payload_bytes()` never report a
            // compression that cannot happen (same honesty rule as the
            // fault engine above).
            let spec = CodecSpec::parse(&cfg.codec, cfg.seed)?;
            match optimizer.comm_pattern() {
                optim::CommPattern::AllReduce => None,
                _ => Some(Mutex::new(CodecState::new(&spec, n, d))),
            }
        };
        // Asynchronous execution: run the discrete-event clock sim over
        // the static topology (DESIGN.md §8). Event times are
        // value-free, so the whole schedule — per-(step, edge)
        // staleness ages plus completion times — is known up front; the
        // fault engine replays the ages from per-slot ring caches while
        // the trainer's global-step loop executes the rounds in order
        // (a topological execution of the event DAG, value-identical to
        // firing nodes in event order). Gossip legs charge the codec's
        // ENCODED payload width, so compression shortens simulated
        // exchanges too.
        let async_report = if cfg.async_mode.trim().is_empty() {
            None
        } else {
            let spec = AsyncSpec::parse(&cfg.async_mode, cfg.seed)?;
            match optimizer.comm_pattern() {
                optim::CommPattern::AllReduce => {
                    // Barrier-synchronous baseline: each simulated round
                    // costs the slowest node's compute plus the
                    // collective; no staleness ever reaches training.
                    let ar = CommCost::new(spec.link()).allreduce_s(n, 4.0 * d as f64);
                    let (cum, wait) = simulate_barrier(&spec, n, ar, cfg.steps);
                    Some(AsyncReport::barrier(cum, wait))
                }
                optim::CommPattern::NeighborPlusPeriodicAllReduce { .. } => {
                    anyhow::bail!(
                        "--async models pure gossip rounds; `{}`'s periodic all-reduce \
                         is a global barrier (run pmsgd for the barrier baseline)",
                        cfg.optimizer
                    );
                }
                optim::CommPattern::Neighbor { payloads } => {
                    anyhow::ensure!(
                        !kind.time_varying(),
                        "--async requires a static topology; `{}` changes neighbors per step",
                        cfg.topology
                    );
                    let neighbor_bytes = match &codec {
                        Some(c) => c.lock().unwrap().payload_bytes(),
                        None => 4.0 * d as f64,
                    };
                    let sched = simulate_gossip(&spec, &comm, neighbor_bytes, payloads, cfg.steps);
                    let report = sched.report();
                    let engine = faults.get_or_insert_with(|| {
                        let mut e = FaultyEngine::new(FaultPlan::new(FaultSpec {
                            seed: cfg.seed,
                            ..Default::default()
                        }));
                        e.set_stale_capable(payloads == 1);
                        e
                    });
                    engine.set_async(sched);
                    Some(report)
                }
            }
        };
        let states = (0..n)
            .map(|_| NodeState::new(workload.init.clone(), optimizer.aux_count()))
            .collect();
        let exec = NodeExecutor::new(cfg.threads);
        let update_exec = if n * d >= PARALLEL_UPDATE_MIN_ITEMS {
            exec
        } else {
            NodeExecutor::serial()
        };
        Ok(Trainer {
            cfg,
            workload,
            kind,
            comm,
            faults,
            codec,
            async_report,
            topo,
            states,
            optimizer,
            scratch: Scratch::new(n, d),
            grads: (0..n).map(|_| vec![0.0; d]).collect(),
            losses: vec![0.0; n],
            exec,
            update_exec,
        })
    }

    /// The network-average model x̄.
    pub fn average_model(&self) -> Vec<f32> {
        let refs: Vec<&[f32]> = self.states.iter().map(|s| s.x.as_slice()).collect();
        math::mean_of(&refs)
    }

    /// Consensus distance (1/n) Σ ‖x_i − x̄‖².
    pub fn consensus_distance(&self) -> f64 {
        let xbar = self.average_model();
        self.states.iter().map(|s| math::dist2(&s.x, &xbar)).sum::<f64>()
            / self.states.len() as f64
    }

    /// Dense mixing matrix of the current topology realization — for
    /// spectral analysis only (O(n²) memory); the training path never
    /// materializes it.
    pub fn mixing_matrix(&self) -> WeightMatrix {
        let wm = metropolis_hastings(&self.topo);
        if self.cfg.positive_definite {
            wm.lazy()
        } else {
            wm
        }
    }

    /// One training step; returns the mean training loss.
    pub fn step(&mut self, k: usize) -> f64 {
        let accum = self.cfg.accum_steps();
        let lr = self.cfg.lr_at(k);
        // --- gradient phase (executor-chunked over nodes) ---
        let loss = {
            let states = &self.states;
            self.exec.for_each_triple_mut(
                &mut self.workload.nodes,
                &mut self.grads,
                &mut self.losses,
                |i, node, g, loss| {
                    *loss = node.grad_accum(&states[i].x, accum, g);
                },
            );
            self.losses.iter().sum::<f64>() / self.losses.len() as f64
        };
        // --- exchange + update phase ---
        if self.kind.time_varying() {
            self.topo = Topology::at_step(self.kind, self.cfg.nodes, self.cfg.seed, k);
            self.comm.rebuild_metropolis(&self.topo);
            if self.cfg.positive_definite {
                self.comm.make_lazy();
            }
        }
        // Realize this step's faults (and async staleness ages) over
        // the nominal weights. An active fault plan makes the
        // *realized* mixing matrix time-varying even on static
        // topologies, and bounded staleness re-injects stale-direction
        // disagreement the same way — either engages the optimizers'
        // time-varying guards (DecentLaM's disagreement clip). An
        // all-fresh async schedule (uniform clocks / tau=0) engages
        // nothing, preserving bitwise equality with synchronous runs.
        let faults_active = match &mut self.faults {
            Some(f) => {
                f.begin_step(k, &self.comm);
                f.active() || f.async_engaged()
            }
            None => false,
        };
        let comm: &dyn CommEngine = match &self.faults {
            Some(f) => f,
            None => &self.comm,
        };
        if let Some(c) = &self.codec {
            c.lock().unwrap().begin_step(k);
        }
        let ctx = RoundCtx {
            comm,
            exec: self.update_exec,
            lr,
            beta: self.cfg.momentum as f32,
            step: k,
            time_varying: self.kind.time_varying() || faults_active,
            layer_ranges: &self.workload.layer_ranges,
            codec: self.codec.as_ref(),
        };
        self.optimizer.round(&mut self.states, &self.grads, &ctx, &mut self.scratch);
        if let Some(f) = &mut self.faults {
            if f.needs_publish_cache() {
                // What went on the wire this round is next round's
                // stale payload for stragglers / stale links. With a
                // lossy codec that is the ENCODED payload (the codec's
                // wire view), not the raw publish buffer — a stale
                // replay re-delivers last round's compressed bytes.
                match &self.codec {
                    Some(c) => {
                        let state = c.lock().unwrap();
                        if state.is_identity() {
                            f.record_publish(&self.scratch.publish);
                        } else {
                            f.record_publish(state.wire());
                        }
                    }
                    None => f.record_publish(&self.scratch.publish),
                }
            }
        }
        loss
    }

    /// Per-payload wire widths of this run: codec-encoded gossip
    /// payloads, raw fp32 all-reduce legs (for the cost model).
    pub fn payload_bytes(&self) -> PayloadBytes {
        let d = self.workload.dim;
        match &self.codec {
            Some(c) => PayloadBytes::compressed(c.lock().unwrap().payload_bytes(), d),
            None => PayloadBytes::fp32(d),
        }
    }

    /// Name of the configured payload codec (None = raw fp32 path).
    pub fn codec_name(&self) -> Option<&'static str> {
        self.codec.as_ref().map(|c| c.lock().unwrap().name())
    }

    /// Communication pattern of the configured optimizer (for the cost
    /// model).
    pub fn comm_pattern(&self) -> optim::CommPattern {
        self.optimizer.comm_pattern()
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Cumulative fault accounting (None when running fault-free, or
    /// when the optimizer's all-reduce traffic bypasses the fault
    /// model entirely).
    pub fn fault_stats(&self) -> Option<&FaultStats> {
        self.faults.as_ref().map(|f| f.stats())
    }

    /// Timing + staleness summary of the `--async` discrete-event run
    /// (None in synchronous mode). `step_done_s[k]` is the simulated
    /// wall second at which every node has completed step k — the
    /// x-axis of time-to-target-loss plots.
    pub fn async_report(&self) -> Option<&AsyncReport> {
        self.async_report.as_ref()
    }

    /// Run the full schedule, reporting losses/evals.
    pub fn run(&mut self) -> TrainReport {
        let mut report = TrainReport { steps: self.cfg.steps, ..Default::default() };
        let mut grad_s = 0.0;
        let mut upd_s = 0.0;
        for k in 0..self.cfg.steps {
            let t0 = Instant::now();
            let loss = self.step(k);
            let dt = t0.elapsed().as_secs_f64();
            // step() mixes both phases; attribute by re-measuring would
            // double work. Track total and split via a dedicated probe in
            // the benches; here we record total into grad_seconds.
            grad_s += dt;
            report.losses.push(loss);
            if self.cfg.eval_every > 0 && (k + 1) % self.cfg.eval_every == 0 {
                let t1 = Instant::now();
                let xbar = self.average_model();
                let acc = self.workload.eval.accuracy(&xbar);
                if acc.is_finite() {
                    report.evals.push((k + 1, acc));
                }
                if let Some(el) = self.workload.eval.loss(&xbar) {
                    report.eval_losses.push((k + 1, el));
                }
                upd_s += t1.elapsed().as_secs_f64();
            }
        }
        let xbar = self.average_model();
        report.final_accuracy = self.workload.eval.accuracy(&xbar);
        report.final_consensus = self.consensus_distance();
        report.grad_seconds = grad_s;
        report.update_seconds = upd_s;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::engine::CommEngine;
    use crate::data::synth::{ClassificationData, SynthSpec};
    use crate::data::LinRegProblem;
    use crate::grad::{linreg, mlp};
    use crate::util::config::LrSchedule;

    fn small_cfg(optimizer: &str, steps: usize) -> Config {
        let mut cfg = Config::default();
        cfg.optimizer = optimizer.into();
        cfg.nodes = 4;
        cfg.steps = steps;
        cfg.total_batch = 128;
        cfg.micro_batch = 32;
        cfg.lr = 0.05;
        cfg.linear_scaling = false;
        cfg.schedule = LrSchedule::Constant;
        cfg.topology = "ring".into();
        cfg
    }

    fn mlp_workload(nodes: usize) -> Workload {
        let spec = SynthSpec {
            nodes,
            samples_per_node: 256,
            eval_samples: 256,
            dirichlet_alpha: 1.0,
            ..Default::default()
        };
        let data = ClassificationData::generate(&spec);
        mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1)
    }

    #[test]
    fn decentlam_trains_mlp_above_chance() {
        let cfg = small_cfg("decentlam", 120);
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let report = t.run();
        assert!(report.losses[0] > report.losses.last().unwrap() * 1.5);
        assert!(report.final_accuracy > 0.4, "acc={}", report.final_accuracy);
    }

    #[test]
    fn all_optimizers_run_and_descend() {
        for name in crate::optim::ALL {
            let mut cfg = small_cfg(name, 40);
            cfg.lr = 0.02;
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let report = t.run();
            let first = report.losses[..5].iter().sum::<f64>() / 5.0;
            let last = report.losses[report.losses.len() - 5..].iter().sum::<f64>() / 5.0;
            assert!(
                last < first,
                "{name}: loss did not descend ({first} -> {last})"
            );
            assert!(report.losses.iter().all(|l| l.is_finite()), "{name} diverged");
        }
    }

    #[test]
    fn linreg_consensus_shrinks_under_training() {
        let p = LinRegProblem::generate(4, 30, 10, 3);
        let mut cfg = small_cfg("decentlam", 400);
        cfg.lr = 0.005;
        cfg.momentum = 0.8;
        let mut t = Trainer::new(cfg, linreg::workload(p)).unwrap();
        let report = t.run();
        assert!(report.final_consensus < 1e-2, "consensus={}", report.final_consensus);
        assert!(report.final_accuracy > -0.05, "rel err={}", -report.final_accuracy);
    }

    #[test]
    fn time_varying_topology_trains() {
        let mut cfg = small_cfg("decentlam", 60);
        cfg.topology = "bipartite".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let report = t.run();
        assert!(report.losses.iter().all(|l| l.is_finite()));
        assert!(report.losses[0] > *report.losses.last().unwrap());
    }

    #[test]
    fn time_varying_topology_rebuilds_neighbor_lists() {
        let mut cfg = small_cfg("dsgd", 3);
        cfg.topology = "bipartite".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let mut partners = Vec::new();
        for k in 0..3 {
            t.step(k);
            // Sparse engine must mirror the step-k realization exactly.
            let topo = t.topology();
            for i in 0..4 {
                assert_eq!(
                    t.comm.row(i).len(),
                    topo.neighbors(i).len() + 1,
                    "step {k} node {i}"
                );
            }
            partners.push(topo.neighbors(0).to_vec());
        }
        assert!(
            partners.iter().any(|p| p != &partners[0]),
            "bipartite match never changed partner"
        );
    }

    #[test]
    fn threaded_and_sequential_phases_agree() {
        let mk = |threads: usize| {
            let mut cfg = small_cfg("dmsgd", 10);
            cfg.threads = threads;
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            t.run().losses
        };
        let seq = mk(1);
        let par = mk(0);
        for (a, b) in seq.iter().zip(&par) {
            assert!((a - b).abs() < 1e-9, "threading changed results: {a} vs {b}");
        }
    }

    #[test]
    fn faulty_run_descends_and_replays_identically() {
        let mk = || {
            let mut cfg = small_cfg("decentlam", 40);
            cfg.lr = 0.02;
            cfg.faults = "drop=0.15,straggle=0.1,seed=5".into();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let stats = *t.fault_stats().unwrap();
            (losses, stats)
        };
        let (a, stats) = mk();
        let (b, stats_b) = mk();
        assert_eq!(a, b, "fault schedule must replay bit-identically");
        assert_eq!(stats, stats_b);
        assert!(a.iter().all(|l| l.is_finite()));
        let first = a[..5].iter().sum::<f64>() / 5.0;
        let last = a[a.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(last < first, "loss did not descend under faults ({first} -> {last})");
        assert_eq!(stats.steps, 40);
        assert!(stats.masked_edges > 0, "drop=0.15 never masked an edge");
        assert!(stats.stale_messages > 0, "straggle=0.1 never went stale");
        assert!(stats.realized_edges < stats.nominal_edges);
    }

    #[test]
    fn zero_rate_faults_bitwise_match_fault_free_run() {
        let run = |faults: &str| {
            let mut cfg = small_cfg("dmsgd", 25);
            cfg.faults = faults.into();
            Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
        };
        assert_eq!(run(""), run("drop=0,link=0,seed=99"));
    }

    #[test]
    fn faults_compose_with_time_varying_topologies() {
        let mut cfg = small_cfg("decentlam", 30);
        cfg.topology = "one-peer-exp".into();
        cfg.faults = "drop=0.2,link=0.1,seed=2".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let r = t.run();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        let stats = t.fault_stats().unwrap();
        assert_eq!(stats.steps, 30);
        assert!(stats.realized_edges < stats.nominal_edges);
    }

    #[test]
    fn allreduce_optimizer_ignores_fault_spec_honestly() {
        // pmsgd never touches the comm engine; a fault spec must not
        // attach an engine that would report phantom fault traffic.
        let mut cfg = small_cfg("pmsgd", 10);
        cfg.faults = "drop=0.5,seed=4".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let r = t.run();
        assert!(t.fault_stats().is_none());
        assert!(r.losses.iter().all(|l| l.is_finite()));
        // Still validated: a malformed spec fails even for pmsgd.
        let mut bad = small_cfg("pmsgd", 5);
        bad.faults = "drop=2".into();
        assert!(Trainer::new(bad, mlp_workload(4)).is_err());
    }

    #[test]
    fn multi_payload_optimizer_masks_stragglers_instead_of_staling() {
        // da-dmsgd publishes two quantities per round; a single stale
        // cache cannot replay both, so its straggle faults must fall
        // back to edge masking (no stale deliveries, edges lost).
        let mut cfg = small_cfg("da-dmsgd", 20);
        cfg.faults = "straggle=0.4,seed=8".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let r = t.run();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        let stats = t.fault_stats().unwrap();
        assert_eq!(stats.stale_messages, 0, "multi-payload round must not stale");
        assert!(stats.masked_edges > 0, "stragglers should mask exchanges");
    }

    #[test]
    fn fp32_codec_is_bitwise_identical_to_no_codec() {
        let run = |codec: &str| {
            let mut cfg = small_cfg("dmsgd", 25);
            cfg.codec = codec.into();
            Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
        };
        assert_eq!(run(""), run("fp32"), "identity codec must not change a single bit");
    }

    #[test]
    fn lossy_codecs_train_and_replay_identically() {
        for codec in ["fp16", "int8,ef=true,seed=5", "topk,k=0.25"] {
            let run = || {
                let mut cfg = small_cfg("decentlam", 40);
                cfg.lr = 0.02;
                cfg.codec = codec.into();
                Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "{codec}: rerun must be byte-identical");
            assert!(a.iter().all(|l| l.is_finite()), "{codec} diverged");
            let first = a[..5].iter().sum::<f64>() / 5.0;
            let last = a[a.len() - 5..].iter().sum::<f64>() / 5.0;
            assert!(last < first, "{codec}: loss did not descend ({first} -> {last})");
        }
    }

    #[test]
    fn codec_threaded_and_serial_runs_agree() {
        let mk = |threads: usize| {
            let mut cfg = small_cfg("dmsgd", 10);
            cfg.threads = threads;
            cfg.codec = "int8,seed=3".into();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            t.run().losses
        };
        let seq = mk(1);
        let par = mk(0);
        assert_eq!(seq, par, "codec must keep parallel == serial bitwise");
    }

    #[test]
    fn codec_composes_with_faults_and_stales_encoded_payloads() {
        // Straggle + int8: the stale cache holds the codec's wire view,
        // and the run stays deterministic and finite.
        let run = || {
            let mut cfg = small_cfg("decentlam", 30);
            cfg.lr = 0.02;
            cfg.codec = "int8,ef=true,seed=4".into();
            cfg.faults = "straggle=0.3,seed=6".into();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let stats = *t.fault_stats().unwrap();
            (losses, stats)
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(sa.stale_messages > 0, "straggle=0.3 never went stale");
    }

    #[test]
    fn multi_payload_optimizer_gets_per_slot_codec_residuals() {
        // da-dmsgd runs two compressed exchanges per round (momentum
        // then parameters); the per-slot EF residuals keep them apart
        // and the run must stay finite + deterministic.
        let run = || {
            let mut cfg = small_cfg("da-dmsgd", 25);
            cfg.lr = 0.02;
            cfg.codec = "int8,ef=true,seed=2".into();
            Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
        };
        let a = run();
        assert_eq!(a, run());
        assert!(a.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn payload_bytes_reflects_codec() {
        let d_of = |t: &Trainer| t.workload.dim;
        let mk = |codec: &str| {
            let mut cfg = small_cfg("decentlam", 1);
            cfg.codec = codec.into();
            Trainer::new(cfg, mlp_workload(4)).unwrap()
        };
        let raw = mk("");
        let d = d_of(&raw);
        assert_eq!(raw.payload_bytes().neighbor, 4.0 * d as f64);
        assert_eq!(raw.codec_name(), None);
        let int8 = mk("int8");
        assert_eq!(int8.payload_bytes().neighbor, d as f64 + 4.0);
        assert_eq!(int8.payload_bytes().allreduce, 4.0 * d as f64);
        assert_eq!(int8.codec_name(), Some("int8"));
        let ratio = raw.payload_bytes().neighbor / int8.payload_bytes().neighbor;
        assert!(ratio >= 3.9, "int8 byte cut {ratio} < 3.9x at d={d}");
    }

    #[test]
    fn allreduce_optimizer_ignores_codec_honestly() {
        // pmsgd never touches the gossip wire; a codec spec must not
        // attach state that would report a compression that never
        // happens — mirrors the fault-engine rule.
        let mut cfg = small_cfg("pmsgd", 5);
        cfg.codec = "int8".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let d = t.workload.dim;
        assert_eq!(t.codec_name(), None);
        assert_eq!(t.payload_bytes().neighbor, 4.0 * d as f64);
        let r = t.run();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        // Still validated: a malformed spec fails even for pmsgd.
        let mut bad = small_cfg("pmsgd", 5);
        bad.codec = "int8,k=0.5".into();
        assert!(Trainer::new(bad, mlp_workload(4)).is_err());
    }

    #[test]
    fn async_uniform_tau0_is_bitwise_synchronous() {
        // The tentpole invariant: uniform speeds + zero jitter + tau=0
        // must reproduce the synchronous trainer losses bit for bit
        // (star included — irregular degrees desynchronize gather
        // times, but version capping keeps every delivery exact).
        for topology in ["ring", "star"] {
            for opt in ["dmsgd", "decentlam"] {
                let run = |asynch: &str| {
                    let mut cfg = small_cfg(opt, 25);
                    cfg.topology = topology.into();
                    cfg.async_mode = asynch.into();
                    Trainer::new(cfg, mlp_workload(4)).unwrap().run().losses
                };
                assert_eq!(
                    run(""),
                    run("tau=0,spread=1,jitter=0"),
                    "{opt} on {topology}: async(uniform, tau=0) must be bitwise synchronous"
                );
            }
        }
    }

    #[test]
    fn async_heterogeneous_run_is_deterministic_and_stale() {
        let run = |threads: usize| {
            let mut cfg = small_cfg("decentlam", 40);
            cfg.lr = 0.02;
            cfg.threads = threads;
            cfg.async_mode = "tau=2,spread=6,jitter=0.3,seed=9".into();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let report = t.async_report().unwrap().clone();
            (losses, report)
        };
        let (a, ra) = run(0);
        let (b, rb) = run(0);
        assert_eq!(a, b, "async rerun must be byte-identical");
        assert_eq!(ra, rb);
        let (c, _) = run(1);
        assert_eq!(a, c, "async parallel != serial");
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(ra.max_staleness >= 1, "spread=6 never delivered stale");
        assert!(ra.mean_staleness > 0.0 && ra.max_staleness <= 2);
        assert_eq!(ra.step_done_s.len(), 40);
        assert!(ra.makespan_s > 0.0);
        let first = a[..5].iter().sum::<f64>() / 5.0;
        let last = a[a.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(last < first, "loss did not descend under staleness ({first} -> {last})");
    }

    #[test]
    fn async_composes_with_faults_and_codec() {
        let run = || {
            let mut cfg = small_cfg("decentlam", 30);
            cfg.lr = 0.02;
            cfg.async_mode = "tau=2,spread=4,jitter=0.2,seed=3".into();
            cfg.faults = "drop=0.1,straggle=0.2,seed=5".into();
            cfg.codec = "int8,ef=true,seed=4".into();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let stats = *t.fault_stats().unwrap();
            (losses, stats)
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(sa.masked_edges > 0, "drop=0.1 never masked");
    }

    #[test]
    fn async_multi_payload_optimizer_staleness_is_faithful() {
        // da-dmsgd exchanges two payload kinds per round; the per-slot
        // ring caches replay each kind's own history, so async staleness
        // needs no masking downgrade.
        let run = |threads: usize| {
            let mut cfg = small_cfg("da-dmsgd", 30);
            cfg.lr = 0.02;
            cfg.threads = threads;
            cfg.async_mode = "tau=2,spread=6,jitter=0.3,seed=11".into();
            let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
            let losses = t.run().losses;
            let stats = *t.fault_stats().unwrap();
            (losses, stats)
        };
        let (a, sa) = run(0);
        assert_eq!(a, run(0).0, "rerun must be byte-identical");
        assert_eq!(a, run(1).0, "parallel != serial");
        assert!(a.iter().all(|l| l.is_finite()));
        assert!(sa.async_stale_messages > 0, "spread=6 never delivered stale");
        assert_eq!(sa.masked_edges, 0, "async staleness must not mask edges");
    }

    #[test]
    fn async_allreduce_baseline_reports_barrier_time_only() {
        let mut cfg = small_cfg("pmsgd", 10);
        cfg.async_mode = "tau=2,spread=4,jitter=0.2".into();
        let mut t = Trainer::new(cfg, mlp_workload(4)).unwrap();
        let r = t.run();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert!(t.fault_stats().is_none(), "pmsgd must not grow a fault engine");
        let rep = t.async_report().unwrap();
        assert_eq!(rep.step_done_s.len(), 10);
        assert_eq!(rep.max_staleness, 0, "all-reduce is a barrier: nothing stales");
        assert!(rep.total_wait_s > 0.0, "a 4x spread barrier must wait");
        assert!(rep.makespan_s > 0.0);
    }

    #[test]
    fn async_rejects_time_varying_topologies_and_slowmo() {
        let mut cfg = small_cfg("decentlam", 5);
        cfg.topology = "bipartite".into();
        cfg.async_mode = "tau=1".into();
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
        let mut cfg = small_cfg("slowmo", 5);
        cfg.async_mode = "tau=1".into();
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
        let mut bad = small_cfg("decentlam", 5);
        bad.async_mode = "tau=999".into();
        assert!(Trainer::new(bad, mlp_workload(4)).is_err());
    }

    #[test]
    fn bad_codec_spec_rejected_at_construction() {
        let mut cfg = small_cfg("dsgd", 5);
        cfg.codec = "zfp".into();
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
    }

    #[test]
    fn bad_fault_spec_rejected_at_construction() {
        let mut cfg = small_cfg("dsgd", 5);
        cfg.faults = "drop=7".into();
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
    }

    #[test]
    fn node_count_mismatch_rejected() {
        let mut cfg = small_cfg("dmsgd", 5);
        cfg.nodes = 6;
        assert!(Trainer::new(cfg, mlp_workload(4)).is_err());
    }

    #[test]
    fn large_ring_trains_without_dense_matrix() {
        // n=128 on a ring: the dense engine would rebuild/walk 16K-entry
        // matrices; the sparse engine holds 3n entries. A couple of
        // linreg steps must run quickly and keep the mean dynamics.
        let p = LinRegProblem::generate(128, 4, 6, 9);
        let mut cfg = small_cfg("dsgd", 3);
        cfg.nodes = 128;
        cfg.lr = 0.01;
        let mut t = Trainer::new(cfg, linreg::workload(p)).unwrap();
        assert_eq!(t.comm.nnz(), 3 * 128);
        for k in 0..3 {
            let loss = t.step(k);
            assert!(loss.is_finite());
        }
    }
}
