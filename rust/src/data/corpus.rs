//! Tiny text corpus + byte-level tokenizer for the end-to-end LM
//! example. Ships a built-in public-domain corpus (no network) and
//! supports loading any UTF-8 file. Tokens are printable ASCII mapped to
//! 0..95 (vocab 96, matching `TransformerConfig::vocab`).

use crate::util::rng::Pcg64;

/// Vocab: printable ASCII 0x20..0x7F -> 0..95; everything else -> 0 (space).
pub const VOCAB: usize = 96;

pub fn encode_byte(b: u8) -> i32 {
    if (0x20..0x80).contains(&b) {
        (b - 0x20) as i32
    } else if b == b'\n' {
        0
    } else {
        0
    }
}

pub fn decode_token(t: i32) -> char {
    let t = t.clamp(0, (VOCAB - 1) as i32) as u8;
    (t + 0x20) as char
}

/// A tokenized corpus with node sharding + batch sampling.
pub struct Corpus {
    pub tokens: Vec<i32>,
}

impl Corpus {
    pub fn from_text(text: &str) -> Corpus {
        Corpus { tokens: text.bytes().map(encode_byte).collect() }
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Corpus> {
        Ok(Self::from_text(&std::fs::read_to_string(path)?))
    }

    /// The built-in corpus: a few public-domain passages, repeated enough
    /// to give a few hundred KB of training text.
    pub fn builtin() -> Corpus {
        let base = concat!(
            "It is a truth universally acknowledged, that a single man in ",
            "possession of a good fortune, must be in want of a wife. ",
            "However little known the feelings or views of such a man may be ",
            "on his first entering a neighbourhood, this truth is so well ",
            "fixed in the minds of the surrounding families, that he is ",
            "considered the rightful property of some one or other of their ",
            "daughters. ",
            "Call me Ishmael. Some years ago, never mind how long precisely, ",
            "having little or no money in my purse, and nothing particular ",
            "to interest me on shore, I thought I would sail about a little ",
            "and see the watery part of the world. ",
            "We the people, in order to form a more perfect union, establish ",
            "justice, insure domestic tranquility, provide for the common ",
            "defence, promote the general welfare, and secure the blessings ",
            "of liberty to ourselves and our posterity. ",
            "In the beginning the universe was created. This has made a lot ",
            "of people very angry and been widely regarded as a bad move. ",
            "The quick brown fox jumps over the lazy dog; pack my box with ",
            "five dozen liquor jugs. ",
        );
        Corpus::from_text(&base.repeat(64))
    }

    /// Contiguous shard of the corpus for one node (decentralized data
    /// parallel: node i reads tokens [i·L/n, (i+1)·L/n)).
    pub fn shard(&self, rank: usize, nodes: usize) -> CorpusShard {
        let l = self.tokens.len();
        let per = l / nodes;
        let start = rank * per;
        let end = if rank + 1 == nodes { l } else { start + per };
        CorpusShard {
            tokens: self.tokens[start..end].to_vec(),
            rng: Pcg64::new(0xc0de, rank as u64),
        }
    }
}

/// One node's token stream: samples random (input, target) windows.
pub struct CorpusShard {
    tokens: Vec<i32>,
    rng: Pcg64,
}

impl CorpusShard {
    /// Raw PCG64 counters of the window-sampling RNG — the shard's only
    /// cross-step state (checkpointing, DESIGN.md §9).
    pub fn export_rng(&self) -> [u64; 4] {
        self.rng.raw_state()
    }

    /// Restore counters captured by [`CorpusShard::export_rng`].
    pub fn restore_rng(&mut self, raw: [u64; 4]) {
        self.rng = Pcg64::from_raw_state(raw);
    }

    /// Fill `(batch, seq)` token windows; targets are inputs shifted by 1.
    pub fn next_batch(&mut self, batch: usize, seq: usize, xs: &mut [i32], ys: &mut [i32]) {
        assert!(self.tokens.len() > seq + 1, "shard too small for seq_len");
        assert_eq!(xs.len(), batch * seq);
        assert_eq!(ys.len(), batch * seq);
        for b in 0..batch {
            let start = self.rng.below(self.tokens.len() - seq - 1);
            xs[b * seq..(b + 1) * seq].copy_from_slice(&self.tokens[start..start + seq]);
            ys[b * seq..(b + 1) * seq]
                .copy_from_slice(&self.tokens[start + 1..start + seq + 1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip_printables() {
        for b in 0x20u8..0x7f {
            let t = encode_byte(b);
            assert_eq!(decode_token(t) as u8, b);
        }
        assert_eq!(encode_byte(0x07), 0, "control chars map to space");
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = Corpus::builtin();
        assert!(c.tokens.len() > 50_000);
        assert!(c.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn shards_partition_corpus() {
        let c = Corpus::builtin();
        let total: usize = (0..4).map(|r| c.shard(r, 4).tokens.len()).sum();
        assert_eq!(total, c.tokens.len());
    }

    #[test]
    fn batch_targets_shift_by_one() {
        let c = Corpus::from_text(&"abcdefgh".repeat(100));
        let mut sh = c.shard(0, 1);
        let (b, t) = (2, 8);
        let mut xs = vec![0i32; b * t];
        let mut ys = vec![0i32; b * t];
        sh.next_batch(b, t, &mut xs, &mut ys);
        // target[k] should equal input[k+1] within each window
        for row in 0..b {
            for k in 0..t - 1 {
                assert_eq!(ys[row * t + k], xs[row * t + k + 1]);
            }
        }
    }
}
