//! Full-batch linear regression (paper App. G.2) — the workload behind
//! Figs. 2–3 and the Table 2 bias-scaling verification.
//!
//!   f_i(x) = ½‖A_i x − b_i‖²,  A_i ∈ R^{50×30} ~ N(0,1),
//!   b_i = A_i x° + s,  s ~ N(0, 0.01²)
//!
//! Exact gradients ∇f_i(x) = A_iᵀ(A_i x − b_i); the global solution x*
//! solves (Σ A_iᵀA_i) x = Σ A_iᵀ b_i (computed by Gaussian elimination).

use crate::util::rng::Pcg64;

/// One decentralized least-squares instance.
#[derive(Debug, Clone)]
pub struct LinRegProblem {
    pub n_nodes: usize,
    pub rows: usize,
    pub dim: usize,
    /// Per node: A_i (rows x dim, row-major) and b_i.
    pub a: Vec<Vec<f32>>,
    pub b: Vec<Vec<f32>>,
    /// Global least-squares solution x*.
    pub x_star: Vec<f32>,
}

impl LinRegProblem {
    /// Generate with the paper's defaults (n=8, 50×30, noise 0.01).
    pub fn generate(n_nodes: usize, rows: usize, dim: usize, seed: u64) -> LinRegProblem {
        let mut rng = Pcg64::new(seed, 0x11e6);
        let mut x0 = vec![0.0f32; dim];
        rng.normal_fill(&mut x0, 1.0);
        let mut a = Vec::with_capacity(n_nodes);
        let mut b = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let mut ai = vec![0.0f32; rows * dim];
            rng.normal_fill(&mut ai, 1.0);
            let mut bi = vec![0.0f32; rows];
            for r in 0..rows {
                let mut v = 0.0f32;
                for c in 0..dim {
                    v += ai[r * dim + c] * x0[c];
                }
                bi[r] = v + rng.normal() as f32 * 0.01;
            }
            a.push(ai);
            b.push(bi);
        }
        let x_star = solve_normal_equations(&a, &b, n_nodes, rows, dim);
        LinRegProblem { n_nodes, rows, dim, a, b, x_star }
    }

    /// Exact local gradient ∇f_i(x) = A_iᵀ(A_i x − b_i).
    pub fn grad(&self, node: usize, x: &[f32], out: &mut [f32]) {
        let (rows, dim) = (self.rows, self.dim);
        let a = &self.a[node];
        let b = &self.b[node];
        out.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..rows {
            let mut resid = -b[r];
            let row = &a[r * dim..(r + 1) * dim];
            for c in 0..dim {
                resid += row[c] * x[c];
            }
            for c in 0..dim {
                out[c] += row[c] * resid;
            }
        }
    }

    /// Local loss f_i(x).
    pub fn loss(&self, node: usize, x: &[f32]) -> f64 {
        let (rows, dim) = (self.rows, self.dim);
        let mut total = 0.0f64;
        for r in 0..rows {
            let mut resid = -self.b[node][r] as f64;
            for c in 0..dim {
                resid += self.a[node][r * dim + c] as f64 * x[c] as f64;
            }
            total += 0.5 * resid * resid;
        }
        total
    }

    /// Relative limiting error (the paper's y-axis):
    /// (1/n) Σ_i ‖x_i − x*‖² / ‖x*‖².
    pub fn relative_error(&self, xs: &[Vec<f32>]) -> f64 {
        let denom = crate::util::math::dot(&self.x_star, &self.x_star);
        let sq = xs.iter().map(|x| crate::util::math::dist2(x, &self.x_star));
        let num = crate::util::math::sum_f64(sq) / xs.len() as f64;
        num / denom
    }

    /// Data-inconsistency b² = (1/n)Σ‖∇f_i(x*)‖² (Proposition 2's knob).
    pub fn b_squared(&self) -> f64 {
        let mut g = vec![0.0f32; self.dim];
        let mut total = 0.0;
        for i in 0..self.n_nodes {
            self.grad(i, &self.x_star, &mut g);
            total += crate::util::math::dot(&g, &g);
        }
        total / self.n_nodes as f64
    }
}

/// Solve (Σ AᵀA) x = Σ Aᵀ b by Gaussian elimination with partial pivoting.
fn solve_normal_equations(
    a: &[Vec<f32>],
    b: &[Vec<f32>],
    n_nodes: usize,
    rows: usize,
    dim: usize,
) -> Vec<f32> {
    let mut h = vec![0.0f64; dim * dim];
    let mut rhs = vec![0.0f64; dim];
    for i in 0..n_nodes {
        for r in 0..rows {
            let row = &a[i][r * dim..(r + 1) * dim];
            for c1 in 0..dim {
                rhs[c1] += row[c1] as f64 * b[i][r] as f64;
                for c2 in 0..dim {
                    h[c1 * dim + c2] += row[c1] as f64 * row[c2] as f64;
                }
            }
        }
    }
    // Gaussian elimination.
    for col in 0..dim {
        // pivot
        let mut piv = col;
        for r in (col + 1)..dim {
            if h[r * dim + col].abs() > h[piv * dim + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..dim {
                h.swap(col * dim + c, piv * dim + c);
            }
            rhs.swap(col, piv);
        }
        let diag = h[col * dim + col];
        assert!(diag.abs() > 1e-12, "singular normal equations");
        for r in 0..dim {
            if r == col {
                continue;
            }
            let f = h[r * dim + col] / diag;
            if f == 0.0 {
                continue;
            }
            for c in col..dim {
                h[r * dim + c] -= f * h[col * dim + c];
            }
            rhs[r] -= f * rhs[col];
        }
    }
    (0..dim).map(|c| (rhs[c] / h[c * dim + c]) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math;

    #[test]
    fn solution_has_zero_global_gradient() {
        let p = LinRegProblem::generate(4, 20, 8, 3);
        let mut g = vec![0.0f32; 8];
        let mut total = vec![0.0f32; 8];
        for i in 0..4 {
            p.grad(i, &p.x_star, &mut g);
            math::axpy(&mut total, 1.0, &g);
        }
        assert!(math::norm2(&total) < 1e-2, "sum grad at x* = {}", math::norm2(&total));
    }

    #[test]
    fn x_star_close_to_planted_solution() {
        // Noise 0.01 -> recovered x* ~ planted x0.
        let p = LinRegProblem::generate(8, 50, 30, 1);
        // re-generate planted x0 with same stream to compare
        let mut rng = Pcg64::new(1, 0x11e6);
        let mut x0 = vec![0.0f32; 30];
        rng.normal_fill(&mut x0, 1.0);
        let rel = math::dist2(&p.x_star, &x0).sqrt() / math::norm2(&x0);
        assert!(rel < 0.01, "rel={rel}");
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = LinRegProblem::generate(2, 10, 5, 7);
        let mut rng = Pcg64::new(9, 1);
        let mut x = vec![0.0f32; 5];
        rng.normal_fill(&mut x, 1.0);
        let mut g = vec![0.0f32; 5];
        p.grad(0, &x, &mut g);
        let eps = 1e-3f32;
        for k in 0..5 {
            let mut xp = x.clone();
            xp[k] += eps;
            let mut xm = x.clone();
            xm[k] -= eps;
            let fd = ((p.loss(0, &xp) - p.loss(0, &xm)) / (2.0 * eps as f64)) as f32;
            assert!((fd - g[k]).abs() < 0.05 * (1.0 + fd.abs()), "k={k} fd={fd} g={}", g[k]);
        }
    }

    #[test]
    fn heterogeneity_positive() {
        let p = LinRegProblem::generate(8, 50, 30, 1);
        assert!(p.b_squared() > 0.0);
    }

    #[test]
    fn relative_error_zero_at_solution() {
        let p = LinRegProblem::generate(3, 20, 6, 5);
        let xs = vec![p.x_star.clone(); 3];
        assert!(p.relative_error(&xs) < 1e-12);
    }
}
