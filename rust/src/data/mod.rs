//! Synthetic workloads (the data substrate — DESIGN.md §2 documents the
//! ImageNet/Cifar → synthetic substitution).

pub mod corpus;
pub mod linreg;
pub mod synth;

pub use linreg::LinRegProblem;
pub use synth::{ClassificationData, NodeShard};
