//! Synthetic Gaussian-mixture classification with Dirichlet-heterogeneous
//! node partitions — the stand-in for Cifar-10 / ImageNet (DESIGN.md §2).
//!
//! Generation: `num_classes` cluster centers in R^input_dim; a sample of
//! class c is center_c + noise. Class separability (`margin`) controls
//! task difficulty; partition heterogeneity is a Dirichlet(α) draw per
//! node over classes — the b² knob of the paper. Small α ⇒ near-disjoint
//! label distributions across nodes ⇒ large inconsistency bias.

use crate::util::rng::Pcg64;

/// The full dataset plus per-node shards and a held-out eval split.
#[derive(Debug, Clone)]
pub struct ClassificationData {
    pub input_dim: usize,
    pub num_classes: usize,
    pub shards: Vec<NodeShard>,
    pub eval_x: Vec<f32>,
    pub eval_y: Vec<i32>,
    pub eval_n: usize,
}

/// One node's local data (row-major features).
#[derive(Debug, Clone)]
pub struct NodeShard {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub input_dim: usize,
    cursor: usize,
    order: Vec<usize>,
    rng: Pcg64,
}

impl NodeShard {
    fn new(x: Vec<f32>, y: Vec<i32>, input_dim: usize, seed: u64, rank: u64) -> NodeShard {
        let n = y.len();
        let mut rng = Pcg64::new(seed, SHARD_STREAM ^ rank);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        NodeShard { x, y, n, input_dim, cursor: 0, order, rng }
    }

    /// Copy the next micro-batch into caller buffers (wraps + reshuffles
    /// at epoch boundaries). Returns the number of samples written.
    pub fn next_batch(&mut self, bx: &mut [f32], by: &mut [i32]) -> usize {
        let b = by.len();
        assert_eq!(bx.len(), b * self.input_dim);
        for k in 0..b {
            if self.cursor >= self.n {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            by[k] = self.y[idx];
            let src = &self.x[idx * self.input_dim..(idx + 1) * self.input_dim];
            bx[k * self.input_dim..(k + 1) * self.input_dim].copy_from_slice(src);
        }
        b
    }

    /// Export the shard's cross-step sampling state — epoch cursor,
    /// shuffle order and RNG counters — for bitwise checkpoint/resume
    /// (DESIGN.md §9).
    pub fn export_cursor(&self) -> ShardCursor {
        ShardCursor {
            cursor: self.cursor as u64,
            order: self.order.iter().map(|&i| i as u32).collect(),
            rng: self.rng.raw_state(),
        }
    }

    /// Restore a cursor captured by [`NodeShard::export_cursor`]: the
    /// next `next_batch` yields exactly what the exported shard's would
    /// have.
    pub fn restore_cursor(&mut self, c: &ShardCursor) -> anyhow::Result<()> {
        anyhow::ensure!(
            c.order.len() == self.n,
            "shard cursor covers {} samples, shard holds {}",
            c.order.len(),
            self.n
        );
        anyhow::ensure!(
            c.cursor as usize <= self.n,
            "shard cursor position {} past shard size {}",
            c.cursor,
            self.n
        );
        self.cursor = c.cursor as usize;
        self.order = c.order.iter().map(|&i| i as usize).collect();
        self.rng = Pcg64::from_raw_state(c.rng);
        Ok(())
    }

    /// Label histogram (diagnostic for heterogeneity).
    pub fn label_histogram(&self, num_classes: usize) -> Vec<usize> {
        let mut h = vec![0usize; num_classes];
        for &y in &self.y {
            h[y as usize] += 1;
        }
        h
    }
}

/// RNG stream tag for shard shuffling (distinct from data generation).
const SHARD_STREAM: u64 = 0x5aa5_1234_9876_feed;

/// Cross-step sampling state of one shard, the unit a checkpoint must
/// carry so resumed runs draw the exact same micro-batches
/// (`rust/tests/elastic.rs` pins save → restore → batch equality).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardCursor {
    /// Position inside the current epoch's shuffle order.
    pub cursor: u64,
    /// The epoch's sample permutation.
    pub order: Vec<u32>,
    /// Raw PCG64 counters ([`Pcg64::raw_state`]) of the shuffle RNG.
    pub rng: [u64; 4],
}

/// Parameters for dataset synthesis.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub input_dim: usize,
    pub num_classes: usize,
    pub samples_per_node: usize,
    pub eval_samples: usize,
    pub nodes: usize,
    /// Cluster separation: higher = easier task.
    pub margin: f32,
    /// Within-class noise std.
    pub noise: f32,
    /// Dirichlet concentration for label heterogeneity across nodes.
    pub dirichlet_alpha: f64,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            input_dim: 64,
            num_classes: 10,
            samples_per_node: 2048,
            eval_samples: 2048,
            nodes: 8,
            margin: 2.2,
            noise: 1.0,
            dirichlet_alpha: 0.3,
            seed: 1,
        }
    }
}

impl ClassificationData {
    pub fn generate(spec: &SynthSpec) -> ClassificationData {
        let mut rng = Pcg64::new(spec.seed, 0xda7a);
        let d = spec.input_dim;
        let c = spec.num_classes;
        // Class centers.
        let mut centers = vec![0.0f32; c * d];
        rng.normal_fill(&mut centers, spec.margin / (d as f32).sqrt() * (d as f32).sqrt());
        // Normalize center norms to `margin`.
        for ci in 0..c {
            let row = &mut centers[ci * d..(ci + 1) * d];
            let norm = crate::util::math::norm2(row) as f32;
            if norm > 0.0 {
                let s = spec.margin / norm;
                row.iter_mut().for_each(|v| *v *= s);
            }
        }
        let sample = |class: usize, rng: &mut Pcg64, out: &mut [f32]| {
            rng.normal_fill(out, spec.noise);
            for (o, &cv) in out.iter_mut().zip(&centers[class * d..(class + 1) * d]) {
                *o += cv;
            }
        };

        // Per-node label distribution: Dirichlet(alpha) over classes.
        let mut shards = Vec::with_capacity(spec.nodes);
        for rank in 0..spec.nodes {
            let probs = rng.dirichlet(spec.dirichlet_alpha, c);
            // CDF sampling of labels.
            let mut cdf = vec![0.0f64; c];
            let mut acc = 0.0;
            for (k, &p) in probs.iter().enumerate() {
                acc += p;
                cdf[k] = acc;
            }
            let m = spec.samples_per_node;
            let mut xs = vec![0.0f32; m * d];
            let mut ys = vec![0i32; m];
            for s in 0..m {
                let u = rng.f64();
                let label = cdf.iter().position(|&p| u <= p).unwrap_or(c - 1);
                ys[s] = label as i32;
                sample(label, &mut rng, &mut xs[s * d..(s + 1) * d]);
            }
            shards.push(NodeShard::new(xs, ys, d, spec.seed, rank as u64));
        }

        // Balanced eval split.
        let en = spec.eval_samples;
        let mut ex = vec![0.0f32; en * d];
        let mut ey = vec![0i32; en];
        for s in 0..en {
            let label = s % c;
            ey[s] = label as i32;
            sample(label, &mut rng, &mut ex[s * d..(s + 1) * d]);
        }

        ClassificationData {
            input_dim: d,
            num_classes: c,
            shards,
            eval_x: ex,
            eval_y: ey,
            eval_n: en,
        }
    }

    /// Empirical heterogeneity: mean total-variation distance between
    /// node label distributions and the global one (0 = iid).
    pub fn heterogeneity(&self) -> f64 {
        let c = self.num_classes;
        let hists: Vec<Vec<usize>> =
            self.shards.iter().map(|s| s.label_histogram(c)).collect();
        let mut global = vec![0usize; c];
        for h in &hists {
            for (g, &v) in global.iter_mut().zip(h) {
                *g += v;
            }
        }
        let gn: f64 = global.iter().sum::<usize>() as f64;
        let gp: Vec<f64> = global.iter().map(|&v| v as f64 / gn).collect();
        let mut tv = 0.0;
        for h in &hists {
            let n: f64 = h.iter().sum::<usize>() as f64;
            let mut t = 0.0;
            for (k, &v) in h.iter().enumerate() {
                t += (v as f64 / n - gp[k]).abs();
            }
            tv += t / 2.0;
        }
        tv / hists.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = SynthSpec { samples_per_node: 100, eval_samples: 50, ..Default::default() };
        let a = ClassificationData::generate(&spec);
        let b = ClassificationData::generate(&spec);
        assert_eq!(a.shards.len(), 8);
        assert_eq!(a.shards[0].n, 100);
        assert_eq!(a.eval_n, 50);
        assert_eq!(a.shards[3].x, b.shards[3].x, "same seed, same data");
        let spec2 = SynthSpec { seed: 2, ..spec };
        let c = ClassificationData::generate(&spec2);
        assert_ne!(a.shards[0].x, c.shards[0].x);
    }

    #[test]
    fn alpha_controls_heterogeneity() {
        let base = SynthSpec { samples_per_node: 500, eval_samples: 10, ..Default::default() };
        let het = ClassificationData::generate(&SynthSpec {
            dirichlet_alpha: 0.05,
            ..base.clone()
        })
        .heterogeneity();
        let iid = ClassificationData::generate(&SynthSpec {
            dirichlet_alpha: 100.0,
            ..base
        })
        .heterogeneity();
        assert!(het > iid + 0.3, "het={het} iid={iid}");
    }

    #[test]
    fn batches_cycle_through_epoch() {
        let spec = SynthSpec { samples_per_node: 10, eval_samples: 4, ..Default::default() };
        let mut data = ClassificationData::generate(&spec);
        let shard = &mut data.shards[0];
        let d = shard.input_dim;
        let mut bx = vec![0.0f32; 4 * d];
        let mut by = vec![0i32; 4];
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..10 {
            shard.next_batch(&mut bx, &mut by);
            for k in 0..4 {
                // fingerprint the sample by its first feature bits
                seen.insert(bx[k * d].to_bits());
            }
        }
        assert!(seen.len() <= 10, "only 10 distinct samples exist");
        assert!(seen.len() >= 9, "epoch iteration should visit most samples");
    }

    #[test]
    fn shard_cursor_roundtrip_replays_batches() {
        let spec = SynthSpec { samples_per_node: 24, eval_samples: 4, ..Default::default() };
        let mut a = ClassificationData::generate(&spec);
        let shard = &mut a.shards[0];
        let d = shard.input_dim;
        let (mut bx, mut by) = (vec![0.0f32; 8 * d], vec![0i32; 8]);
        // Advance past an epoch boundary so the reshuffle RNG moved.
        for _ in 0..5 {
            shard.next_batch(&mut bx, &mut by);
        }
        let cur = shard.export_cursor();
        let mut b = ClassificationData::generate(&spec);
        b.shards[0].restore_cursor(&cur).unwrap();
        let (mut bx2, mut by2) = (vec![0.0f32; 8 * d], vec![0i32; 8]);
        for _ in 0..7 {
            shard.next_batch(&mut bx, &mut by);
            b.shards[0].next_batch(&mut bx2, &mut by2);
            assert_eq!(bx.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                bx2.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
            assert_eq!(by, by2);
        }
        // Mismatched shard size is rejected.
        let other = ClassificationData::generate(&SynthSpec {
            samples_per_node: 10,
            eval_samples: 4,
            ..Default::default()
        });
        let mut wrong = other.shards[0].clone();
        assert!(wrong.restore_cursor(&cur).is_err());
    }

    #[test]
    fn eval_is_balanced() {
        let spec = SynthSpec { samples_per_node: 10, eval_samples: 100, ..Default::default() };
        let data = ClassificationData::generate(&spec);
        let mut h = vec![0usize; data.num_classes];
        for &y in &data.eval_y {
            h[y as usize] += 1;
        }
        assert!(h.iter().all(|&v| v == 10));
    }
}
