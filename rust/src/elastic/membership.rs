//! Roster bookkeeping for elastic membership (DESIGN.md §9).
//!
//! Two index spaces coexist once the node set can change:
//!
//! * **stable ids** name physical nodes for the whole run (0..capacity;
//!   each owns its data shard, fault streams, codec streams and churn
//!   streams) — every seeded schedule keys on them, so a resize never
//!   perturbs another node's randomness;
//! * **dense rows** are the contiguous 0..m space the comm engine,
//!   optimizer rounds and executors see.
//!
//! The [`Roster`] is the bijection between the two: the active stable
//! ids sorted ascending ARE the dense order, so the mapping is fully
//! determined by the set membership — no positional state to corrupt
//! or checkpoint beyond the set itself.

use anyhow::Result;

use super::plan::StepChurn;

/// The active node set of an elastic run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Roster {
    capacity: usize,
    /// Active stable ids, sorted ascending (dense row = rank here).
    active: Vec<u32>,
}

impl Roster {
    /// Initial roster: stable ids 0..n0 active out of `capacity`.
    pub fn new(n0: usize, capacity: usize) -> Roster {
        assert!(n0 >= 1 && n0 <= capacity, "need 1 <= n0 <= capacity");
        Roster { capacity, active: (0..n0 as u32).collect() }
    }

    /// Rebuild from a snapshot's active set (sorted unique ids below
    /// `capacity`).
    pub fn from_active(active: Vec<u32>, capacity: usize) -> Result<Roster> {
        anyhow::ensure!(!active.is_empty(), "roster must keep at least one node");
        anyhow::ensure!(
            active.windows(2).all(|w| w[0] < w[1]),
            "active ids must be sorted and unique"
        );
        anyhow::ensure!(
            (*active.last().unwrap() as usize) < capacity,
            "active id {} outside capacity {capacity}",
            active.last().unwrap()
        );
        Ok(Roster { capacity, active })
    }

    /// Active node count m.
    pub fn n(&self) -> usize {
        self.active.len()
    }

    /// Stable-id capacity (= nmax).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Active stable ids, sorted (dense order).
    pub fn active(&self) -> &[u32] {
        &self.active
    }

    pub fn is_active(&self, id: u32) -> bool {
        self.active.binary_search(&id).is_ok()
    }

    /// Dense row of stable id `id` (None when parked).
    pub fn dense_of(&self, id: u32) -> Option<usize> {
        self.active.binary_search(&id).ok()
    }

    /// Stable id at dense row `dense`.
    pub fn id_at(&self, dense: usize) -> u32 {
        self.active[dense]
    }

    /// Parked ids, sorted — the tail order for engine slots.
    pub fn parked(&self) -> Vec<u32> {
        (0..self.capacity as u32).filter(|&id| !self.is_active(id)).collect()
    }

    /// Engine-slot order: active ids (dense order) then parked ids.
    pub fn slot_order(&self) -> Vec<u32> {
        let mut order = self.active.clone();
        order.extend(self.parked());
        order
    }

    /// Apply one step's realized events (leaves out, joins in).
    pub fn apply(&mut self, ev: &StepChurn) {
        self.active.retain(|id| !ev.leaves.contains(id));
        self.active.extend_from_slice(&ev.joins);
        self.active.sort_unstable();
        debug_assert!(
            self.active.windows(2).all(|w| w[0] < w[1]),
            "roster invariant broken: duplicate or unsorted ids"
        );
        debug_assert!(!self.active.is_empty());
    }
}

/// Cumulative membership accounting across a run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChurnStats {
    /// Nodes that joined (warm-started) over the run.
    pub joins: usize,
    /// Nodes that left over the run.
    pub leaves: usize,
    /// Steps at which the roster changed (and W was rebuilt).
    pub resizes: usize,
}

impl ChurnStats {
    pub fn record(&mut self, ev: &StepChurn) {
        self.joins += ev.joins.len();
        self.leaves += ev.leaves.len();
        self.resizes += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_roster_is_prefix_and_maps_both_ways() {
        let r = Roster::new(4, 8);
        assert_eq!(r.n(), 4);
        assert_eq!(r.capacity(), 8);
        assert_eq!(r.active(), &[0, 1, 2, 3]);
        assert_eq!(r.parked(), vec![4, 5, 6, 7]);
        assert_eq!(r.slot_order(), vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(r.dense_of(2), Some(2));
        assert_eq!(r.dense_of(5), None);
        assert_eq!(r.id_at(3), 3);
    }

    #[test]
    fn apply_keeps_sorted_dense_order() {
        let mut r = Roster::new(4, 8);
        r.apply(&StepChurn { joins: vec![6], leaves: vec![1] });
        assert_eq!(r.active(), &[0, 2, 3, 6]);
        assert_eq!(r.dense_of(6), Some(3));
        assert_eq!(r.dense_of(1), None);
        assert!(r.is_active(6) && !r.is_active(1));
        assert_eq!(r.parked(), vec![1, 4, 5, 7]);
        r.apply(&StepChurn { joins: vec![1], leaves: vec![6] });
        assert_eq!(r.active(), &[0, 1, 2, 3]);
    }

    #[test]
    fn from_active_validates() {
        assert!(Roster::from_active(vec![0, 2, 5], 8).is_ok());
        assert!(Roster::from_active(vec![], 8).is_err());
        assert!(Roster::from_active(vec![2, 2], 8).is_err());
        assert!(Roster::from_active(vec![3, 1], 8).is_err());
        assert!(Roster::from_active(vec![0, 8], 8).is_err());
    }

    #[test]
    fn churn_stats_accumulate() {
        let mut s = ChurnStats::default();
        s.record(&StepChurn { joins: vec![4, 5], leaves: vec![0] });
        s.record(&StepChurn { joins: vec![], leaves: vec![2] });
        assert_eq!(s, ChurnStats { joins: 2, leaves: 2, resizes: 2 });
    }
}
