//! Elastic membership (DESIGN.md §9): seeded node churn, live topology
//! resize and bitwise checkpoint/resume.
//!
//! Real decentralized fleets grow and shrink mid-run — the systems gap
//! "From promise to practice" (arXiv 2410.11998) names between
//! decentralized theory (which fixes the node set) and deployable
//! training. This subsystem makes the roster a first-class, *seeded*
//! quantity:
//!
//! * [`plan`] — a [`ChurnPlan`] draws per-(step, stable id) join/leave
//!   events from counter-keyed PCG64 streams, in the style of
//!   `sim::plan::FaultPlan`: replayable, iteration-order free, and
//!   realized deterministically against the `[nmin, nmax]` roster
//!   bounds.
//! * [`membership`] — the [`Roster`] bijection between *stable ids*
//!   (physical nodes, what every seeded schedule keys on) and *dense
//!   rows* (the contiguous 0..m space the comm engine and optimizer
//!   rounds see). The trainer extends the PR-1 in-place CSR rebuild to
//!   a changing n: departures fold out of the mixing graph and the
//!   Metropolis–Hastings weights are rebuilt over the survivors, so
//!   realized W stays symmetric doubly stochastic at every size
//!   (`rust/tests/elastic.rs` pins row sums and symmetry after every
//!   resize); joiners warm-start from their neighbors' decoded wire
//!   average with momentum zeroed.
//! * [`snapshot`] — a versioned, checksummed [`Snapshot`] of the
//!   complete cross-step trainer state (params, momentum, aux buffers,
//!   shard cursors + RNG counters, codec EF residuals, fault cache and
//!   async ring history, the active roster), such that
//!   save → restore → continue is bitwise identical to an
//!   uninterrupted run.
//!
//! Wired through `Config::churn` /
//! `--churn join=0.02,leave=0.02,nmin=8,nmax=64,seed=7`,
//! `Trainer::{checkpoint, restore, resume}` and
//! `experiments::fig_elastic` (`fig-elastic --smoke` is the CI gate).

pub mod membership;
pub mod plan;
pub mod snapshot;

pub use membership::{ChurnStats, Roster};
pub use plan::{ChurnPlan, ChurnSpec, StepChurn};
pub use snapshot::Snapshot;
