//! Seeded, deterministic churn schedules — the membership analog of
//! [`crate::sim::FaultPlan`] (DESIGN.md §9).
//!
//! A [`ChurnPlan`] turns a [`ChurnSpec`] (per-step join/leave rates
//! plus roster bounds) into concrete per-step membership events. Every
//! decision — "does active node `id` leave at step k?", "does parked
//! id `id` join?" — is drawn from its own counter-keyed
//! [`Pcg64`] stream, so the schedule is
//!
//! * **replayable**: the same (spec, step, id) always yields the same
//!   answer, independent of query order or repetition;
//! * **stable-id keyed**: a node keeps its stream however the dense
//!   roster is packed around it, so fault/codec schedules (which share
//!   the discipline) stay valid across resizes.
//!
//! Realization is deterministic too: candidate leaves are capped so
//! the active count never drops below `nmin`, candidate joins so it
//! never exceeds `nmax`, both lowest-id-first; and events begin at
//! step 1 (step 0 always trains on the initial roster). The realized
//! topology is rebuilt over the surviving roster each resize, so it
//! can never disconnect — the trainer asserts connectivity at every
//! resize as defense in depth.

use anyhow::{bail, Result};

use crate::util::kvspec::KvSpec;
use crate::util::rng::Pcg64;

use super::membership::Roster;

/// Per-step churn rates plus roster bounds and the schedule seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnSpec {
    /// P(a parked stable id joins at a step).
    pub join: f64,
    /// P(an active node leaves at a step).
    pub leave: f64,
    /// Roster floor: leaves are capped so the active count never drops
    /// below it. 0 = unset until [`ChurnSpec::resolve`].
    pub nmin: usize,
    /// Roster capacity: the stable-id space is 0..nmax and the workload
    /// must supply one shard per stable id. 0 = unset until
    /// [`ChurnSpec::resolve`].
    pub nmax: usize,
    /// Seed of the churn schedule (independent of the topology seed).
    pub seed: u64,
    /// True when `seed=` was NOT explicit — the seed should follow the
    /// run seed (resolved later via [`ChurnSpec::with_run_seed`]).
    pub seed_from_run: bool,
}

impl Default for ChurnSpec {
    fn default() -> Self {
        ChurnSpec { join: 0.0, leave: 0.0, nmin: 0, nmax: 0, seed: 0, seed_from_run: true }
    }
}

impl KvSpec for ChurnSpec {
    const NAME: &'static str = "churn";
    const BARE_TRUE: bool = true;

    fn begin(_head: Option<&str>, default_seed: u64) -> Result<ChurnSpec> {
        Ok(ChurnSpec { seed: default_seed, ..Default::default() })
    }

    fn set_kv(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "join" => self.join = parse_rate(key, v)?,
            "leave" => self.leave = parse_rate(key, v)?,
            "nmin" => self.nmin = parse_count(key, v)?,
            "nmax" => self.nmax = parse_count(key, v)?,
            "seed" => {
                self.seed = v.trim().parse()?;
                self.seed_from_run = false;
            }
            other => bail!("unknown churn key `{other}` (join|leave|nmin|nmax|seed)"),
        }
        Ok(())
    }

    fn finish(&self) -> Result<()> {
        if self.nmin > 0 && self.nmax > 0 && self.nmin > self.nmax {
            bail!("churn bounds nmin={} > nmax={}", self.nmin, self.nmax);
        }
        Ok(())
    }

    fn to_spec_string(&self) -> String {
        let mut s = format!("join={},leave={}", self.join, self.leave);
        if self.nmin > 0 {
            s.push_str(&format!(",nmin={}", self.nmin));
        }
        if self.nmax > 0 {
            s.push_str(&format!(",nmax={}", self.nmax));
        }
        if !self.seed_from_run {
            s.push_str(&format!(",seed={}", self.seed));
        }
        s
    }
}

impl ChurnSpec {
    /// Parse the CLI form `join=0.02,leave=0.02,nmin=8,nmax=64,seed=7`.
    /// Rates in [0, 1]; omitted keys default to 0 / `default_seed`;
    /// `nmin`/`nmax` default to the run's node count at
    /// [`ChurnSpec::resolve`]. A bare `--churn` (the literal "true")
    /// parses as all defaults, like `--async`.
    pub fn parse(s: &str, default_seed: u64) -> Result<ChurnSpec> {
        <ChurnSpec as KvSpec>::parse(s, default_seed)
    }

    /// Canonical spec string; reparses (default_seed 0) to an equal spec.
    pub fn to_spec_string(&self) -> String {
        <ChurnSpec as KvSpec>::to_spec_string(self)
    }

    /// Resolve seed inheritance: adopt `run_seed` unless `seed=` was
    /// explicit in the spec string.
    pub fn with_run_seed(mut self, run_seed: u64) -> ChurnSpec {
        if self.seed_from_run {
            self.seed = run_seed;
        }
        self
    }

    /// Fill unset bounds from the run's initial node count and validate
    /// `1 ≤ nmin ≤ n0 ≤ nmax`. `nmin` defaults to min(2, n0), `nmax`
    /// to n0 (a fixed-capacity roster unless the user opens headroom).
    pub fn resolve(mut self, n0: usize) -> Result<ChurnSpec> {
        if self.nmax == 0 {
            self.nmax = n0;
        }
        if self.nmin == 0 {
            self.nmin = 2.min(n0);
        }
        if !(1 <= self.nmin && self.nmin <= n0 && n0 <= self.nmax) {
            bail!(
                "churn bounds must satisfy 1 <= nmin <= nodes <= nmax, \
                 got nmin={} nodes={n0} nmax={}",
                self.nmin,
                self.nmax
            );
        }
        Ok(self)
    }

    /// True when no event can ever fire — the static degenerate plan.
    pub fn is_zero(&self) -> bool {
        self.join == 0.0 && self.leave == 0.0
    }
}

fn parse_rate(key: &str, v: &str) -> Result<f64> {
    let rate: f64 = v.trim().parse()?;
    if !(0.0..=1.0).contains(&rate) {
        bail!("churn rate `{key}={rate}` outside [0, 1]");
    }
    Ok(rate)
}

fn parse_count(key: &str, v: &str) -> Result<usize> {
    let n: usize = v.trim().parse()?;
    if n == 0 {
        bail!("churn bound `{key}` must be >= 1");
    }
    Ok(n)
}

/// Realized membership events of one step, in stable ids (sorted).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepChurn {
    /// Parked ids that join this step (warm-started before the round).
    pub joins: Vec<u32>,
    /// Active ids that leave this step (gone before the round).
    pub leaves: Vec<u32>,
}

impl StepChurn {
    pub fn is_empty(&self) -> bool {
        self.joins.is_empty() && self.leaves.is_empty()
    }
}

/// Domain-separation tags: one independent stream family per event kind.
const TAG_JOIN: u64 = 0xe1a5_0a11;
const TAG_LEAVE: u64 = 0xe1a5_0ff5;

/// A deterministic membership schedule over steps.
#[derive(Debug, Clone)]
pub struct ChurnPlan {
    pub spec: ChurnSpec,
}

impl ChurnPlan {
    pub fn new(spec: ChurnSpec) -> ChurnPlan {
        ChurnPlan { spec }
    }

    /// One Bernoulli draw on the (tag, step, id) stream — the shared
    /// counter-keyed discipline ([`Pcg64::counter_keyed`], the same
    /// helper `sim::FaultPlan` and the codec streams draw from).
    fn draw(&self, tag: u64, step: usize, id: u64, rate: f64) -> bool {
        if rate <= 0.0 {
            return false;
        }
        if rate >= 1.0 {
            return true;
        }
        Pcg64::counter_keyed(self.spec.seed, tag, step as u64, id).f64() < rate
    }

    /// Does active node `id` want to leave at `step`?
    pub fn wants_leave(&self, step: usize, id: u32) -> bool {
        self.draw(TAG_LEAVE, step, id as u64, self.spec.leave)
    }

    /// Does parked id `id` want to join at `step`?
    pub fn wants_join(&self, step: usize, id: u32) -> bool {
        self.draw(TAG_JOIN, step, id as u64, self.spec.join)
    }

    /// Realized events at `step` for the current roster: per-id wishes
    /// capped deterministically (lowest id first) to the `[nmin, nmax]`
    /// bounds. Step 0 is always empty — the initial roster trains at
    /// least one round before anything moves.
    pub fn step_churn(&self, step: usize, roster: &Roster) -> StepChurn {
        if step == 0 || self.spec.is_zero() {
            return StepChurn::default();
        }
        let mut leaves: Vec<u32> = roster
            .active()
            .iter()
            .copied()
            .filter(|&id| self.wants_leave(step, id))
            .collect();
        leaves.truncate(roster.n().saturating_sub(self.spec.nmin));
        let after = roster.n() - leaves.len();
        let mut joins: Vec<u32> = (0..self.spec.nmax as u32)
            .filter(|&id| !roster.is_active(id))
            .filter(|&id| self.wants_join(step, id))
            .collect();
        joins.truncate(self.spec.nmax - after);
        StepChurn { joins, leaves }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(s: &str) -> ChurnSpec {
        ChurnSpec::parse(s, 1).unwrap()
    }

    #[test]
    fn parse_full_spec_and_defaults() {
        let s = spec("join=0.02,leave=0.05,nmin=8,nmax=64,seed=7");
        assert_eq!(s.join, 0.02);
        assert_eq!(s.leave, 0.05);
        assert_eq!(s.nmin, 8);
        assert_eq!(s.nmax, 64);
        assert_eq!(s.seed, 7);
        assert!(!s.is_zero());
        let d = spec("");
        assert!(d.is_zero());
        assert_eq!(d.seed, 1, "seed defaults to the run seed");
        assert!(spec("true").is_zero(), "bare --churn parses as defaults");
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(ChurnSpec::parse("join=1.5", 0).is_err());
        assert!(ChurnSpec::parse("leave=-0.1", 0).is_err());
        assert!(ChurnSpec::parse("nmin=0", 0).is_err());
        assert!(ChurnSpec::parse("warp=0.1", 0).is_err());
        assert!(ChurnSpec::parse("join", 0).is_err());
        assert!(ChurnSpec::parse("nmin=9,nmax=4", 0).is_err());
    }

    #[test]
    fn exact_error_strings_are_pinned() {
        let e = ChurnSpec::parse("join=2", 0).unwrap_err().to_string();
        assert_eq!(e, "churn rate `join=2` outside [0, 1]");
        let e = ChurnSpec::parse("nmin=0", 0).unwrap_err().to_string();
        assert_eq!(e, "churn bound `nmin` must be >= 1");
        let e = ChurnSpec::parse("join", 0).unwrap_err().to_string();
        assert_eq!(e, "churn spec entry `join` is not key=value");
        let e = ChurnSpec::parse("warp=0.1", 0).unwrap_err().to_string();
        assert_eq!(e, "unknown churn key `warp` (join|leave|nmin|nmax|seed)");
        let e = ChurnSpec::parse("nmin=9,nmax=4", 0).unwrap_err().to_string();
        assert_eq!(e, "churn bounds nmin=9 > nmax=4");
    }

    #[test]
    fn spec_string_round_trips() {
        for s in ["true", "", "join=0.02,leave=0.05,nmin=8,nmax=64,seed=7", "join=0.1,nmax=16"] {
            let a = ChurnSpec::parse(s, 0).unwrap();
            let b = ChurnSpec::parse(&a.to_spec_string(), 0).unwrap();
            assert_eq!(a, b, "round trip of `{s}` via `{}`", a.to_spec_string());
        }
    }

    #[test]
    fn run_seed_resolution_respects_explicit_seed() {
        assert_eq!(ChurnSpec::parse("join=0.1", 0).unwrap().with_run_seed(42).seed, 42);
        assert_eq!(ChurnSpec::parse("join=0.1,seed=7", 0).unwrap().with_run_seed(42).seed, 7);
    }

    #[test]
    fn resolve_fills_bounds_and_validates() {
        let s = spec("join=0.1").resolve(8).unwrap();
        assert_eq!(s.nmin, 2);
        assert_eq!(s.nmax, 8);
        let s = spec("join=0.1,nmax=16").resolve(8).unwrap();
        assert_eq!(s.nmax, 16);
        assert!(spec("nmin=9").resolve(8).is_err(), "nmin above n0");
        assert!(spec("nmax=4").resolve(8).is_err(), "nmax below n0");
        let one = spec("").resolve(1).unwrap();
        assert_eq!(one.nmin, 1);
    }

    #[test]
    fn schedule_replays_identically_and_step0_is_quiet() {
        let plan = ChurnPlan::new(spec("join=0.3,leave=0.3,nmin=2,nmax=12").resolve(6).unwrap());
        let roster = Roster::new(6, 12);
        assert!(plan.step_churn(0, &roster).is_empty(), "step 0 must be quiet");
        for step in [1usize, 2, 17, 999] {
            let a = plan.step_churn(step, &roster);
            let b = plan.step_churn(step, &roster);
            assert_eq!(a, b, "step {step}");
        }
        let zero = ChurnPlan::new(spec("").resolve(6).unwrap());
        for step in 0..50 {
            assert!(zero.step_churn(step, &roster).is_empty());
        }
    }

    #[test]
    fn bounds_hold_over_a_long_schedule() {
        let sp = spec("join=0.4,leave=0.4,nmin=3,nmax=10,seed=5").resolve(6).unwrap();
        let plan = ChurnPlan::new(sp);
        let mut roster = Roster::new(6, 10);
        let (mut joins, mut leaves) = (0usize, 0usize);
        for step in 0..300 {
            let ev = plan.step_churn(step, &roster);
            for &j in &ev.joins {
                assert!(!roster.is_active(j), "step {step}: joiner {j} already active");
            }
            for &l in &ev.leaves {
                assert!(roster.is_active(l), "step {step}: leaver {l} not active");
            }
            joins += ev.joins.len();
            leaves += ev.leaves.len();
            roster.apply(&ev);
            assert!(
                (sp.nmin..=sp.nmax).contains(&roster.n()),
                "step {step}: roster size {} outside [{}, {}]",
                roster.n(),
                sp.nmin,
                sp.nmax
            );
        }
        assert!(joins > 0 && leaves > 0, "rates 0.4 never realized an event");
    }

    #[test]
    fn different_seeds_differ() {
        let roster = Roster::new(8, 16);
        let mk = |seed: u64| {
            let sp = ChurnSpec { join: 0.5, leave: 0.5, nmin: 2, nmax: 16, seed, ..Default::default() };
            let plan = ChurnPlan::new(sp);
            (1..20).map(|k| plan.step_churn(k, &roster)).collect::<Vec<_>>()
        };
        assert_ne!(mk(1), mk(2));
    }
}
