//! Versioned, checksummed trainer snapshots (DESIGN.md §9).
//!
//! A [`Snapshot`] captures the COMPLETE cross-step mutable state of a
//! [`crate::coordinator::Trainer`]: per-node params, momentum and aux
//! buffers; per-shard batch cursors and RNG counters; codec
//! error-feedback residuals; the fault engine's publish cache, async
//! ring history and cumulative stats; and the active roster. Restoring
//! it into a freshly constructed trainer of the SAME configuration and
//! continuing is bitwise identical to the uninterrupted run
//! (`rust/tests/elastic.rs` pins this across every optimizer × codec ×
//! fault combination).
//!
//! ## Wire format (version 1, little-endian)
//!
//! ```text
//! magic "DLSNAP01" | version u32 | payload_len u64 | fnv1a64 u64 | payload
//! ```
//!
//! The checksum covers the payload only; readers verify it BEFORE
//! parsing, so a flipped byte fails loudly instead of resuming from
//! silently corrupt state. Strings are u32-length-prefixed UTF-8,
//! vectors u32-length-prefixed, and f32 lanes are raw LE bit patterns
//! (bit-exact round trip — the whole point).
//!
//! The [`SnapshotMeta`] header names the run the snapshot belongs to
//! (optimizer, topology, every spec string, seed, sizes, the
//! optimizer's aux-buffer labels); resume refuses on any mismatch — a
//! checkpoint is only bitwise-resumable into the exact configuration
//! that wrote it.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::synth::ShardCursor;
use crate::optim::NodeState;
use crate::sim::FaultStats;

use super::membership::ChurnStats;

/// File magic; the trailing "01" is the major layout generation (bump
/// together with [`VERSION`] on incompatible changes).
pub const MAGIC: &[u8; 8] = b"DLSNAP01";
/// Format version written (and the only one read).
pub const VERSION: u32 = 1;

/// Identity of the run a snapshot belongs to.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SnapshotMeta {
    pub optimizer: String,
    pub topology: String,
    /// The literal spec strings — byte equality is the compat check.
    pub codec: String,
    pub faults: String,
    pub async_mode: String,
    pub churn: String,
    pub seed: u64,
    /// Initial active node count (`Config::nodes`).
    pub nodes: u32,
    /// Stable-id capacity (= churn nmax; = nodes when not elastic).
    pub capacity: u32,
    /// Flat parameter dimension.
    pub dim: u32,
    /// Workload identity (`Workload::name`) — two architectures can
    /// share a flat dim, so the dim check alone cannot catch resuming
    /// into a different model/dataset.
    pub model: String,
    /// Comma-joined aux-buffer labels of the optimizer (layout check).
    pub aux_labels: String,
    /// Canonical fingerprint of every trajectory-determining hyper
    /// parameter (lr, momentum, schedule, batch shape, lazy-W, SlowMo
    /// knobs, …): resuming with a different lr or schedule would
    /// silently diverge from the uninterrupted run, so it refuses
    /// instead.
    pub hyper: String,
}

/// Fault-engine state carried by a checkpoint.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultState {
    /// The previous round's publish cache (None = cold).
    pub cache: Option<Vec<Vec<f32>>>,
    /// Cumulative fault accounting at the checkpoint.
    pub stats: FaultStats,
    /// Async per-slot ring history: (ring newest→oldest, staged).
    pub rings: Vec<(Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>)>,
}

/// The complete cross-step mutable state of a trainer.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub meta: SnapshotMeta,
    /// Next step the resumed run executes (steps 0..step are done).
    pub step: u64,
    /// Whether any membership change has happened (the resumed run
    /// keeps the time-varying guard engaged if so).
    pub churned: bool,
    /// Step at which the current topology realization was built (the
    /// last resize step; 0 before any resize) — seed-dependent kinds
    /// (erdos) need it to rebuild the exact graph.
    pub topo_step: u64,
    /// Cumulative membership accounting at the checkpoint.
    pub churn_stats: ChurnStats,
    /// Active stable ids, sorted ascending (dense order).
    pub active: Vec<u32>,
    /// Per-node optimizer state in dense order (x, momentum, aux).
    pub states: Vec<NodeState>,
    /// Per-STABLE-id shard cursors, `capacity` entries (None =
    /// stateless gradient engine).
    pub cursors: Vec<Option<ShardCursor>>,
    /// Codec EF residuals `[slot][dense node][dim]` (None = no codec
    /// state attached to the run).
    pub codec_residuals: Option<Vec<Vec<Vec<f32>>>>,
    /// Fault-engine state (None = no fault engine attached).
    pub faults: Option<FaultState>,
}

// ---------------------------------------------------------------- bytes

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Default)]
struct W {
    buf: Vec<u8>,
}

impl W {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn boolean(&mut self, v: bool) {
        self.u8(v as u8);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn u32s(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn rows(&mut self, rows: &[Vec<f32>]) {
        self.u32(rows.len() as u32);
        for r in rows {
            self.f32s(r);
        }
    }
}

struct R<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> R<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.i.checked_add(n).filter(|&e| e <= self.b.len());
        match end {
            Some(e) => {
                let s = &self.b[self.i..e];
                self.i = e;
                Ok(s)
            }
            None => bail!("snapshot truncated at byte {}", self.i),
        }
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn boolean(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => bail!("bad bool byte {v} at offset {}", self.i - 1),
        }
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        Ok(std::str::from_utf8(b)?.to_string())
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let b = self.take(n.checked_mul(4).context("length overflow")?)?;
        Ok(b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let b = self.take(n.checked_mul(4).context("length overflow")?)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn rows(&mut self) -> Result<Vec<Vec<f32>>> {
        let n = self.u32()? as usize;
        let mut rows = Vec::with_capacity(self.cap(n, 4));
        for _ in 0..n {
            rows.push(self.f32s()?);
        }
        Ok(rows)
    }
    /// Sanity-capped capacity hint for a count read from the payload:
    /// every element still needs at least `min_bytes` more payload, so
    /// a forged/garbage count can never force a huge up-front
    /// allocation — parsing simply fails with Err on the missing bytes
    /// (fnv1a64 is integrity, not authentication).
    fn cap(&self, n: usize, min_bytes: usize) -> usize {
        n.min((self.b.len() - self.i) / min_bytes.max(1))
    }
    fn done(&self) -> Result<()> {
        if self.i != self.b.len() {
            bail!("snapshot has {} trailing bytes", self.b.len() - self.i);
        }
        Ok(())
    }
}

impl Snapshot {
    /// Serialize to the checksummed wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = W::default();
        // -- meta
        w.string(&self.meta.optimizer);
        w.string(&self.meta.topology);
        w.string(&self.meta.codec);
        w.string(&self.meta.faults);
        w.string(&self.meta.async_mode);
        w.string(&self.meta.churn);
        w.u64(self.meta.seed);
        w.u32(self.meta.nodes);
        w.u32(self.meta.capacity);
        w.u32(self.meta.dim);
        w.string(&self.meta.model);
        w.string(&self.meta.aux_labels);
        w.string(&self.meta.hyper);
        // -- cursor position
        w.u64(self.step);
        w.boolean(self.churned);
        w.u64(self.topo_step);
        w.u64(self.churn_stats.joins as u64);
        w.u64(self.churn_stats.leaves as u64);
        w.u64(self.churn_stats.resizes as u64);
        w.u32s(&self.active);
        // -- per-node optimizer state
        w.u32(self.states.len() as u32);
        for st in &self.states {
            w.f32s(&st.x);
            w.f32s(&st.m);
            w.u32(st.aux.len() as u32);
            for a in &st.aux {
                w.f32s(a);
            }
        }
        // -- per-stable-id shard cursors
        w.u32(self.cursors.len() as u32);
        for c in &self.cursors {
            match c {
                None => w.boolean(false),
                Some(c) => {
                    w.boolean(true);
                    w.u64(c.cursor);
                    w.u32s(&c.order);
                    for &part in &c.rng {
                        w.u64(part);
                    }
                }
            }
        }
        // -- codec EF residuals
        match &self.codec_residuals {
            None => w.boolean(false),
            Some(slots) => {
                w.boolean(true);
                w.u32(slots.len() as u32);
                for slot in slots {
                    w.rows(slot);
                }
            }
        }
        // -- fault engine
        match &self.faults {
            None => w.boolean(false),
            Some(f) => {
                w.boolean(true);
                match &f.cache {
                    None => w.boolean(false),
                    Some(cache) => {
                        w.boolean(true);
                        w.rows(cache);
                    }
                }
                let s = &f.stats;
                for v in [
                    s.steps,
                    s.nominal_edges,
                    s.realized_edges,
                    s.masked_edges,
                    s.stale_messages,
                    s.async_stale_messages,
                    s.dropped_node_steps,
                    s.straggler_node_steps,
                ] {
                    w.u64(v as u64);
                }
                w.u32(f.rings.len() as u32);
                for (ring, staged) in &f.rings {
                    w.u32(ring.len() as u32);
                    for entry in ring {
                        w.rows(entry);
                    }
                    w.rows(staged);
                }
            }
        }
        // -- frame: magic | version | len | checksum | payload
        let payload = w.buf;
        let mut out = Vec::with_capacity(payload.len() + 28);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Parse and verify the checksummed wire format.
    pub fn from_bytes(bytes: &[u8]) -> Result<Snapshot> {
        let mut r = R { b: bytes, i: 0 };
        let magic = r.take(8)?;
        if magic != MAGIC.as_slice() {
            bail!("not a DecentLaM snapshot (bad magic)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("snapshot version {version} unsupported (this build reads {VERSION})");
        }
        let len = r.u64()? as usize;
        let want = r.u64()?;
        let payload = r.take(len)?;
        r.done()?;
        let got = fnv1a64(payload);
        if got != want {
            bail!("snapshot checksum mismatch: stored {want:#018x}, computed {got:#018x}");
        }
        let mut r = R { b: payload, i: 0 };
        let meta = SnapshotMeta {
            optimizer: r.string()?,
            topology: r.string()?,
            codec: r.string()?,
            faults: r.string()?,
            async_mode: r.string()?,
            churn: r.string()?,
            seed: r.u64()?,
            nodes: r.u32()?,
            capacity: r.u32()?,
            dim: r.u32()?,
            model: r.string()?,
            aux_labels: r.string()?,
            hyper: r.string()?,
        };
        let step = r.u64()?;
        let churned = r.boolean()?;
        let topo_step = r.u64()?;
        let churn_stats = ChurnStats {
            joins: r.u64()? as usize,
            leaves: r.u64()? as usize,
            resizes: r.u64()? as usize,
        };
        let active = r.u32s()?;
        let n_states = r.u32()? as usize;
        let mut states = Vec::with_capacity(r.cap(n_states, 12));
        for _ in 0..n_states {
            let x = r.f32s()?;
            let m = r.f32s()?;
            let n_aux = r.u32()? as usize;
            let mut aux = Vec::with_capacity(r.cap(n_aux, 4));
            for _ in 0..n_aux {
                aux.push(r.f32s()?);
            }
            states.push(NodeState { x, m, aux });
        }
        let n_cursors = r.u32()? as usize;
        let mut cursors = Vec::with_capacity(r.cap(n_cursors, 1));
        for _ in 0..n_cursors {
            if r.boolean()? {
                let cursor = r.u64()?;
                let order = r.u32s()?;
                let mut rng = [0u64; 4];
                for part in rng.iter_mut() {
                    *part = r.u64()?;
                }
                cursors.push(Some(ShardCursor { cursor, order, rng }));
            } else {
                cursors.push(None);
            }
        }
        let codec_residuals = if r.boolean()? {
            let n_slots = r.u32()? as usize;
            let mut slots = Vec::with_capacity(r.cap(n_slots, 4));
            for _ in 0..n_slots {
                slots.push(r.rows()?);
            }
            Some(slots)
        } else {
            None
        };
        let faults = if r.boolean()? {
            let cache = if r.boolean()? { Some(r.rows()?) } else { None };
            let mut raw = [0u64; 8];
            for v in raw.iter_mut() {
                *v = r.u64()?;
            }
            let stats = FaultStats {
                steps: raw[0] as usize,
                nominal_edges: raw[1] as usize,
                realized_edges: raw[2] as usize,
                masked_edges: raw[3] as usize,
                stale_messages: raw[4] as usize,
                async_stale_messages: raw[5] as usize,
                dropped_node_steps: raw[6] as usize,
                straggler_node_steps: raw[7] as usize,
            };
            let n_slots = r.u32()? as usize;
            let mut rings = Vec::with_capacity(r.cap(n_slots, 8));
            for _ in 0..n_slots {
                let depth = r.u32()? as usize;
                let mut ring = Vec::with_capacity(r.cap(depth, 4));
                for _ in 0..depth {
                    ring.push(r.rows()?);
                }
                let staged = r.rows()?;
                rings.push((ring, staged));
            }
            Some(FaultState { cache, stats, rings })
        } else {
            None
        };
        r.done()?;
        Ok(Snapshot {
            meta,
            step,
            churned,
            topo_step,
            churn_stats,
            active,
            states,
            cursors,
            codec_residuals,
            faults,
        })
    }

    /// Write the snapshot to a file — atomically: a crash mid-write
    /// must never destroy the previous checkpoint at `path`, so the
    /// bytes go to a sibling temp file first and rename over the
    /// target (same directory ⇒ same filesystem ⇒ atomic on POSIX).
    /// The temp name APPENDS ".tmp" (never replaces an extension), so
    /// a target that itself ends in ".tmp" still stages elsewhere and
    /// distinct targets never share a staging file.
    pub fn write_file(&self, path: &Path) -> Result<()> {
        let mut tmp_name = path
            .file_name()
            .map(|n| n.to_os_string())
            .ok_or_else(|| anyhow::anyhow!("snapshot path {} has no file name", path.display()))?;
        tmp_name.push(".tmp");
        let tmp = path.with_file_name(tmp_name);
        std::fs::write(&tmp, self.to_bytes())
            .with_context(|| format!("writing snapshot {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("committing snapshot {}", path.display()))
    }

    /// Read and verify a snapshot file.
    pub fn read_file(path: &Path) -> Result<Snapshot> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading snapshot {}", path.display()))?;
        Snapshot::from_bytes(&bytes)
            .with_context(|| format!("parsing snapshot {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rich_snapshot() -> Snapshot {
        Snapshot {
            meta: SnapshotMeta {
                optimizer: "decentlam".into(),
                topology: "ring".into(),
                codec: "int8,ef=true,seed=5".into(),
                faults: "drop=0.1,seed=9".into(),
                async_mode: String::new(),
                churn: "join=0.05,leave=0.05,nmin=2,nmax=6,seed=3".into(),
                seed: 11,
                nodes: 4,
                capacity: 6,
                dim: 3,
                model: "native-mlp".into(),
                aux_labels: "x_prev,prev_update".into(),
                hyper: "lr=0.08;momentum=0.9;schedule=Constant".into(),
            },
            step: 17,
            churned: true,
            topo_step: 9,
            churn_stats: ChurnStats { joins: 3, leaves: 1, resizes: 2 },
            active: vec![0, 2, 3, 5],
            states: vec![
                NodeState {
                    x: vec![1.0, -2.5, f32::MIN_POSITIVE],
                    m: vec![0.5, 0.0, -0.0],
                    aux: vec![vec![9.0, 8.0, 7.0], vec![0.0, 0.1, 0.2]],
                },
                NodeState { x: vec![0.0; 3], m: vec![0.0; 3], aux: vec![] },
            ],
            cursors: vec![
                Some(ShardCursor { cursor: 5, order: vec![2, 0, 1], rng: [1, 2, 3, 4] }),
                None,
                Some(ShardCursor { cursor: 0, order: vec![0], rng: [9, 9, 9, 9] }),
            ],
            codec_residuals: Some(vec![vec![vec![0.25, -0.5, 0.125]; 4]]),
            faults: Some(FaultState {
                cache: Some(vec![vec![1.0, 2.0, 3.0]; 4]),
                stats: FaultStats {
                    steps: 17,
                    nominal_edges: 68,
                    realized_edges: 60,
                    masked_edges: 8,
                    stale_messages: 3,
                    async_stale_messages: 0,
                    dropped_node_steps: 2,
                    straggler_node_steps: 1,
                },
                rings: vec![(vec![vec![vec![5.0, 6.0, 7.0]; 4]], vec![vec![8.0, 9.0, 10.0]; 4])],
            }),
        }
    }

    #[test]
    fn roundtrip_is_exact() {
        let snap = rich_snapshot();
        let bytes = snap.to_bytes();
        let back = Snapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.meta, snap.meta);
        assert_eq!(back.step, snap.step);
        assert_eq!(back.churned, snap.churned);
        assert_eq!(back.topo_step, snap.topo_step);
        assert_eq!(back.churn_stats, snap.churn_stats);
        assert_eq!(back.active, snap.active);
        assert_eq!(back.cursors, snap.cursors);
        assert_eq!(back.codec_residuals, snap.codec_residuals);
        assert_eq!(back.faults, snap.faults);
        assert_eq!(back.states.len(), snap.states.len());
        for (a, b) in back.states.iter().zip(&snap.states) {
            // Bit-level equality (covers -0.0 and subnormals, which
            // `==` would blur).
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a.x), bits(&b.x));
            assert_eq!(bits(&a.m), bits(&b.m));
            assert_eq!(a.aux.len(), b.aux.len());
            for (aa, bb) in a.aux.iter().zip(&b.aux) {
                assert_eq!(bits(aa), bits(bb));
            }
        }
    }

    #[test]
    fn corruption_and_truncation_fail_loudly() {
        let bytes = rich_snapshot().to_bytes();
        // Flip one payload byte: checksum must catch it.
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        assert!(Snapshot::from_bytes(&bad).is_err(), "flipped byte accepted");
        // Truncate: must not panic, must error.
        for cut in [0usize, 4, 7, 8, 20, bytes.len() - 1] {
            assert!(Snapshot::from_bytes(&bytes[..cut]).is_err(), "cut at {cut} accepted");
        }
        // Trailing garbage after the framed payload is rejected.
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(Snapshot::from_bytes(&padded).is_err());
        // Bad magic / version.
        let mut wrong = bytes.clone();
        wrong[0] = b'X';
        assert!(Snapshot::from_bytes(&wrong).is_err());
        let mut vers = bytes;
        vers[8] = 99;
        assert!(Snapshot::from_bytes(&vers).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("decentlam_snapshot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("snap_{}.bin", std::process::id()));
        let snap = rich_snapshot();
        snap.write_file(&path).unwrap();
        let back = Snapshot::read_file(&path).unwrap();
        assert_eq!(back.meta, snap.meta);
        assert_eq!(back.active, snap.active);
        std::fs::remove_file(&path).ok();
        assert!(Snapshot::read_file(&path).is_err(), "missing file must error");
    }

    #[test]
    fn minimal_snapshot_roundtrips() {
        let snap = Snapshot {
            meta: SnapshotMeta::default(),
            step: 0,
            churned: false,
            topo_step: 0,
            churn_stats: ChurnStats::default(),
            active: vec![0],
            states: vec![NodeState { x: vec![], m: vec![], aux: vec![] }],
            cursors: vec![None],
            codec_residuals: None,
            faults: None,
        };
        let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert!(back.codec_residuals.is_none());
        assert!(back.faults.is_none());
        assert_eq!(back.cursors, vec![None]);
    }
}
