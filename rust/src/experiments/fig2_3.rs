//! Figures 2 & 3: convergence of DSGD / DmSGD / DecentLaM on the
//! full-batch linear regression of App. G.2 (n=8 mesh, 50×30 per node,
//! γ=0.001, β=0.8, exact gradients). The y-axis is the relative error
//! (1/n)Σ‖x_i − x*‖²/‖x*‖².
//!
//! Expected shape: DmSGD converges fast but plateaus at a bias
//! ~1/(1−β)² ≈ 25× above DSGD's (Prop. 2); DecentLaM converges as fast
//! as DmSGD but down to DSGD's floor (Prop. 3, Remarks 2–3).

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::data::LinRegProblem;
use crate::grad::linreg;
use crate::util::config::{Config, LrSchedule};
use crate::util::table::{sig, Table};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub rows: usize,
    pub dim: usize,
    pub gamma: f64,
    pub beta: f64,
    pub steps: usize,
    pub record_every: usize,
    pub topology: String,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        // Paper App. G.2 settings.
        Opts {
            nodes: 8,
            rows: 50,
            dim: 30,
            gamma: 0.001,
            beta: 0.8,
            steps: 20_000,
            record_every: 200,
            topology: "mesh".into(),
            seed: 1,
        }
    }
}

/// One method's error trajectory.
#[derive(Debug, Clone)]
pub struct Series {
    pub method: String,
    pub steps: Vec<usize>,
    pub rel_error: Vec<f64>,
}

impl Series {
    pub fn final_error(&self) -> f64 {
        *self.rel_error.last().unwrap()
    }
}

fn run_method(opts: &Opts, method: &str) -> Result<Series> {
    let problem = LinRegProblem::generate(opts.nodes, opts.rows, opts.dim, opts.seed);
    let mut cfg = Config::default();
    cfg.nodes = opts.nodes;
    cfg.optimizer = method.into();
    cfg.topology = opts.topology.clone();
    cfg.lr = opts.gamma;
    cfg.linear_scaling = false;
    cfg.momentum = opts.beta;
    cfg.schedule = LrSchedule::Constant;
    cfg.steps = opts.steps;
    cfg.seed = opts.seed;
    cfg.threads = 1; // exact grads are trivially cheap
    let wl = linreg::workload(problem.clone());
    let mut trainer = Trainer::new(cfg, wl)?;
    let mut steps = Vec::new();
    let mut errs = Vec::new();
    for k in 0..opts.steps {
        trainer.step(k);
        if k % opts.record_every == 0 || k + 1 == opts.steps {
            let xs: Vec<Vec<f32>> = trainer.states.iter().map(|s| s.x.clone()).collect();
            steps.push(k);
            errs.push(problem.relative_error(&xs));
        }
    }
    Ok(Series { method: method.into(), steps, rel_error: errs })
}

/// Run the figure; `with_decentlam=false` reproduces Fig. 2, `true` Fig. 3.
pub fn run(opts: &Opts, with_decentlam: bool) -> Result<(Vec<Series>, Table)> {
    let mut methods = vec!["dsgd", "dmsgd"];
    if with_decentlam {
        methods.push("decentlam");
    }
    let series: Vec<Series> =
        methods.iter().map(|m| run_method(opts, m)).collect::<Result<_>>()?;
    let mut table = Table::new(
        &format!(
            "Fig. {} — full-batch linreg (n={}, {}, gamma={}, beta={})",
            if with_decentlam { 3 } else { 2 },
            opts.nodes,
            opts.topology,
            opts.gamma,
            opts.beta
        ),
        &["method", "final rel. error", "steps to 1e-2"],
    );
    for s in &series {
        let hit = s
            .steps
            .iter()
            .zip(&s.rel_error)
            .find(|(_, &e)| e < 1e-2)
            .map(|(k, _)| k.to_string())
            .unwrap_or_else(|| "-".into());
        table.row(vec![s.method.clone(), sig(s.final_error(), 3), hit]);
    }
    Ok((series, table))
}

/// CSV with one column per method (for plotting).
pub fn to_csv(series: &[Series]) -> String {
    let mut out = String::from("step");
    for s in series {
        out.push_str(&format!(",{}", s.method));
    }
    out.push('\n');
    for i in 0..series[0].steps.len() {
        out.push_str(&series[0].steps[i].to_string());
        for s in series {
            out.push_str(&format!(",{:.6e}", s.rel_error[i]));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dmsgd_bias_exceeds_dsgd_and_decentlam_matches_dsgd() {
        // Shrunk-but-faithful version of Fig. 3.
        let opts = Opts {
            steps: 6000,
            record_every: 500,
            rows: 20,
            dim: 10,
            nodes: 8,
            ..Default::default()
        };
        let (series, _) = run(&opts, true).unwrap();
        let err = |m: &str| {
            series.iter().find(|s| s.method == m).unwrap().final_error()
        };
        let (dsgd, dmsgd, dlam) = (err("dsgd"), err("dmsgd"), err("decentlam"));
        assert!(
            dmsgd > 5.0 * dsgd,
            "momentum must amplify bias: dmsgd={dmsgd:.3e} dsgd={dsgd:.3e}"
        );
        assert!(
            dlam < 3.0 * dsgd,
            "DecentLaM must match DSGD floor: dlam={dlam:.3e} dsgd={dsgd:.3e}"
        );
    }
}
