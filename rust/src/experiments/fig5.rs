//! Figure 5: training-loss and validation-accuracy curves for
//! PmSGD / DmSGD / DecentLaM at a small and a large total batch.
//!
//! Expected shape: at small batch all three loss curves coincide; at
//! large batch DecentLaM reaches a visibly lower training loss than
//! DmSGD (the inconsistency-bias gap).

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::util::table::{pct, sig, Table};

use super::{mlp_workload_named, protocol_config, synth_imagenet};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub steps: usize,
    pub arch: String,
    pub small_batch: usize,
    pub large_batch: usize,
    pub methods: Vec<String>,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 8,
            steps: 400,
            arch: "mlp-s".into(),
            small_batch: 256,
            large_batch: 2048,
            methods: vec!["pmsgd".into(), "dmsgd".into(), "decentlam".into()],
            eval_every: 40,
            seed: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Curve {
    pub method: String,
    pub batch: usize,
    pub losses: Vec<f64>,
    pub evals: Vec<(usize, f64)>,
}

pub fn run(opts: &Opts) -> Result<(Vec<Curve>, Table)> {
    let mut curves = Vec::new();
    for &batch in &[opts.small_batch, opts.large_batch] {
        for method in &opts.methods {
            let data = synth_imagenet(opts.nodes, opts.seed);
            let mut cfg = protocol_config(method, batch, opts.steps, opts.nodes);
            cfg.eval_every = opts.eval_every;
            cfg.seed = opts.seed;
            let wl = mlp_workload_named(&opts.arch, data, cfg.micro_batch, opts.seed)?;
            let mut t = Trainer::new(cfg, wl)?;
            let report = t.run();
            curves.push(Curve {
                method: method.clone(),
                batch,
                losses: report.losses,
                evals: report.evals,
            });
        }
    }
    let mut table = Table::new(
        "Fig. 5 — final train loss / val accuracy",
        &["method", "batch", "final train loss", "final val acc"],
    );
    for c in &curves {
        let tail = &c.losses[c.losses.len().saturating_sub(10)..];
        let final_loss = crate::util::math::mean_f64(tail);
        let final_acc = c.evals.last().map(|e| e.1).unwrap_or(f64::NAN);
        table.row(vec![
            c.method.clone(),
            c.batch.to_string(),
            sig(final_loss, 4),
            pct(final_acc),
        ]);
    }
    Ok((curves, table))
}

/// CSV: step, then one loss column per (method, batch).
pub fn to_csv(curves: &[Curve]) -> String {
    let mut out = String::from("step");
    for c in curves {
        out.push_str(&format!(",{}-{}", c.method, c.batch));
    }
    out.push('\n');
    let steps = curves[0].losses.len();
    for k in 0..steps {
        out.push_str(&k.to_string());
        for c in curves {
            out.push_str(&format!(",{:.6}", c.losses[k]));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrunk_fig5_large_batch_gap() {
        let opts = Opts {
            nodes: 4,
            steps: 100,
            small_batch: 128,
            large_batch: 1024,
            eval_every: 50,
            methods: vec!["dmsgd".into(), "decentlam".into()],
            ..Default::default()
        };
        let (curves, _) = run(&opts).unwrap();
        assert_eq!(curves.len(), 4);
        for c in &curves {
            assert!(c.losses.iter().all(|l| l.is_finite()));
            assert!(c.losses[0] > *c.losses.last().unwrap(), "{} learns", c.method);
        }
        let csv = to_csv(&curves);
        assert!(csv.lines().count() > 100);
    }
}
