//! Figure 6: per-iteration runtime of PmSGD / DmSGD / DecentLaM for a
//! ResNet-50-sized model at several batch sizes and two network
//! bandwidths (10 and 25 Gbps), split into compute and communication.
//!
//! The testbed substitution (DESIGN.md §2): compute time uses the
//! paper's V100 throughput (~250 images/s/GPU for ResNet-50 fwd+bwd).
//! Communication time comes from the **discrete-event clock sim**
//! (`sim::clock`, uniform speeds, zero jitter, τ = 0): the same engine
//! that drives `--async` training prices the figure, so the runtime
//! numbers and the training dynamics share one time model. The
//! closed-form α–β formula of [`crate::comm::cost`] is kept as a
//! cross-check column — on the regular graphs used here the two agree
//! to well under 1% (asserted in the tests), and a drift between them
//! would flag a regression in either model.
//!
//! The claim being reproduced is the *shape*: DmSGD and DecentLaM share
//! the same (cheap) partial-averaging cost, PmSGD pays the all-reduce,
//! and the gap widens as bandwidth drops — overall 1.2–1.9× speedup.

use anyhow::Result;

use crate::comm::{CommCost, CommStats, LinkSpec, PayloadBytes};
use crate::optim::CommPattern;
use crate::sim::clock::{simulate_barrier, simulate_gossip, AsyncSpec};
use crate::topology::{Kind, SparseWeights, Topology};
use crate::util::table::{sig, Table};

/// Simulated rounds per cell — uniform clocks are lockstep, so a short
/// window already gives the exact steady-state per-iteration time.
const SIM_STEPS: usize = 16;

#[derive(Debug, Clone)]
pub struct Opts {
    /// Servers (the paper's 8 nodes × 8 GPUs).
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Model parameters (ResNet-50: 25.5 M).
    pub params: f64,
    /// Per-GPU images/second for fwd+bwd (V100 ResNet-50 ≈ 250).
    pub images_per_s_per_gpu: f64,
    pub batches: Vec<usize>,
    pub bandwidths_gbps: Vec<f64>,
    pub topology: String,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 8,
            gpus_per_node: 8,
            params: 25.5e6,
            images_per_s_per_gpu: 250.0,
            batches: vec![2048, 8192, 16384, 32768],
            bandwidths_gbps: vec![10.0, 25.0],
            topology: "sym-exp".into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Row {
    pub bandwidth_gbps: f64,
    pub batch: usize,
    pub method: String,
    pub compute_ms: f64,
    /// Per-iteration communication from simulated event time.
    pub comm_ms: f64,
    /// The closed-form α–β prediction (cross-check, not the headline).
    pub formula_comm_ms: f64,
    pub total_ms: f64,
    pub speedup_vs_pmsgd: f64,
}

pub fn run(opts: &Opts) -> Result<(Vec<Row>, Table)> {
    let kind = Kind::parse(&opts.topology)?;
    let topo = Topology::at_step(kind, opts.nodes, 1, 0);
    let sw = SparseWeights::metropolis_hastings(&topo);
    let stats = CommStats::of_topology(&topo);
    let bytes = opts.params * 4.0; // fp32 payload per exchange
    let payload = PayloadBytes::uniform(bytes);
    let mut rows = Vec::new();
    for &bw in &opts.bandwidths_gbps {
        let link = LinkSpec { bandwidth_gbps: bw, latency_us: 25.0 };
        let cost = CommCost::new(link);
        for &batch in &opts.batches {
            let per_gpu = batch as f64 / (opts.nodes * opts.gpus_per_node) as f64;
            let compute_s = per_gpu / opts.images_per_s_per_gpu;
            // Uniform, jitter-free, τ=0 clocks: the event engine in its
            // synchronous-barrier regime (the paper's testbed).
            let spec = AsyncSpec {
                tau: 0,
                compute_ms: compute_s * 1e3,
                bw_gbps: bw,
                ..Default::default()
            };
            let mut totals = std::collections::BTreeMap::new();
            for (method, pattern) in [
                ("pmsgd", CommPattern::AllReduce),
                ("dmsgd", CommPattern::Neighbor { payloads: 1 }),
                ("decentlam", CommPattern::Neighbor { payloads: 1 }),
            ] {
                let formula_s = cost.per_iter_comm_s(pattern, &stats, payload);
                let sim_per_iter_s = match pattern {
                    CommPattern::AllReduce => {
                        let ar = cost.allreduce_s(opts.nodes, bytes);
                        let (cum, _) = simulate_barrier(&spec, opts.nodes, ar, SIM_STEPS);
                        cum[SIM_STEPS - 1] / SIM_STEPS as f64
                    }
                    CommPattern::Neighbor { payloads } => {
                        let sched = simulate_gossip(&spec, &sw, bytes, payloads, SIM_STEPS);
                        sched.report().makespan_s / SIM_STEPS as f64
                    }
                    CommPattern::NeighborPlusPeriodicAllReduce { .. } => unreachable!(),
                };
                let comm_s = (sim_per_iter_s - compute_s).max(0.0);
                let total_s = cost.per_iter_wall_s(compute_s, comm_s);
                totals.insert(method.to_string(), (compute_s, comm_s, formula_s, total_s));
            }
            let pmsgd_total = totals["pmsgd"].3;
            for (method, (c, m, f, t)) in totals {
                rows.push(Row {
                    bandwidth_gbps: bw,
                    batch,
                    method,
                    compute_ms: c * 1e3,
                    comm_ms: m * 1e3,
                    formula_comm_ms: f * 1e3,
                    total_ms: t * 1e3,
                    speedup_vs_pmsgd: pmsgd_total / t,
                });
            }
        }
    }
    let mut table = Table::new(
        "Fig. 6 — per-iteration runtime (ResNet-50-sized, 8×8 GPUs; comm from event sim)",
        &[
            "bw (Gbps)",
            "batch",
            "method",
            "compute ms",
            "comm ms (sim)",
            "comm ms (α–β)",
            "total ms",
            "speedup",
        ],
    );
    for r in &rows {
        table.row(vec![
            format!("{}", r.bandwidth_gbps),
            r.batch.to_string(),
            r.method.clone(),
            sig(r.compute_ms, 3),
            sig(r.comm_ms, 3),
            sig(r.formula_comm_ms, 3),
            sig(r.total_ms, 3),
            format!("{:.2}x", r.speedup_vs_pmsgd),
        ]);
    }
    Ok((rows, table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decentralized_speedup_in_paper_band() {
        let (rows, _) = run(&Opts::default()).unwrap();
        for r in rows.iter().filter(|r| r.method == "decentlam") {
            assert!(
                (1.0..2.5).contains(&r.speedup_vs_pmsgd),
                "speedup {} out of band at batch {} bw {}",
                r.speedup_vs_pmsgd,
                r.batch,
                r.bandwidth_gbps
            );
        }
        // Gap widens as bandwidth drops (same batch).
        let s10 = rows
            .iter()
            .find(|r| r.method == "decentlam" && r.bandwidth_gbps == 10.0 && r.batch == 2048)
            .unwrap()
            .speedup_vs_pmsgd;
        let s25 = rows
            .iter()
            .find(|r| r.method == "decentlam" && r.bandwidth_gbps == 25.0 && r.batch == 2048)
            .unwrap()
            .speedup_vs_pmsgd;
        assert!(s10 >= s25 * 0.99, "10Gbps speedup {s10} vs 25Gbps {s25}");
    }

    #[test]
    fn simulated_comm_time_cross_checks_the_formula() {
        // The headline numbers come from the event sim; the closed-form
        // α–β column must agree within 1% on these regular graphs (they
        // are exact up to float accumulation), or one model regressed.
        let (rows, table) = run(&Opts::default()).unwrap();
        for r in &rows {
            let rel = (r.comm_ms - r.formula_comm_ms).abs() / r.formula_comm_ms.max(1e-12);
            assert!(
                rel < 0.01,
                "{} bw={} batch={}: sim {} vs formula {} ({:.3}% off)",
                r.method,
                r.bandwidth_gbps,
                r.batch,
                r.comm_ms,
                r.formula_comm_ms,
                100.0 * rel
            );
        }
        assert!(table.render().contains("sim"));
    }

    #[test]
    fn dmsgd_and_decentlam_equal_runtime() {
        // Same partial-averaging wire pattern -> identical modeled time.
        let (rows, _) = run(&Opts::default()).unwrap();
        for b in [2048usize, 32768] {
            let t = |m: &str| {
                rows.iter()
                    .find(|r| r.method == m && r.batch == b && r.bandwidth_gbps == 25.0)
                    .unwrap()
                    .total_ms
            };
            assert!((t("dmsgd") - t("decentlam")).abs() < 1e-9);
        }
    }

    #[test]
    fn comm_fraction_shrinks_with_batch() {
        // Larger batch = more compute per exchanged byte.
        let (rows, _) = run(&Opts::default()).unwrap();
        let frac = |b: usize| {
            let r = rows
                .iter()
                .find(|r| r.method == "pmsgd" && r.batch == b && r.bandwidth_gbps == 25.0)
                .unwrap();
            r.comm_ms / r.total_ms
        };
        assert!(frac(32768) < frac(2048));
    }
}
