//! Async sweep: time-to-target-loss vs heterogeneity spread for DmSGD
//! vs DecentLaM vs PmSGD (the clock layer's headline figure; no paper
//! analog — this extends §7 to the asynchronous straggler regimes of
//! "From promise to practice", arXiv 2410.11998, probing whether
//! DecentLaM's bias correction survives bounded staleness the way
//! Momentum Tracking, arXiv 2209.15505, suggests raw momentum may not).
//!
//! For each heterogeneity spread S the discrete-event clock sim prices
//! a wall-clock budget: the simulated time `opts.steps` asynchronous
//! gossip rounds take at spread S. Both gossip methods are timed by the
//! *same* schedule (timing is value-free), so they run the identical
//! number of rounds inside the budget — the comparison between them is
//! pure staleness bias at matched simulated wall-clock. PmSGD, the
//! barrier baseline, fits however many barrier rounds the same budget
//! allows (fewer, under stragglers: every round waits for the slowest
//! node and pays the all-reduce) — the "how much wall-clock does
//! decentralization buy" axis.
//!
//! Everything is seeded (data, topology, clock draws), so two runs of
//! the same opts produce identical tables byte for byte.

use anyhow::Result;

use crate::comm::CommCost;
use crate::coordinator::Trainer;
use crate::data::synth::{ClassificationData, SynthSpec};
use crate::grad::mlp;
use crate::sim::clock::{simulate_barrier, simulate_gossip, AsyncSpec};
use crate::topology::{Kind, SparseWeights, Topology};
use crate::util::cli::Args;
use crate::util::config::{Config, LrSchedule};
use crate::util::table::{sig, Table};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    /// Gossip rounds per cell — also what prices the per-spread budget.
    pub steps: usize,
    pub topology: String,
    /// Methods to compare (gossip methods share the schedule; `pmsgd`
    /// runs as the barrier baseline).
    pub methods: Vec<String>,
    /// Heterogeneity spreads swept across columns (slowdown of the
    /// slowest draw relative to the fastest, log-uniform per node).
    pub spreads: Vec<f64>,
    /// Bounded-staleness window.
    pub tau: usize,
    /// Lognormal per-(node, step) jitter sigma.
    pub jitter: f64,
    /// Base compute ms per round at slowdown 1.
    pub compute_ms: f64,
    pub total_batch: usize,
    pub arch: String,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 16,
            steps: 150,
            topology: "ring".into(),
            methods: vec!["dmsgd".into(), "decentlam".into(), "pmsgd".into()],
            spreads: vec![1.0, 2.0, 4.0, 8.0],
            tau: 2,
            jitter: 0.2,
            compute_ms: 10.0,
            total_batch: 2048,
            arch: "mlp-xs".into(),
            seed: 7,
        }
    }
}

impl Opts {
    /// Shared CLI flags for the `fig-async` subcommand and
    /// `examples/async_sweep.rs`.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        self.nodes = args.get_usize("nodes", self.nodes)?;
        self.steps = args.get_usize("steps", self.steps)?;
        self.seed = args.get_usize("seed", self.seed as usize)? as u64;
        self.tau = args.get_usize("tau", self.tau)?;
        self.jitter = args.get_f64("jitter", self.jitter)?;
        self.compute_ms = args.get_f64("compute", self.compute_ms)?;
        if let Some(s) = args.get("spread") {
            self.spreads = vec![s.parse().map_err(|e| anyhow::anyhow!("--spread: {e}"))?];
        }
        if let Some(t) = args.get("topology") {
            self.topology = t.into();
        }
        Ok(())
    }

    fn spec_string(&self, spread: f64) -> String {
        format!(
            "tau={},spread={spread},jitter={},compute={},seed={}",
            self.tau, self.jitter, self.compute_ms, self.seed
        )
    }
}

/// One trained cell of the sweep.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: String,
    pub spread: f64,
    /// Rounds executed inside the spread's wall-clock budget.
    pub steps: usize,
    /// Simulated seconds the run took (≤ the budget, by construction).
    pub sim_s: f64,
    pub mean_staleness: f64,
    /// Eval loss of the network-average model at the end of the budget.
    pub eval_loss: f64,
    pub accuracy: f64,
    pub consensus: f64,
    /// (simulated seconds, eval loss) curve for time-to-target plots.
    pub curve: Vec<(f64, f64)>,
}

fn cell_data(opts: &Opts) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes: opts.nodes,
        samples_per_node: 256,
        eval_samples: 512,
        dirichlet_alpha: 0.1, // strongly heterogeneous: bias regime
        seed: opts.seed,
        ..Default::default()
    })
}

fn cell_config(opts: &Opts, method: &str, spread: f64, steps: usize) -> Result<Config> {
    let mut cfg = Config::default();
    cfg.optimizer = method.into();
    cfg.nodes = opts.nodes;
    cfg.steps = steps;
    cfg.topology = opts.topology.clone();
    cfg.total_batch = opts.total_batch;
    cfg.micro_batch = 32;
    cfg.lr = 0.08;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.seed = opts.seed;
    cfg.eval_every = (steps / 10).max(1);
    cfg.apply_kv("async", &opts.spec_string(spread))?;
    Ok(cfg)
}

fn cell(
    opts: &Opts,
    data: &ClassificationData,
    method: &str,
    spread: f64,
    steps: usize,
) -> Result<Row> {
    let cfg = cell_config(opts, method, spread, steps)?;
    let wl = mlp::workload(
        mlp::MlpArch::family(&opts.arch)?,
        data.clone(),
        cfg.micro_batch,
        opts.seed,
    );
    let mut t = Trainer::new(cfg, wl)?;
    let report = t.run();
    let xbar = t.average_model();
    let eval_loss = t.workload.eval.loss(&xbar).unwrap_or(f64::NAN);
    let async_rep = t.async_report().expect("async cells always carry a report");
    let curve: Vec<(f64, f64)> = report
        .eval_losses
        .iter()
        .map(|&(k, l)| (async_rep.step_done_s[k - 1], l))
        .collect();
    Ok(Row {
        method: method.into(),
        spread,
        steps,
        sim_s: async_rep.makespan_s,
        mean_staleness: async_rep.mean_staleness,
        eval_loss,
        accuracy: report.final_accuracy,
        consensus: report.final_consensus,
        curve,
    })
}

/// Rounds a barrier-synchronous (all-reduce) run fits into `budget_s`.
fn barrier_steps_within(opts: &Opts, spec: &AsyncSpec, d: usize, budget_s: f64) -> usize {
    let ar = CommCost::new(spec.link()).allreduce_s(opts.nodes, 4.0 * d as f64);
    let cap = opts.steps * 4;
    let (cum, _) = simulate_barrier(spec, opts.nodes, ar, cap);
    cum.iter().take_while(|&&t| t <= budget_s).count().max(1)
}

pub fn run(opts: &Opts) -> Result<(Vec<Row>, Table)> {
    let kind = Kind::parse(&opts.topology)?;
    let topo = Topology::at_step(kind, opts.nodes, opts.seed, 0);
    let sw = SparseWeights::metropolis_hastings(&topo);
    let data = cell_data(opts);
    // Any cell's workload has the same dim — build one to size payloads.
    let d = mlp::workload(mlp::MlpArch::family(&opts.arch)?, data.clone(), 32, opts.seed).dim;

    let mut rows = Vec::new();
    for &spread in &opts.spreads {
        let spec = AsyncSpec::parse(&opts.spec_string(spread), opts.seed)?;
        // The spread's wall-clock budget: what `opts.steps` async gossip
        // rounds cost. Gossip methods share this schedule exactly.
        let budget_s =
            simulate_gossip(&spec, &sw, 4.0 * d as f64, 1, opts.steps).report().makespan_s;
        for method in &opts.methods {
            let steps = if method == "pmsgd" {
                barrier_steps_within(opts, &spec, d, budget_s)
            } else {
                opts.steps
            };
            rows.push(cell(opts, &data, method, spread, steps)?);
        }
    }

    let mut table = Table::new(
        &format!(
            "async sweep — {} n={}, tau={}, jitter={}, budget = {} gossip rounds (seed {})",
            opts.topology, opts.nodes, opts.tau, opts.jitter, opts.steps, opts.seed
        ),
        &["method", "spread", "rounds", "sim s", "mean stale", "eval loss", "vs spread=1"],
    );
    for row in &rows {
        let deg = degradation(&rows, &row.method)
            .iter()
            .find(|(s, _)| *s == row.spread)
            .map(|&(_, d)| format!("{d:+.4}"))
            .unwrap_or_else(|| "n/a".into());
        table.row(vec![
            row.method.clone(),
            format!("{}", row.spread),
            row.steps.to_string(),
            sig(row.sim_s, 4),
            sig(row.mean_staleness, 3),
            sig(row.eval_loss, 4),
            deg,
        ]);
    }
    Ok((rows, table))
}

/// Absolute eval-loss degradation of `method` at each spread relative
/// to its own spread=1 cell: `loss(S) − loss(1)`. Empty when the sweep
/// has no spread=1 baseline — callers must not fabricate a verdict
/// from a baseline-less sweep.
pub fn degradation(rows: &[Row], method: &str) -> Vec<(f64, f64)> {
    let Some(base) = rows
        .iter()
        .find(|r| r.method == method && r.spread == 1.0)
        .map(|r| r.eval_loss)
    else {
        return Vec::new();
    };
    rows.iter()
        .filter(|r| r.method == method)
        .map(|r| (r.spread, r.eval_loss - base))
        .collect()
}

/// First simulated second at which `curve` reaches `target` (curves are
/// sampled at eval points; None if never).
pub fn time_to_target(curve: &[(f64, f64)], target: f64) -> Option<f64> {
    curve.iter().find(|&&(_, l)| l <= target).map(|&(t, _)| t)
}

/// CI smoke: the acceptance gate of the async runtime. Asserts
/// (1) async(uniform, tau=0) is bitwise equal to the synchronous
/// trainer, (2) the heterogeneous sweep is deterministic across reruns
/// and parallel == serial, (3) at heterogeneity spread ≥ 4× and matched
/// simulated wall-clock budget, DecentLaM's final eval loss degrades
/// strictly less than DmSGD's. Exits nonzero on any violation.
pub fn smoke(args: &Args) -> Result<()> {
    let mut opts = Opts { spreads: vec![1.0, 8.0], ..Default::default() };
    opts.apply_args(args)?;
    let gate_spread = opts.spreads.iter().cloned().fold(1.0, f64::max);
    anyhow::ensure!(gate_spread >= 4.0, "smoke needs a spread ≥ 4x cell to gate on");
    let data = cell_data(&opts);

    // (1) bitwise: uniform clocks, tau=0 must reproduce the synchronous
    // trainer exactly.
    {
        let steps = 60;
        let run = |asynch: &str| -> Result<Vec<f64>> {
            let mut cfg = cell_config(&opts, "decentlam", 1.0, steps)?;
            cfg.apply_kv("async", asynch)?;
            let wl = mlp::workload(
                mlp::MlpArch::family(&opts.arch)?,
                data.clone(),
                cfg.micro_batch,
                opts.seed,
            );
            Ok(Trainer::new(cfg, wl)?.run().losses)
        };
        let sync = run("")?;
        let uniform = run(&format!("tau=0,spread=1,jitter=0,compute={}", opts.compute_ms))?;
        anyhow::ensure!(
            sync == uniform,
            "async(uniform, tau=0) diverged from the synchronous trainer"
        );
        println!("smoke 1/3 OK: async(uniform, tau=0) bitwise == synchronous ({steps} steps)");
    }

    // (2) determinism + parallel == serial on a heterogeneous cell.
    {
        let run = |threads: usize| -> Result<Vec<f64>> {
            let mut cfg = cell_config(&opts, "decentlam", gate_spread, 40)?;
            cfg.threads = threads;
            let wl = mlp::workload(
                mlp::MlpArch::family(&opts.arch)?,
                data.clone(),
                cfg.micro_batch,
                opts.seed,
            );
            Ok(Trainer::new(cfg, wl)?.run().losses)
        };
        super::smoke::assert_replay_and_par_eq("heterogeneous async cell", run)?;
        println!("smoke 2/3 OK: heterogeneous async deterministic, parallel == serial");
    }

    // (3) the bias gate at matched wall-clock budget.
    let (rows, table) = run(&opts)?;
    println!("{}", table.render());
    let stale = rows
        .iter()
        .find(|r| r.method == "decentlam" && r.spread == gate_spread)
        .expect("gate cell missing");
    anyhow::ensure!(
        stale.mean_staleness > 0.0,
        "spread={gate_spread} realized no staleness — the gate would be vacuous"
    );
    let deg = |method: &str| -> Result<f64> {
        degradation(&rows, method)
            .iter()
            .find(|(s, _)| *s == gate_spread)
            .map(|&(_, d)| d)
            .ok_or_else(|| anyhow::anyhow!("{method}: no spread={gate_spread} cell"))
    };
    let dl = deg("decentlam")?;
    let dm = deg("dmsgd")?;
    anyhow::ensure!(
        dl < dm,
        "DecentLaM degraded no less than DmSGD at spread={gate_spread}: {dl:+.4} vs {dm:+.4}"
    );
    println!(
        "smoke 3/3 OK: at spread={gate_spread} and matched simulated budget, DecentLaM's eval \
         loss degrades {dl:+.4} vs DmSGD's {dm:+.4}"
    );
    // Context line: what the budget bought each pattern.
    if let (Some(g), Some(p)) = (
        rows.iter().find(|r| r.method == "decentlam" && r.spread == gate_spread),
        rows.iter().find(|r| r.method == "pmsgd" && r.spread == gate_spread),
    ) {
        println!(
            "at spread={gate_spread}, the budget bought {} gossip rounds vs {} all-reduce \
             barriers ({:.2}x)",
            g.steps,
            p.steps,
            g.steps as f64 / p.steps as f64
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shrunk() -> Opts {
        Opts {
            nodes: 8,
            steps: 40,
            spreads: vec![1.0, 6.0],
            methods: vec!["dmsgd".into(), "decentlam".into(), "pmsgd".into()],
            total_batch: 256,
            ..Default::default()
        }
    }

    #[test]
    fn shrunk_sweep_has_sane_shape() {
        let opts = shrunk();
        let (rows, table) = run(&opts).unwrap();
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().all(|r| r.eval_loss.is_finite() && r.sim_s > 0.0));
        // Gossip methods share the schedule: same rounds, same sim time.
        for spread in [1.0, 6.0] {
            let get = |m: &str| rows.iter().find(|r| r.method == m && r.spread == spread).unwrap();
            assert_eq!(get("dmsgd").steps, opts.steps);
            assert_eq!(get("dmsgd").steps, get("decentlam").steps);
            assert_eq!(get("dmsgd").sim_s, get("decentlam").sim_s, "shared schedule");
            // PmSGD fits its rounds inside the same budget.
            assert!(get("pmsgd").sim_s <= get("dmsgd").sim_s + 1e-9);
            assert!(get("pmsgd").steps >= 1);
        }
        // Heterogeneity slows the budgeted wall-clock down and realizes
        // staleness for the gossip methods.
        let dl = |spread: f64| {
            rows.iter().find(|r| r.method == "decentlam" && r.spread == spread).unwrap()
        };
        assert!(dl(6.0).sim_s > dl(1.0).sim_s);
        assert_eq!(dl(1.0).mean_staleness, 0.0, "uniform clocks never stale");
        assert!(dl(6.0).mean_staleness > 0.0, "spread=6 never went stale");
        assert!(table.render().contains("decentlam"));
    }

    #[test]
    fn sweep_output_is_deterministic() {
        let mut opts = shrunk();
        opts.steps = 20;
        opts.methods = vec!["decentlam".into()];
        opts.spreads = vec![4.0];
        let (_, a) = run(&opts).unwrap();
        let (_, b) = run(&opts).unwrap();
        assert_eq!(a.render(), b.render(), "same opts must render byte-identically");
    }

    #[test]
    fn degradation_and_time_to_target_helpers() {
        let mk = |method: &str, spread: f64, loss: f64| Row {
            method: method.into(),
            spread,
            steps: 10,
            sim_s: 1.0,
            mean_staleness: 0.0,
            eval_loss: loss,
            accuracy: 0.0,
            consensus: 0.0,
            curve: vec![(0.5, 2.0), (1.0, loss)],
        };
        let rows = vec![mk("m", 1.0, 1.0), mk("m", 4.0, 1.5)];
        let d = degradation(&rows, "m");
        assert_eq!(d, vec![(1.0, 0.0), (4.0, 0.5)]);
        assert!(degradation(&rows[1..], "m").is_empty(), "no baseline -> no verdict");
        assert!(degradation(&rows, "other").is_empty());
        assert_eq!(time_to_target(&rows[0].curve, 1.2), Some(1.0));
        assert_eq!(time_to_target(&rows[0].curve, 2.5), Some(0.5));
        assert_eq!(time_to_target(&rows[0].curve, 0.1), None);
    }
}
