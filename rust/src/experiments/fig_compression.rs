//! Compression sweep: loss vs wire bytes across payload codecs (the
//! codec layer's headline figure; no paper analog — this extends §7
//! toward the wire-volume regimes of "From promise to practice",
//! PAPERS.md).
//!
//! For each (n, optimizer, codec) cell, train on a ring in the
//! heterogeneous regime and report the final eval loss of the average
//! model next to the *exact* per-iteration wire bytes the codec ships
//! ([`wire_bytes_per_iter`] at encoded payload widths). The claim under
//! test: stochastic int8 with error feedback cuts wire volume ~4× at an
//! eval loss within a few percent of uncompressed, and fp32 (the
//! identity codec) reproduces the pre-codec engine bit for bit.
//!
//! Everything is seeded (data, topology, stochastic rounding), so two
//! runs of the same opts produce identical tables byte for byte.

use anyhow::Result;

use crate::comm::cost::PayloadBytes;
use crate::comm::{wire_bytes_per_iter, CommStats};
use crate::coordinator::Trainer;
use crate::data::synth::{ClassificationData, SynthSpec};
use crate::grad::mlp;
use crate::util::cli::Args;
use crate::util::config::{Config, LrSchedule};
use crate::util::table::{pct, sig, Table};

#[derive(Debug, Clone)]
pub struct Opts {
    /// Node counts swept (ring topology scales linearly in edges).
    pub nodes_list: Vec<usize>,
    pub steps: usize,
    pub topology: String,
    /// Optimizers compared per codec.
    pub methods: Vec<String>,
    /// Codec specs swept across columns (`comm::codec` CLI forms).
    pub codecs: Vec<String>,
    pub total_batch: usize,
    pub arch: String,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes_list: vec![16, 64],
            steps: 160,
            topology: "ring".into(),
            methods: vec!["dmsgd".into(), "decentlam".into()],
            codecs: vec!["fp32".into(), "fp16".into(), "int8".into(), "topk,k=0.05".into()],
            total_batch: 1024,
            arch: "mlp-xs".into(),
            seed: 11,
        }
    }
}

impl Opts {
    /// Shared CLI flags for the `fig-compression` subcommand and
    /// `examples/compression_sweep.rs`.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(n) = args.get("nodes") {
            self.nodes_list = vec![n.parse().map_err(|e| anyhow::anyhow!("--nodes: {e}"))?];
        }
        self.steps = args.get_usize("steps", self.steps)?;
        self.seed = args.get_usize("seed", self.seed as usize)? as u64;
        if let Some(t) = args.get("topology") {
            self.topology = t.into();
        }
        if let Some(c) = args.get("codec") {
            self.codecs = vec![c.into()];
        }
        Ok(())
    }
}

/// One trained cell of the sweep.
#[derive(Debug, Clone)]
pub struct Row {
    pub nodes: usize,
    pub method: String,
    pub codec: String,
    /// Bytes of one encoded gossip payload.
    pub payload_bytes: f64,
    /// Total wire bytes per iteration at the realized edge count.
    pub wire_per_iter: f64,
    /// Wire-byte cut relative to the raw fp32 payload (≥ 1).
    pub ratio_vs_fp32: f64,
    /// Eval loss of the network-average model.
    pub eval_loss: f64,
    pub accuracy: f64,
    pub consensus: f64,
}

fn cell_data(opts: &Opts, n: usize) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes: n,
        samples_per_node: 128,
        eval_samples: 512,
        dirichlet_alpha: 0.3,
        seed: opts.seed,
        ..Default::default()
    })
}

fn cell_config(opts: &Opts, n: usize, method: &str, codec: &str) -> Result<Config> {
    let mut cfg = Config::default();
    cfg.optimizer = method.into();
    cfg.nodes = n;
    cfg.steps = opts.steps;
    cfg.topology = opts.topology.clone();
    cfg.total_batch = opts.total_batch;
    cfg.micro_batch = 16;
    cfg.lr = 0.05;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.seed = opts.seed;
    cfg.apply_kv("codec", codec)?;
    Ok(cfg)
}

/// Train one cell and report it. `data` is cloned per cell so every
/// codec sees the exact same shards.
fn cell(
    opts: &Opts,
    data: &ClassificationData,
    n: usize,
    method: &str,
    codec: &str,
) -> Result<Row> {
    let cfg = cell_config(opts, n, method, codec)?;
    let wl = mlp::workload(
        mlp::MlpArch::family(&opts.arch)?,
        data.clone(),
        cfg.micro_batch,
        opts.seed,
    );
    let mut t = Trainer::new(cfg, wl)?;
    let report = t.run();
    let xbar = t.average_model();
    let eval_loss = t.workload.eval.loss(&xbar).unwrap_or(f64::NAN);
    let stats = CommStats::of_engine(&t.comm);
    let payload = t.payload_bytes();
    let pattern = t.comm_pattern();
    let wire = wire_bytes_per_iter(pattern, &stats, payload);
    let wire_fp32 = wire_bytes_per_iter(pattern, &stats, PayloadBytes::fp32(t.workload.dim));
    Ok(Row {
        nodes: n,
        method: method.into(),
        codec: codec.into(),
        payload_bytes: payload.neighbor,
        wire_per_iter: wire,
        ratio_vs_fp32: wire_fp32 / wire,
        eval_loss,
        accuracy: report.final_accuracy,
        consensus: report.final_consensus,
    })
}

pub fn run(opts: &Opts) -> Result<(Vec<Row>, Table)> {
    let mut rows = Vec::new();
    for &n in &opts.nodes_list {
        let data = cell_data(opts, n);
        for method in &opts.methods {
            for codec in &opts.codecs {
                rows.push(cell(opts, &data, n, method, codec)?);
            }
        }
    }
    let mut table = Table::new(
        &format!(
            "compression sweep — {} n={:?}, {} steps, codecs {:?} (seed {})",
            opts.topology, opts.nodes_list, opts.steps, opts.codecs, opts.seed
        ),
        &["n", "method", "codec", "payload B", "wire B/iter", "cut", "eval loss", "acc"],
    );
    for row in &rows {
        table.row(vec![
            row.nodes.to_string(),
            row.method.clone(),
            row.codec.clone(),
            format!("{:.0}", row.payload_bytes),
            format!("{:.0}", row.wire_per_iter),
            format!("{:.2}x", row.ratio_vs_fp32),
            sig(row.eval_loss, 4),
            pct(row.accuracy),
        ]);
    }
    Ok((rows, table))
}

/// CI smoke: the acceptance gate of the codec layer, on a ring at
/// n=64 with DecentLaM. Asserts (1) the fp32 codec is bitwise
/// identical to the pre-codec engine, (2) int8 reruns are
/// byte-identical and parallel == serial, (3) int8 cuts wire bytes
/// ≥ 3.9× vs fp32, (4) the int8 eval loss lands within 5% of
/// uncompressed. Exits nonzero on any violation.
pub fn smoke(args: &Args) -> Result<()> {
    let nodes = args.get_usize("nodes", 64)?;
    let steps = args.get_usize("steps", 80)?;
    let opts = Opts { nodes_list: vec![nodes], steps, ..Default::default() };
    let data = cell_data(&opts, nodes);

    let run = |codec: &str, threads: usize| -> Result<(Vec<f64>, f64, f64)> {
        let mut cfg = cell_config(&opts, nodes, "decentlam", codec)?;
        cfg.threads = threads;
        let wl = mlp::workload(
            mlp::MlpArch::family(&opts.arch)?,
            data.clone(),
            cfg.micro_batch,
            opts.seed,
        );
        let mut t = Trainer::new(cfg, wl)?;
        let report = t.run();
        let xbar = t.average_model();
        let eval_loss = t.workload.eval.loss(&xbar).unwrap_or(f64::NAN);
        let wire = wire_bytes_per_iter(
            t.comm_pattern(),
            &CommStats::of_engine(&t.comm),
            t.payload_bytes(),
        );
        Ok((report.losses, eval_loss, wire))
    };

    let (base, base_loss, wire_fp32) = run("", 0)?;
    let (fp32, fp32_loss, wire_fp32_codec) = run("fp32", 0)?;
    anyhow::ensure!(
        base == fp32 && base_loss == fp32_loss,
        "fp32 codec diverged from the pre-codec engine"
    );
    anyhow::ensure!(wire_fp32 == wire_fp32_codec, "fp32 codec changed byte accounting");

    let (_, int8_loss, wire_int8) =
        super::smoke::assert_replay_and_par_eq("int8 cell", |threads| run("int8", threads))?;

    let ratio = wire_fp32 / wire_int8;
    anyhow::ensure!(ratio >= 3.9, "int8 wire cut {ratio:.3}x < 3.9x");
    let rel = (int8_loss - base_loss).abs() / base_loss.abs().max(1e-12);
    anyhow::ensure!(
        rel <= 0.05,
        "int8 eval loss {int8_loss:.4} vs fp32 {base_loss:.4}: {:.1}% > 5%",
        100.0 * rel
    );

    let mut table = Table::new(
        &format!("compression smoke — ring n={nodes}, {steps} steps, decentlam"),
        &["codec", "wire B/iter", "cut", "final eval loss"],
    );
    table.row(vec!["fp32".into(), format!("{wire_fp32:.0}"), "1.00x".into(), sig(base_loss, 4)]);
    table.row(vec![
        "int8".into(),
        format!("{wire_int8:.0}"),
        format!("{ratio:.2}x"),
        sig(int8_loss, 4),
    ]);
    println!("{}", table.render());
    println!(
        "compression smoke OK: int8 cuts {ratio:.2}x, eval loss within {:.2}% of fp32",
        100.0 * rel
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shrunk() -> Opts {
        Opts {
            nodes_list: vec![8],
            steps: 40,
            methods: vec!["decentlam".into()],
            total_batch: 256,
            ..Default::default()
        }
    }

    #[test]
    fn shrunk_sweep_cuts_bytes_and_keeps_loss_close() {
        let (rows, table) = run(&shrunk()).unwrap();
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.eval_loss.is_finite() && r.consensus.is_finite()));
        let get = |codec: &str| rows.iter().find(|r| r.codec.starts_with(codec)).unwrap();
        let (fp32, fp16, int8, topk) = (get("fp32"), get("fp16"), get("int8"), get("topk"));
        assert!((fp32.ratio_vs_fp32 - 1.0).abs() < 1e-12);
        assert!((fp16.ratio_vs_fp32 - 2.0).abs() < 1e-12, "fp16 halves the payload");
        assert!(int8.ratio_vs_fp32 >= 3.9, "int8 cut {} < 3.9x", int8.ratio_vs_fp32);
        assert!(topk.ratio_vs_fp32 > 5.0, "topk k=0.05 cut {}", topk.ratio_vs_fp32);
        // Lossy codecs stay in the same loss ballpark as raw fp32
        // (the tight 5% gate lives in the smoke run at n=64).
        for r in [fp16, int8] {
            let rel = (r.eval_loss - fp32.eval_loss).abs() / fp32.eval_loss.abs();
            assert!(
                rel < 0.25,
                "{}: eval loss {} vs fp32 {}",
                r.codec,
                r.eval_loss,
                fp32.eval_loss
            );
        }
        let rendered = table.render();
        assert!(rendered.contains("int8") && rendered.contains("topk"));
    }

    #[test]
    fn fp32_cell_bitwise_matches_no_codec_cell() {
        let opts = shrunk();
        let data = cell_data(&opts, 8);
        let a = cell(&opts, &data, 8, "decentlam", "fp32").unwrap();
        let b = cell(&opts, &data, 8, "decentlam", "").unwrap();
        assert_eq!(a.eval_loss, b.eval_loss, "identity codec changed training");
        assert_eq!(a.wire_per_iter, b.wire_per_iter);
    }

    #[test]
    fn sweep_output_is_deterministic() {
        let mut opts = shrunk();
        opts.steps = 15;
        opts.codecs = vec!["int8".into(), "topk,k=0.1".into()];
        let (_, a) = run(&opts).unwrap();
        let (_, b) = run(&opts).unwrap();
        assert_eq!(a.render(), b.render(), "same opts must render byte-identically");
    }

    #[test]
    fn wire_bytes_scale_linearly_in_ring_size() {
        // Ring: 2n payloads per exchange — the codec cut is independent
        // of n, the totals linear in it.
        let mut opts = shrunk();
        opts.steps = 5;
        opts.nodes_list = vec![8, 16];
        opts.codecs = vec!["int8".into()];
        let (rows, _) = run(&opts).unwrap();
        assert_eq!(rows.len(), 2);
        assert!((rows[1].wire_per_iter / rows[0].wire_per_iter - 2.0).abs() < 1e-9);
        assert!((rows[1].ratio_vs_fp32 - rows[0].ratio_vs_fp32).abs() < 1e-9);
    }
}
