//! Elastic-membership sweep: churn rate vs final eval loss for DmSGD
//! vs DecentLaM vs PmSGD (the elastic layer's headline figure; no
//! paper analog — this extends §7 to the dynamic-fleet regimes of
//! "From promise to practice", arXiv 2410.11998).
//!
//! For each (method, churn rate) cell, train in the heterogeneous
//! regime with a seeded [`crate::elastic::ChurnPlan`] joining/leaving
//! nodes mid-run: every join injects a warm-started model averaged
//! from Dirichlet-heterogeneous neighbors — fresh inconsistency that
//! raw momentum can amplify (cf. Momentum Tracking, arXiv 2209.15505)
//! but DecentLaM's bias-corrected momentum should absorb. Reported per
//! cell: final eval loss of the average model, accuracy, consensus,
//! realized joins/leaves and the final roster size.
//!
//! Everything is seeded (data, topology, churn schedule), so two runs
//! of the same opts produce identical tables byte for byte. The
//! `--smoke` mode is the CI acceptance gate of the elastic subsystem:
//! zero-churn bitwise == fixed-roster trainer, a mid-run
//! checkpoint/resume round-trip (through the checksummed file format)
//! reproduces the uninterrupted run bitwise, parallel == serial under
//! active churn, and reruns are byte-identical.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::data::synth::{ClassificationData, SynthSpec};
use crate::elastic::Snapshot;
use crate::grad::mlp;
use crate::util::cli::Args;
use crate::util::config::{Config, LrSchedule};
use crate::util::table::{pct, sig, Table};

#[derive(Debug, Clone)]
pub struct Opts {
    /// Initial active nodes n0.
    pub nodes: usize,
    /// Stable-id capacity (churn nmax): the workload carries one shard
    /// per stable id, so joiners bring their own data.
    pub capacity: usize,
    /// Roster floor (churn nmin).
    pub nmin: usize,
    pub steps: usize,
    pub topology: String,
    /// Methods to compare (Table 3 names).
    pub methods: Vec<String>,
    /// Symmetric churn rates swept across columns (join = leave = r).
    pub churn_rates: Vec<f64>,
    pub total_batch: usize,
    pub arch: String,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 12,
            capacity: 16,
            nmin: 6,
            steps: 160,
            topology: "ring".into(),
            methods: vec!["dmsgd".into(), "decentlam".into(), "pmsgd".into()],
            churn_rates: vec![0.0, 0.02, 0.05],
            total_batch: 1536,
            arch: "mlp-xs".into(),
            seed: 7,
        }
    }
}

impl Opts {
    /// Shared CLI flags for the `fig-elastic` subcommand and
    /// `examples/elastic_churn.rs`.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        self.nodes = args.get_usize("nodes", self.nodes)?;
        self.capacity = args.get_usize("capacity", self.capacity)?;
        self.nmin = args.get_usize("nmin", self.nmin)?;
        self.steps = args.get_usize("steps", self.steps)?;
        self.seed = args.get_usize("seed", self.seed as usize)? as u64;
        if let Some(r) = args.get("rate") {
            self.churn_rates =
                vec![r.parse().map_err(|e| anyhow::anyhow!("--rate: {e}"))?];
        }
        if let Some(t) = args.get("topology") {
            self.topology = t.into();
        }
        Ok(())
    }

    fn churn_string(&self, rate: f64) -> String {
        format!(
            "join={rate},leave={rate},nmin={},nmax={},seed={}",
            self.nmin, self.capacity, self.seed
        )
    }
}

/// One trained cell of the sweep.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: String,
    pub rate: f64,
    /// Roster size when the run ended.
    pub final_nodes: usize,
    /// Realized membership events over the run.
    pub joins: usize,
    pub leaves: usize,
    /// Eval loss of the network-average model.
    pub eval_loss: f64,
    pub accuracy: f64,
    pub consensus: f64,
}

fn cell_data(opts: &Opts) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes: opts.capacity,
        samples_per_node: 192,
        eval_samples: 512,
        dirichlet_alpha: 0.1, // strongly heterogeneous: bias regime
        seed: opts.seed,
        ..Default::default()
    })
}

fn cell_config(opts: &Opts, method: &str, rate: f64, steps: usize) -> Result<Config> {
    let mut cfg = Config::default();
    cfg.optimizer = method.into();
    cfg.nodes = opts.nodes;
    cfg.steps = steps;
    cfg.topology = opts.topology.clone();
    cfg.total_batch = opts.total_batch;
    cfg.micro_batch = 32;
    cfg.lr = 0.08;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.seed = opts.seed;
    cfg.apply_kv("churn", &opts.churn_string(rate))?;
    Ok(cfg)
}

fn cell_workload(
    opts: &Opts,
    data: &ClassificationData,
    cfg: &Config,
) -> Result<crate::grad::Workload> {
    Ok(mlp::workload(
        mlp::MlpArch::family(&opts.arch)?,
        data.clone(),
        cfg.micro_batch,
        opts.seed,
    ))
}

fn cell(opts: &Opts, data: &ClassificationData, method: &str, rate: f64) -> Result<Row> {
    let cfg = cell_config(opts, method, rate, opts.steps)?;
    let wl = cell_workload(opts, data, &cfg)?;
    let mut t = Trainer::new(cfg, wl)?;
    let report = t.run();
    let xbar = t.average_model();
    let eval_loss = t.workload.eval.loss(&xbar).unwrap_or(f64::NAN);
    let stats = t.churn_stats().copied().unwrap_or_default();
    Ok(Row {
        method: method.into(),
        rate,
        final_nodes: t.active_nodes(),
        joins: stats.joins,
        leaves: stats.leaves,
        eval_loss,
        accuracy: report.final_accuracy,
        consensus: report.final_consensus,
    })
}

pub fn run(opts: &Opts) -> Result<(Vec<Row>, Table)> {
    let data = cell_data(opts);
    let mut rows = Vec::new();
    for &rate in &opts.churn_rates {
        for method in &opts.methods {
            rows.push(cell(opts, &data, method, rate)?);
        }
    }
    let mut table = Table::new(
        &format!(
            "elastic churn sweep — {} n={}..{} (floor {}), {} steps, rates {:?} (seed {})",
            opts.topology,
            opts.nodes,
            opts.capacity,
            opts.nmin,
            opts.steps,
            opts.churn_rates,
            opts.seed
        ),
        &["method", "rate", "final n", "joins", "leaves", "consensus", "eval loss", "acc"],
    );
    for row in &rows {
        table.row(vec![
            row.method.clone(),
            format!("{}", row.rate),
            row.final_nodes.to_string(),
            row.joins.to_string(),
            row.leaves.to_string(),
            sig(row.consensus, 3),
            sig(row.eval_loss, 4),
            pct(row.accuracy),
        ]);
    }
    Ok((rows, table))
}

/// Absolute eval-loss degradation of `method` at each churn rate
/// relative to its own churn-free cell: `loss(r) − loss(0)`. Empty
/// when the sweep has no rate-0 baseline — callers must not fabricate
/// a verdict from a baseline-less sweep.
pub fn degradation(rows: &[Row], method: &str) -> Vec<(f64, f64)> {
    let Some(base) = rows
        .iter()
        .find(|r| r.method == method && r.rate == 0.0)
        .map(|r| r.eval_loss)
    else {
        return Vec::new();
    };
    rows.iter()
        .filter(|r| r.method == method)
        .map(|r| (r.rate, r.eval_loss - base))
        .collect()
}

/// CI smoke: the acceptance gate of the elastic subsystem. Asserts
/// (1) a zero-churn config is bitwise identical to the fixed-roster
/// trainer, (2) a mid-run checkpoint/resume — round-tripped through
/// the checksummed snapshot FILE — reproduces the uninterrupted run
/// bitwise, (3) runs under active churn are deterministic across
/// reruns and parallel == serial, (4) the sweep renders byte-
/// identically across reruns. Exits nonzero on any violation.
pub fn smoke(args: &Args) -> Result<()> {
    let mut opts = Opts {
        nodes: 8,
        capacity: 12,
        nmin: 4,
        steps: 40,
        churn_rates: vec![0.0, 0.15],
        total_batch: 768,
        ..Default::default()
    };
    opts.apply_args(args)?;
    let churn_rate = opts.churn_rates.iter().cloned().fold(0.0, f64::max);
    anyhow::ensure!(churn_rate > 0.0, "smoke needs an active-churn cell to gate on");

    // (1) zero churn == fixed roster, bit for bit. The roster is pinned
    // at n (nmin = nmax = n = capacity) so both runs see the same
    // workload shards.
    {
        let pinned = Opts { capacity: opts.nodes, nmin: opts.nodes, ..opts.clone() };
        let data = cell_data(&pinned);
        let run = |churn: bool| -> Result<Vec<f64>> {
            let mut cfg = cell_config(&pinned, "decentlam", 0.0, pinned.steps)?;
            if !churn {
                cfg.churn = None;
            }
            let wl = cell_workload(&pinned, &data, &cfg)?;
            Ok(Trainer::new(cfg, wl)?.run().losses)
        };
        anyhow::ensure!(
            run(true)? == run(false)?,
            "zero-churn run diverged from the fixed-roster trainer"
        );
        println!(
            "smoke 1/4 OK: zero-churn bitwise == fixed-roster trainer ({} steps)",
            pinned.steps
        );
    }

    let data = cell_data(&opts);

    // (2) checkpoint at the midpoint, resume from the FILE, continue:
    // every per-step loss and the final model must match the
    // uninterrupted run bit for bit.
    {
        let cfg = cell_config(&opts, "decentlam", churn_rate, opts.steps)?;
        let mut full = Trainer::new(cfg.clone(), cell_workload(&opts, &data, &cfg)?)?;
        let mut ref_losses = Vec::new();
        for k in 0..opts.steps {
            ref_losses.push(full.step(k));
        }
        let mid = opts.steps / 2;
        let mut first = Trainer::new(cfg.clone(), cell_workload(&opts, &data, &cfg)?)?;
        for (k, want) in ref_losses.iter().take(mid).enumerate() {
            anyhow::ensure!(first.step(k) == *want, "pre-checkpoint prefix diverged at {k}");
        }
        let path = std::env::temp_dir()
            .join(format!("decentlam_elastic_smoke_{}.snap", std::process::id()));
        first.checkpoint_to(&path)?;
        let snap = Snapshot::read_file(&path)?;
        std::fs::remove_file(&path).ok();
        let mut resumed = Trainer::resume(cfg.clone(), cell_workload(&opts, &data, &cfg)?, &snap)?;
        for (k, want) in ref_losses.iter().enumerate().skip(mid) {
            anyhow::ensure!(
                resumed.step(k) == *want,
                "checkpoint/resume diverged from the uninterrupted run at step {k}"
            );
        }
        let full_bits: Vec<u32> = full.average_model().iter().map(|v| v.to_bits()).collect();
        let res_bits: Vec<u32> = resumed.average_model().iter().map(|v| v.to_bits()).collect();
        anyhow::ensure!(full_bits == res_bits, "final average model differs after resume");
        anyhow::ensure!(full.active_ids() == resumed.active_ids(), "rosters differ after resume");
        println!(
            "smoke 2/4 OK: mid-run checkpoint/resume (via file) bitwise == uninterrupted \
             (checkpoint at step {mid}, roster ended at n={})",
            full.active_nodes()
        );
    }

    // (3) determinism + parallel == serial under ACTIVE churn; the cell
    // must actually realize membership events or the gate is vacuous.
    {
        let (losses, stats) =
            super::smoke::assert_replay_and_par_eq("active-churn cell", |threads| {
                let mut cfg = cell_config(&opts, "decentlam", churn_rate, opts.steps)?;
                cfg.threads = threads;
                let wl = cell_workload(&opts, &data, &cfg)?;
                let mut t = Trainer::new(cfg, wl)?;
                let losses = t.run().losses;
                let stats = *t.churn_stats().expect("churn cell must carry churn stats");
                Ok((losses, stats))
            })?;
        anyhow::ensure!(
            stats.joins + stats.leaves > 0,
            "rate={churn_rate} never realized a membership event — the gate is vacuous"
        );
        anyhow::ensure!(losses.iter().all(|l| l.is_finite()), "non-finite loss under churn");
        println!(
            "smoke 3/4 OK: active churn deterministic, parallel == serial \
             ({} joins, {} leaves over {} steps)",
            stats.joins, stats.leaves, opts.steps
        );
    }

    // (4) the sweep itself renders byte-identically.
    let table = {
        let sweep = Opts { steps: 30, ..opts.clone() };
        super::smoke::assert_deterministic("elastic sweep", || {
            Ok(run(&sweep)?.1.render())
        })?
    };
    println!("{table}");
    println!("smoke 4/4 OK: sweep output byte-identical across reruns");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shrunk() -> Opts {
        Opts {
            nodes: 6,
            capacity: 8,
            nmin: 3,
            steps: 40,
            methods: vec!["dmsgd".into(), "decentlam".into()],
            churn_rates: vec![0.0, 0.1],
            total_batch: 384,
            ..Default::default()
        }
    }

    #[test]
    fn shrunk_sweep_has_sane_shape() {
        let opts = shrunk();
        let (rows, table) = run(&opts).unwrap();
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.eval_loss.is_finite() && r.consensus.is_finite()));
        let get = |m: &str, rate: f64| {
            rows.iter().find(|r| r.method == m && r.rate == rate).unwrap()
        };
        // Churn-free cells never move the roster.
        assert_eq!(get("dmsgd", 0.0).final_nodes, opts.nodes);
        assert_eq!(get("dmsgd", 0.0).joins + get("dmsgd", 0.0).leaves, 0);
        // The active cell realizes events within bounds.
        let active = get("decentlam", 0.1);
        assert!(active.joins + active.leaves > 0, "rate=0.1 never churned");
        assert!((opts.nmin..=opts.capacity).contains(&active.final_nodes));
        // Gossip methods share the same churn schedule (same seed).
        assert_eq!(get("dmsgd", 0.1).joins, get("decentlam", 0.1).joins);
        assert_eq!(get("dmsgd", 0.1).leaves, get("decentlam", 0.1).leaves);
        assert!(table.render().contains("decentlam"));
    }

    #[test]
    fn sweep_output_is_deterministic() {
        let mut opts = shrunk();
        opts.steps = 15;
        opts.methods = vec!["decentlam".into()];
        let (_, a) = run(&opts).unwrap();
        let (_, b) = run(&opts).unwrap();
        assert_eq!(a.render(), b.render(), "same opts must render byte-identically");
    }

    #[test]
    fn degradation_is_relative_to_churn_free() {
        let mk = |method: &str, rate: f64, loss: f64| Row {
            method: method.into(),
            rate,
            final_nodes: 8,
            joins: 0,
            leaves: 0,
            eval_loss: loss,
            accuracy: 0.0,
            consensus: 0.0,
        };
        let rows = vec![mk("m", 0.0, 1.0), mk("m", 0.05, 1.5)];
        let d = degradation(&rows, "m");
        assert_eq!(d, vec![(0.0, 0.0), (0.05, 0.5)]);
        assert!(degradation(&rows[1..], "m").is_empty(), "no baseline -> no verdict");
        assert!(degradation(&rows, "other").is_empty());
    }
}
