//! Fault sweep: the DecentLaM-vs-DmSGD bias gap under imperfect
//! communication (the sim layer's headline figure; no paper analog —
//! this extends §7 to the fault regimes of arXiv 2410.11998).
//!
//! For each (method, drop rate) cell, train in the large-batch
//! heterogeneous regime where DmSGD's momentum-amplified inconsistency
//! bias is visible, with the [`crate::sim::FaultyEngine`] masking the
//! requested fraction of nodes per step, and report consensus distance,
//! global eval loss at the average model, accuracy, and the realized
//! (post-masking) edge fraction. Fault masking weakens mixing — the
//! effective ρ grows with the drop rate — so *both* methods degrade;
//! the claim under test is that DecentLaM, whose momentum is built from
//! bias-corrected gradients, degrades **no faster** than DmSGD.
//!
//! Everything is seeded (data, topology, fault schedule), so two runs
//! of the same opts produce identical tables byte for byte.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::data::synth::{ClassificationData, SynthSpec};
use crate::grad::mlp;
use crate::util::cli::Args;
use crate::util::config::{Config, LrSchedule};
use crate::util::table::{pct, sig, Table};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub steps: usize,
    pub topology: String,
    /// Methods to compare (Table 3 names).
    pub methods: Vec<String>,
    /// Per-step node dropout rates swept across columns.
    pub drop_rates: Vec<f64>,
    /// Extra fault rates applied at every cell (0 = off).
    pub straggle: f64,
    pub stale: f64,
    pub link: f64,
    pub total_batch: usize,
    pub arch: String,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 16,
            steps: 200,
            topology: "ring".into(),
            methods: vec!["dmsgd".into(), "decentlam".into()],
            drop_rates: vec![0.0, 0.1, 0.3],
            straggle: 0.0,
            stale: 0.0,
            link: 0.0,
            total_batch: 2048,
            arch: "mlp-xs".into(),
            seed: 7,
        }
    }
}

impl Opts {
    /// Apply the shared CLI flags (`--nodes`, `--steps`, `--seed`,
    /// `--straggle`, `--stale`, `--link`, `--topology`) — one parser
    /// for the `fig-faults` subcommand and `examples/fault_sweep.rs`.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        self.nodes = args.get_usize("nodes", self.nodes)?;
        self.steps = args.get_usize("steps", self.steps)?;
        self.seed = args.get_usize("seed", self.seed as usize)? as u64;
        self.straggle = args.get_f64("straggle", self.straggle)?;
        self.stale = args.get_f64("stale", self.stale)?;
        self.link = args.get_f64("link", self.link)?;
        if let Some(t) = args.get("topology") {
            self.topology = t.into();
        }
        Ok(())
    }
}

/// One trained cell of the sweep.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: String,
    pub drop: f64,
    /// Final consensus distance (1/n)Σ‖x_i − x̄‖².
    pub consensus: f64,
    /// Eval loss of the network-average model.
    pub eval_loss: f64,
    pub accuracy: f64,
    /// Fraction of nominal edges that actually carried messages.
    pub realized_frac: f64,
}

fn fault_string(opts: &Opts, drop: f64) -> String {
    format!(
        "drop={drop},link={},straggle={},stale={},seed={}",
        opts.link, opts.straggle, opts.stale, opts.seed
    )
}

pub fn run(opts: &Opts) -> Result<(Vec<Row>, Table)> {
    // One dataset, cloned per cell: every cell sees the same shards,
    // so differences are method + faults only.
    let data = ClassificationData::generate(&SynthSpec {
        nodes: opts.nodes,
        samples_per_node: 256,
        eval_samples: 512,
        dirichlet_alpha: 0.1, // strongly heterogeneous: bias regime
        seed: opts.seed,
        ..Default::default()
    });
    let mut rows = Vec::new();
    for &drop in &opts.drop_rates {
        for method in &opts.methods {
            let mut cfg = Config::default();
            cfg.optimizer = method.clone();
            cfg.nodes = opts.nodes;
            cfg.steps = opts.steps;
            cfg.topology = opts.topology.clone();
            cfg.total_batch = opts.total_batch;
            cfg.micro_batch = 32;
            cfg.lr = 0.08;
            cfg.linear_scaling = false;
            cfg.momentum = 0.9;
            cfg.schedule = LrSchedule::Constant;
            cfg.seed = opts.seed;
            cfg.apply_kv("faults", &fault_string(opts, drop))?;
            let wl = mlp::workload(
                mlp::MlpArch::family(&opts.arch)?,
                data.clone(),
                cfg.micro_batch,
                opts.seed,
            );
            let mut t = Trainer::new(cfg, wl)?;
            let report = t.run();
            let xbar = t.average_model();
            let eval_loss = t.workload.eval.loss(&xbar).unwrap_or(f64::NAN);
            let realized_frac =
                t.fault_stats().map(|s| s.realized_edge_fraction()).unwrap_or(1.0);
            rows.push(Row {
                method: method.clone(),
                drop,
                consensus: report.final_consensus,
                eval_loss,
                accuracy: report.final_accuracy,
                realized_frac,
            });
        }
    }

    let mut table = Table::new(
        &format!(
            "fault sweep — {} n={} {} steps, drop rates {:?} (seed {})",
            opts.topology, opts.nodes, opts.steps, opts.drop_rates, opts.seed
        ),
        &["method", "drop", "consensus", "eval loss", "acc", "edges realized"],
    );
    for row in &rows {
        table.row(vec![
            row.method.clone(),
            format!("{}", row.drop),
            sig(row.consensus, 3),
            sig(row.eval_loss, 4),
            pct(row.accuracy),
            pct(row.realized_frac),
        ]);
    }
    Ok((rows, table))
}

/// Consensus degradation factor of `method` at each drop rate relative
/// to its own fault-free consensus. Empty when the sweep has no
/// `drop == 0.0` baseline — callers must not fabricate a verdict from
/// a baseline-less sweep (NaN factors would slip through comparisons).
pub fn degradation(rows: &[Row], method: &str) -> Vec<(f64, f64)> {
    let Some(base) = rows
        .iter()
        .find(|r| r.method == method && r.drop == 0.0)
        .map(|r| r.consensus)
    else {
        return Vec::new();
    };
    rows.iter()
        .filter(|r| r.method == method)
        .map(|r| (r.drop, r.consensus / base))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrunk_sweep_keeps_decentlam_ahead_of_dmsgd() {
        let opts = Opts {
            nodes: 8,
            steps: 150,
            drop_rates: vec![0.0, 0.3],
            ..Default::default()
        };
        let (rows, table) = run(&opts).unwrap();
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.consensus.is_finite() && r.consensus >= 0.0));
        assert!(rows.iter().all(|r| r.eval_loss.is_finite()));
        let cons = |method: &str, drop: f64| {
            rows.iter()
                .find(|r| r.method == method && r.drop == drop)
                .unwrap()
                .consensus
        };
        // The bias regime: DecentLaM's consensus stays below DmSGD's,
        // fault-free and under 30% dropout alike (slack for noise).
        assert!(
            cons("decentlam", 0.0) < 1.25 * cons("dmsgd", 0.0),
            "fault-free: decentlam {} vs dmsgd {}",
            cons("decentlam", 0.0),
            cons("dmsgd", 0.0)
        );
        assert!(
            cons("decentlam", 0.3) < 1.25 * cons("dmsgd", 0.3),
            "drop=0.3: decentlam {} vs dmsgd {}",
            cons("decentlam", 0.3),
            cons("dmsgd", 0.3)
        );
        // Faults were actually injected.
        let faulted = rows.iter().find(|r| r.drop == 0.3).unwrap();
        assert!(faulted.realized_frac < 0.95, "drop=0.3 masked almost nothing");
        let clean = rows.iter().find(|r| r.drop == 0.0).unwrap();
        assert!((clean.realized_frac - 1.0).abs() < 1e-12);
        let rendered = table.render();
        assert!(rendered.contains("decentlam") && rendered.contains("dmsgd"));
    }

    #[test]
    fn sweep_output_is_deterministic() {
        let opts = Opts {
            nodes: 4,
            steps: 30,
            drop_rates: vec![0.2],
            total_batch: 256,
            ..Default::default()
        };
        let (_, a) = run(&opts).unwrap();
        let (_, b) = run(&opts).unwrap();
        assert_eq!(a.render(), b.render(), "same opts must render byte-identically");
    }

    #[test]
    fn degradation_is_relative_to_fault_free() {
        let rows = vec![
            Row {
                method: "m".into(),
                drop: 0.0,
                consensus: 2.0,
                eval_loss: 0.0,
                accuracy: 0.0,
                realized_frac: 1.0,
            },
            Row {
                method: "m".into(),
                drop: 0.3,
                consensus: 5.0,
                eval_loss: 0.0,
                accuracy: 0.0,
                realized_frac: 0.5,
            },
        ];
        let d = degradation(&rows, "m");
        assert_eq!(d, vec![(0.0, 1.0), (0.3, 2.5)]);
        // No baseline row -> empty, never NaN factors.
        assert!(degradation(&rows[1..], "m").is_empty());
        assert!(degradation(&rows, "other").is_empty());
    }
}
