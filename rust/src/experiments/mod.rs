//! Experiment harness: one generator per paper table/figure
//! (DESIGN.md §5). Every generator is a library function taking an
//! options struct (so tests can shrink it) and returning [`crate::util::table::Table`]s
//! in the same row/column layout the paper prints. The `decentlam`
//! binary and `rust/benches/` wire them to the CLI.
//!
//! | paper result | module |
//! |---|---|
//! | Table 1 (Pm vs Dm, small/large batch)   | [`table1`] |
//! | Figs. 2–3 (linreg bias curves)          | [`fig2_3`] |
//! | Table 2 (bias order vs β, γ)            | [`table2`] |
//! | Table 3 (9 methods × batch size)        | [`table3`] |
//! | Table 4 (5 architectures × batch)       | [`table4`] |
//! | Table 5 (topologies)                    | [`table5`] |
//! | Fig. 5 (loss / acc curves)              | [`fig5`]   |
//! | Fig. 6 (runtime breakdown)              | [`fig6`]   |
//! | Table 6 (detection analog)              | `table6` (pjrt feature) |
//!
//! Beyond the paper: [`fig_faults`] sweeps the DecentLaM-vs-DmSGD bias
//! gap under fault injection (sim layer, DESIGN.md §6),
//! [`fig_compression`] sweeps loss vs wire bytes across the gossip
//! payload codecs (codec layer, DESIGN.md §7), [`fig_async`] sweeps
//! time-to-target-loss against heterogeneous node clocks under bounded
//! staleness (clock layer, DESIGN.md §8), and [`fig_elastic`] sweeps
//! churn rate vs final loss over an elastic roster with seeded
//! join/leave events (elastic layer, DESIGN.md §9). The [`smoke`]
//! helpers hold the determinism scaffolding every `--smoke` CI gate
//! shares.

pub mod fig2_3;
pub mod fig5;
pub mod fig6;
pub mod fig_async;
pub mod fig_compression;
pub mod fig_elastic;
pub mod fig_faults;
pub mod smoke;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
#[cfg(feature = "pjrt")]
pub mod table6;

use crate::data::synth::{ClassificationData, SynthSpec};
use crate::grad::{mlp, Workload};
use crate::util::config::{Config, LrSchedule};

/// Shared protocol: the paper-§7.1-style config for a given total batch
/// (warmup + step decay for small batch, warmup + cosine for large).
pub fn protocol_config(
    optimizer: &str,
    total_batch: usize,
    steps: usize,
    nodes: usize,
) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.nodes = nodes;
    cfg.steps = steps;
    cfg.total_batch = total_batch;
    cfg.micro_batch = 64;
    cfg.lr = 0.05;
    cfg.lr_ref_batch = 256;
    cfg.linear_scaling = true;
    let large = total_batch > 1024;
    cfg.schedule = if large {
        LrSchedule::WarmupCosine { warmup_steps: steps / 6, total_steps: steps }
    } else {
        LrSchedule::WarmupStep {
            warmup_steps: (steps / 20).max(1),
            milestones: vec![steps / 3, 2 * steps / 3],
        }
    };
    cfg
}

/// Shared synthetic "ImageNet-like" heterogeneous dataset (DESIGN.md §2).
pub fn synth_imagenet(nodes: usize, seed: u64) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 2048,
        eval_samples: 2048,
        // Strong heterogeneity: the regime where the paper's large-batch
        // inconsistency-bias separation is visible (DESIGN.md §2).
        dirichlet_alpha: 0.1,
        margin: 2.0,
        seed,
        ..Default::default()
    })
}

/// Milder "Cifar-like" dataset (less heterogeneity, easier task).
pub fn synth_cifar(nodes: usize, seed: u64) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 1024,
        eval_samples: 2048,
        dirichlet_alpha: 1.0,
        margin: 2.6,
        seed,
        ..Default::default()
    })
}

/// Native-MLP workload of the named architecture over a dataset.
pub fn mlp_workload_named(
    arch: &str,
    data: ClassificationData,
    micro_batch: usize,
    seed: u64,
) -> anyhow::Result<Workload> {
    Ok(mlp::workload(mlp::MlpArch::family(arch)?, data, micro_batch, seed))
}
