//! Shared CI-smoke scaffolding.
//!
//! Every subsystem's `--smoke` gate repeats the same two determinism
//! claims — byte-identical reruns, and parallel == serial — before its
//! subsystem-specific assertions. This module states them once;
//! `fig_compression`, `fig_async` and `fig_elastic` (and any future
//! gate) call in instead of re-rolling the scaffolding.

use anyhow::Result;

/// Run `run(threads)` three times — twice parallel (`threads = 0`),
/// once serial (`threads = 1`) — and assert the result is
/// byte-identical across reruns AND between parallel and serial
/// execution. Returns the first result for further gating.
pub fn assert_replay_and_par_eq<T, F>(label: &str, mut run: F) -> Result<T>
where
    T: PartialEq + std::fmt::Debug,
    F: FnMut(usize) -> Result<T>,
{
    let a = run(0)?;
    let b = run(0)?;
    anyhow::ensure!(a == b, "{label}: rerun was not byte-identical");
    let c = run(1)?;
    anyhow::ensure!(a == c, "{label}: parallel != serial");
    Ok(a)
}

/// Run twice and assert byte-identical output (rendered tables, CSV
/// blobs, …). Returns the first result.
pub fn assert_deterministic<T, F>(label: &str, mut run: F) -> Result<T>
where
    T: PartialEq + std::fmt::Debug,
    F: FnMut() -> Result<T>,
{
    let a = run()?;
    let b = run()?;
    anyhow::ensure!(a == b, "{label}: output was not byte-identical across reruns");
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_gate_passes_deterministic_and_catches_drift() {
        let ok = assert_replay_and_par_eq("ok", |_| Ok(vec![1.0f64, 2.0]));
        assert_eq!(ok.unwrap(), vec![1.0, 2.0]);
        // Thread-dependent result: parallel != serial must fail.
        let bad = assert_replay_and_par_eq("bad", |threads| Ok(threads));
        assert!(bad.is_err());
        // Call-dependent result: rerun must fail.
        let mut calls = 0usize;
        let drift = assert_replay_and_par_eq("drift", |_| {
            calls += 1;
            Ok(calls)
        });
        assert!(drift.is_err());
    }

    #[test]
    fn deterministic_gate() {
        assert_eq!(assert_deterministic("ok", || Ok("x")).unwrap(), "x");
        let mut calls = 0usize;
        assert!(assert_deterministic("drift", || {
            calls += 1;
            Ok(calls)
        })
        .is_err());
    }
}
