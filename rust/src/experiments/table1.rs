//! Table 1: PmSGD vs DmSGD under small and large batch on the two
//! synthetic datasets ("cifar-like" mild heterogeneity, "imagenet-like"
//! strong heterogeneity). No LARS anywhere; identical hyper-parameters
//! between the two methods — exactly the paper's setup.
//!
//! Expected shape: near-parity at small batch; DmSGD degrades more than
//! PmSGD at large batch (momentum-amplified inconsistency bias).

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::util::table::{pct, Table};

use super::{mlp_workload_named, protocol_config, synth_cifar, synth_imagenet};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub steps: usize,
    pub arch: String,
    pub small_batch: usize,
    pub large_batch: usize,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 8,
            steps: 400,
            arch: "mlp-s".into(),
            small_batch: 256,
            large_batch: 4096,
            seed: 1,
        }
    }
}

/// (dataset, batch, method) -> accuracy.
pub type Cell = (String, usize, String, f64);

pub fn run(opts: &Opts) -> Result<(Vec<Cell>, Table)> {
    let mut cells = Vec::new();
    for dataset in ["cifar-like", "imagenet-like"] {
        for &batch in &[opts.small_batch, opts.large_batch] {
            for method in ["pmsgd", "dmsgd"] {
                let data = if dataset == "cifar-like" {
                    synth_cifar(opts.nodes, opts.seed)
                } else {
                    synth_imagenet(opts.nodes, opts.seed)
                };
                let mut cfg = protocol_config(method, batch, opts.steps, opts.nodes);
                cfg.seed = opts.seed;
                let wl = mlp_workload_named(&opts.arch, data, cfg.micro_batch, opts.seed)?;
                let mut t = Trainer::new(cfg, wl)?;
                let report = t.run();
                cells.push((dataset.to_string(), batch, method.to_string(), report.final_accuracy));
            }
        }
    }
    let mut table = Table::new(
        "Table 1 — top-1 validation accuracy, PmSGD vs DmSGD",
        &[
            "method",
            &format!("cifar-like {}", opts.small_batch),
            &format!("cifar-like {}", opts.large_batch),
            &format!("imagenet-like {}", opts.small_batch),
            &format!("imagenet-like {}", opts.large_batch),
        ],
    );
    for method in ["pmsgd", "dmsgd"] {
        let find = |ds: &str, b: usize| {
            cells
                .iter()
                .find(|(d, bb, m, _)| d == ds && *bb == b && m == method)
                .map(|c| pct(c.3))
                .unwrap_or_default()
        };
        table.row(vec![
            method.to_string(),
            find("cifar-like", opts.small_batch),
            find("cifar-like", opts.large_batch),
            find("imagenet-like", opts.small_batch),
            find("imagenet-like", opts.large_batch),
        ]);
    }
    Ok((cells, table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrunk_table1_runs_and_reports_accuracy() {
        let opts = Opts { steps: 60, nodes: 4, large_batch: 1024, ..Default::default() };
        let (cells, table) = run(&opts).unwrap();
        assert_eq!(cells.len(), 8);
        assert!(cells.iter().all(|c| c.3.is_finite() && c.3 > 0.1));
        assert!(table.render().contains("pmsgd"));
    }
}
