//! Table 2: empirical verification of the inconsistency-bias orders.
//!
//! The paper's Table 2 is theoretical; we verify it empirically on the
//! full-batch linear-regression workload by measuring each method's
//! limiting bias while sweeping γ (expect slope 2 in log–log for all
//! methods) and 1/(1−β) (expect slope ≈2 for DmSGD/AWC, ≈0 for
//! DecentLaM/DSGD/D², matching O(γ²b²/(1−β)^p)).

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::data::LinRegProblem;
use crate::grad::linreg;
use crate::util::config::{Config, LrSchedule};
use crate::util::math::linfit_slope;
use crate::util::table::{sig, Table};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub rows: usize,
    pub dim: usize,
    pub steps: usize,
    pub topology: String,
    pub seed: u64,
    pub methods: Vec<String>,
    pub betas: Vec<f64>,
    pub gammas: Vec<f64>,
    /// β used during the γ sweep / γ used during the β sweep.
    pub base_beta: f64,
    pub base_gamma: f64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 8,
            rows: 50,
            dim: 30,
            steps: 25_000,
            topology: "ring".into(),
            seed: 1,
            methods: ["dsgd", "dmsgd", "decentlam", "awc-dmsgd", "da-dmsgd", "d2-dmsgd"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            // The orders in Table 2 are asymptotic (γ → 0); stay in the
            // small-γ regime or higher-order terms flatten the fit.
            betas: vec![0.3, 0.5, 0.8, 0.9],
            gammas: vec![0.00025, 0.0005, 0.001],
            base_beta: 0.8,
            base_gamma: 0.0005,
        }
    }
}

fn limiting_bias(
    problem: &LinRegProblem,
    opts: &Opts,
    method: &str,
    gamma: f64,
    beta: f64,
) -> Result<f64> {
    let mut cfg = Config::default();
    cfg.nodes = opts.nodes;
    cfg.optimizer = method.into();
    cfg.topology = opts.topology.clone();
    cfg.lr = gamma;
    cfg.linear_scaling = false;
    cfg.momentum = beta;
    cfg.schedule = LrSchedule::Constant;
    cfg.steps = opts.steps;
    cfg.seed = opts.seed;
    cfg.threads = 1;
    let mut trainer = Trainer::new(cfg, linreg::workload(problem.clone()))?;
    for k in 0..opts.steps {
        trainer.step(k);
    }
    let xs: Vec<Vec<f32>> = trainer.states.iter().map(|s| s.x.clone()).collect();
    Ok(problem.relative_error(&xs).max(1e-300))
}

/// Measured bias-scaling exponents per method.
#[derive(Debug, Clone)]
pub struct Exponents {
    pub method: String,
    /// Fitted d log(bias) / d log(gamma).
    pub gamma_exp: f64,
    /// Fitted d log(bias) / d log(1/(1−β)).
    pub beta_exp: f64,
    /// Largest bias observed across the sweeps; when this sits at the
    /// f32 noise floor the exponents are meaningless (D² removes the
    /// bias entirely, so there is nothing to fit).
    pub max_bias: f64,
}

/// Below this, limiting bias is indistinguishable from f32 rounding.
pub const NOISE_FLOOR: f64 = 1e-11;

pub fn run(opts: &Opts) -> Result<(Vec<Exponents>, Table)> {
    let problem = LinRegProblem::generate(opts.nodes, opts.rows, opts.dim, opts.seed);
    let mut results = Vec::new();
    for method in &opts.methods {
        // γ sweep at fixed β.
        let lx: Vec<f64> = opts.gammas.iter().map(|g| g.ln()).collect();
        let ly: Vec<f64> = opts
            .gammas
            .iter()
            .map(|&g| limiting_bias(&problem, opts, method, g, opts.base_beta).map(f64::ln))
            .collect::<Result<_>>()?;
        let gamma_exp = linfit_slope(&lx, &ly);
        // β sweep at fixed γ (x-axis log 1/(1−β)).
        let bx: Vec<f64> = opts.betas.iter().map(|b| (1.0 / (1.0 - b)).ln()).collect();
        let by: Vec<f64> = opts
            .betas
            .iter()
            .map(|&b| limiting_bias(&problem, opts, method, opts.base_gamma, b).map(f64::ln))
            .collect::<Result<_>>()?;
        let beta_exp = linfit_slope(&bx, &by);
        let max_bias = ly
            .iter()
            .chain(&by)
            .map(|l| l.exp())
            .fold(0.0f64, f64::max);
        results.push(Exponents { method: method.clone(), gamma_exp, beta_exp, max_bias });
    }
    let mut table = Table::new(
        "Table 2 — measured inconsistency-bias exponents (bias ∝ γ^a · (1/(1−β))^b)",
        &["method", "γ-exponent (theory 2)", "(1−β)-exponent", "theory (1−β)-exp"],
    );
    for e in &results {
        let theory = match e.method.as_str() {
            "dmsgd" | "awc-dmsgd" | "da-dmsgd" => "2",
            "dsgd" | "decentlam" => "0",
            "d2-dmsgd" => "0 (removes bias)",
            _ => "?",
        };
        let (ge, be) = if e.max_bias < NOISE_FLOOR {
            ("— (noise floor)".to_string(), "— (noise floor)".to_string())
        } else {
            (sig(e.gamma_exp, 3), sig(e.beta_exp, 3))
        };
        table.row(vec![e.method.clone(), ge, be, theory.into()]);
    }
    Ok((results, table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dmsgd_beta_exponent_two_decentlam_zero() {
        let opts = Opts {
            rows: 20,
            dim: 10,
            steps: 20_000,
            methods: vec!["dmsgd".into(), "decentlam".into()],
            betas: vec![0.3, 0.8, 0.9],
            gammas: vec![0.00025, 0.0005, 0.001],
            ..Default::default()
        };
        let (res, _) = run(&opts).unwrap();
        let get = |m: &str| res.iter().find(|e| e.method == m).unwrap();
        let dm = get("dmsgd");
        let dl = get("decentlam");
        assert!(dm.beta_exp > 1.2, "DmSGD β-exponent ~2, got {}", dm.beta_exp);
        assert!(dl.beta_exp.abs() < 0.6, "DecentLaM β-independent, got {}", dl.beta_exp);
        assert!((dm.gamma_exp - 2.0).abs() < 0.7, "γ² scaling, got {}", dm.gamma_exp);
        assert!((dl.gamma_exp - 2.0).abs() < 0.7, "γ² scaling, got {}", dl.gamma_exp);
    }
}
