//! Table 3: top-1 accuracy of all nine methods across total batch sizes
//! on the heterogeneous synthetic dataset (ResNet-50/ImageNet analog),
//! symmetric-exponential topology, paper-§7.1 LR protocol.
//!
//! Expected shape: all methods comparable at the smallest batch;
//! momentum-amplified methods (DmSGD, DA/AWC, SlowMo) drop at the
//! largest batch; DecentLaM holds and tops the decentralized column.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::optim;
use crate::util::table::{pct, Table};

use super::{mlp_workload_named, protocol_config, synth_imagenet};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub steps: usize,
    pub arch: String,
    pub batches: Vec<usize>,
    pub methods: Vec<String>,
    pub topology: String,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 8,
            steps: 400,
            arch: "mlp-s".into(),
            // Scaled-down analogs of the paper's 2K/8K/16K/32K.
            batches: vec![256, 1024, 2048, 4096],
            methods: optim::ALL.iter().map(|s| s.to_string()).collect(),
            topology: "sym-exp".into(),
            seed: 1,
        }
    }
}

pub type Cell = (String, usize, f64);

pub fn run(opts: &Opts) -> Result<(Vec<Cell>, Table)> {
    let mut cells: Vec<Cell> = Vec::new();
    for method in &opts.methods {
        for &batch in &opts.batches {
            let data = synth_imagenet(opts.nodes, opts.seed);
            let mut cfg = protocol_config(method, batch, opts.steps, opts.nodes);
            cfg.topology = opts.topology.clone();
            cfg.seed = opts.seed;
            let wl = mlp_workload_named(&opts.arch, data, cfg.micro_batch, opts.seed)?;
            let mut t = Trainer::new(cfg, wl)?;
            let report = t.run();
            cells.push((method.clone(), batch, report.final_accuracy));
        }
    }
    let mut headers: Vec<String> = vec!["method".into()];
    headers.extend(opts.batches.iter().map(|b| b.to_string()));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!("Table 3 — top-1 accuracy vs total batch ({} topology)", opts.topology),
        &hrefs,
    );
    for method in &opts.methods {
        let mut row = vec![method.clone()];
        for &b in &opts.batches {
            let acc = cells
                .iter()
                .find(|(m, bb, _)| m == method && *bb == b)
                .map(|c| c.2)
                .unwrap_or(f64::NAN);
            row.push(pct(acc));
        }
        table.row(row);
    }
    Ok((cells, table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrunk_table3_decentlam_competitive_at_large_batch() {
        let opts = Opts {
            nodes: 4,
            steps: 80,
            batches: vec![128, 1024],
            methods: vec!["pmsgd".into(), "dmsgd".into(), "decentlam".into()],
            ..Default::default()
        };
        let (cells, _) = run(&opts).unwrap();
        let acc = |m: &str, b: usize| {
            cells.iter().find(|(mm, bb, _)| mm == m && *bb == b).unwrap().2
        };
        // Everything learns at the small batch.
        for m in ["pmsgd", "dmsgd", "decentlam"] {
            assert!(acc(m, 128) > 0.3, "{m} small-batch acc {}", acc(m, 128));
        }
        // DecentLaM does not collapse at large batch.
        assert!(
            acc("decentlam", 1024) + 0.10 >= acc("dmsgd", 1024),
            "decentlam {} vs dmsgd {}",
            acc("decentlam", 1024),
            acc("dmsgd", 1024)
        );
    }
}
