//! Table 4: the optimizer comparison across the five-model architecture
//! family (ResNet-18/34/50, MobileNet-v2, EfficientNet stand-ins of
//! increasing capacity) × batch sizes.
//!
//! Expected shape: the optimizer ranking is consistent per architecture;
//! at the largest batch either PmSGD+LARS or DecentLaM takes each
//! column, with DecentLaM winning among decentralized methods.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::optim;
use crate::util::table::{pct, Table};

use super::{mlp_workload_named, protocol_config, synth_imagenet};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub steps: usize,
    pub archs: Vec<String>,
    pub batches: Vec<usize>,
    pub methods: Vec<String>,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 8,
            steps: 250,
            archs: ["mlp-xs", "mlp-s", "mlp-m", "mlp-l", "mlp-xl"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            batches: vec![256, 2048],
            methods: optim::ALL.iter().map(|s| s.to_string()).collect(),
            seed: 1,
        }
    }
}

pub type Cell = (String, String, usize, f64); // (arch, method, batch, acc)

pub fn run(opts: &Opts) -> Result<(Vec<Cell>, Table)> {
    let mut cells: Vec<Cell> = Vec::new();
    for arch in &opts.archs {
        for method in &opts.methods {
            for &batch in &opts.batches {
                let data = synth_imagenet(opts.nodes, opts.seed);
                let mut cfg = protocol_config(method, batch, opts.steps, opts.nodes);
                cfg.seed = opts.seed;
                let wl = mlp_workload_named(arch, data, cfg.micro_batch, opts.seed)?;
                let mut t = Trainer::new(cfg, wl)?;
                let report = t.run();
                cells.push((arch.clone(), method.clone(), batch, report.final_accuracy));
            }
        }
    }
    let mut headers: Vec<String> = vec!["method".into()];
    for arch in &opts.archs {
        for &b in &opts.batches {
            headers.push(format!("{arch}/{b}"));
        }
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table =
        Table::new("Table 4 — top-1 accuracy per architecture × batch", &hrefs);
    for method in &opts.methods {
        let mut row = vec![method.clone()];
        for arch in &opts.archs {
            for &b in &opts.batches {
                let acc = cells
                    .iter()
                    .find(|(a, m, bb, _)| a == arch && m == method && *bb == b)
                    .map(|c| c.3)
                    .unwrap_or(f64::NAN);
                row.push(pct(acc));
            }
        }
        table.row(row);
    }
    Ok((cells, table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrunk_table4_two_archs() {
        let opts = Opts {
            nodes: 4,
            steps: 50,
            archs: vec!["mlp-xs".into(), "mlp-s".into()],
            batches: vec![256],
            methods: vec!["decentlam".into(), "dmsgd".into()],
            ..Default::default()
        };
        let (cells, table) = run(&opts).unwrap();
        assert_eq!(cells.len(), 4);
        assert!(cells.iter().all(|c| c.3 > 0.2), "{cells:?}");
        assert!(table.render().contains("mlp-s/256"));
    }
}
