//! Table 5: DecentLaM across network topologies (ring, mesh, symmetric
//! exponential, bipartite random match) at two large batch sizes, plus
//! the measured spectral constant ρ of each topology.
//!
//! Expected shape: accuracy is consistent (within ~1 point) across
//! topologies — the paper's robustness claim.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::topology::{metropolis_hastings, rho, Kind, Topology};
use crate::util::table::{pct, sig, Table};

use super::{mlp_workload_named, protocol_config, synth_imagenet};

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub steps: usize,
    pub arch: String,
    pub batches: Vec<usize>,
    pub topologies: Vec<String>,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 8,
            steps: 400,
            arch: "mlp-s".into(),
            batches: vec![2048, 4096],
            topologies: ["ring", "mesh", "sym-exp", "bipartite"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            seed: 1,
        }
    }
}

pub type Cell = (String, usize, f64);

pub fn run(opts: &Opts) -> Result<(Vec<Cell>, Table)> {
    let mut cells: Vec<Cell> = Vec::new();
    for topo in &opts.topologies {
        for &batch in &opts.batches {
            let data = synth_imagenet(opts.nodes, opts.seed);
            let mut cfg = protocol_config("decentlam", batch, opts.steps, opts.nodes);
            cfg.topology = topo.clone();
            cfg.seed = opts.seed;
            let wl = mlp_workload_named(&opts.arch, data, cfg.micro_batch, opts.seed)?;
            let mut t = Trainer::new(cfg, wl)?;
            let report = t.run();
            cells.push((topo.clone(), batch, report.final_accuracy));
        }
    }
    let mut headers: Vec<String> = vec!["topology".into(), "rho".into()];
    headers.extend(opts.batches.iter().map(|b| b.to_string()));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Table 5 — DecentLaM across topologies", &hrefs);
    for topo in &opts.topologies {
        let kind = Kind::parse(topo)?;
        let r = rho(&metropolis_hastings(&Topology::at_step(kind, opts.nodes, opts.seed, 0)));
        let mut row = vec![topo.clone(), sig(r, 3)];
        for &b in &opts.batches {
            let acc = cells
                .iter()
                .find(|(t, bb, _)| t == topo && *bb == b)
                .map(|c| c.2)
                .unwrap_or(f64::NAN);
            row.push(pct(acc));
        }
        table.row(row);
    }
    Ok((cells, table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrunk_table5_consistent_across_topologies() {
        let opts = Opts {
            nodes: 4,
            steps: 80,
            batches: vec![512],
            topologies: vec!["ring".into(), "bipartite".into()],
            ..Default::default()
        };
        let (cells, _) = run(&opts).unwrap();
        assert_eq!(cells.len(), 2);
        let accs: Vec<f64> = cells.iter().map(|c| c.2).collect();
        assert!(accs.iter().all(|&a| a > 0.3), "{accs:?}");
        assert!((accs[0] - accs[1]).abs() < 0.2, "topology robustness: {accs:?}");
    }
}
