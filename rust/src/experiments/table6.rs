//! Table 6: object-detection analog — the multi-head synthetic task
//! (classification head + box-regression head, CE + smooth-L1 loss)
//! trained through the PJRT `det-head` artifact. Substitutes VOC/COCO +
//! Faster-RCNN/RetinaNet (DESIGN.md §2): what carries over is that the
//! optimizer ranking holds on a composite multi-loss objective at
//! moderate batch size, where all methods end within a small margin and
//! DecentLaM edges out the baselines.
//!
//! Metric: a bounded mAP-like proxy `100·exp(−eval_loss)` on held-out
//! data (higher is better), reported alongside the raw eval loss.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::data::synth::{ClassificationData, SynthSpec};
use crate::grad::{Evaluator, NodeGrad, Workload};
use crate::runtime::{Manifest, RuntimeHandle, Tensor};
use crate::util::rng::Pcg64;
use crate::util::table::{sig, Table};

use super::protocol_config;

#[derive(Debug, Clone)]
pub struct Opts {
    pub nodes: usize,
    pub steps: usize,
    pub total_batch: usize,
    pub methods: Vec<String>,
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            nodes: 8,
            steps: 150,
            total_batch: 256, // the paper's detection batch
            methods: ["pmsgd", "pmsgd-lars", "dmsgd", "da-dmsgd", "decentlam"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            seed: 1,
        }
    }
}

/// Synthetic detection data: classification features + boxes that are a
/// fixed linear function of the features plus noise.
pub struct DetData {
    pub cls: ClassificationData,
    /// Per shard: row-major (n, 4) box targets aligned with shard order.
    pub boxes: Vec<Vec<f32>>,
    pub eval_boxes: Vec<f32>,
}

pub fn gen_det_data(nodes: usize, seed: u64) -> DetData {
    let cls = ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 1024,
        eval_samples: 512,
        dirichlet_alpha: 0.5,
        seed,
        ..Default::default()
    });
    let d = cls.input_dim;
    let mut rng = Pcg64::new(seed, 0xb0f5);
    let mut bmap = vec![0.0f32; d * 4];
    rng.normal_fill(&mut bmap, (1.0 / d as f32).sqrt());
    let project = |x: &[f32], rng: &mut Pcg64| -> [f32; 4] {
        let mut out = [0.0f32; 4];
        for (k, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (j, &xv) in x.iter().enumerate() {
                acc += xv * bmap[j * 4 + k];
            }
            *o = acc + rng.normal() as f32 * 0.05;
        }
        out
    };
    let boxes: Vec<Vec<f32>> = cls
        .shards
        .iter()
        .map(|sh| {
            let mut out = vec![0.0f32; sh.n * 4];
            for s in 0..sh.n {
                let b = project(&sh.x[s * d..(s + 1) * d], &mut rng);
                out[s * 4..(s + 1) * 4].copy_from_slice(&b);
            }
            out
        })
        .collect();
    let mut eval_boxes = vec![0.0f32; cls.eval_n * 4];
    for s in 0..cls.eval_n {
        let b = project(&cls.eval_x[s * d..(s + 1) * d], &mut rng);
        eval_boxes[s * 4..(s + 1) * 4].copy_from_slice(&b);
    }
    DetData { cls, boxes, eval_boxes }
}

/// PJRT detection node: samples (x, y, box) micro-batches, runs
/// `det-head_grad`.
struct DetNodeGrad {
    rt: RuntimeHandle,
    dim: usize,
    input_dim: usize,
    micro_batch: usize,
    x: Vec<f32>,
    y: Vec<i32>,
    boxes: Vec<f32>,
    rng: Pcg64,
}

impl NodeGrad for DetNodeGrad {
    fn grad_accum(&mut self, theta: &[f32], accum: usize, out: &mut [f32]) -> f64 {
        out.iter_mut().for_each(|v| *v = 0.0);
        let b = self.micro_batch;
        let d = self.input_dim;
        let n = self.y.len();
        let mut loss = 0.0;
        for _ in 0..accum {
            let mut bx = vec![0.0f32; b * d];
            let mut by = vec![0i32; b];
            let mut bb = vec![0.0f32; b * 4];
            for k in 0..b {
                let idx = self.rng.below(n);
                bx[k * d..(k + 1) * d].copy_from_slice(&self.x[idx * d..(idx + 1) * d]);
                by[k] = self.y[idx];
                bb[k * 4..(k + 1) * 4].copy_from_slice(&self.boxes[idx * 4..(idx + 1) * 4]);
            }
            let outputs = self
                .rt
                .exec(
                    "det-head_grad",
                    vec![
                        Tensor::f32(theta.to_vec(), &[self.dim as i64]),
                        Tensor::f32(bx, &[b as i64, d as i64]),
                        Tensor::i32(by, &[b as i64]),
                        Tensor::f32(bb, &[b as i64, 4]),
                    ],
                )
                .expect("det grad exec failed");
            loss += outputs[0][0] as f64;
            crate::util::math::axpy(out, 1.0, &outputs[1]);
        }
        let inv = 1.0 / accum as f32;
        out.iter_mut().for_each(|v| *v *= inv);
        loss / accum as f64
    }
}

/// Held-out composite loss -> mAP-like proxy.
struct DetEvaluator {
    rt: RuntimeHandle,
    dim: usize,
    input_dim: usize,
    micro_batch: usize,
    x: Vec<f32>,
    y: Vec<i32>,
    boxes: Vec<f32>,
}

impl DetEvaluator {
    fn eval_loss(&mut self, theta: &[f32]) -> f64 {
        let b = self.micro_batch;
        let d = self.input_dim;
        let n = self.y.len();
        let mut total = 0.0;
        let mut batches = 0;
        let mut done = 0;
        while done + b <= n {
            let bx = self.x[done * d..(done + b) * d].to_vec();
            let by = self.y[done..done + b].to_vec();
            let bb = self.boxes[done * 4..(done + b) * 4].to_vec();
            let out = self
                .rt
                .exec(
                    "det-head_grad",
                    vec![
                        Tensor::f32(theta.to_vec(), &[self.dim as i64]),
                        Tensor::f32(bx, &[b as i64, d as i64]),
                        Tensor::i32(by, &[b as i64]),
                        Tensor::f32(bb, &[b as i64, 4]),
                    ],
                )
                .expect("det eval exec failed");
            total += out[0][0] as f64;
            batches += 1;
            done += b;
        }
        total / batches.max(1) as f64
    }
}

impl Evaluator for DetEvaluator {
    fn accuracy(&mut self, theta: &[f32]) -> f64 {
        // mAP-like bounded proxy in [0, 1].
        (-self.eval_loss(theta)).exp()
    }

    fn loss(&mut self, theta: &[f32]) -> Option<f64> {
        Some(self.eval_loss(theta))
    }
}

/// Build the PJRT detection workload.
pub fn det_workload(rt: &RuntimeHandle, manifest: &Manifest, data: DetData, seed: u64) -> Result<Workload> {
    let info = manifest.model("det-head")?;
    rt.load_artifact(manifest, "det-head_grad")?;
    let init = manifest.load_init(&info)?;
    let d = info.input_dim;
    let nodes: Vec<Box<dyn NodeGrad>> = data
        .cls
        .shards
        .iter()
        .zip(&data.boxes)
        .enumerate()
        .map(|(rank, (sh, boxes))| {
            Box::new(DetNodeGrad {
                rt: rt.clone(),
                dim: info.dim,
                input_dim: d,
                micro_batch: info.micro_batch,
                x: sh.x.clone(),
                y: sh.y.clone(),
                boxes: boxes.clone(),
                rng: Pcg64::new(seed, 0xde7 + rank as u64),
            }) as Box<dyn NodeGrad>
        })
        .collect();
    let eval = DetEvaluator {
        rt: rt.clone(),
        dim: info.dim,
        input_dim: d,
        micro_batch: info.micro_batch,
        x: data.cls.eval_x.clone(),
        y: data.cls.eval_y.clone(),
        boxes: data.eval_boxes.clone(),
    };
    Ok(Workload {
        name: "det-head".into(),
        dim: info.dim,
        layer_ranges: info.layer_ranges.clone(),
        init,
        nodes,
        eval: Box::new(eval),
    })
}

pub type Cell = (String, f64, f64); // (method, map_proxy, eval_loss)

pub fn run(rt: &RuntimeHandle, manifest: &Manifest, opts: &Opts) -> Result<(Vec<Cell>, Table)> {
    let mut cells = Vec::new();
    for method in &opts.methods {
        let data = gen_det_data(opts.nodes, opts.seed);
        let mut cfg = protocol_config(method, opts.total_batch, opts.steps, opts.nodes);
        cfg.micro_batch = manifest.model("det-head")?.micro_batch;
        cfg.seed = opts.seed;
        cfg.lr = 0.02;
        let wl = det_workload(rt, manifest, data, opts.seed)?;
        let mut t = Trainer::new(cfg, wl)?;
        let report = t.run();
        let map_proxy = report.final_accuracy;
        let eval_loss = -report.final_accuracy.ln();
        cells.push((method.clone(), map_proxy, eval_loss));
    }
    let mut table = Table::new(
        "Table 6 — detection analog (multi-head CE + smooth-L1)",
        &["method", "mAP proxy (x100)", "eval loss"],
    );
    for (m, p, l) in &cells {
        table.row(vec![m.clone(), sig(100.0 * p, 4), sig(*l, 4)]);
    }
    Ok((cells, table))
}
