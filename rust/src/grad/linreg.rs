//! NodeGrad adapters over the exact linear-regression problem
//! (full-batch, deterministic — the Figs. 2–3 / Table 2 workload).

use std::sync::Arc;

use crate::data::LinRegProblem;

use super::{Evaluator, NodeGrad, Workload};

/// Full-batch exact gradient for one node.
pub struct LinRegNodeGrad {
    problem: Arc<LinRegProblem>,
    rank: usize,
}

impl NodeGrad for LinRegNodeGrad {
    fn grad_accum(&mut self, x: &[f32], _accum: usize, out: &mut [f32]) -> f64 {
        // Full batch: accumulation is a no-op (zero gradient noise — the
        // extreme the paper uses to isolate inconsistency bias).
        self.problem.grad(self.rank, x, out);
        self.problem.loss(self.rank, x)
    }
}

/// "Accuracy" = negative relative error to x*, so higher is better.
pub struct LinRegEvaluator {
    problem: Arc<LinRegProblem>,
}

impl Evaluator for LinRegEvaluator {
    fn accuracy(&mut self, x: &[f32]) -> f64 {
        let xs = vec![x.to_vec()];
        -self.problem.relative_error(&xs)
    }
}

/// Build the linear-regression workload (all nodes share the Arc'd
/// problem; gradients are exact).
pub fn workload(problem: LinRegProblem) -> Workload {
    let problem = Arc::new(problem);
    let dim = problem.dim;
    let nodes: Vec<Box<dyn NodeGrad>> = (0..problem.n_nodes)
        .map(|rank| {
            Box::new(LinRegNodeGrad { problem: Arc::clone(&problem), rank })
                as Box<dyn NodeGrad>
        })
        .collect();
    Workload {
        name: "linreg".into(),
        dim,
        layer_ranges: vec![(0, dim)],
        init: vec![0.0; dim],
        nodes,
        eval: Box::new(LinRegEvaluator { problem }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_shapes() {
        let p = LinRegProblem::generate(4, 10, 6, 1);
        let mut wl = workload(p);
        assert_eq!(wl.dim, 6);
        assert_eq!(wl.nodes.len(), 4);
        let mut g = vec![0.0f32; 6];
        let loss = wl.nodes[0].grad_accum(&vec![0.0; 6], 1, &mut g);
        assert!(loss > 0.0);
        assert!(crate::util::math::norm2(&g) > 0.0);
    }

    #[test]
    fn evaluator_peaks_at_solution() {
        let p = LinRegProblem::generate(4, 20, 6, 2);
        let xstar = p.x_star.clone();
        let mut wl = workload(p);
        let at_solution = wl.eval.accuracy(&xstar);
        let away = wl.eval.accuracy(&vec![0.0; 6]);
        assert!(at_solution > away);
        assert!(at_solution > -1e-12);
    }
}
