//! Native flat-parameter MLP gradient engine.
//!
//! Bit-for-bit the same parameterization as `python/compile/model.py`
//! (`MlpConfig`): theta packs `[W1 (i×o row-major), b1, W2, b2, ...]`;
//! hidden activations are ReLU, loss is mean softmax cross-entropy.
//! Used as the fast path for the large table sweeps; its gradients are
//! cross-checked against the PJRT artifact in `rust/tests/integration.rs`
//! and against finite differences here.

use crate::data::synth::{ClassificationData, NodeShard, ShardCursor};
use crate::util::rng::Pcg64;

use super::{Evaluator, NodeGrad, Workload};

/// Architecture description matching `MlpConfig` in model.py.
#[derive(Debug, Clone)]
pub struct MlpArch {
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub num_classes: usize,
}

impl MlpArch {
    pub fn new(input_dim: usize, hidden: &[usize], num_classes: usize) -> MlpArch {
        MlpArch { input_dim, hidden: hidden.to_vec(), num_classes }
    }

    /// The Table 4 model family (DESIGN.md §2).
    pub fn family(name: &str) -> anyhow::Result<MlpArch> {
        Ok(match name {
            "mlp-xs" => MlpArch::new(64, &[64], 10),
            "mlp-s" | "native-mlp" => MlpArch::new(64, &[128, 64], 10),
            "mlp-m" => MlpArch::new(64, &[256, 128], 10),
            "mlp-l" => MlpArch::new(64, &[512, 256, 128], 10),
            "mlp-xl" => MlpArch::new(64, &[1024, 512, 256], 10),
            "native-logreg" => MlpArch::new(64, &[], 10),
            other => anyhow::bail!("unknown MLP architecture `{other}`"),
        })
    }

    fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.input_dim];
        d.extend_from_slice(&self.hidden);
        d.push(self.num_classes);
        d
    }

    pub fn dim(&self) -> usize {
        let d = self.dims();
        d.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Flat offsets of every tensor (W then b per layer), matching
    /// `ParamSpec::layer_ranges` in model.py.
    pub fn layer_ranges(&self) -> Vec<(usize, usize)> {
        let d = self.dims();
        let mut out = Vec::new();
        let mut off = 0;
        for w in d.windows(2) {
            out.push((off, off + w[0] * w[1]));
            off += w[0] * w[1];
            out.push((off, off + w[1]));
            off += w[1];
        }
        out
    }

    /// He-init, mirroring `MlpConfig.init` (different RNG, same law).
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0x1417);
        let d = self.dims();
        let mut theta = Vec::with_capacity(self.dim());
        for w in d.windows(2) {
            let (i, o) = (w[0], w[1]);
            let sigma = (2.0 / i as f64).sqrt() as f32;
            let mut wbuf = vec![0.0f32; i * o];
            rng.normal_fill(&mut wbuf, sigma);
            theta.extend_from_slice(&wbuf);
            theta.extend(std::iter::repeat(0.0f32).take(o));
        }
        theta
    }
}

/// Scratch for one forward/backward pass at a fixed micro-batch.
struct Pass {
    /// Activations per layer (incl. input copy), each B × dim.
    acts: Vec<Vec<f32>>,
    /// Pre-activations per layer.
    zs: Vec<Vec<f32>>,
    /// Gradient buffer w.r.t. current layer output.
    delta: Vec<f32>,
    delta_next: Vec<f32>,
}

/// Forward + backward over one micro-batch; accumulates grads into
/// `gout` (+=) and returns the batch loss. Factored out so both the
/// shard engine and tests use identical code.
#[allow(clippy::too_many_arguments)]
fn fwd_bwd(
    arch: &MlpArch,
    theta: &[f32],
    xb: &[f32],
    yb: &[i32],
    pass: &mut Pass,
    gout: &mut [f32],
) -> f64 {
    let dims = arch.dims();
    let layers = dims.len() - 1;
    let b = yb.len();
    // ---- forward ----
    pass.acts[0][..b * dims[0]].copy_from_slice(xb);
    let mut off = 0usize;
    let mut offsets = Vec::with_capacity(layers);
    for l in 0..layers {
        let (i, o) = (dims[l], dims[l + 1]);
        offsets.push(off);
        let w = &theta[off..off + i * o];
        let bias = &theta[off + i * o..off + i * o + o];
        off += i * o + o;
        let src = &pass.acts[l];
        let z = &mut pass.zs[l];
        // z = src @ W + b
        for r in 0..b {
            let zr = &mut z[r * o..(r + 1) * o];
            zr.copy_from_slice(bias);
            let xr = &src[r * i..(r + 1) * i];
            for (k, &xv) in xr.iter().enumerate() {
                if xv != 0.0 {
                    let wrow = &w[k * o..(k + 1) * o];
                    for (zv, &wv) in zr.iter_mut().zip(wrow) {
                        *zv += xv * wv;
                    }
                }
            }
        }
        let act = &mut pass.acts[l + 1];
        if l + 1 < layers {
            for (av, &zv) in act[..b * o].iter_mut().zip(&z[..b * o]) {
                *av = zv.max(0.0);
            }
        } else {
            act[..b * o].copy_from_slice(&z[..b * o]);
        }
    }
    // ---- loss + dlogits ----
    let c = dims[layers];
    let logits = &pass.acts[layers];
    let mut loss = 0.0f64;
    let delta = &mut pass.delta;
    for r in 0..b {
        let row = &logits[r * c..(r + 1) * c];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f64;
        for &v in row {
            denom += ((v - maxv) as f64).exp();
        }
        let y = yb[r] as usize;
        loss += -((row[y] - maxv) as f64 - denom.ln());
        let dr = &mut delta[r * c..(r + 1) * c];
        for (k, dv) in dr.iter_mut().enumerate() {
            let p = (((row[k] - maxv) as f64).exp() / denom) as f32;
            *dv = (p - if k == y { 1.0 } else { 0.0 }) / b as f32;
        }
    }
    loss /= b as f64;
    // ---- backward ----
    for l in (0..layers).rev() {
        let (i, o) = (dims[l], dims[l + 1]);
        let off = offsets[l];
        let w = &theta[off..off + i * o];
        let src = &pass.acts[l];
        // dW += src^T delta ; db += sum delta
        {
            let gw = &mut gout[off..off + i * o];
            for r in 0..b {
                let dr = &pass.delta[r * o..(r + 1) * o];
                let xr = &src[r * i..(r + 1) * i];
                for (k, &xv) in xr.iter().enumerate() {
                    if xv != 0.0 {
                        let gwrow = &mut gw[k * o..(k + 1) * o];
                        for (gv, &dv) in gwrow.iter_mut().zip(dr) {
                            *gv += xv * dv;
                        }
                    }
                }
            }
        }
        {
            let gb = &mut gout[off + i * o..off + i * o + o];
            for r in 0..b {
                let dr = &pass.delta[r * o..(r + 1) * o];
                for (gv, &dv) in gb.iter_mut().zip(dr) {
                    *gv += dv;
                }
            }
        }
        if l > 0 {
            // delta_next = delta @ W^T, masked by relu'(z_{l-1})
            let z_prev = &pass.zs[l - 1];
            let dn = &mut pass.delta_next;
            for r in 0..b {
                let dr = &pass.delta[r * o..(r + 1) * o];
                let dnr = &mut dn[r * i..(r + 1) * i];
                for (k, dnv) in dnr.iter_mut().enumerate() {
                    let wrow = &w[k * o..(k + 1) * o];
                    let mut acc = 0.0f32;
                    for (&dv, &wv) in dr.iter().zip(wrow) {
                        acc += dv * wv;
                    }
                    *dnv = if z_prev[r * i + k] > 0.0 { acc } else { 0.0 };
                }
            }
            std::mem::swap(&mut pass.delta, &mut pass.delta_next);
        }
    }
    loss
}

fn new_pass(arch: &MlpArch, b: usize) -> Pass {
    let dims = arch.dims();
    let maxd = *dims.iter().max().unwrap();
    Pass {
        acts: dims.iter().map(|&d| vec![0.0f32; b * d]).collect(),
        zs: dims[1..].iter().map(|&d| vec![0.0f32; b * d]).collect(),
        delta: vec![0.0f32; b * maxd],
        delta_next: vec![0.0f32; b * maxd],
    }
}

/// Per-node engine: owns the node's shard + scratch buffers.
pub struct MlpNodeGrad {
    arch: MlpArch,
    shard: NodeShard,
    _micro_batch: usize,
    pass: Pass,
    bx: Vec<f32>,
    by: Vec<i32>,
}

impl MlpNodeGrad {
    pub fn new(arch: MlpArch, shard: NodeShard, micro_batch: usize) -> MlpNodeGrad {
        let pass = new_pass(&arch, micro_batch);
        let bx = vec![0.0f32; micro_batch * arch.input_dim];
        let by = vec![0i32; micro_batch];
        MlpNodeGrad { arch, shard, _micro_batch: micro_batch, pass, bx, by }
    }
}

impl NodeGrad for MlpNodeGrad {
    fn grad_accum(&mut self, x: &[f32], accum: usize, out: &mut [f32]) -> f64 {
        out.iter_mut().for_each(|v| *v = 0.0);
        let mut loss = 0.0;
        for _ in 0..accum {
            self.shard.next_batch(&mut self.bx, &mut self.by);
            loss += fwd_bwd(&self.arch, x, &self.bx, &self.by, &mut self.pass, out);
        }
        let inv = 1.0 / accum as f32;
        for v in out.iter_mut() {
            *v *= inv;
        }
        loss / accum as f64
    }

    fn export_cursor(&self) -> Option<ShardCursor> {
        Some(self.shard.export_cursor())
    }

    fn restore_cursor(&mut self, cursor: &ShardCursor) -> anyhow::Result<()> {
        self.shard.restore_cursor(cursor)
    }
}

/// Evaluator over the held-out split.
pub struct MlpEvaluator {
    arch: MlpArch,
    x: Vec<f32>,
    y: Vec<i32>,
    pass: Pass,
    batch: usize,
}

impl MlpEvaluator {
    pub fn new(arch: MlpArch, data: &ClassificationData) -> MlpEvaluator {
        let batch = 256.min(data.eval_n.max(1));
        let pass = new_pass(&arch, batch);
        MlpEvaluator { arch, x: data.eval_x.clone(), y: data.eval_y.clone(), pass, batch }
    }

    /// Forward-only pass over a batch (reuse fwd_bwd machinery would
    /// also do backward); leaves the logits in the last `acts` buffer.
    fn forward(&mut self, theta: &[f32], xb: &[f32], b: usize) {
        let dims = self.arch.dims();
        let layers = dims.len() - 1;
        self.pass.acts[0][..b * dims[0]].copy_from_slice(xb);
        let mut off = 0usize;
        for l in 0..layers {
            let (i, o) = (dims[l], dims[l + 1]);
            let w = &theta[off..off + i * o];
            let bias = &theta[off + i * o..off + i * o + o];
            off += i * o + o;
            let (a, rest) = self.pass.acts.split_at_mut(l + 1);
            let src = &a[l];
            let dst = &mut rest[0];
            for r in 0..b {
                let zr = &mut dst[r * o..(r + 1) * o];
                zr.copy_from_slice(bias);
                let xr = &src[r * i..(r + 1) * i];
                for (k, &xv) in xr.iter().enumerate() {
                    if xv != 0.0 {
                        let wrow = &w[k * o..(k + 1) * o];
                        for (zv, &wv) in zr.iter_mut().zip(wrow) {
                            *zv += xv * wv;
                        }
                    }
                }
                if l + 1 < layers {
                    for zv in zr.iter_mut() {
                        *zv = zv.max(0.0);
                    }
                }
            }
        }
    }

    fn logits_argmax(&mut self, theta: &[f32], xb: &[f32], b: usize) -> Vec<usize> {
        self.forward(theta, xb, b);
        let dims = self.arch.dims();
        let layers = dims.len() - 1;
        let c = dims[layers];
        let logits = &self.pass.acts[layers];
        (0..b)
            .map(|r| {
                let row = &logits[r * c..(r + 1) * c];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0
            })
            .collect()
    }
}

impl Evaluator for MlpEvaluator {
    fn accuracy(&mut self, theta: &[f32]) -> f64 {
        let d = self.arch.input_dim;
        let n = self.y.len();
        let mut correct = 0usize;
        let mut done = 0usize;
        while done < n {
            let b = self.batch.min(n - done);
            let xb: Vec<f32> = self.x[done * d..(done + b) * d].to_vec();
            let preds = self.logits_argmax(theta, &xb, b);
            for (k, &p) in preds.iter().enumerate() {
                if p == self.y[done + k] as usize {
                    correct += 1;
                }
            }
            done += b;
        }
        correct as f64 / n as f64
    }

    /// Mean softmax cross-entropy over the eval split — the same loss
    /// the training forward/backward optimizes, so the experiment
    /// harness can compare the GLOBAL objective at the average model
    /// (per-node local loss is the wrong observable under bias drift).
    fn loss(&mut self, theta: &[f32]) -> Option<f64> {
        let d = self.arch.input_dim;
        let n = self.y.len();
        if n == 0 {
            return None;
        }
        let dims = self.arch.dims();
        let layers = dims.len() - 1;
        let c = dims[layers];
        let mut total = 0.0f64;
        let mut done = 0usize;
        while done < n {
            let b = self.batch.min(n - done);
            let xb: Vec<f32> = self.x[done * d..(done + b) * d].to_vec();
            self.forward(theta, &xb, b);
            let logits = &self.pass.acts[layers];
            for r in 0..b {
                let row = &logits[r * c..(r + 1) * c];
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v)) as f64;
                let exp_sum =
                    crate::util::math::sum_f64(row.iter().map(|&v| (v as f64 - m).exp()));
                let lse = m + exp_sum.ln();
                total += lse - row[self.y[done + r] as usize] as f64;
            }
            done += b;
        }
        Some(total / n as f64)
    }
}

/// Build a complete native-MLP workload from synthetic data.
pub fn workload(
    arch: MlpArch,
    data: ClassificationData,
    micro_batch: usize,
    seed: u64,
) -> Workload {
    let dim = arch.dim();
    let ranges = arch.layer_ranges();
    let init = arch.init(seed);
    let evaluator = MlpEvaluator::new(arch.clone(), &data);
    let nodes: Vec<Box<dyn NodeGrad>> = data
        .shards
        .into_iter()
        .map(|sh| Box::new(MlpNodeGrad::new(arch.clone(), sh, micro_batch)) as Box<dyn NodeGrad>)
        .collect();
    Workload {
        name: "native-mlp".into(),
        dim,
        layer_ranges: ranges,
        init,
        nodes,
        eval: Box::new(evaluator),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn dim_and_ranges_match_python_layout() {
        // mlp-s: 64 -> 128 -> 64 -> 10 (same arithmetic as model.py smoke)
        let arch = MlpArch::family("mlp-s").unwrap();
        assert_eq!(arch.dim(), 17226);
        let r = arch.layer_ranges();
        assert_eq!(r[0], (0, 64 * 128));
        assert_eq!(r.last().unwrap().1, 17226);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let arch = MlpArch::new(4, &[5], 3);
        let theta = arch.init(3);
        let xb: Vec<f32> = (0..8 * 4).map(|i| ((i * 37 % 11) as f32 - 5.0) / 5.0).collect();
        let yb: Vec<i32> = (0..8).map(|i| (i % 3) as i32).collect();
        let mut pass = new_pass(&arch, 8);
        let mut g = vec![0.0f32; arch.dim()];
        let loss0 = fwd_bwd(&arch, &theta, &xb, &yb, &mut pass, &mut g);
        assert!(loss0 > 0.0);
        let eps = 1e-3f32;
        for k in [0usize, 7, 20, arch.dim() - 1, arch.dim() / 2] {
            let mut tp = theta.clone();
            tp[k] += eps;
            let mut tm = theta.clone();
            tm[k] -= eps;
            let mut scratch = vec![0.0f32; arch.dim()];
            let lp = fwd_bwd(&arch, &tp, &xb, &yb, &mut pass, &mut scratch);
            scratch.iter_mut().for_each(|v| *v = 0.0);
            let lm = fwd_bwd(&arch, &tm, &xb, &yb, &mut pass, &mut scratch);
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g[k]).abs() < 2e-2 * (1.0 + fd.abs()),
                "k={k}: fd={fd} analytic={}",
                g[k]
            );
        }
    }

    #[test]
    fn training_reduces_loss_and_beats_chance() {
        let spec = SynthSpec {
            samples_per_node: 512,
            eval_samples: 512,
            nodes: 1,
            dirichlet_alpha: 100.0,
            ..Default::default()
        };
        let data = ClassificationData::generate(&spec);
        let arch = MlpArch::family("mlp-xs").unwrap();
        let mut wl = workload(arch, data, 64, 1);
        let mut x = wl.init.clone();
        let mut g = vec![0.0f32; wl.dim];
        let l0 = wl.nodes[0].grad_accum(&x, 1, &mut g);
        for _ in 0..150 {
            wl.nodes[0].grad_accum(&x, 1, &mut g);
            crate::util::math::axpy(&mut x, -0.1, &g);
        }
        let l1 = wl.nodes[0].grad_accum(&x, 1, &mut g);
        assert!(l1 < 0.7 * l0, "loss {l0} -> {l1}");
        let acc = wl.eval.accuracy(&x);
        assert!(acc > 0.5, "accuracy {acc} should beat chance (0.1)");
    }

    #[test]
    fn eval_loss_starts_near_chance_and_tracks_training() {
        let spec = SynthSpec {
            samples_per_node: 512,
            eval_samples: 512,
            nodes: 1,
            dirichlet_alpha: 100.0,
            ..Default::default()
        };
        let data = ClassificationData::generate(&spec);
        let arch = MlpArch::family("mlp-xs").unwrap();
        let mut wl = workload(arch, data, 64, 2);
        let mut x = wl.init.clone();
        let l0 = wl.eval.loss(&x).expect("MLP evaluator reports a loss");
        // Small random logits at init: cross-entropy near ln(num_classes).
        assert!((1.5..4.0).contains(&l0), "init eval loss {l0}");
        let mut g = vec![0.0f32; wl.dim];
        for _ in 0..100 {
            wl.nodes[0].grad_accum(&x, 1, &mut g);
            crate::util::math::axpy(&mut x, -0.1, &g);
        }
        let l1 = wl.eval.loss(&x).unwrap();
        assert!(l1.is_finite() && l1 < 0.8 * l0, "eval loss {l0} -> {l1}");
    }

    #[test]
    fn accum_averages_micro_batches() {
        let spec = SynthSpec {
            samples_per_node: 256,
            eval_samples: 16,
            nodes: 1,
            ..Default::default()
        };
        let data = ClassificationData::generate(&spec);
        let arch = MlpArch::family("mlp-xs").unwrap();
        let mut wl = workload(arch, data, 32, 1);
        let x = wl.init.clone();
        let mut g1 = vec![0.0f32; wl.dim];
        let mut g8 = vec![0.0f32; wl.dim];
        wl.nodes[0].grad_accum(&x, 1, &mut g1);
        wl.nodes[0].grad_accum(&x, 8, &mut g8);
        // More accumulation = lower variance: ||g8|| should not exceed
        // ||g1|| wildly; both nonzero.
        let n1 = crate::util::math::norm2(&g1);
        let n8 = crate::util::math::norm2(&g8);
        assert!(n1 > 0.0 && n8 > 0.0);
        assert!(n8 < 3.0 * n1);
    }
}
