//! Gradient engines: where the loss/gradient of each workload comes from.
//!
//! Three families:
//! * [`linreg`] — exact closed-form least-squares gradients (Figs. 2–3,
//!   Table 2; deterministic, full batch).
//! * [`mlp`] — a native Rust implementation of the same flat-parameter
//!   MLP as `python/compile/model.py` (fast path for the big table
//!   sweeps; verified against the PJRT artifacts in integration tests).
//! * `pjrt` (feature-gated) — the production path: gradients come from
//!   the AOT-lowered JAX/Pallas HLO artifacts executed through the
//!   PJRT CPU client.
//!
//! A [`Workload`] bundles per-node gradient providers with an evaluator
//! and the initial parameters; the coordinator is engine-agnostic.

pub mod linreg;
pub mod mlp;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::data::synth::ShardCursor;

/// Per-node gradient provider. `grad_accum` computes the mean gradient
/// over `accum` micro-batches at `x` (the large-batch engine) and
/// returns the mean loss.
pub trait NodeGrad: Send {
    fn grad_accum(&mut self, x: &[f32], accum: usize, out: &mut [f32]) -> f64;

    /// Cross-step mutable sampling state (epoch cursor + RNG counters)
    /// for bitwise checkpoint/resume (DESIGN.md §9). `None` means the
    /// engine is stateless between steps — exact full-batch gradients
    /// (linreg) need nothing restored. Engines with sampling state MUST
    /// override both hooks or resumed runs drift off the uninterrupted
    /// batch sequence.
    fn export_cursor(&self) -> Option<ShardCursor> {
        None
    }

    /// Restore a cursor captured by [`NodeGrad::export_cursor`]. The
    /// default REFUSES: the trainer only calls this on engines whose
    /// `export_cursor` returned `Some`, so reaching the default means
    /// an engine exports state it cannot restore — silently accepting
    /// would drift the resumed run off the batch sequence.
    fn restore_cursor(&mut self, _cursor: &ShardCursor) -> anyhow::Result<()> {
        anyhow::bail!(
            "gradient engine exports a cursor but does not implement restore_cursor"
        )
    }
}

/// Held-out evaluation on the current (average) model.
pub trait Evaluator: Send {
    /// Top-1 accuracy in [0,1] (or task metric).
    fn accuracy(&mut self, x: &[f32]) -> f64;
    /// Mean eval loss, if the engine supports it.
    fn loss(&mut self, _x: &[f32]) -> Option<f64> {
        None
    }
}

/// A complete training workload for `nodes.len()` nodes.
pub struct Workload {
    pub name: String,
    pub dim: usize,
    pub layer_ranges: Vec<(usize, usize)>,
    pub init: Vec<f32>,
    pub nodes: Vec<Box<dyn NodeGrad>>,
    pub eval: Box<dyn Evaluator>,
}

/// No-op evaluator for workloads without a metric (e.g. pure bias runs).
pub struct NoEval;

impl Evaluator for NoEval {
    fn accuracy(&mut self, _x: &[f32]) -> f64 {
        f64::NAN
    }
}
