//! PJRT-backed gradient engines: the production path where gradients
//! come from the AOT-lowered JAX/Pallas artifacts (Layer 2 calling the
//! Layer-1 `fused_linear` kernel) executed through the runtime thread.

use anyhow::Result;

use crate::data::corpus::{Corpus, CorpusShard};
use crate::data::synth::{ClassificationData, NodeShard, ShardCursor};
use crate::runtime::{Manifest, ModelInfo, RuntimeHandle, Tensor};

use super::{Evaluator, NodeGrad, Workload};

/// MLP classifier node: gradients via `<model>_grad` artifact.
pub struct PjrtMlpNodeGrad {
    rt: RuntimeHandle,
    artifact: String,
    info: ModelInfo,
    shard: NodeShard,
    bx: Vec<f32>,
    by: Vec<i32>,
}

impl NodeGrad for PjrtMlpNodeGrad {
    fn grad_accum(&mut self, x: &[f32], accum: usize, out: &mut [f32]) -> f64 {
        out.iter_mut().for_each(|v| *v = 0.0);
        let b = self.info.micro_batch;
        let d = self.info.input_dim;
        let mut loss = 0.0;
        for _ in 0..accum {
            self.shard.next_batch(&mut self.bx, &mut self.by);
            let outputs = self
                .rt
                .exec(
                    &self.artifact,
                    vec![
                        Tensor::f32(x.to_vec(), &[self.info.dim as i64]),
                        Tensor::f32(self.bx.clone(), &[b as i64, d as i64]),
                        Tensor::i32(self.by.clone(), &[b as i64]),
                    ],
                )
                .expect("pjrt grad exec failed");
            loss += outputs[0][0] as f64;
            crate::util::math::axpy(out, 1.0, &outputs[1]);
        }
        let inv = 1.0 / accum as f32;
        out.iter_mut().for_each(|v| *v *= inv);
        loss / accum as f64
    }

    fn export_cursor(&self) -> Option<ShardCursor> {
        Some(self.shard.export_cursor())
    }

    fn restore_cursor(&mut self, cursor: &ShardCursor) -> anyhow::Result<()> {
        self.shard.restore_cursor(cursor)
    }
}

/// Evaluator via the `<model>_logits` artifact.
pub struct PjrtMlpEvaluator {
    rt: RuntimeHandle,
    artifact: String,
    info: ModelInfo,
    eval_x: Vec<f32>,
    eval_y: Vec<i32>,
}

impl Evaluator for PjrtMlpEvaluator {
    fn accuracy(&mut self, theta: &[f32]) -> f64 {
        let b = self.info.eval_batch;
        let d = self.info.input_dim;
        let c = self.info.num_classes;
        let n = self.eval_y.len();
        let mut correct = 0usize;
        let mut done = 0usize;
        while done < n {
            // Static shapes: pad the tail batch with the first rows.
            let mut xb = vec![0.0f32; b * d];
            let take = b.min(n - done);
            xb[..take * d].copy_from_slice(&self.eval_x[done * d..(done + take) * d]);
            let out = self
                .rt
                .exec(
                    &self.artifact,
                    vec![
                        Tensor::f32(theta.to_vec(), &[self.info.dim as i64]),
                        Tensor::f32(xb, &[b as i64, d as i64]),
                    ],
                )
                .expect("pjrt eval exec failed");
            let logits = &out[0];
            for r in 0..take {
                let row = &logits[r * c..(r + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                if pred == self.eval_y[done + r] as usize {
                    correct += 1;
                }
            }
            done += take;
        }
        correct as f64 / n as f64
    }
}

/// Build a PJRT MLP workload: loads `<model>_grad` + `<model>_logits`,
/// initial params from the manifest, shards from the synthetic dataset.
pub fn mlp_workload(
    rt: &RuntimeHandle,
    manifest: &Manifest,
    model: &str,
    data: ClassificationData,
) -> Result<Workload> {
    let info = manifest.model(model)?;
    let grad_art = format!("{model}_grad");
    let logits_art = format!("{model}_logits");
    rt.load_artifact(manifest, &grad_art)?;
    rt.load_artifact(manifest, &logits_art)?;
    let init = manifest.load_init(&info)?;
    let b = info.micro_batch;
    let d = info.input_dim;
    let nodes: Vec<Box<dyn NodeGrad>> = data
        .shards
        .into_iter()
        .map(|shard| {
            Box::new(PjrtMlpNodeGrad {
                rt: rt.clone(),
                artifact: grad_art.clone(),
                info: info.clone(),
                shard,
                bx: vec![0.0; b * d],
                by: vec![0; b],
            }) as Box<dyn NodeGrad>
        })
        .collect();
    let eval = PjrtMlpEvaluator {
        rt: rt.clone(),
        artifact: logits_art,
        info: info.clone(),
        eval_x: data.eval_x,
        eval_y: data.eval_y,
    };
    Ok(Workload {
        name: model.to_string(),
        dim: info.dim,
        layer_ranges: info.layer_ranges.clone(),
        init,
        nodes,
        eval: Box::new(eval),
    })
}

/// Transformer-LM node: gradients via `lm-base_grad` over corpus windows.
pub struct PjrtLmNodeGrad {
    rt: RuntimeHandle,
    artifact: String,
    info: ModelInfo,
    shard: CorpusShard,
    xs: Vec<i32>,
    ys: Vec<i32>,
}

impl NodeGrad for PjrtLmNodeGrad {
    fn grad_accum(&mut self, x: &[f32], accum: usize, out: &mut [f32]) -> f64 {
        out.iter_mut().for_each(|v| *v = 0.0);
        let (b, t) = (self.info.micro_batch, self.info.seq_len);
        let mut loss = 0.0;
        for _ in 0..accum {
            self.shard.next_batch(b, t, &mut self.xs, &mut self.ys);
            let outputs = self
                .rt
                .exec(
                    &self.artifact,
                    vec![
                        Tensor::f32(x.to_vec(), &[self.info.dim as i64]),
                        Tensor::i32(self.xs.clone(), &[b as i64, t as i64]),
                        Tensor::i32(self.ys.clone(), &[b as i64, t as i64]),
                    ],
                )
                .expect("pjrt lm grad exec failed");
            loss += outputs[0][0] as f64;
            crate::util::math::axpy(out, 1.0, &outputs[1]);
        }
        let inv = 1.0 / accum as f32;
        out.iter_mut().for_each(|v| *v *= inv);
        loss / accum as f64
    }

    fn export_cursor(&self) -> Option<ShardCursor> {
        // The corpus shard's only cross-step state is the window RNG;
        // reuse the cursor container with an empty epoch order.
        Some(ShardCursor { cursor: 0, order: Vec::new(), rng: self.shard.export_rng() })
    }

    fn restore_cursor(&mut self, cursor: &ShardCursor) -> anyhow::Result<()> {
        anyhow::ensure!(
            cursor.order.is_empty() && cursor.cursor == 0,
            "corpus-shard cursor carries unexpected epoch state"
        );
        self.shard.restore_rng(cursor.rng);
        Ok(())
    }
}

/// LM evaluator: mean held-out loss via `lm-base_loss` (accuracy = NaN).
pub struct PjrtLmEvaluator {
    rt: RuntimeHandle,
    artifact: String,
    info: ModelInfo,
    shard: CorpusShard,
    xs: Vec<i32>,
    ys: Vec<i32>,
    batches: usize,
}

impl Evaluator for PjrtLmEvaluator {
    fn accuracy(&mut self, _x: &[f32]) -> f64 {
        f64::NAN
    }

    fn loss(&mut self, theta: &[f32]) -> Option<f64> {
        let (b, t) = (self.info.micro_batch, self.info.seq_len);
        let mut total = 0.0;
        for _ in 0..self.batches {
            self.shard.next_batch(b, t, &mut self.xs, &mut self.ys);
            let out = self
                .rt
                .exec(
                    &self.artifact,
                    vec![
                        Tensor::f32(theta.to_vec(), &[self.info.dim as i64]),
                        Tensor::i32(self.xs.clone(), &[b as i64, t as i64]),
                        Tensor::i32(self.ys.clone(), &[b as i64, t as i64]),
                    ],
                )
                .ok()?;
            total += out[0][0] as f64;
        }
        Some(total / self.batches as f64)
    }
}

/// Build the end-to-end LM pretraining workload over `nodes` corpus shards.
pub fn lm_workload(
    rt: &RuntimeHandle,
    manifest: &Manifest,
    model: &str,
    corpus: &Corpus,
    nodes: usize,
) -> Result<Workload> {
    let info = manifest.model(model)?;
    let grad_art = format!("{model}_grad");
    let loss_art = format!("{model}_loss");
    rt.load_artifact(manifest, &grad_art)?;
    rt.load_artifact(manifest, &loss_art)?;
    let init = manifest.load_init(&info)?;
    let (b, t) = (info.micro_batch, info.seq_len);
    let node_grads: Vec<Box<dyn NodeGrad>> = (0..nodes)
        .map(|rank| {
            Box::new(PjrtLmNodeGrad {
                rt: rt.clone(),
                artifact: grad_art.clone(),
                info: info.clone(),
                shard: corpus.shard(rank, nodes + 1),
                xs: vec![0; b * t],
                ys: vec![0; b * t],
            }) as Box<dyn NodeGrad>
        })
        .collect();
    // Last shard held out for eval.
    let eval = PjrtLmEvaluator {
        rt: rt.clone(),
        artifact: loss_art,
        info: info.clone(),
        shard: corpus.shard(nodes, nodes + 1),
        xs: vec![0; b * t],
        ys: vec![0; b * t],
        batches: 4,
    };
    Ok(Workload {
        name: model.to_string(),
        dim: info.dim,
        layer_ranges: info.layer_ranges.clone(),
        init,
        nodes: node_grads,
        eval: Box::new(eval),
    })
}
