//! # DecentLaM — decentralized large-batch momentum training framework
//!
//! A Rust + JAX + Pallas reproduction of *DecentLaM: Decentralized
//! Momentum SGD for Large-batch Deep Training* (Yuan et al., 2021).
//!
//! Architecture (see `DESIGN.md`):
//! - **Layer 3 (this crate)** — the decentralized coordination runtime:
//!   topologies + Metropolis–Hastings mixing weights ([`topology`]), the
//!   ten optimizer update rules ([`optim`]), multi-node training driver
//!   ([`coordinator`]), communication cost model ([`comm`]), gradient
//!   engines ([`grad`]), fault-injection simulation ([`sim`]), elastic
//!   membership + checkpointing ([`elastic`]), synthetic workloads
//!   ([`data`]), the paper's experiment harness ([`experiments`]) and
//!   the fail-closed scenario manifests + golden corpus ([`scenario`]).
//! - **Layer 2 / Layer 1 (python/, build time only)** — JAX models and
//!   Pallas kernels, AOT-lowered to HLO-text artifacts that `runtime`
//!   loads and executes through the PJRT CPU client (`xla` crate).
//!   Everything touching PJRT is behind the `pjrt` cargo feature; the
//!   default build is pure Rust with zero external artifacts.
//!
//! Python never runs on the training path: after `make artifacts` the
//! `decentlam` binary (and every example) is self-contained.

pub mod comm;
pub mod coordinator;
pub mod data;
pub mod elastic;
pub mod experiments;
pub mod grad;
pub mod optim;
pub mod prop;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod telemetry;
pub mod topology;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
