//! `decentlam` — CLI launcher for the DecentLaM framework.
//!
//! Subcommands regenerate every table/figure of the paper (DESIGN.md §5)
//! plus ablations and a generic training entry point:
//!
//! ```text
//! decentlam table1|table2|table3|table4|table5|table6   # paper tables
//! decentlam fig2|fig3|fig5|fig6                         # paper figures
//! decentlam fig-faults [--nodes N --straggle R ...]     # fault sweep
//! decentlam fig-compression [--smoke]                   # codec sweep
//! decentlam train [--optimizer X --batch B ...]         # one run
//! decentlam run-scenarios [DIR --tier smoke|full|all]   # golden corpus
//! decentlam ablate-pd | ablate-atc | ablate-rho         # design ablations
//! decentlam topo [--nodes N]                            # topology report
//! ```
//!
//! Common flags: `--quick` (shrunk protocol), `--csv FILE` (dump series),
//! `--steps`, `--nodes`, plus every `Config` key (see `util::config`).

use anyhow::Result;

use decentlam::coordinator::Trainer;
use decentlam::data::LinRegProblem;
use decentlam::experiments as exp;
use decentlam::grad::linreg;
#[cfg(feature = "pjrt")]
use decentlam::runtime::{Manifest, Runtime};
use decentlam::topology::{metropolis_hastings, rho, spectral, Kind, Topology};
use decentlam::util::cli::Args;
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::table::{sig, Table};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn write_csv(args: &Args, csv: &str) -> Result<()> {
    if let Some(path) = args.get("csv") {
        std::fs::write(path, csv)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let quick = args.get_bool("quick");
    match cmd {
        "fig2" | "fig3" => {
            let mut opts = exp::fig2_3::Opts::default();
            if quick {
                opts.steps = 6000;
            }
            opts.steps = args.get_usize("steps", opts.steps)?;
            opts.beta = args.get_f64("beta", opts.beta)?;
            opts.gamma = args.get_f64("lr", opts.gamma)?;
            let (series, table) = exp::fig2_3::run(&opts, cmd == "fig3")?;
            println!("{}", table.render());
            write_csv(args, &exp::fig2_3::to_csv(&series))?;
        }
        "table1" => {
            let mut opts = exp::table1::Opts::default();
            if quick {
                opts.steps = 100;
                opts.large_batch = 1024;
            }
            opts.steps = args.get_usize("steps", opts.steps)?;
            let (_, table) = exp::table1::run(&opts)?;
            println!("{}", table.render());
        }
        "table2" => {
            let mut opts = exp::table2::Opts::default();
            if quick {
                opts.steps = 8000;
                opts.methods = vec!["dsgd".into(), "dmsgd".into(), "decentlam".into()];
            }
            opts.steps = args.get_usize("steps", opts.steps)?;
            let (_, table) = exp::table2::run(&opts)?;
            println!("{}", table.render());
        }
        "table3" => {
            let mut opts = exp::table3::Opts::default();
            if quick {
                opts.steps = 120;
                opts.batches = vec![256, 2048];
            }
            opts.steps = args.get_usize("steps", opts.steps)?;
            let (_, table) = exp::table3::run(&opts)?;
            println!("{}", table.render());
        }
        "table4" => {
            let mut opts = exp::table4::Opts::default();
            if quick {
                opts.steps = 80;
                opts.archs = vec!["mlp-xs".into(), "mlp-s".into(), "mlp-m".into()];
            }
            opts.steps = args.get_usize("steps", opts.steps)?;
            let (_, table) = exp::table4::run(&opts)?;
            println!("{}", table.render());
        }
        "table5" => {
            let mut opts = exp::table5::Opts::default();
            if quick {
                opts.steps = 120;
                opts.batches = vec![2048];
            }
            opts.steps = args.get_usize("steps", opts.steps)?;
            let (_, table) = exp::table5::run(&opts)?;
            println!("{}", table.render());
        }
        #[cfg(feature = "pjrt")]
        "table6" => {
            let mut opts = exp::table6::Opts::default();
            if quick {
                opts.steps = 40;
                opts.methods = vec!["pmsgd".into(), "dmsgd".into(), "decentlam".into()];
            }
            opts.steps = args.get_usize("steps", opts.steps)?;
            let manifest =
                Manifest::load(std::path::Path::new(args.get_str("artifacts", "artifacts")))?;
            let runtime = Runtime::start()?;
            let (_, table) = exp::table6::run(&runtime.handle(), &manifest, &opts)?;
            println!("{}", table.render());
        }
        #[cfg(not(feature = "pjrt"))]
        "table6" => {
            anyhow::bail!(
                "table6 runs on the PJRT detection artifact — rebuild with \
                 `--features pjrt` (requires the xla crate + `make artifacts`)"
            );
        }
        "fig5" => {
            let mut opts = exp::fig5::Opts::default();
            if quick {
                opts.steps = 120;
            }
            opts.steps = args.get_usize("steps", opts.steps)?;
            let (curves, table) = exp::fig5::run(&opts)?;
            println!("{}", table.render());
            write_csv(args, &exp::fig5::to_csv(&curves))?;
        }
        "fig6" => {
            let mut opts = exp::fig6::Opts::default();
            if let Some(bw) = args.get("bw-gbps") {
                opts.bandwidths_gbps = vec![bw.parse()?];
            }
            let (_, table) = exp::fig6::run(&opts)?;
            println!("{}", table.render());
        }
        "fig-compression" => {
            if args.get_bool("smoke") {
                exp::fig_compression::smoke(args)?;
                return Ok(());
            }
            let mut opts = exp::fig_compression::Opts::default();
            if quick {
                opts.nodes_list = vec![16];
                opts.steps = 60;
            }
            opts.apply_args(args)?;
            let (rows, table) = exp::fig_compression::run(&opts)?;
            println!("{}", table.render());
            for row in rows.iter().filter(|r| r.codec.starts_with("int8")) {
                println!(
                    "n={} {}: int8 ships {:.0} B/iter ({:.2}x cut) at eval loss {:.4}",
                    row.nodes, row.method, row.wire_per_iter, row.ratio_vs_fp32, row.eval_loss
                );
            }
        }
        "fig-async" => {
            if args.get_bool("smoke") {
                exp::fig_async::smoke(args)?;
                return Ok(());
            }
            let mut opts = exp::fig_async::Opts::default();
            if quick {
                opts.nodes = 8;
                opts.steps = 60;
                opts.spreads = vec![1.0, 4.0];
            }
            opts.apply_args(args)?;
            let (rows, table) = exp::fig_async::run(&opts)?;
            println!("{}", table.render());
            // Time-to-target view: first simulated second each cell
            // reaches 1.1x the uniform DecentLaM final loss.
            if let Some(base) = rows
                .iter()
                .find(|r| r.method == "decentlam" && r.spread == 1.0)
                .map(|r| r.eval_loss)
            {
                let target = 1.1 * base;
                for row in &rows {
                    match exp::fig_async::time_to_target(&row.curve, target) {
                        Some(t) => println!(
                            "{} spread={}: reaches eval loss {target:.4} at {t:.3} sim s",
                            row.method, row.spread
                        ),
                        None => println!(
                            "{} spread={}: never reaches eval loss {target:.4} in budget",
                            row.method, row.spread
                        ),
                    }
                }
            }
        }
        "fig-elastic" => {
            if args.get_bool("smoke") {
                exp::fig_elastic::smoke(args)?;
                return Ok(());
            }
            let mut opts = exp::fig_elastic::Opts::default();
            if quick {
                opts.nodes = 8;
                opts.capacity = 10;
                opts.nmin = 4;
                opts.steps = 60;
                opts.churn_rates = vec![0.0, 0.05];
            }
            opts.apply_args(args)?;
            let (rows, table) = exp::fig_elastic::run(&opts)?;
            println!("{}", table.render());
            for method in &opts.methods {
                let deg: Vec<String> = exp::fig_elastic::degradation(&rows, method)
                    .iter()
                    .map(|(r, d)| format!("rate={r}: {d:+.4}"))
                    .collect();
                if !deg.is_empty() {
                    println!("{method} eval-loss degradation vs churn-free: {}", deg.join("  "));
                }
            }
        }
        "fig-faults" => {
            let mut opts = exp::fig_faults::Opts::default();
            if quick {
                opts.nodes = 8;
                opts.steps = 100;
                opts.drop_rates = vec![0.0, 0.3];
            }
            opts.apply_args(args)?;
            let (rows, table) = exp::fig_faults::run(&opts)?;
            println!("{}", table.render());
            for method in &opts.methods {
                let deg: Vec<String> = exp::fig_faults::degradation(&rows, method)
                    .iter()
                    .map(|(r, d)| format!("drop={r}: {d:.2}x"))
                    .collect();
                println!("{method} consensus degradation vs fault-free: {}", deg.join("  "));
            }
        }
        "train" => train(args)?,
        "replay" => {
            let path = args.positional.get(1).map(|s| s.as_str()).ok_or_else(|| {
                anyhow::anyhow!("usage: decentlam replay RUN.jsonl (a --telemetry stream)")
            })?;
            let r = decentlam::telemetry::replay_path(std::path::Path::new(path))?;
            print_replay(&r);
        }
        "profile" => {
            let path = args.positional.get(1).map(|s| s.as_str()).ok_or_else(|| {
                anyhow::anyhow!("usage: decentlam profile RUN.jsonl (a --telemetry stream)")
            })?;
            let r = decentlam::telemetry::replay_path(std::path::Path::new(path))?;
            print_profile(&r);
        }
        "run-scenarios" => {
            let dir = args.positional.get(1).map(|s| s.as_str()).unwrap_or("scenarios");
            let opts = decentlam::scenario::RunOpts {
                tier: decentlam::scenario::TierFilter::parse(args.get_str("tier", "all"))?,
                filter: args.get("filter").map(|s| s.to_string()),
                pin: args.get_bool("pin"),
                telemetry: args.get("telemetry").map(std::path::PathBuf::from),
            };
            let summary = decentlam::scenario::run_corpus(std::path::Path::new(dir), &opts)?;
            println!("{}", summary.table().render());
            if let Some(path) = args.get("json") {
                std::fs::write(path, summary.to_json().to_pretty_string())?;
                println!("wrote {path}");
            }
            anyhow::ensure!(
                summary.failed() == 0,
                "{} scenario(s) failed — see table above",
                summary.failed()
            );
        }
        "topo" => topo_report(args)?,
        "ablate-pd" => ablate_pd(args)?,
        "ablate-atc" => ablate_atc(args)?,
        "ablate-rho" => ablate_rho(args)?,
        _ => {
            println!(
                "decentlam — decentralized large-batch momentum training\n\n\
                 subcommands:\n  \
                 table1..table6, fig2, fig3, fig5, fig6   regenerate paper results\n  \
                 fig-faults   DecentLaM vs DmSGD under fault injection\n  \
                 fig-compression   loss vs wire bytes per payload codec (--smoke = CI gate)\n  \
                 fig-async    time-to-target-loss vs clock heterogeneity (--smoke = CI gate)\n  \
                 fig-elastic  churn rate vs loss over an elastic roster (--smoke = CI gate)\n  \
                 train        one training run (all Config flags apply; --telemetry RUN.jsonl\n               \
                 streams typed step/eval/fault/churn events, DESIGN.md §11)\n  \
                 replay FILE  reconstruct a run summary from a --telemetry stream offline\n  \
                 profile FILE aggregate a stream into a run-profile report (bias\n               \
                 trajectory from `metrics` lines, wire breakdown, phase timings\n               \
                 from `timing` lines; DESIGN.md §14)\n  \
                 run-scenarios [DIR]   run the scenario corpus (--tier smoke|full|all,\n               \
                 --filter SUBSTR, --json FILE, --pin, --telemetry DIR tees + verifies\n               \
                 per-scenario streams)\n  \
                 topo         topology / spectral report\n  \
                 ablate-pd    positive-definite (lazy) W ablation\n  \
                 ablate-atc   ATC vs AWC partial-averaging ablation\n  \
                 ablate-rho   limiting bias vs topology rho\n\n\
                 common flags: --quick, --steps N, --csv FILE, --nodes N,\n  \
                 --optimizer X, --batch B, --beta B, --lr G, --topology T,\n  \
                 --faults drop=0.1,straggle=0.05,seed=7,\n  \
                 --codec int8,ef=true,seed=7 (fp32|fp16|int8|topk,k=0.05),\n  \
                 --async tau=2,spread=4,jitter=0.2,seed=7,\n  \
                 --churn join=0.02,leave=0.02,nmin=8,nmax=64,seed=7,\n  \
                 --telemetry RUN.jsonl[,flush=K] (stream events; flush cadence K),\n  \
                 --metrics every=K (stream deterministic `metrics` lines),\n  \
                 --profile [every=K] (stream wall-clock `timing` lines)"
            );
        }
    }
    Ok(())
}

/// Generic single training run over the native MLP workload.
fn train(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    // Elastic runs shard data over the whole stable-id capacity (nmax)
    // so joiners bring their own data; `nodes` stays the initial count.
    let capacity = match cfg.churn {
        None => cfg.nodes,
        Some(spec) => spec.with_run_seed(cfg.seed).resolve(cfg.nodes)?.nmax,
    };
    let data = exp::synth_imagenet(capacity, cfg.seed);
    let wl = exp::mlp_workload_named(
        if cfg.model.starts_with("native") { "mlp-s" } else { &cfg.model },
        data,
        cfg.micro_batch,
        cfg.seed,
    )?;
    println!(
        "train: optimizer={} topology={} nodes={} total_batch={} steps={}{}{}{}",
        cfg.optimizer,
        cfg.topology,
        cfg.nodes,
        cfg.total_batch,
        cfg.steps,
        cfg.faults
            .as_ref()
            .map(|s| format!(" faults=[{}]", s.to_spec_string()))
            .unwrap_or_default(),
        cfg.codec
            .as_ref()
            .map(|s| format!(" codec=[{}]", s.to_spec_string()))
            .unwrap_or_default(),
        cfg.churn
            .as_ref()
            .map(|s| format!(" churn=[{}] capacity={capacity}", s.to_spec_string()))
            .unwrap_or_default()
    );
    let eval_every = if cfg.eval_every == 0 { cfg.steps / 10 } else { cfg.eval_every };
    let mut cfg = cfg;
    cfg.eval_every = eval_every.max(1);
    let mut t = Trainer::new(cfg, wl)?;
    let report = t.run();
    for (k, acc) in &report.evals {
        println!("step {k:>6}  val acc {acc:.4}");
    }
    println!(
        "final: loss={:.4} acc={:.4} consensus={:.3e} ({} steps, {:.1}s)",
        report.losses.last().unwrap(),
        report.final_accuracy,
        report.final_consensus,
        report.steps,
        report.grad_seconds
    );
    match t.fault_stats() {
        Some(s) => println!(
            "faults: {:.1}% of edges realized ({} masked), {} stale msgs, \
             {} dropped / {} straggler node-steps",
            100.0 * s.realized_edge_fraction(),
            s.masked_edges,
            s.stale_messages,
            s.dropped_node_steps,
            s.straggler_node_steps
        ),
        None if t.cfg.faults.is_some() => println!(
            "faults: n/a — {}'s all-reduce traffic bypasses the decentralized fault model",
            t.cfg.optimizer
        ),
        None => {}
    }
    match t.codec_name() {
        Some(name) => {
            let payload = t.payload_bytes();
            println!(
                "codec: {name} — gossip payload {:.0} B ({:.2}x cut vs raw fp32 {:.0} B)",
                payload.neighbor,
                payload.allreduce / payload.neighbor,
                payload.allreduce
            );
        }
        None if t.cfg.codec.is_some() => println!(
            "codec: n/a — {}'s all-reduce traffic bypasses the gossip codec path",
            t.cfg.optimizer
        ),
        None => {}
    }
    if let Some(a) = t.async_report() {
        println!(
            "async: {:.3} simulated s ({:.3} ms/round), {:.1}% deliveries stale \
             (mean age {:.3}, max {}), {:.3} node-s waited",
            a.makespan_s,
            1e3 * a.makespan_s / t.cfg.steps.max(1) as f64,
            100.0 * a.stale_fraction,
            a.mean_staleness,
            a.max_staleness,
            a.total_wait_s
        );
    }
    if let Some(s) = t.churn_stats() {
        println!(
            "churn: {} joins / {} leaves over {} resizes; roster ended at n={} \
             (ids {:?})",
            s.joins,
            s.leaves,
            s.resizes,
            t.active_nodes(),
            t.active_ids()
        );
    }
    if t.cfg.telemetry.is_some() {
        match t.telemetry_error() {
            Some(e) => eprintln!("warning: telemetry stream truncated — {e}"),
            None => println!(
                "telemetry: streamed to {} ({:.0} realized wire B/iter)",
                t.cfg.telemetry.as_deref().unwrap_or(""),
                t.wire_bytes_per_iter()
            ),
        }
    }
    Ok(())
}

/// Deterministic text summary of a replayed telemetry stream (the
/// `replay` subcommand): everything here derives from the stream bytes
/// alone, so two replays of the same file print identically.
fn print_replay(r: &decentlam::telemetry::Replay) {
    let rep = &r.report;
    println!(
        "replay: {} events — {}{}",
        r.events,
        if r.complete { "complete run" } else { "INCOMPLETE (no run-end)" },
        if r.truncated { ", truncated tail dropped" } else { "" }
    );
    println!("manifest: {}", rep.manifest);
    if let Some(ev) = &r.async_event {
        println!("async: {}", ev.to_line());
    }
    println!(
        "steps: {} (final loss {})",
        rep.steps,
        rep.losses.last().map(|l| format!("{l:.6}")).unwrap_or_else(|| "-".into())
    );
    for (k, acc) in &rep.evals {
        println!("step {k:>6}  val acc {acc:.4}");
    }
    if r.complete {
        println!(
            "final: acc={:.4} consensus={:.3e}",
            rep.final_accuracy, rep.final_consensus
        );
    }
    println!(
        "wire: {:.0} B total, {:.0} B/iter (realized)",
        rep.wire_bytes_total, rep.wire_bytes_per_iter
    );
    if let Some(f) = &r.fault_totals {
        println!(
            "faults: {} steps realized faults — {} masked edges, {} stale msgs \
             ({} async), {} dropped / {} straggler node-steps",
            f.steps,
            f.masked_edges,
            f.stale_messages,
            f.async_stale_messages,
            f.dropped_node_steps,
            f.straggler_node_steps
        );
    }
    if r.churn_events > 0 {
        println!("churn: {} membership events", r.churn_events);
    }
    if !r.checkpoints.is_empty() {
        println!("checkpoints at steps {:?}", r.checkpoints);
    }
}

/// Deterministic run-profile report aggregated from a telemetry stream
/// (the `profile` subcommand; DESIGN.md §14). A pure function of the
/// stream bytes: the bias trajectory and wire breakdown reproduce the
/// live run's numbers bit for bit, and the timing section reprints the
/// stream's own last `timing` line (wall-clock noise lives in the file,
/// not in this aggregation).
fn print_profile(r: &decentlam::telemetry::Replay) {
    let rep = &r.report;
    println!(
        "profile: {} stream, {} events, {} steps{}{}",
        r.version,
        r.events,
        rep.steps,
        if r.complete { "" } else { " — INCOMPLETE (no run-end)" },
        if r.truncated { ", truncated tail dropped" } else { "" }
    );
    println!(
        "wire: {:.0} B total, {:.0} B/iter (realized)",
        rep.wire_bytes_total, rep.wire_bytes_per_iter
    );
    if r.metrics.is_empty() {
        println!("metrics: none (run without --metrics every=K)");
    } else {
        println!("metrics: {} lines", r.metrics.len());
        println!(
            "{:>8}  {:>13}  {:>13}  {:>13}  {:>13}  {:>13}",
            "step", "cons-p50", "cons-p95", "cons-max", "mom-disagree", "bias-proxy"
        );
        for m in &r.metrics {
            println!(
                "{:>8}  {:>13.6e}  {:>13.6e}  {:>13.6e}  {:>13.6e}  {:>13.6e}",
                m.step,
                m.consensus_p50,
                m.consensus_p95,
                m.consensus_max,
                m.momentum_disagreement,
                m.bias_proxy
            );
        }
        let (first, last) = (&r.metrics[0], &r.metrics[r.metrics.len() - 1]);
        if first.bias_proxy > 0.0 {
            println!(
                "bias trajectory: {:.6e} -> {:.6e} ({:.2}x over {} observations)",
                first.bias_proxy,
                last.bias_proxy,
                last.bias_proxy / first.bias_proxy,
                r.metrics.len()
            );
        }
    }
    match &r.last_timing {
        Some(decentlam::telemetry::Event::Timing {
            step,
            grad_ns,
            encode_ns,
            exchange_ns,
            update_ns,
            lane_busy_ns,
            ..
        }) => {
            let total = grad_ns + encode_ns + exchange_ns + update_ns;
            println!(
                "timing: {} lines; cumulative through step {} \
                 (wall-clock — excluded from replay equality)",
                r.timing_events, step
            );
            for (name, ns) in [
                ("grad", *grad_ns),
                ("encode", *encode_ns),
                ("exchange", *exchange_ns),
                ("update", *update_ns),
            ] {
                let pct = if total > 0 { 100.0 * ns as f64 / total as f64 } else { 0.0 };
                println!("  {name:>8}: {:>14} ns  ({pct:5.1}%)", ns);
            }
            let busiest = lane_busy_ns.iter().copied().max().unwrap_or(0);
            for (lane, &busy) in lane_busy_ns.iter().enumerate() {
                let frac = if busiest > 0 { busy as f64 / busiest as f64 } else { 0.0 };
                println!("  lane {lane:>3}: {busy:>14} ns busy ({:5.1}% of busiest)", 100.0 * frac);
            }
        }
        _ => println!("timing: none (run without --profile)"),
    }
}

/// Topology / spectral-gap report.
fn topo_report(args: &Args) -> Result<()> {
    let n = args.get_usize("nodes", 8)?;
    let mut table = Table::new(
        &format!("topology report (n={n}, Metropolis–Hastings weights)"),
        &["topology", "max degree", "edges", "rho", "spectral gap", "mixing T(1e-3)"],
    );
    for name in ["ring", "mesh", "star", "sym-exp", "full", "erdos", "bipartite"] {
        let kind = Kind::parse(name)?;
        let t = Topology::at_step(kind, n, 1, 0);
        let wm = metropolis_hastings(&t);
        let r = rho(&wm);
        table.row(vec![
            name.into(),
            t.max_degree().to_string(),
            t.num_edges().to_string(),
            sig(r, 4),
            sig(1.0 - r, 4),
            sig(spectral::mixing_time(&wm, 1e-3), 3),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn linreg_bias_run(optimizer: &str, topology: &str, pd: bool, steps: usize) -> Result<(f64, f64)> {
    let problem = LinRegProblem::generate(8, 50, 30, 1);
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.topology = topology.into();
    cfg.lr = 0.001;
    cfg.linear_scaling = false;
    cfg.momentum = 0.8;
    cfg.schedule = LrSchedule::Constant;
    cfg.steps = steps;
    cfg.positive_definite = pd;
    cfg.threads = 1;
    let mut t = Trainer::new(cfg, linreg::workload(problem.clone()))?;
    for k in 0..steps {
        t.step(k);
    }
    let xs: Vec<Vec<f32>> = t.states.iter().map(|s| s.x.clone()).collect();
    Ok((rho(&t.mixing_matrix()), problem.relative_error(&xs)))
}

/// Theorem 1 restriction ablation: plain vs lazy (positive-definite) W.
fn ablate_pd(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 8000)?;
    let mut table = Table::new(
        "ablation — positive-definite (lazy) W vs plain Metropolis",
        &["W", "rho", "final rel. error (decentlam, ring linreg)"],
    );
    for pd in [false, true] {
        let (r, err) = linreg_bias_run("decentlam", "ring", pd, steps)?;
        table.row(vec![
            if pd { "lazy (I+W)/2" } else { "metropolis" }.into(),
            sig(r, 4),
            sig(err, 3),
        ]);
    }
    println!("{}", table.render());
    println!("(Theorem 1 assumes positive-definite W; plain W works in practice — paper §6.1)");
    Ok(())
}

/// Remark 1 ablation: ATC (dmsgd) vs AWC (awc-dmsgd) limiting bias.
fn ablate_atc(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 12000)?;
    let mut table = Table::new(
        "ablation — ATC vs AWC partial averaging (mesh linreg limiting bias)",
        &["form", "optimizer", "rho", "final rel. error"],
    );
    for (form, opt) in [("ATC", "dmsgd"), ("AWC", "awc-dmsgd"), ("ATC+corr", "decentlam")] {
        let (r, err) = linreg_bias_run(opt, "mesh", false, steps)?;
        table.row(vec![form.into(), opt.into(), sig(r, 4), sig(err, 3)]);
    }
    println!("{}", table.render());
    Ok(())
}

/// Limiting bias as a function of topology connectivity ρ.
fn ablate_rho(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 12000)?;
    let mut table = Table::new(
        "ablation — DecentLaM limiting bias vs topology rho (theory: bias ∝ 1/(1−ρ)²)",
        &["topology", "rho", "final rel. error"],
    );
    for name in ["full", "sym-exp", "mesh", "ring"] {
        let (r, err) = linreg_bias_run("decentlam", name, false, steps)?;
        table.row(vec![name.into(), sig(r, 4), sig(err, 3)]);
    }
    println!("{}", table.render());
    Ok(())
}
