//! AWC-DmSGD (Balu et al. 2020) — adaptation-while-combination momentum
//! SGD: the partial-averaging step is mixed *into* the local momentum
//! update rather than applied after it (paper Remark 1 contrasts AWC
//! with the ATC form used by DmSGD/DecentLaM):
//!
//!   m_i ← β m_i + g_i
//!   x_i ← Σ_j w_ij x_j − γ m_i
//!
//! AWC tolerates smaller learning rates than ATC (Sayed 2014 §10.6),
//! which is exactly why the paper's Table 2 shows its worse bias order.

use crate::util::math;

use super::{gossip_exchange, CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

pub struct AwcDmsgd;

impl Optimizer for AwcDmsgd {
    fn name(&self) -> &'static str {
        "awc-dmsgd"
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        // Complete per-node state is (x, m); no aux buffers.
        &[]
    }

    fn comm_pattern(&self) -> CommPattern {
        CommPattern::Neighbor { payloads: 1 }
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        // Publish the raw model (combination input).
        let states_ro: &[NodeState] = states;
        ctx.exec.for_each_mut(&mut scratch.publish, |i, p| {
            p.copy_from_slice(&states_ro[i].x);
        });
        gossip_exchange(ctx, &scratch.publish, &mut scratch.mixed);
        let mixed = &scratch.mixed;
        ctx.exec.for_each_mut(states, |i, st| {
            math::axpby(&mut st.m, 1.0, &grads[i], ctx.beta);
            st.x.copy_from_slice(&mixed[i]);
            math::axpy(&mut st.x, -ctx.lr, &st.m);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::dsgd::tests::setup;
    use super::*;

    #[test]
    fn differs_from_atc_after_one_step_with_spread_models() {
        let d = 2;
        let (wm, states0, mut scratch) = setup(4, d); // x_i = i
        let grads: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; d]).collect();
        let ctx = RoundCtx::new(&wm, 0.1, 0.5, 0, false);
        let mut awc = states0.clone();
        AwcDmsgd.round(&mut awc, &grads, &ctx, &mut scratch);
        let mut atc = states0.clone();
        super::super::dmsgd::Dmsgd.round(&mut atc, &grads, &ctx, &mut scratch);
        // AWC: Wx - γm (gradient not averaged); ATC: W(x - γm).
        let diff: f32 = awc
            .iter()
            .zip(&atc)
            .map(|(a, b)| (a.x[0] - b.x[0]).abs())
            .sum();
        assert!(diff > 1e-4, "AWC must differ from ATC, diff={diff}");
    }

    #[test]
    fn consensus_zero_grad_fixed_point() {
        let (wm, _, mut scratch) = setup(4, 1);
        let mut states: Vec<NodeState> =
            (0..4).map(|_| NodeState::new(vec![7.0], 0)).collect();
        let grads = vec![vec![0.0f32]; 4];
        let ctx = RoundCtx::new(&wm, 0.1, 0.9, 0, false);
        AwcDmsgd.round(&mut states, &grads, &ctx, &mut scratch);
        for st in &states {
            assert!((st.x[0] - 7.0).abs() < 1e-6);
        }
    }
}
