//! D²-DmSGD (Tang et al. 2018; the momentum form the paper tests, via
//! Yuan et al. 2020's bias-corrected rewrite). D² cancels the
//! inconsistency bias with the primal-dual correction
//!
//!   x^{k+1} = W ( 2 x^k − x^{k−1} − γ^k m^k + γ^{k−1} m^{k−1} )
//!
//! where m is the local heavy-ball momentum m^k = β m^{k−1} + g^k
//! (momentum added to the local update step as described in paper §7).
//! First iteration falls back to one DmSGD round.
//!
//! NOTE the γ^{k−1} on the correction term: D² subtracts the *previous
//! actual update*; re-scaling the old momentum by the current learning
//! rate corrupts the correction whenever the schedule moves (warmup /
//! decay) and collapses training.
//!
//! Aux buffers: [0] x^{k−1}, [1] the previous update vector γ^{k−1}·m^{k−1}.

use super::{gossip_exchange, CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

pub struct D2Dmsgd;

impl Optimizer for D2Dmsgd {
    fn name(&self) -> &'static str {
        "d2-dmsgd"
    }

    fn aux_count(&self) -> usize {
        2 // [x_prev, m_prev]
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        &["x_prev", "prev_update"]
    }

    fn warm_start(&self, st: &mut NodeState) {
        // A joiner has no history: with m = 0, previous update = 0 and
        // x_prev = x, its first D² combination collapses to the DmSGD
        // half-step x − γm — the same fallback the step-0 branch takes.
        st.m.iter_mut().for_each(|v| *v = 0.0);
        st.aux[1].iter_mut().for_each(|v| *v = 0.0);
        st.aux[0].copy_from_slice(&st.x);
    }

    fn comm_pattern(&self) -> CommPattern {
        CommPattern::Neighbor { payloads: 1 }
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        let first = ctx.step == 0;
        ctx.exec.for_each_pair_mut(states, &mut scratch.publish, |i, st, p| {
            // momentum update: m = beta*m + g
            for (mi, &gi) in st.m.iter_mut().zip(&grads[i]) {
                *mi = ctx.beta * *mi + gi;
            }
            if first {
                // DmSGD-style half step.
                for ((pi, &xi), &mi) in p.iter_mut().zip(&st.x).zip(&st.m) {
                    *pi = xi - ctx.lr * mi;
                }
            } else {
                // D² combination: 2x − x_prev − γ^k m^k + (γ^{k−1} m^{k−1}).
                for k in 0..st.x.len() {
                    p[k] = 2.0 * st.x[k] - st.aux[0][k] - ctx.lr * st.m[k] + st.aux[1][k];
                }
            }
            // Record previous iterate and previous update vector.
            for k in 0..st.x.len() {
                st.aux[0][k] = st.x[k];
                st.aux[1][k] = ctx.lr * st.m[k];
            }
        });
        gossip_exchange(ctx, &scratch.publish, &mut scratch.mixed);
        let mixed = &scratch.mixed;
        ctx.exec.for_each_mut(states, |i, st| {
            st.x.copy_from_slice(&mixed[i]);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::dsgd::tests::setup;
    use super::*;

    #[test]
    fn first_round_matches_dmsgd() {
        let d = 2;
        let (wm, _, mut scratch) = setup(4, d);
        let mk = |aux: usize| -> Vec<NodeState> {
            (0..4).map(|i| NodeState::new(vec![i as f32; d], aux)).collect()
        };
        let grads: Vec<Vec<f32>> = (0..4).map(|i| vec![0.1 * i as f32; d]).collect();
        let ctx = RoundCtx::new(&wm, 0.1, 0.9, 0, false);
        let mut a = mk(2);
        D2Dmsgd.round(&mut a, &grads, &ctx, &mut scratch);
        let mut b = mk(0);
        super::super::dmsgd::Dmsgd.round(&mut b, &grads, &ctx, &mut scratch);
        for (sa, sb) in a.iter().zip(&b) {
            for (va, vb) in sa.x.iter().zip(&sb.x) {
                assert!((va - vb).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn d2_kills_heterogeneous_bias_on_quadratics() {
        // f_i(x) = 0.5 (x - c_i)^2 with different c_i: DSGD stalls at a
        // biased point for constant γ, D² converges to the exact mean.
        let n = 4;
        let (wm, _, mut scratch) = setup(n, 1);
        let c: Vec<f32> = vec![-3.0, -1.0, 1.0, 3.0]; // mean 0
        let mut states: Vec<NodeState> =
            (0..n).map(|_| NodeState::new(vec![2.0], 2)).collect();
        let mut o = D2Dmsgd;
        for step in 0..4000 {
            let grads: Vec<Vec<f32>> =
                states.iter().zip(&c).map(|(s, ci)| vec![s.x[0] - ci]).collect();
            let ctx = RoundCtx::new(&wm, 0.05, 0.8, step, false);
            o.round(&mut states, &grads, &ctx, &mut scratch);
        }
        for st in &states {
            assert!(st.x[0].abs() < 2e-2, "D² should reach x*=0, got {}", st.x[0]);
        }
    }
}
