//! DA-DmSGD (Yu, Jin & Yang 2019) — doubly-averaged decentralized
//! momentum SGD: an *additional* partial averaging over the momentum
//! increases stability at the price of a second parameter-sized payload
//! per iteration (paper §7: "it has double partial averages per
//! iteration").
//!
//!   m_i ← Σ_j w_ij (β m_j + g_j)        (momentum gossip)
//!   x_i ← Σ_j w_ij (x_j − γ m_i)        (model gossip)

use crate::util::math;

use super::{gossip_exchange, CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

pub struct DaDmsgd;

impl Optimizer for DaDmsgd {
    fn name(&self) -> &'static str {
        "da-dmsgd"
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        // Complete per-node state is (x, m); no aux buffers.
        &[]
    }

    fn comm_pattern(&self) -> CommPattern {
        CommPattern::Neighbor { payloads: 2 }
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        // Publish half-momentum beta*m + g, gossip it.
        let states_ro: &[NodeState] = states;
        ctx.exec.for_each_mut(&mut scratch.publish, |i, p| {
            for ((pi, &mi), &gi) in p.iter_mut().zip(&states_ro[i].m).zip(&grads[i]) {
                *pi = ctx.beta * mi + gi;
            }
        });
        gossip_exchange(ctx, &scratch.publish, &mut scratch.mixed);
        // Install the averaged momentum, publish the half-step with it.
        let mixed_ro: &[Vec<f32>] = &scratch.mixed;
        ctx.exec.for_each_pair_mut(states, &mut scratch.publish, |i, st, z| {
            st.m.copy_from_slice(&mixed_ro[i]);
            z.copy_from_slice(&st.x);
            math::axpy(z, -ctx.lr, &st.m);
        });
        gossip_exchange(ctx, &scratch.publish, &mut scratch.mixed);
        let mixed = &scratch.mixed;
        ctx.exec.for_each_mut(states, |i, st| {
            st.x.copy_from_slice(&mixed[i]);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::dsgd::tests::setup;
    use super::*;

    #[test]
    fn momentum_is_gossiped() {
        let (wm, mut states, mut scratch) = setup(4, 1);
        // Only node 0 has a gradient; after one round every node's
        // neighborhood of 0 picks up momentum mass.
        let mut grads = vec![vec![0.0f32]; 4];
        grads[0][0] = 1.0;
        let ctx = RoundCtx::new(&wm, 0.0, 0.9, 0, false);
        DaDmsgd.round(&mut states, &grads, &ctx, &mut scratch);
        // Node 1 and 3 are ring-neighbors of 0.
        assert!(states[1].m[0] > 0.0);
        assert!(states[3].m[0] > 0.0);
        assert!(states[2].m[0].abs() < 1e-7, "two hops away stays zero");
        // Momentum mean preserved by doubly-stochastic gossip: 1/4.
        let mean: f32 = states.iter().map(|s| s.m[0]).sum::<f32>() / 4.0;
        assert!((mean - 0.25).abs() < 1e-6);
    }

    #[test]
    fn consensus_zero_grad_fixed_point() {
        let (wm, _, mut scratch) = setup(4, 2);
        let mut states: Vec<NodeState> =
            (0..4).map(|_| NodeState::new(vec![2.0, 3.0], 0)).collect();
        let grads = vec![vec![0.0f32; 2]; 4];
        let ctx = RoundCtx::new(&wm, 0.1, 0.9, 0, false);
        DaDmsgd.round(&mut states, &grads, &ctx, &mut scratch);
        for st in &states {
            assert!((st.x[0] - 2.0).abs() < 1e-6 && (st.x[1] - 3.0).abs() < 1e-6);
        }
    }
}
