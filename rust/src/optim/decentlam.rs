//! DecentLaM (paper Algorithm 2, eq. (17)) — THE contribution.
//!
//! Each node publishes the same half-step as DSGD (z_i = x_i − γ g_i; no
//! extra traffic vs DmSGD), then forms the bias-corrected gradient
//!
//! ```text
//! gt_i = (x_i − Σ_j w_ij z_j) / γ
//! ```
//!
//! and runs vanilla heavy-ball on g̃: m ← βm + g̃, x ← x − γm. Because
//! the momentum is built from the *corrected* gradient, the fixed point
//! satisfies (I−W)x = −γW∇f(x) independent of β (Proposition 3): the
//! momentum-amplified inconsistency bias of DmSGD vanishes.
//!
//! The apply step is exactly the fused Layer-1 Pallas kernel
//! (`python/compile/kernels/decentlam_update.py`); this Rust routine is
//! the native mirror, verified against the kernel's golden vectors in
//! `rust/tests/golden.rs`.

use super::{gossip_exchange, CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

/// Full-model gradient norms at or below this are treated as vanishing
/// and skip the disagreement clip. The clip bounds the
/// correction/gradient loop gain, which is meaningless as ‖g‖ → 0: the
/// limit would collapse to ~0, rescale `mix` back onto `x`, and freeze
/// consensus mixing entirely — while the vanishing-gradient dynamics
/// (pure heavy-ball consensus, x^{k+1} = W x^k + β(x^k − x^{k−1})) are
/// contractive on their own and need no guard: the echo instability
/// the clip exists for is *gradient feedback* at disagreeing iterates,
/// which is numerically absent below this scale. 1e-6 is far below any
/// training-regime full-model gradient norm (so gradient-driven runs
/// are untouched) yet wide enough that the near-converged tail doesn't
/// fall back into the frozen-mixing regime.
///
/// The threshold is deliberately ABSOLUTE, not relative to the
/// disagreement: a relative guard ("skip when corr ≫ clip·‖g‖") would
/// disarm the clip precisely in the echo-divergence regime it exists
/// for — the blow-up inflates corr relative to ‖g‖, and stability
/// rests on the correction staying bounded by clip·‖g‖ there. The
/// price is that a genuinely small-but-nonzero gradient with large
/// disagreement mixes slowly (at ~clip·‖g‖·γ per step) until the
/// disagreement drains; a per-node rule cannot distinguish that benign
/// case from the echo without global information.
const CLIP_GRAD_EPS: f32 = 1e-6;

pub struct DecentLam {
    /// Cap on ‖g̃‖ as a multiple of ‖g_raw‖. The corrected gradient
    /// contains the disagreement term (x − Σw z)/γ; on TIME-VARYING
    /// topologies (bipartite random match, one-peer exp) the momentum
    /// re-injects stale-direction disagreement that the static-W
    /// analysis (paper §5, which assumes a fixed W = W^½·W^½) cancels —
    /// left unchecked the echo loop diverges at β ≈ 0.9. Clipping the
    /// correction at `clip`×‖g‖ bounds the loop gain; it never engages
    /// in the static-topology regime (verified by the Fig. 2/3 bias
    /// tests, which reproduce the paper's limiting bias exactly).
    pub clip: f32,
}

impl Default for DecentLam {
    fn default() -> Self {
        DecentLam { clip: 4.0 }
    }
}

/// Fused single-node apply (the kernel's contract):
/// given mix = Σ w_ij z_j, update (x, m) in place.
///
///   m' = β m + (x − mix)/γ
///   x' = mix − γ β m        (≡ x − γ m')
#[inline]
pub fn fused_apply(x: &mut [f32], m: &mut [f32], mix: &[f32], gamma: f32, beta: f32) {
    let inv_gamma = 1.0 / gamma;
    let gb = gamma * beta;
    for ((xi, mi), &mixi) in x.iter_mut().zip(m.iter_mut()).zip(mix) {
        let m_old = *mi;
        *mi = beta * m_old + (*xi - mixi) * inv_gamma;
        *xi = mixi - gb * m_old;
    }
}

impl Optimizer for DecentLam {
    fn name(&self) -> &'static str {
        "decentlam"
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        // Complete per-node state is (x, m): the correction term is
        // recomputed from (x − Σw z)/γ every round, never stored — a
        // warm-started joiner needs nothing beyond x and zeroed m.
        &[]
    }

    fn comm_pattern(&self) -> CommPattern {
        // Same wire traffic as DSGD/DmSGD: one parameter-sized payload.
        CommPattern::Neighbor { payloads: 1 }
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        // Publish z_i = x_i - lr*g_i (identical payload to DSGD).
        let states_ro: &[NodeState] = states;
        ctx.exec.for_each_mut(&mut scratch.publish, |i, z| {
            for ((zi, &xi), &gi) in z.iter_mut().zip(&states_ro[i].x).zip(&grads[i]) {
                *zi = xi - ctx.lr * gi;
            }
        });
        gossip_exchange(ctx, &scratch.publish, &mut scratch.mixed);
        // Fused corrected-momentum apply (eq. 17), with the correction
        // clipped at `clip`×‖g‖ (see field docs — time-varying graphs).
        // Vanishing gradients skip the clip: the limit would otherwise
        // collapse toward 0 and rewrite mix ≈ x, freezing consensus.
        let clip = self.clip;
        ctx.exec.for_each_pair_mut(states, &mut scratch.mixed, |i, st, mix| {
            let g_norm = crate::util::math::norm2(&grads[i]) as f32;
            let corr_norm = (crate::util::math::dist2(&st.x, mix).sqrt() / ctx.lr as f64) as f32;
            let limit = clip * g_norm;
            if ctx.time_varying && g_norm > CLIP_GRAD_EPS && corr_norm > limit {
                // mix_eff = x + (mix − x)·s keeps the update direction,
                // bounds ‖g̃‖ = ‖x − mix_eff‖/γ at the limit.
                let s = limit / corr_norm;
                for (mi, &xi) in mix.iter_mut().zip(&st.x) {
                    *mi = xi + (*mi - xi) * s;
                }
            }
            fused_apply(&mut st.x, &mut st.m, mix, ctx.lr, ctx.beta);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::dsgd::tests::setup;
    use super::super::partial_average_all;
    use super::*;
    use crate::topology::{metropolis_hastings, Kind, Topology};

    #[test]
    fn fused_apply_matches_unfused_algebra() {
        let d = 16;
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let mut x = vec![0.0f32; d];
        let mut m = vec![0.0f32; d];
        let mut mix = vec![0.0f32; d];
        rng.normal_fill(&mut x, 1.0);
        rng.normal_fill(&mut m, 1.0);
        rng.normal_fill(&mut mix, 1.0);
        let (gamma, beta) = (0.05f32, 0.9f32);
        // Unfused reference: gt = (x-mix)/gamma; m' = beta*m+gt; x' = x-gamma*m'.
        let mut xe = x.clone();
        let mut me = m.clone();
        for i in 0..d {
            let gt = (xe[i] - mix[i]) / gamma;
            me[i] = beta * me[i] + gt;
            xe[i] -= gamma * me[i];
        }
        fused_apply(&mut x, &mut m, &mix, gamma, beta);
        for i in 0..d {
            assert!((x[i] - xe[i]).abs() < 1e-4, "x[{i}]");
            assert!((m[i] - me[i]).abs() < 1e-4, "m[{i}]");
        }
    }

    #[test]
    fn zero_grad_time_varying_consensus_still_contracts() {
        // Regression: the clip limit used to be `clip*‖g‖ + 1e-12`, so
        // vanishing gradients on a time-varying topology collapsed the
        // limit to 1e-12 and the rescale s = limit/corr ≈ 0 rewrote
        // mix ≈ x — consensus mixing froze completely. With the
        // vanishing-gradient guard, pure heavy-ball consensus over the
        // changing matchings must keep contracting.
        let n = 4;
        let d = 3;
        let mut rng = crate::util::rng::Pcg64::seeded(17);
        let states: Vec<NodeState> = (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                rng.normal_fill(&mut x, 1.0);
                NodeState::new(x, 0)
            })
            .collect();
        let consensus = |sts: &[NodeState]| -> f64 {
            let xbar: Vec<f32> = (0..d)
                .map(|k| sts.iter().map(|s| s.x[k]).sum::<f32>() / n as f32)
                .collect();
            sts.iter()
                .map(|s| crate::util::math::dist2(&s.x, &xbar))
                .sum::<f64>()
                / n as f64
        };
        let initial = consensus(&states);
        assert!(initial > 1e-3, "nodes must start spread out");
        // Exactly-zero AND tiny-but-nonzero gradients (below the
        // vanishing threshold) must both leave mixing unfrozen.
        for tiny in [0.0f32, 1e-9] {
            let mut states = states.clone();
            let grads = vec![vec![tiny; d]; n];
            let mut scratch = Scratch::new(n, d);
            let mut o = DecentLam::default();
            let mut sw = crate::topology::SparseWeights::default();
            for step in 0..120 {
                let topo =
                    Topology::at_step(crate::topology::Kind::BipartiteRandomMatch, n, 7, step);
                sw.rebuild_metropolis(&topo);
                let ctx = RoundCtx::new(&sw, 0.05, 0.6, step, true);
                o.round(&mut states, &grads, &ctx, &mut scratch);
            }
            let final_c = consensus(&states);
            assert!(
                final_c < 0.5 * initial,
                "g={tiny}: consensus froze on time-varying graph: {initial} -> {final_c}"
            );
            assert!(states.iter().all(|s| s.x.iter().all(|v| v.is_finite())));
        }
    }

    #[test]
    fn consensus_zero_grad_is_fixed_point() {
        // All nodes at the same x with zero gradient: x unchanged, m decays.
        let (wm, _, mut scratch) = setup(4, 2);
        let mut states: Vec<NodeState> =
            (0..4).map(|_| NodeState::new(vec![1.5, -0.5], 0)).collect();
        let grads = vec![vec![0.0f32; 2]; 4];
        let ctx = RoundCtx::new(&wm, 0.1, 0.9, 0, false);
        let mut o = DecentLam::default();
        o.round(&mut states, &grads, &ctx, &mut scratch);
        for st in &states {
            assert!((st.x[0] - 1.5).abs() < 1e-6 && (st.x[1] + 0.5).abs() < 1e-6);
            assert!(st.m.iter().all(|&v| v.abs() < 1e-6));
        }
    }

    #[test]
    fn beta_zero_equals_dsgd() {
        let d = 3;
        let (wm, states0, mut scratch) = setup(4, d);
        let grads: Vec<Vec<f32>> = (0..4).map(|i| vec![0.3 * (i as f32 - 1.0); d]).collect();
        let ctx = RoundCtx::new(&wm, 0.2, 0.0, 0, false);
        let mut a = states0.clone();
        DecentLam::default().round(&mut a, &grads, &ctx, &mut scratch);
        let mut b = states0.clone();
        super::super::dsgd::Dsgd.round(&mut b, &grads, &ctx, &mut scratch);
        for (sa, sb) in a.iter().zip(&b) {
            for (va, vb) in sa.x.iter().zip(&sb.x) {
                assert!((va - vb).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn reformulation_b2_holds() {
        // App. B.2, eq. (36): x^{k+1} = W(x^k - γ g^k) + β(x^k - x^{k-1}).
        let n = 4;
        let d = 2;
        let wm = metropolis_hastings(&Topology::build(Kind::Ring, n));
        let mut rng = crate::util::rng::Pcg64::seeded(9);
        let mut states: Vec<NodeState> = (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                rng.normal_fill(&mut x, 1.0);
                NodeState::new(x, 0)
            })
            .collect();
        let mut scratch = Scratch::new(n, d);
        let mut o = DecentLam::default();
        let gamma = 0.1f32;
        let beta = 0.8f32;
        let grad_at = |xs: &[NodeState], step: usize| -> Vec<Vec<f32>> {
            // A fixed deterministic "gradient" field g_i(x) = x + c_i + step noise-free.
            xs.iter()
                .enumerate()
                .map(|(i, st)| {
                    st.x.iter()
                        .map(|&v| v + i as f32 * 0.5 + step as f32 * 0.0)
                        .collect()
                })
                .collect()
        };
        let ctx = RoundCtx::new(&wm, gamma, beta, 0, false);

        // Track x^{k-1}, x^k to verify the recursion at k >= 1.
        let mut x_prev: Vec<Vec<f32>> = states.iter().map(|s| s.x.clone()).collect();
        let g0 = grad_at(&states, 0);
        o.round(&mut states, &g0, &ctx, &mut scratch);
        let x_k: Vec<Vec<f32>> = states.iter().map(|s| s.x.clone()).collect();
        let g1 = grad_at(&states, 1);
        o.round(&mut states, &g1, &ctx, &mut scratch);

        // Predicted: W(x_k - γ g1) + β (x_k - x_prev)
        let half: Vec<Vec<f32>> = x_k
            .iter()
            .zip(&g1)
            .map(|(x, g)| x.iter().zip(g).map(|(xi, gi)| xi - gamma * gi).collect())
            .collect();
        let mut mixed = vec![vec![0.0f32; d]; n];
        partial_average_all(&wm, &half, &mut mixed);
        for i in 0..n {
            for jd in 0..d {
                let pred = mixed[i][jd] + beta * (x_k[i][jd] - x_prev[i][jd]);
                assert!(
                    (states[i].x[jd] - pred).abs() < 1e-4,
                    "node {i} dim {jd}: got {} want {pred}",
                    states[i].x[jd]
                );
            }
        }
        x_prev = x_k;
        let _ = x_prev;
    }
}
