//! DmSGD (paper Algorithm 1; Assran et al. 2019) — decentralized
//! momentum SGD. Momentum update, local model update, then partial
//! averaging of the half-step. Its momentum term amplifies the
//! inconsistency bias by 1/(1−β)² (Proposition 2) — the defect
//! DecentLaM removes.

use crate::util::math;

use super::{gossip_exchange, CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

pub struct Dmsgd;

impl Optimizer for Dmsgd {
    fn name(&self) -> &'static str {
        "dmsgd"
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        // Complete per-node state is (x, m); no aux buffers.
        &[]
    }

    fn comm_pattern(&self) -> CommPattern {
        CommPattern::Neighbor { payloads: 1 }
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        ctx.exec.for_each_pair_mut(states, &mut scratch.publish, |i, st, z| {
            // m = beta*m + g  (momentum update)
            math::axpby(&mut st.m, 1.0, &grads[i], ctx.beta);
            // z = x - lr*m  (local model update)
            z.copy_from_slice(&st.x);
            math::axpy(z, -ctx.lr, &st.m);
        });
        // x = sum_j w_ij z_j  (partial average)
        gossip_exchange(ctx, &scratch.publish, &mut scratch.mixed);
        let mixed = &scratch.mixed;
        ctx.exec.for_each_mut(states, |i, st| {
            st.x.copy_from_slice(&mixed[i]);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::dsgd::tests::setup;
    use super::*;

    #[test]
    fn momentum_accumulates_geometrically() {
        let (wm, mut states, mut scratch) = setup(4, 1);
        for s in states.iter_mut() {
            s.x[0] = 0.0;
        }
        let grads = vec![vec![1.0f32]; 4];
        let ctx = RoundCtx::new(&wm, 0.0, 0.5, 0, false);
        let mut o = Dmsgd;
        o.round(&mut states, &grads, &ctx, &mut scratch);
        assert!((states[0].m[0] - 1.0).abs() < 1e-6);
        o.round(&mut states, &grads, &ctx, &mut scratch);
        assert!((states[0].m[0] - 1.5).abs() < 1e-6);
        o.round(&mut states, &grads, &ctx, &mut scratch);
        assert!((states[0].m[0] - 1.75).abs() < 1e-6);
    }

    #[test]
    fn beta_zero_equals_dsgd() {
        let d = 3;
        let (wm, states0, mut scratch) = setup(4, d);
        let grads: Vec<Vec<f32>> = (0..4).map(|i| vec![0.1 * i as f32; d]).collect();
        let ctx = RoundCtx::new(&wm, 0.2, 0.0, 0, false);

        let mut a = states0.clone();
        Dmsgd.round(&mut a, &grads, &ctx, &mut scratch);
        let mut b = states0.clone();
        super::super::dsgd::Dsgd.round(&mut b, &grads, &ctx, &mut scratch);
        for (sa, sb) in a.iter().zip(&b) {
            for (va, vb) in sa.x.iter().zip(&sb.x) {
                assert!((va - vb).abs() < 1e-6);
            }
        }
    }
}
