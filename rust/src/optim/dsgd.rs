//! DSGD (Lian et al. 2017) — decentralized SGD, paper eqs. (4)–(5).
//!
//! ATC form: local half-step z_i = x_i − γ g_i, then partial averaging
//! x_i ← Σ_j w_ij z_j. Momentum-free; its O(γ²b²/(1−ρ)²) inconsistency
//! bias (App. C.1) is the floor DecentLaM is designed to match.

use crate::util::math;

use super::{gossip_exchange, CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

pub struct Dsgd;

impl Optimizer for Dsgd {
    fn name(&self) -> &'static str {
        "dsgd"
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        // Momentum-free: complete per-node state is x (m stays zero).
        &[]
    }

    fn comm_pattern(&self) -> CommPattern {
        CommPattern::Neighbor { payloads: 1 }
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        // z_i = x_i - lr * g_i  (local update, eq. 4)
        let states_ro: &[NodeState] = states;
        ctx.exec.for_each_mut(&mut scratch.publish, |i, z| {
            z.copy_from_slice(&states_ro[i].x);
            math::axpy(z, -ctx.lr, &grads[i]);
        });
        // x_i = sum_j w_ij z_j  (partial averaging, eq. 5)
        gossip_exchange(ctx, &scratch.publish, &mut scratch.mixed);
        let mixed = &scratch.mixed;
        ctx.exec.for_each_mut(states, |i, st| {
            st.x.copy_from_slice(&mixed[i]);
        });
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::topology::{metropolis_hastings, Kind, Topology, WeightMatrix};

    pub(crate) fn setup(n: usize, d: usize) -> (WeightMatrix, Vec<NodeState>, Scratch) {
        let wm = metropolis_hastings(&Topology::build(Kind::Ring, n));
        let states = (0..n)
            .map(|i| NodeState::new(vec![i as f32; d], 0))
            .collect();
        let scratch = Scratch::new(n, d);
        (wm, states, scratch)
    }

    #[test]
    fn zero_grad_is_pure_gossip() {
        let (wm, mut states, mut scratch) = setup(4, 2);
        let grads = vec![vec![0.0f32; 2]; 4];
        let ctx = RoundCtx::new(&wm, 0.1, 0.9, 0, false);
        let before_mean: f32 = states.iter().map(|s| s.x[0]).sum::<f32>() / 4.0;
        Dsgd.round(&mut states, &grads, &ctx, &mut scratch);
        let after_mean: f32 = states.iter().map(|s| s.x[0]).sum::<f32>() / 4.0;
        assert!((before_mean - after_mean).abs() < 1e-6);
        // Consensus (spread) must shrink.
        let spread =
            states.iter().map(|s| (s.x[0] - after_mean).abs()).fold(0.0f32, f32::max);
        assert!(spread < 1.5);
    }

    #[test]
    fn fully_connected_reduces_to_parallel_sgd() {
        let wm = metropolis_hastings(&Topology::build(Kind::Full, 4));
        let d = 3;
        let mut states: Vec<NodeState> =
            (0..4).map(|_| NodeState::new(vec![1.0; d], 0)).collect();
        let grads: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; d]).collect();
        let ctx = RoundCtx::new(&wm, 0.5, 0.0, 0, false);
        let mut scratch = Scratch::new(4, d);
        Dsgd.round(&mut states, &grads, &ctx, &mut scratch);
        // mean grad = 1.5 -> every x = 1 - 0.5*1.5 = 0.25
        for st in &states {
            for &v in &st.x {
                assert!((v - 0.25).abs() < 1e-6);
            }
        }
    }
}
