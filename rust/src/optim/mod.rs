//! The decentralized optimizer suite (paper §3, §5, §7 baselines).
//!
//! Each algorithm is a synchronous round over `n` nodes holding flat
//! parameter vectors. A round receives this step's per-node gradients
//! (already averaged over the node's accumulated micro-batches by the
//! coordinator) and performs its communication + update. Communication
//! is expressed exclusively through [`gossip_exchange`] (the
//! codec-aware wire primitive over [`partial_average_all`]) and
//! [`global_average`] over an abstract [`CommEngine`] (sparse neighbor
//! lists in production — see `topology::sparse`) so that (a) the
//! decentralized methods only ever read *neighbor* rows of `W`, never a
//! dense matrix, (b) a configured payload codec compresses every gossip
//! payload in one place, and (c) the cost model can charge exactly the
//! payloads declared by [`Optimizer::comm_pattern`] from realized edge
//! counts at their encoded widths.
//! Per-node work inside a round fans out through the
//! [`RoundCtx::exec`] node executor; every loop body is independent
//! per node, so parallel and serial execution are bitwise identical.
//!
//! Implemented algorithms:
//!
//! | name        | reference                | file           |
//! |-------------|--------------------------|----------------|
//! | `dsgd`      | Lian et al. 2017         | `dsgd.rs`      |
//! | `dmsgd`     | Assran et al. / Alg. 1   | `dmsgd.rs`     |
//! | `decentlam` | **this paper, Alg. 2**   | `decentlam.rs` |
//! | `pmsgd`     | Goyal et al. (DDP)       | `pmsgd.rs`     |
//! | `pmsgd-lars`| You et al. (LARS)        | `pmsgd.rs`     |
//! | `da-dmsgd`  | Yu, Jin, Yang 2019       | `da_dmsgd.rs`  |
//! | `awc-dmsgd` | Balu et al. 2020         | `awc_dmsgd.rs` |
//! | `slowmo`    | Wang et al. 2019         | `slowmo.rs`    |
//! | `qg-dmsgd`  | Lin et al. 2021          | `qg_dmsgd.rs`  |
//! | `d2-dmsgd`  | Tang et al. 2018 + mom.  | `d2_dmsgd.rs`  |

pub mod awc_dmsgd;
pub mod d2_dmsgd;
pub mod da_dmsgd;
pub mod decentlam;
pub mod dmsgd;
pub mod dsgd;
pub mod pmsgd;
pub mod qg_dmsgd;
pub mod schedule;
pub mod slowmo;

use std::sync::Mutex;

use anyhow::bail;

use crate::comm::codec::CodecState;
use crate::comm::engine::CommEngine;
use crate::coordinator::executor::NodeExecutor;
use crate::util::math;

/// Per-node optimizer state: model, momentum, and algorithm-specific
/// auxiliary buffers (previous iterates, slow momentum, ...).
#[derive(Debug, Clone)]
pub struct NodeState {
    pub x: Vec<f32>,
    pub m: Vec<f32>,
    pub aux: Vec<Vec<f32>>,
}

impl NodeState {
    pub fn new(x0: Vec<f32>, aux_count: usize) -> NodeState {
        let d = x0.len();
        NodeState {
            x: x0,
            m: vec![0.0; d],
            aux: (0..aux_count).map(|_| vec![0.0; d]).collect(),
        }
    }
}

/// Everything a round needs besides node state.
pub struct RoundCtx<'a> {
    /// Mixing weights, exposed as sparse neighbor rows.
    pub comm: &'a dyn CommEngine,
    /// Node executor the round fans per-node work out through.
    pub exec: NodeExecutor,
    /// Learning rate at this step (schedule already applied).
    pub lr: f32,
    /// Momentum coefficient β.
    pub beta: f32,
    /// Iteration index k.
    pub step: usize,
    /// Whether the mixing matrix changes between iterations (one-peer
    /// exp, bipartite random match). DecentLaM's disagreement-clip guard
    /// only engages in this regime (see `decentlam.rs`).
    pub time_varying: bool,
    /// Flat-vector layer boundaries (for LARS); empty = single group.
    pub layer_ranges: &'a [(usize, usize)],
    /// Payload codec for the gossip wire path (None = raw fp32). Behind
    /// a mutex because encoding mutates cross-round state (EF
    /// residuals, wire buffers) while `RoundCtx` is shared immutably
    /// across the executor's threads; [`gossip_exchange`] locks it once
    /// per exchange.
    pub codec: Option<&'a Mutex<CodecState>>,
    /// Phase clock the profiler attaches when `--profile` is on (None =
    /// unprofiled). [`gossip_exchange`] splits its wall time into
    /// encode/exchange spans; timing is observability only and never
    /// feeds back into the arithmetic (DESIGN.md §14).
    pub clock: Option<&'a crate::util::bench::PhaseClock>,
}

impl<'a> RoundCtx<'a> {
    /// Serial-executor context with no layer ranges (the common test
    /// shape; the trainer builds the full struct itself).
    pub fn new(
        comm: &'a dyn CommEngine,
        lr: f32,
        beta: f32,
        step: usize,
        time_varying: bool,
    ) -> RoundCtx<'a> {
        RoundCtx {
            comm,
            exec: NodeExecutor::serial(),
            lr,
            beta,
            step,
            time_varying,
            layer_ranges: &[],
            codec: None,
            clock: None,
        }
    }
}

/// Reusable cross-round buffers, allocated once by the coordinator —
/// the step loop is allocation-free (see EXPERIMENTS.md §Perf).
pub struct Scratch {
    /// Per-node publish buffer (what goes "on the wire").
    pub publish: Vec<Vec<f32>>,
    /// Per-node mixed result.
    pub mixed: Vec<Vec<f32>>,
}

impl Scratch {
    pub fn new(n: usize, d: usize) -> Scratch {
        Scratch {
            publish: (0..n).map(|_| vec![0.0; d]).collect(),
            mixed: (0..n).map(|_| vec![0.0; d]).collect(),
        }
    }

    /// Resize to `n` nodes (elastic membership resizes, DESIGN.md §9):
    /// surplus buffers drop, new ones allocate zeroed. Contents are
    /// per-round transient, so nothing needs migrating.
    pub fn resize(&mut self, n: usize, d: usize) {
        self.publish.resize_with(n, || vec![0.0; d]);
        self.mixed.resize_with(n, || vec![0.0; d]);
    }
}

/// Communication pattern of one round, consumed by the Fig. 6 cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CommPattern {
    /// `payloads` neighbor exchanges of the full parameter vector.
    Neighbor { payloads: usize },
    /// One global all-reduce of the parameter-sized vector.
    AllReduce,
    /// Neighbor exchange every step + an all-reduce every `period` steps.
    NeighborPlusPeriodicAllReduce { payloads: usize, period: usize },
}

/// A decentralized optimizer: one synchronous round at a time.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;
    /// Number of auxiliary D-sized buffers each node needs.
    fn aux_count(&self) -> usize {
        0
    }
    /// State-export schema: labels of the aux buffers in
    /// `NodeState::aux` order (exactly `aux_count()` entries). The
    /// snapshot writer records them and resume validates the layout, so
    /// a checkpoint can never be silently reinterpreted by an optimizer
    /// with a different aux meaning (DESIGN.md §9). Every optimizer
    /// declares this explicitly — an empty slice is the statement that
    /// its complete per-node state is `(x, m)`.
    fn aux_labels(&self) -> &'static [&'static str];
    /// Initialize the optimizer buffers of a freshly joined node whose
    /// params were just warm-started from its neighbors (elastic
    /// membership, DESIGN.md §9). Default: momentum and every aux
    /// buffer zeroed. Optimizers whose aux anchors on the iterate
    /// override (SlowMo's anchor, D²'s previous iterate) — a zero
    /// anchor there would fling the joiner toward the origin.
    fn warm_start(&self, st: &mut NodeState) {
        st.m.iter_mut().for_each(|v| *v = 0.0);
        for a in st.aux.iter_mut() {
            a.iter_mut().for_each(|v| *v = 0.0);
        }
    }
    fn comm_pattern(&self) -> CommPattern;
    /// Execute one round: update every node's state in place given the
    /// per-node gradients of this iteration.
    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    );
}

/// mixed[i] = Σ_{j ∈ N(i)} w_ij · src[j] — the partial-averaging
/// primitive (paper eq. (3)). Reads only the sparse neighbor row of
/// whatever engine backs `comm`; terms are fused pairwise
/// (`math::weighted_sum_into`) to halve destination traffic on this
/// memory-bound loop.
pub fn partial_average_all(comm: &dyn CommEngine, src: &[Vec<f32>], dst: &mut [Vec<f32>]) {
    for (i, row) in dst.iter_mut().enumerate() {
        comm.mix_node(i, src, row);
    }
}

/// [`partial_average_all`] fanned out over the node executor —
/// destination rows are independent, so the arithmetic (and result) is
/// identical to the serial version.
pub fn partial_average_all_par(
    comm: &dyn CommEngine,
    src: &[Vec<f32>],
    dst: &mut [Vec<f32>],
    exec: &NodeExecutor,
) {
    exec.for_each_mut(dst, |i, row| comm.mix_node(i, src, row));
}

/// THE gossip wire primitive: one neighbor exchange of `src` under the
/// round's comm engine, through the configured payload codec when one
/// is set. Each node's publish buffer is encoded exactly once (its
/// error-feedback residual updated in the same pass) and the mix reads
/// the shared decoded wire view — value-identical to decoding per edge,
/// since decode is deterministic and a sender broadcasts one payload to
/// all its neighbors. Identity codecs (fp32) skip the wire copy
/// entirely, so they are bitwise identical to the pre-codec path, and
/// the mix fan-out stays per-row independent: parallel == serial holds
/// for every codec.
///
/// The engine's [`CommEngine::begin_exchange`] hook fires once per
/// exchange with the exact view the mix reads — the async
/// bounded-staleness engine records its per-slot payload history there
/// (encoded wire bytes under a lossy codec, so staleness composes with
/// compression); plain engines ignore it.
pub fn gossip_exchange(ctx: &RoundCtx, src: &[Vec<f32>], dst: &mut [Vec<f32>]) {
    // Timed spans only exist when a profiler clock is attached, so the
    // unprofiled path takes zero clock reads.
    let exchange = |wire: &[Vec<f32>], dst: &mut [Vec<f32>]| {
        let t = ctx.clock.map(|_| crate::util::bench::WallTimer::start());
        ctx.comm.begin_exchange(wire);
        partial_average_all_par(ctx.comm, wire, dst, &ctx.exec);
        if let (Some(clock), Some(t)) = (ctx.clock, t) {
            clock.add_exchange(t.elapsed_ns());
        }
    };
    match ctx.codec {
        Some(codec) => {
            let mut state = codec.lock().unwrap();
            if state.is_identity() {
                drop(state);
                exchange(src, dst);
            } else {
                let t = ctx.clock.map(|_| crate::util::bench::WallTimer::start());
                let wire = state.encode_round(src, &ctx.exec);
                if let (Some(clock), Some(t)) = (ctx.clock, t) {
                    clock.add_encode(t.elapsed_ns());
                }
                exchange(wire, dst);
            }
        }
        None => exchange(src, dst),
    }
}

/// Global average into every destination row (the All-Reduce primitive).
pub fn global_average(src: &[Vec<f32>], dst: &mut [Vec<f32>]) {
    let n = src.len();
    let d = src[0].len();
    // Average once, then broadcast.
    let mut mean = vec![0.0f32; d];
    for row in src {
        math::axpy(&mut mean, 1.0, row);
    }
    math::scale(&mut mean, 1.0 / n as f32);
    for row in dst.iter_mut() {
        row.copy_from_slice(&mean);
    }
}

/// Construct an optimizer by config name.
pub fn build(
    name: &str,
    slowmo_period: usize,
    slowmo_beta: f64,
) -> anyhow::Result<Box<dyn Optimizer>> {
    Ok(match name {
        "dsgd" => Box::new(dsgd::Dsgd),
        "dmsgd" => Box::new(dmsgd::Dmsgd),
        "decentlam" => Box::new(decentlam::DecentLam::default()),
        "pmsgd" => Box::new(pmsgd::Pmsgd::plain()),
        "pmsgd-lars" => Box::new(pmsgd::Pmsgd::lars()),
        "da-dmsgd" => Box::new(da_dmsgd::DaDmsgd),
        "awc-dmsgd" => Box::new(awc_dmsgd::AwcDmsgd),
        "slowmo" => Box::new(slowmo::SlowMo::new(slowmo_period, slowmo_beta as f32)),
        "qg-dmsgd" => Box::new(qg_dmsgd::QgDmsgd),
        "d2-dmsgd" => Box::new(d2_dmsgd::D2Dmsgd),
        other => bail!("unknown optimizer `{other}`"),
    })
}

/// All optimizer names, in the paper's Table 3 row order.
pub const ALL: [&str; 9] = [
    "pmsgd",
    "pmsgd-lars",
    "dmsgd",
    "da-dmsgd",
    "awc-dmsgd",
    "slowmo",
    "qg-dmsgd",
    "d2-dmsgd",
    "decentlam",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{metropolis_hastings, Kind, Topology};

    #[test]
    fn partial_average_preserves_consensus() {
        let wm = metropolis_hastings(&Topology::build(Kind::Ring, 4));
        let src = vec![vec![2.0f32, -1.0]; 4];
        let mut dst = vec![vec![0.0f32; 2]; 4];
        partial_average_all(&wm, &src, &mut dst);
        for row in &dst {
            assert!((row[0] - 2.0).abs() < 1e-6 && (row[1] + 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn partial_average_preserves_mean() {
        // W doubly stochastic => the network average is invariant.
        let wm = metropolis_hastings(&Topology::build(Kind::SymExp, 8));
        let src: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32, -(i as f32)]).collect();
        let mut dst = vec![vec![0.0f32; 2]; 8];
        partial_average_all(&wm, &src, &mut dst);
        let mean_before: f32 = src.iter().map(|r| r[0]).sum::<f32>() / 8.0;
        let mean_after: f32 = dst.iter().map(|r| r[0]).sum::<f32>() / 8.0;
        assert!((mean_before - mean_after).abs() < 1e-5);
    }

    #[test]
    fn global_average_exact() {
        let src = vec![vec![1.0f32], vec![3.0f32]];
        let mut dst = vec![vec![0.0f32]; 2];
        global_average(&src, &mut dst);
        assert_eq!(dst, vec![vec![2.0], vec![2.0]]);
    }

    #[test]
    fn factory_builds_all() {
        for name in ALL {
            let o = build(name, 12, 0.7).unwrap();
            assert_eq!(o.name(), name);
        }
        assert!(build("adamw", 0, 0.0).is_err());
    }

    #[test]
    fn aux_labels_match_aux_counts() {
        // The state-export schema must name exactly the aux buffers a
        // node carries — the snapshot layout check depends on it.
        for name in ALL.iter().chain([&"dsgd"]) {
            let o = build(name, 12, 0.7).unwrap();
            assert_eq!(
                o.aux_labels().len(),
                o.aux_count(),
                "{name}: aux_labels/aux_count mismatch"
            );
        }
    }

    #[test]
    fn warm_start_zeroes_momentum_and_anchors_on_x() {
        for name in ALL.iter().chain([&"dsgd"]) {
            let o = build(name, 12, 0.7).unwrap();
            let mut st = NodeState::new(vec![1.5f32, -2.0, 0.5], o.aux_count());
            st.m = vec![9.0; 3];
            for a in st.aux.iter_mut() {
                a.copy_from_slice(&[7.0, 7.0, 7.0]);
            }
            o.warm_start(&mut st);
            assert_eq!(st.x, vec![1.5, -2.0, 0.5], "{name}: warm_start must not touch x");
            assert!(st.m.iter().all(|&v| v == 0.0), "{name}: momentum not zeroed");
            match *name {
                "slowmo" => {
                    assert!(st.aux[0].iter().all(|&v| v == 0.0));
                    assert_eq!(st.aux[1], st.x, "slowmo anchor must be x");
                }
                "d2-dmsgd" => {
                    assert_eq!(st.aux[0], st.x, "d2 x_prev must be x");
                    assert!(st.aux[1].iter().all(|&v| v == 0.0));
                }
                _ => {
                    assert!(st.aux.iter().all(|a| a.iter().all(|&v| v == 0.0)), "{name}");
                }
            }
        }
    }
}
