//! PmSGD — Parallel momentum SGD (the DDP/All-Reduce baseline) and its
//! LARS variant (You et al. 2017), the paper's large-batch reference.
//!
//! All nodes all-reduce their gradients, then run identical heavy-ball
//! steps; with LARS the update is rescaled per layer by the trust ratio
//! η‖x_l‖ / (‖g_l‖ + wd·‖x_l‖). Weight decay is folded into LARS as in
//! the original paper.

use crate::util::math;

use super::{CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

pub struct Pmsgd {
    lars: bool,
    /// LARS trust coefficient η.
    pub trust: f32,
    /// Weight decay used inside the trust ratio.
    pub weight_decay: f32,
}

impl Pmsgd {
    pub fn plain() -> Pmsgd {
        Pmsgd { lars: false, trust: 0.0, weight_decay: 0.0 }
    }

    pub fn lars() -> Pmsgd {
        Pmsgd { lars: true, trust: 0.02, weight_decay: 1e-4 }
    }
}

impl Optimizer for Pmsgd {
    fn name(&self) -> &'static str {
        if self.lars {
            "pmsgd-lars"
        } else {
            "pmsgd"
        }
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        // Complete per-node state is (x, m) for both the plain and the
        // LARS variant (trust ratios are recomputed per round).
        &[]
    }

    fn comm_pattern(&self) -> CommPattern {
        CommPattern::AllReduce
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        let n = states.len();
        let d = states[0].x.len();
        // All-reduce: global mean gradient (reuse mixed[0] as the buffer).
        let gbar = &mut scratch.mixed[0];
        gbar.iter_mut().for_each(|v| *v = 0.0);
        for g in grads {
            math::axpy(gbar, 1.0, g);
        }
        math::scale(gbar, 1.0 / n as f32);

        // LARS layer scaling on the mean gradient.
        let scaled = &mut scratch.publish[0];
        scaled.copy_from_slice(gbar);
        if self.lars {
            let whole = [(0usize, d)];
            let ranges: &[(usize, usize)] = if ctx.layer_ranges.is_empty() {
                &whole
            } else {
                ctx.layer_ranges
            };
            // Trust ratio from node 0's params (all nodes are identical).
            let x = &states[0].x;
            for &(s, e) in ranges {
                let wn = math::norm2(&x[s..e]) as f32;
                let gn = math::norm2(&scaled[s..e]) as f32;
                if wn > 0.0 && gn > 0.0 {
                    let ratio = self.trust * wn / (gn + self.weight_decay * wn);
                    for (v, &xv) in scaled[s..e].iter_mut().zip(&x[s..e]) {
                        *v = ratio * (*v + self.weight_decay * xv);
                    }
                }
            }
        }

        // Identical heavy-ball step on every node (parallel over nodes;
        // `scaled` is read-only from here on).
        let scaled_ro: &[f32] = scaled;
        ctx.exec.for_each_mut(states, |_i, st| {
            math::axpby(&mut st.m, 1.0, scaled_ro, ctx.beta);
            math::axpy(&mut st.x, -ctx.lr, &st.m);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::WeightMatrix;

    fn ctx<'a>(wm: &'a WeightMatrix, ranges: &'a [(usize, usize)]) -> RoundCtx<'a> {
        RoundCtx { layer_ranges: ranges, ..RoundCtx::new(wm, 0.1, 0.9, 0, false) }
    }

    #[test]
    fn nodes_stay_identical() {
        let wm = WeightMatrix::global_average(4);
        let d = 6;
        let mut states: Vec<NodeState> =
            (0..4).map(|_| NodeState::new(vec![1.0; d], 0)).collect();
        let grads: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32 * 0.1; d]).collect();
        let mut scratch = Scratch::new(4, d);
        let mut o = Pmsgd::plain();
        for _ in 0..3 {
            o.round(&mut states, &grads, &ctx(&wm, &[]), &mut scratch);
        }
        for st in &states[1..] {
            assert_eq!(st.x, states[0].x);
        }
        // x moved by -lr * (m1 + m2 + m3) with gbar = 0.15
        assert!(states[0].x[0] < 1.0);
    }

    #[test]
    fn plain_matches_hand_heavy_ball() {
        let wm = WeightMatrix::global_average(2);
        let mut states: Vec<NodeState> =
            (0..2).map(|_| NodeState::new(vec![0.0], 0)).collect();
        let grads = vec![vec![1.0f32], vec![3.0f32]]; // mean 2
        let mut scratch = Scratch::new(2, 1);
        let mut o = Pmsgd::plain();
        let c = RoundCtx::new(&wm, 0.1, 0.5, 0, false);
        o.round(&mut states, &grads, &c, &mut scratch);
        // m=2, x=-0.2
        assert!((states[0].m[0] - 2.0).abs() < 1e-6);
        assert!((states[0].x[0] + 0.2).abs() < 1e-6);
        o.round(&mut states, &grads, &c, &mut scratch);
        // m=3, x=-0.5
        assert!((states[0].m[0] - 3.0).abs() < 1e-6);
        assert!((states[0].x[0] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn lars_normalizes_layer_scale() {
        // Two layers with wildly different gradient scales: LARS equalizes
        // the relative update magnitude.
        let wm = WeightMatrix::global_average(2);
        let d = 8;
        static RANGES: [(usize, usize); 2] = [(0, 4), (4, 8)];
        let mut states: Vec<NodeState> =
            (0..2).map(|_| NodeState::new(vec![1.0; d], 0)).collect();
        let mut g = vec![0.0f32; d];
        for v in g[0..4].iter_mut() {
            *v = 1000.0;
        }
        for v in g[4..8].iter_mut() {
            *v = 0.001;
        }
        let grads = vec![g.clone(), g];
        let mut scratch = Scratch::new(2, d);
        let mut o = Pmsgd::lars();
        let c = RoundCtx { layer_ranges: &RANGES, ..RoundCtx::new(&wm, 1.0, 0.0, 0, false) };
        o.round(&mut states, &grads, &c, &mut scratch);
        let d0 = (1.0 - states[0].x[0]).abs();
        let d1 = (1.0 - states[0].x[4]).abs();
        assert!(d0 > 0.0 && d1 > 0.0);
        let ratio = d0 / d1;
        assert!(
            (0.5..2.0).contains(&ratio),
            "LARS should equalize layer update scale, ratio={ratio}"
        );
    }
}
