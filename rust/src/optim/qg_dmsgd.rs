//! QG-DmSGD (Lin et al. 2021) — quasi-global momentum, heavy-ball
//! variant (the paper's §7 baseline). Instead of momentum on the local
//! stochastic gradient, each node maintains a momentum estimate of the
//! *global* optimization direction, approximated by its own iterate
//! displacement:
//!
//!   z_i   = x_i − γ (g_i + β m̂_i)             (local update w/ QG mom.)
//!   x_i⁺  = Σ_j w_ij z_j                       (partial averaging)
//!   m̂_i  ← β m̂_i + (1−β)(x_i − x_i⁺)/γ        (quasi-global momentum)
//!
//! Aux buffer [0] holds m̂ (we keep `NodeState::m` as its storage — no
//! aux needed).

use super::{gossip_exchange, CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

pub struct QgDmsgd;

impl Optimizer for QgDmsgd {
    fn name(&self) -> &'static str {
        "qg-dmsgd"
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        // Complete per-node state is (x, m̂) — the quasi-global
        // momentum lives in `NodeState::m`; no aux buffers.
        &[]
    }

    fn comm_pattern(&self) -> CommPattern {
        CommPattern::Neighbor { payloads: 1 }
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        let states_ro: &[NodeState] = states;
        ctx.exec.for_each_mut(&mut scratch.publish, |i, z| {
            let st = &states_ro[i];
            for (((zi, &xi), &gi), &mi) in z.iter_mut().zip(&st.x).zip(&grads[i]).zip(&st.m) {
                *zi = xi - ctx.lr * (gi + ctx.beta * mi);
            }
        });
        gossip_exchange(ctx, &scratch.publish, &mut scratch.mixed);
        let inv_gamma = 1.0 / ctx.lr.max(1e-12);
        let mixed = &scratch.mixed;
        ctx.exec.for_each_mut(states, |i, st| {
            for ((mi, xi), &newx) in st.m.iter_mut().zip(st.x.iter_mut()).zip(&mixed[i]) {
                let disp = (*xi - newx) * inv_gamma;
                *mi = ctx.beta * *mi + (1.0 - ctx.beta) * disp;
                *xi = newx;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::dsgd::tests::setup;
    use super::*;

    #[test]
    fn consensus_zero_grad_is_fixed_point() {
        let (wm, _, mut scratch) = setup(4, 1);
        let mut states: Vec<NodeState> =
            (0..4).map(|_| NodeState::new(vec![3.0], 0)).collect();
        let grads = vec![vec![0.0f32]; 4];
        let ctx = RoundCtx::new(&wm, 0.1, 0.9, 0, false);
        QgDmsgd.round(&mut states, &grads, &ctx, &mut scratch);
        for st in &states {
            assert!((st.x[0] - 3.0).abs() < 1e-6);
            assert!(st.m[0].abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_fixed_point_is_g_over_one_minus_beta() {
        // With homogeneous gradient g at consensus: disp/γ = g + β m̂, so
        // the fixed point solves m(1−β)² = (1−β)g, i.e. m* = g/(1−β) —
        // the heavy-ball momentum magnitude, as QG intends.
        let (wm, _, mut scratch) = setup(4, 1);
        let mut states: Vec<NodeState> =
            (0..4).map(|_| NodeState::new(vec![0.0], 0)).collect();
        let grads = vec![vec![2.0f32]; 4];
        let ctx = RoundCtx::new(&wm, 0.1, 0.5, 0, false);
        let mut o = QgDmsgd;
        for _ in 0..60 {
            o.round(&mut states, &grads, &ctx, &mut scratch);
        }
        for st in &states {
            assert!((st.m[0] - 4.0).abs() < 0.05, "m̂ ≈ g/(1−β), got {}", st.m[0]);
        }
    }
}
