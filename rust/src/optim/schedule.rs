//! Gradient-accumulation helper for the large-batch engine.
//!
//! The paper varies TOTAL batch from 2K to 32K; we realize B_total as
//! n nodes × accumulation × micro-batch with static-shape PJRT
//! artifacts (DESIGN.md §2). This module owns that arithmetic plus the
//! accumulator buffer so the grad engines stay allocation-free.

use crate::util::math;

/// Accumulates micro-batch gradients into a running mean.
#[derive(Debug, Clone)]
pub struct GradAccumulator {
    sum: Vec<f32>,
    count: usize,
}

impl GradAccumulator {
    pub fn new(d: usize) -> GradAccumulator {
        GradAccumulator { sum: vec![0.0; d], count: 0 }
    }

    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|v| *v = 0.0);
        self.count = 0;
    }

    pub fn add(&mut self, grad: &[f32]) {
        // A mis-sized gradient must fail loudly: axpy's zip (and the
        // copy loop in mean_into) would silently truncate to the
        // shorter length and corrupt the mean in release builds.
        assert_eq!(
            grad.len(),
            self.sum.len(),
            "gradient dim {} != accumulator dim {}",
            grad.len(),
            self.sum.len()
        );
        math::axpy(&mut self.sum, 1.0, grad);
        self.count += 1;
    }

    /// Mean gradient over the accumulated micro-batches, written into `out`.
    pub fn mean_into(&self, out: &mut [f32]) {
        assert!(self.count > 0, "no micro-batches accumulated");
        assert_eq!(
            out.len(),
            self.sum.len(),
            "output dim {} != accumulator dim {}",
            out.len(),
            self.sum.len()
        );
        let inv = 1.0 / self.count as f32;
        for (o, &s) in out.iter_mut().zip(&self.sum) {
            *o = s * inv;
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_two() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&[1.0, 2.0]);
        acc.add(&[3.0, 4.0]);
        let mut out = vec![0.0; 2];
        acc.mean_into(&mut out);
        assert_eq!(out, vec![2.0, 3.0]);
        assert_eq!(acc.count(), 2);
        acc.reset();
        assert_eq!(acc.count(), 0);
    }

    #[test]
    #[should_panic]
    fn empty_mean_panics() {
        let acc = GradAccumulator::new(1);
        let mut out = vec![0.0];
        acc.mean_into(&mut out);
    }

    #[test]
    #[should_panic(expected = "gradient dim")]
    fn short_gradient_panics_instead_of_truncating() {
        let mut acc = GradAccumulator::new(3);
        acc.add(&[1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "gradient dim")]
    fn long_gradient_panics_instead_of_truncating() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "output dim")]
    fn mismatched_mean_output_panics() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&[1.0, 2.0]);
        let mut out = vec![0.0; 3];
        acc.mean_into(&mut out);
    }
}
