//! SlowMo (Wang et al. 2019) — slow momentum over a decentralized base
//! optimizer. Inner loop: plain DmSGD rounds. Every `period` steps the
//! nodes exact-average (all-reduce) and take a *slow* heavy-ball step in
//! the averaged iterate:
//!
//!   u ← β_slow · u + (anchor − x̄)/γ_eff
//!   x ← anchor − α_slow · γ_eff · u ;  anchor ← x
//!
//! with γ_eff the base LR at the sync step and α_slow = 1 (the paper's
//! default). Aux buffers: [0] slow momentum u, [1] anchor.

use super::{dmsgd::Dmsgd, CommPattern, NodeState, Optimizer, RoundCtx, Scratch};

pub struct SlowMo {
    base: Dmsgd,
    period: usize,
    slow_beta: f32,
    alpha: f32,
}

impl SlowMo {
    pub fn new(period: usize, slow_beta: f32) -> SlowMo {
        SlowMo { base: Dmsgd, period: period.max(1), slow_beta, alpha: 1.0 }
    }
}

impl Optimizer for SlowMo {
    fn name(&self) -> &'static str {
        "slowmo"
    }

    fn aux_count(&self) -> usize {
        2 // [u, anchor]
    }

    fn aux_labels(&self) -> &'static [&'static str] {
        &["slow_momentum", "anchor"]
    }

    fn warm_start(&self, st: &mut NodeState) {
        // A joiner starts a fresh slow cycle: fast momentum and slow
        // momentum u at zero, anchor at the warm-started iterate (the
        // default zero anchor would make the next sync step pull the
        // joiner toward the origin via (anchor − x̄)/γ).
        st.m.iter_mut().for_each(|v| *v = 0.0);
        st.aux[0].iter_mut().for_each(|v| *v = 0.0);
        st.aux[1].copy_from_slice(&st.x);
    }

    fn comm_pattern(&self) -> CommPattern {
        CommPattern::NeighborPlusPeriodicAllReduce { payloads: 1, period: self.period }
    }

    fn round(
        &mut self,
        states: &mut [NodeState],
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
        scratch: &mut Scratch,
    ) {
        let d = states[0].x.len();
        if ctx.step == 0 {
            for st in states.iter_mut() {
                let x = st.x.clone();
                st.aux[1].copy_from_slice(&x); // anchor = x_0
            }
        }
        self.base.round(states, grads, ctx, scratch);

        if (ctx.step + 1) % self.period == 0 {
            // Exact average of models (the periodic synchronization).
            let xs: Vec<Vec<f32>> = states.iter().map(|s| s.x.clone()).collect();
            super::global_average(&xs, &mut scratch.mixed);
            let xbar = scratch.mixed[0].clone();
            let gamma = ctx.lr.max(1e-8);
            let (slow_beta, alpha) = (self.slow_beta, self.alpha);
            ctx.exec.for_each_mut(states, |_i, st| {
                for k in 0..d {
                    let u = slow_beta * st.aux[0][k] + (st.aux[1][k] - xbar[k]) / gamma;
                    st.aux[0][k] = u;
                    let xk = st.aux[1][k] - alpha * gamma * u;
                    st.x[k] = xk;
                    st.aux[1][k] = xk; // new anchor
                }
                // Reset the fast momentum at sync (per the SlowMo paper's
                // base-optimizer buffer reset variant).
                st.m.iter_mut().for_each(|v| *v = 0.0);
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::dsgd::tests::setup;
    use super::*;

    #[test]
    fn sync_step_brings_exact_consensus() {
        let (wm, _, mut scratch) = setup(4, 2);
        let mut states: Vec<NodeState> =
            (0..4).map(|i| NodeState::new(vec![i as f32; 2], 2)).collect();
        let grads = vec![vec![0.0f32; 2]; 4];
        let mut o = SlowMo::new(2, 0.5);
        for step in 0..2 {
            let ctx = RoundCtx::new(&wm, 0.1, 0.9, step, false);
            o.round(&mut states, &grads, &ctx, &mut scratch);
        }
        // After the sync at step 1 (period 2), all nodes share x exactly.
        for st in &states[1..] {
            assert_eq!(st.x, states[0].x);
        }
    }

    #[test]
    fn slow_momentum_zero_when_already_consensus() {
        let (wm, _, mut scratch) = setup(4, 1);
        let mut states: Vec<NodeState> =
            (0..4).map(|_| NodeState::new(vec![5.0], 2)).collect();
        let grads = vec![vec![0.0f32]; 4];
        let mut o = SlowMo::new(1, 0.5);
        let ctx = RoundCtx::new(&wm, 0.1, 0.9, 0, false);
        o.round(&mut states, &grads, &ctx, &mut scratch);
        for st in &states {
            assert!((st.x[0] - 5.0).abs() < 1e-6);
            assert!(st.aux[0][0].abs() < 1e-6, "u stays zero at consensus");
        }
    }
}
