//! Mini property-based testing harness (the offline registry has no
//! `proptest`). Seeded generators + a runner that, on failure, reports
//! the failing seed/case and retries a deterministic shrink ladder of
//! "smaller" cases drawn from the same seed.
//!
//! Usage:
//! ```no_run
//! use decentlam::prop::{check, Gen};
//! use decentlam::util::rng::Pcg64;
//! check("sum is commutative", 100, |rng| {
//!     (rng.f32(), rng.f32())
//! }, |&(a, b)| {
//!     if (a + b - (b + a)).abs() < 1e-6 { Ok(()) } else { Err("order".into()) }
//! });
//! ```

use crate::util::rng::Pcg64;

/// Generator = any closure from RNG to a case.
pub trait Gen<T>: Fn(&mut Pcg64) -> T {}
impl<T, F: Fn(&mut Pcg64) -> T> Gen<T> for F {}

/// Run `prop` on `cases` generated inputs; panic with diagnostics on the
/// first failure. The base seed can be pinned via DECENTLAM_PROP_SEED to
/// replay a failure.
pub fn check<T: std::fmt::Debug, G: Gen<T>, P: Fn(&T) -> Result<(), String>>(
    name: &str,
    cases: usize,
    gen: G,
    prop: P,
) {
    let base_seed: u64 = std::env::var("DECENTLAM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xdec0_51a1);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed}):\n  \
                 reason: {msg}\n  input: {input:?}\n  \
                 replay with DECENTLAM_PROP_SEED={base_seed}"
            );
        }
    }
}

/// Common generators.
pub mod gens {
    use crate::util::rng::Pcg64;

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(rng: &mut Pcg64, lo: f32, hi: f32) -> f32 {
        lo + rng.f32() * (hi - lo)
    }

    /// A vector of standard normals.
    pub fn normal_vec(rng: &mut Pcg64, d: usize) -> Vec<f32> {
        let mut v = vec![0.0; d];
        rng.normal_fill(&mut v, 1.0);
        v
    }

    /// A stochastic weight row of length k (non-negative, sums to 1).
    pub fn stochastic_row(rng: &mut Pcg64, k: usize) -> Vec<f32> {
        let mut w: Vec<f32> = (0..k).map(|_| rng.f32() + 0.05).collect();
        let s: f32 = w.iter().sum();
        for x in w.iter_mut() {
            *x /= s;
        }
        w
    }

    /// A dimension drawn from a size ladder (mixes tiny + realistic).
    pub fn dim(rng: &mut Pcg64) -> usize {
        const LADDER: [usize; 8] = [1, 2, 3, 7, 16, 65, 256, 1000];
        LADDER[rng.below(LADDER.len())]
    }

    /// Node count in 2..=16.
    pub fn nodes(rng: &mut Pcg64) -> usize {
        2 + rng.below(15)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |r| (r.f32(), r.f32()), |&(a, b)| {
            if (a + b - (b + a)).abs() < 1e-6 {
                Ok(())
            } else {
                Err("no".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_diagnostics() {
        check("always-fails", 5, |r| r.f32(), |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..100 {
            let x = gens::f32_in(&mut rng, -2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
            let w = gens::stochastic_row(&mut rng, 5);
            assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-5);
            let n = gens::nodes(&mut rng);
            assert!((2..=16).contains(&n));
        }
    }
}
