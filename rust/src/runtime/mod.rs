//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! Layer-3 hot path.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), while the
//! coordinator wants engine handles it can move across node contexts.
//! We therefore run ONE runtime thread that owns the client and every
//! compiled executable; [`RuntimeHandle`] (cheaply cloneable, `Send`)
//! submits execute requests over a channel and blocks on the reply.
//! XLA's CPU backend multithreads each execution internally, so the
//! single service thread does not serialize away parallelism.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥0.5
//! serialized protos use 64-bit ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub raw: Value,
}

/// Metadata for one model in the manifest.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub kind: String,
    pub dim: usize,
    pub micro_batch: usize,
    pub init_file: String,
    pub layer_ranges: Vec<(usize, usize)>,
    pub input_dim: usize,
    pub num_classes: usize,
    pub eval_batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Ok(Manifest { dir: dir.to_path_buf(), raw: Value::parse(&text)? })
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .raw
            .get("artifacts")?
            .get(name)
            .map_err(|_| anyhow!("artifact `{name}` not in manifest"))?
            .get("file")?
            .as_str()?
            .to_string();
        Ok(self.dir.join(file))
    }

    pub fn model(&self, name: &str) -> Result<ModelInfo> {
        let m = self
            .raw
            .get("models")?
            .get(name)
            .map_err(|_| anyhow!("model `{name}` not in manifest"))?;
        let ranges = m
            .get("layer_ranges")?
            .as_arr()?
            .iter()
            .map(|p| {
                let pair = p.as_arr()?;
                Ok((pair[0].as_usize()?, pair[1].as_usize()?))
            })
            .collect::<Result<Vec<_>>>()?;
        let get_us = |k: &str| -> usize { m.opt(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as usize };
        Ok(ModelInfo {
            name: name.to_string(),
            kind: m.get("kind")?.as_str()?.to_string(),
            dim: m.get("dim")?.as_usize()?,
            micro_batch: get_us("micro_batch"),
            init_file: m.get("init")?.as_str()?.to_string(),
            layer_ranges: ranges,
            input_dim: get_us("input_dim"),
            num_classes: get_us("num_classes"),
            eval_batch: get_us("eval_batch"),
            seq_len: get_us("seq_len"),
            vocab: get_us("vocab"),
        })
    }

    /// Load a model's initial flat parameters (little-endian f32 .bin).
    pub fn load_init(&self, info: &ModelInfo) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.dir.join(&info.init_file))?;
        if bytes.len() != info.dim * 4 {
            bail!(
                "init file {} has {} bytes, expected {}",
                info.init_file,
                bytes.len(),
                info.dim * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// The decentlam-update kernel artifact name for a given dim, if any.
    pub fn update_kernel_for_dim(&self, dim: usize) -> Option<String> {
        let name = format!("decentlam_update_{dim}");
        self.raw.opt("kernels").and_then(|k| k.opt(&name)).map(|_| name)
    }
}

/// One input tensor for an execute request.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[i64]) -> Tensor {
        debug_assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Tensor::F32 { data, shape: shape.to_vec() }
    }

    pub fn i32(data: Vec<i32>, shape: &[i64]) -> Tensor {
        debug_assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Tensor::I32 { data, shape: shape.to_vec() }
    }
}

enum Request {
    /// Compile the artifact at `path` under key `name` (idempotent).
    Load { name: String, path: PathBuf, reply: mpsc::Sender<Result<()>> },
    /// Execute artifact `name`; reply with the flattened f32 outputs.
    Exec { name: String, inputs: Vec<Tensor>, reply: mpsc::Sender<Result<Vec<Vec<f32>>>> },
    Shutdown,
}

/// Cloneable, `Send` handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Request>,
}

/// Owns the service thread; dropping shuts the runtime down.
pub struct Runtime {
    handle: RuntimeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Runtime {
    /// Start the PJRT CPU service thread.
    pub fn start() -> Result<Runtime> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            // lint:allow(D04): one service thread, fed by one mpsc channel in send order
            .spawn(move || service_loop(rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during startup"))??;
        Ok(Runtime { handle: RuntimeHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    /// Compile an HLO-text artifact under `name` (no-op if loaded).
    pub fn load(&self, name: &str, path: &Path) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Load { name: name.to_string(), path: path.to_path_buf(), reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread gone"))?
    }

    /// Load every artifact a manifest model needs.
    pub fn load_artifact(&self, manifest: &Manifest, name: &str) -> Result<()> {
        self.load(name, &manifest.artifact_path(name)?)
    }

    /// Execute a loaded artifact. Outputs come back as flat f32 vectors
    /// in artifact output order.
    pub fn exec(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Exec { name: name.to_string(), inputs, reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread gone"))?
    }
}

fn literal_of(t: &Tensor) -> Result<xla::Literal> {
    Ok(match t {
        Tensor::F32 { data, shape } => xla::Literal::vec1(data).reshape(shape)?,
        Tensor::I32 { data, shape } => xla::Literal::vec1(data).reshape(shape)?,
    })
}

fn service_loop(rx: mpsc::Receiver<Request>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu failed: {e}")));
            return;
        }
    };
    // BTreeMap, not HashMap: iteration order never leaks here today,
    // but the determinism lint (D01) bans unordered maps outright so
    // an innocent refactor can't start depending on one.
    let mut execs: BTreeMap<String, xla::PjRtLoadedExecutable> = BTreeMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Load { name, path, reply } => {
                let r = (|| -> Result<()> {
                    if execs.contains_key(&name) {
                        return Ok(());
                    }
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                    )
                    .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| anyhow!("compiling {name}: {e}"))?;
                    execs.insert(name.clone(), exe);
                    Ok(())
                })();
                let _ = reply.send(r);
            }
            Request::Exec { name, inputs, reply } => {
                let r = (|| -> Result<Vec<Vec<f32>>> {
                    let exe = execs
                        .get(&name)
                        .ok_or_else(|| anyhow!("artifact `{name}` not loaded"))?;
                    let lits = inputs
                        .iter()
                        .map(literal_of)
                        .collect::<Result<Vec<_>>>()?;
                    let bufs = exe
                        .execute::<xla::Literal>(&lits)
                        .map_err(|e| anyhow!("executing {name}: {e}"))?;
                    let lit = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("readback {name}: {e}"))?;
                    // aot.py lowers with return_tuple=True: always a tuple.
                    let parts = lit.to_tuple().map_err(|e| anyhow!("tuple: {e}"))?;
                    parts
                        .into_iter()
                        .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}")))
                        .collect()
                })();
                let _ = reply.send(r);
            }
        }
    }
}
