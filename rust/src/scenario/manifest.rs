//! Scenario manifest schema + fail-closed parser.
//!
//! ```json
//! {
//!   "version": "DLSCEN01",
//!   "name": "ring-decentlam-int8",
//!   "description": "int8+EF gossip on a ring descends and replays",
//!   "tier": "smoke",
//!   "config": { ... Config manifest object (util::config) ... },
//!   "expect": {
//!     "eval-loss": {"value": 1.83, "tol": 0.05},
//!     "wire-bytes-per-iter": {"value": 41504.0, "tol": 0.0},
//!     "run-sha256": "replay"
//!   }
//! }
//! ```
//!
//! Rejected-combo scenarios swap `expect` for the EXACT error string
//! the config boundary must produce:
//!
//! ```json
//!   "expect": {"reject": "scenario.config.faults: fault rate `drop=2` outside [0, 1]"}
//! ```
//!
//! The config section itself parses through
//! [`Config::from_manifest`] + [`Config::validate`] — the same
//! fail-closed path `--config` files and the CLI use — so a scenario
//! can never drift from what the trainer actually accepts.

use anyhow::{bail, Context, Result};

use crate::util::config::Config;
use crate::util::json::{Cursor, Value};

use super::MANIFEST_VERSION;

/// Corpus tier: `smoke` runs on every PR, `full` only nightly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Smoke,
    Full,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Smoke => "smoke",
            Tier::Full => "full",
        }
    }
}

/// A pinned scalar expectation: `|actual - value| <= tol`. A pin
/// without `value` asserts only that the run produces a finite number —
/// the authoring state before `run-scenarios --pin` fills values in.
#[derive(Debug, Clone, PartialEq)]
pub struct Pinned {
    pub value: Option<f64>,
    pub tol: f64,
}

/// Bitwise digest pin over the run (manifest bytes + every per-step
/// loss + final accuracy/consensus/eval-loss bits).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShaPin {
    /// Execute the scenario twice and require identical digests — the
    /// self-verifying determinism pin (no stored hex to go stale).
    Replay,
    /// Exact digest, 64 lowercase hex chars (written by `--pin`).
    Hex(String),
}

/// Expected outputs of a runnable scenario.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RunExpect {
    pub eval_loss: Option<Pinned>,
    pub wire_bytes_per_iter: Option<Pinned>,
    pub run_sha256: Option<ShaPin>,
}

/// What the scenario claims: it runs and matches pins, or the config
/// boundary rejects it with exactly this error.
#[derive(Debug, Clone, PartialEq)]
pub enum Expect {
    Run(RunExpect),
    Reject { error: String },
}

/// The config section's parse outcome. Rejection is captured (not
/// propagated) so rejected-combo scenarios can pin the error string.
#[derive(Debug, Clone)]
pub enum ScenarioConfig {
    Valid(Config),
    /// `format!("{e:#}")` of the boundary error — the full context
    /// chain, path-prefixed (e.g. ``scenario.config.faults: fault rate
    /// `drop=2` outside [0, 1]``).
    Rejected(String),
}

/// One parsed scenario manifest.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    pub tier: Tier,
    pub config: ScenarioConfig,
    pub expect: Expect,
}

impl Scenario {
    /// Parse a manifest document, fail-closed. Errors on anything
    /// outside the schema; config-section errors are CAPTURED into
    /// [`ScenarioConfig::Rejected`] (the runner decides whether that
    /// rejection was expected).
    pub fn parse(v: &Value) -> Result<Scenario> {
        let c = Cursor::root(v, "scenario");
        c.deny_unknown(&["version", "name", "description", "tier", "config", "expect"])?;
        let version = c.get("version")?.as_str()?;
        if version != MANIFEST_VERSION {
            bail!(
                "scenario.version: unsupported manifest version `{version}` \
                 (this build reads {MANIFEST_VERSION})"
            );
        }
        let name = c.get("name")?.as_str()?.to_string();
        let description = c.get("description")?.as_str()?.to_string();
        let tier = match c.get("tier")?.as_str()? {
            "smoke" => Tier::Smoke,
            "full" => Tier::Full,
            other => bail!("scenario.tier: unknown tier `{other}` (smoke|full)"),
        };
        let expect = parse_expect(&c.get("expect")?)?;
        let cfg_cursor = c.get("config")?;
        let config = match Config::from_manifest(&cfg_cursor).and_then(|cfg| {
            // Cross-field invariants carry the config path too, so the
            // pinned rejection string localizes the failure.
            cfg.validate().with_context(|| cfg_cursor.path().to_string())?;
            Ok(cfg)
        }) {
            Ok(cfg) => ScenarioConfig::Valid(cfg),
            Err(e) => ScenarioConfig::Rejected(format!("{e:#}")),
        };
        Ok(Scenario { name, description, tier, config, expect })
    }

    /// Parse from manifest text (JSON).
    pub fn parse_str(text: &str) -> Result<Scenario> {
        Scenario::parse(&Value::parse(text)?)
    }
}

fn parse_pinned(x: &Cursor) -> Result<Pinned> {
    x.deny_unknown(&["value", "tol"])?;
    let value = x.opt("value").map(|v| v.as_f64()).transpose()?;
    let tol = x.opt("tol").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0);
    if !(tol >= 0.0) {
        bail!("{}: tolerance {tol} must be >= 0", x.path());
    }
    Ok(Pinned { value, tol })
}

fn parse_expect(x: &Cursor) -> Result<Expect> {
    if x.opt("reject").is_some() {
        x.deny_unknown(&["reject"])?;
        return Ok(Expect::Reject { error: x.get("reject")?.as_str()?.to_string() });
    }
    x.deny_unknown(&["eval-loss", "wire-bytes-per-iter", "run-sha256"])?;
    let run_sha256 = match x.opt("run-sha256") {
        None => None,
        Some(s) => {
            let pin = s.as_str()?;
            if pin == "replay" {
                Some(ShaPin::Replay)
            } else if pin.len() == 64
                && pin.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase())
            {
                Some(ShaPin::Hex(pin.to_string()))
            } else {
                bail!(
                    "{}: expected \"replay\" or 64 lowercase hex chars, got `{pin}`",
                    s.path()
                );
            }
        }
    };
    Ok(Expect::Run(RunExpect {
        eval_loss: x.opt("eval-loss").map(|p| parse_pinned(&p)).transpose()?,
        wire_bytes_per_iter: x
            .opt("wire-bytes-per-iter")
            .map(|p| parse_pinned(&p))
            .transpose()?,
        run_sha256,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal(expect: &str) -> String {
        format!(
            r#"{{
              "version": "DLSCEN01",
              "name": "t",
              "description": "d",
              "tier": "smoke",
              "config": {{"nodes": 4, "topology": "ring", "steps": 10}},
              "expect": {expect}
            }}"#
        )
    }

    #[test]
    fn parses_a_minimal_runnable_scenario() {
        let s = Scenario::parse_str(&minimal(r#"{"run-sha256": "replay"}"#)).unwrap();
        assert_eq!(s.name, "t");
        assert_eq!(s.tier, Tier::Smoke);
        match &s.config {
            ScenarioConfig::Valid(cfg) => {
                assert_eq!(cfg.nodes, 4);
                assert_eq!(cfg.topology, "ring");
                assert_eq!(cfg.steps, 10);
            }
            ScenarioConfig::Rejected(e) => panic!("unexpected rejection: {e}"),
        }
        assert_eq!(
            s.expect,
            Expect::Run(RunExpect { run_sha256: Some(ShaPin::Replay), ..Default::default() })
        );
    }

    #[test]
    fn unknown_fields_are_hard_errors_naming_the_field() {
        let text = minimal(r#"{"run-sha256": "replay"}"#).replace("\"tier\"", "\"teir\"");
        let e = format!("{:#}", Scenario::parse_str(&text).unwrap_err());
        assert_eq!(
            e,
            "scenario: unknown field `teir` \
             (allowed: version, name, description, tier, config, expect)"
        );
        let text = minimal(r#"{"run-sha265": "replay"}"#);
        let e = format!("{:#}", Scenario::parse_str(&text).unwrap_err());
        assert_eq!(
            e,
            "scenario.expect: unknown field `run-sha265` \
             (allowed: eval-loss, wire-bytes-per-iter, run-sha256)"
        );
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let text = minimal(r#"{}"#).replace("DLSCEN01", "DLSCEN02");
        let e = format!("{:#}", Scenario::parse_str(&text).unwrap_err());
        assert_eq!(
            e,
            "scenario.version: unsupported manifest version `DLSCEN02` \
             (this build reads DLSCEN01)"
        );
    }

    #[test]
    fn pins_parse_with_value_tol_and_sha_forms() {
        let s = Scenario::parse_str(&minimal(
            r#"{"eval-loss": {"value": 1.5, "tol": 0.1}, "wire-bytes-per-iter": {"tol": 0.0}}"#,
        ))
        .unwrap();
        let Expect::Run(exp) = &s.expect else { panic!("expected Run") };
        assert_eq!(exp.eval_loss, Some(Pinned { value: Some(1.5), tol: 0.1 }));
        assert_eq!(exp.wire_bytes_per_iter, Some(Pinned { value: None, tol: 0.0 }));
        assert_eq!(exp.run_sha256, None);

        let hex = "a".repeat(64);
        let s =
            Scenario::parse_str(&minimal(&format!(r#"{{"run-sha256": "{hex}"}}"#))).unwrap();
        let Expect::Run(exp) = &s.expect else { panic!("expected Run") };
        assert_eq!(exp.run_sha256, Some(ShaPin::Hex(hex)));

        let e = format!(
            "{:#}",
            Scenario::parse_str(&minimal(r#"{"run-sha256": "DEADBEEF"}"#)).unwrap_err()
        );
        assert_eq!(
            e,
            "scenario.expect.run-sha256: expected \"replay\" or 64 lowercase hex chars, \
             got `DEADBEEF`"
        );
    }

    #[test]
    fn config_errors_are_captured_with_their_path() {
        let text = minimal(r#"{"reject": "x"}"#)
            .replace(r#""topology": "ring""#, r#""topology": "ring", "faults": "drop=2""#);
        let s = Scenario::parse_str(&text).unwrap();
        match &s.config {
            ScenarioConfig::Rejected(e) => assert_eq!(
                e,
                "scenario.config.faults: fault rate `drop=2` outside [0, 1]"
            ),
            ScenarioConfig::Valid(_) => panic!("drop=2 must reject"),
        }
    }

    #[test]
    fn cross_field_invariants_reject_at_parse_time() {
        let text = minimal(r#"{"reject": "x"}"#).replace(
            r#""topology": "ring""#,
            r#""topology": "ring", "churn": "true", "async": "true""#,
        );
        let s = Scenario::parse_str(&text).unwrap();
        match &s.config {
            ScenarioConfig::Rejected(e) => assert_eq!(
                e,
                "scenario.config: --churn models synchronous rounds over an elastic \
                 roster; composing with --async (churn-aware schedules) is an open \
                 item — see ROADMAP.md"
            ),
            ScenarioConfig::Valid(_) => panic!("churn+async must reject"),
        }
    }

    #[test]
    fn reject_expectation_is_exclusive() {
        let e = format!(
            "{:#}",
            Scenario::parse_str(&minimal(r#"{"reject": "x", "run-sha256": "replay"}"#))
                .unwrap_err()
        );
        assert_eq!(e, "scenario.expect: unknown field `run-sha256` (allowed: reject)");
    }
}
