//! Scenario registry (DESIGN.md §10): versioned, fail-closed JSON
//! manifests that pin a complete run — topology × optimizer × faults ×
//! codec × async × churn × lr schedule — TOGETHER with its expected
//! outputs, plus a batch runner over the checked-in `scenarios/`
//! corpus.
//!
//! A scenario manifest is the executable form of a claim this repo
//! makes: "this composition trains to this eval loss, ships this many
//! wire bytes, and replays bit for bit" — or "this composition is
//! rejected with exactly this error". The corpus is the regression
//! surface for cross-subsystem behavior that unit tests cover only
//! piecewise; `decentlam run-scenarios scenarios/` re-verifies every
//! claim and CI gates on it (smoke tier per PR, everything nightly).
//!
//! Fail-closed throughout: an unknown field anywhere in a manifest is a
//! hard parse error naming the offending path ([`crate::util::json::Cursor`]),
//! the `version` field must match [`MANIFEST_VERSION`], and cross-field
//! config invariants ([`crate::util::config::Config::validate`]) are
//! checked at parse time — a rejected-combo scenario pins the EXACT
//! error string, so error-message drift fails the corpus.
//!
//! Module layout: [`manifest`] parses `Scenario` values; [`runner`]
//! executes them against a small fixed synthetic workload and checks
//! the pins ([`Pinned`] tolerances, [`ShaPin`] bitwise digests).

mod manifest;
mod runner;

pub use manifest::{Expect, Pinned, RunExpect, Scenario, ScenarioConfig, ShaPin, Tier};
pub use runner::{
    run_corpus, run_scenario, run_scenario_tee, CorpusSummary, Outcome, RunOpts, Status,
    TierFilter,
};

/// Manifest format version. Bumped on any breaking change to the
/// scenario schema; readers reject every other value ("DL" scenario,
/// revision 01).
pub const MANIFEST_VERSION: &str = "DLSCEN01";
