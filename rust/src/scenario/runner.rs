//! Execute scenarios and check their pins; batch-run a corpus
//! directory with a summary table + JSON artifact.
//!
//! Every scenario runs the SAME tiny synthetic workload family (96
//! samples/node, 256 eval samples, the config's Dirichlet alpha and
//! seed) so pinned numbers depend only on the manifest — and stay fast
//! enough for the smoke tier to run inside debug-build `cargo test`.
//! `native-*` model names map to `mlp-xs` here; corpus manifests name
//! an `mlp-*` arch explicitly.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::Trainer;
use crate::data::synth::{ClassificationData, SynthSpec};
use crate::grad::mlp;
use crate::util::config::Config;
use crate::util::json::Value;
use crate::util::sha256::Sha256;
use crate::util::table::Table;

use super::{Expect, Pinned, Scenario, ScenarioConfig, ShaPin, Tier, MANIFEST_VERSION};

/// Which tiers a corpus run admits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierFilter {
    Smoke,
    Full,
    All,
}

impl TierFilter {
    pub fn parse(s: &str) -> Result<TierFilter> {
        match s {
            "smoke" => Ok(TierFilter::Smoke),
            "full" => Ok(TierFilter::Full),
            "all" => Ok(TierFilter::All),
            other => bail!("unknown tier filter `{other}` (smoke|full|all)"),
        }
    }

    fn admits(self, tier: Tier) -> bool {
        match self {
            TierFilter::All => true,
            TierFilter::Smoke => tier == Tier::Smoke,
            TierFilter::Full => tier == Tier::Full,
        }
    }
}

/// Corpus-run options.
pub struct RunOpts {
    pub tier: TierFilter,
    /// Only scenarios whose name contains this substring.
    pub filter: Option<String>,
    /// Rewrite each executed manifest with its measured pins (fills
    /// `value` fields and hex digests; updates `reject` strings).
    pub pin: bool,
    /// Tee each executed scenario's telemetry stream to
    /// `<dir>/<name>.jsonl` and verify the offline replay reconstructs
    /// the live report exactly (DESIGN.md §11).
    pub telemetry: Option<PathBuf>,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts { tier: TierFilter::All, filter: None, pin: false, telemetry: None }
    }
}

/// One scenario's verdict.
#[derive(Debug, Clone, PartialEq)]
pub enum Status {
    /// Ran and every pin held.
    Pass,
    /// Rejected at the config boundary with exactly the pinned error.
    RejectedAsPinned,
    /// Anything else; the string says what broke.
    Fail(String),
}

/// Result of one scenario run (also a row of the summary artifact).
#[derive(Debug, Clone)]
pub struct Outcome {
    pub name: String,
    pub tier: Tier,
    pub status: Status,
    /// Measured values (None for rejected/failed-before-run scenarios).
    pub eval_loss: Option<f64>,
    pub wire_bytes_per_iter: Option<f64>,
    pub run_sha256: Option<String>,
}

/// Everything one execution of a valid config produces.
struct Executed {
    eval_loss: Option<f64>,
    wire_bytes: f64,
    digest: String,
}

/// Build the fixed scenario workload and train. Deterministic in the
/// config alone: data, init, and every schedule derive from `cfg.seed`.
fn execute(cfg: &Config, telemetry: Option<&Path>) -> Result<Executed> {
    // Elastic runs shard over the full stable-id capacity (nmax).
    let capacity = match cfg.churn {
        None => cfg.nodes,
        Some(spec) => spec.with_run_seed(cfg.seed).resolve(cfg.nodes)?.nmax,
    };
    let data = ClassificationData::generate(&SynthSpec {
        nodes: capacity,
        samples_per_node: 96,
        eval_samples: 256,
        dirichlet_alpha: cfg.dirichlet_alpha,
        margin: 2.0,
        seed: cfg.seed,
        ..Default::default()
    });
    let arch = if cfg.model.starts_with("native") { "mlp-xs" } else { cfg.model.as_str() };
    let wl = mlp::workload(mlp::MlpArch::family(arch)?, data, cfg.micro_batch, cfg.seed);
    // The tee path is CLI-only plumbing: it never enters the manifest,
    // so the digest below is unchanged with telemetry on or off.
    let mut cfg = cfg.clone();
    if let Some(path) = telemetry {
        cfg.telemetry = Some(path.to_string_lossy().into_owned());
    }
    let mut t = Trainer::new(cfg, wl)?;
    let report = t.run();
    if let Some(path) = telemetry {
        // Fail-closed tee: the stream must replay back to the live
        // report exactly, every time — a scenario run with a broken
        // stream is a failed scenario.
        let replayed = crate::telemetry::replay_path(path)?;
        replayed
            .matches_report(&report)
            .with_context(|| format!("telemetry replay of {}", path.display()))?;
    }
    let xbar = t.average_model();
    let eval_loss = t.workload.eval.loss(&xbar);
    // REALIZED per-iter traffic from the run itself (satellite fix):
    // fault masks and membership resizes change the per-step edge
    // counts, so one end-of-run nominal snapshot × steps misstates
    // them. Static fault-free runs realize the same graph every step
    // and keep their exact analytic pins.
    let wire_bytes = report.wire_bytes_per_iter;
    // Digest = run manifest + the full loss trajectory + final metrics,
    // all at the bit level: two digests agree iff the runs agree.
    let mut h = Sha256::new();
    h.update(report.manifest.as_bytes());
    for l in &report.losses {
        h.update(&l.to_bits().to_be_bytes());
    }
    h.update(&report.final_accuracy.to_bits().to_be_bytes());
    h.update(&report.final_consensus.to_bits().to_be_bytes());
    if let Some(el) = eval_loss {
        h.update(&el.to_bits().to_be_bytes());
    }
    Ok(Executed { eval_loss, wire_bytes, digest: h.finish_hex() })
}

fn check_pin(key: &str, pin: &Pinned, actual: Option<f64>, fails: &mut Vec<String>) {
    match (pin.value, actual) {
        (_, None) => fails.push(format!("{key}: run produced no value")),
        (None, Some(a)) => {
            if !a.is_finite() {
                fails.push(format!("{key}: non-finite value {a}"));
            }
        }
        (Some(want), Some(a)) => {
            // NaN fails closed: the comparison below is false for NaN.
            if !((a - want).abs() <= pin.tol) {
                fails.push(format!(
                    "{key}: measured {a} vs pinned {want} ± {} (off by {})",
                    pin.tol,
                    (a - want).abs()
                ));
            }
        }
    }
}

/// Run one scenario and check its expectations. Never errors — every
/// failure mode lands in [`Status::Fail`] so a corpus run always
/// reports per-scenario verdicts.
pub fn run_scenario(s: &Scenario) -> Outcome {
    run_scenario_tee(s, None)
}

/// [`run_scenario`] with an optional telemetry tee: when set, the run
/// streams to `telemetry` and the offline replay is verified against
/// the live report (a broken stream fails the scenario).
pub fn run_scenario_tee(s: &Scenario, telemetry: Option<&Path>) -> Outcome {
    let mut out = Outcome {
        name: s.name.clone(),
        tier: s.tier,
        status: Status::Pass,
        eval_loss: None,
        wire_bytes_per_iter: None,
        run_sha256: None,
    };
    match (&s.config, &s.expect) {
        (ScenarioConfig::Rejected(got), Expect::Reject { error: want }) => {
            if got != want {
                out.status = Status::Fail(format!(
                    "rejection message drifted:\n  pinned: {want}\n  actual: {got}"
                ));
            } else {
                out.status = Status::RejectedAsPinned;
            }
        }
        (ScenarioConfig::Rejected(got), Expect::Run(_)) => {
            out.status = Status::Fail(format!("config rejected: {got}"));
        }
        (ScenarioConfig::Valid(_), Expect::Reject { error: want }) => {
            out.status = Status::Fail(format!(
                "config unexpectedly valid (expected rejection: {want})"
            ));
        }
        (ScenarioConfig::Valid(cfg), Expect::Run(exp)) => {
            let first = match execute(cfg, telemetry) {
                Ok(r) => r,
                Err(e) => {
                    out.status = Status::Fail(format!("run failed: {e:#}"));
                    return out;
                }
            };
            out.eval_loss = first.eval_loss;
            out.wire_bytes_per_iter = Some(first.wire_bytes);
            out.run_sha256 = Some(first.digest.clone());
            let mut fails = Vec::new();
            if let Some(pin) = &exp.eval_loss {
                check_pin("eval-loss", pin, first.eval_loss, &mut fails);
            }
            if let Some(pin) = &exp.wire_bytes_per_iter {
                check_pin("wire-bytes-per-iter", pin, Some(first.wire_bytes), &mut fails);
            }
            match &exp.run_sha256 {
                None => {}
                Some(ShaPin::Hex(want)) => {
                    if *want != first.digest {
                        fails.push(format!(
                            "run-sha256: digest {} != pinned {want}",
                            first.digest
                        ));
                    }
                }
                // The replay leg re-streams to the same tee path; the
                // two runs are deterministic, so the file ends up
                // byte-identical either way.
                Some(ShaPin::Replay) => match execute(cfg, telemetry) {
                    Err(e) => fails.push(format!("replay failed: {e:#}")),
                    Ok(second) => {
                        if second.digest != first.digest {
                            fails.push(format!(
                                "run-sha256: replay diverged ({} then {})",
                                first.digest, second.digest
                            ));
                        }
                    }
                },
            }
            if !fails.is_empty() {
                out.status = Status::Fail(fails.join("; "));
            }
        }
    }
    out
}

/// Corpus run summary: per-scenario outcomes + counters.
pub struct CorpusSummary {
    pub outcomes: Vec<Outcome>,
    pub skipped: usize,
}

impl CorpusSummary {
    pub fn failed(&self) -> usize {
        self.outcomes.iter().filter(|o| matches!(o.status, Status::Fail(_))).count()
    }

    /// Human summary table (one row per executed scenario).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "scenario corpus — {} run, {} skipped, {} failed",
                self.outcomes.len(),
                self.skipped,
                self.failed()
            ),
            &["scenario", "tier", "status", "eval loss", "wire B/iter", "detail"],
        );
        for o in &self.outcomes {
            let (status, detail) = match &o.status {
                Status::Pass => ("pass".to_string(), String::new()),
                Status::RejectedAsPinned => ("rejected".to_string(), "as pinned".into()),
                Status::Fail(why) => {
                    ("FAIL".to_string(), why.lines().next().unwrap_or("").to_string())
                }
            };
            t.row(vec![
                o.name.clone(),
                o.tier.name().to_string(),
                status,
                o.eval_loss.map(|v| format!("{v:.4}")).unwrap_or_else(|| "-".into()),
                o.wire_bytes_per_iter
                    .map(|v| format!("{v:.0}"))
                    .unwrap_or_else(|| "-".into()),
                detail,
            ]);
        }
        t
    }

    /// Machine-readable artifact (uploaded by the CI scenario job).
    pub fn to_json(&self) -> Value {
        let scenarios = self
            .outcomes
            .iter()
            .map(|o| {
                let mut pairs = vec![
                    ("name", Value::Str(o.name.clone())),
                    ("tier", Value::Str(o.tier.name().to_string())),
                    (
                        "status",
                        Value::Str(match &o.status {
                            Status::Pass => "pass".into(),
                            Status::RejectedAsPinned => "rejected-as-pinned".into(),
                            Status::Fail(why) => format!("fail: {why}"),
                        }),
                    ),
                ];
                if let Some(v) = o.eval_loss {
                    pairs.push(("eval-loss", Value::Num(v)));
                }
                if let Some(v) = o.wire_bytes_per_iter {
                    pairs.push(("wire-bytes-per-iter", Value::Num(v)));
                }
                if let Some(d) = &o.run_sha256 {
                    pairs.push(("run-sha256", Value::Str(d.clone())));
                }
                Value::obj(pairs)
            })
            .collect();
        Value::obj(vec![
            ("version", Value::Str(MANIFEST_VERSION.to_string())),
            ("run", Value::Num(self.outcomes.len() as f64)),
            ("skipped", Value::Num(self.skipped as f64)),
            ("failed", Value::Num(self.failed() as f64)),
            ("scenarios", Value::Arr(scenarios)),
        ])
    }
}

/// Sorted `*.json` manifests under `dir`.
fn corpus_paths(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading corpus dir {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .filter(|p| p.extension().map(|e| e == "json").unwrap_or(false))
        .collect();
    paths.sort();
    Ok(paths)
}

/// Batch-run every manifest in a directory (sorted, fail-closed: a
/// manifest that does not parse aborts the whole run — the corpus
/// itself must always be loadable). Returns per-scenario outcomes;
/// check [`CorpusSummary::failed`] to gate.
pub fn run_corpus(dir: &Path, opts: &RunOpts) -> Result<CorpusSummary> {
    let paths = corpus_paths(dir)?;
    ensure!(!paths.is_empty(), "no scenario manifests (*.json) under {}", dir.display());
    let mut outcomes = Vec::new();
    let mut skipped = 0usize;
    for path in &paths {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Value::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let s = Scenario::parse(&v).with_context(|| format!("parsing {}", path.display()))?;
        // The file name is the scenario name — keeps the corpus
        // greppable and the glob-to-scenario mapping bijective.
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("");
        ensure!(
            stem == s.name,
            "{}: scenario name `{}` must match the file stem `{stem}`",
            path.display(),
            s.name
        );
        let name_hit =
            opts.filter.as_deref().map(|f| s.name.contains(f)).unwrap_or(true);
        if !opts.tier.admits(s.tier) || !name_hit {
            skipped += 1;
            continue;
        }
        let tee = match &opts.telemetry {
            None => None,
            Some(dir) => {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating telemetry dir {}", dir.display()))?;
                Some(dir.join(format!("{}.jsonl", s.name)))
            }
        };
        let outcome = run_scenario_tee(&s, tee.as_deref());
        if opts.pin {
            let pinned = repin(&v, &s, &outcome)?;
            std::fs::write(path, pinned.to_pretty_string())
                .with_context(|| format!("writing {}", path.display()))?;
        }
        outcomes.push(outcome);
    }
    Ok(CorpusSummary { outcomes, skipped })
}

/// `--pin`: rewrite a manifest's `expect` section from measured
/// outputs. Fills `value` on present pins (keeping their tolerances),
/// replaces hex digests, and updates pinned rejection strings; the pin
/// STRUCTURE (which keys exist, replay-vs-hex) is authored by hand and
/// preserved.
fn repin(original: &Value, s: &Scenario, outcome: &Outcome) -> Result<Value> {
    let new_expect = match (&s.expect, &s.config) {
        (Expect::Reject { .. }, ScenarioConfig::Rejected(got)) => {
            Value::obj(vec![("reject", Value::Str(got.clone()))])
        }
        (Expect::Run(exp), _) => {
            let mut pairs = Vec::new();
            if let Some(pin) = &exp.eval_loss {
                if let Some(measured) = outcome.eval_loss {
                    pairs.push((
                        "eval-loss",
                        Value::obj(vec![
                            ("value", Value::Num(measured)),
                            ("tol", Value::Num(pin.tol)),
                        ]),
                    ));
                }
            }
            if let Some(pin) = &exp.wire_bytes_per_iter {
                if let Some(measured) = outcome.wire_bytes_per_iter {
                    pairs.push((
                        "wire-bytes-per-iter",
                        Value::obj(vec![
                            ("value", Value::Num(measured)),
                            ("tol", Value::Num(pin.tol)),
                        ]),
                    ));
                }
            }
            match (&exp.run_sha256, &outcome.run_sha256) {
                (Some(ShaPin::Replay), _) => {
                    pairs.push(("run-sha256", Value::Str("replay".into())))
                }
                (Some(ShaPin::Hex(_)), Some(digest)) => {
                    pairs.push(("run-sha256", Value::Str(digest.clone())))
                }
                _ => {}
            }
            Value::obj(pairs)
        }
        // Expected a rejection but the config was valid: nothing
        // measured to pin; leave the manifest as written.
        (Expect::Reject { .. }, ScenarioConfig::Valid(_)) => return Ok(original.clone()),
    };
    let mut v = original.clone();
    let Value::Obj(top) = &mut v else { bail!("manifest is not an object") };
    top.insert("expect".to_string(), new_expect);
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn scenario(config: &str, expect: &str) -> Scenario {
        Scenario::parse_str(&format!(
            r#"{{
              "version": "DLSCEN01",
              "name": "t",
              "description": "d",
              "tier": "smoke",
              "config": {config},
              "expect": {expect}
            }}"#
        ))
        .unwrap()
    }

    const TINY: &str = r#"{
        "nodes": 4, "topology": "ring", "optimizer": "decentlam",
        "model": "mlp-xs", "steps": 8, "total-batch": 64, "micro-batch": 16,
        "lr": 0.05, "linear-scaling": false, "schedule": "constant",
        "eval-every": 0, "threads": 1
    }"#;

    #[test]
    fn check_pin_tolerance_and_finiteness() {
        let mut fails = Vec::new();
        check_pin("k", &Pinned { value: Some(1.0), tol: 0.1 }, Some(1.05), &mut fails);
        check_pin("k", &Pinned { value: None, tol: 0.0 }, Some(0.5), &mut fails);
        assert!(fails.is_empty(), "{fails:?}");
        check_pin("k", &Pinned { value: Some(1.0), tol: 0.1 }, Some(1.2), &mut fails);
        check_pin("k", &Pinned { value: Some(1.0), tol: 0.1 }, Some(f64::NAN), &mut fails);
        check_pin("k", &Pinned { value: None, tol: 0.0 }, Some(f64::INFINITY), &mut fails);
        check_pin("k", &Pinned { value: Some(1.0), tol: 0.1 }, None, &mut fails);
        assert_eq!(fails.len(), 4, "{fails:?}");
    }

    #[test]
    fn tiny_scenario_runs_replays_and_reports_measurements() {
        let s = scenario(TINY, r#"{"run-sha256": "replay"}"#);
        let out = run_scenario(&s);
        assert_eq!(out.status, Status::Pass, "{:?}", out.status);
        assert!(out.eval_loss.unwrap().is_finite());
        assert!(out.wire_bytes_per_iter.unwrap() > 0.0);
        assert_eq!(out.run_sha256.as_ref().unwrap().len(), 64);
        // The digest is a stable function of the manifest: a fresh
        // parse + run reproduces it (this is what a Hex pin asserts).
        let again = run_scenario(&scenario(TINY, r#"{"run-sha256": "replay"}"#));
        assert_eq!(out.run_sha256, again.run_sha256);
    }

    #[test]
    fn wrong_hex_pin_fails_with_both_digests() {
        let hex = "0".repeat(64);
        let s = scenario(TINY, &format!(r#"{{"run-sha256": "{hex}"}}"#));
        let out = run_scenario(&s);
        let Status::Fail(why) = &out.status else { panic!("expected Fail") };
        assert!(why.contains("run-sha256"), "{why}");
        assert!(why.contains(&hex), "{why}");
    }

    #[test]
    fn pinned_rejection_passes_and_drift_fails() {
        let bad_cfg = r#"{"nodes": 4, "topology": "ring", "faults": "drop=2"}"#;
        let pinned =
            r#"{"reject": "scenario.config.faults: fault rate `drop=2` outside [0, 1]"}"#;
        let out = run_scenario(&scenario(bad_cfg, pinned));
        assert_eq!(out.status, Status::RejectedAsPinned);

        let drifted = r#"{"reject": "some other message"}"#;
        let out = run_scenario(&scenario(bad_cfg, drifted));
        assert!(matches!(&out.status, Status::Fail(w) if w.contains("drifted")));

        // A rejection pin on a VALID config is a corpus bug.
        let out = run_scenario(&scenario(TINY, drifted));
        assert!(matches!(&out.status, Status::Fail(w) if w.contains("unexpectedly valid")));
    }

    #[test]
    fn eval_loss_pin_gates_within_tolerance() {
        let s = scenario(TINY, r#"{}"#);
        let measured = run_scenario(&s).eval_loss.unwrap();
        let pin = format!(r#"{{"eval-loss": {{"value": {measured}, "tol": 1e-9}}}}"#);
        assert_eq!(run_scenario(&scenario(TINY, &pin)).status, Status::Pass);
        let off = format!(r#"{{"eval-loss": {{"value": {}, "tol": 1e-9}}}}"#, measured + 1.0);
        assert!(matches!(run_scenario(&scenario(TINY, &off)).status, Status::Fail(_)));
    }

    #[test]
    fn telemetry_tee_streams_replays_and_matches_the_live_run() {
        let dir = std::env::temp_dir()
            .join(format!("decentlam_runner_tee_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let s = scenario(TINY, r#"{}"#);
        let out = run_scenario_tee(&s, Some(&path));
        assert_eq!(out.status, Status::Pass, "{:?}", out.status);
        let r = crate::telemetry::replay_path(&path).unwrap();
        assert!(r.complete && !r.truncated);
        assert_eq!(r.report.losses.len(), 8);
        assert_eq!(Some(r.report.wire_bytes_per_iter), out.wire_bytes_per_iter);
        // The tee never perturbs the run: same digest with and without.
        assert_eq!(run_scenario(&s).run_sha256, out.run_sha256);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repin_fills_values_and_keeps_structure() {
        let text = format!(
            r#"{{
              "version": "DLSCEN01", "name": "t", "description": "d",
              "tier": "smoke", "config": {TINY},
              "expect": {{"eval-loss": {{"tol": 0.05}}, "run-sha256": "replay"}}
            }}"#
        );
        let v = Value::parse(&text).unwrap();
        let s = Scenario::parse(&v).unwrap();
        let out = run_scenario(&s);
        let pinned = repin(&v, &s, &out).unwrap();
        let re = Scenario::parse(&pinned).unwrap();
        let Expect::Run(exp) = &re.expect else { panic!("expected Run") };
        let pin = exp.eval_loss.as_ref().unwrap();
        assert_eq!(pin.value, out.eval_loss);
        assert_eq!(pin.tol, 0.05);
        assert_eq!(exp.run_sha256, Some(ShaPin::Replay));
        // And the repinned manifest now self-verifies.
        assert_eq!(run_scenario(&re).status, Status::Pass);
    }
}
