//! Discrete-event asynchronous gossip clock (DESIGN.md §8).
//!
//! The synchronous [`crate::coordinator::Trainer`] models every round
//! as an instantaneous barrier; the closed-form α–β formula in
//! [`crate::comm::cost`] then prices it after the fact. Real
//! decentralized clusters are neither: nodes run at different speeds,
//! fire their rounds when their own clock allows, and mix against
//! whatever their neighbors last published ("From promise to practice",
//! arXiv 2410.11998). This module simulates that regime exactly, and
//! deterministically:
//!
//! * [`AsyncSpec`] — the `--async tau=2,spread=4,jitter=0.2` knobs:
//!   bounded-staleness window τ, per-node slowdown spread, lognormal
//!   per-step jitter, base compute time and link bandwidth;
//! * [`NodeClocks`] — seeded per-(node, step) compute-time draws from
//!   counter-keyed PCG64 streams (replayable, iteration-order-free,
//!   exactly like the PR-2 fault schedules);
//! * [`EventQueue`] — a binary-heap event queue with a *total* order on
//!   `(time, phase, node)`, so the pop sequence is independent of
//!   insertion order and replay-identical for a fixed seed;
//! * [`simulate_gossip`] — the engine itself: each node's local step is
//!   a publish event (gradient + publish payload, after its seeded
//!   compute time) followed by a gather event (after its α–β exchange
//!   time, charged at the node's own degree). A node at local step `k`
//!   mixes, for every neighbor `j`, the payload version
//!   `min(latest_published_j, k)` and *blocks* until
//!   `latest_published_j ≥ max(k − τ, 0)` — the bounded-staleness
//!   window. Blocked gathers park and are woken by the unblocking
//!   publish (plus one per-edge α + M/B retransmit).
//!
//! The output is an [`AsyncSchedule`]: per (global step, edge) staleness
//! ages in `[0, τ]` plus simulated completion times. The schedule is
//! **value-free** — event times depend only on the spec, topology and
//! payload width, never on gradients — so the same engine prices Fig. 6
//! (uniform clocks) and drives training (the trainer replays the
//! schedule through the [`super::FaultyEngine`] ring caches, one global
//! step at a time).
//!
//! Why the global-step replay is faithful: a node at step `k` only ever
//! mixes payload versions in `[max(k − τ, 0), k]` (versions newer than
//! its own round are capped at `k` to keep momentum round-aligned), and
//! every version `≤ k` is a function of state from rounds `< k` plus
//! round `k`'s own publishes. Executing global steps in order is
//! therefore a topological execution of the event DAG — the values are
//! identical to firing nodes in event order. With uniform speeds, zero
//! jitter and τ = 0 every entry is version-exact (`= k`), so async
//! training is **bitwise equal** to the synchronous trainer (pinned in
//! `rust/tests/async_gossip.rs`).
//!
//! Liveness: the minimum-step unfinished node is never blocked (every
//! neighbor has published at least that step − 1 ≥ its requirement), so
//! the event loop cannot deadlock for any τ ≥ 0.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use crate::comm::cost::{neighbor_exchange_deg_s, LinkSpec};
use crate::comm::engine::CommEngine;
use crate::util::kvspec::KvSpec;
use crate::util::rng::Pcg64;

/// Hard cap on the staleness window: each unit of τ costs one n×d ring
/// entry per exchange slot, so an unbounded τ is a memory foot-gun.
pub const MAX_TAU: usize = 32;

/// The `--async` knobs: bounded staleness + heterogeneous clocks.
#[derive(Debug, Clone, PartialEq)]
pub struct AsyncSpec {
    /// Bounded-staleness window: a node at local step k blocks until
    /// every neighbor has published step max(k − tau, 0), and never
    /// mixes anything older. tau = 0 is barrier-exact synchrony.
    pub tau: usize,
    /// Slowdown spread: per-node multipliers are drawn log-uniform in
    /// [1, spread] (spread = 1 ⇒ every node exactly 1.0).
    pub spread: f64,
    /// Lognormal per-(node, step) jitter sigma (0 ⇒ exactly 1.0).
    pub jitter: f64,
    /// Base compute seconds per local step at slowdown 1, in ms.
    pub compute_ms: f64,
    /// NIC bandwidth of the α–β link model, Gbit/s.
    pub bw_gbps: f64,
    /// Seed of the clock draws (independent of data/topology seeds).
    pub seed: u64,
    /// True when `seed=` was NOT explicit — the seed should follow the
    /// run seed (resolved later via [`AsyncSpec::with_run_seed`]).
    pub seed_from_run: bool,
}

impl Default for AsyncSpec {
    fn default() -> Self {
        AsyncSpec {
            tau: 1,
            spread: 1.0,
            jitter: 0.0,
            compute_ms: 10.0,
            bw_gbps: 25.0,
            seed: 0,
            seed_from_run: true,
        }
    }
}

impl KvSpec for AsyncSpec {
    const NAME: &'static str = "async";
    const BARE_TRUE: bool = true;

    fn begin(_head: Option<&str>, default_seed: u64) -> Result<AsyncSpec> {
        Ok(AsyncSpec { seed: default_seed, ..Default::default() })
    }

    fn set_kv(&mut self, key: &str, v: &str) -> Result<()> {
        let v = v.trim();
        match key {
            "tau" => {
                self.tau = v.parse()?;
                if self.tau > MAX_TAU {
                    bail!("async tau={} above the cap {MAX_TAU}", self.tau);
                }
            }
            "spread" => {
                self.spread = v.parse()?;
                if !(1.0..=1e6).contains(&self.spread) {
                    bail!("async spread={} outside [1, 1e6]", self.spread);
                }
            }
            "jitter" => {
                self.jitter = v.parse()?;
                if !(0.0..=4.0).contains(&self.jitter) {
                    bail!("async jitter={} outside [0, 4]", self.jitter);
                }
            }
            "compute" => {
                self.compute_ms = v.parse()?;
                if !self.compute_ms.is_finite() || self.compute_ms <= 0.0 {
                    bail!("async compute={} must be > 0 ms", self.compute_ms);
                }
            }
            "bw" => {
                self.bw_gbps = v.parse()?;
                if !self.bw_gbps.is_finite() || self.bw_gbps <= 0.0 {
                    bail!("async bw={} must be > 0 Gbps", self.bw_gbps);
                }
            }
            "seed" => {
                self.seed = v.parse()?;
                self.seed_from_run = false;
            }
            other => bail!("unknown async key `{other}` (tau|spread|jitter|compute|bw|seed)"),
        }
        Ok(())
    }

    fn to_spec_string(&self) -> String {
        let mut s = format!(
            "tau={},spread={},jitter={},compute={},bw={}",
            self.tau, self.spread, self.jitter, self.compute_ms, self.bw_gbps
        );
        if !self.seed_from_run {
            s.push_str(&format!(",seed={}", self.seed));
        }
        s
    }
}

impl AsyncSpec {
    /// Parse the CLI form `tau=2,spread=4,jitter=0.2,seed=7`. Keys:
    /// `tau` (0..=32), `spread` (≥ 1), `jitter` (in [0, 4]), `compute`
    /// (ms > 0), `bw` (Gbps > 0), `seed`. Omitted keys default; a bare
    /// `--async` (the parser passes `true`) means all defaults.
    pub fn parse(s: &str, default_seed: u64) -> Result<AsyncSpec> {
        <AsyncSpec as KvSpec>::parse(s, default_seed)
    }

    /// Canonical spec string; reparses (default_seed 0) to an equal spec.
    pub fn to_spec_string(&self) -> String {
        <AsyncSpec as KvSpec>::to_spec_string(self)
    }

    /// Resolve seed inheritance: adopt `run_seed` unless `seed=` was
    /// explicit in the spec string.
    pub fn with_run_seed(mut self, run_seed: u64) -> AsyncSpec {
        if self.seed_from_run {
            self.seed = run_seed;
        }
        self
    }

    /// Uniform clocks: every compute draw is exactly `compute_ms`.
    pub fn is_uniform(&self) -> bool {
        self.spread <= 1.0 && self.jitter <= 0.0
    }

    /// The α–β link this spec's exchanges are priced on.
    pub fn link(&self) -> LinkSpec {
        LinkSpec { bandwidth_gbps: self.bw_gbps, latency_us: 25.0 }
    }
}

/// Domain-separation tags (same pattern as the fault plan's).
const TAG_SPEED: u64 = 0xc10c_5eed;
const TAG_JITTER: u64 = 0xc10c_717e;

/// Seeded per-(node, step) virtual compute times. Every draw comes from
/// its own counter-keyed PCG64 stream, so clocks are replayable and
/// iteration-order-free — querying (i, k) never perturbs (j, l).
#[derive(Debug, Clone)]
pub struct NodeClocks {
    spec: AsyncSpec,
}

impl NodeClocks {
    pub fn new(spec: AsyncSpec) -> NodeClocks {
        NodeClocks { spec }
    }

    /// Fixed per-node slowdown multiplier, log-uniform in [1, spread].
    /// Exactly 1.0 when spread = 1 (no draw: uniform runs stay bitwise
    /// independent of the seed).
    pub fn slowdown(&self, node: usize) -> f64 {
        if self.spec.spread <= 1.0 {
            return 1.0;
        }
        let u = Pcg64::new(self.spec.seed ^ TAG_SPEED, node as u64).f64();
        (self.spec.spread.ln() * u).exp()
    }

    /// Per-(node, step) lognormal jitter factor; exactly 1.0 at σ = 0.
    pub fn jitter(&self, node: usize, step: usize) -> f64 {
        if self.spec.jitter <= 0.0 {
            return 1.0;
        }
        let mut rng =
            Pcg64::counter_keyed(self.spec.seed, TAG_JITTER, step as u64, node as u64);
        (self.spec.jitter * rng.normal()).exp()
    }

    /// Virtual seconds node `node` spends computing local step `step`.
    pub fn compute_s(&self, node: usize, step: usize) -> f64 {
        self.spec.compute_ms * 1e-3 * self.slowdown(node) * self.jitter(node, step)
    }
}

/// Event phase: all publishes at a tick precede all gathers at the same
/// tick, so a gather never misses a same-time publish.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    Publish,
    Gather,
}

/// One scheduled node event. The ordering key `(time, phase, node)` is
/// total (f64 via `total_cmp`; times are always finite here) and unique
/// while each node owns at most one pending event — which makes the
/// queue's pop sequence independent of insertion order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub time: f64,
    pub phase: Phase,
    pub node: u32,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.phase.cmp(&other.phase))
            .then(self.node.cmp(&other.node))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-heap over [`Event`]s.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, ev: Event) {
        debug_assert!(ev.time.is_finite(), "event times must be finite");
        self.heap.push(std::cmp::Reverse(ev));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|r| r.0)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Timing + staleness summary of a simulated run (what sweeps report).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AsyncReport {
    /// Simulated seconds at which ALL nodes have completed step k.
    pub step_done_s: Vec<f64>,
    /// `step_done_s` of the final step.
    pub makespan_s: f64,
    /// Node-seconds spent blocked on the staleness window (gossip) or
    /// the barrier (all-reduce baseline).
    pub total_wait_s: f64,
    /// Mean staleness age over all (step, directed edge) deliveries.
    pub mean_staleness: f64,
    /// Largest staleness age any delivery saw (≤ τ by construction).
    pub max_staleness: u16,
    /// Fraction of deliveries with age ≥ 1.
    pub stale_fraction: f64,
}

impl AsyncReport {
    /// Barrier-synchronous report (the PmSGD baseline): cumulative
    /// per-round times, zero staleness.
    pub fn barrier(step_done_s: Vec<f64>, total_wait_s: f64) -> AsyncReport {
        let makespan_s = step_done_s.last().copied().unwrap_or(0.0);
        AsyncReport { step_done_s, makespan_s, total_wait_s, ..Default::default() }
    }
}

/// A realized asynchronous run: per-(global step, directed edge)
/// staleness ages plus the event times. Value-free — reusable across
/// optimizers with the same wire pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct AsyncSchedule {
    n: usize,
    steps: usize,
    tau: usize,
    /// CSR over each node's non-self neighbors, ascending — the exact
    /// order of the comm engine's nominal rows with the self entry
    /// removed, so the fault engine can align by ordinal.
    row_ptr: Vec<u32>,
    neighbors: Vec<u32>,
    /// stale[step * nnz + row_ptr[i] + e]: age of the payload node i
    /// mixes from its e-th neighbor at global step `step` (0 = fresh).
    stale: Vec<u16>,
    report: AsyncReport,
}

impl AsyncSchedule {
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    pub fn tau(&self) -> usize {
        self.tau
    }

    /// Non-self neighbors of node `i`, ascending.
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.neighbors[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize]
    }

    /// Staleness ages of node `i`'s incoming payloads at global step
    /// `step`, aligned with [`AsyncSchedule::neighbors`]. `None` past
    /// the simulated horizon (callers run fresh there).
    pub fn staleness(&self, step: usize, i: usize) -> Option<&[u16]> {
        if step >= self.steps {
            return None;
        }
        let nnz = self.neighbors.len();
        let base = step * nnz;
        Some(&self.stale[base + self.row_ptr[i] as usize..base + self.row_ptr[i + 1] as usize])
    }

    pub fn max_staleness(&self) -> u16 {
        self.report.max_staleness
    }

    pub fn report(&self) -> AsyncReport {
        self.report.clone()
    }

    /// Hand-built schedule for the engine's unit tests: staleness ages
    /// given directly, CSR taken from the engine's nominal rows.
    #[cfg(test)]
    pub(crate) fn handmade(
        comm: &dyn CommEngine,
        tau: usize,
        stale_per_step: Vec<Vec<u16>>,
    ) -> AsyncSchedule {
        let n = comm.n();
        let mut row_ptr = vec![0u32];
        let mut neighbors = Vec::new();
        for i in 0..n {
            for &(j, _) in comm.row(i) {
                if j as usize != i {
                    neighbors.push(j);
                }
            }
            row_ptr.push(neighbors.len() as u32);
        }
        let nnz = neighbors.len();
        let steps = stale_per_step.len();
        let mut stale = Vec::with_capacity(nnz * steps);
        for s in &stale_per_step {
            assert_eq!(s.len(), nnz);
            stale.extend_from_slice(s);
        }
        let max = stale.iter().copied().max().unwrap_or(0);
        let report = AsyncReport { max_staleness: max, ..Default::default() };
        AsyncSchedule { n, steps, tau, row_ptr, neighbors, stale, report }
    }
}

/// Run the discrete-event simulation of `steps` asynchronous gossip
/// rounds over the engine's (static) topology: per-node seeded compute
/// times, per-node α–β exchange times at `payloads` payloads of
/// `payload_bytes` each, bounded staleness `spec.tau`.
pub fn simulate_gossip(
    spec: &AsyncSpec,
    comm: &dyn CommEngine,
    payload_bytes: f64,
    payloads: usize,
    steps: usize,
) -> AsyncSchedule {
    let n = comm.n();
    // CSR of non-self neighbors, in nominal row order.
    let mut row_ptr = vec![0u32];
    let mut neighbors: Vec<u32> = Vec::new();
    for i in 0..n {
        for &(j, _) in comm.row(i) {
            if j as usize != i {
                neighbors.push(j);
            }
        }
        row_ptr.push(neighbors.len() as u32);
    }
    let nnz = neighbors.len();
    let nbrs = |i: usize| &neighbors[row_ptr[i] as usize..row_ptr[i + 1] as usize];

    let clocks = NodeClocks::new(spec.clone());
    let link = spec.link();
    // Per-node exchange time: the node's whole neighbor fan charged at
    // its own degree (the cost model's formula, per node instead of at
    // the bottleneck degree).
    let exchange_s: Vec<f64> = (0..n)
        .map(|i| {
            payloads.max(1) as f64 * neighbor_exchange_deg_s(&link, nbrs(i).len(), payload_bytes)
        })
        .collect();
    // A payload that arrives late (its publish is what unblocks a parked
    // gather) pays one extra per-edge retransmit: α + M/B.
    let wake_s = link.latency_s() + link.transfer_s(payload_bytes);

    let mut version = vec![-1i64; n];
    let mut cur_step = vec![0u32; n];
    let mut parked: Vec<Option<f64>> = vec![None; n];
    let mut finish = vec![0f64; n * steps];
    let mut stale = vec![0u16; nnz * steps];
    let tau = spec.tau as i64;
    let satisfied = |k: usize, row: &[u32], version: &[i64]| -> bool {
        let need = (k as i64 - tau).max(0);
        row.iter().all(|&j| version[j as usize] >= need)
    };

    let mut q = EventQueue::new();
    for i in 0..n {
        if steps > 0 {
            q.push(Event { time: clocks.compute_s(i, 0), phase: Phase::Publish, node: i as u32 });
        }
    }

    let (mut total_wait, mut sum_stale, mut stale_entries) = (0.0f64, 0u64, 0usize);
    let mut max_stale = 0u16;
    while let Some(ev) = q.pop() {
        let i = ev.node as usize;
        let k = cur_step[i] as usize;
        match ev.phase {
            Phase::Publish => {
                version[i] = k as i64;
                q.push(Event {
                    time: ev.time + exchange_s[i],
                    phase: Phase::Gather,
                    node: ev.node,
                });
                // Wake neighbors whose staleness window this publish
                // completes (ascending id — deterministic).
                for &jn in nbrs(i) {
                    let w = jn as usize;
                    if let Some(since) = parked[w] {
                        if satisfied(cur_step[w] as usize, nbrs(w), &version) {
                            parked[w] = None;
                            let wake = ev.time + wake_s;
                            total_wait += wake - since;
                            q.push(Event { time: wake, phase: Phase::Gather, node: jn });
                        }
                    }
                }
            }
            Phase::Gather => {
                if !satisfied(k, nbrs(i), &version) {
                    parked[i] = Some(ev.time);
                    continue;
                }
                let base = k * nnz + row_ptr[i] as usize;
                for (e, &j) in nbrs(i).iter().enumerate() {
                    let age = (k as i64 - version[j as usize].min(k as i64)) as u16;
                    debug_assert!(age as i64 <= tau);
                    stale[base + e] = age;
                    sum_stale += age as u64;
                    stale_entries += (age > 0) as usize;
                    max_stale = max_stale.max(age);
                }
                finish[k * n + i] = ev.time;
                cur_step[i] += 1;
                if (cur_step[i] as usize) < steps {
                    q.push(Event {
                        time: ev.time + clocks.compute_s(i, cur_step[i] as usize),
                        phase: Phase::Publish,
                        node: ev.node,
                    });
                }
            }
        }
    }
    debug_assert!(cur_step.iter().all(|&k| k as usize == steps), "event loop stalled");

    let step_done_s: Vec<f64> = (0..steps)
        .map(|k| finish[k * n..(k + 1) * n].iter().cloned().fold(0.0, f64::max))
        .collect();
    let deliveries = (nnz * steps).max(1);
    let report = AsyncReport {
        makespan_s: step_done_s.last().copied().unwrap_or(0.0),
        step_done_s,
        total_wait_s: total_wait,
        mean_staleness: sum_stale as f64 / deliveries as f64,
        max_staleness: max_stale,
        stale_fraction: stale_entries as f64 / deliveries as f64,
    };
    AsyncSchedule { n, steps, tau: spec.tau, row_ptr, neighbors, stale, report }
}

/// Barrier-synchronous timing (the PmSGD / all-reduce baseline): every
/// round costs the slowest node's compute draw plus `comm_s`. Returns
/// cumulative per-round times and the summed barrier wait.
pub fn simulate_barrier(spec: &AsyncSpec, n: usize, comm_s: f64, steps: usize) -> (Vec<f64>, f64) {
    let clocks = NodeClocks::new(spec.clone());
    let mut cum = Vec::with_capacity(steps);
    let mut t = 0.0;
    let mut wait = 0.0;
    for k in 0..steps {
        let mut slowest = 0.0f64;
        let mut sum = 0.0f64;
        for i in 0..n {
            let c = clocks.compute_s(i, k);
            slowest = slowest.max(c);
            sum += c;
        }
        wait += n as f64 * slowest - sum;
        t += slowest + comm_s;
        cum.push(t);
    }
    (cum, wait)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommCost, CommStats};
    use crate::optim::CommPattern;
    use crate::topology::{Kind, SparseWeights, Topology};

    fn ring(n: usize) -> SparseWeights {
        SparseWeights::metropolis_hastings(&Topology::build(Kind::Ring, n))
    }

    #[test]
    fn parse_full_spec_and_defaults() {
        let s = AsyncSpec::parse("tau=3,spread=4,jitter=0.2,seed=9", 1).unwrap();
        assert_eq!(s.tau, 3);
        assert_eq!(s.spread, 4.0);
        assert_eq!(s.jitter, 0.2);
        assert_eq!(s.seed, 9);
        assert!(!s.is_uniform());
        let d = AsyncSpec::parse("", 5).unwrap();
        assert_eq!(d.seed, 5);
        assert!(d.is_uniform());
        // A bare `--async` arrives as the string "true": all defaults.
        assert_eq!(AsyncSpec::parse("true", 5).unwrap(), d);
        assert!(AsyncSpec::parse("tau=99", 0).is_err());
        assert!(AsyncSpec::parse("spread=0.5", 0).is_err());
        assert!(AsyncSpec::parse("jitter=-1", 0).is_err());
        assert!(AsyncSpec::parse("warp=1", 0).is_err());
        assert!(AsyncSpec::parse("tau", 0).is_err());
    }

    #[test]
    fn exact_error_strings_are_pinned() {
        let e = AsyncSpec::parse("tau=99", 0).unwrap_err().to_string();
        assert_eq!(e, "async tau=99 above the cap 32");
        let e = AsyncSpec::parse("tau", 0).unwrap_err().to_string();
        assert_eq!(e, "async spec entry `tau` is not key=value");
        let e = AsyncSpec::parse("warp=1", 0).unwrap_err().to_string();
        assert_eq!(e, "unknown async key `warp` (tau|spread|jitter|compute|bw|seed)");
        let e = AsyncSpec::parse("spread=0.5", 0).unwrap_err().to_string();
        assert_eq!(e, "async spread=0.5 outside [1, 1e6]");
    }

    #[test]
    fn spec_string_round_trips() {
        for s in ["true", "", "tau=3,spread=4,jitter=0.2,seed=9", "compute=2.5,bw=10"] {
            let a = AsyncSpec::parse(s, 0).unwrap();
            let b = AsyncSpec::parse(&a.to_spec_string(), 0).unwrap();
            assert_eq!(a, b, "round trip of `{s}` via `{}`", a.to_spec_string());
        }
    }

    #[test]
    fn run_seed_resolution_respects_explicit_seed() {
        assert_eq!(AsyncSpec::parse("tau=2", 0).unwrap().with_run_seed(42).seed, 42);
        assert_eq!(AsyncSpec::parse("tau=2,seed=7", 0).unwrap().with_run_seed(42).seed, 7);
    }

    #[test]
    fn clocks_are_deterministic_and_exact_at_uniform() {
        let uni = NodeClocks::new(AsyncSpec { compute_ms: 7.0, ..Default::default() });
        for i in 0..8 {
            for k in [0usize, 3, 999] {
                assert_eq!(uni.compute_s(i, k), 7.0e-3, "uniform draw must be exact");
            }
        }
        let het =
            NodeClocks::new(AsyncSpec { spread: 4.0, jitter: 0.3, seed: 11, ..Default::default() });
        let a = het.compute_s(3, 17);
        assert_eq!(a, het.compute_s(3, 17), "counter-keyed draws must replay");
        assert_ne!(a, het.compute_s(4, 17));
        assert_ne!(a, het.compute_s(3, 18));
        for i in 0..32 {
            let m = het.slowdown(i);
            assert!((1.0..=4.0).contains(&m), "slowdown {m} outside [1, spread]");
        }
    }

    #[test]
    fn event_order_is_total_and_publish_precedes_gather() {
        let a = Event { time: 1.0, phase: Phase::Publish, node: 5 };
        let b = Event { time: 1.0, phase: Phase::Gather, node: 0 };
        let c = Event { time: 1.0, phase: Phase::Publish, node: 6 };
        assert!(a < b, "same-time publish must precede gather");
        assert!(a < c, "node id breaks ties");
        let mut q = EventQueue::new();
        for ev in [b, c, a] {
            q.push(ev);
        }
        assert_eq!(q.pop(), Some(a));
        assert_eq!(q.pop(), Some(c));
        assert_eq!(q.pop(), Some(b));
        assert!(q.is_empty());
    }

    #[test]
    fn uniform_ring_is_lockstep_and_matches_formula_exactly() {
        // Uniform speeds, zero jitter on a regular graph: the event time
        // per step equals compute + the closed-form neighbor-exchange
        // cost, and no delivery is ever stale.
        let n = 16;
        let sw = ring(n);
        let spec = AsyncSpec { tau: 2, compute_ms: 5.0, ..Default::default() };
        let bytes = 4.0 * 10_000.0;
        let steps = 12;
        let sched = simulate_gossip(&spec, &sw, bytes, 1, steps);
        let r = sched.report();
        assert_eq!(r.max_staleness, 0, "uniform regular lockstep never goes stale");
        assert_eq!(r.total_wait_s, 0.0);
        let cost = CommCost::new(spec.link());
        let stats = CommStats::of_engine(&sw);
        let payload = crate::comm::PayloadBytes::uniform(bytes);
        let per_iter = 5.0e-3
            + cost.per_iter_comm_s(CommPattern::Neighbor { payloads: 1 }, &stats, payload);
        let sim_per_iter = r.makespan_s / steps as f64;
        assert!(
            (sim_per_iter - per_iter).abs() <= 1e-12 + 1e-9 * per_iter,
            "sim {sim_per_iter} vs formula {per_iter}"
        );
        // Per-step completion times are evenly spaced.
        for k in 1..steps {
            let dt = r.step_done_s[k] - r.step_done_s[k - 1];
            assert!((dt - per_iter).abs() < 1e-12);
        }
    }

    #[test]
    fn schedule_replays_identically() {
        let sw = ring(12);
        let spec = AsyncSpec { tau: 3, spread: 6.0, jitter: 0.4, seed: 13, ..Default::default() };
        let a = simulate_gossip(&spec, &sw, 4096.0, 1, 40);
        let b = simulate_gossip(&spec, &sw, 4096.0, 1, 40);
        assert_eq!(a, b, "same spec must produce the identical schedule");
    }

    #[test]
    fn staleness_is_bounded_by_tau_and_realized_under_heterogeneity() {
        let sw = ring(12);
        for tau in [1usize, 2, 3] {
            let spec = AsyncSpec { tau, spread: 8.0, jitter: 0.3, seed: 7, ..Default::default() };
            let sched = simulate_gossip(&spec, &sw, 4096.0, 1, 60);
            let r = sched.report();
            assert!(r.max_staleness as usize <= tau, "tau={tau}: max {}", r.max_staleness);
            assert!(r.max_staleness >= 1, "spread=8 never went stale at tau={tau}");
            assert!(r.mean_staleness > 0.0 && r.mean_staleness <= tau as f64);
            // Exhaustive bound over every (step, edge) delivery.
            for k in 0..60 {
                for i in 0..12 {
                    for &s in sched.staleness(k, i).unwrap() {
                        assert!(s as usize <= tau);
                        assert!(s as usize <= k, "staleness {s} exceeds available history at {k}");
                    }
                }
            }
            assert!(sched.staleness(60, 0).is_none(), "past the horizon is fresh");
        }
    }

    #[test]
    fn tau_zero_forces_every_delivery_fresh_even_with_stragglers() {
        let sw = ring(8);
        let spec = AsyncSpec { tau: 0, spread: 8.0, jitter: 0.5, seed: 3, ..Default::default() };
        let sched = simulate_gossip(&spec, &sw, 4096.0, 1, 30);
        let r = sched.report();
        assert_eq!(r.max_staleness, 0, "tau=0 is barrier-exact");
        assert!(r.total_wait_s > 0.0, "a 8x straggler must make someone wait");
    }

    #[test]
    fn makespan_tracks_the_slowest_node() {
        let sw = ring(8);
        let slow = AsyncSpec { tau: 2, spread: 8.0, seed: 5, ..Default::default() };
        let fast = AsyncSpec { tau: 2, spread: 1.0, seed: 5, ..Default::default() };
        let ms = |spec: &AsyncSpec| simulate_gossip(spec, &sw, 4096.0, 1, 40).report().makespan_s;
        assert!(ms(&slow) > 1.5 * ms(&fast), "an 8x spread must slow the run down");
    }

    #[test]
    fn barrier_matches_allreduce_formula_at_uniform() {
        let spec = AsyncSpec { compute_ms: 4.0, ..Default::default() };
        let ar = CommCost::new(spec.link()).allreduce_s(16, 1e6);
        let (cum, wait) = simulate_barrier(&spec, 16, ar, 10);
        assert_eq!(cum.len(), 10);
        assert!(wait.abs() < 1e-12, "uniform barrier wait {wait}");
        let per_iter = cum[9] / 10.0;
        assert!((per_iter - (4.0e-3 + ar)).abs() < 1e-12);
        // Heterogeneous barrier pays the max every round.
        let het = AsyncSpec { spread: 4.0, jitter: 0.2, seed: 2, compute_ms: 4.0, ..spec };
        let (cum_h, wait_h) = simulate_barrier(&het, 16, ar, 10);
        assert!(cum_h[9] > cum[9]);
        assert!(wait_h > 0.0);
    }
}
