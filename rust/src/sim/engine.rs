//! The fault-injecting comm engine (DESIGN.md §6).
//!
//! [`FaultyEngine`] wraps a nominal mixing-weight engine and realizes
//! one step of the [`FaultPlan`] on top of it:
//!
//! * **masking** — edges incident to a dropped node, and links the plan
//!   fails this step, are removed from both rows;
//! * **renormalization** — each masked edge's Metropolis–Hastings
//!   weight w_ij is folded back into w_ii *and* w_jj. The mask set is
//!   symmetric and the nominal matrix is symmetric doubly stochastic,
//!   so the realized matrix stays symmetric doubly stochastic (row sums
//!   are untouched; the property suite pins it);
//! * **staleness** — entries whose sender straggled (or whose link the
//!   plan marked stale) keep their weight but are resolved against the
//!   engine's cache of the *previous* round's published vectors instead
//!   of this round's `src`. Until the cache is warm (before the first
//!   `record_publish`) stale entries deliver fresh data — staleness
//!   starts at step 1 at the earliest.
//!
//! The rebuild reuses the CSR allocation path of
//! [`crate::topology::sparse::SparseWeights`]: `begin_step` rewrites
//! `row_ptr` + entry lists in O(n + edges) without touching a dense
//! matrix. Rows with no stale entry mix through the exact same
//! [`mix_row`] kernel as every other engine, which makes a zero-rate
//! plan bitwise identical to the fault-free engine (tested), and
//! per-row mixing stays independent across nodes, so parallel execution
//! remains bitwise equal to serial under faults.
//!
//! Cost accounting is *realized*, not nominal: the engine's rows after
//! masking are what [`crate::comm::cost::CommStats::of_engine`] sees,
//! and [`FaultStats`] accumulates the realized/masked/stale totals a
//! sweep reports.

use crate::comm::engine::{mix_row, CommEngine, RowEntry};
use crate::util::math;

use super::plan::FaultPlan;

/// Cumulative fault accounting across `begin_step` calls.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Steps realized.
    pub steps: usize,
    /// Undirected edge totals of the nominal topology.
    pub nominal_edges: usize,
    /// Undirected edges that actually carried a message (incl. stale).
    pub realized_edges: usize,
    /// Undirected edges masked (dropout or link failure).
    pub masked_edges: usize,
    /// Directed stale deliveries (message served from the cache).
    pub stale_messages: usize,
    /// Node-steps spent fully dropped out.
    pub dropped_node_steps: usize,
    /// Node-steps spent straggling.
    pub straggler_node_steps: usize,
}

impl FaultStats {
    /// Fraction of nominal edges that carried a message.
    pub fn realized_edge_fraction(&self) -> f64 {
        if self.nominal_edges == 0 {
            1.0
        } else {
            self.realized_edges as f64 / self.nominal_edges as f64
        }
    }
}

/// A comm engine that masks, renormalizes and staleness-injects a
/// nominal engine's rows according to a [`FaultPlan`].
#[derive(Debug, Clone)]
pub struct FaultyEngine {
    plan: FaultPlan,
    n: usize,
    /// Realized CSR rows (masked + renormalized), self entries kept.
    row_ptr: Vec<u32>,
    entries: Vec<RowEntry>,
    /// Parallel to `entries`: resolve this entry from the stale cache?
    stale: Vec<bool>,
    /// Per-row flag so fresh rows skip straight to `mix_row`.
    row_has_stale: Vec<bool>,
    /// Previous round's published vectors (what a straggler's neighbors
    /// mix instead of the fresh message).
    cache: Vec<Vec<f32>>,
    cache_warm: bool,
    /// Can stale delivery be simulated faithfully? True for optimizers
    /// that publish ONE quantity per round (the cache then holds the
    /// previous round's same quantity). Optimizers with multi-payload
    /// rounds (da-dmsgd exchanges momentum AND parameters) would mix a
    /// cached payload of the wrong kind, so for them straggle/stale
    /// faults degrade to symmetric edge masking instead: the
    /// deadline-missed message is lost, not replayed.
    stale_capable: bool,
    stats: FaultStats,
}

impl FaultyEngine {
    pub fn new(plan: FaultPlan) -> FaultyEngine {
        FaultyEngine {
            plan,
            n: 0,
            row_ptr: Vec::new(),
            entries: Vec::new(),
            stale: Vec::new(),
            row_has_stale: Vec::new(),
            cache: Vec::new(),
            cache_warm: false,
            stale_capable: true,
            stats: FaultStats::default(),
        }
    }

    /// Disable stale-message substitution (multi-payload optimizers):
    /// straggle/stale faults become symmetric edge masks instead.
    pub fn set_stale_capable(&mut self, capable: bool) {
        self.stale_capable = capable;
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn stats(&self) -> &FaultStats {
        &self.stats
    }

    /// Any nonzero rate? (Zero-rate engines are pass-throughs; the
    /// trainer also skips the stale cache entirely for them.)
    pub fn active(&self) -> bool {
        !self.plan.spec.is_zero()
    }

    /// Does this engine need `record_publish` after each round?
    pub fn needs_publish_cache(&self) -> bool {
        self.stale_capable && self.plan.spec.wants_stale()
    }

    /// Realize step `step`'s faults over the nominal engine: rebuild the
    /// masked + renormalized rows in place, O(n + edges).
    pub fn begin_step(&mut self, step: usize, nominal: &dyn CommEngine) {
        let n = nominal.n();
        self.n = n;
        let faults = self.plan.node_faults(step, n);
        self.row_ptr.clear();
        self.entries.clear();
        self.stale.clear();
        self.row_has_stale.clear();
        self.row_ptr.push(0);
        let warm = self.cache_warm;
        let (mut realized_dir, mut masked_dir, mut stale_dir) = (0usize, 0usize, 0usize);
        for i in 0..n {
            // Weight folded back into w_ii from this row's masked edges.
            let mut returned = 0.0f64;
            let mut self_slot = None;
            let mut any_stale = false;
            for &(j, w) in nominal.row(i) {
                let ju = j as usize;
                if ju == i {
                    self_slot = Some(self.entries.len());
                    self.entries.push((j, w));
                    self.stale.push(false);
                    continue;
                }
                let mut masked = faults.dropped[i]
                    || faults.dropped[ju]
                    || self.plan.link_failed(step, i, ju);
                if !self.stale_capable {
                    // No faithful stale replay: the deadline-missed
                    // message is lost. Symmetric predicate (either
                    // endpoint straggling kills the whole exchange) so
                    // the renormalized weights stay doubly stochastic.
                    masked = masked
                        || faults.straggler[i]
                        || faults.straggler[ju]
                        || self.plan.link_stale(step, i, ju);
                }
                if masked {
                    returned += w as f64;
                    masked_dir += 1;
                    continue;
                }
                let is_stale = self.stale_capable
                    && warm
                    && (faults.straggler[ju] || self.plan.link_stale(step, i, ju));
                self.entries.push((j, w));
                self.stale.push(is_stale);
                any_stale |= is_stale;
                realized_dir += 1;
                if is_stale {
                    stale_dir += 1;
                }
            }
            let slot = self_slot.expect("MH rows always carry a self entry");
            // Renormalization: masked weight returns to the diagonal.
            // `+= 0.0` when nothing was masked, so zero-rate plans keep
            // the nominal weights bit-for-bit.
            self.entries[slot].1 += returned as f32;
            self.row_ptr.push(self.entries.len() as u32);
            self.row_has_stale.push(any_stale);
        }
        self.stats.steps += 1;
        self.stats.nominal_edges += nominal.num_edges();
        // The mask predicate is symmetric, so directed counts are even.
        self.stats.realized_edges += realized_dir / 2;
        self.stats.masked_edges += masked_dir / 2;
        self.stats.stale_messages += stale_dir;
        self.stats.dropped_node_steps += faults.dropped.iter().filter(|&&d| d).count();
        self.stats.straggler_node_steps +=
            faults.straggler.iter().filter(|&&s| s).count();
    }

    /// Record this round's published vectors as the next round's stale
    /// payloads. Call after the optimizer round (the trainer does).
    pub fn record_publish(&mut self, publish: &[Vec<f32>]) {
        if self.cache.len() == publish.len()
            && self.cache.first().map(|c| c.len()) == publish.first().map(|p| p.len())
        {
            for (c, p) in self.cache.iter_mut().zip(publish) {
                c.copy_from_slice(p);
            }
        } else {
            self.cache = publish.to_vec();
        }
        self.cache_warm = true;
    }
}

impl CommEngine for FaultyEngine {
    fn n(&self) -> usize {
        self.n
    }

    fn row(&self, i: usize) -> &[RowEntry] {
        &self.entries[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize]
    }

    /// Resolve stale entries against the publish cache; rows without
    /// stale entries take the exact default kernel. Allocation-free
    /// like [`mix_row`], with the same pairwise term fusion — only the
    /// per-entry source lookup differs.
    fn mix_node(&self, i: usize, src: &[Vec<f32>], out: &mut [f32]) {
        let (start, end) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        let row = &self.entries[start..end];
        if !self.row_has_stale[i] {
            mix_row(row, src, out);
            return;
        }
        let stale = &self.stale[start..end];
        fn pick<'a>(
            k: usize,
            row: &[RowEntry],
            stale: &[bool],
            cache: &'a [Vec<f32>],
            src: &'a [Vec<f32>],
        ) -> &'a [f32] {
            let j = row[k].0 as usize;
            if stale[k] {
                &cache[j]
            } else {
                &src[j]
            }
        }
        let len = row.len();
        let w0 = row[0].1;
        for (o, &x) in out.iter_mut().zip(pick(0, row, stale, &self.cache, src)) {
            *o = w0 * x;
        }
        let mut k = 1;
        while k + 1 < len {
            let (wa, wb) = (row[k].1, row[k + 1].1);
            let xa = pick(k, row, stale, &self.cache, src);
            let xb = pick(k + 1, row, stale, &self.cache, src);
            for ((o, &a), &b) in out.iter_mut().zip(xa).zip(xb) {
                *o += wa * a + wb * b;
            }
            k += 2;
        }
        if k < len {
            math::axpy(out, row[k].1, pick(k, row, stale, &self.cache, src));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::plan::FaultSpec;
    use super::*;
    use crate::topology::{Kind, SparseWeights, Topology};

    fn engine(spec: &str) -> FaultyEngine {
        FaultyEngine::new(FaultPlan::new(FaultSpec::parse(spec, 11).unwrap()))
    }

    #[test]
    fn zero_rate_rows_match_nominal_bitwise() {
        let topo = Topology::build(Kind::SymExp, 12);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("");
        for step in 0..4 {
            f.begin_step(step, &nominal);
            assert_eq!(f.n(), nominal.n());
            for i in 0..12 {
                assert_eq!(f.row(i), nominal.row(i), "step {step} row {i}");
            }
            assert_eq!(f.num_edges(), nominal.num_edges());
        }
        assert!(!f.active());
    }

    #[test]
    fn full_dropout_is_identity_matrix() {
        let topo = Topology::build(Kind::Ring, 6);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("drop=1");
        f.begin_step(0, &nominal);
        for i in 0..6 {
            assert_eq!(f.row(i).len(), 1, "row {i}");
            let (j, w) = f.row(i)[0];
            assert_eq!(j as usize, i);
            assert!((w - 1.0).abs() < 1e-6, "w_{i}{i} = {w}");
        }
        assert_eq!(f.num_edges(), 0);
        assert_eq!(f.stats().masked_edges, 6);
        assert_eq!(f.stats().realized_edges, 0);
        assert_eq!(f.stats().dropped_node_steps, 6);
    }

    #[test]
    fn masked_weights_return_to_both_diagonals() {
        // Fail every link: each node's self weight becomes its row sum.
        let topo = Topology::build(Kind::Star, 5);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("link=1");
        f.begin_step(3, &nominal);
        assert!(f.row_sum_error() < 1e-6);
        for i in 0..5 {
            assert!((f.self_weight(i) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn stale_entries_mix_from_cache() {
        let topo = Topology::build(Kind::Ring, 4);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("stale=1");
        let old: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32]).collect();
        let fresh: Vec<Vec<f32>> = (0..4).map(|i| vec![10.0 + i as f32]).collect();

        // Cold cache: stale entries deliver fresh data.
        f.begin_step(0, &nominal);
        let mut out = vec![0.0f32];
        f.mix_node(0, &fresh, &mut out);
        let fresh_mix = out[0];

        // Warm cache: neighbor entries resolve against `old`, the self
        // entry stays fresh.
        f.record_publish(&old);
        f.begin_step(1, &nominal);
        f.mix_node(0, &fresh, &mut out);
        let want: f32 = f
            .row(0)
            .iter()
            .map(|&(j, w)| {
                let v = if j == 0 { fresh[0][0] } else { old[j as usize][0] };
                w * v
            })
            .sum();
        assert!((out[0] - want).abs() < 1e-6, "{} vs {want}", out[0]);
        assert!((out[0] - fresh_mix).abs() > 1.0, "staleness had no effect");
        assert!(f.stats().stale_messages > 0);
    }

    #[test]
    fn straggler_outgoing_messages_are_stale_incoming_fresh() {
        let topo = Topology::build(Kind::Ring, 4);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("straggle=1");
        f.begin_step(0, &nominal);
        f.record_publish(&(0..4).map(|i| vec![i as f32]).collect::<Vec<_>>());
        f.begin_step(1, &nominal);
        for i in 0..4 {
            let start = f.row_ptr[i] as usize;
            for (k, &(j, _)) in f.row(i).iter().enumerate() {
                let expect_stale = j as usize != i; // every sender straggles
                assert_eq!(f.stale[start + k], expect_stale, "row {i} entry {j}");
            }
        }
        assert_eq!(f.stats().straggler_node_steps, 8);
    }

    #[test]
    fn multi_payload_mode_masks_instead_of_staling() {
        // With stale replay disabled (multi-payload optimizers), a
        // straggler kills its exchanges symmetrically instead of being
        // served from the cache — weights must stay doubly stochastic.
        let topo = Topology::build(Kind::Ring, 6);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("straggle=1");
        f.set_stale_capable(false);
        assert!(!f.needs_publish_cache());
        f.begin_step(0, &nominal);
        for i in 0..6 {
            assert_eq!(f.row(i).len(), 1, "row {i} should be fully masked");
        }
        assert!(f.row_sum_error() < 1e-6);
        assert_eq!(f.stats().stale_messages, 0);
        assert_eq!(f.stats().masked_edges, 6);
    }

    #[test]
    fn realized_stats_accumulate() {
        let topo = Topology::build(Kind::Ring, 8);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("drop=0.4,seed=3");
        for step in 0..50 {
            f.begin_step(step, &nominal);
            assert_eq!(
                f.stats().realized_edges + f.stats().masked_edges,
                f.stats().nominal_edges
            );
        }
        let s = f.stats();
        assert_eq!(s.steps, 50);
        assert_eq!(s.nominal_edges, 8 * 50);
        assert!(s.masked_edges > 0 && s.realized_edges > 0);
        let frac = s.realized_edge_fraction();
        // P(edge survives) = (1-0.4)^2 = 0.36.
        assert!((0.2..0.55).contains(&frac), "realized fraction {frac}");
    }
}
