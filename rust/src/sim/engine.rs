//! The fault-injecting comm engine (DESIGN.md §6).
//!
//! [`FaultyEngine`] wraps a nominal mixing-weight engine and realizes
//! one step of the [`FaultPlan`] on top of it:
//!
//! * **masking** — edges incident to a dropped node, and links the plan
//!   fails this step, are removed from both rows;
//! * **renormalization** — each masked edge's Metropolis–Hastings
//!   weight w_ij is folded back into w_ii *and* w_jj. The mask set is
//!   symmetric and the nominal matrix is symmetric doubly stochastic,
//!   so the realized matrix stays symmetric doubly stochastic (row sums
//!   are untouched; the property suite pins it);
//! * **staleness** — entries whose sender straggled (or whose link the
//!   plan marked stale) keep their weight but are resolved against the
//!   engine's cache of the *previous* round's published vectors instead
//!   of this round's `src`. Until the cache is warm (before the first
//!   `record_publish`) stale entries deliver fresh data — staleness
//!   starts at step 1 at the earliest.
//!
//! The rebuild reuses the CSR allocation path of
//! [`crate::topology::sparse::SparseWeights`]: `begin_step` rewrites
//! `row_ptr` + entry lists in O(n + edges) without touching a dense
//! matrix. Rows with no stale entry mix through the exact same
//! [`mix_row`] kernel as every other engine, which makes a zero-rate
//! plan bitwise identical to the fault-free engine (tested), and
//! per-row mixing stays independent across nodes, so parallel execution
//! remains bitwise equal to serial under faults.
//!
//! Cost accounting is *realized*, not nominal: the engine's rows after
//! masking are what [`crate::comm::cost::CommStats::of_engine`] sees,
//! and [`FaultStats`] accumulates the realized/masked/stale totals a
//! sweep reports.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::comm::engine::{mix_row, CommEngine, RowEntry};
use crate::util::math;

use super::clock::AsyncSchedule;
use super::plan::FaultPlan;

/// Cumulative fault accounting across `begin_step` calls.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Steps realized.
    pub steps: usize,
    /// Undirected edge totals of the nominal topology.
    pub nominal_edges: usize,
    /// Undirected edges that actually carried a message (incl. stale).
    pub realized_edges: usize,
    /// Undirected edges masked (dropout or link failure).
    pub masked_edges: usize,
    /// Directed stale deliveries (message served from the cache).
    pub stale_messages: usize,
    /// Directed deliveries the async bounded-staleness schedule served
    /// from a past round's ring cache (DESIGN.md §8).
    pub async_stale_messages: usize,
    /// Node-steps spent fully dropped out.
    pub dropped_node_steps: usize,
    /// Node-steps spent straggling.
    pub straggler_node_steps: usize,
}

impl FaultStats {
    /// Fraction of nominal edges that carried a message.
    pub fn realized_edge_fraction(&self) -> f64 {
        if self.nominal_edges == 0 {
            1.0
        } else {
            self.realized_edges as f64 / self.nominal_edges as f64
        }
    }
}

/// Per-exchange-slot ring caches of past rounds' wire payloads, behind
/// a mutex because [`CommEngine::begin_exchange`] runs on a shared
/// `&self`. da-dmsgd's two exchanges per round (momentum, then
/// parameters) each get their own slot, so an entry aged `d` always
/// replays the *same payload kind* from `d` rounds ago — the reason the
/// PR-2 fault path had to downgrade multi-payload staleness to masking
/// disappears here.
#[derive(Debug, Default)]
struct SlotCaches {
    /// rings[slot][age − 1] = that slot's payloads from `age` rounds ago.
    rings: Vec<VecDeque<Vec<Vec<f32>>>>,
    /// This round's payloads per slot; committed to the rings at the
    /// next `begin_step` (a round must never read its own publish as
    /// history).
    staged: Vec<Vec<Vec<f32>>>,
    /// Retired ring entries recycled as staging buffers (keeps the
    /// async step loop allocation-free after warmup).
    spare: Vec<Vec<Vec<f32>>>,
    /// Slot the in-flight exchange resolves against.
    cur_slot: usize,
    /// Exchanges seen this round (the slot allocator).
    seen: usize,
    /// Ring depth = the schedule's staleness bound τ.
    depth: usize,
}

/// A comm engine that masks, renormalizes and staleness-injects a
/// nominal engine's rows according to a [`FaultPlan`] — and, when an
/// [`AsyncSchedule`] is attached, replays the discrete-event clock
/// sim's bounded staleness from per-slot ring caches (DESIGN.md §8).
#[derive(Debug)]
pub struct FaultyEngine {
    plan: FaultPlan,
    n: usize,
    /// Realized CSR rows (masked + renormalized), self entries kept.
    row_ptr: Vec<u32>,
    entries: Vec<RowEntry>,
    /// Parallel to `entries`: resolve this entry from the stale cache?
    stale: Vec<bool>,
    /// Per-row flag so fresh rows skip straight to `mix_row`.
    row_has_stale: Vec<bool>,
    /// Previous round's published vectors (what a straggler's neighbors
    /// mix instead of the fresh message).
    cache: Vec<Vec<f32>>,
    cache_warm: bool,
    /// Can stale delivery be simulated faithfully? True for optimizers
    /// that publish ONE quantity per round (the cache then holds the
    /// previous round's same quantity). Optimizers with multi-payload
    /// rounds (da-dmsgd exchanges momentum AND parameters) would mix a
    /// cached payload of the wrong kind, so for them straggle/stale
    /// faults degrade to symmetric edge masking instead: the
    /// deadline-missed message is lost, not replayed. (The async ring
    /// caches below are per-slot and exempt from this restriction.)
    stale_capable: bool,
    /// Bounded-staleness schedule from `sim::clock` (None = the PR-2
    /// synchronous behavior, bit for bit).
    async_sched: Option<AsyncSchedule>,
    /// Will any mix ever read the ring history? True when the schedule
    /// realized staleness OR the fault plan wants stale replay. False
    /// keeps `begin_exchange` a no-op, so all-fresh async runs (uniform
    /// clocks, τ = 0) pay zero copies.
    ring_needed: bool,
    /// Parallel to `entries`: ring age this entry resolves at (0 =
    /// fresh `src`). Only nonzero while an async schedule is attached;
    /// fault-origin stales fold in at age 1.
    async_age: Vec<u16>,
    /// Per-row flag for the async resolver path.
    row_has_async: Vec<bool>,
    /// Optional dense→stable id remap for the plan's streams (elastic
    /// membership, DESIGN.md §9): fault draws key on `ids[i]` instead
    /// of the dense row, so the schedule follows physical nodes across
    /// roster resizes. None = identity (the fixed-roster fast path,
    /// bit-identical to the pre-elastic engine).
    ids: Option<Vec<u32>>,
    slots: Mutex<SlotCaches>,
    stats: FaultStats,
}

impl FaultyEngine {
    pub fn new(plan: FaultPlan) -> FaultyEngine {
        FaultyEngine {
            plan,
            n: 0,
            row_ptr: Vec::new(),
            entries: Vec::new(),
            stale: Vec::new(),
            row_has_stale: Vec::new(),
            cache: Vec::new(),
            cache_warm: false,
            stale_capable: true,
            async_sched: None,
            ring_needed: false,
            async_age: Vec::new(),
            row_has_async: Vec::new(),
            ids: None,
            slots: Mutex::new(SlotCaches::default()),
            stats: FaultStats::default(),
        }
    }

    /// Install (or clear) the dense→stable id remap for the fault
    /// plan's streams. Length must match the nominal engine's node
    /// count at the next `begin_step`.
    pub fn set_ids(&mut self, ids: Option<Vec<u32>>) {
        self.ids = ids;
    }

    /// Drop the publish cache. Elastic resizes call this: a roster
    /// change invalidates the per-dense-row history, so the first round
    /// after a resize serves fresh messages while the cache re-warms —
    /// the same rule as the cold-start warmup.
    pub fn clear_cache(&mut self) {
        self.cache.clear();
        self.cache_warm = false;
    }

    /// The previous round's publish cache for checkpointing (None when
    /// cold — before the first `record_publish` or right after a
    /// resize).
    pub fn export_cache(&self) -> Option<Vec<Vec<f32>>> {
        if self.cache_warm {
            Some(self.cache.clone())
        } else {
            None
        }
    }

    /// Restore a cache captured by [`FaultyEngine::export_cache`].
    pub fn restore_cache(&mut self, cache: Option<Vec<Vec<f32>>>) {
        match cache {
            Some(c) => {
                self.cache = c;
                self.cache_warm = true;
            }
            None => self.clear_cache(),
        }
    }

    /// Overwrite the cumulative fault accounting (checkpoint resume —
    /// stats keep counting from where the saved run left off).
    pub fn restore_stats(&mut self, stats: FaultStats) {
        self.stats = stats;
    }

    /// Per-exchange-slot async ring history for checkpointing:
    /// `(ring newest→oldest, staged)` per slot. Empty when the rings
    /// never engaged (synchronous runs, all-fresh schedules).
    pub fn export_rings(&self) -> Vec<(Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>)> {
        let s = self.slots.lock().unwrap();
        s.rings
            .iter()
            .zip(&s.staged)
            .map(|(ring, staged)| (ring.iter().cloned().collect(), staged.clone()))
            .collect()
    }

    /// Restore ring history captured by [`FaultyEngine::export_rings`].
    /// The ring depth itself is derived from the attached schedule
    /// (`set_async`), not from the snapshot.
    pub fn restore_rings(&mut self, slots: Vec<(Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>)>) {
        let s = self.slots.get_mut().unwrap();
        s.rings.clear();
        s.staged.clear();
        for (ring, staged) in slots {
            s.rings.push(ring.into_iter().collect());
            s.staged.push(staged);
        }
        s.spare.clear();
        s.seen = 0;
        s.cur_slot = 0;
    }

    /// Attach a bounded-staleness schedule from the discrete-event
    /// clock sim. Entries the schedule marks stale resolve against
    /// per-exchange-slot ring caches of past wire payloads (recorded by
    /// [`CommEngine::begin_exchange`]); fault-origin stales fold into
    /// the same rings at age 1 and the trainer-driven single cache goes
    /// unused ([`FaultyEngine::needs_publish_cache`] turns false).
    pub fn set_async(&mut self, sched: AsyncSchedule) {
        // The ring must cover the schedule's window AND the age-1
        // replay fault stales need — a τ = 0 window with a straggle/
        // stale fault plan still keeps one round of history (otherwise
        // those faults would silently become no-ops). Conversely, an
        // all-fresh schedule with no stale-wanting plan never reads the
        // rings, so the recording path stays off entirely.
        let wants_fault_stale = self.plan.spec.wants_stale();
        self.slots.get_mut().unwrap().depth = sched.tau().max(wants_fault_stale as usize);
        self.ring_needed = sched.max_staleness() > 0 || wants_fault_stale;
        self.async_sched = Some(sched);
    }

    /// Does the attached schedule ever deliver a stale payload? False
    /// when no schedule is attached or when it realized all-fresh
    /// (uniform clocks / τ = 0) — the trainer's time-varying guard keys
    /// off this so all-fresh async runs stay bitwise synchronous.
    pub fn async_engaged(&self) -> bool {
        self.async_sched.as_ref().is_some_and(|s| s.max_staleness() > 0)
    }

    pub fn async_schedule(&self) -> Option<&AsyncSchedule> {
        self.async_sched.as_ref()
    }

    /// Disable stale-message substitution (multi-payload optimizers):
    /// straggle/stale faults become symmetric edge masks instead.
    pub fn set_stale_capable(&mut self, capable: bool) {
        self.stale_capable = capable;
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn stats(&self) -> &FaultStats {
        &self.stats
    }

    /// Any nonzero rate? (Zero-rate engines are pass-throughs; the
    /// trainer also skips the stale cache entirely for them.)
    pub fn active(&self) -> bool {
        !self.plan.spec.is_zero()
    }

    /// Does this engine need `record_publish` after each round? Not in
    /// async mode: there the per-slot rings recorded by
    /// `begin_exchange` hold the history, including what fault-origin
    /// stales replay.
    pub fn needs_publish_cache(&self) -> bool {
        self.stale_capable && self.plan.spec.wants_stale() && self.async_sched.is_none()
    }

    /// Realize step `step`'s faults over the nominal engine: rebuild the
    /// masked + renormalized rows in place, O(n + edges). With an async
    /// schedule attached, also commit last round's staged payloads to
    /// the ring history and stamp each surviving entry with the age the
    /// schedule assigns it at this global step.
    pub fn begin_step(&mut self, step: usize, nominal: &dyn CommEngine) {
        // Commit staged payloads: they are now one round old. Retired
        // entries past the ring depth are recycled as staging buffers.
        if self.ring_needed {
            let s = self.slots.get_mut().unwrap();
            for slot in 0..s.staged.len() {
                if s.staged[slot].is_empty() {
                    continue;
                }
                let staged = std::mem::take(&mut s.staged[slot]);
                s.rings[slot].push_front(staged);
                if s.rings[slot].len() > s.depth.max(1) {
                    if let Some(old) = s.rings[slot].pop_back() {
                        s.spare.push(old);
                    }
                }
            }
            s.seen = 0;
            s.cur_slot = 0;
        }
        // Fault-origin stales need one round of ring history before
        // they can replay (same warmup rule as the PR-2 cache).
        let async_warm = self.async_sched.is_some()
            && self.slots.get_mut().unwrap().rings.first().is_some_and(|r| !r.is_empty());
        let sched = self.async_sched.as_ref();
        let n = nominal.n();
        self.n = n;
        // Stable-id view of the roster: fault draws key on `sid(i)`, so
        // an elastic resize repacks the dense rows without perturbing
        // any physical node's schedule. Identity when no remap is set.
        let ids = self.ids.clone();
        if let Some(v) = &ids {
            assert_eq!(v.len(), n, "fault-plan id remap out of sync with the roster");
        }
        let sid = |i: usize| -> usize { ids.as_ref().map_or(i, |v| v[i] as usize) };
        let faults = self.plan.node_faults_mapped(step, n, ids.as_deref());
        self.row_ptr.clear();
        self.entries.clear();
        self.stale.clear();
        self.row_has_stale.clear();
        self.async_age.clear();
        self.row_has_async.clear();
        self.row_ptr.push(0);
        let warm = self.cache_warm;
        let (mut realized_dir, mut masked_dir, mut stale_dir) = (0usize, 0usize, 0usize);
        let mut async_stale_dir = 0usize;
        for i in 0..n {
            // Weight folded back into w_ii from this row's masked edges.
            let mut returned = 0.0f64;
            let mut self_slot = None;
            let mut any_stale = false;
            let mut any_async = false;
            // Schedule row for this step (None past the horizon → all
            // fresh), aligned by non-self ordinal with the nominal row.
            let srow = sched.and_then(|sc| sc.staleness(step, i));
            let mut ord = 0usize;
            for &(j, w) in nominal.row(i) {
                let ju = j as usize;
                if ju == i {
                    self_slot = Some(self.entries.len());
                    self.entries.push((j, w));
                    self.stale.push(false);
                    self.async_age.push(0);
                    continue;
                }
                let sched_age = match srow {
                    Some(ss) => {
                        debug_assert_eq!(
                            sched.map(|sc| sc.neighbors(i)[ord]),
                            Some(j),
                            "async schedule misaligned with the nominal rows"
                        );
                        let a = ss[ord];
                        ord += 1;
                        a
                    }
                    None => 0,
                };
                let mut masked = faults.dropped[i]
                    || faults.dropped[ju]
                    || self.plan.link_failed(step, sid(i), sid(ju));
                if !self.stale_capable && sched.is_none() {
                    // No faithful stale replay: the deadline-missed
                    // message is lost. Symmetric predicate (either
                    // endpoint straggling kills the whole exchange) so
                    // the renormalized weights stay doubly stochastic.
                    // In async mode the per-slot rings replay the right
                    // payload kind, so multi-payload rounds are exempt.
                    masked = masked
                        || faults.straggler[i]
                        || faults.straggler[ju]
                        || self.plan.link_stale(step, sid(i), sid(ju));
                }
                if masked {
                    returned += w as f64;
                    masked_dir += 1;
                    continue;
                }
                let fault_stale = (self.stale_capable || sched.is_some())
                    && if sched.is_some() { async_warm } else { warm }
                    && (faults.straggler[ju] || self.plan.link_stale(step, sid(i), sid(ju)));
                self.entries.push((j, w));
                realized_dir += 1;
                if sched.is_some() {
                    // Async resolver: fault stales fold in at age 1;
                    // the legacy single-cache flags stay off.
                    let age = sched_age.max(fault_stale as u16);
                    self.stale.push(false);
                    self.async_age.push(age);
                    any_async |= age > 0;
                    if sched_age > 0 {
                        async_stale_dir += 1;
                    }
                } else {
                    self.stale.push(fault_stale);
                    self.async_age.push(0);
                    any_stale |= fault_stale;
                }
                if fault_stale {
                    stale_dir += 1;
                }
            }
            let slot = self_slot.expect("MH rows always carry a self entry");
            // Renormalization: masked weight returns to the diagonal.
            // `+= 0.0` when nothing was masked, so zero-rate plans keep
            // the nominal weights bit-for-bit.
            self.entries[slot].1 += returned as f32;
            self.row_ptr.push(self.entries.len() as u32);
            self.row_has_stale.push(any_stale);
            self.row_has_async.push(any_async);
        }
        self.stats.steps += 1;
        self.stats.nominal_edges += nominal.num_edges();
        // The mask predicate is symmetric, so directed counts are even.
        self.stats.realized_edges += realized_dir / 2;
        self.stats.masked_edges += masked_dir / 2;
        self.stats.stale_messages += stale_dir;
        self.stats.async_stale_messages += async_stale_dir;
        self.stats.dropped_node_steps += faults.dropped.iter().filter(|&&d| d).count();
        self.stats.straggler_node_steps +=
            faults.straggler.iter().filter(|&&s| s).count();
    }

    /// Record this round's published vectors as the next round's stale
    /// payloads. Call after the optimizer round (the trainer does).
    pub fn record_publish(&mut self, publish: &[Vec<f32>]) {
        if self.cache.len() == publish.len()
            && self.cache.first().map(|c| c.len()) == publish.first().map(|p| p.len())
        {
            for (c, p) in self.cache.iter_mut().zip(publish) {
                c.copy_from_slice(p);
            }
        } else {
            self.cache = publish.to_vec();
        }
        self.cache_warm = true;
    }

    /// The async mix resolver: entries aged `a ≥ 1` read the current
    /// exchange slot's ring at depth `a − 1` (the payload of `a` rounds
    /// ago), fresh entries read `src`. One lock per stale row; the ring
    /// is read-only during the fan-out, so parallel == serial holds.
    fn mix_node_async(
        &self,
        i: usize,
        start: usize,
        end: usize,
        src: &[Vec<f32>],
        out: &mut [f32],
    ) {
        let row = &self.entries[start..end];
        let age = &self.async_age[start..end];
        let slots = self.slots.lock().unwrap();
        assert!(
            slots.cur_slot < slots.rings.len(),
            "async staleness requires exchanges to flow through gossip_exchange \
             (begin_exchange never ran for node {i})"
        );
        let ring = &slots.rings[slots.cur_slot];
        fn pick<'a>(
            k: usize,
            row: &[RowEntry],
            age: &[u16],
            ring: &'a VecDeque<Vec<Vec<f32>>>,
            src: &'a [Vec<f32>],
        ) -> &'a [f32] {
            let j = row[k].0 as usize;
            match age[k] {
                0 => &src[j],
                a => &ring[(a - 1) as usize][j],
            }
        }
        let len = row.len();
        let w0 = row[0].1;
        for (o, &x) in out.iter_mut().zip(pick(0, row, age, ring, src)) {
            *o = w0 * x;
        }
        let mut k = 1;
        while k + 1 < len {
            let (wa, wb) = (row[k].1, row[k + 1].1);
            let xa = pick(k, row, age, ring, src);
            let xb = pick(k + 1, row, age, ring, src);
            for ((o, &a), &b) in out.iter_mut().zip(xa).zip(xb) {
                *o += wa * a + wb * b;
            }
            k += 2;
        }
        if k < len {
            math::axpy(out, row[k].1, pick(k, row, age, ring, src));
        }
    }
}

impl CommEngine for FaultyEngine {
    fn n(&self) -> usize {
        self.n
    }

    fn row(&self, i: usize) -> &[RowEntry] {
        &self.entries[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize]
    }

    /// Snapshot the exchange's wire view into this round's staging slot
    /// (async mode only; a no-op otherwise, so the PR-2 paths cost
    /// nothing). Runs once per exchange on the orchestrating thread,
    /// before the per-row mix fan-out — the parallel mixes then only
    /// read, so parallel == serial still holds.
    fn begin_exchange(&self, src: &[Vec<f32>]) {
        if !self.ring_needed {
            // No schedule, all-fresh schedule, or no stale-wanting
            // fault plan: nothing will ever read the rings.
            return;
        }
        let mut s = self.slots.lock().unwrap();
        let slot = s.seen;
        s.seen += 1;
        s.cur_slot = slot;
        while s.rings.len() <= slot {
            s.rings.push(VecDeque::new());
            s.staged.push(Vec::new());
        }
        let same_shape = |b: &Vec<Vec<f32>>| {
            b.len() == src.len() && b.first().map(|r| r.len()) == src.first().map(|r| r.len())
        };
        let buf = match s.spare.pop() {
            Some(mut b) if same_shape(&b) => {
                for (dst, src_row) in b.iter_mut().zip(src) {
                    dst.copy_from_slice(src_row);
                }
                b
            }
            _ => src.to_vec(),
        };
        s.staged[slot] = buf;
    }

    /// Resolve stale entries against the publish cache (fault mode) or
    /// the per-slot ring history (async mode); rows without stale
    /// entries take the exact default kernel. Allocation-free like
    /// [`mix_row`], with the same pairwise term fusion — only the
    /// per-entry source lookup differs.
    fn mix_node(&self, i: usize, src: &[Vec<f32>], out: &mut [f32]) {
        let (start, end) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        let row = &self.entries[start..end];
        if self.row_has_async.get(i).copied().unwrap_or(false) {
            self.mix_node_async(i, start, end, src, out);
            return;
        }
        if !self.row_has_stale[i] {
            mix_row(row, src, out);
            return;
        }
        let stale = &self.stale[start..end];
        fn pick<'a>(
            k: usize,
            row: &[RowEntry],
            stale: &[bool],
            cache: &'a [Vec<f32>],
            src: &'a [Vec<f32>],
        ) -> &'a [f32] {
            let j = row[k].0 as usize;
            if stale[k] {
                &cache[j]
            } else {
                &src[j]
            }
        }
        let len = row.len();
        let w0 = row[0].1;
        for (o, &x) in out.iter_mut().zip(pick(0, row, stale, &self.cache, src)) {
            *o = w0 * x;
        }
        let mut k = 1;
        while k + 1 < len {
            let (wa, wb) = (row[k].1, row[k + 1].1);
            let xa = pick(k, row, stale, &self.cache, src);
            let xb = pick(k + 1, row, stale, &self.cache, src);
            for ((o, &a), &b) in out.iter_mut().zip(xa).zip(xb) {
                *o += wa * a + wb * b;
            }
            k += 2;
        }
        if k < len {
            math::axpy(out, row[k].1, pick(k, row, stale, &self.cache, src));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::plan::FaultSpec;
    use super::*;
    use crate::topology::{Kind, SparseWeights, Topology};

    fn engine(spec: &str) -> FaultyEngine {
        FaultyEngine::new(FaultPlan::new(FaultSpec::parse(spec, 11).unwrap()))
    }

    #[test]
    fn zero_rate_rows_match_nominal_bitwise() {
        let topo = Topology::build(Kind::SymExp, 12);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("");
        for step in 0..4 {
            f.begin_step(step, &nominal);
            assert_eq!(f.n(), nominal.n());
            for i in 0..12 {
                assert_eq!(f.row(i), nominal.row(i), "step {step} row {i}");
            }
            assert_eq!(f.num_edges(), nominal.num_edges());
        }
        assert!(!f.active());
    }

    #[test]
    fn full_dropout_is_identity_matrix() {
        let topo = Topology::build(Kind::Ring, 6);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("drop=1");
        f.begin_step(0, &nominal);
        for i in 0..6 {
            assert_eq!(f.row(i).len(), 1, "row {i}");
            let (j, w) = f.row(i)[0];
            assert_eq!(j as usize, i);
            assert!((w - 1.0).abs() < 1e-6, "w_{i}{i} = {w}");
        }
        assert_eq!(f.num_edges(), 0);
        assert_eq!(f.stats().masked_edges, 6);
        assert_eq!(f.stats().realized_edges, 0);
        assert_eq!(f.stats().dropped_node_steps, 6);
    }

    #[test]
    fn masked_weights_return_to_both_diagonals() {
        // Fail every link: each node's self weight becomes its row sum.
        let topo = Topology::build(Kind::Star, 5);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("link=1");
        f.begin_step(3, &nominal);
        assert!(f.row_sum_error() < 1e-6);
        for i in 0..5 {
            assert!((f.self_weight(i) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn stale_entries_mix_from_cache() {
        let topo = Topology::build(Kind::Ring, 4);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("stale=1");
        let old: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32]).collect();
        let fresh: Vec<Vec<f32>> = (0..4).map(|i| vec![10.0 + i as f32]).collect();

        // Cold cache: stale entries deliver fresh data.
        f.begin_step(0, &nominal);
        let mut out = vec![0.0f32];
        f.mix_node(0, &fresh, &mut out);
        let fresh_mix = out[0];

        // Warm cache: neighbor entries resolve against `old`, the self
        // entry stays fresh.
        f.record_publish(&old);
        f.begin_step(1, &nominal);
        f.mix_node(0, &fresh, &mut out);
        let want: f32 = f
            .row(0)
            .iter()
            .map(|&(j, w)| {
                let v = if j == 0 { fresh[0][0] } else { old[j as usize][0] };
                w * v
            })
            .sum();
        assert!((out[0] - want).abs() < 1e-6, "{} vs {want}", out[0]);
        assert!((out[0] - fresh_mix).abs() > 1.0, "staleness had no effect");
        assert!(f.stats().stale_messages > 0);
    }

    #[test]
    fn straggler_outgoing_messages_are_stale_incoming_fresh() {
        let topo = Topology::build(Kind::Ring, 4);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("straggle=1");
        f.begin_step(0, &nominal);
        f.record_publish(&(0..4).map(|i| vec![i as f32]).collect::<Vec<_>>());
        f.begin_step(1, &nominal);
        for i in 0..4 {
            let start = f.row_ptr[i] as usize;
            for (k, &(j, _)) in f.row(i).iter().enumerate() {
                let expect_stale = j as usize != i; // every sender straggles
                assert_eq!(f.stale[start + k], expect_stale, "row {i} entry {j}");
            }
        }
        assert_eq!(f.stats().straggler_node_steps, 8);
    }

    #[test]
    fn multi_payload_mode_masks_instead_of_staling() {
        // With stale replay disabled (multi-payload optimizers), a
        // straggler kills its exchanges symmetrically instead of being
        // served from the cache — weights must stay doubly stochastic.
        let topo = Topology::build(Kind::Ring, 6);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("straggle=1");
        f.set_stale_capable(false);
        assert!(!f.needs_publish_cache());
        f.begin_step(0, &nominal);
        for i in 0..6 {
            assert_eq!(f.row(i).len(), 1, "row {i} should be fully masked");
        }
        assert!(f.row_sum_error() < 1e-6);
        assert_eq!(f.stats().stale_messages, 0);
        assert_eq!(f.stats().masked_edges, 6);
    }

    #[test]
    fn all_fresh_async_schedule_is_bitwise_nominal() {
        // A τ=2 schedule whose realized ages are all zero (what uniform
        // clocks produce) must leave rows AND mixing bit-identical to
        // the plain zero-rate engine — the foundation of the trainer's
        // "async(uniform, tau=0) == sync" guarantee.
        let topo = Topology::build(Kind::SymExp, 8);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let nnz = (0..8).map(|i| nominal.row(i).len() - 1).sum::<usize>();
        let mut f = engine("");
        f.set_async(super::super::clock::AsyncSchedule::handmade(
            &nominal,
            2,
            vec![vec![0u16; nnz]; 3],
        ));
        assert!(!f.async_engaged(), "all-fresh schedule must not engage the guard");
        let src: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32, -(i as f32)]).collect();
        for step in 0..3 {
            f.begin_step(step, &nominal);
            f.begin_exchange(&src);
            for i in 0..8 {
                assert_eq!(f.row(i), nominal.row(i), "step {step} row {i}");
                let mut a = vec![0.0f32; 2];
                let mut b = vec![0.0f32; 2];
                f.mix_node(i, &src, &mut a);
                nominal.mix_node(i, &src, &mut b);
                assert_eq!(a, b, "step {step} row {i} mix");
            }
        }
    }

    #[test]
    fn async_ages_replay_the_right_round_from_the_ring() {
        // Ring n=4; node 0's two neighbor entries aged 1 and 2 at step
        // 2: the mix must combine the fresh self entry with the
        // payloads staged 1 and 2 rounds ago.
        let topo = Topology::build(Kind::Ring, 4);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let nnz = (0..4).map(|i| nominal.row(i).len() - 1).sum::<usize>();
        // Node 0's row is [(0, self), (1, w), (3, w)] → non-self
        // ordinals 0 and 1 of the CSR.
        let mut step2 = vec![0u16; nnz];
        step2[0] = 1; // payload of round 1
        step2[1] = 2; // payload of round 0
        let mut f = engine("");
        f.set_async(super::super::clock::AsyncSchedule::handmade(
            &nominal,
            2,
            vec![vec![0u16; nnz], vec![0u16; nnz], step2],
        ));
        assert!(f.async_engaged());
        assert!(!f.needs_publish_cache(), "rings replace the trainer-driven cache");
        let round: Vec<Vec<Vec<f32>>> = (0..3)
            .map(|r| (0..4).map(|i| vec![100.0 * r as f32 + i as f32]).collect())
            .collect();
        let mut out = vec![0.0f32];
        for step in 0..2 {
            f.begin_step(step, &nominal);
            f.begin_exchange(&round[step]);
            f.mix_node(0, &round[step], &mut out); // fresh rounds
        }
        f.begin_step(2, &nominal);
        f.begin_exchange(&round[2]);
        f.mix_node(0, &round[2], &mut out);
        let row = f.row(0);
        let want: f32 = row
            .iter()
            .map(|&(j, w)| {
                let v = match j {
                    0 => round[2][0][0], // self: fresh
                    1 => round[1][1][0], // age 1 → round 1
                    3 => round[0][3][0], // age 2 → round 0
                    _ => unreachable!(),
                };
                w * v
            })
            .sum();
        assert!((out[0] - want).abs() < 1e-6, "{} vs {want}", out[0]);
        assert_eq!(f.stats().async_stale_messages, 2);
    }

    #[test]
    fn multi_slot_exchanges_keep_their_own_history() {
        // Two exchanges per round (the da-dmsgd shape) with different
        // payloads: an aged entry must replay its OWN slot's past
        // payload, never the other exchange's.
        let topo = Topology::build(Kind::Ring, 4);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let nnz = (0..4).map(|i| nominal.row(i).len() - 1).sum::<usize>();
        let mut step1 = vec![0u16; nnz];
        step1[0] = 1; // node 0's first neighbor (node 1), one round old
        let mut f = engine("");
        f.set_async(super::super::clock::AsyncSchedule::handmade(
            &nominal,
            1,
            vec![vec![0u16; nnz], step1],
        ));
        let momentum: Vec<Vec<f32>> = (0..4).map(|i| vec![10.0 + i as f32]).collect();
        let params: Vec<Vec<f32>> = (0..4).map(|i| vec![20.0 + i as f32]).collect();
        let mut out = vec![0.0f32];
        f.begin_step(0, &nominal);
        f.begin_exchange(&momentum); // slot 0, round 0
        f.mix_node(0, &momentum, &mut out);
        f.begin_exchange(&params); // slot 1, round 0
        f.mix_node(0, &params, &mut out);
        f.begin_step(1, &nominal);
        let fresh_m: Vec<Vec<f32>> = (0..4).map(|i| vec![30.0 + i as f32]).collect();
        let fresh_p: Vec<Vec<f32>> = (0..4).map(|i| vec![40.0 + i as f32]).collect();
        let expect = |fresh: &[Vec<f32>], old: &[Vec<f32>]| -> f32 {
            f.row(0)
                .iter()
                .map(|&(j, w)| w * if j == 1 { old[1][0] } else { fresh[j as usize][0] })
                .sum()
        };
        f.begin_exchange(&fresh_m);
        f.mix_node(0, &fresh_m, &mut out);
        let want_m = expect(&fresh_m, &momentum);
        assert!((out[0] - want_m).abs() < 1e-6, "slot 0: {} vs {want_m}", out[0]);
        f.begin_exchange(&fresh_p);
        f.mix_node(0, &fresh_p, &mut out);
        let want_p = expect(&fresh_p, &params);
        assert!((out[0] - want_p).abs() < 1e-6, "slot 1: {} vs {want_p}", out[0]);
    }

    #[test]
    fn identity_id_remap_is_bitwise_inert_and_stable_ids_follow_nodes() {
        let topo = Topology::build(Kind::Ring, 6);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        // Identity remap must realize exactly the same rows as no remap.
        let mut plain = engine("drop=0.4,link=0.3,seed=3");
        let mut mapped = engine("drop=0.4,link=0.3,seed=3");
        mapped.set_ids(Some((0..6).collect()));
        for step in 0..8 {
            plain.begin_step(step, &nominal);
            mapped.begin_step(step, &nominal);
            for i in 0..6 {
                assert_eq!(plain.row(i), mapped.row(i), "step {step} row {i}");
            }
        }
        // A non-identity remap draws the REMAPPED node's schedule: with
        // drop=1 scoped by comparing two engines whose row 0 maps to
        // different stable ids, the realizations must differ somewhere
        // over a few steps.
        let mut a = engine("drop=0.5,seed=3");
        a.set_ids(Some(vec![0, 1, 2, 3, 4, 5]));
        let mut b = engine("drop=0.5,seed=3");
        b.set_ids(Some(vec![6, 7, 8, 9, 10, 11]));
        let mut differed = false;
        for step in 0..12 {
            a.begin_step(step, &nominal);
            b.begin_step(step, &nominal);
            differed |= (0..6).any(|i| a.row(i) != b.row(i));
        }
        assert!(differed, "distinct stable ids never changed a realization");
    }

    #[test]
    fn cache_export_restore_roundtrip() {
        let topo = Topology::build(Kind::Ring, 4);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("stale=1");
        assert!(f.export_cache().is_none(), "cold cache exports None");
        let published: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32]).collect();
        f.record_publish(&published);
        let cache = f.export_cache().expect("warm cache exports Some");
        assert_eq!(cache, published);
        f.clear_cache();
        assert!(f.export_cache().is_none());
        f.restore_cache(Some(cache));
        f.begin_step(1, &nominal);
        // Restored cache serves stale entries exactly as before.
        let fresh: Vec<Vec<f32>> = (0..4).map(|i| vec![10.0 + i as f32]).collect();
        let mut out = vec![0.0f32];
        f.mix_node(0, &fresh, &mut out);
        let want: f32 = f
            .row(0)
            .iter()
            .map(|&(j, w)| {
                let v = if j == 0 { fresh[0][0] } else { published[j as usize][0] };
                w * v
            })
            .sum();
        assert!((out[0] - want).abs() < 1e-6);
    }

    #[test]
    fn realized_stats_accumulate() {
        let topo = Topology::build(Kind::Ring, 8);
        let nominal = SparseWeights::metropolis_hastings(&topo);
        let mut f = engine("drop=0.4,seed=3");
        for step in 0..50 {
            f.begin_step(step, &nominal);
            assert_eq!(
                f.stats().realized_edges + f.stats().masked_edges,
                f.stats().nominal_edges
            );
        }
        let s = f.stats();
        assert_eq!(s.steps, 50);
        assert_eq!(s.nominal_edges, 8 * 50);
        assert!(s.masked_edges > 0 && s.realized_edges > 0);
        let frac = s.realized_edge_fraction();
        // P(edge survives) = (1-0.4)^2 = 0.36.
        assert!((0.2..0.55).contains(&frac), "realized fraction {frac}");
    }
}
