//! Fault-injection simulation layer (DESIGN.md §6).
//!
//! Real decentralized deployments are not the ideal synchronous
//! networks of the paper's analysis: nodes drop out, links fail,
//! stragglers miss sync deadlines and deliver stale messages ("From
//! promise to practice", arXiv 2410.11998). This module makes those
//! regimes simulable — deterministically — on top of any
//! `topology::Kind`:
//!
//! * [`plan::FaultSpec`] / [`plan::FaultPlan`] — seeded per-step fault
//!   schedules (node dropout, link failure, straggler delay, stale
//!   links), replayable and iteration-order-free;
//! * [`engine::FaultyEngine`] — a [`crate::comm::CommEngine`] wrapper
//!   that masks failed edges, renormalizes the Metropolis–Hastings
//!   weights in place (masked weight returns to both diagonals, so the
//!   realized matrix stays symmetric doubly stochastic) and substitutes
//!   cached previous-round messages on stale entries. Realized — not
//!   nominal — edges are what the cost model charges.
//!
//! The trainer enables it via `Config::faults`
//! (`--faults drop=0.1,straggle=0.05,seed=7`); `experiments::fig_faults`
//! and `examples/fault_sweep.rs` sweep the DecentLaM-vs-DmSGD bias gap
//! as fault rates grow.
//!
//! On top of the fault layer, [`clock`] adds the asynchronous regime
//! (DESIGN.md §8): a deterministic discrete-event engine with
//! heterogeneous per-node clocks whose bounded-staleness schedules the
//! [`engine::FaultyEngine`] replays through per-exchange-slot ring
//! caches — `--async tau=2,spread=4,jitter=0.2`, composing with both
//! codecs and faults. `experiments::fig_async` and
//! `examples/async_sweep.rs` sweep time-to-target-loss against the
//! heterogeneity spread.

pub mod clock;
pub mod engine;
pub mod plan;

pub use clock::{simulate_barrier, simulate_gossip, AsyncReport, AsyncSchedule, AsyncSpec};
pub use engine::{FaultStats, FaultyEngine};
pub use plan::{FaultPlan, FaultSpec, StepFaults};
