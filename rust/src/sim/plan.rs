//! Seeded, deterministic fault schedules (DESIGN.md §6).
//!
//! A [`FaultPlan`] turns a [`FaultSpec`] (per-step rates) into concrete
//! per-step fault realizations. Every decision — "does node i drop out
//! at step k?", "does edge (i,j) fail at step k?" — is drawn from its
//! own counter-keyed [`Pcg64`] stream, so the schedule is
//!
//! * **replayable**: the same (spec, step) always yields the same
//!   faults, independent of how many times or in what order queries
//!   are made;
//! * **order-free**: decisions for different entities never share RNG
//!   state, so iterating edges in any order (or skipping some) cannot
//!   perturb the others — the property suite pins this.
//!
//! All nodes of the simulated cluster share the plan the same way they
//! share the topology seed (paper App. G.3): everyone agrees on who is
//! out this step, so the synchronous round structure is preserved.

use anyhow::{bail, Result};

use crate::util::kvspec::KvSpec;
use crate::util::rng::Pcg64;

/// Per-step fault rates plus the schedule seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// P(node drops out for a step): all its edges are masked; it
    /// neither sends nor receives and updates on its own state only.
    pub drop: f64,
    /// P(an individual link fails for a step): that edge is masked.
    pub link: f64,
    /// P(node straggles for a step): it misses the sync deadline, so
    /// neighbors mix its *previous* published message (stale) while it
    /// still receives fresh messages itself.
    pub straggle: f64,
    /// P(a link delivers stale data for a step, both directions).
    pub stale: f64,
    /// Seed of the fault schedule (independent of the topology seed).
    pub seed: u64,
    /// True when `seed=` was NOT explicit — the seed should follow the
    /// run seed (resolved later via [`FaultSpec::with_run_seed`]).
    pub seed_from_run: bool,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            drop: 0.0,
            link: 0.0,
            straggle: 0.0,
            stale: 0.0,
            seed: 0,
            seed_from_run: true,
        }
    }
}

impl KvSpec for FaultSpec {
    const NAME: &'static str = "fault";

    fn begin(_head: Option<&str>, default_seed: u64) -> Result<FaultSpec> {
        Ok(FaultSpec { seed: default_seed, ..Default::default() })
    }

    fn set_kv(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "drop" => self.drop = parse_rate(key, v)?,
            "link" => self.link = parse_rate(key, v)?,
            "straggle" => self.straggle = parse_rate(key, v)?,
            "stale" => self.stale = parse_rate(key, v)?,
            "seed" => {
                self.seed = v.trim().parse()?;
                self.seed_from_run = false;
            }
            other => bail!("unknown fault key `{other}` (drop|link|straggle|stale|seed)"),
        }
        Ok(())
    }

    fn to_spec_string(&self) -> String {
        let mut s = format!(
            "drop={},link={},straggle={},stale={}",
            self.drop, self.link, self.straggle, self.stale
        );
        if !self.seed_from_run {
            s.push_str(&format!(",seed={}", self.seed));
        }
        s
    }
}

impl FaultSpec {
    /// Parse the CLI form `drop=0.1,straggle=0.05,seed=7`. Keys:
    /// `drop`, `link`, `straggle`, `stale` (rates in [0,1]) and `seed`.
    /// Omitted keys default to 0 / `default_seed`.
    pub fn parse(s: &str, default_seed: u64) -> Result<FaultSpec> {
        <FaultSpec as KvSpec>::parse(s, default_seed)
    }

    /// Canonical spec string; reparses (default_seed 0) to an equal spec.
    pub fn to_spec_string(&self) -> String {
        <FaultSpec as KvSpec>::to_spec_string(self)
    }

    /// Resolve seed inheritance: adopt `run_seed` unless `seed=` was
    /// explicit in the spec string.
    pub fn with_run_seed(mut self, run_seed: u64) -> FaultSpec {
        if self.seed_from_run {
            self.seed = run_seed;
        }
        self
    }

    /// True when every rate is zero — the fault-free degenerate plan.
    pub fn is_zero(&self) -> bool {
        self.drop == 0.0 && self.link == 0.0 && self.straggle == 0.0 && self.stale == 0.0
    }

    /// Does this spec ever substitute stale messages (and therefore
    /// need the engine's publish cache)?
    pub fn wants_stale(&self) -> bool {
        self.straggle > 0.0 || self.stale > 0.0
    }
}

fn parse_rate(key: &str, v: &str) -> Result<f64> {
    let rate: f64 = v.trim().parse()?;
    if !(0.0..=1.0).contains(&rate) {
        bail!("fault rate `{key}={rate}` outside [0, 1]");
    }
    Ok(rate)
}

/// Node-level fault flags for one step.
#[derive(Debug, Clone)]
pub struct StepFaults {
    /// dropped[i]: node i is fully out this step.
    pub dropped: Vec<bool>,
    /// straggler[i]: node i missed the deadline; its outgoing messages
    /// are served stale from the cache.
    pub straggler: Vec<bool>,
}

impl StepFaults {
    pub fn none(n: usize) -> StepFaults {
        StepFaults { dropped: vec![false; n], straggler: vec![false; n] }
    }
}

/// Domain-separation tags: one independent stream family per fault kind.
const TAG_DROP: u64 = 0xfa17_d209;
const TAG_STRAGGLE: u64 = 0xfa17_57a6;
const TAG_LINK: u64 = 0xfa17_11f4;
const TAG_STALE: u64 = 0xfa17_57a1;

/// A deterministic fault schedule over steps.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub spec: FaultSpec,
}

impl FaultPlan {
    pub fn new(spec: FaultSpec) -> FaultPlan {
        FaultPlan { spec }
    }

    /// One Bernoulli draw on the (tag, step, entity) stream.
    fn draw(&self, tag: u64, step: usize, entity: u64, rate: f64) -> bool {
        if rate <= 0.0 {
            return false;
        }
        if rate >= 1.0 {
            return true;
        }
        Pcg64::counter_keyed(self.spec.seed, tag, step as u64, entity).f64() < rate
    }

    /// Does node `id` drop out at `step`? Keyed by STABLE id — elastic
    /// rosters remap dense rows to stable ids before drawing, so the
    /// schedule follows physical nodes across membership resizes
    /// (DESIGN.md §9).
    pub fn node_dropped(&self, step: usize, id: usize) -> bool {
        self.draw(TAG_DROP, step, id as u64, self.spec.drop)
    }

    /// Does node `id` straggle at `step`? Stable-id keyed like
    /// [`FaultPlan::node_dropped`].
    pub fn node_straggles(&self, step: usize, id: usize) -> bool {
        self.draw(TAG_STRAGGLE, step, id as u64, self.spec.straggle)
    }

    /// Node dropout / straggler flags at `step` for `n` dense rows,
    /// drawn on `ids[i]` when a stable-id remap is given (elastic
    /// rosters) and on the dense index itself otherwise. The single
    /// source of the per-node draw loop — `FaultyEngine::begin_step`
    /// and the identity-roster [`FaultPlan::node_faults`] both call it.
    pub fn node_faults_mapped(&self, step: usize, n: usize, ids: Option<&[u32]>) -> StepFaults {
        let sid = |i: usize| ids.map_or(i, |v| v[i] as usize);
        StepFaults {
            dropped: (0..n).map(|i| self.node_dropped(step, sid(i))).collect(),
            straggler: (0..n).map(|i| self.node_straggles(step, sid(i))).collect(),
        }
    }

    /// Node dropout / straggler flags at `step` for the identity roster
    /// (dense index = stable id).
    pub fn node_faults(&self, step: usize, n: usize) -> StepFaults {
        self.node_faults_mapped(step, n, None)
    }

    /// Does the undirected edge {i, j} fail at `step`? Symmetric in
    /// (i, j) by canonicalization — masking must be symmetric for the
    /// renormalized weights to stay doubly stochastic.
    pub fn link_failed(&self, step: usize, i: usize, j: usize) -> bool {
        self.draw(TAG_LINK, step, edge_key(i, j), self.spec.link)
    }

    /// Does the undirected edge {i, j} deliver stale data at `step`?
    pub fn link_stale(&self, step: usize, i: usize, j: usize) -> bool {
        self.draw(TAG_STALE, step, edge_key(i, j), self.spec.stale)
    }
}

/// Canonical stream id of an undirected edge.
fn edge_key(i: usize, j: usize) -> u64 {
    let (lo, hi) = (i.min(j) as u64, i.max(j) as u64);
    (lo << 32) | hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let s = FaultSpec::parse("drop=0.1,straggle=0.05,seed=7", 1).unwrap();
        assert_eq!(s.drop, 0.1);
        assert_eq!(s.straggle, 0.05);
        assert_eq!(s.link, 0.0);
        assert_eq!(s.seed, 7);
        assert!(!s.is_zero());
        assert!(s.wants_stale());
    }

    #[test]
    fn parse_defaults_and_errors() {
        let s = FaultSpec::parse("", 9).unwrap();
        assert!(s.is_zero());
        assert_eq!(s.seed, 9);
        assert!(FaultSpec::parse("drop=1.5", 0).is_err());
        assert!(FaultSpec::parse("warp=0.1", 0).is_err());
        assert!(FaultSpec::parse("drop", 0).is_err());
        assert!(FaultSpec::parse("link=-0.2", 0).is_err());
    }

    #[test]
    fn exact_error_strings_are_pinned() {
        let e = FaultSpec::parse("drop=2", 0).unwrap_err().to_string();
        assert_eq!(e, "fault rate `drop=2` outside [0, 1]");
        let e = FaultSpec::parse("drop", 0).unwrap_err().to_string();
        assert_eq!(e, "fault spec entry `drop` is not key=value");
        let e = FaultSpec::parse("warp=0.1", 0).unwrap_err().to_string();
        assert_eq!(e, "unknown fault key `warp` (drop|link|straggle|stale|seed)");
    }

    #[test]
    fn spec_string_round_trips() {
        for s in ["", "drop=0.1,straggle=0.05,seed=7", "link=0.25,stale=1"] {
            let a = FaultSpec::parse(s, 0).unwrap();
            let b = FaultSpec::parse(&a.to_spec_string(), 0).unwrap();
            assert_eq!(a, b, "round trip of `{s}` via `{}`", a.to_spec_string());
        }
    }

    #[test]
    fn run_seed_resolution_respects_explicit_seed() {
        let inherit = FaultSpec::parse("drop=0.1", 0).unwrap().with_run_seed(42);
        assert_eq!(inherit.seed, 42);
        let explicit = FaultSpec::parse("drop=0.1,seed=7", 0).unwrap().with_run_seed(42);
        assert_eq!(explicit.seed, 7);
    }

    #[test]
    fn schedule_replays_identically() {
        let plan = FaultPlan::new(
            FaultSpec::parse("drop=0.3,link=0.2,straggle=0.2,stale=0.1,seed=42", 0).unwrap(),
        );
        for step in [0usize, 1, 17, 999] {
            let a = plan.node_faults(step, 16);
            let b = plan.node_faults(step, 16);
            assert_eq!(a.dropped, b.dropped, "step {step}");
            assert_eq!(a.straggler, b.straggler, "step {step}");
            for i in 0..16 {
                for j in (i + 1)..16 {
                    assert_eq!(
                        plan.link_failed(step, i, j),
                        plan.link_failed(step, j, i),
                        "link symmetry step {step} ({i},{j})"
                    );
                    assert_eq!(
                        plan.link_stale(step, i, j),
                        plan.link_stale(step, j, i),
                        "stale symmetry step {step} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn rates_hit_empirical_frequencies() {
        let plan =
            FaultPlan::new(FaultSpec { drop: 0.2, ..Default::default() });
        let mut hits = 0usize;
        let trials = 5000;
        for step in 0..trials / 10 {
            let f = plan.node_faults(step, 10);
            hits += f.dropped.iter().filter(|&&d| d).count();
        }
        let freq = hits as f64 / trials as f64;
        assert!((freq - 0.2).abs() < 0.03, "empirical drop rate {freq}");
    }

    #[test]
    fn zero_and_one_rates_are_exact() {
        let never = FaultPlan::new(FaultSpec::default());
        let f = never.node_faults(3, 8);
        assert!(f.dropped.iter().all(|&d| !d));
        let always = FaultPlan::new(FaultSpec { drop: 1.0, ..Default::default() });
        assert!(always.node_faults(3, 8).dropped.iter().all(|&d| d));
    }

    #[test]
    fn mapped_draws_match_identity_and_follow_stable_ids() {
        let plan = FaultPlan::new(FaultSpec {
            drop: 0.5,
            straggle: 0.5,
            seed: 3,
            ..Default::default()
        });
        let identity: Vec<u32> = (0..16).collect();
        let a = plan.node_faults(4, 16);
        let b = plan.node_faults_mapped(4, 16, Some(&identity));
        assert_eq!(a.dropped, b.dropped, "identity remap must not change draws");
        assert_eq!(a.straggler, b.straggler);
        // A shifted remap draws the REMAPPED nodes' schedules.
        let shifted: Vec<u32> = (16..32).collect();
        let c = plan.node_faults_mapped(4, 16, Some(&shifted));
        assert_ne!(a.dropped, c.dropped, "shifted stable ids must draw other streams");
    }

    #[test]
    fn different_seeds_differ() {
        let mk = |seed| {
            FaultPlan::new(FaultSpec { drop: 0.5, seed, ..Default::default() })
                .node_faults(0, 64)
                .dropped
        };
        assert_ne!(mk(1), mk(2));
    }
}
