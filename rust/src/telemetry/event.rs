//! The typed telemetry event schema (DESIGN.md §11, §14).
//!
//! One event = one compact JSON object = one stream line. Keys are
//! emitted in sorted order (the [`Value::obj`] BTreeMap), numbers print
//! through the shortest-round-trip `f64` form, and non-finite values
//! map to JSON `null` (read back as NaN) — so serialization is
//! deterministic and [`Event::parse_line`] ∘ [`Event::to_line`] is the
//! identity on every emitted line, byte for byte.
//!
//! Parsing is fail-closed like every other manifest reader in this
//! repo: unknown event names, unknown fields and type mismatches are
//! hard errors naming the path. Version pinning lives on the
//! `run-start` envelope: readers accept exactly
//! [`ACCEPTED_STREAM_VERSIONS`] (the current [`STREAM_VERSION`] and the
//! committed legacy `DLTEL01`) and reject everything else. The parsed
//! version is preserved in the variant, so re-serializing a legacy
//! stream stays byte-identical.

use anyhow::{bail, ensure, Result};

use crate::util::json::{Cursor, Value};

use super::{ACCEPTED_STREAM_VERSIONS, STREAM_VERSION, STREAM_VERSION_LEGACY};

/// One telemetry event. Field units and emission rules:
///
/// * ordering within a step: `churn` (roster change at the top of the
///   step) → `fault` (this step's realizations, omitted when nothing
///   was realized) → `step` → `metrics` (cadence-gated) → `timing`
///   (cadence-gated, profiled runs only);
/// * `eval` mirrors the trainer's report rule exactly: `accuracy` only
///   when finite, `eval-loss` only when the evaluator provides one, no
///   event when neither exists;
/// * `async` is emitted once, right after `run-start`, when the run
///   executes against the discrete-event clock sim;
/// * `metrics` lines are deterministic (bitwise rerun-identical and
///   par == serial); `timing` lines carry wall-clock measurements and
///   are the ONE event class excluded from two-run byte-identity and
///   from [`super::Replay::matches_report`];
/// * `run-end` closes the stream — its totals must equal the sum of the
///   per-step values (the replay parser verifies this bit for bit).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Stream envelope: the schema version this stream was written
    /// under plus the run manifest as its compact-JSON string
    /// (byte-identical to `TrainReport.manifest`). Build new streams
    /// with [`Event::run_start`]; parsing preserves whichever accepted
    /// version the stream declares.
    RunStart { version: String, manifest: String },
    /// Timing + staleness summary of an `--async` run.
    Async {
        steps: usize,
        makespan_s: f64,
        total_wait_s: f64,
        mean_staleness: f64,
        max_staleness: usize,
        stale_fraction: f64,
    },
    /// One training step: mean loss, learning rate, consensus distance
    /// (1/n)Σ‖x_i − x̄‖², and this step's REALIZED wire bytes.
    Step { step: usize, loss: f64, lr: f64, consensus: f64, wire_bytes: f64 },
    /// Periodic evaluation of the network-average model.
    Eval { step: usize, accuracy: Option<f64>, eval_loss: Option<f64> },
    /// This step's fault realizations (per-step deltas of the engine's
    /// cumulative [`crate::sim::FaultStats`]); only emitted when some
    /// count is nonzero.
    Fault {
        step: usize,
        nominal_edges: usize,
        realized_edges: usize,
        masked_edges: usize,
        stale_messages: usize,
        async_stale_messages: usize,
        dropped_node_steps: usize,
        straggler_node_steps: usize,
    },
    /// A membership change: stable ids joining/leaving this step and
    /// the resulting active node count.
    Churn { step: usize, joins: Vec<u32>, leaves: Vec<u32>, nodes: usize },
    /// A checkpoint written at this step cursor.
    Checkpoint { step: usize },
    /// Cadence-gated run-profile metrics (`--metrics every=K`,
    /// DESIGN.md §14): per-node consensus dispersion ‖x_i − x̄‖² as
    /// p50/p95/max plus a sparse exponent-bucket histogram, momentum
    /// disagreement (1/n)Σ‖m_i − m̄‖², and the momentum-bias proxy
    /// (dispersion of the realized update's deviation from the
    /// bias-free W-mixed update). Deterministic: computed with
    /// `util::math` canonical reductions, so these lines are bitwise
    /// rerun-identical and par == serial. `DLTEL02`-only.
    Metrics {
        step: usize,
        consensus_p50: f64,
        consensus_p95: f64,
        consensus_max: f64,
        /// Sparse histogram of per-node ‖x_i − x̄‖²: `(bucket, count)`
        /// where bucket is the value's raw IEEE-754 exponent
        /// (zero/subnormal → −1023), ascending.
        consensus_hist: Vec<(i32, usize)>,
        momentum_disagreement: f64,
        bias_proxy: f64,
    },
    /// Cadence-gated wall-clock phase profile (`--profile [every=K]`,
    /// DESIGN.md §14): cumulative per-phase nanoseconds, per-phase
    /// log2-ns histograms of per-step durations (`(bucket, count)`
    /// with bucket = number of bits in the ns value, 0 for 0 ns), and
    /// cumulative per-lane executor busy nanoseconds. The one
    /// NON-deterministic event class: replay parses it but excludes it
    /// from `matches_report`, and byte-identity checks strip these
    /// lines first ([`super::strip_timing`]). `DLTEL02`-only.
    Timing {
        step: usize,
        grad_ns: u64,
        encode_ns: u64,
        exchange_ns: u64,
        update_ns: u64,
        grad_hist: Vec<(i32, usize)>,
        encode_hist: Vec<(i32, usize)>,
        exchange_hist: Vec<(i32, usize)>,
        update_hist: Vec<(i32, usize)>,
        lane_busy_ns: Vec<u64>,
    },
    /// Stream close: the run's final metrics and wire-byte total.
    RunEnd { steps: usize, final_accuracy: f64, final_consensus: f64, wire_bytes_total: f64 },
}

/// Finite numbers serialize as numbers; NaN/±∞ (which the hand-rolled
/// JSON writer cannot represent) map to `null` and read back as NaN.
fn num(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

fn count(x: usize) -> Value {
    Value::Num(x as f64)
}

/// Nanosecond counters: exact in f64 up to 2⁵³ ns (≈104 days).
fn nanos(x: u64) -> Value {
    Value::Num(x as f64)
}

fn f64_or_null(c: &Cursor) -> Result<f64> {
    match c.value() {
        Value::Null => Ok(f64::NAN),
        _ => c.as_f64(),
    }
}

fn ids(c: &Cursor) -> Result<Vec<u32>> {
    c.items()?
        .iter()
        .map(|x| {
            let v = x.as_u64()?;
            u32::try_from(v)
                .map_err(|_| anyhow::anyhow!("{}: node id {v} exceeds u32", x.path()))
        })
        .collect()
}

fn id_arr(ids: &[u32]) -> Value {
    Value::Arr(ids.iter().map(|&i| Value::Num(i as f64)).collect())
}

/// Sparse histogram wire form: an array of `[bucket, count]` pairs.
fn hist_arr(h: &[(i32, usize)]) -> Value {
    Value::Arr(
        h.iter()
            .map(|&(b, n)| Value::Arr(vec![Value::Num(b as f64), Value::Num(n as f64)]))
            .collect(),
    )
}

fn hist(c: &Cursor) -> Result<Vec<(i32, usize)>> {
    c.items()?
        .iter()
        .map(|pair| {
            let it = pair.items()?;
            ensure!(
                it.len() == 2,
                "{}: histogram entry must be a [bucket, count] pair",
                pair.path()
            );
            let b = it[0].as_f64()?;
            ensure!(
                b.fract() == 0.0 && (-2048.0..=2048.0).contains(&b),
                "{}: histogram bucket must be a small integer",
                it[0].path()
            );
            Ok((b as i32, it[1].as_usize()?))
        })
        .collect()
}

fn nanos_arr(ns: &[u64]) -> Value {
    Value::Arr(ns.iter().map(|&x| nanos(x)).collect())
}

fn nanos_vec(c: &Cursor) -> Result<Vec<u64>> {
    c.items()?.iter().map(|x| x.as_u64()).collect()
}

impl Event {
    /// The `run-start` envelope for a NEW stream: stamps the current
    /// [`STREAM_VERSION`].
    pub fn run_start(manifest: String) -> Event {
        Event::RunStart { version: STREAM_VERSION.to_string(), manifest }
    }

    /// The event's wire name (the `event` discriminator field).
    pub fn name(&self) -> &'static str {
        match self {
            Event::RunStart { .. } => "run-start",
            Event::Async { .. } => "async",
            Event::Step { .. } => "step",
            Event::Eval { .. } => "eval",
            Event::Fault { .. } => "fault",
            Event::Churn { .. } => "churn",
            Event::Checkpoint { .. } => "checkpoint",
            Event::Metrics { .. } => "metrics",
            Event::Timing { .. } => "timing",
            Event::RunEnd { .. } => "run-end",
        }
    }

    /// Serialize to the canonical JSON object (sorted keys).
    pub fn to_value(&self) -> Value {
        let mut pairs = vec![("event", Value::Str(self.name().to_string()))];
        match self {
            Event::RunStart { version, manifest } => {
                pairs.push(("version", Value::Str(version.clone())));
                pairs.push(("manifest", Value::Str(manifest.clone())));
            }
            Event::Async {
                steps,
                makespan_s,
                total_wait_s,
                mean_staleness,
                max_staleness,
                stale_fraction,
            } => {
                pairs.push(("steps", count(*steps)));
                pairs.push(("makespan-s", num(*makespan_s)));
                pairs.push(("total-wait-s", num(*total_wait_s)));
                pairs.push(("mean-staleness", num(*mean_staleness)));
                pairs.push(("max-staleness", count(*max_staleness)));
                pairs.push(("stale-fraction", num(*stale_fraction)));
            }
            Event::Step { step, loss, lr, consensus, wire_bytes } => {
                pairs.push(("step", count(*step)));
                pairs.push(("loss", num(*loss)));
                pairs.push(("lr", num(*lr)));
                pairs.push(("consensus", num(*consensus)));
                pairs.push(("wire-bytes", num(*wire_bytes)));
            }
            Event::Eval { step, accuracy, eval_loss } => {
                pairs.push(("step", count(*step)));
                if let Some(a) = accuracy {
                    pairs.push(("accuracy", num(*a)));
                }
                if let Some(l) = eval_loss {
                    pairs.push(("eval-loss", num(*l)));
                }
            }
            Event::Fault {
                step,
                nominal_edges,
                realized_edges,
                masked_edges,
                stale_messages,
                async_stale_messages,
                dropped_node_steps,
                straggler_node_steps,
            } => {
                pairs.push(("step", count(*step)));
                pairs.push(("nominal-edges", count(*nominal_edges)));
                pairs.push(("realized-edges", count(*realized_edges)));
                pairs.push(("masked-edges", count(*masked_edges)));
                pairs.push(("stale-messages", count(*stale_messages)));
                pairs.push(("async-stale-messages", count(*async_stale_messages)));
                pairs.push(("dropped-node-steps", count(*dropped_node_steps)));
                pairs.push(("straggler-node-steps", count(*straggler_node_steps)));
            }
            Event::Churn { step, joins, leaves, nodes } => {
                pairs.push(("step", count(*step)));
                pairs.push(("joins", id_arr(joins)));
                pairs.push(("leaves", id_arr(leaves)));
                pairs.push(("nodes", count(*nodes)));
            }
            Event::Checkpoint { step } => {
                pairs.push(("step", count(*step)));
            }
            Event::Metrics {
                step,
                consensus_p50,
                consensus_p95,
                consensus_max,
                consensus_hist,
                momentum_disagreement,
                bias_proxy,
            } => {
                pairs.push(("step", count(*step)));
                pairs.push(("consensus-p50", num(*consensus_p50)));
                pairs.push(("consensus-p95", num(*consensus_p95)));
                pairs.push(("consensus-max", num(*consensus_max)));
                pairs.push(("consensus-hist", hist_arr(consensus_hist)));
                pairs.push(("momentum-disagreement", num(*momentum_disagreement)));
                pairs.push(("bias-proxy", num(*bias_proxy)));
            }
            Event::Timing {
                step,
                grad_ns,
                encode_ns,
                exchange_ns,
                update_ns,
                grad_hist,
                encode_hist,
                exchange_hist,
                update_hist,
                lane_busy_ns,
            } => {
                pairs.push(("step", count(*step)));
                pairs.push(("grad-ns", nanos(*grad_ns)));
                pairs.push(("encode-ns", nanos(*encode_ns)));
                pairs.push(("exchange-ns", nanos(*exchange_ns)));
                pairs.push(("update-ns", nanos(*update_ns)));
                pairs.push(("grad-hist", hist_arr(grad_hist)));
                pairs.push(("encode-hist", hist_arr(encode_hist)));
                pairs.push(("exchange-hist", hist_arr(exchange_hist)));
                pairs.push(("update-hist", hist_arr(update_hist)));
                pairs.push(("lane-busy-ns", nanos_arr(lane_busy_ns)));
            }
            Event::RunEnd { steps, final_accuracy, final_consensus, wire_bytes_total } => {
                pairs.push(("steps", count(*steps)));
                pairs.push(("final-accuracy", num(*final_accuracy)));
                pairs.push(("final-consensus", num(*final_consensus)));
                pairs.push(("wire-bytes-total", num(*wire_bytes_total)));
            }
        }
        Value::obj(pairs)
    }

    /// One canonical stream line (compact JSON, no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_value().to_string()
    }

    /// Parse one event object, fail-closed (unknown events/fields and
    /// type mismatches are hard errors naming the path).
    pub fn parse(c: &Cursor) -> Result<Event> {
        let kind = c.get("event")?.as_str()?;
        match kind {
            "run-start" => {
                c.deny_unknown(&["event", "version", "manifest"])?;
                let version = c.get("version")?.as_str()?;
                if !ACCEPTED_STREAM_VERSIONS.contains(&version) {
                    bail!(
                        "{}: unsupported stream version `{version}` \
                         (this build reads {STREAM_VERSION_LEGACY}/{STREAM_VERSION})",
                        c.path()
                    );
                }
                Ok(Event::RunStart {
                    version: version.to_string(),
                    manifest: c.get("manifest")?.as_str()?.to_string(),
                })
            }
            "async" => {
                c.deny_unknown(&[
                    "event",
                    "steps",
                    "makespan-s",
                    "total-wait-s",
                    "mean-staleness",
                    "max-staleness",
                    "stale-fraction",
                ])?;
                Ok(Event::Async {
                    steps: c.get("steps")?.as_usize()?,
                    makespan_s: f64_or_null(&c.get("makespan-s")?)?,
                    total_wait_s: f64_or_null(&c.get("total-wait-s")?)?,
                    mean_staleness: f64_or_null(&c.get("mean-staleness")?)?,
                    max_staleness: c.get("max-staleness")?.as_usize()?,
                    stale_fraction: f64_or_null(&c.get("stale-fraction")?)?,
                })
            }
            "step" => {
                c.deny_unknown(&["event", "step", "loss", "lr", "consensus", "wire-bytes"])?;
                Ok(Event::Step {
                    step: c.get("step")?.as_usize()?,
                    loss: f64_or_null(&c.get("loss")?)?,
                    lr: f64_or_null(&c.get("lr")?)?,
                    consensus: f64_or_null(&c.get("consensus")?)?,
                    wire_bytes: f64_or_null(&c.get("wire-bytes")?)?,
                })
            }
            "eval" => {
                c.deny_unknown(&["event", "step", "accuracy", "eval-loss"])?;
                let accuracy = c.opt("accuracy").map(|x| f64_or_null(&x)).transpose()?;
                let eval_loss = c.opt("eval-loss").map(|x| f64_or_null(&x)).transpose()?;
                if accuracy.is_none() && eval_loss.is_none() {
                    bail!("{}: eval event carries neither accuracy nor eval-loss", c.path());
                }
                Ok(Event::Eval { step: c.get("step")?.as_usize()?, accuracy, eval_loss })
            }
            "fault" => {
                c.deny_unknown(&[
                    "event",
                    "step",
                    "nominal-edges",
                    "realized-edges",
                    "masked-edges",
                    "stale-messages",
                    "async-stale-messages",
                    "dropped-node-steps",
                    "straggler-node-steps",
                ])?;
                Ok(Event::Fault {
                    step: c.get("step")?.as_usize()?,
                    nominal_edges: c.get("nominal-edges")?.as_usize()?,
                    realized_edges: c.get("realized-edges")?.as_usize()?,
                    masked_edges: c.get("masked-edges")?.as_usize()?,
                    stale_messages: c.get("stale-messages")?.as_usize()?,
                    async_stale_messages: c.get("async-stale-messages")?.as_usize()?,
                    dropped_node_steps: c.get("dropped-node-steps")?.as_usize()?,
                    straggler_node_steps: c.get("straggler-node-steps")?.as_usize()?,
                })
            }
            "churn" => {
                c.deny_unknown(&["event", "step", "joins", "leaves", "nodes"])?;
                Ok(Event::Churn {
                    step: c.get("step")?.as_usize()?,
                    joins: ids(&c.get("joins")?)?,
                    leaves: ids(&c.get("leaves")?)?,
                    nodes: c.get("nodes")?.as_usize()?,
                })
            }
            "checkpoint" => {
                c.deny_unknown(&["event", "step"])?;
                Ok(Event::Checkpoint { step: c.get("step")?.as_usize()? })
            }
            "metrics" => {
                c.deny_unknown(&[
                    "event",
                    "step",
                    "consensus-p50",
                    "consensus-p95",
                    "consensus-max",
                    "consensus-hist",
                    "momentum-disagreement",
                    "bias-proxy",
                ])?;
                Ok(Event::Metrics {
                    step: c.get("step")?.as_usize()?,
                    consensus_p50: f64_or_null(&c.get("consensus-p50")?)?,
                    consensus_p95: f64_or_null(&c.get("consensus-p95")?)?,
                    consensus_max: f64_or_null(&c.get("consensus-max")?)?,
                    consensus_hist: hist(&c.get("consensus-hist")?)?,
                    momentum_disagreement: f64_or_null(&c.get("momentum-disagreement")?)?,
                    bias_proxy: f64_or_null(&c.get("bias-proxy")?)?,
                })
            }
            "timing" => {
                c.deny_unknown(&[
                    "event",
                    "step",
                    "grad-ns",
                    "encode-ns",
                    "exchange-ns",
                    "update-ns",
                    "grad-hist",
                    "encode-hist",
                    "exchange-hist",
                    "update-hist",
                    "lane-busy-ns",
                ])?;
                Ok(Event::Timing {
                    step: c.get("step")?.as_usize()?,
                    grad_ns: c.get("grad-ns")?.as_u64()?,
                    encode_ns: c.get("encode-ns")?.as_u64()?,
                    exchange_ns: c.get("exchange-ns")?.as_u64()?,
                    update_ns: c.get("update-ns")?.as_u64()?,
                    grad_hist: hist(&c.get("grad-hist")?)?,
                    encode_hist: hist(&c.get("encode-hist")?)?,
                    exchange_hist: hist(&c.get("exchange-hist")?)?,
                    update_hist: hist(&c.get("update-hist")?)?,
                    lane_busy_ns: nanos_vec(&c.get("lane-busy-ns")?)?,
                })
            }
            "run-end" => {
                c.deny_unknown(&[
                    "event",
                    "steps",
                    "final-accuracy",
                    "final-consensus",
                    "wire-bytes-total",
                ])?;
                Ok(Event::RunEnd {
                    steps: c.get("steps")?.as_usize()?,
                    final_accuracy: f64_or_null(&c.get("final-accuracy")?)?,
                    final_consensus: f64_or_null(&c.get("final-consensus")?)?,
                    wire_bytes_total: f64_or_null(&c.get("wire-bytes-total")?)?,
                })
            }
            other => bail!("{}: unknown event `{other}`", c.path()),
        }
    }

    /// Parse one stream line.
    pub fn parse_line(line: &str) -> Result<Event> {
        let v = Value::parse(line)?;
        Event::parse(&Cursor::root(&v, "event"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<Event> {
        vec![
            Event::run_start(r#"{"config":{"nodes":4}}"#.to_string()),
            Event::Async {
                steps: 12,
                makespan_s: 3.25,
                total_wait_s: 0.5,
                mean_staleness: 0.75,
                max_staleness: 2,
                stale_fraction: 0.4,
            },
            Event::Step { step: 0, loss: 2.3021, lr: 0.05, consensus: 1e-9, wire_bytes: 153920.0 },
            Event::Eval { step: 4, accuracy: Some(0.5), eval_loss: Some(1.71) },
            Event::Eval { step: 8, accuracy: None, eval_loss: Some(1.62) },
            Event::Fault {
                step: 3,
                nominal_edges: 4,
                realized_edges: 2,
                masked_edges: 2,
                stale_messages: 1,
                async_stale_messages: 0,
                dropped_node_steps: 1,
                straggler_node_steps: 0,
            },
            Event::Churn { step: 5, joins: vec![9], leaves: vec![2, 3], nodes: 7 },
            Event::Checkpoint { step: 6 },
            Event::Metrics {
                step: 10,
                consensus_p50: 3.5e-7,
                consensus_p95: 1.25e-6,
                consensus_max: 2.5e-6,
                consensus_hist: vec![(-1023, 1), (-22, 2), (-20, 1)],
                momentum_disagreement: 4.75e-5,
                bias_proxy: 1.5e-8,
            },
            Event::Timing {
                step: 10,
                grad_ns: 1_250_000,
                encode_ns: 0,
                exchange_ns: 310_000,
                update_ns: 94_000,
                grad_hist: vec![(17, 9), (18, 2)],
                encode_hist: vec![(0, 11)],
                exchange_hist: vec![(15, 11)],
                update_hist: vec![(13, 10), (14, 1)],
                lane_busy_ns: vec![840_000, 822_000, 0],
            },
            Event::RunEnd {
                steps: 12,
                final_accuracy: 0.875,
                final_consensus: 4.2e-7,
                wire_bytes_total: 1847040.0,
            },
        ]
    }

    #[test]
    fn every_event_round_trips_through_its_line() {
        for ev in samples() {
            let line = ev.to_line();
            let back = Event::parse_line(&line).unwrap_or_else(|e| panic!("{line}: {e:#}"));
            assert_eq!(back, ev, "{line}");
            // Canonical serialization: re-emitting is byte-identical.
            assert_eq!(back.to_line(), line);
        }
    }

    #[test]
    fn non_finite_values_round_trip_as_null() {
        let ev = Event::Step {
            step: 1,
            loss: f64::NAN,
            lr: 0.1,
            consensus: f64::INFINITY,
            wire_bytes: 8.0,
        };
        let line = ev.to_line();
        assert!(line.contains(r#""loss":null"#), "{line}");
        assert!(line.contains(r#""consensus":null"#), "{line}");
        let Event::Step { loss, consensus, .. } = Event::parse_line(&line).unwrap() else {
            panic!("wrong variant");
        };
        assert!(loss.is_nan() && consensus.is_nan());
    }

    #[test]
    fn schema_violations_are_hard_errors_naming_the_path() {
        let e = format!("{:#}", Event::parse_line(r#"{"event":"warp"}"#).unwrap_err());
        assert_eq!(e, "event: unknown event `warp`");
        let e = format!(
            "{:#}",
            Event::parse_line(r#"{"event":"checkpoint","step":1,"extra":2}"#).unwrap_err()
        );
        assert_eq!(e, "event: unknown field `extra` (allowed: event, step)");
        let e = format!(
            "{:#}",
            Event::parse_line(r#"{"event":"checkpoint","step":"one"}"#).unwrap_err()
        );
        assert_eq!(e, "event.step: not a number");
        let e = format!("{:#}", Event::parse_line(r#"{"event":"eval","step":4}"#).unwrap_err());
        assert_eq!(e, "event: eval event carries neither accuracy nor eval-loss");
    }

    #[test]
    fn malformed_histograms_are_hard_errors() {
        let good = Event::Metrics {
            step: 0,
            consensus_p50: 1.0,
            consensus_p95: 1.0,
            consensus_max: 1.0,
            consensus_hist: vec![(-3, 2)],
            momentum_disagreement: 0.0,
            bias_proxy: 0.0,
        }
        .to_line();
        // A [bucket] singleton instead of a [bucket, count] pair.
        let bad = good.replace("[-3,2]", "[-3]");
        assert!(Event::parse_line(&bad).is_err(), "{bad}");
        // A fractional bucket index.
        let bad = good.replace("[-3,2]", "[-3.5,2]");
        assert!(Event::parse_line(&bad).is_err(), "{bad}");
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let line = Event::run_start("{}".into()).to_line().replace("DLTEL02", "DLTEL99");
        let e = format!("{:#}", Event::parse_line(&line).unwrap_err());
        assert_eq!(
            e,
            "event: unsupported stream version `DLTEL99` (this build reads DLTEL01/DLTEL02)"
        );
    }

    #[test]
    fn legacy_version_still_parses_and_round_trips() {
        let line = Event::run_start("{}".into()).to_line().replace("DLTEL02", "DLTEL01");
        let ev = Event::parse_line(&line).unwrap();
        let Event::RunStart { version, .. } = &ev else { panic!("wrong variant") };
        assert_eq!(version, "DLTEL01");
        // Re-serializing a legacy line preserves its declared version.
        assert_eq!(ev.to_line(), line);
    }
}
