//! The typed telemetry event schema (DESIGN.md §11).
//!
//! One event = one compact JSON object = one stream line. Keys are
//! emitted in sorted order (the [`Value::obj`] BTreeMap), numbers print
//! through the shortest-round-trip `f64` form, and non-finite values
//! map to JSON `null` (read back as NaN) — so serialization is
//! deterministic and [`Event::parse_line`] ∘ [`Event::to_line`] is the
//! identity on every emitted line, byte for byte.
//!
//! Parsing is fail-closed like every other manifest reader in this
//! repo: unknown event names, unknown fields and type mismatches are
//! hard errors naming the path. Version pinning lives on the
//! `run-start` envelope: readers reject any stream whose version is not
//! [`STREAM_VERSION`].

use anyhow::{bail, Result};

use crate::util::json::{Cursor, Value};

use super::STREAM_VERSION;

/// One telemetry event. Field units and emission rules:
///
/// * ordering within a step: `churn` (roster change at the top of the
///   step) → `fault` (this step's realizations, omitted when nothing
///   was realized) → `step`;
/// * `eval` mirrors the trainer's report rule exactly: `accuracy` only
///   when finite, `eval-loss` only when the evaluator provides one, no
///   event when neither exists;
/// * `async` is emitted once, right after `run-start`, when the run
///   executes against the discrete-event clock sim;
/// * `run-end` closes the stream — its totals must equal the sum of the
///   per-step values (the replay parser verifies this bit for bit).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Stream envelope: the run manifest as its compact-JSON string
    /// (byte-identical to `TrainReport.manifest`).
    RunStart { manifest: String },
    /// Timing + staleness summary of an `--async` run.
    Async {
        steps: usize,
        makespan_s: f64,
        total_wait_s: f64,
        mean_staleness: f64,
        max_staleness: usize,
        stale_fraction: f64,
    },
    /// One training step: mean loss, learning rate, consensus distance
    /// (1/n)Σ‖x_i − x̄‖², and this step's REALIZED wire bytes.
    Step { step: usize, loss: f64, lr: f64, consensus: f64, wire_bytes: f64 },
    /// Periodic evaluation of the network-average model.
    Eval { step: usize, accuracy: Option<f64>, eval_loss: Option<f64> },
    /// This step's fault realizations (per-step deltas of the engine's
    /// cumulative [`crate::sim::FaultStats`]); only emitted when some
    /// count is nonzero.
    Fault {
        step: usize,
        nominal_edges: usize,
        realized_edges: usize,
        masked_edges: usize,
        stale_messages: usize,
        async_stale_messages: usize,
        dropped_node_steps: usize,
        straggler_node_steps: usize,
    },
    /// A membership change: stable ids joining/leaving this step and
    /// the resulting active node count.
    Churn { step: usize, joins: Vec<u32>, leaves: Vec<u32>, nodes: usize },
    /// A checkpoint written at this step cursor.
    Checkpoint { step: usize },
    /// Stream close: the run's final metrics and wire-byte total.
    RunEnd { steps: usize, final_accuracy: f64, final_consensus: f64, wire_bytes_total: f64 },
}

/// Finite numbers serialize as numbers; NaN/±∞ (which the hand-rolled
/// JSON writer cannot represent) map to `null` and read back as NaN.
fn num(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

fn count(x: usize) -> Value {
    Value::Num(x as f64)
}

fn f64_or_null(c: &Cursor) -> Result<f64> {
    match c.value() {
        Value::Null => Ok(f64::NAN),
        _ => c.as_f64(),
    }
}

fn ids(c: &Cursor) -> Result<Vec<u32>> {
    c.items()?
        .iter()
        .map(|x| {
            let v = x.as_u64()?;
            u32::try_from(v)
                .map_err(|_| anyhow::anyhow!("{}: node id {v} exceeds u32", x.path()))
        })
        .collect()
}

fn id_arr(ids: &[u32]) -> Value {
    Value::Arr(ids.iter().map(|&i| Value::Num(i as f64)).collect())
}

impl Event {
    /// The event's wire name (the `event` discriminator field).
    pub fn name(&self) -> &'static str {
        match self {
            Event::RunStart { .. } => "run-start",
            Event::Async { .. } => "async",
            Event::Step { .. } => "step",
            Event::Eval { .. } => "eval",
            Event::Fault { .. } => "fault",
            Event::Churn { .. } => "churn",
            Event::Checkpoint { .. } => "checkpoint",
            Event::RunEnd { .. } => "run-end",
        }
    }

    /// Serialize to the canonical JSON object (sorted keys).
    pub fn to_value(&self) -> Value {
        let mut pairs = vec![("event", Value::Str(self.name().to_string()))];
        match self {
            Event::RunStart { manifest } => {
                pairs.push(("version", Value::Str(STREAM_VERSION.to_string())));
                pairs.push(("manifest", Value::Str(manifest.clone())));
            }
            Event::Async {
                steps,
                makespan_s,
                total_wait_s,
                mean_staleness,
                max_staleness,
                stale_fraction,
            } => {
                pairs.push(("steps", count(*steps)));
                pairs.push(("makespan-s", num(*makespan_s)));
                pairs.push(("total-wait-s", num(*total_wait_s)));
                pairs.push(("mean-staleness", num(*mean_staleness)));
                pairs.push(("max-staleness", count(*max_staleness)));
                pairs.push(("stale-fraction", num(*stale_fraction)));
            }
            Event::Step { step, loss, lr, consensus, wire_bytes } => {
                pairs.push(("step", count(*step)));
                pairs.push(("loss", num(*loss)));
                pairs.push(("lr", num(*lr)));
                pairs.push(("consensus", num(*consensus)));
                pairs.push(("wire-bytes", num(*wire_bytes)));
            }
            Event::Eval { step, accuracy, eval_loss } => {
                pairs.push(("step", count(*step)));
                if let Some(a) = accuracy {
                    pairs.push(("accuracy", num(*a)));
                }
                if let Some(l) = eval_loss {
                    pairs.push(("eval-loss", num(*l)));
                }
            }
            Event::Fault {
                step,
                nominal_edges,
                realized_edges,
                masked_edges,
                stale_messages,
                async_stale_messages,
                dropped_node_steps,
                straggler_node_steps,
            } => {
                pairs.push(("step", count(*step)));
                pairs.push(("nominal-edges", count(*nominal_edges)));
                pairs.push(("realized-edges", count(*realized_edges)));
                pairs.push(("masked-edges", count(*masked_edges)));
                pairs.push(("stale-messages", count(*stale_messages)));
                pairs.push(("async-stale-messages", count(*async_stale_messages)));
                pairs.push(("dropped-node-steps", count(*dropped_node_steps)));
                pairs.push(("straggler-node-steps", count(*straggler_node_steps)));
            }
            Event::Churn { step, joins, leaves, nodes } => {
                pairs.push(("step", count(*step)));
                pairs.push(("joins", id_arr(joins)));
                pairs.push(("leaves", id_arr(leaves)));
                pairs.push(("nodes", count(*nodes)));
            }
            Event::Checkpoint { step } => {
                pairs.push(("step", count(*step)));
            }
            Event::RunEnd { steps, final_accuracy, final_consensus, wire_bytes_total } => {
                pairs.push(("steps", count(*steps)));
                pairs.push(("final-accuracy", num(*final_accuracy)));
                pairs.push(("final-consensus", num(*final_consensus)));
                pairs.push(("wire-bytes-total", num(*wire_bytes_total)));
            }
        }
        Value::obj(pairs)
    }

    /// One canonical stream line (compact JSON, no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_value().to_string()
    }

    /// Parse one event object, fail-closed (unknown events/fields and
    /// type mismatches are hard errors naming the path).
    pub fn parse(c: &Cursor) -> Result<Event> {
        let kind = c.get("event")?.as_str()?;
        match kind {
            "run-start" => {
                c.deny_unknown(&["event", "version", "manifest"])?;
                let version = c.get("version")?.as_str()?;
                if version != STREAM_VERSION {
                    bail!(
                        "{}: unsupported stream version `{version}` \
                         (this build reads {STREAM_VERSION})",
                        c.path()
                    );
                }
                Ok(Event::RunStart { manifest: c.get("manifest")?.as_str()?.to_string() })
            }
            "async" => {
                c.deny_unknown(&[
                    "event",
                    "steps",
                    "makespan-s",
                    "total-wait-s",
                    "mean-staleness",
                    "max-staleness",
                    "stale-fraction",
                ])?;
                Ok(Event::Async {
                    steps: c.get("steps")?.as_usize()?,
                    makespan_s: f64_or_null(&c.get("makespan-s")?)?,
                    total_wait_s: f64_or_null(&c.get("total-wait-s")?)?,
                    mean_staleness: f64_or_null(&c.get("mean-staleness")?)?,
                    max_staleness: c.get("max-staleness")?.as_usize()?,
                    stale_fraction: f64_or_null(&c.get("stale-fraction")?)?,
                })
            }
            "step" => {
                c.deny_unknown(&["event", "step", "loss", "lr", "consensus", "wire-bytes"])?;
                Ok(Event::Step {
                    step: c.get("step")?.as_usize()?,
                    loss: f64_or_null(&c.get("loss")?)?,
                    lr: f64_or_null(&c.get("lr")?)?,
                    consensus: f64_or_null(&c.get("consensus")?)?,
                    wire_bytes: f64_or_null(&c.get("wire-bytes")?)?,
                })
            }
            "eval" => {
                c.deny_unknown(&["event", "step", "accuracy", "eval-loss"])?;
                let accuracy = c.opt("accuracy").map(|x| f64_or_null(&x)).transpose()?;
                let eval_loss = c.opt("eval-loss").map(|x| f64_or_null(&x)).transpose()?;
                if accuracy.is_none() && eval_loss.is_none() {
                    bail!("{}: eval event carries neither accuracy nor eval-loss", c.path());
                }
                Ok(Event::Eval { step: c.get("step")?.as_usize()?, accuracy, eval_loss })
            }
            "fault" => {
                c.deny_unknown(&[
                    "event",
                    "step",
                    "nominal-edges",
                    "realized-edges",
                    "masked-edges",
                    "stale-messages",
                    "async-stale-messages",
                    "dropped-node-steps",
                    "straggler-node-steps",
                ])?;
                Ok(Event::Fault {
                    step: c.get("step")?.as_usize()?,
                    nominal_edges: c.get("nominal-edges")?.as_usize()?,
                    realized_edges: c.get("realized-edges")?.as_usize()?,
                    masked_edges: c.get("masked-edges")?.as_usize()?,
                    stale_messages: c.get("stale-messages")?.as_usize()?,
                    async_stale_messages: c.get("async-stale-messages")?.as_usize()?,
                    dropped_node_steps: c.get("dropped-node-steps")?.as_usize()?,
                    straggler_node_steps: c.get("straggler-node-steps")?.as_usize()?,
                })
            }
            "churn" => {
                c.deny_unknown(&["event", "step", "joins", "leaves", "nodes"])?;
                Ok(Event::Churn {
                    step: c.get("step")?.as_usize()?,
                    joins: ids(&c.get("joins")?)?,
                    leaves: ids(&c.get("leaves")?)?,
                    nodes: c.get("nodes")?.as_usize()?,
                })
            }
            "checkpoint" => {
                c.deny_unknown(&["event", "step"])?;
                Ok(Event::Checkpoint { step: c.get("step")?.as_usize()? })
            }
            "run-end" => {
                c.deny_unknown(&[
                    "event",
                    "steps",
                    "final-accuracy",
                    "final-consensus",
                    "wire-bytes-total",
                ])?;
                Ok(Event::RunEnd {
                    steps: c.get("steps")?.as_usize()?,
                    final_accuracy: f64_or_null(&c.get("final-accuracy")?)?,
                    final_consensus: f64_or_null(&c.get("final-consensus")?)?,
                    wire_bytes_total: f64_or_null(&c.get("wire-bytes-total")?)?,
                })
            }
            other => bail!("{}: unknown event `{other}`", c.path()),
        }
    }

    /// Parse one stream line.
    pub fn parse_line(line: &str) -> Result<Event> {
        let v = Value::parse(line)?;
        Event::parse(&Cursor::root(&v, "event"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<Event> {
        vec![
            Event::RunStart { manifest: r#"{"config":{"nodes":4}}"#.to_string() },
            Event::Async {
                steps: 12,
                makespan_s: 3.25,
                total_wait_s: 0.5,
                mean_staleness: 0.75,
                max_staleness: 2,
                stale_fraction: 0.4,
            },
            Event::Step { step: 0, loss: 2.3021, lr: 0.05, consensus: 1e-9, wire_bytes: 153920.0 },
            Event::Eval { step: 4, accuracy: Some(0.5), eval_loss: Some(1.71) },
            Event::Eval { step: 8, accuracy: None, eval_loss: Some(1.62) },
            Event::Fault {
                step: 3,
                nominal_edges: 4,
                realized_edges: 2,
                masked_edges: 2,
                stale_messages: 1,
                async_stale_messages: 0,
                dropped_node_steps: 1,
                straggler_node_steps: 0,
            },
            Event::Churn { step: 5, joins: vec![9], leaves: vec![2, 3], nodes: 7 },
            Event::Checkpoint { step: 6 },
            Event::RunEnd {
                steps: 12,
                final_accuracy: 0.875,
                final_consensus: 4.2e-7,
                wire_bytes_total: 1847040.0,
            },
        ]
    }

    #[test]
    fn every_event_round_trips_through_its_line() {
        for ev in samples() {
            let line = ev.to_line();
            let back = Event::parse_line(&line).unwrap_or_else(|e| panic!("{line}: {e:#}"));
            assert_eq!(back, ev, "{line}");
            // Canonical serialization: re-emitting is byte-identical.
            assert_eq!(back.to_line(), line);
        }
    }

    #[test]
    fn non_finite_values_round_trip_as_null() {
        let ev = Event::Step {
            step: 1,
            loss: f64::NAN,
            lr: 0.1,
            consensus: f64::INFINITY,
            wire_bytes: 8.0,
        };
        let line = ev.to_line();
        assert!(line.contains(r#""loss":null"#), "{line}");
        assert!(line.contains(r#""consensus":null"#), "{line}");
        let Event::Step { loss, consensus, .. } = Event::parse_line(&line).unwrap() else {
            panic!("wrong variant");
        };
        assert!(loss.is_nan() && consensus.is_nan());
    }

    #[test]
    fn schema_violations_are_hard_errors_naming_the_path() {
        let e = format!("{:#}", Event::parse_line(r#"{"event":"warp"}"#).unwrap_err());
        assert_eq!(e, "event: unknown event `warp`");
        let e = format!(
            "{:#}",
            Event::parse_line(r#"{"event":"checkpoint","step":1,"extra":2}"#).unwrap_err()
        );
        assert_eq!(e, "event: unknown field `extra` (allowed: event, step)");
        let e = format!(
            "{:#}",
            Event::parse_line(r#"{"event":"checkpoint","step":"one"}"#).unwrap_err()
        );
        assert_eq!(e, "event.step: not a number");
        let e = format!("{:#}", Event::parse_line(r#"{"event":"eval","step":4}"#).unwrap_err());
        assert_eq!(e, "event: eval event carries neither accuracy nor eval-loss");
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let line = Event::RunStart { manifest: "{}".into() }
            .to_line()
            .replace("DLTEL01", "DLTEL99");
        let e = format!("{:#}", Event::parse_line(&line).unwrap_err());
        assert_eq!(
            e,
            "event: unsupported stream version `DLTEL99` (this build reads DLTEL01)"
        );
    }
}
