//! Cadence-gated run-profile metrics (DESIGN.md §14): the paper's
//! Section-3 quantities as a deterministic stream event.
//!
//! [`collect`] runs on the trainer thread at `--metrics every=K` steps
//! and computes three statistics over the post-round node states:
//!
//! 1. **Per-node consensus dispersion** — `d_i = ‖x_i − x̄‖²` for every
//!    node, reported as nearest-rank p50/p95/max plus a sparse
//!    exponent-bucket histogram (bucket = the raw IEEE-754 exponent of
//!    `d_i`; zeros and subnormals land in −1023). `Step.consensus`
//!    already carries the mean; the dispersion view is what shows a
//!    straggling node hiding inside a healthy average.
//! 2. **Momentum disagreement** — `(1/n) Σ ‖m_i − m̄‖²`. The paper's
//!    analysis pins the DmSGD inconsistency bias to exactly this
//!    quantity being amplified through `(I − W)`.
//! 3. **Momentum-bias proxy** — the dispersion of
//!    `b_i = (x_i⁺ − mix_i(x)) + γ · mix_i(g)`: how far each node's
//!    realized round deviates from the bias-free W-mixed SGD update
//!    `mix_i(x) − γ·mix_i(g)`. Exact algebra per optimizer (fault-free,
//!    up to f32 rounding): `dsgd` publishes `x − γg`, so `b_i ≈ 0` —
//!    the proxy is *zero for momentum-free methods*, which is what
//!    earns it the name. DmSGD gives `b_i = −γβ·mix_i(m)` (dispersion
//!    `γ²β²·disp(mix(m))` — the momentum-amplified, γ²-scaled bias the
//!    paper analyzes), DecentLaM `b_i ≈ −γβ·m_i` (its *local*
//!    correction, no `(I−W)` amplification of the history).
//!
//! Both mixes go through the **nominal** weights (the trainer's
//! `SparseWeights`), never the fault wrapper: a fault engine's
//! `mix_node` may substitute cached stale *publishes* for `src[j]`,
//! which would silently blend parameters into a gradient mix. Under
//! injected faults the realized-vs-nominal gap therefore shows up in
//! the proxy too — that is observed inconsistency, not an artifact.
//!
//! Determinism: everything reduces through `util::math` canonical
//! reductions on the trainer thread, and the inputs (states, grads)
//! are already bitwise par == serial — so `metrics` lines are bitwise
//! rerun-identical and independent of `--threads`.

use std::collections::BTreeMap;

use crate::comm::engine::CommEngine;
use crate::optim::NodeState;
use crate::util::math;

use super::Event;

/// One step's run-profile metrics (the payload of [`Event::Metrics`]).
#[derive(Debug, Clone, PartialEq)]
pub struct StepMetrics {
    pub step: usize,
    pub consensus_p50: f64,
    pub consensus_p95: f64,
    pub consensus_max: f64,
    pub consensus_hist: Vec<(i32, usize)>,
    pub momentum_disagreement: f64,
    pub bias_proxy: f64,
}

impl StepMetrics {
    pub fn to_event(&self) -> Event {
        Event::Metrics {
            step: self.step,
            consensus_p50: self.consensus_p50,
            consensus_p95: self.consensus_p95,
            consensus_max: self.consensus_max,
            consensus_hist: self.consensus_hist.clone(),
            momentum_disagreement: self.momentum_disagreement,
            bias_proxy: self.bias_proxy,
        }
    }
}

/// The value's raw IEEE-754 exponent: the fixed histogram bucket for
/// non-negative dispersion values. Zeros and subnormals share −1023;
/// NaN/∞ (a diverged run) land in 1024.
pub fn exponent_bucket(x: f64) -> i32 {
    ((x.to_bits() >> 52) & 0x7ff) as i32 - 1023
}

/// Sparse ascending histogram over [`exponent_bucket`]s.
pub fn exponent_hist(values: &[f64]) -> Vec<(i32, usize)> {
    let mut hist: BTreeMap<i32, usize> = BTreeMap::new();
    for &v in values {
        *hist.entry(exponent_bucket(v)).or_insert(0) += 1;
    }
    hist.into_iter().collect()
}

/// Nearest-rank percentile (q in (0, 1]) over a `total_cmp`-sorted
/// copy — the textbook deterministic definition, no interpolation.
pub fn percentile(values: &[f64], q: f64) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Compute one step's metrics from the round's before/after view.
///
/// * `x_before` — every node's parameters entering the round (the
///   trainer snapshots them only on metric steps);
/// * `states` — post-round node states (`x` and `m`);
/// * `grads` — this step's per-node accumulated gradients;
/// * `comm` — the NOMINAL mixing weights (see module docs);
/// * `lr` — γ at this step (schedule already applied).
pub fn collect(
    step: usize,
    x_before: &[Vec<f32>],
    states: &[NodeState],
    grads: &[Vec<f32>],
    comm: &dyn CommEngine,
    lr: f32,
) -> StepMetrics {
    let n = states.len();
    if n == 0 {
        return StepMetrics {
            step,
            consensus_p50: f64::NAN,
            consensus_p95: f64::NAN,
            consensus_max: f64::NAN,
            consensus_hist: Vec::new(),
            momentum_disagreement: f64::NAN,
            bias_proxy: f64::NAN,
        };
    }
    let d = states[0].x.len();

    // 1. Per-node consensus dispersion around the network average.
    let xrefs: Vec<&[f32]> = states.iter().map(|s| s.x.as_slice()).collect();
    let xbar = math::mean_of(&xrefs);
    let disp: Vec<f64> = states.iter().map(|s| math::dist2(&s.x, &xbar)).collect();

    // 2. Momentum disagreement around the average momentum.
    let mrefs: Vec<&[f32]> = states.iter().map(|s| s.m.as_slice()).collect();
    let mbar = math::mean_of(&mrefs);
    let momentum_disagreement =
        math::sum_f64(states.iter().map(|s| math::dist2(&s.m, &mbar))) / n as f64;

    // 3. Momentum-bias proxy: b_i = (x_i⁺ − mix_i(x)) + γ·mix_i(g).
    let mut mixx = vec![0.0f32; d];
    let mut mixg = vec![0.0f32; d];
    let mut b: Vec<Vec<f32>> = Vec::with_capacity(n);
    for i in 0..n {
        comm.mix_node(i, x_before, &mut mixx);
        comm.mix_node(i, grads, &mut mixg);
        b.push((0..d).map(|t| (states[i].x[t] - mixx[t]) + lr * mixg[t]).collect());
    }
    let brefs: Vec<&[f32]> = b.iter().map(|r| r.as_slice()).collect();
    let bbar = math::mean_of(&brefs);
    let bias_proxy = math::sum_f64(b.iter().map(|bi| math::dist2(bi, &bbar))) / n as f64;

    StepMetrics {
        step,
        consensus_p50: percentile(&disp, 0.50),
        consensus_p95: percentile(&disp, 0.95),
        consensus_max: percentile(&disp, 1.0),
        consensus_hist: exponent_hist(&disp),
        momentum_disagreement,
        bias_proxy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{metropolis_hastings, Kind, Topology};

    #[test]
    fn exponent_buckets_are_the_raw_exponent() {
        assert_eq!(exponent_bucket(1.0), 0);
        assert_eq!(exponent_bucket(0.5), -1);
        assert_eq!(exponent_bucket(4.0), 2);
        assert_eq!(exponent_bucket(7.9), 2);
        assert_eq!(exponent_bucket(0.0), -1023);
        assert_eq!(exponent_bucket(f64::MIN_POSITIVE / 2.0), -1023);
        assert_eq!(exponent_bucket(f64::NAN), 1024);
        let h = exponent_hist(&[1.0, 1.5, 0.5, 0.0]);
        assert_eq!(h, vec![(-1023, 1), (-1, 1), (0, 2)]);
    }

    #[test]
    fn nearest_rank_percentiles() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 0.95), 5.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    /// A hand-simulated momentum-free round (dsgd: publish x − γg, mix)
    /// must land the bias proxy at f32-rounding scale, while equal
    /// momenta give exactly zero disagreement.
    #[test]
    fn bias_proxy_is_rounding_level_for_momentum_free_rounds() {
        let n = 4;
        let d = 8;
        let wm = metropolis_hastings(&Topology::build(Kind::Ring, n));
        let lr = 0.1f32;
        let x_before: Vec<Vec<f32>> =
            (0..n).map(|i| (0..d).map(|t| (i * d + t) as f32 * 0.01).collect()).collect();
        let grads: Vec<Vec<f32>> =
            (0..n).map(|i| (0..d).map(|t| ((i + t) % 3) as f32 * 0.2 - 0.1).collect()).collect();
        let publish: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..d).map(|t| x_before[i][t] - lr * grads[i][t]).collect())
            .collect();
        let mut states: Vec<NodeState> =
            x_before.iter().map(|x| NodeState::new(x.clone(), 0)).collect();
        for (i, st) in states.iter_mut().enumerate() {
            wm.mix_node(i, &publish, &mut st.x);
            st.m = vec![0.25; d];
        }
        let m = collect(3, &x_before, &states, &grads, &wm, lr);
        assert_eq!(m.step, 3);
        assert!(m.bias_proxy < 1e-12, "dsgd-style round must be bias-free: {}", m.bias_proxy);
        assert_eq!(m.momentum_disagreement, 0.0);
        assert!(m.consensus_max >= m.consensus_p95 && m.consensus_p95 >= m.consensus_p50);
        let total: usize = m.consensus_hist.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, n);
    }

    /// Injecting a per-node momentum correction of size γβ·m_i (the
    /// DmSGD shape) moves the proxy to exactly γ²β²·disp(mix(m)).
    #[test]
    fn bias_proxy_scales_with_lr_squared() {
        let n = 4;
        let d = 6;
        let wm = metropolis_hastings(&Topology::build(Kind::Ring, n));
        let beta = 0.9f32;
        let x_before: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32 * 0.1; d]).collect();
        let grads: Vec<Vec<f32>> = (0..n).map(|i| vec![0.05 * (i as f32 - 1.5); d]).collect();
        let momenta: Vec<Vec<f32>> = (0..n).map(|i| vec![0.3 * i as f32; d]).collect();
        let proxy_at = |lr: f32| {
            // DmSGD: publish x − γ(βm + g), mix.
            let publish: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    (0..d)
                        .map(|t| x_before[i][t] - lr * (beta * momenta[i][t] + grads[i][t]))
                        .collect()
                })
                .collect();
            let mut states: Vec<NodeState> =
                x_before.iter().map(|x| NodeState::new(x.clone(), 0)).collect();
            for (i, st) in states.iter_mut().enumerate() {
                wm.mix_node(i, &publish, &mut st.x);
            }
            collect(0, &x_before, &states, &grads, &wm, lr).bias_proxy
        };
        let b1 = proxy_at(0.1);
        let b2 = proxy_at(0.2);
        assert!(b1 > 0.0);
        let ratio = b2 / b1;
        assert!((ratio - 4.0).abs() < 0.05, "expected ~4x from 2x lr, got {ratio}");
    }
}
