//! Streaming telemetry bus + offline replay (DESIGN.md §11, §14).
//!
//! Long large-batch runs are exactly where momentum-incurred
//! inconsistency bias accumulates (the paper's core finding), yet a
//! [`crate::coordinator::TrainReport`] is only visible at the end of a
//! run. This module streams every signal the trainer produces — per-step
//! losses, learning rate, consensus distance, realized wire bytes,
//! fault/churn/staleness realizations, eval points, checkpoints, and
//! (cadence-gated) run-profile observability — as a typed, versioned
//! (`"DLTEL02"`) JSONL event stream:
//!
//! * [`event::Event`] — the typed schema: `run-start` / `run-end`
//!   envelopes carrying the run manifest, `step`, `eval`, `fault`,
//!   `churn`, `async` and `checkpoint` events, plus two observability
//!   classes introduced by `DLTEL02`: `metrics` (deterministic
//!   consensus/momentum-bias statistics, see [`metrics`]) and `timing`
//!   (wall-clock phase profile — parsed but excluded from replay
//!   equality). One compact JSON object per line with deterministically
//!   sorted keys (two identical runs produce byte-identical streams,
//!   once `timing` lines are stripped);
//! * [`metrics`] — the cadence-gated collector behind `--metrics
//!   every=K`: per-node consensus dispersion histograms, momentum
//!   disagreement, and the paper's momentum-bias proxy, all reduced
//!   through `util::math` so metrics lines are bitwise replayable and
//!   par == serial;
//! * [`sink::TelemetrySink`] — a buffered file writer behind a mutex,
//!   off the step loop's hot path; IO errors never abort training (the
//!   first one is recorded and the stream simply truncates, which is
//!   exactly what the replay side tolerates). Flushes every
//!   `flush_every` events (default 64, `--telemetry out.jsonl,flush=K`)
//!   so a live dashboard can tail the file;
//! * [`replay::Replay`] — the tolerant line-oriented offline parser: a
//!   truncated final line (a crashed or still-running writer) is
//!   skipped, while schema violations mid-stream are hard errors naming
//!   the line. Replaying a complete stream reconstructs the run's
//!   summary — losses, evals, final metrics, wire bytes — exactly
//!   ([`replay::Replay::matches_report`] pins bit-level equality
//!   against the live report; `metrics`/`timing` lines never enter it).
//!
//! The trainer emits only when `Config::telemetry` is set
//! (`--telemetry out.jsonl`); with it unset the trainer is bitwise
//! identical to the pre-telemetry code path. The sink path is
//! observability plumbing, not run identity: it never enters the run
//! manifest, sha digests or snapshots, and neither does the metrics or
//! profiling cadence.

pub mod event;
pub mod metrics;
pub mod replay;
pub mod sink;

/// Stream schema version written by this build, carried by every
/// `run-start` event. A schema change is a stream-format migration, not
/// a quiet reinterpretation (same rule as the scenario registry's
/// `DLSCEN01`).
pub const STREAM_VERSION: &str = "DLTEL02";

/// The previous stream version. Committed `DLTEL01` streams stay
/// readable forever: replay dispatches on the `run-start` version and
/// only rejects event classes the declared version cannot carry
/// (`metrics`/`timing` inside a `DLTEL01` stream are hard errors).
pub const STREAM_VERSION_LEGACY: &str = "DLTEL01";

/// Every version this build's readers accept.
pub const ACCEPTED_STREAM_VERSIONS: [&str; 2] = [STREAM_VERSION_LEGACY, STREAM_VERSION];

pub use event::Event;
pub use metrics::StepMetrics;
pub use replay::{replay_path, replay_str, strip_timing, Replay};
pub use sink::TelemetrySink;
