//! Streaming telemetry bus + offline replay (DESIGN.md §11).
//!
//! Long large-batch runs are exactly where momentum-incurred
//! inconsistency bias accumulates (the paper's core finding), yet a
//! [`crate::coordinator::TrainReport`] is only visible at the end of a
//! run. This module streams every signal the trainer produces — per-step
//! losses, learning rate, consensus distance, realized wire bytes,
//! fault/churn/staleness realizations, eval points, checkpoints — as a
//! typed, versioned (`"DLTEL01"`) JSONL event stream:
//!
//! * [`event::Event`] — the typed schema: `run-start` / `run-end`
//!   envelopes carrying the run manifest, `step`, `eval`, `fault`,
//!   `churn`, `async` and `checkpoint` events, one compact JSON object
//!   per line with deterministically sorted keys (two identical runs
//!   produce byte-identical streams);
//! * [`sink::TelemetrySink`] — a buffered file writer behind a mutex,
//!   off the step loop's hot path; IO errors never abort training (the
//!   first one is recorded and the stream simply truncates, which is
//!   exactly what the replay side tolerates);
//! * [`replay::Replay`] — the tolerant line-oriented offline parser: a
//!   truncated final line (a crashed or still-running writer) is
//!   skipped, while schema violations mid-stream are hard errors naming
//!   the line. Replaying a complete stream reconstructs the run's
//!   summary — losses, evals, final metrics, wire bytes — exactly
//!   ([`replay::Replay::matches_report`] pins bit-level equality
//!   against the live report).
//!
//! The trainer emits only when `Config::telemetry` is set
//! (`--telemetry out.jsonl`); with it unset the trainer is bitwise
//! identical to the pre-telemetry code path. The sink path is
//! observability plumbing, not run identity: it never enters the run
//! manifest, sha digests or snapshots.

pub mod event;
pub mod replay;
pub mod sink;

/// Stream schema version, carried by every `run-start` event. Readers
/// reject every other version — a schema change is a stream-format
/// migration, not a quiet reinterpretation (same rule as the scenario
/// registry's `DLSCEN01`).
pub const STREAM_VERSION: &str = "DLTEL01";

pub use event::Event;
pub use replay::{replay_path, replay_str, Replay};
pub use sink::TelemetrySink;
