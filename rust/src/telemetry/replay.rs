//! Offline replay: reconstruct a run summary from its JSONL stream
//! (DESIGN.md §11, §14).
//!
//! The parser is line-oriented and deliberately asymmetric about
//! failure:
//!
//! * **Tolerant at the tail.** The final line of a stream from a
//!   crashed (or still-running) writer is routinely truncated mid-JSON
//!   by the buffered sink. The last line is therefore dropped unless
//!   terminated by `\n`; a stream that never reached `run-end` yields a
//!   partial summary with [`Replay::complete`]` == false`.
//! * **Fail-closed everywhere else.** A malformed or out-of-schema line
//!   *before* the tail means the file is not a telemetry stream this
//!   build understands — that is a hard error naming the line number,
//!   never a skip (silently dropping mid-stream events would corrupt
//!   the reconstruction while looking successful).
//!
//! Internal consistency is checked, not assumed: step events must be
//! contiguous, `run-start` must come first and `run-end` last, and the
//! `run-end` wire-byte total must equal the sum of the per-step values
//! bit for bit. Replay is **version-dispatched** on the `run-start`
//! envelope: committed `DLTEL01` streams parse exactly as before, while
//! the `DLTEL02` observability classes (`metrics`, `timing`) are hard
//! errors inside a stream that declares the legacy version.
//! [`Replay::matches_report`] then pins the reconstruction against a
//! live [`TrainReport`] at bit-level equality — `metrics` and `timing`
//! lines are collected alongside but NEVER enter that comparison (the
//! `timing` class is wall-clock and non-deterministic by nature; use
//! [`strip_timing`] before any two-run byte compare).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::TrainReport;
use crate::sim::FaultStats;

use super::{Event, StepMetrics, STREAM_VERSION_LEGACY};

/// A run summary reconstructed purely from a telemetry stream.
#[derive(Debug, Clone, Default)]
pub struct Replay {
    /// The reconstructed summary. `grad_seconds` / `update_seconds`
    /// stay zero: wall-clock timings are non-deterministic and are
    /// deliberately not streamed into the replay report (a profiled
    /// run's `timing` events live in [`Replay::last_timing`] instead).
    pub report: TrainReport,
    /// The stream's declared schema version (from `run-start`).
    pub version: String,
    /// True iff the stream reached its `run-end` envelope.
    pub complete: bool,
    /// True iff a truncated (newline-less) final line was dropped.
    pub truncated: bool,
    /// Number of events successfully parsed.
    pub events: usize,
    /// Sum of per-step fault realizations, if any `fault` events were
    /// streamed. `steps` counts fault events (steps with realizations),
    /// not training steps.
    pub fault_totals: Option<FaultStats>,
    /// Number of `churn` events (membership changes).
    pub churn_events: usize,
    /// Step cursors at which checkpoints were written.
    pub checkpoints: Vec<usize>,
    /// The `async` summary line verbatim, when the run was async.
    pub async_event: Option<Event>,
    /// Every `metrics` event in stream order — the deterministic
    /// bias/dispersion trajectory (`--metrics every=K` runs).
    pub metrics: Vec<StepMetrics>,
    /// Number of `timing` events parsed (profiled runs).
    pub timing_events: usize,
    /// The last `timing` event verbatim: phase counters are cumulative,
    /// so the final one is the run's whole profile.
    pub last_timing: Option<Event>,
}

/// Bit-exact f64 comparison that treats NaN as equal to NaN — the
/// stream maps non-finite values to JSON `null` and reads them back as
/// NaN, so NaN-ness (not the payload) is the preserved property.
fn same(a: f64, b: f64) -> bool {
    a == b || (a.is_nan() && b.is_nan())
}

/// Drop every complete `timing` line from a stream, byte-preserving
/// everything else — the canonical compare for two-run byte-identity of
/// profiled runs (`timing` is the one event class allowed to differ).
/// A torn (newline-less) tail passes through untouched.
pub fn strip_timing(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut rest = text;
    while let Some(pos) = rest.find('\n') {
        let line = &rest[..=pos];
        if !line.contains("\"event\":\"timing\"") {
            out.push_str(line);
        }
        rest = &rest[pos + 1..];
    }
    out.push_str(rest);
    out
}

impl Replay {
    /// Verify this reconstruction against the live report of the same
    /// run: manifest bytes, every loss/eval sample, final metrics, step
    /// and wire-byte totals — all at bit-level (NaN-tolerant) equality.
    /// `metrics` and `timing` events are deliberately outside this
    /// contract: they never enter the [`TrainReport`].
    pub fn matches_report(&self, live: &TrainReport) -> Result<()> {
        if !self.complete {
            bail!("replayed stream is incomplete (no run-end); cannot certify against a report");
        }
        let r = &self.report;
        if r.manifest != live.manifest {
            bail!("replayed manifest differs from live report");
        }
        if r.steps != live.steps {
            bail!("replayed steps {} != live {}", r.steps, live.steps);
        }
        if r.losses.len() != live.losses.len()
            || r.losses.iter().zip(&live.losses).any(|(&a, &b)| !same(a, b))
        {
            bail!(
                "replayed losses differ from live report ({} vs {} samples)",
                r.losses.len(),
                live.losses.len()
            );
        }
        if r.evals != live.evals {
            bail!("replayed evals differ from live report");
        }
        if r.eval_losses.len() != live.eval_losses.len()
            || r.eval_losses
                .iter()
                .zip(&live.eval_losses)
                .any(|((sa, a), (sb, b))| sa != sb || !same(*a, *b))
        {
            bail!("replayed eval losses differ from live report");
        }
        if !same(r.final_accuracy, live.final_accuracy) {
            bail!(
                "replayed final accuracy {} != live {}",
                r.final_accuracy,
                live.final_accuracy
            );
        }
        if !same(r.final_consensus, live.final_consensus) {
            bail!(
                "replayed final consensus {} != live {}",
                r.final_consensus,
                live.final_consensus
            );
        }
        if !same(r.wire_bytes_total, live.wire_bytes_total)
            || !same(r.wire_bytes_per_iter, live.wire_bytes_per_iter)
        {
            bail!(
                "replayed wire bytes {} ({}/iter) != live {} ({}/iter)",
                r.wire_bytes_total,
                r.wire_bytes_per_iter,
                live.wire_bytes_total,
                live.wire_bytes_per_iter
            );
        }
        Ok(())
    }
}

/// Replay a stream from a file.
pub fn replay_path(path: &Path) -> Result<Replay> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading telemetry stream {}", path.display()))?;
    replay_str(&text).with_context(|| format!("replaying {}", path.display()))
}

/// Replay a stream from its text. See the module docs for the
/// tolerance rules (truncated tail skipped, mid-stream violations
/// hard-error).
pub fn replay_str(text: &str) -> Result<Replay> {
    let mut lines: Vec<&str> = text.split('\n').collect();
    let mut out = Replay::default();
    // `split('\n')` leaves "" after a terminated final line; anything
    // else in last position lacks its newline — a truncated tail from a
    // crashed writer — and is dropped without parsing.
    match lines.pop() {
        Some("") | None => {}
        Some(_) => out.truncated = true,
    }

    let mut started = false;
    let mut ended = false;
    // Step contiguity: the first step index is free (a resumed run's
    // stream starts mid-run), every later one must be the successor.
    let mut next_step: Option<usize> = None;
    let mut wire_sum = 0.0f64;

    for (i, line) in lines.iter().enumerate() {
        let ev = Event::parse_line(line).with_context(|| format!("telemetry line {}", i + 1))?;
        if ended {
            bail!("telemetry line {}: event after run-end", i + 1);
        }
        if !started && !matches!(ev, Event::RunStart { .. }) {
            bail!("telemetry line {}: stream must begin with run-start", i + 1);
        }
        out.events += 1;
        match ev {
            Event::RunStart { version, manifest } => {
                if started {
                    bail!("telemetry line {}: duplicate run-start", i + 1);
                }
                started = true;
                out.version = version;
                out.report.manifest = manifest;
            }
            Event::Async { .. } => {
                if out.async_event.is_some() {
                    bail!("telemetry line {}: duplicate async summary", i + 1);
                }
                out.async_event = Some(ev);
            }
            Event::Step { step, loss, wire_bytes, .. } => {
                if let Some(want) = next_step {
                    if step != want {
                        bail!(
                            "telemetry line {}: step {step} out of order (expected {want})",
                            i + 1
                        );
                    }
                }
                next_step = Some(step + 1);
                out.report.losses.push(loss);
                wire_sum += wire_bytes;
            }
            Event::Eval { step, accuracy, eval_loss } => {
                if let Some(a) = accuracy {
                    out.report.evals.push((step, a));
                }
                if let Some(l) = eval_loss {
                    out.report.eval_losses.push((step, l));
                }
            }
            Event::Fault {
                nominal_edges,
                realized_edges,
                masked_edges,
                stale_messages,
                async_stale_messages,
                dropped_node_steps,
                straggler_node_steps,
                ..
            } => {
                let t = out.fault_totals.get_or_insert_with(FaultStats::default);
                t.steps += 1;
                t.nominal_edges += nominal_edges;
                t.realized_edges += realized_edges;
                t.masked_edges += masked_edges;
                t.stale_messages += stale_messages;
                t.async_stale_messages += async_stale_messages;
                t.dropped_node_steps += dropped_node_steps;
                t.straggler_node_steps += straggler_node_steps;
            }
            Event::Churn { .. } => out.churn_events += 1,
            Event::Checkpoint { step } => out.checkpoints.push(step),
            Event::Metrics {
                step,
                consensus_p50,
                consensus_p95,
                consensus_max,
                consensus_hist,
                momentum_disagreement,
                bias_proxy,
            } => {
                if out.version == STREAM_VERSION_LEGACY {
                    bail!(
                        "telemetry line {}: `metrics` events require DLTEL02 \
                         (stream declares {STREAM_VERSION_LEGACY})",
                        i + 1
                    );
                }
                out.metrics.push(StepMetrics {
                    step,
                    consensus_p50,
                    consensus_p95,
                    consensus_max,
                    consensus_hist,
                    momentum_disagreement,
                    bias_proxy,
                });
            }
            Event::Timing { .. } => {
                if out.version == STREAM_VERSION_LEGACY {
                    bail!(
                        "telemetry line {}: `timing` events require DLTEL02 \
                         (stream declares {STREAM_VERSION_LEGACY})",
                        i + 1
                    );
                }
                out.timing_events += 1;
                out.last_timing = Some(ev);
            }
            Event::RunEnd { steps, final_accuracy, final_consensus, wire_bytes_total } => {
                if wire_bytes_total.to_bits() != wire_sum.to_bits() {
                    bail!(
                        "telemetry line {}: run-end wire-bytes-total {wire_bytes_total} \
                         does not equal the per-step sum {wire_sum}",
                        i + 1
                    );
                }
                ended = true;
                out.report.steps = steps;
                out.report.final_accuracy = final_accuracy;
                out.report.final_consensus = final_consensus;
                out.report.wire_bytes_total = wire_bytes_total;
            }
        }
    }

    if !started {
        bail!("empty telemetry stream (no run-start)");
    }
    out.complete = ended;
    if !ended {
        // Partial reconstruction from whatever arrived before the cut.
        out.report.steps = out.report.losses.len();
        out.report.wire_bytes_total = wire_sum;
    }
    out.report.wire_bytes_per_iter = if out.report.losses.is_empty() {
        0.0
    } else {
        out.report.wire_bytes_total / out.report.losses.len() as f64
    };
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(events: &[Event]) -> String {
        let mut s = String::new();
        for ev in events {
            s.push_str(&ev.to_line());
            s.push('\n');
        }
        s
    }

    fn full_run() -> Vec<Event> {
        vec![
            Event::run_start(r#"{"config":{"nodes":4}}"#.to_string()),
            Event::Step { step: 0, loss: 2.5, lr: 0.05, consensus: 0.0, wire_bytes: 100.0 },
            Event::Fault {
                step: 1,
                nominal_edges: 4,
                realized_edges: 3,
                masked_edges: 1,
                stale_messages: 0,
                async_stale_messages: 0,
                dropped_node_steps: 0,
                straggler_node_steps: 1,
            },
            Event::Step { step: 1, loss: 2.25, lr: 0.05, consensus: 1e-6, wire_bytes: 75.0 },
            Event::Eval { step: 2, accuracy: Some(0.5), eval_loss: Some(1.9) },
            Event::Churn { step: 2, joins: vec![4], leaves: vec![], nodes: 5 },
            Event::Step { step: 2, loss: 2.0, lr: 0.05, consensus: 2e-6, wire_bytes: 125.0 },
            Event::Checkpoint { step: 3 },
            Event::RunEnd {
                steps: 3,
                final_accuracy: 0.625,
                final_consensus: 1.5e-6,
                wire_bytes_total: 300.0,
            },
        ]
    }

    fn sample_metrics(step: usize) -> Event {
        Event::Metrics {
            step,
            consensus_p50: 1e-7,
            consensus_p95: 2e-7,
            consensus_max: 4e-7,
            consensus_hist: vec![(-24, 3), (-22, 1)],
            momentum_disagreement: 3e-5,
            bias_proxy: 5e-9,
        }
    }

    fn sample_timing(step: usize) -> Event {
        Event::Timing {
            step,
            grad_ns: 1000,
            encode_ns: 0,
            exchange_ns: 200,
            update_ns: 50,
            grad_hist: vec![(10, 1)],
            encode_hist: vec![(0, 1)],
            exchange_hist: vec![(8, 1)],
            update_hist: vec![(6, 1)],
            lane_busy_ns: vec![900, 880],
        }
    }

    #[test]
    fn complete_stream_reconstructs_the_summary() {
        let r = replay_str(&stream(&full_run())).unwrap();
        assert!(r.complete && !r.truncated);
        assert_eq!(r.events, 9);
        assert_eq!(r.version, "DLTEL02");
        assert_eq!(r.report.manifest, r#"{"config":{"nodes":4}}"#);
        assert_eq!(r.report.losses, vec![2.5, 2.25, 2.0]);
        assert_eq!(r.report.evals, vec![(2, 0.5)]);
        assert_eq!(r.report.eval_losses, vec![(2, 1.9)]);
        assert_eq!(r.report.steps, 3);
        assert_eq!(r.report.final_accuracy, 0.625);
        assert_eq!(r.report.final_consensus, 1.5e-6);
        assert_eq!(r.report.wire_bytes_total, 300.0);
        assert_eq!(r.report.wire_bytes_per_iter, 100.0);
        let f = r.fault_totals.unwrap();
        assert_eq!((f.steps, f.masked_edges, f.straggler_node_steps), (1, 1, 1));
        assert_eq!(r.churn_events, 1);
        assert_eq!(r.checkpoints, vec![3]);
        assert!(r.async_event.is_none());
        assert!(r.metrics.is_empty() && r.timing_events == 0);
    }

    #[test]
    fn truncated_final_line_is_dropped_not_fatal() {
        let mut text = stream(&full_run());
        // Chop the run-end line in half: the writer died mid-line.
        text.truncate(text.len() - 25);
        let r = replay_str(&text).unwrap();
        assert!(r.truncated && !r.complete);
        // Partial summary from the steps that made it.
        assert_eq!(r.report.losses.len(), 3);
        assert_eq!(r.report.steps, 3);
        assert_eq!(r.report.wire_bytes_total, 300.0);
    }

    #[test]
    fn mid_stream_violations_are_hard_errors_naming_the_line() {
        // Malformed JSON mid-stream (note trailing newline: not a tail).
        let text = "not json\n";
        let e = format!("{:#}", replay_str(text).unwrap_err());
        assert!(e.starts_with("telemetry line 1:"), "{e}");

        let mut evs = full_run();
        evs[3] = Event::Step { step: 5, loss: 0.0, lr: 0.0, consensus: 0.0, wire_bytes: 0.0 };
        let e = format!("{:#}", replay_str(&stream(&evs)).unwrap_err());
        assert_eq!(e, "telemetry line 4: step 5 out of order (expected 1)");

        let evs = vec![Event::Checkpoint { step: 0 }];
        let e = format!("{:#}", replay_str(&stream(&evs)).unwrap_err());
        assert_eq!(e, "telemetry line 1: stream must begin with run-start");

        let mut evs = full_run();
        evs.push(Event::Checkpoint { step: 9 });
        let e = format!("{:#}", replay_str(&stream(&evs)).unwrap_err());
        assert_eq!(e, "telemetry line 10: event after run-end");

        let mut evs = full_run();
        evs.insert(1, evs[0].clone());
        let e = format!("{:#}", replay_str(&stream(&evs)).unwrap_err());
        assert_eq!(e, "telemetry line 2: duplicate run-start");

        let mut evs = full_run();
        if let Event::RunEnd { wire_bytes_total, .. } = &mut evs[8] {
            *wire_bytes_total += 1.0;
        }
        let e = format!("{:#}", replay_str(&stream(&evs)).unwrap_err());
        assert!(e.contains("does not equal the per-step sum"), "{e}");

        assert!(replay_str("").is_err());
        assert!(replay_str("\n").is_err());
    }

    #[test]
    fn nan_losses_survive_the_round_trip() {
        let evs = vec![
            Event::run_start("{}".to_string()),
            Event::Step { step: 0, loss: f64::NAN, lr: 0.1, consensus: 0.0, wire_bytes: 0.0 },
        ];
        let r = replay_str(&stream(&evs)).unwrap();
        assert!(r.report.losses[0].is_nan());
        assert!(!r.complete);
    }

    #[test]
    fn matches_report_pins_every_field() {
        let r = replay_str(&stream(&full_run())).unwrap();
        let mut live = r.report.clone();
        r.matches_report(&live).unwrap();
        live.losses[1] += 1e-9;
        assert!(r.matches_report(&live).is_err());

        let mut text = stream(&full_run());
        text.truncate(text.len() - 25);
        let partial = replay_str(&text).unwrap();
        let e = format!("{:#}", partial.matches_report(&r.report).unwrap_err());
        assert!(e.contains("incomplete"), "{e}");
    }

    #[test]
    fn metrics_and_timing_ride_along_without_entering_the_report() {
        let mut evs = full_run();
        evs.insert(2, sample_metrics(0));
        evs.insert(3, sample_timing(0));
        evs.insert(8, sample_metrics(2));
        let r = replay_str(&stream(&evs)).unwrap();
        assert!(r.complete);
        assert_eq!(r.metrics.len(), 2);
        assert_eq!((r.metrics[0].step, r.metrics[1].step), (0, 2));
        assert_eq!(r.metrics[0].consensus_hist, vec![(-24, 3), (-22, 1)]);
        assert_eq!(r.timing_events, 1);
        assert!(matches!(r.last_timing, Some(Event::Timing { .. })));
        // The observability classes never touch the report contract:
        // the same report matches with and without them in the stream.
        let plain = replay_str(&stream(&full_run())).unwrap();
        r.matches_report(&plain.report).unwrap();
    }

    #[test]
    fn legacy_streams_cannot_carry_observability_events() {
        let legacy_start =
            Event::run_start("{}".to_string()).to_line().replace("DLTEL02", "DLTEL01");
        let mut text = format!("{legacy_start}\n");
        text.push_str(&sample_metrics(0).to_line());
        text.push('\n');
        let e = format!("{:#}", replay_str(&text).unwrap_err());
        assert!(e.contains("`metrics` events require DLTEL02"), "{e}");

        let mut text = format!("{legacy_start}\n");
        text.push_str(&sample_timing(0).to_line());
        text.push('\n');
        let e = format!("{:#}", replay_str(&text).unwrap_err());
        assert!(e.contains("`timing` events require DLTEL02"), "{e}");
    }

    #[test]
    fn strip_timing_removes_exactly_the_timing_lines() {
        let mut evs = full_run();
        evs.insert(2, sample_timing(0));
        evs.insert(5, sample_timing(1));
        let with = stream(&evs);
        let without = stream(&full_run());
        assert_eq!(strip_timing(&with), without);
        // Idempotent on clean streams, and a torn tail passes through.
        assert_eq!(strip_timing(&without), without);
        let torn = format!("{without}{{\"event\":\"tim");
        assert!(strip_timing(&torn).ends_with("{\"event\":\"tim"));
    }
}
