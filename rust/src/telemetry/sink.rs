//! The buffered JSONL writer the trainer emits into (DESIGN.md §11).
//!
//! Design constraints, in order:
//!
//! 1. **Off the hot path.** Lines go through a [`BufWriter`] (64 KiB)
//!    so a `step` event is a format + memcpy, not a syscall; the OS
//!    sees large sequential writes at buffer-flush boundaries.
//! 2. **Tail-able.** A 64 KiB buffer alone can lag a live dashboard by
//!    minutes on small runs, so the sink also flushes every
//!    `flush_every` events (default [`DEFAULT_FLUSH_EVERY`],
//!    `--telemetry out.jsonl,flush=K`; 0 disables the cadence) on top
//!    of the existing run-end/checkpoint/drop flushes. Flush cadence
//!    changes WHEN bytes reach the OS, never which bytes — the stream
//!    is byte-identical at any `flush_every`.
//! 3. **Never abort training.** Telemetry is observability, not run
//!    state: an IO error after creation is recorded (first one wins)
//!    and further emits become no-ops. The stream simply truncates —
//!    which is exactly the shape the replay parser tolerates — and the
//!    caller can surface [`TelemetrySink::error`] at end of run.
//! 4. **Deterministic bytes.** The sink writes [`Event::to_line`]
//!    output verbatim plus `\n`; all canonicalization (sorted keys,
//!    shortest-round-trip numbers) lives in the event layer, so two
//!    identical runs produce byte-identical files.
//!
//! Creation errors (bad path, unwritable directory) DO fail loudly —
//! at that point no training work has been lost, and a user who asked
//! for `--telemetry` wants to know the file cannot be opened.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Mutex, MutexGuard};

use anyhow::{Context, Result};

use super::Event;

/// Default event-count flush cadence: frequent enough that a dashboard
/// tailing the file sees a small run progress, rare enough that the
/// BufWriter still batches syscalls.
pub const DEFAULT_FLUSH_EVERY: usize = 64;

struct SinkInner {
    w: BufWriter<File>,
    /// First IO error, if any; once set the sink is inert.
    error: Option<String>,
    /// Flush after this many emits (0 = only explicit/drop flushes).
    flush_every: usize,
    /// Emits since the last flush of any kind.
    since_flush: usize,
}

/// A shared handle to one telemetry stream. Interior mutability via a
/// mutex so emission sites only need `&self` (the trainer holds the
/// sink alongside mutably-borrowed state during `step`).
pub struct TelemetrySink {
    out: Mutex<SinkInner>,
}

/// Telemetry must keep working after a panicking thread poisons the
/// mutex — the guarded state is a plain writer whose invariants hold
/// between operations, so recovering the inner value is sound (same
/// idiom as the executor's pool lock).
fn lock(m: &Mutex<SinkInner>) -> MutexGuard<'_, SinkInner> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl TelemetrySink {
    /// Create (truncate) the stream file with the default flush
    /// cadence, creating parent directories as needed.
    pub fn create(path: &Path) -> Result<TelemetrySink> {
        TelemetrySink::create_with_flush(path, DEFAULT_FLUSH_EVERY)
    }

    /// [`TelemetrySink::create`] with an explicit event-count flush
    /// cadence (`--telemetry out.jsonl,flush=K`; 0 disables it).
    pub fn create_with_flush(path: &Path, flush_every: usize) -> Result<TelemetrySink> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating telemetry dir {}", dir.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("creating telemetry stream {}", path.display()))?;
        Ok(TelemetrySink {
            out: Mutex::new(SinkInner {
                w: BufWriter::with_capacity(64 * 1024, f),
                error: None,
                flush_every,
                since_flush: 0,
            }),
        })
    }

    /// Append one event line. Best-effort: the first IO failure is
    /// recorded and the sink goes inert — training never aborts over
    /// telemetry.
    pub fn emit(&self, ev: &Event) {
        let mut inner = lock(&self.out);
        if inner.error.is_some() {
            return;
        }
        let mut line = ev.to_line();
        line.push('\n');
        if let Err(e) = inner.w.write_all(line.as_bytes()) {
            inner.error = Some(format!("telemetry write failed: {e}"));
            return;
        }
        inner.since_flush += 1;
        if inner.flush_every > 0 && inner.since_flush >= inner.flush_every {
            inner.since_flush = 0;
            if let Err(e) = inner.w.flush() {
                inner.error = Some(format!("telemetry flush failed: {e}"));
            }
        }
    }

    /// Flush buffered lines to the OS (end of run, after a checkpoint).
    pub fn flush(&self) {
        let mut inner = lock(&self.out);
        if inner.error.is_some() {
            return;
        }
        inner.since_flush = 0;
        if let Err(e) = inner.w.flush() {
            inner.error = Some(format!("telemetry flush failed: {e}"));
        }
    }

    /// The first IO error, if the stream went inert mid-run.
    pub fn error(&self) -> Option<String> {
        lock(&self.out).error.clone()
    }
}

impl Drop for TelemetrySink {
    fn drop(&mut self) {
        // Last-chance flush so a normally-dropped sink leaves a complete
        // stream even if the caller forgot the explicit end-of-run flush.
        if let Ok(inner) = self.out.get_mut() {
            if inner.error.is_none() {
                let _ = inner.w.flush();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("decentlam_sink_{}_{name}", std::process::id()))
    }

    #[test]
    fn writes_one_canonical_line_per_event() {
        let path = tmp("lines.jsonl");
        let sink = TelemetrySink::create(&path).unwrap();
        let a = Event::Checkpoint { step: 3 };
        let b = Event::Step { step: 3, loss: 1.5, lr: 0.05, consensus: 0.0, wire_bytes: 64.0 };
        sink.emit(&a);
        sink.emit(&b);
        sink.flush();
        assert!(sink.error().is_none());
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, format!("{}\n{}\n", a.to_line(), b.to_line()));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn creates_missing_parent_directories() {
        let dir = tmp("nested_dir");
        let path = dir.join("deep").join("run.jsonl");
        let sink = TelemetrySink::create(&path).unwrap();
        sink.emit(&Event::Checkpoint { step: 0 });
        drop(sink); // drop-flush
        assert!(std::fs::read_to_string(&path).unwrap().ends_with("\"step\":0}\n"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn creation_on_unwritable_path_fails_loudly() {
        // A path whose parent is a regular file cannot be created.
        let blocker = tmp("blocker");
        std::fs::write(&blocker, b"x").unwrap();
        let err = TelemetrySink::create(&blocker.join("run.jsonl")).unwrap_err();
        assert!(format!("{err:#}").contains("telemetry"), "{err:#}");
        std::fs::remove_file(&blocker).unwrap();
    }

    #[test]
    fn flush_cadence_never_changes_stream_bytes() {
        // The same event sequence through flush_every ∈ {0, 1, 3,
        // default} must land byte-identical files — cadence is about
        // WHEN bytes reach the OS, never which bytes.
        let events: Vec<Event> = (0..10)
            .map(|k| Event::Step {
                step: k,
                loss: 2.0 - k as f64 * 0.125,
                lr: 0.05,
                consensus: 1e-7,
                wire_bytes: 64.0,
            })
            .collect();
        let mut streams = Vec::new();
        for (tag, every) in
            [("f0", Some(0)), ("f1", Some(1)), ("f3", Some(3)), ("fdefault", None)]
        {
            let path = tmp(&format!("cadence_{tag}.jsonl"));
            let sink = match every {
                Some(k) => TelemetrySink::create_with_flush(&path, k).unwrap(),
                None => TelemetrySink::create(&path).unwrap(),
            };
            for ev in &events {
                sink.emit(ev);
            }
            drop(sink);
            streams.push(std::fs::read(&path).unwrap());
            std::fs::remove_file(&path).unwrap();
        }
        assert!(streams.windows(2).all(|w| w[0] == w[1]));
        assert!(!streams[0].is_empty());
    }

    #[test]
    fn eager_flush_makes_lines_visible_before_drop() {
        // flush_every=1: a reader tailing the live file sees each line
        // as soon as it is emitted — the live-dashboard contract.
        let path = tmp("eager.jsonl");
        let sink = TelemetrySink::create_with_flush(&path, 1).unwrap();
        sink.emit(&Event::Checkpoint { step: 7 });
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with("\"step\":7}\n"), "{text:?}");
        drop(sink);
        std::fs::remove_file(&path).unwrap();
    }
}
