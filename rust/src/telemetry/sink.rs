//! The buffered JSONL writer the trainer emits into (DESIGN.md §11).
//!
//! Design constraints, in order:
//!
//! 1. **Off the hot path.** Lines go through a [`BufWriter`] (64 KiB)
//!    so a `step` event is a format + memcpy, not a syscall; the OS
//!    sees large sequential writes at buffer-flush boundaries.
//! 2. **Never abort training.** Telemetry is observability, not run
//!    state: an IO error after creation is recorded (first one wins)
//!    and further emits become no-ops. The stream simply truncates —
//!    which is exactly the shape the replay parser tolerates — and the
//!    caller can surface [`TelemetrySink::error`] at end of run.
//! 3. **Deterministic bytes.** The sink writes [`Event::to_line`]
//!    output verbatim plus `\n`; all canonicalization (sorted keys,
//!    shortest-round-trip numbers) lives in the event layer, so two
//!    identical runs produce byte-identical files.
//!
//! Creation errors (bad path, unwritable directory) DO fail loudly —
//! at that point no training work has been lost, and a user who asked
//! for `--telemetry` wants to know the file cannot be opened.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::Event;

struct SinkInner {
    w: BufWriter<File>,
    /// First IO error, if any; once set the sink is inert.
    error: Option<String>,
}

/// A shared handle to one telemetry stream. Interior mutability via a
/// mutex so emission sites only need `&self` (the trainer holds the
/// sink alongside mutably-borrowed state during `step`).
pub struct TelemetrySink {
    out: Mutex<SinkInner>,
}

impl TelemetrySink {
    /// Create (truncate) the stream file, creating parent directories
    /// as needed.
    pub fn create(path: &Path) -> Result<TelemetrySink> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating telemetry dir {}", dir.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("creating telemetry stream {}", path.display()))?;
        Ok(TelemetrySink {
            out: Mutex::new(SinkInner { w: BufWriter::with_capacity(64 * 1024, f), error: None }),
        })
    }

    /// Append one event line. Best-effort: the first IO failure is
    /// recorded and the sink goes inert — training never aborts over
    /// telemetry.
    pub fn emit(&self, ev: &Event) {
        let mut inner = self.out.lock().expect("telemetry sink poisoned");
        if inner.error.is_some() {
            return;
        }
        let mut line = ev.to_line();
        line.push('\n');
        if let Err(e) = inner.w.write_all(line.as_bytes()) {
            inner.error = Some(format!("telemetry write failed: {e}"));
        }
    }

    /// Flush buffered lines to the OS (end of run, after a checkpoint).
    pub fn flush(&self) {
        let mut inner = self.out.lock().expect("telemetry sink poisoned");
        if inner.error.is_some() {
            return;
        }
        if let Err(e) = inner.w.flush() {
            inner.error = Some(format!("telemetry flush failed: {e}"));
        }
    }

    /// The first IO error, if the stream went inert mid-run.
    pub fn error(&self) -> Option<String> {
        self.out.lock().expect("telemetry sink poisoned").error.clone()
    }
}

impl Drop for TelemetrySink {
    fn drop(&mut self) {
        // Last-chance flush so a normally-dropped sink leaves a complete
        // stream even if the caller forgot the explicit end-of-run flush.
        if let Ok(inner) = self.out.get_mut() {
            if inner.error.is_none() {
                let _ = inner.w.flush();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("decentlam_sink_{}_{name}", std::process::id()))
    }

    #[test]
    fn writes_one_canonical_line_per_event() {
        let path = tmp("lines.jsonl");
        let sink = TelemetrySink::create(&path).unwrap();
        let a = Event::Checkpoint { step: 3 };
        let b = Event::Step { step: 3, loss: 1.5, lr: 0.05, consensus: 0.0, wire_bytes: 64.0 };
        sink.emit(&a);
        sink.emit(&b);
        sink.flush();
        assert!(sink.error().is_none());
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, format!("{}\n{}\n", a.to_line(), b.to_line()));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn creates_missing_parent_directories() {
        let dir = tmp("nested_dir");
        let path = dir.join("deep").join("run.jsonl");
        let sink = TelemetrySink::create(&path).unwrap();
        sink.emit(&Event::Checkpoint { step: 0 });
        drop(sink); // drop-flush
        assert!(std::fs::read_to_string(&path).unwrap().ends_with("\"step\":0}\n"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn creation_on_unwritable_path_fails_loudly() {
        // A path whose parent is a regular file cannot be created.
        let blocker = tmp("blocker");
        std::fs::write(&blocker, b"x").unwrap();
        let err = TelemetrySink::create(&blocker.join("run.jsonl")).unwrap_err();
        assert!(format!("{err:#}").contains("telemetry"), "{err:#}");
        std::fs::remove_file(&blocker).unwrap();
    }
}
