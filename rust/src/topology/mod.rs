//! Network topologies and mixing-weight matrices (paper §3, App. G.3).
//!
//! A [`Topology`] is the undirected neighbor structure; [`weights`] turns
//! it into a symmetric doubly-stochastic mixing matrix `W` (Assumption
//! A.3) via the Metropolis–Hastings rule; [`spectral`] computes
//! ρ = max(|λ₂|, |λₙ|), the connectivity constant in every bound.
//!
//! Static topologies: ring, mesh (2-D torus grid), fully-connected, star,
//! symmetric exponential. Time-varying: one-peer exponential and
//! bipartite random match regenerate each iteration from a shared seed
//! (all nodes must draw the same graph — paper App. G.3 keeps "the same
//! random seed in all nodes to avoid deadlocks").

pub mod sparse;
pub mod spectral;
pub mod weights;

use crate::util::rng::Pcg64;

pub use sparse::SparseWeights;
pub use spectral::{rho, rho_power};
pub use weights::{metropolis_hastings, WeightMatrix};

/// Topology kinds (paper Table 5 + App. G.3 + one-peer exp of Assran et al.).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ring,
    Mesh,
    Full,
    Star,
    SymExp,
    OnePeerExp,
    BipartiteRandomMatch,
    ErdosRenyi,
}

impl Kind {
    /// Every topology kind — the single source of truth for exhaustive
    /// sweeps (property tests, the explorer). Extend this when adding a
    /// variant so new kinds get sparse-engine coverage automatically.
    pub const ALL: [Kind; 8] = [
        Kind::Ring,
        Kind::Mesh,
        Kind::Full,
        Kind::Star,
        Kind::SymExp,
        Kind::OnePeerExp,
        Kind::BipartiteRandomMatch,
        Kind::ErdosRenyi,
    ];

    /// Canonical name (the primary spelling `parse` accepts).
    pub fn name(self) -> &'static str {
        match self {
            Kind::Ring => "ring",
            Kind::Mesh => "mesh",
            Kind::Full => "full",
            Kind::Star => "star",
            Kind::SymExp => "sym-exp",
            Kind::OnePeerExp => "one-peer-exp",
            Kind::BipartiteRandomMatch => "bipartite",
            Kind::ErdosRenyi => "erdos",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Kind> {
        Ok(match s {
            "ring" => Kind::Ring,
            "mesh" | "grid" => Kind::Mesh,
            "full" | "all" => Kind::Full,
            "star" => Kind::Star,
            "sym-exp" | "exp" => Kind::SymExp,
            "one-peer-exp" => Kind::OnePeerExp,
            "bipartite" | "random-match" => Kind::BipartiteRandomMatch,
            "erdos" | "er" => Kind::ErdosRenyi,
            other => anyhow::bail!("unknown topology `{other}`"),
        })
    }

    /// Does the neighbor structure change per iteration?
    pub fn time_varying(self) -> bool {
        matches!(self, Kind::OnePeerExp | Kind::BipartiteRandomMatch)
    }

    /// The B-connectivity window: number of consecutive steps whose
    /// union graph is guaranteed connected (Assumption A.3 holds over a
    /// window for time-varying kinds, per step for static ones).
    /// `None` for kinds with only probabilistic guarantees (bipartite
    /// random match, where any fixed window can miss a node pair).
    pub fn connectivity_window(self, n: usize) -> Option<usize> {
        match self {
            // One-peer exp cycles hops 2^0..2^(stages-1); any `stages`
            // consecutive steps realize every hop once, and hop 1 alone
            // is the connected ring.
            Kind::OnePeerExp => {
                let stages = (usize::BITS - n.saturating_sub(1).leading_zeros()) as usize;
                Some(stages.max(1))
            }
            Kind::BipartiteRandomMatch => None,
            _ => Some(1),
        }
    }
}

/// An undirected graph over `n` nodes, stored as sorted adjacency lists
/// (NOT including self — self-loops are implicit in the weight matrix).
#[derive(Debug, Clone)]
pub struct Topology {
    pub n: usize,
    pub kind: Kind,
    adj: Vec<Vec<usize>>,
}

impl Topology {
    /// Build a static topology (panics if `kind.time_varying()` — use
    /// [`Topology::at_step`] for those).
    pub fn build(kind: Kind, n: usize) -> Topology {
        assert!(!kind.time_varying(), "use at_step for time-varying kinds");
        Self::construct(kind, n, 0, 0)
    }

    /// Realize the (possibly time-varying) topology at iteration `step`
    /// with the experiment seed.
    pub fn at_step(kind: Kind, n: usize, seed: u64, step: usize) -> Topology {
        Self::construct(kind, n, seed, step)
    }

    fn construct(kind: Kind, n: usize, seed: u64, step: usize) -> Topology {
        assert!(n >= 1);
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        let connect = |a: usize, b: usize, adj: &mut Vec<Vec<usize>>| {
            if a != b && !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        };
        match kind {
            Kind::Ring => {
                for i in 0..n {
                    connect(i, (i + 1) % n, &mut adj);
                }
            }
            Kind::Mesh => {
                // 2-D torus grid, rows x cols as square as possible.
                let rows = (1..=n).rev().find(|r| n % r == 0 && *r * *r <= n).unwrap_or(1);
                let cols = n / rows;
                let id = |r: usize, c: usize| r * cols + c;
                for r in 0..rows {
                    for c in 0..cols {
                        if cols > 1 {
                            connect(id(r, c), id(r, (c + 1) % cols), &mut adj);
                        }
                        if rows > 1 {
                            connect(id(r, c), id((r + 1) % rows, c), &mut adj);
                        }
                    }
                }
            }
            Kind::Full => {
                for i in 0..n {
                    for j in (i + 1)..n {
                        connect(i, j, &mut adj);
                    }
                }
            }
            Kind::Star => {
                for i in 1..n {
                    connect(0, i, &mut adj);
                }
            }
            Kind::SymExp => {
                // Symmetric exponential graph (App. G.3): each node links
                // to nodes at hop distances 1, 2, 4, ... (powers of two).
                let mut hop = 1usize;
                while hop < n {
                    for i in 0..n {
                        connect(i, (i + hop) % n, &mut adj);
                    }
                    hop *= 2;
                }
            }
            Kind::OnePeerExp => {
                // One-peer exponential: at step k every node talks to the
                // single peer at hop 2^(k mod log2 n).
                let stages = (usize::BITS - (n - 1).leading_zeros()) as usize;
                let hop = 1usize << (step % stages.max(1));
                for i in 0..n {
                    connect(i, (i + hop) % n, &mut adj);
                }
            }
            Kind::BipartiteRandomMatch => {
                // Random perfect matching per step (shared seed).
                let mut rng = Pcg64::new(seed ^ 0xb19a, step as u64);
                let perm = rng.permutation(n);
                for pair in perm.chunks(2) {
                    if pair.len() == 2 {
                        connect(pair[0], pair[1], &mut adj);
                    }
                }
            }
            Kind::ErdosRenyi => {
                // p = 2 ln(n)/n, resampled until connected.
                let mut attempt = 0u64;
                loop {
                    for a in adj.iter_mut() {
                        a.clear();
                    }
                    let mut rng = Pcg64::new(seed ^ 0xe2d0, step as u64 * 1000 + attempt);
                    let p = (2.0 * (n.max(2) as f64).ln() / n as f64).min(1.0);
                    for i in 0..n {
                        for j in (i + 1)..n {
                            if rng.f64() < p {
                                connect(i, j, &mut adj);
                            }
                        }
                    }
                    let t = Topology { n, kind, adj: adj.clone() };
                    if t.is_connected() || n <= 1 {
                        break;
                    }
                    attempt += 1;
                }
            }
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        Topology { n, kind, adj }
    }

    /// Union graph of `window` consecutive realizations starting at
    /// `start` — the object the B-connectivity assumption (A.3 over a
    /// window) is about. [`Kind::connectivity_window`] names the window
    /// for which this union is guaranteed connected; the trainer
    /// asserts it at startup and the topology tests sweep it.
    pub fn union_over_window(
        kind: Kind,
        n: usize,
        seed: u64,
        start: usize,
        window: usize,
    ) -> Topology {
        assert!(window >= 1, "window must cover at least one step");
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for step in start..start + window {
            let t = Topology::at_step(kind, n, seed, step);
            for (i, merged) in adj.iter_mut().enumerate() {
                for &j in t.neighbors(i) {
                    if !merged.contains(&j) {
                        merged.push(j);
                    }
                }
            }
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        Topology { n, kind, adj }
    }

    /// Neighbors of `i` (excluding `i` itself).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Degree of node `i` (excluding self).
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    /// Total undirected edges.
    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// BFS connectivity check (Assumption A.3 requires strong
    /// connectivity; for time-varying graphs connectivity holds over a
    /// window rather than per step).
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Symmetry invariant: j ∈ N(i) ⇔ i ∈ N(j).
    pub fn is_symmetric(&self) -> bool {
        (0..self.n).all(|i| self.adj[i].iter().all(|&j| self.adj[j].contains(&i)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KINDS: [Kind; 6] = [
        Kind::Ring,
        Kind::Mesh,
        Kind::Full,
        Kind::Star,
        Kind::SymExp,
        Kind::ErdosRenyi,
    ];

    #[test]
    fn static_topologies_connected_and_symmetric() {
        for kind in KINDS {
            for n in [2, 3, 4, 8, 16, 12] {
                let t = Topology::at_step(kind, n, 7, 0);
                assert!(t.is_connected(), "{kind:?} n={n} disconnected");
                assert!(t.is_symmetric(), "{kind:?} n={n} asymmetric");
            }
        }
    }

    #[test]
    fn ring_degrees() {
        let t = Topology::build(Kind::Ring, 8);
        assert!((0..8).all(|i| t.degree(i) == 2));
        assert_eq!(t.num_edges(), 8);
    }

    #[test]
    fn sym_exp_degree_log_n() {
        let t = Topology::build(Kind::SymExp, 8);
        // hops 1,2,4 -> neighbors {±1, ±2, 4} = 5 per node
        assert!((0..8).all(|i| t.degree(i) == 5), "{:?}", t.adj);
    }

    #[test]
    fn star_center_hub() {
        let t = Topology::build(Kind::Star, 8);
        assert_eq!(t.degree(0), 7);
        assert!((1..8).all(|i| t.degree(i) == 1));
    }

    #[test]
    fn mesh_is_torus_grid() {
        let t = Topology::build(Kind::Mesh, 8); // 2x4 torus
        assert!(t.is_connected());
        for i in 0..8 {
            assert!(t.degree(i) >= 2 && t.degree(i) <= 4);
        }
    }

    #[test]
    fn bipartite_match_is_perfect_matching() {
        for step in 0..20 {
            let t = Topology::at_step(Kind::BipartiteRandomMatch, 8, 3, step);
            assert!((0..8).all(|i| t.degree(i) == 1), "step {step}");
        }
    }

    #[test]
    fn bipartite_match_varies_and_is_seed_deterministic() {
        let a = Topology::at_step(Kind::BipartiteRandomMatch, 8, 3, 0);
        let b = Topology::at_step(Kind::BipartiteRandomMatch, 8, 3, 1);
        let a2 = Topology::at_step(Kind::BipartiteRandomMatch, 8, 3, 0);
        assert_eq!(a.adj, a2.adj);
        assert_ne!(a.adj, b.adj);
    }

    #[test]
    fn one_peer_exp_cycles_through_hops() {
        let t0 = Topology::at_step(Kind::OnePeerExp, 8, 0, 0);
        let t1 = Topology::at_step(Kind::OnePeerExp, 8, 0, 1);
        let t2 = Topology::at_step(Kind::OnePeerExp, 8, 0, 2);
        assert!(t0.adj[0].contains(&1));
        assert!(t1.adj[0].contains(&2));
        assert!(t2.adj[0].contains(&4));
        // union over one period is the symmetric exponential graph
        let t3 = Topology::at_step(Kind::OnePeerExp, 8, 0, 3);
        assert_eq!(t3.adj, t0.adj);
    }

    #[test]
    fn union_over_declared_window_is_connected_from_any_start() {
        // The B-connectivity guarantee: for ring/exp/one-peer kinds the
        // union of any `connectivity_window` consecutive realizations
        // must be connected, wherever the window starts.
        for kind in [Kind::Ring, Kind::SymExp, Kind::OnePeerExp] {
            for n in [2usize, 3, 4, 8, 10, 16] {
                let w = kind.connectivity_window(n).unwrap();
                for start in 0..8 {
                    let u = Topology::union_over_window(kind, n, 5, start, w);
                    assert!(
                        u.is_connected(),
                        "{kind:?} n={n} start={start} window={w} disconnected"
                    );
                    assert!(u.is_symmetric(), "{kind:?} n={n} union asymmetric");
                }
            }
        }
    }

    #[test]
    fn one_peer_exp_needs_the_full_window() {
        // A one-peer step at hop 4 (step 2 of the n=8 cycle) is a
        // perfect matching: disconnected — the window is load-bearing.
        let single = Topology::at_step(Kind::OnePeerExp, 8, 0, 2);
        assert!(!single.is_connected());
        assert_eq!(Kind::OnePeerExp.connectivity_window(8), Some(3));
        // The union over the window equals the symmetric exponential graph.
        let union = Topology::union_over_window(Kind::OnePeerExp, 8, 0, 0, 3);
        let sym = Topology::build(Kind::SymExp, 8);
        for i in 0..8 {
            assert_eq!(union.neighbors(i), sym.neighbors(i), "node {i}");
        }
    }

    #[test]
    fn connectivity_windows_declared() {
        assert_eq!(Kind::Ring.connectivity_window(8), Some(1));
        assert_eq!(Kind::SymExp.connectivity_window(64), Some(1));
        assert_eq!(Kind::OnePeerExp.connectivity_window(2), Some(1));
        assert_eq!(Kind::OnePeerExp.connectivity_window(16), Some(4));
        assert_eq!(Kind::BipartiteRandomMatch.connectivity_window(8), None);
        assert_eq!(Kind::Ring.connectivity_window(1), Some(1));
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(Kind::parse("ring").unwrap(), Kind::Ring);
        assert_eq!(Kind::parse("sym-exp").unwrap(), Kind::SymExp);
        assert!(Kind::parse("moebius").is_err());
        assert!(Kind::BipartiteRandomMatch.time_varying());
        assert!(!Kind::Ring.time_varying());
    }

    #[test]
    fn canonical_names_round_trip_through_parse() {
        for kind in Kind::ALL {
            assert_eq!(Kind::parse(kind.name()).unwrap(), kind, "{kind:?}");
        }
    }
}
