//! Sparse neighbor-list mixing weights — the CSR-style comm engine the
//! trainer runs on (DESIGN.md §3).
//!
//! [`SparseWeights`] stores only the populated entries of the
//! Metropolis–Hastings matrix: `row_ptr` offsets plus `(neighbor,
//! weight)` pairs sorted by neighbor index, self entry included. Memory
//! and per-step rebuild cost are O(n + edges) instead of the dense
//! engine's O(n²) — the difference between simulating a ring at n=1024
//! in microseconds versus megabytes of matrix rebuilt every step on
//! time-varying topologies (`benches/sparse_vs_dense.rs` quantifies
//! it). The weights themselves are identical to the dense
//! [`super::weights::metropolis_hastings`] construction; the property
//! suite (`rust/tests/properties.rs`) pins the two engines together to
//! 1e-6 on random topologies.

use crate::comm::engine::{CommEngine, RowEntry};

use super::Topology;

/// CSR-style symmetric doubly-stochastic mixing weights.
#[derive(Debug, Clone, Default)]
pub struct SparseWeights {
    n: usize,
    /// Row offsets into `entries`, length n + 1.
    row_ptr: Vec<u32>,
    /// (neighbor index incl. self, weight), rows sorted by neighbor.
    entries: Vec<RowEntry>,
}

impl SparseWeights {
    /// Build Metropolis–Hastings weights for a topology without ever
    /// materializing the dense matrix: O(edges).
    pub fn metropolis_hastings(topo: &Topology) -> SparseWeights {
        let mut sw = SparseWeights::default();
        sw.rebuild_metropolis(topo);
        sw
    }

    /// Rebuild in place for a new topology realization — the per-step
    /// path for time-varying topologies (one-peer exponential,
    /// bipartite random match) and for elastic-resize churn. Reuses
    /// the allocations and rewrites all neighbor lists in O(n +
    /// edges); it never touches (let alone rebuilds) an n×n matrix,
    /// and after a [`Self::reserve_for`] warmup at the fleet's maximum
    /// size it never allocates either. There is no incremental per-row
    /// diffing — for these graphs every row changes each step anyway.
    pub fn rebuild_metropolis(&mut self, topo: &Topology) {
        let n = topo.n;
        self.n = n;
        self.row_ptr.clear();
        self.entries.clear();
        self.row_ptr.push(0);
        for i in 0..n {
            let deg_i = topo.degree(i);
            // Same f64 off-diagonal terms as the dense builder; the
            // diagonal differs from it only by summation-order rounding
            // (tests compare at 1e-6, far above f64 ulps).
            let mut self_w = 1.0f64;
            let mut self_slot: Option<usize> = None;
            for &j in topo.neighbors(i) {
                if j > i && self_slot.is_none() {
                    self_slot = Some(self.entries.len());
                    self.entries.push((i as u32, 0.0));
                }
                let w = 1.0 / (1.0 + deg_i.max(topo.degree(j)) as f64);
                self_w -= w;
                self.entries.push((j as u32, w as f32));
            }
            let slot = match self_slot {
                Some(s) => s,
                None => {
                    self.entries.push((i as u32, 0.0));
                    self.entries.len() - 1
                }
            };
            self.entries[slot].1 = self_w as f32;
            self.row_ptr.push(self.entries.len() as u32);
        }
    }

    /// Lazy (half-identity) transform in place: W ← (I + W)/2, the
    /// positive-definite variant Theorem 1 assumes.
    pub fn make_lazy(&mut self) {
        for i in 0..self.n {
            let (start, end) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            for e in &mut self.entries[start..end] {
                e.1 *= 0.5;
                if e.0 as usize == i {
                    e.1 += 0.5;
                }
            }
        }
    }

    /// Stored entries (diagnostic; n + 2·edges).
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Pre-size the arenas for the largest realization this engine
    /// will ever hold: `n` nodes and `nnz` entries (`n + 2·edges` for
    /// Metropolis–Hastings rows). The elastic trainer calls this once
    /// at construction with the churn roster's `nmax`, after which
    /// [`Self::rebuild_metropolis`] never reallocates — resizes under
    /// `apply_churn` rewrite the high-water-marked arenas in place
    /// (`tests/executor_pool.rs` pins the capacities across churn).
    pub fn reserve_for(&mut self, n: usize, nnz: usize) {
        let rows = n + 1;
        // `reserve_exact` takes *additional* capacity beyond len; the
        // guards make the call a no-op when the high-water mark is
        // already high enough, so repeated reservations never thrash.
        if self.row_ptr.capacity() < rows {
            self.row_ptr.reserve_exact(rows - self.row_ptr.len());
        }
        if self.entries.capacity() < nnz {
            self.entries.reserve_exact(nnz - self.entries.len());
        }
    }

    /// Current arena capacities `(row_ptr, entries)` — lets tests
    /// assert rebuilds are allocation-free after warmup.
    pub fn arena_capacity(&self) -> (usize, usize) {
        (self.row_ptr.capacity(), self.entries.capacity())
    }
}

impl CommEngine for SparseWeights {
    fn n(&self) -> usize {
        self.n
    }

    fn row(&self, i: usize) -> &[RowEntry] {
        &self.entries[self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{metropolis_hastings, Kind};

    fn agree(sw: &SparseWeights, topo: &Topology) {
        let wm = metropolis_hastings(topo);
        assert_eq!(sw.n(), wm.n);
        for i in 0..topo.n {
            assert_eq!(sw.row(i).len(), wm.row(i).len(), "row {i} length");
            for (&(js, ws), &(jd, wd)) in sw.row(i).iter().zip(wm.row(i)) {
                assert_eq!(js, jd, "row {i} neighbor order");
                assert!((ws - wd).abs() < 1e-6, "w[{i}][{js}]: {ws} vs {wd}");
            }
        }
    }

    #[test]
    fn matches_dense_builder_on_static_kinds() {
        for kind in [Kind::Ring, Kind::Mesh, Kind::Full, Kind::Star, Kind::SymExp] {
            for n in [2usize, 3, 5, 8, 16] {
                let topo = Topology::build(kind, n);
                agree(&SparseWeights::metropolis_hastings(&topo), &topo);
            }
        }
    }

    #[test]
    fn matches_dense_builder_on_time_varying_kinds() {
        for kind in [Kind::OnePeerExp, Kind::BipartiteRandomMatch] {
            for step in 0..6 {
                let topo = Topology::at_step(kind, 8, 11, step);
                agree(&SparseWeights::metropolis_hastings(&topo), &topo);
            }
        }
    }

    #[test]
    fn rebuild_reuses_allocations_and_stays_correct() {
        let mut sw = SparseWeights::default();
        for step in 0..10 {
            let topo = Topology::at_step(Kind::BipartiteRandomMatch, 12, 5, step);
            sw.rebuild_metropolis(&topo);
            agree(&sw, &topo);
            assert!(sw.row_sum_error() < 1e-6, "step {step}");
        }
    }

    #[test]
    fn reserve_for_pins_capacity_across_oscillating_rebuilds() {
        let nmax = 24usize;
        for kind in [Kind::Ring, Kind::SymExp] {
            let edges_max = Topology::build(kind, nmax).num_edges();
            let mut sw = SparseWeights::default();
            sw.reserve_for(nmax, nmax + 2 * edges_max);
            let warm = sw.arena_capacity();
            assert!(warm.0 >= nmax + 1 && warm.1 >= nmax + 2 * edges_max);
            // Elastic churn oscillates n <= nmax; every rebuild must
            // run inside the warmed arenas (no reallocation).
            for n in [4usize, nmax, 7, 16, 3, nmax, 12] {
                let topo = Topology::build(kind, n);
                sw.rebuild_metropolis(&topo);
                agree(&sw, &topo);
                assert_eq!(sw.arena_capacity(), warm, "{kind:?} n={n} reallocated");
            }
        }
    }

    #[test]
    fn rows_sorted_with_self_entry() {
        let topo = Topology::build(Kind::SymExp, 16);
        let sw = SparseWeights::metropolis_hastings(&topo);
        for i in 0..16 {
            let row = sw.row(i);
            assert!(row.windows(2).all(|p| p[0].0 < p[1].0), "row {i} unsorted");
            assert!(row.iter().any(|&(j, _)| j as usize == i), "row {i} missing self");
        }
    }

    #[test]
    fn edge_and_degree_counts_match_topology() {
        let topo = Topology::build(Kind::Mesh, 12);
        let sw = SparseWeights::metropolis_hastings(&topo);
        assert_eq!(sw.num_edges(), topo.num_edges());
        assert_eq!(sw.max_degree(), topo.max_degree());
    }

    #[test]
    fn lazy_halves_gossip_and_keeps_stochasticity() {
        let topo = Topology::build(Kind::Ring, 8);
        let mut sw = SparseWeights::metropolis_hastings(&topo);
        let off_before = sw.row(0).iter().find(|&&(j, _)| j == 1).unwrap().1;
        sw.make_lazy();
        assert!(sw.row_sum_error() < 1e-6);
        let off_after = sw.row(0).iter().find(|&&(j, _)| j == 1).unwrap().1;
        assert!((off_after - off_before / 2.0).abs() < 1e-7);
        assert!((sw.self_weight(0) - (0.5 + 1.0 / 6.0)).abs() < 1e-6);
    }

    #[test]
    fn single_node_is_identity() {
        let topo = Topology::build(Kind::Ring, 1);
        let sw = SparseWeights::metropolis_hastings(&topo);
        assert_eq!(sw.row(0), &[(0u32, 1.0f32)]);
        assert_eq!(sw.num_edges(), 0);
    }
}
