//! Spectral analysis of mixing matrices: ρ = max(|λ₂|, |λₙ|) (paper
//! App. A, eq. (28)) — the constant every convergence bound depends on.
//!
//! Two routes: the exact dense eigensolve ([`rho`], O(n³), fine up to a
//! few dozen nodes) and a deflated power iteration over any
//! [`CommEngine`] ([`rho_power`], O(edges · iters)) — the one the
//! large-n tools use so a ring at n=512–1024 stays interactive.

use crate::comm::engine::CommEngine;
use crate::util::rng::Pcg64;

use super::weights::WeightMatrix;

/// ρ(W) = ‖W − 11ᵀ/n‖₂ = max(|λ₂|, |λₙ|) for symmetric doubly-stochastic W.
pub fn rho(w: &WeightMatrix) -> f64 {
    let ev = w.eigenvalues();
    let n = ev.len();
    if n <= 1 {
        return 0.0;
    }
    // ev ascending: λn = ev[0], λ2 = ev[n-2] (λ1 = ev[n-1] = 1).
    ev[0].abs().max(ev[n - 2].abs())
}

/// ρ(W) via power iteration on the consensus-deflated operator, using
/// only the sparse rows: start from a mean-zero vector (orthogonal to
/// the top eigenvector 1), repeatedly apply W, re-center against f64
/// drift, and read |λ| off the norm growth. Deterministic (fixed seed)
/// and O(edges) per iteration; stops when the estimate moves < 1e-10
/// or after `max_iters`.
pub fn rho_power(w: &dyn CommEngine, max_iters: usize) -> f64 {
    let n = w.n();
    if n <= 1 {
        return 0.0;
    }
    let mut rng = Pcg64::seeded(0x59ec ^ n as u64);
    let mut x: Vec<f64> = (0..n).map(|_| rng.f64() - 0.5).collect();
    center(&mut x);
    let mut norm = norm2(&x);
    if norm < 1e-300 {
        return 0.0;
    }
    for v in x.iter_mut() {
        *v /= norm;
    }
    let mut y = vec![0.0f64; n];
    let mut lambda = 0.0f64;
    for _ in 0..max_iters {
        for (i, yi) in y.iter_mut().enumerate() {
            *yi = w.row(i).iter().map(|&(j, wij)| wij as f64 * x[j as usize]).sum();
        }
        center(&mut y);
        norm = norm2(&y);
        if norm < 1e-300 {
            // Deflated spectrum is (numerically) zero — e.g. the
            // complete graph, where W = 11ᵀ/n exactly.
            return 0.0;
        }
        let next = norm; // ‖W x‖ with ‖x‖ = 1 -> dominant |λ| estimate
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / norm;
        }
        if (next - lambda).abs() < 1e-10 {
            return next.min(1.0);
        }
        lambda = next;
    }
    lambda.min(1.0)
}

fn center(x: &mut [f64]) {
    let mean = crate::util::math::mean_f64(x);
    for v in x.iter_mut() {
        *v -= mean;
    }
}

fn norm2(x: &[f64]) -> f64 {
    crate::util::math::norm2_f64(x)
}

/// Spectral gap 1 − ρ.
pub fn spectral_gap(w: &WeightMatrix) -> f64 {
    1.0 - rho(w)
}

/// Iterations for gossip averaging to contract consensus error by `eps`
/// (diagnostic: k ≈ ln(1/eps) / ln(1/ρ)).
pub fn mixing_time(w: &WeightMatrix, eps: f64) -> f64 {
    mixing_time_of(rho(w), eps)
}

/// [`mixing_time`] from an already-computed ρ (e.g. [`rho_power`]).
pub fn mixing_time_of(r: f64, eps: f64) -> f64 {
    if r <= 0.0 {
        return 1.0;
    }
    (1.0 / eps).ln() / (1.0 / r).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{metropolis_hastings, Kind, Topology};

    fn rho_of(kind: Kind, n: usize) -> f64 {
        rho(&metropolis_hastings(&Topology::build(kind, n)))
    }

    #[test]
    fn full_graph_mixes_instantly() {
        // MH on the complete graph gives W = 11ᵀ/n exactly -> ρ = 0.
        assert!(rho_of(Kind::Full, 8) < 1e-9);
    }

    #[test]
    fn denser_graphs_mix_faster() {
        let ring = rho_of(Kind::Ring, 16);
        let mesh = rho_of(Kind::Mesh, 16);
        let exp = rho_of(Kind::SymExp, 16);
        let full = rho_of(Kind::Full, 16);
        assert!(full < exp && exp < mesh && mesh < ring, "{full} {exp} {mesh} {ring}");
        assert!(ring < 1.0);
    }

    #[test]
    fn rho_grows_with_ring_size() {
        assert!(rho_of(Kind::Ring, 32) > rho_of(Kind::Ring, 8));
    }

    #[test]
    fn ring4_rho_matches_closed_form() {
        // Ring n=4 MH: circulant with first row [1/3,1/3,0,1/3];
        // eigenvalues 1, 1/3·(1+2cos(πk/2))... compute directly: 1, 1/3, -1/3, 1/3.
        let r = rho_of(Kind::Ring, 4);
        assert!((r - 1.0 / 3.0).abs() < 1e-9, "rho={r}");
    }

    #[test]
    fn gossip_contracts_at_rho() {
        // Empirically verify ‖(W − R)x‖ <= ρ‖x‖ on mean-zero vectors.
        let w = metropolis_hastings(&Topology::build(Kind::Ring, 8));
        let r = rho(&w);
        let x: Vec<f64> = (0..8).map(|i| i as f64 - 3.5).collect();
        let wx = w.dense.matvec(&x);
        let mean: f64 = wx.iter().sum::<f64>() / 8.0;
        let centered: f64 = wx.iter().map(|v| (v - mean).powi(2)).sum::<f64>().sqrt();
        let x_norm: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(centered <= r * x_norm + 1e-9);
    }

    #[test]
    fn mixing_time_monotone_in_eps() {
        let w = metropolis_hastings(&Topology::build(Kind::Ring, 8));
        assert!(mixing_time(&w, 1e-6) > mixing_time(&w, 1e-2));
    }

    #[test]
    fn power_iteration_matches_dense_rho() {
        use crate::topology::SparseWeights;
        for kind in [Kind::Ring, Kind::Mesh, Kind::SymExp, Kind::Star] {
            let topo = Topology::build(kind, 16);
            let dense = rho(&metropolis_hastings(&topo));
            let sparse = rho_power(&SparseWeights::metropolis_hastings(&topo), 200_000);
            assert!(
                (dense - sparse).abs() < 1e-4,
                "{kind:?}: dense rho {dense} vs power-iteration {sparse}"
            );
        }
    }

    #[test]
    fn power_iteration_complete_graph_is_zero() {
        use crate::topology::SparseWeights;
        let topo = Topology::build(Kind::Full, 12);
        let r = rho_power(&SparseWeights::metropolis_hastings(&topo), 10_000);
        assert!(r < 1e-6, "complete graph mixes in one round, rho={r}");
    }

    #[test]
    fn power_iteration_feasible_at_ring_512() {
        use crate::topology::SparseWeights;
        let topo = Topology::build(Kind::Ring, 512);
        let r = rho_power(&SparseWeights::metropolis_hastings(&topo), 200_000);
        // Ring ρ = (1 + 2cos(2π/n))/3 -> extremely close to 1 at n=512.
        let exact = (1.0 + 2.0 * (2.0 * std::f64::consts::PI / 512.0).cos()) / 3.0;
        assert!((r - exact).abs() < 1e-3, "rho {r} vs exact {exact}");
    }
}
