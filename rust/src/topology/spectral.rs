//! Spectral analysis of mixing matrices: ρ = max(|λ₂|, |λₙ|) (paper
//! App. A, eq. (28)) — the constant every convergence bound depends on.

use super::weights::WeightMatrix;

/// ρ(W) = ‖W − 11ᵀ/n‖₂ = max(|λ₂|, |λₙ|) for symmetric doubly-stochastic W.
pub fn rho(w: &WeightMatrix) -> f64 {
    let ev = w.eigenvalues();
    let n = ev.len();
    if n <= 1 {
        return 0.0;
    }
    // ev ascending: λn = ev[0], λ2 = ev[n-2] (λ1 = ev[n-1] = 1).
    ev[0].abs().max(ev[n - 2].abs())
}

/// Spectral gap 1 − ρ.
pub fn spectral_gap(w: &WeightMatrix) -> f64 {
    1.0 - rho(w)
}

/// Iterations for gossip averaging to contract consensus error by `eps`
/// (diagnostic: k ≈ ln(1/eps) / ln(1/ρ)).
pub fn mixing_time(w: &WeightMatrix, eps: f64) -> f64 {
    let r = rho(w);
    if r <= 0.0 {
        return 1.0;
    }
    (1.0 / eps).ln() / (1.0 / r).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{metropolis_hastings, Kind, Topology};

    fn rho_of(kind: Kind, n: usize) -> f64 {
        rho(&metropolis_hastings(&Topology::build(kind, n)))
    }

    #[test]
    fn full_graph_mixes_instantly() {
        // MH on the complete graph gives W = 11ᵀ/n exactly -> ρ = 0.
        assert!(rho_of(Kind::Full, 8) < 1e-9);
    }

    #[test]
    fn denser_graphs_mix_faster() {
        let ring = rho_of(Kind::Ring, 16);
        let mesh = rho_of(Kind::Mesh, 16);
        let exp = rho_of(Kind::SymExp, 16);
        let full = rho_of(Kind::Full, 16);
        assert!(full < exp && exp < mesh && mesh < ring, "{full} {exp} {mesh} {ring}");
        assert!(ring < 1.0);
    }

    #[test]
    fn rho_grows_with_ring_size() {
        assert!(rho_of(Kind::Ring, 32) > rho_of(Kind::Ring, 8));
    }

    #[test]
    fn ring4_rho_matches_closed_form() {
        // Ring n=4 MH: circulant with first row [1/3,1/3,0,1/3];
        // eigenvalues 1, 1/3·(1+2cos(πk/2))... compute directly: 1, 1/3, -1/3, 1/3.
        let r = rho_of(Kind::Ring, 4);
        assert!((r - 1.0 / 3.0).abs() < 1e-9, "rho={r}");
    }

    #[test]
    fn gossip_contracts_at_rho() {
        // Empirically verify ‖(W − R)x‖ <= ρ‖x‖ on mean-zero vectors.
        let w = metropolis_hastings(&Topology::build(Kind::Ring, 8));
        let r = rho(&w);
        let x: Vec<f64> = (0..8).map(|i| i as f64 - 3.5).collect();
        let wx = w.dense.matvec(&x);
        let mean: f64 = wx.iter().sum::<f64>() / 8.0;
        let centered: f64 = wx.iter().map(|v| (v - mean).powi(2)).sum::<f64>().sqrt();
        let x_norm: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(centered <= r * x_norm + 1e-9);
    }

    #[test]
    fn mixing_time_monotone_in_eps() {
        let w = metropolis_hastings(&Topology::build(Kind::Ring, 8));
        assert!(mixing_time(&w, 1e-6) > mixing_time(&w, 1e-2));
    }
}
