//! Mixing-weight matrices over a topology (paper eq. (2), Assumption A.3).
//!
//! The Metropolis–Hastings rule ([Sayed 2014, Table 14.1], the paper's
//! choice in App. G.2/G.3) produces a symmetric doubly-stochastic `W`
//! for any undirected graph:
//!
//!   w_ij = 1 / (1 + max(deg_i, deg_j))   for j ∈ N(i), j ≠ i
//!   w_ii = 1 − Σ_{j≠i} w_ij
//!
//! `lazy` mixing W' = (I + W)/2 shifts the spectrum into (0, 1], giving
//! the positive-definite matrix Theorem 1 assumes (ablation `--pd`).

use crate::comm::engine::{CommEngine, RowEntry};
use crate::util::math::SymMatrix;

use super::Topology;

/// A dense symmetric mixing matrix plus per-node sparse views. Kept for
/// spectral analysis (eigenvalues need the full matrix) and as the
/// reference the sparse engine ([`super::sparse::SparseWeights`]) is
/// property-tested against; the trainer's hot path no longer touches
/// it.
#[derive(Debug, Clone)]
pub struct WeightMatrix {
    pub n: usize,
    /// Dense row-major weights (n x n), kept in f64 for spectral math.
    pub dense: SymMatrix,
    /// Per node: (neighbor index including self, weight), sorted.
    rows: Vec<Vec<RowEntry>>,
}

impl WeightMatrix {
    fn from_dense(dense: SymMatrix) -> WeightMatrix {
        let n = dense.n;
        let rows = (0..n)
            .map(|i| {
                (0..n)
                    .filter(|&j| dense.get(i, j) != 0.0)
                    .map(|j| (j as u32, dense.get(i, j) as f32))
                    .collect()
            })
            .collect();
        WeightMatrix { n, dense, rows }
    }

    /// Sparse row for node `i`: (j, w_ij) with w_ij > 0, includes self.
    pub fn row(&self, i: usize) -> &[RowEntry] {
        &self.rows[i]
    }

    /// Self weight w_ii.
    pub fn self_weight(&self, i: usize) -> f32 {
        self.dense.get(i, i) as f32
    }

    /// Max |row sum − 1| (doubly-stochastic check; symmetry makes column
    /// sums equal row sums).
    pub fn stochasticity_error(&self) -> f64 {
        (0..self.n)
            .map(|i| {
                let s: f64 = (0..self.n).map(|j| self.dense.get(i, j)).sum();
                (s - 1.0).abs()
            })
            .fold(0.0, f64::max)
    }

    /// All eigenvalues (ascending).
    pub fn eigenvalues(&self) -> Vec<f64> {
        self.dense.eigenvalues()
    }

    /// Is every eigenvalue positive (Theorem 1's restriction)?
    pub fn is_positive_definite(&self) -> bool {
        self.eigenvalues().iter().all(|&l| l > 1e-12)
    }

    /// Lazy (half-identity) version: (I + W)/2, positive-definite.
    pub fn lazy(&self) -> WeightMatrix {
        let mut d = SymMatrix::zeros(self.n);
        for i in 0..self.n {
            for j in 0..self.n {
                let mut v = self.dense.get(i, j) / 2.0;
                if i == j {
                    v += 0.5;
                }
                if v != 0.0 {
                    d.set(i, j, v);
                }
            }
        }
        WeightMatrix::from_dense(d)
    }

    /// Uniform global-average matrix (PmSGD's implicit W = 11ᵀ/n).
    pub fn global_average(n: usize) -> WeightMatrix {
        let mut d = SymMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                d.set(i, j, 1.0 / n as f64);
            }
        }
        WeightMatrix::from_dense(d)
    }
}

impl CommEngine for WeightMatrix {
    fn n(&self) -> usize {
        self.n
    }

    fn row(&self, i: usize) -> &[RowEntry] {
        &self.rows[i]
    }
}

/// Metropolis–Hastings weights for a topology (dense reference builder;
/// the trainer uses [`super::sparse::SparseWeights::metropolis_hastings`]).
pub fn metropolis_hastings(topo: &Topology) -> WeightMatrix {
    let n = topo.n;
    let mut d = SymMatrix::zeros(n);
    for i in 0..n {
        for &j in topo.neighbors(i) {
            if j > i {
                let w = 1.0 / (1.0 + topo.degree(i).max(topo.degree(j)) as f64);
                d.set(i, j, w);
            }
        }
    }
    for i in 0..n {
        let off: f64 = (0..n).filter(|&j| j != i).map(|j| d.get(i, j)).sum();
        d.set(i, i, 1.0 - off);
    }
    WeightMatrix::from_dense(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Kind;

    fn all_kinds_n8() -> Vec<WeightMatrix> {
        [Kind::Ring, Kind::Mesh, Kind::Full, Kind::Star, Kind::SymExp]
            .iter()
            .map(|&k| metropolis_hastings(&Topology::build(k, 8)))
            .collect()
    }

    #[test]
    fn doubly_stochastic_and_symmetric() {
        for w in all_kinds_n8() {
            assert!(w.stochasticity_error() < 1e-12);
            assert!(w.dense.asymmetry() < 1e-15);
        }
    }

    #[test]
    fn weights_nonnegative_with_positive_diagonal() {
        for w in all_kinds_n8() {
            for i in 0..w.n {
                assert!(w.self_weight(i) > 0.0, "w_ii must be > 0");
                for &(_, wij) in w.row(i) {
                    assert!(wij >= 0.0);
                }
            }
        }
    }

    #[test]
    fn rows_include_self_and_match_dense() {
        let w = metropolis_hastings(&Topology::build(Kind::Ring, 6));
        for i in 0..6 {
            assert!(w.row(i).iter().any(|&(j, _)| j as usize == i));
            let s: f32 = w.row(i).iter().map(|&(_, v)| v).sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn top_eigenvalue_is_one() {
        for w in all_kinds_n8() {
            let ev = w.eigenvalues();
            assert!((ev.last().unwrap() - 1.0).abs() < 1e-9);
            assert!(ev[0] > -1.0 + 1e-9, "spectrum in (-1, 1]");
        }
    }

    #[test]
    fn lazy_is_positive_definite() {
        let w = metropolis_hastings(&Topology::build(Kind::Ring, 8));
        let lz = w.lazy();
        assert!(lz.is_positive_definite());
        assert!(lz.stochasticity_error() < 1e-12);
        // Lazy matrix halves the gossip strength but keeps the fixed point.
        assert!((lz.dense.get(0, 0) - (0.5 + w.dense.get(0, 0) / 2.0)).abs() < 1e-12);
    }

    #[test]
    fn global_average_matrix() {
        let w = WeightMatrix::global_average(4);
        assert!(w.stochasticity_error() < 1e-12);
        assert_eq!(w.row(0).len(), 4);
        let ev = w.eigenvalues();
        // eigenvalues: 1 with multiplicity 1, 0 with multiplicity n-1
        assert!((ev[3] - 1.0).abs() < 1e-9 && ev[2].abs() < 1e-9);
    }

    #[test]
    fn ring_mh_matches_hand_computation() {
        // Ring n=4: every degree 2 -> off-diag 1/3, diag 1/3.
        let w = metropolis_hastings(&Topology::build(Kind::Ring, 4));
        assert!((w.dense.get(0, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((w.dense.get(0, 0) - 1.0 / 3.0).abs() < 1e-12);
    }
}
