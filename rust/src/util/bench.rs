//! Micro-benchmark harness (no `criterion` offline): warmup + timed
//! iterations, robust statistics, throughput reporting. Used by every
//! target in `rust/benches/` (all declared `harness = false`).
//!
//! `--json <path>` (see [`Bench::write_json_arg`]) dumps the collected
//! measurements as one JSON object keyed by case name — what CI merges
//! into the `BENCH_<PR>.json` perf-trajectory artifact.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// Optional bytes processed per iteration (enables GB/s reporting).
    pub bytes_per_iter: Option<f64>,
    /// Optional "items" per iteration (params, requests, ...).
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    pub fn gibps(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b / self.mean_ns * 1e9 / (1024.0 * 1024.0 * 1024.0))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12}   median {:>12}   p10..p90 [{} .. {}]",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
        );
        if let Some(g) = self.gibps() {
            s.push_str(&format!("   {g:.2} GiB/s"));
        }
        if let Some(items) = self.items_per_iter {
            let per = self.mean_ns / items;
            s.push_str(&format!("   {per:.2} ns/item"));
        }
        s
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner: calibrates iteration count to `target_time`, then
/// collects `samples` batches and reports robust percentiles.
pub struct Bench {
    pub warmup: Duration,
    pub target_time: Duration,
    pub samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // CLI/env escape hatch for CI: DECENTLAM_BENCH_FAST=1 shrinks runs.
        let fast = std::env::var("DECENTLAM_BENCH_FAST").is_ok();
        Bench {
            warmup: Duration::from_millis(if fast { 20 } else { 150 }),
            target_time: Duration::from_millis(if fast { 60 } else { 400 }),
            samples: if fast { 8 } else { 20 },
            results: Vec::new(),
        }
    }

    /// Time `f`, which performs ONE logical iteration per call.
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.case_full(name, None, None, &mut f)
    }

    /// Time with a bytes-per-iteration annotation (GB/s reporting).
    pub fn case_bytes<F: FnMut()>(&mut self, name: &str, bytes: f64, mut f: F) -> &Measurement {
        self.case_full(name, Some(bytes), None, &mut f)
    }

    /// Time with an items-per-iteration annotation (ns/item reporting).
    pub fn case_items<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> &Measurement {
        self.case_full(name, None, Some(items), &mut f)
    }

    fn case_full(
        &mut self,
        name: &str,
        bytes: Option<f64>,
        items: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &Measurement {
        // Warmup + calibration. Divide by the MEASURED elapsed time,
        // not the warmup target: a slow final iteration overshoots the
        // target, and target/iters would underestimate per_iter (and
        // oversize the timed batches).
        let start = Instant::now();
        let mut calib_iters = 0u64;
        while start.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let batch = ((self.target_time.as_secs_f64() / self.samples as f64) / per_iter)
            .ceil()
            .max(1.0) as u64;

        let mut sample_ns: Vec<f64> = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t.elapsed().as_nanos() as f64 / batch as f64;
            sample_ns.push(ns);
            total_iters += batch;
        }
        sample_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |q: f64| sample_ns[((sample_ns.len() - 1) as f64 * q).round() as usize];
        let mean = sample_ns.iter().sum::<f64>() / sample_ns.len() as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            median_ns: pick(0.5),
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
            bytes_per_iter: bytes,
            items_per_iter: items,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Machine-readable dump of every collected case: one JSON object
    /// keyed by case name, values carrying the robust statistics
    /// (`median_ns` is the perf-trajectory headline; mean/percentiles
    /// and iteration counts ride along for context).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        for (idx, m) in self.results.iter().enumerate() {
            if idx > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "  \"{}\": {{\"median_ns\": {:.3}, \"mean_ns\": {:.3}, \"p10_ns\": {:.3}, \
                 \"p90_ns\": {:.3}, \"iters\": {}}}",
                json_escape(&m.name),
                m.median_ns,
                m.mean_ns,
                m.p10_ns,
                m.p90_ns,
                m.iters
            ));
        }
        out.push_str("\n}\n");
        out
    }

    /// Honor a bench target's `--json <path>` flag: write [`Bench::to_json`]
    /// to the path and say so. No flag = no-op, so every target can call
    /// this unconditionally at the end of `main`.
    pub fn write_json_arg(&self, args: &super::cli::Args) -> std::io::Result<()> {
        if let Some(path) = args.get("json") {
            std::fs::write(path, self.to_json())?;
            println!("wrote {} cases to {path}", self.results.len());
        }
        Ok(())
    }
}

/// The single sanctioned wall-clock source outside this module
/// (determinism rule D02, DESIGN.md §12). Wall time is
/// observability-only: values read here may feed report-side fields
/// like `TrainReport::grad_seconds`, but must never reach manifests,
/// scenario digests, checkpoints, or the telemetry stream — those
/// replay bitwise, and wall time never does.
pub struct WallTimer {
    t0: Instant,
}

impl WallTimer {
    pub fn start() -> Self {
        Self { t0: Instant::now() }
    }

    /// Seconds since construction (or the last [`WallTimer::restart`]).
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Re-arm, returning the seconds elapsed up to this instant.
    pub fn restart(&mut self) -> f64 {
        let s = self.elapsed_s();
        self.t0 = Instant::now();
        s
    }

    /// Whole nanoseconds since construction (saturating at u64::MAX —
    /// ~584 years, i.e. never in practice).
    pub fn elapsed_ns(&self) -> u64 {
        u64::try_from(self.t0.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

/// Deterministic log2 duration bucket: the number of significant bits
/// in the ns value (0 ns → bucket 0, 1 ns → 1, 1–2 µs → 11, ...). The
/// `timing` event histograms (DESIGN.md §14) use exactly this mapping —
/// 65 possible buckets cover the whole u64 range with no float math.
pub fn log2_ns_bucket(ns: u64) -> i32 {
    (u64::BITS - ns.leading_zeros()) as i32
}

/// Cross-thread per-phase nanosecond accumulators for the run profiler
/// (`--profile`, DESIGN.md §14). Lives in this module because it is
/// wall-clock plumbing behind the D02 fence: [`gossip-exchange`'s
/// encode/exchange spans](crate::optim::gossip_exchange) add into it
/// through `RoundCtx`, and the trainer reads before/after deltas to
/// attribute the remainder of a round to the update phase. Relaxed
/// atomics: counters are monotone sums read only between rounds.
///
/// Profiling is observability-only — values recorded here feed the
/// non-deterministic `timing` event class and nothing else.
#[derive(Debug, Default)]
pub struct PhaseClock {
    encode_ns: std::sync::atomic::AtomicU64,
    exchange_ns: std::sync::atomic::AtomicU64,
}

impl PhaseClock {
    pub fn new() -> PhaseClock {
        PhaseClock::default()
    }

    pub fn add_encode(&self, ns: u64) {
        self.encode_ns.fetch_add(ns, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn add_exchange(&self, ns: u64) {
        self.exchange_ns.fetch_add(ns, std::sync::atomic::Ordering::Relaxed);
    }

    /// Cumulative (encode, exchange) nanoseconds.
    pub fn totals(&self) -> (u64, u64) {
        (
            self.encode_ns.load(std::sync::atomic::Ordering::Relaxed),
            self.exchange_ns.load(std::sync::atomic::Ordering::Relaxed),
        )
    }
}

/// Per-lane busy-time meter for the node executor (`--profile`,
/// DESIGN.md §14): every executor dispatch wraps its block body in a
/// [`WallTimer`] span and adds the duration to that lane's counter, so
/// the `timing` event can report how evenly phase work spreads across
/// pool lanes. Lane 0 doubles as the serial/inline lane.
#[derive(Debug)]
pub struct LaneMeter {
    lanes: Vec<std::sync::atomic::AtomicU64>,
}

impl LaneMeter {
    pub fn new(lanes: usize) -> LaneMeter {
        LaneMeter {
            lanes: (0..lanes.max(1)).map(|_| std::sync::atomic::AtomicU64::new(0)).collect(),
        }
    }

    /// Add a busy span to `lane` (clamped into range so a dispatch can
    /// never index out of bounds, whatever the block count).
    pub fn add(&self, lane: usize, ns: u64) {
        let i = lane.min(self.lanes.len() - 1);
        self.lanes[i].fetch_add(ns, std::sync::atomic::Ordering::Relaxed);
    }

    /// Cumulative busy nanoseconds per lane.
    pub fn snapshot(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.load(std::sync::atomic::Ordering::Relaxed)).collect()
    }
}

/// Minimal JSON string escaping (case names are ASCII identifiers plus
/// spaces/=/punctuation; quotes and backslashes are the only hazards).
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Re-export of `black_box` so bench targets only import this module.
#[inline]
pub fn opaque<T>(x: T) -> T {
    black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("DECENTLAM_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        let m = b.case("noop-ish", || {
            acc = opaque(acc.wrapping_add(1));
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.p10_ns <= m.p90_ns);
    }

    #[test]
    fn gibps_annotation() {
        let m = Measurement {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            p10_ns: 1e9,
            p90_ns: 1e9,
            bytes_per_iter: Some((1024 * 1024 * 1024) as f64),
            items_per_iter: None,
        };
        assert!((m.gibps().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn json_dump_is_well_formed_and_keyed_by_case() {
        std::env::set_var("DECENTLAM_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        b.case("alpha d=64", || {
            acc = opaque(acc.wrapping_add(1));
        });
        b.case("beta \"quoted\"", || {
            acc = opaque(acc.wrapping_add(3));
        });
        let json = b.to_json();
        let v = crate::util::json::Value::parse(&json).expect("bench JSON must parse");
        assert_eq!(v.as_obj().unwrap().len(), 2);
        let median =
            v.get("alpha d=64").unwrap().get("median_ns").unwrap().as_f64().unwrap();
        assert!(median > 0.0);
        let iters = v.get("alpha d=64").unwrap().get("iters").unwrap().as_usize().unwrap();
        assert!(iters > 0);
        assert!(v.get("beta \"quoted\"").is_ok(), "escaping must round-trip");
    }

    #[test]
    fn wall_timer_is_monotone_and_restartable() {
        let mut t = WallTimer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(a >= 0.0 && b >= a);
        let s = t.restart();
        assert!(s >= b);
        assert!(t.elapsed_s() < s + 60.0);
    }

    #[test]
    fn log2_buckets_cover_the_range() {
        assert_eq!(log2_ns_bucket(0), 0);
        assert_eq!(log2_ns_bucket(1), 1);
        assert_eq!(log2_ns_bucket(2), 2);
        assert_eq!(log2_ns_bucket(3), 2);
        assert_eq!(log2_ns_bucket(1024), 11);
        assert_eq!(log2_ns_bucket(u64::MAX), 64);
    }

    #[test]
    fn phase_clock_and_lane_meter_accumulate() {
        let c = PhaseClock::new();
        c.add_encode(5);
        c.add_encode(7);
        c.add_exchange(100);
        assert_eq!(c.totals(), (12, 100));

        let m = LaneMeter::new(3);
        m.add(0, 10);
        m.add(2, 30);
        m.add(99, 1); // out-of-range lanes clamp to the last
        assert_eq!(m.snapshot(), vec![10, 0, 31]);
        // Zero lanes still yields one usable lane.
        let m = LaneMeter::new(0);
        m.add(0, 4);
        assert_eq!(m.snapshot(), vec![4]);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
