//! Tiny CLI argument parser (no `clap` offline): `--key value`,
//! `--key=value` and bare positional arguments.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line: positionals + `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (program name excluded).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    // bare boolean flag
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        Ok(self.get_f64(key, default as f64)? as f32)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        // NOTE: a bare `--flag` followed by a non-flag token consumes it
        // as the flag's value (documented greedy rule); bare booleans must
        // come last or use `--flag=true`.
        let a = parse("table3 run --nodes 8 --beta=0.9 --verbose");
        assert_eq!(a.positional, vec!["table3", "run"]);
        assert_eq!(a.get_usize("nodes", 0).unwrap(), 8);
        assert_eq!(a.get_f64("beta", 0.0).unwrap(), 0.9);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_str("name", "d"), "d");
        assert!(!a.get_bool("nope"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("--nodes eight");
        assert!(a.get_usize("nodes", 1).is_err());
    }

    #[test]
    fn negative_values_via_equals() {
        let a = parse("--offset=-3.5");
        assert_eq!(a.get_f64("offset", 0.0).unwrap(), -3.5);
    }
}
