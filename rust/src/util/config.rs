//! Experiment configuration: typed struct + JSON file loading + CLI
//! overrides (`--key value`). Every launcher entry point (`decentlam`
//! binary, examples, benches) builds one of these.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::cli::Args;
use super::json::Value;

/// Learning-rate schedule, following the paper's §7.1 protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// Constant learning rate (the theory sections / bias experiments).
    Constant,
    /// Linear warmup for `warmup_steps`, then ×0.1 decays at the given
    /// step milestones (the small-batch protocol of Goyal et al.).
    WarmupStep { warmup_steps: usize, milestones: Vec<usize> },
    /// Linear warmup then cosine annealing to zero over `total_steps`
    /// (the large-batch protocol of You et al.).
    WarmupCosine { warmup_steps: usize, total_steps: usize },
}

impl LrSchedule {
    /// Multiplier applied to the base LR at step `k`.
    pub fn factor(&self, k: usize) -> f64 {
        match self {
            LrSchedule::Constant => 1.0,
            LrSchedule::WarmupStep { warmup_steps, milestones } => {
                if k < *warmup_steps {
                    (k + 1) as f64 / *warmup_steps as f64
                } else {
                    let hits = milestones.iter().filter(|&&m| k >= m).count() as i32;
                    0.1f64.powi(hits)
                }
            }
            LrSchedule::WarmupCosine { warmup_steps, total_steps } => {
                if k < *warmup_steps {
                    (k + 1) as f64 / *warmup_steps as f64
                } else {
                    let t = (k - warmup_steps) as f64
                        / (total_steps.saturating_sub(*warmup_steps)).max(1) as f64;
                    0.5 * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos())
                }
            }
        }
    }
}

/// One experiment run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of computing nodes n.
    pub nodes: usize,
    /// Topology name: ring | mesh | full | star | sym-exp | one-peer-exp |
    /// bipartite | erdos.
    pub topology: String,
    /// Optimizer: decentlam | dmsgd | dsgd | pmsgd | pmsgd-lars |
    /// da-dmsgd | awc-dmsgd | slowmo | qg-dmsgd | d2-dmsgd.
    pub optimizer: String,
    /// Model name from the AOT manifest ("native-logreg"/"native-mlp" use
    /// the in-crate gradient engines instead of PJRT).
    pub model: String,
    /// TOTAL batch per iteration, across all nodes. Realized as per-node
    /// micro-batches × gradient accumulation (DESIGN.md §2).
    pub total_batch: usize,
    /// Micro-batch per node per gradient evaluation.
    pub micro_batch: usize,
    /// Training steps (outer iterations).
    pub steps: usize,
    /// Base learning rate, linearly scaled by total batch (paper §7.1)
    /// when `linear_scaling` is set.
    pub lr: f64,
    pub linear_scaling: bool,
    /// Reference batch for linear scaling (lr_effective = lr * B/B_ref).
    pub lr_ref_batch: usize,
    /// Cap on the linear-scaling factor (Goyal et al. note linear scaling
    /// breaks past a point; our synthetic task destabilizes above ~8x).
    pub max_lr_scale: f64,
    pub momentum: f64,
    pub schedule: LrSchedule,
    /// Dirichlet concentration controlling inter-node heterogeneity
    /// (small = heterogeneous; the paper's b² knob).
    pub dirichlet_alpha: f64,
    pub seed: u64,
    /// Directory with AOT artifacts.
    pub artifacts: String,
    /// SlowMo sync period (steps) and slow-momentum coefficient.
    pub slowmo_period: usize,
    pub slowmo_beta: f64,
    /// Use positive-definite (lazy) Metropolis weights (Thm. 1 ablation).
    pub positive_definite: bool,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    /// Worker threads for the gradient/exchange/update phases
    /// (0 = one per hardware thread, 1 = serial).
    pub threads: usize,
    /// Fault-injection spec, e.g. `drop=0.1,straggle=0.05,seed=7`
    /// (empty = fault-free; see `sim::FaultSpec::parse`). The fault
    /// seed defaults to `seed` when the spec omits `seed=`.
    pub faults: String,
    /// Gossip payload codec, e.g. `int8,ef=true,seed=7` or `topk,k=0.05`
    /// (empty = raw fp32; see `comm::codec::CodecSpec::parse`). The
    /// codec seed defaults to `seed` when the spec omits `seed=`.
    pub codec: String,
    /// Asynchronous execution spec, e.g. `tau=2,spread=4,jitter=0.2`
    /// (empty = synchronous rounds; see `sim::clock::AsyncSpec::parse`).
    /// Nodes run on heterogeneous simulated clocks and mix neighbor
    /// payloads up to `tau` rounds stale; requires a static topology.
    /// The clock seed defaults to `seed` when the spec omits `seed=`.
    pub async_mode: String,
    /// Elastic-membership spec, e.g. `join=0.02,leave=0.02,nmin=8,
    /// nmax=64,seed=7` (empty = fixed roster; see
    /// `elastic::ChurnSpec::parse`). Nodes join/leave mid-run on a
    /// seeded schedule; the workload must supply `nmax` shards and
    /// `nodes` is the initial active count. Requires a static topology
    /// and synchronous execution. The churn seed defaults to `seed`
    /// when the spec omits `seed=`.
    pub churn: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            nodes: 8,
            topology: "sym-exp".into(),
            optimizer: "decentlam".into(),
            model: "native-mlp".into(),
            total_batch: 512,
            micro_batch: 64,
            steps: 300,
            lr: 0.1,
            linear_scaling: true,
            lr_ref_batch: 256,
            max_lr_scale: 8.0,
            momentum: 0.9,
            schedule: LrSchedule::WarmupStep { warmup_steps: 20, milestones: vec![150, 250] },
            dirichlet_alpha: 0.3,
            seed: 1,
            artifacts: "artifacts".into(),
            slowmo_period: 12,
            slowmo_beta: 0.7,
            positive_definite: false,
            eval_every: 0,
            threads: 0,
            faults: String::new(),
            codec: String::new(),
            async_mode: String::new(),
            churn: String::new(),
        }
    }
}

impl Config {
    /// Effective base LR after linear scaling.
    pub fn scaled_lr(&self) -> f64 {
        if self.linear_scaling {
            let scale =
                (self.total_batch as f64 / self.lr_ref_batch as f64).min(self.max_lr_scale);
            self.lr * scale
        } else {
            self.lr
        }
    }

    /// LR at step k.
    pub fn lr_at(&self, k: usize) -> f32 {
        (self.scaled_lr() * self.schedule.factor(k)) as f32
    }

    /// Gradient-accumulation micro-steps per node per iteration.
    pub fn accum_steps(&self) -> usize {
        let per_node = (self.total_batch + self.nodes - 1) / self.nodes;
        ((per_node + self.micro_batch - 1) / self.micro_batch).max(1)
    }

    /// Apply `--key value` CLI overrides.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        for (k, v) in &args.flags {
            self.apply_kv(k, v)
                .with_context(|| format!("applying --{k} {v}"))?;
        }
        Ok(())
    }

    /// Set one field by name.
    pub fn apply_kv(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "nodes" => self.nodes = v.parse()?,
            "topology" => self.topology = v.into(),
            "optimizer" | "opt" => self.optimizer = v.into(),
            "model" => self.model = v.into(),
            "total-batch" | "batch" => self.total_batch = v.parse()?,
            "micro-batch" => self.micro_batch = v.parse()?,
            "steps" => self.steps = v.parse()?,
            "lr" => self.lr = v.parse()?,
            "linear-scaling" => self.linear_scaling = v.parse()?,
            "lr-ref-batch" => self.lr_ref_batch = v.parse()?,
            "max-lr-scale" => self.max_lr_scale = v.parse()?,
            "momentum" | "beta" => self.momentum = v.parse()?,
            "schedule" => {
                self.schedule = match v {
                    "constant" => LrSchedule::Constant,
                    "warmup-step" => LrSchedule::WarmupStep {
                        warmup_steps: self.steps / 20,
                        milestones: vec![self.steps / 3, 2 * self.steps / 3],
                    },
                    "warmup-cosine" => LrSchedule::WarmupCosine {
                        warmup_steps: self.steps / 6,
                        total_steps: self.steps,
                    },
                    other => bail!("unknown schedule `{other}`"),
                }
            }
            "alpha" | "dirichlet-alpha" => self.dirichlet_alpha = v.parse()?,
            "seed" => self.seed = v.parse()?,
            "artifacts" => self.artifacts = v.into(),
            "slowmo-period" => self.slowmo_period = v.parse()?,
            "slowmo-beta" => self.slowmo_beta = v.parse()?,
            "positive-definite" | "pd" => self.positive_definite = v.parse()?,
            "eval-every" => self.eval_every = v.parse()?,
            "threads" => self.threads = v.parse()?,
            "faults" => {
                // Validate eagerly so a typo fails at the CLI, not
                // deep inside Trainer::new (seed resolution happens
                // there, where the run seed is known).
                crate::sim::FaultSpec::parse(v, 0)?;
                self.faults = v.into();
            }
            "codec" => {
                // Same eager validation as --faults: typos fail at the
                // CLI; seed resolution happens in Trainer::new.
                crate::comm::codec::CodecSpec::parse(v, 0)?;
                self.codec = v.into();
            }
            "async" => {
                // Eager validation like --faults/--codec. A bare
                // `--async` parses as "true" = all defaults.
                crate::sim::AsyncSpec::parse(v, 0)?;
                self.async_mode = v.into();
            }
            "churn" => {
                // Eager validation like the other spec flags; bound
                // resolution against the run's node count happens in
                // Trainer::new, where n is known.
                crate::elastic::ChurnSpec::parse(v, 0)?;
                self.churn = v.into();
            }
            "config" | "out" | "csv" | "quick" | "bw-gbps" | "fast" => {} // consumed elsewhere
            other => bail!("unknown config key `{other}`"),
        }
        Ok(())
    }

    /// Load overrides from a JSON config file, then CLI args on top.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Value::parse(&text)?;
        let mut cfg = Config::default();
        for (k, val) in v.as_obj()? {
            let s = match val {
                Value::Str(s) => s.clone(),
                Value::Num(x) => {
                    if x.fract() == 0.0 {
                        format!("{}", *x as i64)
                    } else {
                        format!("{x}")
                    }
                }
                Value::Bool(b) => format!("{b}"),
                _ => bail!("config key `{k}` must be scalar"),
            };
            cfg.apply_kv(k, &s)?;
        }
        Ok(cfg)
    }

    /// Build from CLI (optionally `--config file.json` first).
    pub fn from_args(args: &Args) -> Result<Config> {
        let mut cfg = match args.get("config") {
            Some(p) => Config::load(Path::new(p))?,
            None => Config::default(),
        };
        cfg.apply_args(args)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.nodes, 8);
        assert!(c.accum_steps() >= 1);
    }

    #[test]
    fn linear_scaling_math() {
        let mut c = Config::default();
        c.lr = 0.1;
        c.lr_ref_batch = 256;
        c.total_batch = 1024;
        assert!((c.scaled_lr() - 0.4).abs() < 1e-12);
        c.linear_scaling = false;
        assert!((c.scaled_lr() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn accum_steps_covers_total_batch() {
        let mut c = Config::default();
        c.nodes = 8;
        c.micro_batch = 64;
        for tb in [64, 512, 513, 4096] {
            c.total_batch = tb;
            let per_node_capacity = c.accum_steps() * c.micro_batch * c.nodes;
            assert!(per_node_capacity >= tb, "tb={tb}");
        }
    }

    #[test]
    fn warmup_step_schedule() {
        let s = LrSchedule::WarmupStep { warmup_steps: 10, milestones: vec![100, 200] };
        assert!(s.factor(0) < s.factor(5));
        assert!((s.factor(9) - 1.0).abs() < 1e-12);
        assert!((s.factor(50) - 1.0).abs() < 1e-12);
        assert!((s.factor(150) - 0.1).abs() < 1e-12);
        assert!((s.factor(250) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn warmup_cosine_schedule() {
        let s = LrSchedule::WarmupCosine { warmup_steps: 10, total_steps: 110 };
        assert!((s.factor(9) - 1.0).abs() < 1e-12);
        assert!(s.factor(60) < 1.0 && s.factor(60) > 0.0);
        assert!(s.factor(109) < 0.01);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            ["--nodes", "4", "--beta", "0.95", "--topology", "ring"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.momentum, 0.95);
        assert_eq!(cfg.topology, "ring");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Config::default();
        assert!(c.apply_kv("warp-drive", "on").is_err());
    }

    #[test]
    fn faults_key_validated_eagerly() {
        let mut c = Config::default();
        c.apply_kv("faults", "drop=0.1,straggle=0.05,seed=7").unwrap();
        assert_eq!(c.faults, "drop=0.1,straggle=0.05,seed=7");
        assert!(c.apply_kv("faults", "drop=2.0").is_err());
        assert!(c.apply_kv("faults", "gremlins=0.1").is_err());
    }

    #[test]
    fn codec_key_validated_eagerly() {
        let mut c = Config::default();
        c.apply_kv("codec", "int8,ef=true,seed=3").unwrap();
        assert_eq!(c.codec, "int8,ef=true,seed=3");
        c.apply_kv("codec", "topk,k=0.05").unwrap();
        assert!(c.apply_kv("codec", "zfp").is_err());
        assert!(c.apply_kv("codec", "topk,k=2").is_err());
        assert!(c.apply_kv("codec", "int8,gremlins=1").is_err());
    }

    #[test]
    fn async_key_validated_eagerly() {
        let mut c = Config::default();
        c.apply_kv("async", "tau=2,spread=4,jitter=0.2,seed=7").unwrap();
        assert_eq!(c.async_mode, "tau=2,spread=4,jitter=0.2,seed=7");
        c.apply_kv("async", "true").unwrap(); // bare --async: defaults
        assert!(c.apply_kv("async", "tau=99").is_err());
        assert!(c.apply_kv("async", "spread=0.1").is_err());
        assert!(c.apply_kv("async", "gremlins=1").is_err());
    }

    #[test]
    fn churn_key_validated_eagerly() {
        let mut c = Config::default();
        c.apply_kv("churn", "join=0.02,leave=0.02,nmin=8,nmax=64,seed=7").unwrap();
        assert_eq!(c.churn, "join=0.02,leave=0.02,nmin=8,nmax=64,seed=7");
        c.apply_kv("churn", "true").unwrap(); // bare --churn: defaults
        assert!(c.apply_kv("churn", "join=2").is_err());
        assert!(c.apply_kv("churn", "nmin=0").is_err());
        assert!(c.apply_kv("churn", "gremlins=1").is_err());
    }

    #[test]
    fn json_config_file() {
        let dir = std::env::temp_dir().join("decentlam_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"nodes": 16, "optimizer": "dmsgd", "lr": 0.05}"#).unwrap();
        let cfg = Config::load(&p).unwrap();
        assert_eq!(cfg.nodes, 16);
        assert_eq!(cfg.optimizer, "dmsgd");
        assert!((cfg.lr - 0.05).abs() < 1e-12);
    }
}
